//! Bench: codec-pipeline encode/decode throughput at paper-model sizes.
//!
//! The transport runs once per aggregated client per round, on the
//! server's critical path; it must stay cheap next to ClientUpdate. Run
//! with `cargo bench --bench codec_pipeline`.
//!
//! Thin wrapper — the body lives in `fedavg::obs::bench`, and the
//! canonical entry point is `fedavg bench`, which also records the
//! committed `BENCH_codec_pipeline.json` snapshot (DESIGN.md §10).

use fedavg::obs::bench;
use fedavg::util::bench::Bencher;

fn main() -> fedavg::Result<()> {
    let mut b = Bencher::default();
    println!("codec_pipeline — encode/measure/decode at CNN size (1.66M params)\n");
    bench::codec_pipeline(&mut b)
}
