//! Bench: codec-pipeline encode/decode throughput at paper-model sizes.
//!
//! The transport runs once per aggregated client per round, on the
//! server's critical path; it must stay cheap next to ClientUpdate. Run
//! with `cargo bench --bench codec_pipeline`.

use fedavg::comms::wire::Pipeline;
use fedavg::data::rng::Rng;
use fedavg::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();
    println!("codec_pipeline — encode/measure/decode at CNN size (1.66M params)\n");

    let dim = 1_663_370; // MNIST CNN parameter count
    let mut rng = Rng::new(3);
    let base: Vec<f32> = (0..dim).map(|_| rng.gauss_f32() * 0.1).collect();
    let mut theta = base.clone();
    for i in (0..dim).step_by(100) {
        theta[i] += 0.05; // ~1% round-to-round change
    }

    for spec in ["q8", "topk:0.01", "topk:0.01|q8"] {
        let p = Pipeline::parse(spec).unwrap();
        let mut enc_rng = Rng::new(7);
        b.bench_elems(&format!("run/{spec}"), dim as f64, || {
            std::hint::black_box(p.run(&theta, None, &mut enc_rng).unwrap());
        });
    }

    // delta downlink: measure (pricing pass, no allocation of the frame)
    // vs full encode+serialize
    let delta = Pipeline::parse("delta").unwrap();
    b.bench_elems("measure/delta", dim as f64, || {
        std::hint::black_box(delta.measure(&theta, Some(&base)).unwrap());
    });
    let mut enc_rng = Rng::new(9);
    b.bench_elems("encode/delta", dim as f64, || {
        std::hint::black_box(delta.encode(&theta, Some((1, &base)), &mut enc_rng).unwrap());
    });

    // frame round-trip at the wire level
    let p = Pipeline::parse("topk:0.01|q8").unwrap();
    let frame = p.encode(&theta, None, &mut Rng::new(11)).unwrap();
    println!("\n  topk:0.01|q8 frame: {} bytes (dense {})", frame.wire_bytes(), 4 * dim);
    b.bench_elems("decode/topk:0.01|q8", dim as f64, || {
        std::hint::black_box(frame.decode(None).unwrap());
    });
}
