//! Bench: ClientUpdate latency per model/batch-size — the paper's unit of
//! client-side work. Measures one local SGD step, a full-batch gradient,
//! an apply, and a full E=1 ClientUpdate through the PJRT executables.
//!
//! Requires `make artifacts`; skips cleanly otherwise.

use fedavg::config::BatchSize;
use fedavg::data::{Dataset, Examples};
use fedavg::federated::{local_update, LocalSpec};
use fedavg::runtime::Engine;
use fedavg::util::bench::Bencher;

fn toy_image(n: usize, dim: usize) -> Dataset {
    let mut rng = fedavg::data::rng::Rng::new(5);
    Dataset {
        name: "bench".into(),
        examples: Examples::Image {
            x: (0..n * dim).map(|_| rng.f32()).collect(),
            y: (0..n).map(|_| rng.below(10) as i32).collect(),
            dim,
        },
    }
}

fn main() {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return;
    }
    let engine = Engine::load(dir).expect("engine");
    let mut b = Bencher::quick();
    println!("client_update — per-executable and per-ClientUpdate latency\n");

    for (mname, dim) in [("mnist_2nn", 784usize), ("mnist_cnn", 784)] {
        let model = engine.model(mname).expect("model");
        let theta = model.init(1).expect("init");
        let data = toy_image(60, dim);
        let idxs: Vec<usize> = (0..60).collect();

        let batch10 = data.padded_batch(&idxs[..10], 10);
        b.bench(&format!("{mname}/step_b10"), || {
            std::hint::black_box(model.step(&theta, &batch10, 0.05).unwrap());
        });

        let cap = model.meta().acc_batch;
        let batch_acc = data.padded_batch(&idxs[..cap.min(60)], cap);
        b.bench(&format!("{mname}/gradacc_b{cap}"), || {
            std::hint::black_box(model.gradacc(&theta, &batch_acc).unwrap());
        });

        let g = vec![0.01f32; theta.len()];
        b.bench(&format!("{mname}/apply"), || {
            std::hint::black_box(model.apply(&theta, &g, 0.05).unwrap());
        });

        b.bench(&format!("{mname}/eval_b{cap}"), || {
            std::hint::black_box(model.eval_batch(&theta, &batch_acc).unwrap());
        });

        // one full ClientUpdate: E=1, B=10 over 60 examples (6 steps)
        let spec = LocalSpec {
            epochs: 1,
            batch: BatchSize::Fixed(10),
            lr: 0.05,
            prox_mu: 0.0,
            shuffle_seed: 3,
        };
        b.bench(&format!("{mname}/client_update_E1_B10_n60"), || {
            std::hint::black_box(local_update(&model, &data, &idxs, &theta, &spec).unwrap());
        });
    }

    let stats = engine.stats();
    println!(
        "\nengine: {} steps / {} gradaccs / {} evals, compile {:.1}s, execute {:.1}s",
        stats.steps,
        stats.gradaccs,
        stats.evals,
        stats.compile_ms as f64 / 1e3,
        stats.execute_ms as f64 / 1e3
    );
}
