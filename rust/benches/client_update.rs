//! Bench: ClientUpdate latency per model/batch-size — the paper's unit of
//! client-side work. Measures one local SGD step, a full-batch gradient,
//! an apply, and a full E=1 ClientUpdate through the PJRT executables.
//!
//! Requires `make artifacts`; skips cleanly otherwise.
//!
//! Thin wrapper — the body lives in `fedavg::obs::bench`, and the
//! canonical entry point is `fedavg bench`, which also records the
//! committed `BENCH_client_update.json` snapshot (DESIGN.md §10).

use fedavg::obs::bench::{self, AreaStatus};
use fedavg::util::bench::Bencher;

fn main() -> fedavg::Result<()> {
    let mut b = Bencher::quick();
    println!("client_update — per-executable and per-ClientUpdate latency\n");
    if let AreaStatus::Skipped(why) = bench::client_update(&mut b)? {
        eprintln!("SKIP: {why}");
    }
    Ok(())
}
