//! Bench: event-queue scheduling overhead at fleet scale. The
//! coordinator runs once per round on the serving path, so its cost must
//! stay negligible next to a round's training work — this pins the
//! select → over-select → schedule → account pipeline at 1k / 10k / 100k
//! simulated clients.
//!
//! Thin wrapper — the body lives in `fedavg::obs::bench`, and the
//! canonical entry point is `fedavg bench`, which also records the
//! committed `BENCH_fleet_round.json` snapshot (DESIGN.md §10).

use fedavg::obs::bench;
use fedavg::util::bench::Bencher;

fn main() -> fedavg::Result<()> {
    let mut b = Bencher::default();
    println!("fleet_round — coordinator overhead per round\n");
    bench::fleet_round(&mut b)
}
