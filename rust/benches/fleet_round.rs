//! Bench: event-queue scheduling overhead at fleet scale. The
//! coordinator runs once per round on the serving path, so its cost must
//! stay negligible next to a round's training work — this pins the
//! select → over-select → schedule → account pipeline at 1k / 10k / 100k
//! simulated clients.

use fedavg::coordinator::{schedule_round, FleetConfig, FleetProfile, FleetSim};
use fedavg::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();
    println!("fleet_round — coordinator overhead per round\n");

    // full round pipeline: diurnal online scan + sample + schedule
    for k in [1_000usize, 10_000, 100_000] {
        let cfg = FleetConfig {
            profile: FleetProfile::Mobile,
            overselect: 0.3,
            deadline_s: Some(90.0),
            ..Default::default()
        };
        let m = (k / 100).max(1); // C = 0.01
        let mut sim =
            FleetSim::new(&cfg, k, m, 6_653_480, 300.0, 7).expect("sim");
        b.bench_elems(&format!("fleet_round/k={k}"), k as f64, || {
            std::hint::black_box(sim.step());
        });
    }

    // scheduler alone: the event queue at growing dispatch sizes
    for n in [1_000usize, 10_000, 100_000] {
        let mut rng = fedavg::data::rng::Rng::new(11);
        let durations: Vec<(usize, f64)> =
            (0..n).map(|c| (c, 1.0 + 99.0 * rng.f64())).collect();
        let m = n * 3 / 4;
        b.bench_elems(&format!("schedule_round/n={n}"), n as f64, || {
            std::hint::black_box(schedule_round(m, Some(80.0), &durations));
        });
    }
}
