//! Bench: the server's parameter-vector hot path (weighted averaging,
//! axpy, interpolation) across the paper's model sizes. Target: memory-
//! bandwidth bound (GB/s scale), so FedAvg's server step never dominates
//! a round (§Perf L3).
//!
//! Thin wrapper — the body lives in `fedavg::obs::bench`, and the
//! canonical entry point is `fedavg bench`, which also records the
//! committed `BENCH_params_hot_path.json` snapshot (DESIGN.md §10).

use fedavg::obs::bench;
use fedavg::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();
    println!("params_hot_path — model-size param vectors\n");
    bench::params_hot_path(&mut b);
}
