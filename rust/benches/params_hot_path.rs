//! Bench: the server's parameter-vector hot path (weighted averaging,
//! axpy, interpolation) across the paper's model sizes. Target: memory-
//! bandwidth bound (GB/s scale), so FedAvg's server step never dominates
//! a round (§Perf L3).

use fedavg::params;
use fedavg::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();
    println!("params_hot_path — model-size param vectors\n");

    // paper model sizes: 2NN, char-LSTM, CIFAR CNN, MNIST CNN, word-LSTM
    for (name, p) in [
        ("2nn_199k", 199_210usize),
        ("lstm_820k", 820_522),
        ("cifar_1.07m", 1_068_298),
        ("cnn_1.66m", 1_663_370),
        ("word_4.36m", 4_359_120),
    ] {
        let vecs: Vec<Vec<f32>> = (0..10)
            .map(|i| (0..p).map(|j| ((i * j) % 97) as f32 * 0.01).collect())
            .collect();
        let weighted: Vec<(f32, &[f32])> = vecs
            .iter()
            .enumerate()
            .map(|(i, v)| (1.0 + i as f32, v.as_slice()))
            .collect();

        b.bench_elems(
            &format!("weighted_mean/10clients/{name}"),
            (10 * p) as f64,
            || {
                std::hint::black_box(params::weighted_mean(&weighted));
            },
        );

        let mut acc = vec![0.0f32; p];
        b.bench_elems(&format!("axpy/{name}"), p as f64, || {
            params::axpy(&mut acc, 0.5, &vecs[0]);
            std::hint::black_box(&acc);
        });

        b.bench_elems(&format!("interpolate/{name}"), p as f64, || {
            std::hint::black_box(params::interpolate(&vecs[0], &vecs[1], 0.37));
        });
    }

    // GB/s summary for the averaging loop (reads 10 vecs + writes out per accumulate)
    if let Some(r) = b
        .results()
        .iter()
        .find(|r| r.name == "weighted_mean/10clients/cnn_1.66m")
    {
        let bytes = (2 * 10) as f64 * 1_663_370.0 * 4.0; // read acc+src per axpy
        println!(
            "\nweighted_mean(cnn) effective bandwidth: {:.2} GB/s",
            bytes / (r.mean_ns / 1e9) / 1e9
        );
    }
}
