//! Bench: communication-simulator overhead. The simulator must be
//! negligible next to a round's real work (it runs once per round).

use fedavg::comms::{model_bytes, Availability, CommModel, CommSim};
use fedavg::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();
    println!("comms_sim — accounting overhead per round\n");

    for m in [1usize, 10, 100, 1000] {
        let mut sim = CommSim::new(CommModel::default(), 7);
        let bytes = model_bytes(1_663_370);
        b.bench(&format!("round_accounting/m={m}"), || {
            std::hint::black_box(sim.round(m, bytes));
        });
    }

    for k in [100usize, 1000, 100_000] {
        let av = Availability::new(0.7, 9);
        let mut round = 0u64;
        b.bench(&format!("availability/k={k}"), || {
            round += 1;
            std::hint::black_box(av.online(round, k));
        });
    }
}
