//! Bench: full FedAvg round latency vs client fraction C — the end-to-end
//! number behind every table (one round = sample, m ClientUpdates,
//! weighted average, comm accounting). Also reports the coordinator-only
//! overhead (everything but executable execution), which §Perf requires
//! to stay <5% of a round.

use fedavg::config::{BatchSize, FedConfig, Partition};
use fedavg::exper::mnist_fed;
use fedavg::federated::{self, ServerOptions};
use fedavg::runtime::Engine;
use fedavg::util::bench::Bencher;
use std::time::Duration;

#[allow(clippy::disallowed_methods)] // Instant::now: this bench measures wall time by design
fn main() {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return;
    }
    let engine = Engine::load(dir).expect("engine");
    let fed = mnist_fed(0.05, Partition::Iid, 3);
    println!(
        "round_e2e — {} clients x {} examples (mnist_2nn)\n",
        fed.num_clients(),
        fed.total_examples() / fed.num_clients()
    );
    let mut b = Bencher::new(Duration::from_millis(100), Duration::from_secs(3));

    for c in [0.1, 0.5, 1.0] {
        let cfg = FedConfig {
            model: "mnist_2nn".into(),
            c,
            e: 1,
            b: BatchSize::Fixed(10),
            lr: 0.05,
            rounds: 1, // bench one round at a time
            eval_every: 10_000, // no eval inside the timed round
            seed: 11,
            ..Default::default()
        };
        b.bench(&format!("fedavg_round/C={c}"), || {
            let opts = ServerOptions {
                eval_cap: Some(1),
                ..Default::default()
            };
            std::hint::black_box(federated::run(&engine, &fed, &cfg, opts).unwrap());
        });
    }

    // coordinator overhead: total wall minus engine execute time
    let before = engine.stats();
    let cfg = FedConfig {
        model: "mnist_2nn".into(),
        c: 1.0,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.05,
        rounds: 5,
        eval_every: 10_000,
        seed: 13,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    federated::run(
        &engine,
        &fed,
        &cfg,
        ServerOptions {
            eval_cap: Some(1),
            ..Default::default()
        },
    )
    .unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let exec = (engine.stats().execute_ms - before.execute_ms) as f64 / 1e3;
    println!(
        "\ncoordinator overhead: wall {wall:.2}s, executable time {exec:.2}s, \
         overhead {:.1}% (§Perf target <5%)",
        100.0 * (wall - exec).max(0.0) / wall
    );
}
