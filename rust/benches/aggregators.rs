//! Bench: aggregation rules at paper-model sizes.
//!
//! The aggregator runs once per round on the server's critical path. The
//! weighted mean is memory-bandwidth-bound; the robust order statistics
//! sort per coordinate (O(dim·m log m)) and must stay cheap next to m
//! ClientUpdates. Run with `cargo bench --bench aggregators`.

use fedavg::data::rng::Rng;
use fedavg::federated::aggregate::{AggConfig, Aggregator as _};
use fedavg::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();
    println!("aggregators — combine/step at 2NN size (199,210 params), m=50 clients\n");

    let dim = 199_210; // MNIST 2NN parameter count
    let m = 50;
    let mut rng = Rng::new(3);
    let deltas: Vec<Vec<f32>> = (0..m)
        .map(|_| (0..dim).map(|_| rng.gauss_f32() * 0.01).collect())
        .collect();
    let refs: Vec<(f32, &[f32])> = deltas.iter().map(|d| (600.0, d.as_slice())).collect();

    for spec in ["fedavg", "trimmed:0.1", "median"] {
        let agg = AggConfig {
            spec: spec.into(),
            ..Default::default()
        }
        .build()
        .unwrap();
        b.bench_elems(&format!("combine/{spec}"), dim as f64, || {
            std::hint::black_box(agg.combine(&refs).unwrap());
        });
    }

    // stateful server steps at CNN size (the heavyweight image model).
    // step() consumes its input, so feed the returned buffer back in —
    // no per-iteration clone polluting the measurement (the values drift
    // as the optimizer reprocesses its own output; only timing matters).
    let big = 1_663_370;
    let delta: Vec<f32> = (0..big).map(|_| rng.gauss_f32() * 0.01).collect();
    for spec in ["fedavgm", "fedadam"] {
        let mut agg = AggConfig {
            spec: spec.into(),
            ..Default::default()
        }
        .build()
        .unwrap();
        let mut round = 0u64;
        let mut buf = delta.clone();
        b.bench_elems(&format!("step/{spec} (1.66M params)"), big as f64, || {
            round += 1;
            buf = agg.step(round, std::mem::take(&mut buf)).unwrap();
            std::hint::black_box(buf.len());
        });
    }
}
