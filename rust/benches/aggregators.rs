//! Bench: aggregation rules at paper-model sizes.
//!
//! The aggregator runs once per round on the server's critical path. The
//! weighted mean is memory-bandwidth-bound; the robust order statistics
//! sort per coordinate (O(dim·m log m)) and must stay cheap next to m
//! ClientUpdates. Run with `cargo bench --bench aggregators`.
//!
//! Thin wrapper — the body lives in `fedavg::obs::bench`, and the
//! canonical entry point is `fedavg bench`, which also records the
//! committed `BENCH_aggregators.json` snapshot (DESIGN.md §10).

use fedavg::obs::bench;
use fedavg::util::bench::Bencher;

fn main() -> fedavg::Result<()> {
    let mut b = Bencher::default();
    println!("aggregators — combine/step at 2NN size (199,210 params), m=50 clients\n");
    bench::aggregators(&mut b)
}
