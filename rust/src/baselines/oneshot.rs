//! One-shot averaging — the related-work endpoint (§1): each client
//! trains to (approximate) convergence on its local data once, the server
//! averages once. Known to be no better than a single client's model in
//! the worst case; we reproduce it as the contrast to iterative FedAvg.

use crate::config::BatchSize;
use crate::data::Federated;
use crate::federated::client::{local_update, LocalSpec};
use crate::params::weighted_mean;
use crate::runtime::{Engine, EvalSums};
use crate::Result;

#[derive(Debug, Clone)]
pub struct OneShotConfig {
    pub model: String,
    /// local epochs each client trains before the single average.
    pub epochs: usize,
    pub batch: BatchSize,
    pub lr: f64,
    pub seed: u64,
}

pub struct OneShotResult {
    /// test metrics of the averaged model.
    pub averaged: EvalSums,
    /// test metrics of the best *individual* client model.
    pub best_single: EvalSums,
}

/// Train every client once from the shared init, average once, evaluate.
pub fn run(
    engine: &Engine,
    fed: &Federated,
    cfg: &OneShotConfig,
    eval_cap: Option<usize>,
) -> Result<OneShotResult> {
    let model = engine.model(&cfg.model)?;
    let theta0 = model.init(cfg.seed as i32)?;
    let eval_idxs: Option<Vec<usize>> =
        eval_cap.map(|c| (0..fed.test.len().min(c)).collect());

    let mut updates = Vec::new();
    let mut best_single: Option<EvalSums> = None;
    for (ck, idxs) in fed.clients.iter().enumerate() {
        if idxs.is_empty() {
            continue;
        }
        let spec = LocalSpec {
            epochs: cfg.epochs,
            batch: cfg.batch,
            lr: cfg.lr as f32,
            prox_mu: 0.0,
            shuffle_seed: cfg.seed ^ (ck as u64).wrapping_mul(0xD1B54A32D192ED03),
        };
        let res = local_update(&model, &fed.train, idxs, &theta0, &spec)?;
        let sums = model.eval_dataset(&res.theta, &fed.test, eval_idxs.as_deref())?;
        if best_single
            .map(|b| sums.accuracy() > b.accuracy())
            .unwrap_or(true)
        {
            best_single = Some(sums);
        }
        updates.push((res.weight as f32, res.theta));
    }
    let refs: Vec<(f32, &[f32])> = updates.iter().map(|(w, t)| (*w, t.as_slice())).collect();
    let avg = weighted_mean(&refs);
    let averaged = model.eval_dataset(&avg, &fed.test, eval_idxs.as_deref())?;
    Ok(OneShotResult {
        averaged,
        best_single: best_single.expect("no non-empty clients"),
    })
}
