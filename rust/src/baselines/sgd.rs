//! Sequential minibatch SGD on the pooled (un-partitioned) training set.
//!
//! The paper's CIFAR baseline: "standard SGD training on the full training
//! set, using minibatches of size 100" — each minibatch update counts as
//! one communication round when compared against the federated runs.

use crate::data::rng::Rng;
use crate::data::Dataset;
use crate::metrics::LearningCurve;
use crate::params::ParamVec;
use crate::runtime::Engine;
use crate::Result;

#[derive(Debug, Clone)]
pub struct SgdConfig {
    pub model: String,
    pub batch: usize,
    pub lr: f64,
    pub lr_decay: f64,
    /// total minibatch updates (== "rounds" in the paper's comparison).
    pub updates: usize,
    pub eval_every: usize,
    pub target_accuracy: Option<f64>,
    pub seed: u64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        Self {
            model: "cifar_cnn".into(),
            batch: 100,
            lr: 0.1,
            lr_decay: 1.0,
            updates: 1000,
            eval_every: 50,
            target_accuracy: None,
            seed: 23,
        }
    }
}

pub struct SgdResult {
    pub accuracy: LearningCurve,
    pub test_loss: LearningCurve,
    pub final_theta: ParamVec,
    pub updates_run: u64,
}

/// Run sequential SGD; the learning curve is keyed by minibatch updates.
pub fn run(
    engine: &Engine,
    train: &Dataset,
    test: &Dataset,
    cfg: &SgdConfig,
    eval_cap: Option<usize>,
) -> Result<SgdResult> {
    let model = engine.model(&cfg.model)?;
    let cap = model
        .meta()
        .step_capacity_for(cfg.batch)
        .ok_or_else(|| anyhow::anyhow!(
            "no step executable for B={} on {}",
            cfg.batch,
            cfg.model
        ))?;
    let mut theta = model.init(cfg.seed as i32)?;
    let mut rng = Rng::new(cfg.seed ^ 0x56D);
    let n = train.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut cursor = 0usize;

    let eval_idxs: Option<Vec<usize>> = eval_cap.map(|c| (0..test.len().min(c)).collect());
    let mut accuracy = LearningCurve::new();
    let mut test_loss = LearningCurve::new();
    let mut updates_run = 0u64;

    for u in 1..=cfg.updates as u64 {
        updates_run = u;
        // epoch boundary: reshuffle
        if cursor + cfg.batch > n {
            rng.shuffle(&mut order);
            cursor = 0;
        }
        let chunk = &order[cursor..cursor + cfg.batch.min(n)];
        cursor += cfg.batch;
        let lr = (cfg.lr * cfg.lr_decay.powi(u as i32 - 1)) as f32;
        let batch = train.padded_batch(chunk, cap);
        theta = model.step(&theta, &batch, lr)?;

        if u % cfg.eval_every as u64 == 0 || u == cfg.updates as u64 {
            let sums = model.eval_dataset(&theta, test, eval_idxs.as_deref())?;
            accuracy.push(u, sums.accuracy());
            test_loss.push(u, sums.mean_loss());
            if let Some(t) = cfg.target_accuracy {
                if sums.accuracy() >= t {
                    break;
                }
            }
        }
    }
    Ok(SgdResult {
        accuracy,
        test_loss,
        final_theta: theta,
        updates_run,
    })
}
