//! Baselines the paper evaluates against.
//!
//! * [`sgd`] — standard sequential minibatch SGD on the pooled training
//!   set, "each minibatch update requires a communication round in the
//!   federated setting" (§3, CIFAR experiments / Table 3 / Figure 9).
//! * [`oneshot`] — one-shot averaging: train each client to (near)
//!   convergence once, average once (§1 related work endpoint).
//!
//! Both run over the same engine/artifact stack as
//! [`federated`](crate::federated) (DESIGN.md §1), so baseline-vs-FedAvg
//! comparisons differ only in the algorithm, never the substrate.

pub mod oneshot;
pub mod sgd;
