//! Baselines the paper evaluates against.
//!
//! * [`sgd`] — standard sequential minibatch SGD on the pooled training
//!   set, "each minibatch update requires a communication round in the
//!   federated setting" (§3, CIFAR experiments / Table 3 / Figure 9).
//! * [`oneshot`] — one-shot averaging: train each client to (near)
//!   convergence once, average once (§1 related work endpoint).

pub mod oneshot;
pub mod sgd;
