//! Compression primitives — the follow-up direction the paper's footnote
//! 7 cites (Konečný et al., "Federated Learning: Strategies for
//! Improving Communication Efficiency"): clients upload *compressed*
//! model deltas, trading accuracy-per-round for bytes-per-round.
//!
//! Two schemes, both with exact byte accounting:
//!
//! * [`top_k`] — magnitude sparsification: keep the k largest-|·|
//!   coordinates (indices + values on the wire). With *error feedback*
//!   ([`ErrorFeedback`]) the dropped mass re-enters the next round's
//!   delta, the standard fix for sparsification bias. Since the
//!   transport subsystem landed, the feedback residual is **per-client
//!   uplink state owned by [`comms::transport`](crate::comms::transport)**
//!   (one residual per client, advanced only when that client's update
//!   is actually encoded — DESIGN.md §6), and it is captured by run-state
//!   snapshots so resumed runs replay it exactly (DESIGN.md §8).
//! * [`quantize`] — uniform stochastic quantization to b bits with
//!   per-chunk scale (unbiased: E[deq(q(x))] = x).
//!
//! These are the *primitives*; composition, framing, and wire pricing
//! live one layer up in [`comms::wire`](crate::comms::wire), where a
//! registry-named codec pipeline (`topk:1000|q8`, `delta|q8`, …) chains
//! them behind one `wire_bytes` source of truth (DESIGN.md §6).

use crate::data::rng::Rng;

/// A sparsified update: sorted coordinate indices + their values.
#[derive(Debug, Clone)]
pub struct SparseUpdate {
    pub dim: usize,
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

/// Wire size of a `k`-coordinate sparse update without materializing it
/// — the fleet scheduler prices uplinks with this before any training
/// runs. Single source of truth with [`SparseUpdate::wire_bytes`].
pub fn sparse_wire_bytes(k: usize) -> u64 {
    (k * 8 + 16) as u64
}

/// Wire size of a `dim`-coordinate `bits`-bit quantized update without
/// materializing it. Single source of truth with
/// [`QuantizedUpdate::wire_bytes`].
pub fn quantized_wire_bytes(dim: usize, bits: u8) -> u64 {
    quantized_value_bytes(dim, bits) + 16
}

/// Bare value-payload size of `n` quantized coordinates (packed `bits`
/// codes + per-chunk scales), with no header — the frame layer in
/// [`comms::wire`](crate::comms::wire) adds its own.
pub fn quantized_value_bytes(n: usize, bits: u8) -> u64 {
    let codes = (n * bits as usize + 7) / 8;
    let scales = (n + QCHUNK - 1) / QCHUNK;
    (codes + scales * 8) as u64
}

impl SparseUpdate {
    /// Wire size: 4 bytes per index + 4 per value (+16 header).
    pub fn wire_bytes(&self) -> u64 {
        sparse_wire_bytes(self.idx.len())
    }

    pub fn densify(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }
}

/// Keep the `k` largest-magnitude coordinates of `update`.
pub fn top_k(update: &[f32], k: usize) -> SparseUpdate {
    let k = k.min(update.len());
    // partial select via nth_element-style sort of (|v|, i)
    let mut order: Vec<u32> = (0..update.len() as u32).collect();
    let nth = k.saturating_sub(1).min(order.len() - 1);
    order.select_nth_unstable_by(nth, |&a, &b| {
        update[b as usize]
            .abs()
            .partial_cmp(&update[a as usize].abs())
            .unwrap()
    });
    let mut idx: Vec<u32> = order[..k].to_vec();
    idx.sort_unstable();
    let val = idx.iter().map(|&i| update[i as usize]).collect();
    SparseUpdate {
        dim: update.len(),
        idx,
        val,
    }
}

/// Error feedback: accumulates what compression dropped and folds it
/// into the next update. Each instance is one client's uplink residual,
/// keyed and owned by [`comms::transport`](crate::comms::transport)
/// (DESIGN.md §6) and included in run-state snapshots (DESIGN.md §8).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
}

impl ErrorFeedback {
    /// Rebuild a residual captured by [`residual`](Self::residual) — the
    /// snapshot-restore path. An empty vector is the pristine state.
    pub fn from_residual(residual: Vec<f32>) -> Self {
        Self { residual }
    }

    /// The raw residual (empty until the first fold/record).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// `update += residual`; call before compressing.
    pub fn fold_in(&mut self, update: &mut [f32]) {
        if self.residual.is_empty() {
            self.residual = vec![0.0; update.len()];
            return;
        }
        for (u, r) in update.iter_mut().zip(&self.residual) {
            *u += r;
        }
    }

    /// Record `full - kept` as the new residual; call after compressing.
    pub fn record(&mut self, full: &[f32], kept: &SparseUpdate) {
        if self.residual.len() != full.len() {
            self.residual = vec![0.0; full.len()];
        }
        self.residual.copy_from_slice(full);
        for (&i, &v) in kept.idx.iter().zip(&kept.val) {
            self.residual[i as usize] -= v;
        }
    }

    /// Record `full - delivered` as the new residual — the general form
    /// of [`record`](Self::record) for any lossy codec output (the
    /// residual then also carries quantization error, not just the
    /// sparsified-away mass).
    pub fn record_dense(&mut self, full: &[f32], delivered: &[f32]) {
        assert_eq!(full.len(), delivered.len());
        if self.residual.len() != full.len() {
            self.residual = vec![0.0; full.len()];
        }
        for ((r, f), d) in self.residual.iter_mut().zip(full).zip(delivered) {
            *r = *f - *d;
        }
    }

    pub fn residual_norm(&self) -> f64 {
        crate::params::l2_norm(&self.residual)
    }
}

/// A b-bit uniformly quantized update with per-chunk scales.
#[derive(Debug, Clone)]
pub struct QuantizedUpdate {
    pub dim: usize,
    pub bits: u8,
    pub chunk: usize,
    /// (min, step) per chunk.
    pub scales: Vec<(f32, f32)>,
    /// packed little-endian codes, `bits` each.
    pub codes: Vec<u8>,
}

impl QuantizedUpdate {
    pub fn wire_bytes(&self) -> u64 {
        // truthful for any chunk size; the planning formula must agree
        // for the standard QCHUNK layout [`quantize`] produces
        if self.chunk == QCHUNK {
            debug_assert_eq!(
                (self.codes.len() + self.scales.len() * 8 + 16) as u64,
                quantized_wire_bytes(self.dim, self.bits)
            );
        }
        (self.codes.len() + self.scales.len() * 8 + 16) as u64
    }
}

/// Coordinates per quantization chunk (one `(min, step)` scale pair
/// each). Fixed by the wire format: frames do not carry it.
pub const QCHUNK: usize = 2048;

/// Unbiased stochastic uniform quantization to `bits` (1..=8).
pub fn quantize(update: &[f32], bits: u8, rng: &mut Rng) -> QuantizedUpdate {
    assert!((1..=8).contains(&bits), "bits in 1..=8");
    let levels = (1u32 << bits) - 1;
    let mut scales = Vec::new();
    let mut codes_vals: Vec<u32> = Vec::with_capacity(update.len());
    for chunk in update.chunks(QCHUNK) {
        let lo = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let step = if hi > lo { (hi - lo) / levels as f32 } else { 0.0 };
        scales.push((lo, step));
        for &v in chunk {
            let code = if step == 0.0 {
                0
            } else {
                // stochastic rounding -> unbiased
                let t = (v - lo) / step;
                let fl = t.floor();
                let p = t - fl;
                let up = (rng.f32() < p) as u32;
                (fl as u32 + up).min(levels)
            };
            codes_vals.push(code);
        }
    }
    // bit-pack
    let mut codes = Vec::with_capacity((codes_vals.len() * bits as usize + 7) / 8);
    let mut acc = 0u32;
    let mut nbits = 0u8;
    for c in codes_vals {
        acc |= c << nbits;
        nbits += bits;
        while nbits >= 8 {
            codes.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        codes.push((acc & 0xFF) as u8);
    }
    QuantizedUpdate {
        dim: update.len(),
        bits,
        chunk: QCHUNK,
        scales,
        codes,
    }
}

/// Invert [`quantize`] (up to quantization noise).
pub fn dequantize(q: &QuantizedUpdate) -> Vec<f32> {
    let mut out = Vec::with_capacity(q.dim);
    dequantize_into(q, &mut out);
    out
}

/// [`dequantize`] into a caller-owned buffer (cleared, reused) — the
/// zero-alloc decode path (DESIGN.md §14). Identical unpack walk, so
/// the produced bits cannot differ from [`dequantize`]'s.
pub fn dequantize_into(q: &QuantizedUpdate, out: &mut Vec<f32>) {
    dequantize_raw_into(q.dim, q.bits, q.chunk, &q.scales, &q.codes, out);
}

/// The unpack walk behind [`dequantize`], over borrowed scales/codes —
/// lets frame decoders dequantize wire bytes in place instead of
/// copying them into an owned [`QuantizedUpdate`] first.
pub fn dequantize_raw_into(
    dim: usize,
    bits: u8,
    chunk: usize,
    scales: &[(f32, f32)],
    codes: &[u8],
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(dim);
    let mut bitpos = 0usize;
    let mask = (1u32 << bits) - 1;
    for i in 0..dim {
        let byte = bitpos / 8;
        let off = (bitpos % 8) as u32;
        let mut raw = codes[byte] as u32 >> off;
        let mut have = 8 - off;
        let mut next = byte + 1;
        while have < bits as u32 {
            raw |= (codes[next] as u32) << have;
            have += 8;
            next += 1;
        }
        let code = raw & mask;
        let (lo, step) = scales[i / chunk];
        out.push(lo + code as f32 * step);
        bitpos += bits as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_keeps_largest_and_densifies() {
        let u = vec![0.1, -5.0, 0.0, 3.0, -0.2, 1.0];
        let s = top_k(&u, 3);
        assert_eq!(s.idx, vec![1, 3, 5]);
        assert_eq!(s.val, vec![-5.0, 3.0, 1.0]);
        let d = s.densify();
        assert_eq!(d, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn top_k_wire_bytes_shrink_at_scale() {
        let u: Vec<f32> = (0..100_000).map(|i| (i % 913) as f32 - 400.0).collect();
        let s = top_k(&u, 1000); // 1% sparsity
        // 1% of coords at 8 bytes each ≈ 50x smaller than 400KB dense
        assert!(s.wire_bytes() < (u.len() * 4) as u64 / 40);
    }

    #[test]
    fn top_k_full_is_lossless() {
        let u = vec![1.0f32, -2.0, 3.0];
        let s = top_k(&u, 10);
        assert_eq!(s.densify(), u);
    }

    #[test]
    fn error_feedback_conservation_and_bounded_residual() {
        // exact invariant: delivered + residual == Σ of true updates,
        // and the residual stays bounded (no coordinate starves forever)
        let mut ef = ErrorFeedback::default();
        let total_true: Vec<f32> = vec![1.0, 0.6, 0.1, 0.05];
        let mut delivered = vec![0.0f32; 4];
        let rounds = 50;
        let mut max_resid = 0.0f64;
        for _round in 0..rounds {
            let mut upd = total_true.clone();
            ef.fold_in(&mut upd);
            let kept = top_k(&upd, 1);
            ef.record(&upd, &kept);
            max_resid = max_resid.max(ef.residual_norm());
            for (d, v) in delivered.iter_mut().zip(kept.densify()) {
                *d += v;
            }
        }
        for (i, (d, t)) in delivered.iter().zip(&total_true).enumerate() {
            let want = t * rounds as f32;
            let resid = ef.residual_norm() as f32;
            assert!(
                (d - want).abs() <= resid + 1e-3,
                "coord {i}: delivered {d}, true-sum {want}, residual {resid}"
            );
        }
        // residual bounded well below the delivered mass (k=1 of 4 coords)
        assert!(
            max_resid < 2.0 * total_true.iter().sum::<f32>() as f64 * 4.0,
            "residual blew up: {max_resid}"
        );
    }

    #[test]
    fn quantize_roundtrip_error_bounded_and_unbiased() {
        let mut rng = Rng::new(11);
        let u: Vec<f32> = (0..5000).map(|_| rng.gauss_f32() * 2.0).collect();
        let q = quantize(&u, 8, &mut rng);
        let d = dequantize(&q);
        assert_eq!(d.len(), u.len());
        let (lo, hi) = u.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        let step = (hi - lo) / 255.0;
        for (a, b) in u.iter().zip(&d) {
            assert!((a - b).abs() <= step * 1.01, "{a} vs {b}");
        }
        // unbiasedness: mean error ~ 0
        let me: f64 = u
            .iter()
            .zip(&d)
            .map(|(a, b)| (*b - *a) as f64)
            .sum::<f64>()
            / u.len() as f64;
        assert!(me.abs() < step as f64 * 0.05, "bias {me}");
        // compression ratio ~4x for 8-bit
        assert!(q.wire_bytes() * 3 < (u.len() * 4) as u64);
    }

    #[test]
    fn quantize_low_bits_and_constant_chunks() {
        let mut rng = Rng::new(3);
        let u = vec![5.0f32; 3000]; // constant chunk: step 0 path
        let q = quantize(&u, 2, &mut rng);
        let d = dequantize(&q);
        assert!(d.iter().all(|&v| (v - 5.0).abs() < 1e-6));
        let u2: Vec<f32> = (0..300).map(|i| i as f32).collect();
        let q2 = quantize(&u2, 1, &mut rng);
        let d2 = dequantize(&q2);
        // 1-bit: only endpoints representable
        for v in &d2 {
            assert!((*v - 0.0).abs() < 1e-5 || (*v - 299.0).abs() < 1e-3);
        }
    }
}
