//! Cross-file consistency rules — the checks that parse more than one
//! file and catch the drift a single-file linter cannot:
//!
//! * `knob-fingerprint` — every CLI knob `main.rs` accepts is either
//!   present in the `RunMeta` resume fingerprint (`federated/server.rs`)
//!   or explicitly exempted here with a reason. A trajectory-changing
//!   flag that is missing from the fingerprint lets a resumed run
//!   silently continue under different physics (DESIGN.md §8).
//! * `snapshot-tags` — every section tag the snapshot writer emits has
//!   a reader dispatch arm, and no declared tag is dead. An unread tag
//!   is state that a resume silently drops.
//! * `curve-schema` — every `curve.csv` column telemetry writes is
//!   documented in README's schema table.
//!
//! Each function takes source text as parameters (not paths) so the
//! fixture tests can exercise drift scenarios in-memory.

use std::collections::BTreeMap;

use crate::analysis::report::Finding;
use crate::analysis::scanner::Source;

/// How a CLI knob relates to the `RunMeta` resume fingerprint.
enum Coverage {
    /// Fingerprinted: the token must appear in the `let meta = RunMeta
    /// { … };` construction in `federated/server.rs`.
    Fp(&'static str),
    /// Deliberately not fingerprinted; the reason is part of the table
    /// so review sees the argument, not just the exemption.
    Exempt(&'static str),
}
use Coverage::{Exempt, Fp};

/// The knob classification table. Every flag accepted by a
/// `check_known(&[…])` list in `main.rs` must have a row; rows for
/// flags that no longer exist are themselves findings (stale policy).
const KNOBS: &[(&str, Coverage)] = &[
    // --- Algorithm 1 knobs: all folded into the config label ---
    ("model", Fp("cfg.label()")),
    ("c", Fp("cfg.label()")),
    ("e", Fp("cfg.label()")),
    ("b", Fp("cfg.label()")),
    ("lr", Fp("cfg.label()")),
    ("lr-decay", Fp("lr_decay")),
    ("eval-every", Fp("eval_every")),
    ("seed", Fp("cfg.seed")),
    // --- dataset shape ---
    ("partition", Fp("data_fp")),
    ("scale", Fp("data_fp")),
    ("eval-cap", Fp("eval_cap")),
    ("track-train-loss", Fp("track_train_loss")),
    // --- server-side physics ---
    ("availability", Fp("opts.availability")),
    ("dp-clip", Fp("opts.dp")),
    ("dp-sigma", Fp("opts.dp")),
    ("secure-agg", Fp("secure_agg")),
    ("agg", Fp("agg_label")),
    ("server-lr", Fp("agg_label")),
    ("server-momentum", Fp("agg_label")),
    ("prox-mu", Fp("prox_mu")),
    // --- transport ---
    ("codec", Fp("codec_label")),
    ("down-codec", Fp("codec_label")),
    ("topk", Fp("codec_label")),
    ("quant-bits", Fp("codec_label")),
    // --- fleet shape ---
    ("fleet-profile", Fp("fleet.profile")),
    ("overselect", Fp("fleet.overselect")),
    ("deadline", Fp("fleet.deadline_s")),
    ("step-cost", Fp("fleet.step_cost_s")),
    ("shards", Fp("fleet.shards")),
    // --- async round modes ---
    ("async-buffer", Fp("async_buffer")),
    ("staleness-decay", Fp("staleness_decay")),
    ("late-policy", Fp("late_policy")),
    // --- exempt: cannot change the trajectory prefix ---
    (
        "config",
        Exempt("a file path; the typed knobs it expands into are classified individually"),
    ),
    (
        "rounds",
        Exempt("stop condition only — resuming with more rounds is a legitimate continuation"),
    ),
    (
        "target",
        Exempt("early-stop condition only; the trajectory prefix is unchanged"),
    ),
    (
        "workers",
        Exempt("bit-identical across worker counts by design (DESIGN.md §4); resuming at a different parallelism is legitimate"),
    ),
    (
        "checkpoint-every",
        Exempt("snapshot cadence; resume is byte-identical regardless of where the checkpoint fell (DESIGN.md §8)"),
    ),
    (
        "checkpoint-keep",
        Exempt("retention budget for old snapshots; no training effect"),
    ),
    // --- exempt: run lifecycle / naming / observation ---
    ("out", Exempt("run-dir location")),
    ("name", Exempt("run-dir naming")),
    ("overwrite", Exempt("run-dir lifecycle control")),
    ("resume", Exempt("the resume request itself")),
    (
        "trace",
        Exempt("observation only; traced runs are byte-identical (DESIGN.md §10)"),
    ),
    // --- exempt: training-free sim path (no snapshots; fast-forward) ---
    (
        "clients",
        Exempt("sim-only population size; trained runs derive K from the dataset and fingerprint it via `clients`"),
    ),
    ("sim-only", Exempt("mode selector for the training-free sim")),
    (
        "start-round",
        Exempt("sim fast-forward positioning; the sim path writes no snapshots"),
    ),
    ("model-bytes", Exempt("sim-only wire sizing; the sim path writes no snapshots")),
    ("steps", Exempt("sim-only compute sizing; the sim path writes no snapshots")),
    (
        "abort-p",
        Exempt("sim-only seeded fault stream; the sim path writes no snapshots"),
    ),
    (
        "duplicate-p",
        Exempt("sim-only seeded fault stream; the sim path writes no snapshots"),
    ),
    // --- exempt: non-run subcommand flags (bench / lint harnesses) ---
    ("areas", Exempt("bench harness selection; no training state")),
    ("check", Exempt("bench smoke mode; no training state")),
    ("quick", Exempt("bench profile; no training state")),
    ("compare", Exempt("bench snapshot diff; no training state")),
    ("tolerance", Exempt("bench regression threshold; no training state")),
    ("json", Exempt("lint output format")),
    ("fix-allow", Exempt("lint rewrite mode")),
];

/// Rule `knob-fingerprint`. `main_src` is scanned for `check_known`
/// flag lists; `server_src` for the `let meta = RunMeta { … };`
/// construction. See [`KNOBS`].
pub fn check_knob_fingerprint(main_path: &str, main_src: &str, server_src: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let knobs = collect_check_known(main_src);
    let region = runmeta_region(server_src);
    if knobs.is_empty() {
        out.push(Finding::new(
            main_path,
            1,
            "knob-fingerprint",
            "no check_known(&[…]) flag lists found — the knob inventory is empty, \
             so the fingerprint audit cannot run",
        ));
        return out;
    }
    let Some(region) = region else {
        out.push(Finding::new(
            main_path,
            1,
            "knob-fingerprint",
            "no `let meta = RunMeta {` construction found in federated/server.rs — \
             the resume fingerprint audit cannot run",
        ));
        return out;
    };
    let table: BTreeMap<&str, &Coverage> = KNOBS.iter().map(|(k, c)| (*k, c)).collect();
    for (knob, line) in &knobs {
        match table.get(knob.as_str()) {
            None => out.push(Finding::new(
                main_path,
                *line,
                "knob-fingerprint",
                format!(
                    "--{knob} is not classified in the fingerprint table \
                     (analysis::consistency::KNOBS) — add it as fingerprinted or \
                     exempt-with-reason"
                ),
            )),
            Some(Fp(token)) => {
                if !region.contains(token) {
                    out.push(Finding::new(
                        main_path,
                        *line,
                        "knob-fingerprint",
                        format!(
                            "--{knob} is classified as fingerprinted via `{token}`, but \
                             that token does not appear in the RunMeta construction — \
                             a resume under a different --{knob} would not be refused"
                        ),
                    ));
                }
            }
            Some(Exempt(_)) => {}
        }
    }
    for (knob, _) in KNOBS {
        if !knobs.contains_key(*knob) {
            out.push(Finding::new(
                main_path,
                1,
                "knob-fingerprint",
                format!(
                    "stale fingerprint-table row: --{knob} is classified but no \
                     check_known list accepts it"
                ),
            ));
        }
    }
    out
}

/// All quoted flag names inside `check_known(&[ … ])` calls, with the
/// 1-based line each first appears on. Parses raw text (string literal
/// contents are the payload here).
fn collect_check_known(src: &str) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    let mut in_list = false;
    for (idx, line) in src.lines().enumerate() {
        if !in_list && line.contains("check_known") {
            in_list = true;
        }
        if in_list {
            for name in quoted_strings(line) {
                out.entry(name).or_insert(idx + 1);
            }
            if line.contains("])") {
                in_list = false;
            }
        }
    }
    out
}

/// The `let meta = RunMeta { … };` block (raw text, format strings
/// included — the harness format string is where most knobs live).
fn runmeta_region(src: &str) -> Option<String> {
    let lines: Vec<&str> = src.lines().collect();
    let start = lines
        .iter()
        .position(|l| l.contains("let meta = RunMeta {"))?;
    let mut region = String::new();
    for line in &lines[start..] {
        region.push_str(line);
        region.push('\n');
        if line.trim() == "};" {
            return Some(region);
        }
    }
    None
}

/// Contents of every `"…"` literal on `line` (no escape handling —
/// flag names are plain idents).
fn quoted_strings(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = line;
    while let Some(a) = rest.find('"') {
        let Some(b) = rest[a + 1..].find('"') else {
            break;
        };
        out.push(rest[a + 1..a + 1 + b].to_string());
        rest = &rest[a + b + 2..];
    }
    out
}

/// Rule `snapshot-tags`. Parses `runstate/snapshot.rs` (or a fixture):
/// `const SEC_X: u16 = n;` declarations, `section(…, SEC_X, …)` writer
/// calls, and `SEC_X =>` reader dispatch arms. Every written tag needs
/// a reader arm; every declared tag must be both written and read.
pub fn check_snapshot_tags(path: &str, src_text: &str) -> Vec<Finding> {
    let src = Source::scan(path, src_text);
    let mut declared: BTreeMap<String, usize> = BTreeMap::new();
    let mut written: BTreeMap<String, usize> = BTreeMap::new();
    let mut read: BTreeMap<String, usize> = BTreeMap::new();
    for (i, line) in src.lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        let code = line.code.trim();
        let tags = sec_idents(code);
        if code.starts_with("const SEC_") {
            for t in &tags {
                declared.entry(t.clone()).or_insert(i + 1);
            }
        } else if code.contains("section(") && !code.contains("fn section") {
            for t in &tags {
                written.entry(t.clone()).or_insert(i + 1);
            }
        } else if code.starts_with("SEC_") && code.contains("=>") {
            for t in &tags {
                read.entry(t.clone()).or_insert(i + 1);
            }
        }
    }
    let mut out = Vec::new();
    for (tag, line) in &written {
        if !read.contains_key(tag) {
            out.push(Finding::new(
                path,
                *line,
                "snapshot-tags",
                format!(
                    "section {tag} is written but has no reader dispatch arm — \
                     a resume would silently drop this state"
                ),
            ));
        }
    }
    for (tag, line) in &declared {
        if !written.contains_key(tag) || !read.contains_key(tag) {
            out.push(Finding::new(
                path,
                *line,
                "snapshot-tags",
                format!("section {tag} is declared but not both written and read — dead tag"),
            ));
        }
    }
    out
}

/// `SEC_*` identifiers on a code line.
fn sec_idents(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = code;
    while let Some(pos) = rest.find("SEC_") {
        let tail = &rest[pos..];
        let name: String = tail
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        out.push(name.clone());
        rest = &rest[pos + name.len().max(4)..];
    }
    out
}

/// Rule `curve-schema`. Extracts the `CURVE_HEADER` literal from
/// `telemetry/mod.rs` (or a fixture) and requires every column to
/// appear backtick-quoted in README's schema table.
pub fn check_curve_schema(telemetry_path: &str, telemetry_src: &str, readme: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some((line, header)) = curve_header(telemetry_src) else {
        out.push(Finding::new(
            telemetry_path,
            1,
            "curve-schema",
            "no `const CURVE_HEADER` literal found — the schema audit cannot run",
        ));
        return out;
    };
    for col in header.split(',') {
        if !readme.contains(&format!("`{col}`")) {
            out.push(Finding::new(
                telemetry_path,
                line,
                "curve-schema",
                format!(
                    "curve.csv column `{col}` is not documented in README's \
                     telemetry schema table"
                ),
            ));
        }
    }
    out
}

/// `(line, literal)` of the `const CURVE_HEADER … = "…";` declaration.
fn curve_header(src: &str) -> Option<(usize, String)> {
    for (idx, line) in src.lines().enumerate() {
        if line.contains("const CURVE_HEADER") {
            let lit = quoted_strings(line);
            if let Some(h) = lit.first() {
                return Some((idx + 1, h.clone()));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const SERVER_OK: &str = "\
        let meta = RunMeta {\n\
            label: cfg.label(),\n\
            agg: agg_label.clone(),\n\
            codec: codec_label.clone(),\n\
            seed: cfg.seed,\n\
            harness: format!(\"x\", data_fp),\n\
        };\n";

    #[test]
    fn knob_missing_from_table_is_flagged() {
        let main = "args.check_known(&[\"model\", \"brand-new-flag\"])?;\n";
        let f = check_knob_fingerprint("main.rs", main, SERVER_OK);
        assert!(
            f.iter()
                .any(|f| f.message.contains("--brand-new-flag") && f.message.contains("not classified")),
            "{f:?}"
        );
    }

    #[test]
    fn fingerprinted_knob_missing_from_runmeta_is_flagged() {
        let main = "args.check_known(&[\"model\", \"partition\"])?;\n";
        let server_without_data_fp = "let meta = RunMeta {\n    label: cfg.label(),\n};\n";
        let f = check_knob_fingerprint("main.rs", main, server_without_data_fp);
        assert!(
            f.iter().any(|f| f.message.contains("--partition") && f.message.contains("data_fp")),
            "{f:?}"
        );
        let ok = check_knob_fingerprint("main.rs", main, SERVER_OK);
        assert!(
            !ok.iter().any(|f| f.message.contains("--partition")),
            "{ok:?}"
        );
    }

    #[test]
    fn stale_table_rows_reported_against_tiny_list() {
        let main = "args.check_known(&[\"model\"])?;\n";
        let f = check_knob_fingerprint("main.rs", main, SERVER_OK);
        assert!(f.iter().any(|f| f.message.contains("stale fingerprint-table row")));
    }

    #[test]
    fn snapshot_written_but_unread_tag_is_flagged() {
        let good = "\
            const SEC_META: u16 = 1;\n\
            fn section(out: &mut W, id: u16, body: W) {}\n\
            Self::section(&mut out, SEC_META, w);\n\
            SEC_META => { x() }\n";
        assert!(check_snapshot_tags("snap.rs", good).is_empty());
        let unread = "\
            const SEC_META: u16 = 1;\n\
            Self::section(&mut out, SEC_META, w);\n";
        let f = check_snapshot_tags("snap.rs", unread);
        assert!(
            f.iter().any(|f| f.message.contains("no reader dispatch arm")),
            "{f:?}"
        );
        let dead = "const SEC_GHOST: u16 = 9;\n";
        let f = check_snapshot_tags("snap.rs", dead);
        assert!(f.iter().any(|f| f.message.contains("dead tag")), "{f:?}");
    }

    #[test]
    fn undocumented_curve_column_is_flagged() {
        let telem = "const CURVE_HEADER: &str = \"round,lr,brand_new_col\";\n";
        let readme = "| `round` | x |\n| `lr` | y |\n";
        let f = check_curve_schema("telemetry/mod.rs", telem, readme);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("brand_new_col"));
        let readme_full = "| `round` | x |\n| `lr` | y |\n| `brand_new_col` | z |\n";
        assert!(check_curve_schema("telemetry/mod.rs", telem, readme_full).is_empty());
    }
}
