//! Where each rule does and does not apply.
//!
//! Two scoping mechanisms, both centralized here so the policy is one
//! diff away from review:
//!
//! * **module allowlists** — rules that ban a construct everywhere
//!   *except* designated modules (wall-clock in observation code,
//!   float folds in the `params` kernels, ad-hoc RNG in `data::rng`);
//! * **scope lists** — rules that apply *only* to designated files
//!   (the panic-surface audit of untrusted decode/load paths).
//!
//! Per-site exceptions use the `// lint:allow(<rule>): <justification>`
//! escape hatch (see [`crate::analysis::scanner`]); this module is the
//! structural policy that should rarely change.

/// Modules allowed to read the wall clock. Everything here is an
/// observation surface whose output never feeds telemetry rows, grid
/// manifests, or training state (DESIGN.md §10/§13):
/// `util::bench` (bench timing), `obs::*` (tracer, bench snapshots),
/// `telemetry` (elapsed-seconds progress line on stdout only), and
/// `runtime` (compile/execute stats, surfaced via `fedavg info`).
pub const WALL_CLOCK_MODULES: &[&str] = &["util::bench", "obs", "telemetry", "runtime"];

/// The only module allowed to define or import RNG primitives. All
/// randomness must flow through `data::rng`'s counter-based seeded
/// generators so every draw is a pure function of (seed, position)
/// (DESIGN.md §5).
pub const RNG_MODULES: &[&str] = &["data::rng"];

/// Modules allowed to run unordered float reductions. `params` owns
/// the canonical accumulation order that the bit-identity guarantees
/// of DESIGN.md §7/§11/§12 are defined against; a float `.sum()`
/// anywhere else risks quietly introducing a second, different order.
pub const FLOAT_FOLD_MODULES: &[&str] = &["params"];

/// Files whose non-test code must be panic-free: they decode untrusted
/// or on-disk bytes (wire frames, snapshots, config text) and must
/// reject malformed input with a typed error, never a panic
/// (DESIGN.md §6/§8).
pub const PANIC_SURFACE_FILES: &[&str] = &[
    "comms/wire.rs",
    "runstate/snapshot.rs",
    "config/mod.rs",
    "util/bytes.rs",
];

/// Identifiers conventionally bound to untrusted/raw buffers in the
/// panic-surface files; direct indexing on them is audited (a checked
/// `get` or a `ByteReader` is required instead).
pub const UNTRUSTED_BUFFER_NAMES: &[&str] = &["b", "buf", "bytes", "payload", "raw", "body"];

/// Files whose non-test code sits on the per-round hot path and is
/// audited for per-call heap churn (DESIGN.md §13/§14): the `params`
/// kernels, parallel dispatch, the sharded-aggregation cascade, and the
/// transport round loop. `Vec::new(` / `.to_vec()` / `.clone()` in
/// these files need a `lint:allow(hot-alloc)` hatch naming the
/// boundary that makes the copy necessary. Deliberately *not* listed:
/// `comms/wire.rs` (encode paths construct owned frames by design —
/// the borrowed-view decode side has no alloc tokens to flag) and
/// `federated/server.rs` (the round loop allocates once before the
/// loop; flagging every setup line would drown the signal).
pub const HOT_ALLOC_FILES: &[&str] = &[
    "src/params/mod.rs",
    "src/coordinator/exec.rs",
    "src/federated/aggregate/shards.rs",
    "src/comms/transport.rs",
];

/// `module` matches an allowlist entry if it equals the entry or sits
/// beneath it (`obs` covers `obs::trace`).
pub fn module_matches(module: &str, list: &[&str]) -> bool {
    list.iter()
        .any(|p| module == *p || module.starts_with(&format!("{p}::")))
}

/// `path` (repo-relative, `/`-separated) matches a scope-list entry by
/// suffix (`rust/src/comms/wire.rs` matches `comms/wire.rs`).
pub fn path_in_scope(path: &str, list: &[&str]) -> bool {
    list.iter()
        .any(|p| path == *p || path.ends_with(&format!("/{p}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_prefix_matching() {
        assert!(module_matches("obs", WALL_CLOCK_MODULES));
        assert!(module_matches("obs::trace", WALL_CLOCK_MODULES));
        assert!(module_matches("util::bench", WALL_CLOCK_MODULES));
        assert!(!module_matches("util::bytes", WALL_CLOCK_MODULES));
        assert!(!module_matches("observer", WALL_CLOCK_MODULES));
        assert!(!module_matches("coordinator", WALL_CLOCK_MODULES));
    }

    #[test]
    fn path_suffix_matching() {
        assert!(path_in_scope("rust/src/comms/wire.rs", PANIC_SURFACE_FILES));
        assert!(path_in_scope("comms/wire.rs", PANIC_SURFACE_FILES));
        assert!(!path_in_scope("rust/src/comms/transport.rs", PANIC_SURFACE_FILES));
        assert!(!path_in_scope("rust/src/fire.rs", PANIC_SURFACE_FILES));
    }
}
