//! Lint findings and their renderings.
//!
//! One finding = one `file:line rule message` row. The text rendering
//! is the CLI/CI surface; `--json` emits the same rows as a stable
//! machine-readable array (uploaded as a CI artifact).

use crate::util::json::escape;

/// One lint violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Repo-relative path, `/`-separated.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id (`wall-clock`, `hash-order`, … `bad-allow`).
    pub rule: String,
    pub message: String,
}

impl Finding {
    pub fn new(path: &str, line: usize, rule: &str, message: impl Into<String>) -> Self {
        Finding {
            path: path.to_string(),
            line,
            rule: rule.to_string(),
            message: message.into(),
        }
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{} {} {}",
            self.path, self.line, self.rule, self.message
        )
    }
}

/// Deterministic report order: path, then line, then rule.
pub fn sort(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule.as_str()).cmp(&(b.path.as_str(), b.line, b.rule.as_str()))
    });
}

/// The human/CI rendering: one `file:line rule message` row per finding.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&f.to_string());
        out.push('\n');
    }
    out
}

/// Stable JSON array of findings (the `--json` CI artifact).
pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"path\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
            escape(&f.path),
            f.line,
            escape(&f.rule),
            escape(&f.message)
        ));
    }
    out.push_str(if findings.is_empty() { "]\n" } else { "\n]\n" });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_row_shape() {
        let f = Finding::new("rust/src/a.rs", 7, "wall-clock", "Instant::now outside obs");
        assert_eq!(
            f.to_string(),
            "rust/src/a.rs:7 wall-clock Instant::now outside obs"
        );
    }

    #[test]
    fn sorted_and_json_parse_back() {
        let mut fs = vec![
            Finding::new("b.rs", 2, "hash-order", "x"),
            Finding::new("a.rs", 9, "wall-clock", "said \"now\""),
            Finding::new("a.rs", 1, "float-fold", "y"),
        ];
        sort(&mut fs);
        assert_eq!(fs[0].path, "a.rs");
        assert_eq!(fs[0].line, 1);
        let json = render_json(&fs);
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].get("message").unwrap().as_str().unwrap(), "said \"now\"");
        assert_eq!(crate::util::json::Json::parse(&render_json(&[])).unwrap().as_arr().unwrap().len(), 0);
    }
}
