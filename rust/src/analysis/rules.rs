//! The single-file rule families. Each takes a scanned [`Source`] and
//! returns raw findings; escape-hatch filtering happens centrally in
//! [`crate::analysis::lint_source`]. Test regions are always exempt —
//! the rules police shipping code, not assertions about it.
//!
//! Every rule is a token heuristic, not a type check: it runs on the
//! stripped token stream the scanner produces, errs toward flagging
//! (the `lint:allow` hatch is the pressure valve for deliberate
//! exceptions), and its exact matching policy is documented inline and
//! mirrored by the fixture tests in `rust/tests/lint.rs`.

use std::collections::BTreeSet;

use crate::analysis::allowlist::{
    module_matches, path_in_scope, FLOAT_FOLD_MODULES, HOT_ALLOC_FILES, PANIC_SURFACE_FILES,
    RNG_MODULES, UNTRUSTED_BUFFER_NAMES, WALL_CLOCK_MODULES,
};
use crate::analysis::report::Finding;
use crate::analysis::scanner::Source;

/// `tok` occurs in `code` with no identifier character immediately
/// before it (so `StdRng` does not match `MyStdRng`, `b[` does not
/// match `verb[`).
fn has_token(code: &str, tok: &str) -> bool {
    let mut from = 0;
    while let Some(rel) = code[from..].find(tok) {
        let at = from + rel;
        let prev = code[..at].chars().next_back();
        if !prev.is_some_and(|c| c.is_alphanumeric() || c == '_') {
            return true;
        }
        from = at + tok.len();
    }
    false
}

/// `name` occurs in `code` as a standalone identifier (non-identifier
/// characters, or the text boundary, on both sides).
fn has_ident(code: &str, name: &str) -> bool {
    let mut from = 0;
    while let Some(rel) = code[from..].find(name) {
        let at = from + rel;
        let prev_ok = !code[..at]
            .chars()
            .next_back()
            .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let next_ok = !code[at + name.len()..]
            .chars()
            .next()
            .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if prev_ok && next_ok {
            return true;
        }
        from = at + name.len();
    }
    false
}

/// Rule `wall-clock`: `Instant::now` / `SystemTime::now` only in the
/// observation modules ([`WALL_CLOCK_MODULES`]). Anywhere else, a
/// wall-clock read is a nondeterminism seed — sim time must come from
/// the virtual clock, telemetry time from `sim_seconds`.
pub fn wall_clock(src: &Source) -> Vec<Finding> {
    let mut out = Vec::new();
    if module_matches(&src.module(), WALL_CLOCK_MODULES) {
        return out;
    }
    for (i, line) in src.lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        for call in ["Instant::now", "SystemTime::now"] {
            if line.code.contains(call) {
                out.push(Finding::new(
                    &src.path,
                    i + 1,
                    "wall-clock",
                    format!(
                        "{call} outside the observation modules ({}) — deterministic \
                         code must use the virtual clock",
                        WALL_CLOCK_MODULES.join(", ")
                    ),
                ));
            }
        }
    }
    out
}

/// Iteration methods whose order a hash map does not define.
const ITER_TOKENS: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".retain(",
];

/// Rule `hash-order`: no iteration over `HashMap`/`HashSet` anywhere in
/// `src/` — construction and keyed lookup are fine; anything that
/// visits entries in hash order (iter/keys/values/drain/retain/for)
/// must use a `BTreeMap`/`BTreeSet` or a sorted drain instead.
///
/// Heuristic: bindings and struct fields declared on a line mentioning
/// `HashMap`/`HashSet` are tracked by name for the rest of the file;
/// iteration tokens on a tracked name (or on a line that itself
/// mentions the types) are flagged.
pub fn hash_order(src: &Source) -> Vec<Finding> {
    let mut names: BTreeSet<String> = BTreeSet::new();
    for line in &src.lines {
        if line.is_test || !(line.code.contains("HashMap") || line.code.contains("HashSet")) {
            continue;
        }
        if let Some(name) = binding_name(&line.code) {
            names.insert(name);
        }
    }
    let mut out = Vec::new();
    for (i, line) in src.lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        let iterated = ITER_TOKENS.iter().any(|t| line.code.contains(t));
        let direct = (line.code.contains("HashMap") || line.code.contains("HashSet")) && iterated;
        // `for … in <expr mentioning a tracked name>` — the loop itself
        // is the iteration, no method token needed
        let for_tail = line
            .code
            .contains("for ")
            .then(|| line.code.find(" in ").map(|p| &line.code[p + 4..]))
            .flatten();
        let via_name = names.iter().any(|n| {
            (iterated && has_token(&line.code, &format!("{n}.")))
                || for_tail.is_some_and(|tail| has_ident(tail, n))
        });
        if direct || via_name {
            out.push(Finding::new(
                &src.path,
                i + 1,
                "hash-order",
                "iteration over a HashMap/HashSet visits entries in hash order — \
                 use BTreeMap/BTreeSet or collect-and-sort before iterating",
            ));
        }
    }
    out
}

/// `let [mut] NAME` or a struct-field `NAME:` on a line that mentions a
/// hash type.
fn binding_name(code: &str) -> Option<String> {
    let ident = |s: &str| -> Option<String> {
        let name: String = s
            .trim_start()
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        (!name.is_empty()).then_some(name)
    };
    if let Some(pos) = code.find("let ") {
        let rest = code[pos + 4..].trim_start();
        let rest = rest.strip_prefix("mut ").unwrap_or(rest);
        return ident(rest);
    }
    // field form: `[pub] name: …HashMap<…>` (types after the colon)
    let t = code.trim_start();
    let t = t.strip_prefix("pub(crate) ").unwrap_or(t);
    let t = t.strip_prefix("pub ").unwrap_or(t);
    let name = ident(t)?;
    let after = t[name.len()..].trim_start();
    (after.starts_with(':') && !after.starts_with("::")).then_some(name)
}

/// Rule `seeded-rng`: every random draw must be a pure function of
/// (seed, position) via `data::rng`'s counter-based generators. Entropy
/// sources and the `rand` crate family are banned everywhere else.
pub fn seeded_rng(src: &Source) -> Vec<Finding> {
    let mut out = Vec::new();
    if module_matches(&src.module(), RNG_MODULES) {
        return out;
    }
    const BANNED: &[&str] = &[
        "rand::",
        "thread_rng",
        "StdRng",
        "SmallRng",
        "RandomState",
        "from_entropy",
        "OsRng",
        "getrandom",
    ];
    for (i, line) in src.lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        for tok in BANNED {
            if has_token(&line.code, tok) {
                out.push(Finding::new(
                    &src.path,
                    i + 1,
                    "seeded-rng",
                    format!(
                        "`{tok}` outside data::rng — randomness must come from the \
                         seeded counter-based generators"
                    ),
                ));
            }
        }
    }
    out
}

/// Rule `panic-surface`: the untrusted decode/load paths
/// ([`PANIC_SURFACE_FILES`]) must reject malformed bytes with a typed
/// error — `unwrap`/`expect`/`panic!` and raw indexing on buffer-named
/// slices are flagged.
pub fn panic_surface(src: &Source) -> Vec<Finding> {
    let mut out = Vec::new();
    if !path_in_scope(&src.path, PANIC_SURFACE_FILES) {
        return out;
    }
    const PANICS: &[&str] = &[
        ".unwrap()",
        ".expect(",
        "panic!(",
        "unreachable!(",
        "todo!(",
        "unimplemented!(",
    ];
    for (i, line) in src.lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        for tok in PANICS {
            if line.code.contains(tok) {
                out.push(Finding::new(
                    &src.path,
                    i + 1,
                    "panic-surface",
                    format!(
                        "`{tok}` in a decode/load path — untrusted bytes must fail \
                         with a typed error, never a panic",
                    ),
                ));
            }
        }
        for name in UNTRUSTED_BUFFER_NAMES {
            if has_token(&line.code, &format!("{name}[")) {
                out.push(Finding::new(
                    &src.path,
                    i + 1,
                    "panic-surface",
                    format!(
                        "raw indexing on untrusted buffer `{name}` — a truncated input \
                         panics here; use a checked `get` or a ByteReader",
                    ),
                ));
            }
        }
    }
    out
}

/// Rule `float-fold`: unordered float reductions (`.sum()`,
/// `.product()`, accumulator folds over `f32`/`f64`) only inside the
/// `params` kernels, which own the canonical accumulation order.
/// Min/max folds are exempt (order-independent). The float-typedness
/// check looks at a ±2-line window around the reduction, so turbofish,
/// `let x: f64 =`, and `as f64` spellings are all caught.
pub fn float_fold(src: &Source) -> Vec<Finding> {
    let mut out = Vec::new();
    if module_matches(&src.module(), FLOAT_FOLD_MODULES) {
        return out;
    }
    for (i, line) in src.lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        let sum = ["\u{2e}sum(", ".sum::<", ".product(", ".product::<"]
            .iter()
            .any(|t| line.code.contains(t));
        let fold = line.code.contains(".fold(");
        if !sum && !fold {
            continue;
        }
        let lo = i.saturating_sub(2);
        let hi = (i + 2).min(src.lines.len());
        let ctx: String = src.lines[lo..hi]
            .iter()
            .map(|l| l.code.as_str())
            .collect::<Vec<_>>()
            .join("\n");
        let floaty = ctx.contains("f32") || ctx.contains("f64");
        if !floaty {
            continue;
        }
        if !sum && ["::min", "::max", ".min(", ".max("].iter().any(|t| ctx.contains(t)) {
            continue; // min/max folds are order-independent
        }
        out.push(Finding::new(
            &src.path,
            i + 1,
            "float-fold",
            "unordered float reduction outside the params kernels — reduction \
             order is part of the bit-identity contract; route through params \
             or document why order cannot matter",
        ));
    }
    out
}

/// Rule `hot-alloc`: the audited hot-path files ([`HOT_ALLOC_FILES`])
/// must not allocate per call — `Vec::new(`, `.to_vec()`, and
/// `.clone()` are flagged unless hatched with a justification naming
/// the ownership boundary (DESIGN.md §14: scratch is hoisted to the
/// caller and cleared, not reallocated). Token boundaries keep the
/// heuristic honest: `ParamVec::new(` / `VecDeque::new(` do not match
/// `Vec::new(`, `.cloned()` does not match `.clone()`, and
/// `Vec::with_capacity` / `vec![…]` are not tokens at all — sized
/// one-time setup allocations are the sanctioned pattern.
pub fn hot_alloc(src: &Source) -> Vec<Finding> {
    let mut out = Vec::new();
    if !path_in_scope(&src.path, HOT_ALLOC_FILES) {
        return out;
    }
    const ALLOCS: &[&str] = &["Vec::new(", ".to_vec()", ".clone()"];
    for (i, line) in src.lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        for tok in ALLOCS {
            // dot-prefixed method tokens are self-delimiting (`.cloned()`
            // never contains `.clone()`); `Vec::new(` needs the ident
            // boundary so `ParamVec::new(` does not match
            let hit = if tok.starts_with('.') {
                line.code.contains(tok)
            } else {
                has_token(&line.code, tok)
            };
            if hit {
                out.push(Finding::new(
                    &src.path,
                    i + 1,
                    "hot-alloc",
                    format!(
                        "`{tok}` in an audited hot path — reuse caller-provided \
                         scratch (DESIGN.md §14), or hatch with the ownership \
                         boundary that forces the copy",
                    ),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(path: &str, text: &str) -> Source {
        Source::scan(path, text)
    }

    #[test]
    fn wall_clock_fires_outside_obs_only() {
        let bad = scan("rust/src/coordinator/exec.rs", "let t = Instant::now();\n");
        assert_eq!(wall_clock(&bad).len(), 1);
        let ok = scan("rust/src/obs/trace.rs", "let t = Instant::now();\n");
        assert!(wall_clock(&ok).is_empty());
        let test_only = scan(
            "rust/src/coordinator/exec.rs",
            "#[cfg(test)]\nmod tests {\n fn t() { let t = Instant::now(); }\n}\n",
        );
        assert!(wall_clock(&test_only).is_empty());
    }

    #[test]
    fn hash_order_flags_iteration_not_lookup() {
        let src = scan(
            "rust/src/x.rs",
            "let mut m: HashMap<String, u32> = HashMap::new();\n\
             m.insert(k, v);\n\
             let v = m.get(&k);\n\
             for (k, v) in m.iter() {\n\
             for k in &keys {\n\
             for k in &m {\n",
        );
        let f = hash_order(&src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert_eq!(f[0].line, 4);
        assert_eq!(f[1].line, 6);
    }

    #[test]
    fn hash_order_tracks_struct_fields() {
        let src = scan(
            "rust/src/x.rs",
            "struct S {\n    cache: RefCell<HashMap<String, u32>>,\n}\n\
             fn f(s: &S) { for x in s.cache.borrow().keys() {} }\n",
        );
        assert_eq!(hash_order(&src).len(), 1);
    }

    #[test]
    fn seeded_rng_banned_outside_data_rng() {
        let bad = scan("rust/src/sweep/mod.rs", "let r = thread_rng();\n");
        assert_eq!(seeded_rng(&bad).len(), 1);
        let home = scan("rust/src/data/rng.rs", "use rand::thread_rng;\n");
        assert!(seeded_rng(&home).is_empty());
    }

    #[test]
    fn panic_surface_scoped_to_decode_files() {
        let bad = scan(
            "rust/src/comms/wire.rs",
            "let x = hdr.len.unwrap();\nlet y = buf[0];\n",
        );
        assert_eq!(panic_surface(&bad).len(), 2);
        let elsewhere = scan("rust/src/sweep/mod.rs", "let x = v.unwrap();\n");
        assert!(panic_surface(&elsewhere).is_empty());
        let ok = scan(
            "rust/src/comms/wire.rs",
            "let x = buf.get(0).ok_or_else(err)?;\nlet s = rebuf[0];\n",
        );
        assert!(panic_surface(&ok).is_empty());
    }

    #[test]
    fn hot_alloc_scoped_with_honest_token_boundaries() {
        let bad = scan(
            "rust/src/comms/transport.rs",
            "let mut v = Vec::new();\nlet w = xs.to_vec();\nlet z = theta.clone();\n",
        );
        assert_eq!(hot_alloc(&bad).len(), 3);
        // sanctioned spellings: sized setup, newtype ctors, iterator clone
        let ok = scan(
            "rust/src/comms/transport.rs",
            "let mut v = Vec::with_capacity(n);\n\
             let p = ParamVec::new();\n\
             let q: VecDeque<u8> = VecDeque::new();\n\
             let a = vec![0.0f32; dim];\n\
             let it = xs.iter().cloned();\n",
        );
        assert!(hot_alloc(&ok).is_empty(), "{:?}", hot_alloc(&ok));
        let elsewhere = scan("rust/src/federated/server.rs", "let v = Vec::new();\n");
        assert!(hot_alloc(&elsewhere).is_empty());
        let in_test = scan(
            "rust/src/coordinator/exec.rs",
            "#[cfg(test)]\nmod tests {\n fn t() { let v = xs.to_vec(); }\n}\n",
        );
        assert!(hot_alloc(&in_test).is_empty());
    }

    #[test]
    fn float_fold_catches_all_spellings_outside_params() {
        let bad = scan(
            "rust/src/federated/x.rs",
            "let a = xs.iter().sum::<f64>();\n\
             let b: f32 = ys.iter().sum();\n\
             let c = zs.iter().map(|&v| v as f64)\n    .sum();\n",
        );
        assert_eq!(float_fold(&bad).len(), 3);
        let in_params = scan("rust/src/params/mod.rs", "let a = xs.iter().sum::<f64>();\n");
        assert!(float_fold(&in_params).is_empty());
        let minmax = scan(
            "rust/src/federated/x.rs",
            "let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);\n",
        );
        assert!(float_fold(&minmax).is_empty());
        let usize_sum = scan(
            "rust/src/federated/x.rs",
            "let n = xs.iter().map(|c| c.len()).sum::<usize>();\n",
        );
        assert!(float_fold(&usize_sum).is_empty());
    }
}
