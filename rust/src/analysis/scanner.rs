//! Token-aware Rust source scanner for the lint pass (offline image:
//! no syn/proc-macro2 — a hand-rolled state machine, pure std).
//!
//! The scanner does three things the rules need and plain `grep` cannot:
//!
//! 1. **Strips string/char literals and comments** from every line, so a
//!    rule matching `Instant::now` never fires on a doc comment or an
//!    error-message string that merely mentions it.
//! 2. **Tracks `#[cfg(test)]` regions** by brace depth, so test modules
//!    — where `unwrap()` and wall-clock are idiomatic — are exempt.
//! 3. **Collects `// lint:allow(<rule>): <justification>` escape
//!    hatches**, attaching each to the code line it governs. A bare
//!    `lint:allow` with no rule or no justification is itself reported
//!    (rule `bad-allow`): the escape hatch must leave an audit trail.
//!
//! The model is line-oriented: [`Source::lines`] holds, per input line,
//! the stripped code text, the line-comment text (for allow parsing),
//! and whether the line sits inside a test region.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::report::Finding;

/// One scanned input line.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Source text with comments removed and string/char literal
    /// *contents* removed (the delimiting quotes remain, so `"a,b"`
    /// becomes `""` — still a token boundary, never a false match).
    pub code: String,
    /// Text of any `//` comment on this line (block comments are
    /// discarded; `lint:allow` must be a line comment).
    pub comment: String,
    /// Inside a `#[cfg(test)]`-gated brace region.
    pub is_test: bool,
}

/// A scanned file: stripped lines plus the allow-annotation map.
#[derive(Debug)]
pub struct Source {
    /// Repo-relative path with `/` separators (display + allowlisting).
    pub path: String,
    pub lines: Vec<Line>,
    /// 1-based code line -> rules allowed on that line.
    allows: BTreeMap<usize, BTreeSet<String>>,
    /// Malformed escape hatches found while scanning.
    bad_allows: Vec<Finding>,
}

impl Source {
    /// Scan `text`, which lives at repo-relative `path`.
    pub fn scan(path: &str, text: &str) -> Source {
        let lines = strip(text);
        let (allows, bad_allows) = collect_allows(path, &lines);
        Source {
            path: path.to_string(),
            lines,
            allows,
            bad_allows,
        }
    }

    /// Is `rule` explicitly allowed on 1-based line `line`?
    pub fn is_allowed(&self, line: usize, rule: &str) -> bool {
        self.allows
            .get(&line)
            .is_some_and(|rules| rules.contains(rule))
    }

    /// `bad-allow` findings: escape hatches missing a rule name or a
    /// justification.
    pub fn bad_allows(&self) -> &[Finding] {
        &self.bad_allows
    }

    /// Module path for allowlist matching: `comms/wire.rs` ->
    /// `comms::wire`, `telemetry/mod.rs` -> `telemetry`, `main.rs` ->
    /// `main`. The path is taken relative to the last `src/` component
    /// if present.
    pub fn module(&self) -> String {
        let rel = match self.path.rfind("src/") {
            Some(i) => &self.path[i + 4..],
            None => self.path.as_str(),
        };
        let rel = rel.strip_suffix(".rs").unwrap_or(rel);
        let rel = rel.strip_suffix("/mod").unwrap_or(rel);
        if rel == "lib" || rel == "mod" {
            return String::new();
        }
        rel.replace('/', "::")
    }
}

/// Lexer state for [`strip`].
enum State {
    Code,
    LineComment,
    /// Nesting depth (Rust block comments nest).
    BlockComment(u32),
    Str,
    /// `r##"…"##` — number of `#`s to match on close.
    RawStr(u32),
    Char,
}

/// Strip comments and literal contents, preserving line structure.
fn strip(text: &str) -> Vec<Line> {
    let mut out: Vec<Line> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let chars: Vec<char> = text.chars().collect();
    let mut i = 0;
    let n = chars.len();
    while i < n {
        let c = chars[i];
        if c == '\n' {
            // Line comments end at the newline; strings legally span
            // lines (their contents are dropped either way).
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            out.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                is_test: false,
            });
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    // raw/byte prefixes: only treat as a raw string when
                    // the prefix is not part of a longer identifier
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    // r"…", r#"…"#, b"…", br#"…"# — count the hashes
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') && (c == 'r' || j > i + 1) {
                        code.push('"');
                        state = State::RawStr(hashes);
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal vs lifetime: a literal closes within
                    // two chars or starts with a backslash escape
                    if next == Some('\\') || chars.get(i + 2) == Some(&'\'') {
                        code.push('\'');
                        state = State::Char;
                        i += 1;
                    } else {
                        code.push('\''); // lifetime tick
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(d) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(d + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if d == 1 {
                        State::Code
                    } else {
                        State::BlockComment(d - 1)
                    };
                    i += 2;
                } else {
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2; // skip the escaped char (may be `"` or `\`)
                } else if c == '"' {
                    code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes as usize {
                        if chars.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        code.push('"');
                        state = State::Code;
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                i += 1;
            }
            State::Char => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    code.push('\'');
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        out.push(Line {
            code,
            comment,
            is_test: false,
        });
    }
    mark_test_regions(&mut out);
    out
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// Mark every line inside a `#[cfg(test)]`-attributed brace region.
/// The attribute arms a pending flag; the next `{` opens the region at
/// the current depth; the matching `}` closes it.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut entry: Option<i64> = None;
    for line in lines.iter_mut() {
        if entry.is_none() && line.code.contains("#[cfg(test)]") {
            pending = true;
        }
        let in_test_at_start = entry.is_some() || pending;
        for c in line.code.chars() {
            match c {
                '{' => {
                    if pending {
                        entry = Some(depth);
                        pending = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if entry == Some(depth) {
                        entry = None;
                    }
                }
                _ => {}
            }
        }
        line.is_test = in_test_at_start || entry.is_some();
    }
}

/// Parse `lint:allow(<rule>): <justification>` annotations. A trailing
/// comment governs its own line; a standalone comment line governs the
/// next line that carries code. Only plain `//` comments count — doc
/// comments (`///`, `//!`) are documentation *about* the hatch syntax,
/// not hatches.
#[allow(clippy::type_complexity)]
fn collect_allows(
    path: &str,
    lines: &[Line],
) -> (BTreeMap<usize, BTreeSet<String>>, Vec<Finding>) {
    let mut allows: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    let mut bad = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        if line.comment.starts_with('/') || line.comment.starts_with('!') {
            continue; // doc comment: `///…` or `//!…`
        }
        let Some(pos) = line.comment.find("lint:allow") else {
            continue;
        };
        let rest = &line.comment[pos + "lint:allow".len()..];
        let parsed = parse_allow(rest);
        let Some(rule) = parsed else {
            bad.push(Finding::new(
                path,
                lineno,
                "bad-allow",
                "malformed escape hatch: expected `lint:allow(<rule>): <justification>` \
                 with a non-empty justification",
            ));
            continue;
        };
        // Attach to this line if it has code, else to the next code line.
        let mut target = lineno;
        if line.code.trim().is_empty() {
            for (j, later) in lines.iter().enumerate().skip(idx + 1) {
                if !later.code.trim().is_empty() {
                    target = j + 1;
                    break;
                }
            }
        }
        allows.entry(target).or_default().insert(rule);
    }
    (allows, bad)
}

/// `rest` is the comment text after `lint:allow`; returns the rule name
/// if the annotation is well-formed (`(<rule>): <justification>`).
fn parse_allow(rest: &str) -> Option<String> {
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim();
    if rule.is_empty() || !rule.chars().all(|c| c.is_ascii_alphanumeric() || c == '-') {
        return None;
    }
    let after = rest[close + 1..].trim_start();
    let just = after.strip_prefix(':')?.trim();
    if just.is_empty() {
        return None;
    }
    Some(rule.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_stripped() {
        let src = Source::scan(
            "x.rs",
            "let a = \"Instant::now\"; // Instant::now\nlet b = 1; /* SystemTime::now */\n",
        );
        assert_eq!(src.lines[0].code, "let a = \"\"; ");
        assert!(src.lines[0].comment.contains("Instant::now"));
        assert_eq!(src.lines[1].code, "let b = 1; ");
    }

    #[test]
    fn raw_strings_and_chars_and_lifetimes() {
        let src = Source::scan(
            "x.rs",
            "let r = r#\"a \"quoted\" HashMap\"#;\nlet c = '{'; let l: &'static str = \"\";\n",
        );
        assert_eq!(src.lines[0].code, "let r = \"\";");
        assert!(!src.lines[1].code.contains('{'), "{}", src.lines[1].code);
    }

    #[test]
    fn escaped_quote_in_string() {
        let src = Source::scan("x.rs", "let s = \"a\\\"b.unwrap()\"; s.len();\n");
        assert_eq!(src.lines[0].code, "let s = \"\"; s.len();");
    }

    #[test]
    fn test_region_marked_by_brace_depth() {
        let src = Source::scan(
            "x.rs",
            "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { x(); }\n}\nfn c() {}\n",
        );
        let flags: Vec<bool> = src.lines.iter().map(|l| l.is_test).collect();
        assert_eq!(flags, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn allow_attaches_to_own_or_next_line() {
        let src = Source::scan(
            "x.rs",
            "x(); // lint:allow(wall-clock): measuring only\n\
             // lint:allow(panic-surface): length checked above\n\
             y();\n",
        );
        assert!(src.is_allowed(1, "wall-clock"));
        assert!(src.is_allowed(3, "panic-surface"));
        assert!(!src.is_allowed(3, "wall-clock"));
    }

    #[test]
    fn bare_allow_is_reported() {
        for bad in [
            "x(); // lint:allow\n",
            "x(); // lint:allow(wall-clock)\n",
            "x(); // lint:allow(wall-clock):   \n",
            "x(); // lint:allow(): why\n",
        ] {
            let src = Source::scan("x.rs", bad);
            assert_eq!(src.bad_allows().len(), 1, "{bad:?}");
            assert_eq!(src.bad_allows()[0].rule, "bad-allow");
        }
        let ok = Source::scan("x.rs", "x(); // lint:allow(wall-clock): because\n");
        assert!(ok.bad_allows().is_empty());
        // doc comments describe the syntax; they are not hatches
        let doc = Source::scan("x.rs", "/// a bare `lint:allow` is rejected\nfn f() {}\n");
        assert!(doc.bad_allows().is_empty());
    }

    #[test]
    fn module_paths() {
        for (p, m) in [
            ("rust/src/comms/wire.rs", "comms::wire"),
            ("rust/src/telemetry/mod.rs", "telemetry"),
            ("rust/src/main.rs", "main"),
            ("rust/src/lib.rs", ""),
        ] {
            assert_eq!(Source::scan(p, "").module(), m, "{p}");
        }
    }
}
