//! `fedavg lint` — the project-invariant static-analysis pass
//! (DESIGN.md §13).
//!
//! Every guarantee this codebase ships — byte-identical runs under
//! reordering, resume, worker count (§5/§8/§11/§12), panic-free decode
//! of untrusted bytes (§6), documented telemetry (§10) — is enforced
//! after the fact by the bit-identity test matrix. This pass enforces
//! the *preconditions* mechanically, at the source level, so a
//! violation is caught at review time instead of three PRs later when
//! a test finally trips:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `wall-clock`       | time only in observation modules |
//! | `hash-order`       | no hash-order iteration anywhere |
//! | `seeded-rng`       | all randomness via `data::rng` |
//! | `panic-surface`    | decode/load paths return `Result` |
//! | `float-fold`       | float reduction order owned by `params` |
//! | `hot-alloc`        | no per-call allocation in audited hot paths |
//! | `knob-fingerprint` | CLI knobs covered by the resume fingerprint |
//! | `snapshot-tags`    | written snapshot sections have reader arms |
//! | `curve-schema`     | curve.csv columns documented in README |
//! | `bad-allow`        | escape hatches carry justifications |
//!
//! Escape hatch: `// lint:allow(<rule>): <justification>` on (or
//! directly above) the offending line. A hatch without a rule or a
//! justification is itself a finding — exceptions must leave an audit
//! trail. The pass is pure std, runs as `fedavg lint [--fix-allow]
//! [--json]`, and is pinned by the tier-1 suite (`rust/tests/lint.rs`:
//! zero findings on this tree, and every rule fires on its fixture).

pub mod allowlist;
pub mod consistency;
pub mod report;
pub mod rules;
pub mod scanner;

use std::path::{Path, PathBuf};

use anyhow::Context;

pub use report::{render_json, render_text, Finding};

use crate::Result;

/// Filesystem anchors for a tree-wide lint run.
#[derive(Debug, Clone)]
pub struct Paths {
    /// `rust/src` — the scanned tree.
    pub src_root: PathBuf,
    /// Repo root — findings are reported relative to it, and README.md
    /// lives there.
    pub repo_root: PathBuf,
}

impl Paths {
    /// Derive both anchors from the crate's manifest dir (`rust/`),
    /// which both the CLI and the integration tests know at compile
    /// time via `env!("CARGO_MANIFEST_DIR")`.
    pub fn from_manifest_dir(manifest_dir: &Path) -> Paths {
        Paths {
            src_root: manifest_dir.join("src"),
            repo_root: manifest_dir
                .parent()
                .unwrap_or(manifest_dir)
                .to_path_buf(),
        }
    }
}

/// Lint one in-memory source file: scan, run every single-file rule,
/// honor `lint:allow` hatches, report malformed hatches. This is the
/// fixture-test entry point; [`lint_tree`] calls it per file.
pub fn lint_source(path: &str, text: &str) -> Vec<Finding> {
    let src = scanner::Source::scan(path, text);
    let mut out: Vec<Finding> = Vec::new();
    out.extend(src.bad_allows().iter().cloned());
    out.extend(rules::wall_clock(&src));
    out.extend(rules::hash_order(&src));
    out.extend(rules::seeded_rng(&src));
    out.extend(rules::panic_surface(&src));
    out.extend(rules::float_fold(&src));
    out.extend(rules::hot_alloc(&src));
    // the hatch silences every rule except complaints about the hatch
    out.retain(|f| f.rule == "bad-allow" || !src.is_allowed(f.line, &f.rule));
    report::sort(&mut out);
    out
}

/// Lint the whole tree: every `.rs` file under `src_root` through
/// [`lint_source`], then the cross-file consistency rules. Findings
/// come back in deterministic (path, line, rule) order.
pub fn lint_tree(paths: &Paths) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    for file in rs_files(&paths.src_root)? {
        let text = std::fs::read_to_string(&file)
            .with_context(|| format!("reading {file:?}"))?;
        let rel = display_path(&paths.repo_root, &file);
        out.extend(lint_source(&rel, &text));
    }

    let read = |rel: &str| -> Result<String> {
        let p = paths.src_root.join(rel);
        std::fs::read_to_string(&p).with_context(|| format!("reading {p:?}"))
    };
    let main_src = read("main.rs")?;
    let server_src = read("federated/server.rs")?;
    let snapshot_src = read("runstate/snapshot.rs")?;
    let telemetry_src = read("telemetry/mod.rs")?;
    let readme_path = paths.repo_root.join("README.md");
    let readme = std::fs::read_to_string(&readme_path)
        .with_context(|| format!("reading {readme_path:?}"))?;

    let main_rel = display_path(&paths.repo_root, &paths.src_root.join("main.rs"));
    let snap_rel = display_path(&paths.repo_root, &paths.src_root.join("runstate/snapshot.rs"));
    let telem_rel = display_path(&paths.repo_root, &paths.src_root.join("telemetry/mod.rs"));
    out.extend(consistency::check_knob_fingerprint(&main_rel, &main_src, &server_src));
    out.extend(consistency::check_snapshot_tags(&snap_rel, &snapshot_src));
    out.extend(consistency::check_curve_schema(&telem_rel, &telemetry_src, &readme));

    report::sort(&mut out);
    Ok(out)
}

/// Every `.rs` file under `root`, depth-first in sorted order (the
/// report must be byte-stable across filesystems).
fn rs_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
            .with_context(|| format!("listing {dir:?}"))?
            .map(|e| e.map(|e| e.path()))
            .collect::<std::io::Result<_>>()?;
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Repo-relative `/`-separated display path.
fn display_path(repo_root: &Path, file: &Path) -> String {
    file.strip_prefix(repo_root)
        .unwrap_or(file)
        .to_string_lossy()
        .replace('\\', "/")
}

/// `--fix-allow`: insert a placeholder escape hatch above every finding
/// so a violation burn-down can start from a compiling tree. The
/// inserted justification is a greppable `FIXME`, which reviewers must
/// replace — the hatch is valid (the pass goes green) but the audit
/// trail is visibly unfinished. `bad-allow` and cross-file findings
/// are skipped (no line-local fix exists). Returns the insert count.
pub fn fix_allow(repo_root: &Path, findings: &[Finding]) -> Result<usize> {
    const NO_LOCAL_FIX: &[&str] = &["bad-allow", "knob-fingerprint", "snapshot-tags", "curve-schema"];
    let mut by_file: std::collections::BTreeMap<&str, Vec<&Finding>> = Default::default();
    for f in findings {
        if !NO_LOCAL_FIX.contains(&f.rule.as_str()) {
            by_file.entry(f.path.as_str()).or_default().push(f);
        }
    }
    let mut inserted = 0;
    for (rel, file_findings) in by_file {
        let path = repo_root.join(rel);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let mut lines: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        // bottom-up so earlier insertions don't shift later line numbers
        let mut sorted: Vec<&&Finding> = file_findings.iter().collect();
        sorted.sort_by_key(|f| std::cmp::Reverse((f.line, f.rule.clone())));
        for f in sorted {
            let idx = f.line.saturating_sub(1).min(lines.len());
            let indent: String = lines
                .get(idx)
                .map(|l| l.chars().take_while(|c| c.is_whitespace()).collect())
                .unwrap_or_default();
            lines.insert(
                idx,
                format!("{indent}// lint:allow({}): FIXME: justify this exception", f.rule),
            );
            inserted += 1;
        }
        let mut joined = lines.join("\n");
        joined.push('\n');
        std::fs::write(&path, joined).with_context(|| format!("writing {path:?}"))?;
    }
    Ok(inserted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_hatch_silences_exactly_its_rule() {
        let with_hatch = "\
            // lint:allow(wall-clock): latency probe, output discarded\n\
            let t = Instant::now();\n";
        assert!(lint_source("rust/src/coordinator/x.rs", with_hatch).is_empty());
        let wrong_rule = "\
            // lint:allow(hash-order): wrong rule\n\
            let t = Instant::now();\n";
        let f = lint_source("rust/src/coordinator/x.rs", wrong_rule);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "wall-clock");
    }

    #[test]
    fn bad_allow_cannot_silence_itself() {
        let f = lint_source(
            "rust/src/coordinator/x.rs",
            "x(); // lint:allow(bad-allow)\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "bad-allow");
    }

    #[test]
    fn findings_are_sorted() {
        let f = lint_source(
            "rust/src/coordinator/x.rs",
            "let t = SystemTime::now();\nlet r = thread_rng();\nlet u = Instant::now();\n",
        );
        let lines: Vec<usize> = f.iter().map(|f| f.line).collect();
        assert_eq!(lines, vec![1, 2, 3]);
    }

    #[test]
    fn fix_allow_inserts_a_valid_hatch() {
        let dir = std::env::temp_dir().join(format!("fedavg-lint-fix-{}", std::process::id()));
        std::fs::create_dir_all(dir.join("rust/src/coordinator")).unwrap();
        let rel = "rust/src/coordinator/x.rs";
        std::fs::write(dir.join(rel), "fn f() {\n    let t = Instant::now();\n}\n").unwrap();
        let before = lint_source(rel, &std::fs::read_to_string(dir.join(rel)).unwrap());
        assert_eq!(before.len(), 1);
        let n = fix_allow(&dir, &before).unwrap();
        assert_eq!(n, 1);
        let after_text = std::fs::read_to_string(dir.join(rel)).unwrap();
        assert!(after_text.contains("lint:allow(wall-clock): FIXME"));
        assert!(lint_source(rel, &after_text).is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
