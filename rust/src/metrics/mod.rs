//! Learning-curve machinery — the paper's evaluation methodology.
//!
//! §3: *"we construct a learning curve ..., then make the curve
//! monotonically improving by taking the best value of test-set accuracy
//! achieved over all prior rounds, and then calculate the number of rounds
//! where the curve crosses the target accuracy, using linear interpolation
//! between the discrete points forming the curve."*
//!
//! [`LearningCurve::rounds_to_target`] implements exactly that.

/// A (round, value) learning curve. Rounds must be pushed in increasing
/// order; values are arbitrary (accuracy, loss, ...).
#[derive(Debug, Clone, Default)]
pub struct LearningCurve {
    points: Vec<(u64, f64)>,
}

impl LearningCurve {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, round: u64, value: f64) {
        if let Some(&(last, _)) = self.points.last() {
            assert!(round > last, "rounds must increase: {round} after {last}");
        }
        self.points.push((round, value));
    }

    /// Rebuild a curve from points captured by [`points`](Self::points) —
    /// the run-state snapshot restore path (DESIGN.md §8). Validates the
    /// strictly-increasing-rounds invariant `push` enforces.
    pub fn from_points(points: Vec<(u64, f64)>) -> crate::Result<LearningCurve> {
        for w in points.windows(2) {
            anyhow::ensure!(
                w[1].0 > w[0].0,
                "corrupt curve: round {} after {}",
                w[1].0,
                w[0].0
            );
        }
        Ok(LearningCurve { points })
    }

    pub fn points(&self) -> &[(u64, f64)] {
        &self.points
    }

    pub fn last_value(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    pub fn best_value(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|&(_, v)| v)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// The paper's monotone transform: value at round r becomes the best
    /// value achieved at any round <= r.
    pub fn monotone(&self) -> LearningCurve {
        let mut best = f64::NEG_INFINITY;
        let points = self
            .points
            .iter()
            .map(|&(r, v)| {
                best = best.max(v);
                (r, best)
            })
            .collect();
        LearningCurve { points }
    }

    /// First (fractional) round where the *monotone* curve crosses
    /// `target`, by linear interpolation between curve points — the
    /// paper's Table 1/2/3/4 statistic. `None` if never reached.
    pub fn rounds_to_target(&self, target: f64) -> Option<f64> {
        let mono = self.monotone();
        let pts = &mono.points;
        if pts.is_empty() {
            return None;
        }
        if pts[0].1 >= target {
            return Some(pts[0].0 as f64);
        }
        for w in pts.windows(2) {
            let (r0, v0) = w[0];
            let (r1, v1) = w[1];
            if v0 < target && v1 >= target {
                let frac = (target - v0) / (v1 - v0);
                return Some(r0 as f64 + frac * (r1 - r0) as f64);
            }
        }
        None
    }
}

/// Speedup of `ours` vs `baseline` in rounds-to-target (paper's "(N×)"
/// annotations). `None` if either never reached the target.
pub fn speedup(baseline: Option<f64>, ours: Option<f64>) -> Option<f64> {
    match (baseline, ours) {
        (Some(b), Some(o)) if o > 0.0 => Some(b / o),
        _ => None,
    }
}

/// Format a rounds-to-target cell the way the paper prints them:
/// rounded rounds plus speedup vs baseline, or "—" for not reached.
pub fn format_cell(rounds: Option<f64>, base: Option<f64>) -> String {
    match rounds {
        None => "— (—)".to_string(),
        Some(r) => match speedup(base, Some(r)) {
            Some(s) => format!("{:.0} ({:.1}x)", r.ceil(), s),
            None => format!("{:.0} (—)", r.ceil()),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(points: &[(u64, f64)]) -> LearningCurve {
        let mut c = LearningCurve::new();
        for &(r, v) in points {
            c.push(r, v);
        }
        c
    }

    #[test]
    fn monotone_takes_running_best() {
        let c = curve(&[(1, 0.5), (2, 0.7), (3, 0.6), (4, 0.8)]);
        let m = c.monotone();
        assert_eq!(m.points(), &[(1, 0.5), (2, 0.7), (3, 0.7), (4, 0.8)]);
    }

    #[test]
    fn rounds_to_target_interpolates() {
        let c = curve(&[(10, 0.50), (20, 0.90)]);
        // crosses 0.70 exactly halfway between rounds 10 and 20
        assert!((c.rounds_to_target(0.70).unwrap() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn rounds_to_target_uses_monotone_curve() {
        // dips below target after first crossing must not matter
        let c = curve(&[(1, 0.2), (2, 0.8), (3, 0.1), (4, 0.9)]);
        assert!((c.rounds_to_target(0.5).unwrap() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn rounds_to_target_never_reached() {
        let c = curve(&[(1, 0.2), (2, 0.3)]);
        assert_eq!(c.rounds_to_target(0.5), None);
    }

    #[test]
    fn target_met_at_first_point() {
        let c = curve(&[(5, 0.99)]);
        assert_eq!(c.rounds_to_target(0.9), Some(5.0));
    }

    #[test]
    fn speedup_and_formatting() {
        assert_eq!(speedup(Some(100.0), Some(25.0)), Some(4.0));
        assert_eq!(speedup(None, Some(25.0)), None);
        assert_eq!(format_cell(Some(25.0), Some(100.0)), "25 (4.0x)");
        assert_eq!(format_cell(None, Some(100.0)), "— (—)");
    }

    #[test]
    #[should_panic(expected = "rounds must increase")]
    fn push_rejects_nonmonotone_rounds() {
        let mut c = LearningCurve::new();
        c.push(5, 0.1);
        c.push(5, 0.2);
    }
}
