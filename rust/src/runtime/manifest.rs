//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime: which models exist, their parameter counts, input
//! kinds/shapes, and which HLO-text file implements each entry point.
//! Parsed with the in-tree JSON reader (offline image: no serde).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context};

use crate::util::json::Json;
use crate::Result;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelMeta>,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub param_count: usize,
    /// "image" (x: f32[B, x_dim], y/w: [B]) or "tokens" (x/y/w: [B, x_dim]).
    pub kind: String,
    /// Feature dim for images, unroll length T for token models.
    pub x_dim: usize,
    /// Classes (image) or vocabulary size (tokens).
    pub num_classes: usize,
    /// Batch capacities with a dedicated `step_b{B}` executable.
    pub step_batches: Vec<usize>,
    /// Capacity of the `gradacc`/`eval` executables.
    pub acc_batch: usize,
    pub entries: BTreeMap<String, EntryMeta>,
}

#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub file: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text).context("parsing manifest.json")
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text)?;
        let mut models = BTreeMap::new();
        for (name, m) in root.get("models")?.as_obj()? {
            let mut entries = BTreeMap::new();
            for (ename, e) in m.get("entries")?.as_obj()? {
                entries.insert(
                    ename.clone(),
                    EntryMeta {
                        file: e.get("file")?.as_str()?.to_string(),
                    },
                );
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    name: m.get("name")?.as_str()?.to_string(),
                    param_count: m.get("param_count")?.as_usize()?,
                    kind: m.get("kind")?.as_str()?.to_string(),
                    x_dim: m.get("x_dim")?.as_usize()?,
                    num_classes: m.get("num_classes")?.as_usize()?,
                    step_batches: m
                        .get("step_batches")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<_>>()?,
                    acc_batch: m.get("acc_batch")?.as_usize()?,
                    entries,
                },
            );
        }
        Ok(Manifest { models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name:?} not in manifest (have: {:?}) — \
                 run `make artifacts` (or artifacts-full for word_lstm)",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

impl ModelMeta {
    pub fn is_tokens(&self) -> bool {
        self.kind == "tokens"
    }

    /// Smallest step capacity >= the logical batch, if any.
    pub fn step_capacity_for(&self, logical: usize) -> Option<usize> {
        self.step_batches
            .iter()
            .copied()
            .filter(|&c| c >= logical)
            .min()
    }

    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("model {} has no entry {name:?}", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"models":{"m":{"name":"m","param_count":3,"kind":"image",
        "x_dim":4,"num_classes":10,"step_batches":[10,50],"acc_batch":64,
        "entries":{"init":{"file":"m.init.hlo.txt","sha256":"ab","bytes":12}}}}}"#;

    #[test]
    fn step_capacity_selection() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let m = m.model("m").unwrap();
        assert_eq!(m.step_capacity_for(1), Some(10));
        assert_eq!(m.step_capacity_for(10), Some(10));
        assert_eq!(m.step_capacity_for(11), Some(50));
        assert_eq!(m.step_capacity_for(50), Some(50));
        assert_eq!(m.step_capacity_for(51), None);
    }

    #[test]
    fn manifest_parse_roundtrip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model("m").unwrap().param_count, 3);
        assert!(m.model("nope").is_err());
        assert_eq!(m.models["m"].entry("init").unwrap().file, "m.init.hlo.txt");
        assert!(m.models["m"].entry("step_b10").is_err());
        assert!(!m.models["m"].is_tokens());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // exercised against the actual artifacts when they exist
        for dir in ["artifacts", "../artifacts"] {
            let p = Path::new(dir);
            if p.join("manifest.json").exists() {
                let m = Manifest::load(p).unwrap();
                assert!(m.model("mnist_2nn").is_ok());
                let meta = m.model("mnist_2nn").unwrap();
                assert_eq!(meta.param_count, 199_210);
            }
        }
    }
}
