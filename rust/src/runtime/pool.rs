//! Generic worker pool over non-`Send` engines.
//!
//! The `xla` crate's client/executable types hold raw pointers and are not
//! `Send`, so parallel client updates cannot share one [`super::Engine`].
//! Instead each worker *thread* constructs its own engine via a factory
//! closure that runs inside the thread; jobs and results are plain `Send`
//! values moved over channels.
//!
//! On a single-core testbed this degenerates gracefully to one worker
//! (the default), but the topology is the same one a multi-socket
//! deployment would use — Algorithm 1's "for each client k ∈ S_t **in
//! parallel**".

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::anyhow;

use crate::Result;

/// A pool of `n` workers, each owning worker-local state of type `W`
/// (constructed in-thread by the factory, so `W` need not be `Send`).
pub struct WorkerPool<J: Send + 'static, O: Send + 'static> {
    job_tx: Option<mpsc::Sender<J>>,
    out_rx: mpsc::Receiver<O>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl<J: Send + 'static, O: Send + 'static> WorkerPool<J, O> {
    /// Spawn `workers` threads. `factory(worker_id)` builds the local
    /// state; `run(&mut state, job)` handles one job.
    ///
    /// Construction is a readiness barrier: every thread runs its
    /// factory and acks over a channel before `new` returns, so a
    /// factory failure surfaces here as the *real* error instead of an
    /// opaque "workers gone" on the first submit — which also lets
    /// callers drop their own validate-by-loading probes (the
    /// `ParallelExec` double-`Engine::load` this replaced).
    pub fn new<W, F, R>(workers: usize, factory: F, run: R) -> Result<Self>
    where
        F: Fn(usize) -> Result<W> + Send + Sync + Clone + 'static,
        R: Fn(&mut W, J) -> O + Send + Sync + Clone + 'static,
    {
        anyhow::ensure!(workers >= 1, "pool needs >= 1 worker");
        let (job_tx, job_rx) = mpsc::channel::<J>();
        let job_rx = std::sync::Arc::new(std::sync::Mutex::new(job_rx));
        let (out_tx, out_rx) = mpsc::channel::<O>();
        let (ack_tx, ack_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let mut handles = Vec::new();
        for id in 0..workers {
            let job_rx = job_rx.clone();
            let out_tx = out_tx.clone();
            let ack_tx = ack_tx.clone();
            let factory = factory.clone();
            let run = run.clone();
            handles.push(std::thread::spawn(move || {
                let mut state = match factory(id) {
                    Ok(s) => {
                        let _ = ack_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ack_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                drop(ack_tx);
                loop {
                    let job = match job_rx.lock().expect("pool queue poisoned").recv() {
                        Ok(j) => j,
                        Err(_) => return, // all senders dropped — shut down
                    };
                    if out_tx.send(run(&mut state, job)).is_err() {
                        return;
                    }
                }
            }));
        }
        drop(ack_tx);
        for _ in 0..workers {
            let ack = ack_rx
                .recv()
                .map_err(|_| anyhow!("pool worker exited before reporting readiness"));
            if let Err(e) = ack.and_then(|r| r.map_err(|e| anyhow!("pool worker factory failed: {e}"))) {
                drop(job_tx); // close the queue so ready workers shut down
                for h in handles {
                    let _ = h.join();
                }
                return Err(e);
            }
        }
        Ok(Self {
            job_tx: Some(job_tx),
            out_rx,
            handles,
            workers,
        })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Submit a job (non-blocking).
    pub fn submit(&self, job: J) -> Result<()> {
        self.job_tx
            .as_ref()
            .ok_or_else(|| anyhow!("pool already shut down"))?
            .send(job)
            .map_err(|_| anyhow!("pool workers gone"))
    }

    /// Receive one result (blocking).
    pub fn recv(&self) -> Result<O> {
        self.out_rx.recv().map_err(|_| anyhow!("pool workers gone"))
    }

    /// Submit all jobs, then collect exactly as many results.
    pub fn map(&self, jobs: impl IntoIterator<Item = J>) -> Result<Vec<O>> {
        let mut out = Vec::new();
        self.map_into(jobs, &mut out)?;
        Ok(out)
    }

    /// [`Self::map`] into a caller-owned buffer (cleared, then filled) —
    /// the per-round scratch path (DESIGN.md §14): the buffer's spine is
    /// reused round to round instead of reallocated.
    pub fn map_into(&self, jobs: impl IntoIterator<Item = J>, out: &mut Vec<O>) -> Result<()> {
        out.clear();
        let mut n = 0usize;
        for j in jobs {
            self.submit(j)?;
            n += 1;
        }
        out.reserve(n);
        for _ in 0..n {
            out.push(self.recv()?);
        }
        Ok(())
    }
}

impl<J: Send + 'static, O: Send + 'static> Drop for WorkerPool<J, O> {
    fn drop(&mut self) {
        self.job_tx.take(); // close the queue
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_jobs_with_thread_local_state() {
        // worker state is a non-trivial accumulator built in-thread
        let pool: WorkerPool<u64, u64> =
            WorkerPool::new(3, |id| Ok(id as u64 * 1000), |state, j| {
                *state += 1; // worker-local mutation
                j * 2
            })
            .unwrap();
        let mut out = pool.map(1..=50u64).unwrap();
        out.sort_unstable();
        assert_eq!(out, (1..=50u64).map(|v| v * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_single_worker_ordering() {
        let pool: WorkerPool<u32, u32> =
            WorkerPool::new(1, |_| Ok(()), |_, j| j + 1).unwrap();
        let out = pool.map([1, 2, 3]).unwrap();
        assert_eq!(out, vec![2, 3, 4]); // single worker preserves order
    }

    #[test]
    fn pool_factory_failure_surfaces_real_error() {
        // one bad worker out of three: new() must fail with the factory's
        // own message, and the ready workers must shut down cleanly
        let r: Result<WorkerPool<u32, u32>> = WorkerPool::new(
            3,
            |id| {
                if id == 1 {
                    Err(anyhow!("boom on worker 1"))
                } else {
                    Ok(())
                }
            },
            |_, j| j,
        );
        let err = format!("{:#}", r.err().expect("factory failure must propagate"));
        assert!(err.contains("boom on worker 1"), "got: {err}");
    }

    #[test]
    fn pool_map_into_reuses_buffer() {
        let pool: WorkerPool<u32, u32> =
            WorkerPool::new(1, |_| Ok(()), |_, j| j * 10).unwrap();
        let mut out = vec![7u32; 32]; // stale contents must be cleared
        pool.map_into([1, 2, 3], &mut out).unwrap();
        assert_eq!(out, vec![10, 20, 30]);
        pool.map_into([4], &mut out).unwrap();
        assert_eq!(out, vec![40]);
    }

    #[test]
    fn pool_shutdown_on_drop_is_clean() {
        let pool: WorkerPool<u32, u32> =
            WorkerPool::new(2, |_| Ok(()), |_, j| j).unwrap();
        pool.submit(9).unwrap();
        let _ = pool.recv().unwrap();
        drop(pool); // must not hang or panic
    }
}
