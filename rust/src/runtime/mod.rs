//! PJRT runtime — loads the AOT HLO-text artifacts and executes them.
//!
//! Pattern follows `/opt/xla-example/load_hlo/`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled lazily on first use and cached for the life
//! of the [`Engine`]; after construction the request path is pure rust +
//! XLA (no python anywhere).

pub mod manifest;
pub mod pool;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::anyhow;

use crate::data::PaddedBatch;
use crate::params::ParamVec;
use crate::Result;

pub use manifest::{Manifest, ModelMeta};

/// Aggregate eval statistics returned by the `eval` entry point.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EvalSums {
    pub loss_sum: f64,
    pub correct_sum: f64,
    pub weight_sum: f64,
}

impl EvalSums {
    pub fn accumulate(&mut self, other: EvalSums) {
        self.loss_sum += other.loss_sum;
        self.correct_sum += other.correct_sum;
        self.weight_sum += other.weight_sum;
    }

    pub fn mean_loss(&self) -> f64 {
        self.loss_sum / self.weight_sum.max(1e-12)
    }

    pub fn accuracy(&self) -> f64 {
        self.correct_sum / self.weight_sum.max(1e-12)
    }
}

/// Counters for everything the engine has executed — feeds the §Perf
/// benches and the computation accounting in experiment reports.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub steps: u64,
    pub gradaccs: u64,
    pub applies: u64,
    pub evals: u64,
    pub inits: u64,
    pub compile_ms: u64,
    pub execute_ms: u64,
}

/// One PJRT CPU client plus a lazily-compiled executable cache.
///
/// Not `Send`/`Sync` (the underlying crate types hold raw pointers);
/// for multi-worker setups each worker thread owns its own `Engine`
/// (see [`pool`]).
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    // BTreeMap rather than HashMap: the cache is keyed lookup only today,
    // but an ordered map keeps any future iteration deterministic for free
    // (rule `hash-order` — DESIGN.md §13).
    cache: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<ExecStats>,
}

impl Engine {
    /// Load the manifest from `dir` (usually `artifacts/`) and connect to
    /// the PJRT CPU platform.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(ExecStats::default()),
        })
    }

    /// Locate the artifacts directory: `$FEDAVG_ARTIFACTS`, else
    /// `./artifacts`, else `../artifacts` (for `cargo test` from subdirs).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("FEDAVG_ARTIFACTS") {
            return PathBuf::from(d);
        }
        for cand in ["artifacts", "../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    /// The artifacts directory this engine loaded from — worker pools
    /// construct their per-thread sibling engines from it.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    /// Handle for one model family's entry points.
    pub fn model(&self, name: &str) -> Result<Model<'_>> {
        let meta = self.manifest.model(name)?.clone();
        Ok(Model { engine: self, meta })
    }

    #[allow(clippy::disallowed_methods)] // Instant::now: compile-time stats only, never trajectory state
    fn executable(
        &self,
        model: &ModelMeta,
        entry: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}.{}", model.name, entry);
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let file = self.dir.join(&model.entry(entry)?.file);
        // lint:allow(wall-clock): compile-time accounting feeds ExecStats reporting; no trajectory decision reads it.
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {file:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {key}: {e}"))?;
        self.stats.borrow_mut().compile_ms += t0.elapsed().as_millis() as u64;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of entries (so timed runs exclude compile cost).
    pub fn warmup(&self, model_name: &str, entries: &[&str]) -> Result<()> {
        let meta = self.manifest.model(model_name)?.clone();
        for e in entries {
            self.executable(&meta, e)?;
        }
        Ok(())
    }

    #[allow(clippy::disallowed_methods)] // Instant::now: execute-time stats only, never trajectory state
    fn run1(&self, exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
        // lint:allow(wall-clock): execute-time accounting feeds ExecStats reporting; no trajectory decision reads it.
        let t0 = std::time::Instant::now();
        let bufs = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        self.stats.borrow_mut().execute_ms += t0.elapsed().as_millis() as u64;
        // aot.py lowers with return_tuple=True → single-element tuple.
        lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))
    }
}

// ------------------------------------------------------- literal helpers

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(v);
    }
    v.reshape(dims).map_err(|e| anyhow!("reshape f32: {e}"))
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(v);
    }
    v.reshape(dims).map_err(|e| anyhow!("reshape i32: {e}"))
}

/// One model family's typed entry points.
pub struct Model<'e> {
    engine: &'e Engine,
    meta: ModelMeta,
}

impl<'e> Model<'e> {
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn param_count(&self) -> usize {
        self.meta.param_count
    }

    fn batch_literals(&self, b: &PaddedBatch) -> Result<[xla::Literal; 3]> {
        let cap = b.cap as i64;
        let rd = b.row_dim as i64;
        if b.tokens {
            Ok([
                lit_i32(&b.xi, &[cap, rd])?,
                lit_i32(&b.y, &[cap, rd])?,
                lit_f32(&b.w, &[cap, rd])?,
            ])
        } else {
            Ok([
                lit_f32(&b.xf, &[cap, rd])?,
                lit_i32(&b.y, &[cap])?,
                lit_f32(&b.w, &[cap])?,
            ])
        }
    }

    /// `init(seed) -> θ` — paper-faithful random initialization.
    pub fn init(&self, seed: i32) -> Result<ParamVec> {
        let exe = self.engine.executable(&self.meta, "init")?;
        let out = self.engine.run1(&exe, &[xla::Literal::scalar(seed)])?;
        self.engine.stats.borrow_mut().inits += 1;
        out.to_vec::<f32>().map_err(|e| anyhow!("init out: {e}"))
    }

    /// One local SGD step on a (weight-padded) minibatch.
    pub fn step(&self, theta: &[f32], batch: &PaddedBatch, lr: f32) -> Result<ParamVec> {
        let entry = format!("step_b{}", batch.cap);
        let exe = self.engine.executable(&self.meta, &entry)?;
        let [x, y, w] = self.batch_literals(batch)?;
        let t = lit_f32(theta, &[theta.len() as i64])?;
        let out = self
            .engine
            .run1(&exe, &[t, x, y, w, xla::Literal::scalar(lr)])?;
        self.engine.stats.borrow_mut().steps += 1;
        out.to_vec::<f32>().map_err(|e| anyhow!("step out: {e}"))
    }

    /// Σᵢ wᵢ∇ℓᵢ over a batch (unnormalized; linear in examples).
    pub fn gradacc(&self, theta: &[f32], batch: &PaddedBatch) -> Result<ParamVec> {
        let entry = format!("gradacc_b{}", batch.cap);
        let exe = self.engine.executable(&self.meta, &entry)?;
        let [x, y, w] = self.batch_literals(batch)?;
        let t = lit_f32(theta, &[theta.len() as i64])?;
        let out = self.engine.run1(&exe, &[t, x, y, w])?;
        self.engine.stats.borrow_mut().gradaccs += 1;
        out.to_vec::<f32>().map_err(|e| anyhow!("gradacc out: {e}"))
    }

    /// `θ - lr·g` via the fused Pallas axpy.
    pub fn apply(&self, theta: &[f32], grad: &[f32], lr: f32) -> Result<ParamVec> {
        let exe = self.engine.executable(&self.meta, "apply")?;
        let t = lit_f32(theta, &[theta.len() as i64])?;
        let g = lit_f32(grad, &[grad.len() as i64])?;
        let out = self.engine.run1(&exe, &[t, g, xla::Literal::scalar(lr)])?;
        self.engine.stats.borrow_mut().applies += 1;
        out.to_vec::<f32>().map_err(|e| anyhow!("apply out: {e}"))
    }

    /// Weighted eval sums over one batch.
    pub fn eval_batch(&self, theta: &[f32], batch: &PaddedBatch) -> Result<EvalSums> {
        let entry = format!("eval_b{}", batch.cap);
        let exe = self.engine.executable(&self.meta, &entry)?;
        let [x, y, w] = self.batch_literals(batch)?;
        let t = lit_f32(theta, &[theta.len() as i64])?;
        let out = self.engine.run1(&exe, &[t, x, y, w])?;
        self.engine.stats.borrow_mut().evals += 1;
        let v = out.to_vec::<f32>().map_err(|e| anyhow!("eval out: {e}"))?;
        anyhow::ensure!(v.len() == 3, "eval returned {} values", v.len());
        Ok(EvalSums {
            loss_sum: v[0] as f64,
            correct_sum: v[1] as f64,
            weight_sum: v[2] as f64,
        })
    }

    /// Evaluate θ over an entire dataset (or an index subset), chunked
    /// through the fixed-capacity eval executable.
    pub fn eval_dataset(
        &self,
        theta: &[f32],
        data: &crate::data::Dataset,
        idxs: Option<&[usize]>,
    ) -> Result<EvalSums> {
        let cap = self.meta.acc_batch;
        let all: Vec<usize>;
        let idxs = match idxs {
            Some(i) => i,
            None => {
                all = (0..data.len()).collect();
                &all
            }
        };
        let mut sums = EvalSums::default();
        for chunk in idxs.chunks(cap) {
            let b = data.padded_batch(chunk, cap);
            sums.accumulate(self.eval_batch(theta, &b)?);
        }
        Ok(sums)
    }

    /// Exact full-batch gradient of the *mean* loss over `idxs`, chunked
    /// through the gradacc executable (exact because per-example gradients
    /// sum linearly — verified by test_entries.py + integration tests).
    /// Returns the gradient and the total example weight it averaged over.
    pub fn full_gradient(
        &self,
        theta: &[f32],
        data: &crate::data::Dataset,
        idxs: &[usize],
    ) -> Result<(ParamVec, f64)> {
        let cap = self.meta.acc_batch;
        let mut g = vec![0.0f32; theta.len()];
        let mut wsum = 0.0f64;
        for chunk in idxs.chunks(cap) {
            let b = data.padded_batch(chunk, cap);
            wsum += b.weight_sum();
            let part = self.gradacc(theta, &b)?;
            crate::params::axpy(&mut g, 1.0, &part);
        }
        let inv = 1.0 / wsum.max(1e-12);
        crate::params::scale(&mut g, inv as f32);
        Ok((g, wsum))
    }
}
