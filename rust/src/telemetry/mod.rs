//! Run telemetry: per-round CSV curves + JSON run summaries under
//! `runs/<name>/`, plus a console progress logger. Everything the
//! experiment harnesses print is also persisted so figures can be
//! re-plotted without re-running.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Context;

use crate::util::json::escape;
use crate::Result;

/// Writer for one training run's outputs.
pub struct RunWriter {
    dir: PathBuf,
    curve: BufWriter<File>,
    started: Instant,
    quiet: bool,
}

/// One evaluated round's record.
#[derive(Debug, Clone, Copy)]
pub struct RoundRecord<'a> {
    pub round: u64,
    pub test_accuracy: f64,
    pub test_loss: f64,
    pub train_loss: Option<f64>,
    pub clients: usize,
    pub lr: f64,
    /// Wire bytes uploaded this round (transport-metered).
    pub up_bytes: u64,
    /// Wire bytes broadcast this round, incl. to dropped stragglers.
    pub down_bytes: u64,
    /// Active codec label, `"<up>/<down>"` (e.g. `topk:0.01|q8/delta`;
    /// `dense/dense` on the legacy path).
    pub codec: &'a str,
    pub sim_seconds: f64,
    /// Straggler updates dropped since the previous record (fleet runs;
    /// 0 on the legacy path).
    pub dropped: usize,
    /// Round deadlines missed since the previous record.
    pub deadline_misses: usize,
    /// Active aggregation rule, the canonical registry label
    /// (`"fedavg"`, `"fedavgm:0.9"`, `"trimmed:0.1"`, …).
    pub agg: &'a str,
    /// Server-optimizer state norms as `;`-joined `name=l2` pairs
    /// (`federated::aggregate::fmt_state_norms`); empty for stateless
    /// rules like plain FedAvg.
    pub server_state: &'a str,
}

/// Sanitize `name` and create `<root>/<name>/`. Shared by both writers.
fn run_dir(root: impl AsRef<Path>, name: &str) -> Result<PathBuf> {
    let safe: String = name
        .chars()
        .map(|c| if c.is_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' })
        .collect();
    let dir = root.as_ref().join(safe);
    std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
    Ok(dir)
}

impl RunWriter {
    /// Create `runs/<name>/` (name sanitized) and open curve.csv.
    pub fn create(root: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = run_dir(root, name)?;
        let curve = BufWriter::new(File::create(dir.join("curve.csv"))?);
        let mut w = Self {
            dir,
            curve,
            started: Instant::now(),
            quiet: std::env::var("FEDAVG_QUIET").is_ok(),
        };
        writeln!(
            w.curve,
            "round,test_accuracy,test_loss,train_loss,clients,lr,up_bytes,down_bytes,codec,sim_seconds,dropped,deadline_misses,agg,server_state"
        )?;
        Ok(w)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn record(&mut self, r: &RoundRecord<'_>) -> Result<()> {
        writeln!(
            self.curve,
            "{},{:.6},{:.6},{},{},{:.6},{},{},{},{:.3},{},{},{},{}",
            r.round,
            r.test_accuracy,
            r.test_loss,
            r.train_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
            r.clients,
            r.lr,
            r.up_bytes,
            r.down_bytes,
            r.codec,
            r.sim_seconds,
            r.dropped,
            r.deadline_misses,
            r.agg,
            r.server_state
        )?;
        if !self.quiet {
            let tl = r
                .train_loss
                .map(|v| format!(" train_loss={v:.4}"))
                .unwrap_or_default();
            let fleet = if r.dropped > 0 || r.deadline_misses > 0 {
                format!(" dropped={} misses={}", r.dropped, r.deadline_misses)
            } else {
                String::new()
            };
            println!(
                "[{:>7.1}s] round {:>5}  acc={:.4} loss={:.4}{tl}  (m={}, lr={:.4}){fleet}",
                self.started.elapsed().as_secs_f64(),
                r.round,
                r.test_accuracy,
                r.test_loss,
                r.clients,
                r.lr
            );
        }
        Ok(())
    }

    /// Write the final summary JSON (flat string→string map + numbers).
    pub fn finish(mut self, fields: &[(&str, String)]) -> Result<PathBuf> {
        self.curve.flush()?;
        write_summary(&self.dir, fields)
    }
}

/// Write `<dir>/summary.json` as a flat map (numbers pass through bare
/// if they parse; strings escaped). Shared by [`RunWriter`] and
/// [`FleetWriter`].
pub fn write_summary(dir: &Path, fields: &[(&str, String)]) -> Result<PathBuf> {
    let mut out = String::from("{\n");
    for (i, (k, v)) in fields.iter().enumerate() {
        let comma = if i + 1 == fields.len() { "" } else { "," };
        if v.parse::<f64>().is_ok() || v == "true" || v == "false" || v == "null" {
            out.push_str(&format!("  {}: {v}{comma}\n", escape(k)));
        } else {
            out.push_str(&format!("  {}: {}{comma}\n", escape(k), escape(v)));
        }
    }
    out.push_str("}\n");
    let path = dir.join("summary.json");
    std::fs::write(&path, out)?;
    Ok(path)
}

/// Per-round record of a training-free fleet simulation
/// (`fedavg fleet --sim-only`, `examples/fleet_stress.rs`).
#[derive(Debug, Clone, Copy)]
pub struct FleetRoundRecord {
    pub round: u64,
    pub online: usize,
    pub dispatched: usize,
    pub completed: usize,
    pub dropped: usize,
    pub deadline_miss: bool,
    pub round_seconds: f64,
}

/// Writer for fleet-simulation runs: `runs/<name>/fleet.csv` + the same
/// summary JSON as [`RunWriter`].
pub struct FleetWriter {
    dir: PathBuf,
    csv: BufWriter<File>,
}

impl FleetWriter {
    pub fn create(root: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = run_dir(root, name)?;
        let mut csv = BufWriter::new(File::create(dir.join("fleet.csv"))?);
        writeln!(
            csv,
            "round,online,dispatched,completed,dropped,deadline_miss,round_seconds"
        )?;
        Ok(Self { dir, csv })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn record(&mut self, r: &FleetRoundRecord) -> Result<()> {
        writeln!(
            self.csv,
            "{},{},{},{},{},{},{:.3}",
            r.round, r.online, r.dispatched, r.completed, r.dropped, r.deadline_miss as u8,
            r.round_seconds
        )?;
        Ok(())
    }

    pub fn finish(mut self, fields: &[(&str, String)]) -> Result<PathBuf> {
        self.csv.flush()?;
        write_summary(&self.dir, fields)
    }
}

/// Null telemetry sink for benches/tests (writes to a temp-ish dir under
/// target/).
pub fn scratch_writer(tag: &str) -> Result<RunWriter> {
    let pid = std::process::id();
    RunWriter::create("target/test-runs", &format!("{tag}-{pid}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_curve_and_summary() {
        let mut w = scratch_writer("telemetry-test").unwrap();
        let dir = w.dir().to_path_buf();
        w.record(&RoundRecord {
            round: 1,
            test_accuracy: 0.5,
            test_loss: 1.2,
            train_loss: Some(1.1),
            clients: 10,
            lr: 0.1,
            up_bytes: 123,
            down_bytes: 999,
            codec: "dense/dense",
            sim_seconds: 4.5,
            dropped: 0,
            deadline_misses: 0,
            agg: "fedavg",
            server_state: "",
        })
        .unwrap();
        w.record(&RoundRecord {
            round: 2,
            test_accuracy: 0.6,
            test_loss: 1.0,
            train_loss: None,
            clients: 10,
            lr: 0.1,
            up_bytes: 456,
            down_bytes: 888,
            codec: "topk:0.01|q8/delta",
            sim_seconds: 9.0,
            dropped: 3,
            deadline_misses: 1,
            agg: "fedavgm:0.9",
            server_state: "momentum=1.000000e0",
        })
        .unwrap();
        let summary = w
            .finish(&[("rounds", "2".into()), ("model", "mnist_2nn".into())])
            .unwrap();
        let csv = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
        assert!(csv.starts_with("round,"));
        assert!(csv.lines().next().unwrap().contains("up_bytes,down_bytes,codec"));
        assert!(csv.lines().next().unwrap().ends_with("dropped,deadline_misses,agg,server_state"));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("2,0.600000"));
        assert!(csv.contains("123,999,dense/dense"));
        assert!(csv.contains("456,888,topk:0.01|q8/delta"));
        assert!(csv.lines().nth(1).unwrap().ends_with(",0,0,fedavg,"));
        assert!(csv.lines().nth(2).unwrap().ends_with(",3,1,fedavgm:0.9,momentum=1.000000e0"));
        let json = std::fs::read_to_string(summary).unwrap();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("rounds").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("model").unwrap().as_str().unwrap(), "mnist_2nn");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fleet_writer_csv_and_summary() {
        let pid = std::process::id();
        let mut w =
            FleetWriter::create("target/test-runs", &format!("fleet-test-{pid}")).unwrap();
        let dir = w.dir().to_path_buf();
        w.record(&FleetRoundRecord {
            round: 1,
            online: 900,
            dispatched: 130,
            completed: 100,
            dropped: 30,
            deadline_miss: false,
            round_seconds: 41.5,
        })
        .unwrap();
        let summary = w.finish(&[("rounds", "1".into())]).unwrap();
        let csv = std::fs::read_to_string(dir.join("fleet.csv")).unwrap();
        assert!(csv.starts_with("round,online,dispatched,"));
        assert!(csv.contains("1,900,130,100,30,0,41.500"));
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(summary).unwrap()).unwrap();
        assert_eq!(parsed.get("rounds").unwrap().as_usize().unwrap(), 1);
        std::fs::remove_dir_all(dir).ok();
    }
}
