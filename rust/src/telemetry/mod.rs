//! Run telemetry: per-round CSV curves + JSON run summaries under
//! `runs/<name>/`, plus a console progress logger. Everything the
//! experiment harnesses print is also persisted so figures can be
//! re-plotted without re-running.
//!
//! Durability rules (DESIGN.md §8):
//!
//! * every record is flushed to the OS as it is written, so a killed run
//!   keeps its whole curve up to the last completed eval — telemetry
//!   must never lose more than the row being written;
//! * [`RunWriter::create`] / [`FleetWriter::create`] refuse to reuse a
//!   run directory that already holds a curve (two run names can
//!   sanitize to the same directory — e.g. `C=0.1` and `C 0.1` — and
//!   truncating silently destroys the first run's data); reruns opt in
//!   via [`RunWriter::create_overwrite`] (`--overwrite`), resumed runs
//!   via [`RunWriter::reopen`] (`--resume`), which truncates the curve
//!   back to the checkpointed round and appends from there.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Context;

use crate::util::json::escape;
use crate::Result;

/// Writer for one training run's outputs.
pub struct RunWriter {
    dir: PathBuf,
    curve: BufWriter<File>,
    started: Instant,
    quiet: bool,
}

/// One evaluated round's record.
#[derive(Debug, Clone, Copy)]
pub struct RoundRecord<'a> {
    pub round: u64,
    pub test_accuracy: f64,
    pub test_loss: f64,
    pub train_loss: Option<f64>,
    pub clients: usize,
    pub lr: f64,
    /// Wire bytes uploaded this round (transport-metered).
    pub up_bytes: u64,
    /// Wire bytes broadcast this round, incl. to dropped stragglers.
    pub down_bytes: u64,
    /// Active codec label, `"<up>/<down>"` (e.g. `topk:0.01|q8/delta`;
    /// `dense/dense` on the legacy path).
    pub codec: &'a str,
    pub sim_seconds: f64,
    /// Straggler updates dropped since the previous record (fleet runs;
    /// 0 on the legacy path).
    pub dropped: usize,
    /// Round deadlines missed since the previous record.
    pub deadline_misses: usize,
    /// Active aggregation rule, the canonical registry label
    /// (`"fedavg"`, `"fedavgm:0.9"`, `"trimmed:0.1"`, …).
    pub agg: &'a str,
    /// Server-optimizer state norms as `;`-joined `name=l2` pairs
    /// (`federated::aggregate::fmt_state_norms`); empty for stateless
    /// rules like plain FedAvg.
    pub server_state: &'a str,
    /// Mean staleness (in server applies) over the deltas applied since
    /// the previous record — async round modes (DESIGN.md §12); 0 on the
    /// synchronous path, where every applied delta is fresh.
    pub staleness_mean: f64,
    /// Deltas waiting in the async buffer / semi-sync late queue when the
    /// record was written; 0 on the synchronous path.
    pub buffer_fill: usize,
}

/// Sanitize a run/grid name for use as a directory component — the one
/// rule shared by the run writers and the grid engine's grid dirs
/// (`exper::grid`).
pub fn sanitize_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' })
        .collect()
}

/// Sanitize `name` and create `<root>/<name>/`. Shared by both writers.
fn run_dir(root: impl AsRef<Path>, name: &str) -> Result<PathBuf> {
    let dir = root.as_ref().join(sanitize_name(name));
    std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
    Ok(dir)
}

/// The curve.csv header row (also the schema table in README.md).
const CURVE_HEADER: &str = "round,test_accuracy,test_loss,train_loss,clients,lr,up_bytes,down_bytes,codec,sim_seconds,dropped,deadline_misses,agg,server_state,staleness_mean,buffer_fill";

/// Refuse to clobber an existing curve file: sanitized run names can
/// collide, and `File::create` would silently truncate the loser.
fn refuse_existing(dir: &Path, file: &str) -> Result<()> {
    let path = dir.join(file);
    anyhow::ensure!(
        !path.exists(),
        "run dir {dir:?} already holds {file} — pick a fresh --name, rerun \
         with --overwrite, or continue it with --resume {dir:?}"
    );
    Ok(())
}

impl RunWriter {
    /// Create `runs/<name>/` (name sanitized) and open a fresh curve.csv.
    /// Errors if the directory already holds one (see the module docs);
    /// use [`create_overwrite`](Self::create_overwrite) to replace it or
    /// [`reopen`](Self::reopen) to resume it.
    pub fn create(root: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = run_dir(root, name)?;
        refuse_existing(&dir, "curve.csv")?;
        Self::open_fresh(dir)
    }

    /// Like [`create`](Self::create), but knowingly replaces any
    /// existing curve (experiment harness reruns, scratch writers).
    /// Also removes a stale `checkpoints/` dir from the replaced run:
    /// its higher-round snapshots would otherwise win the keep-last-K
    /// rotation (deleting the new run's own snapshots as "oldest") and
    /// hijack a later `--resume` (DESIGN.md §8).
    pub fn create_overwrite(root: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = run_dir(root, name)?;
        let ckpts = dir.join("checkpoints");
        if ckpts.exists() {
            std::fs::remove_dir_all(&ckpts)
                .with_context(|| format!("clearing stale {ckpts:?}"))?;
        }
        Self::open_fresh(dir)
    }

    /// Open `dir` itself as a fresh run dir — for callers that key run
    /// dirs directly (the grid engine's fingerprint-keyed cell dirs,
    /// `exper::grid`). Overwrite semantics of
    /// [`create_overwrite`](Self::create_overwrite): a stale curve is
    /// replaced and leftover checkpoints are cleared.
    pub fn create_dir_overwrite(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
        let ckpts = dir.join("checkpoints");
        if ckpts.exists() {
            std::fs::remove_dir_all(&ckpts)
                .with_context(|| format!("clearing stale {ckpts:?}"))?;
        }
        Self::open_fresh(dir)
    }

    /// Silence the per-round console line (parallel grid cells would
    /// interleave); rows still land in curve.csv. Additive with the
    /// `FEDAVG_QUIET` env var — neither can unmute the other.
    pub fn set_quiet(&mut self, quiet: bool) {
        self.quiet = self.quiet || quiet;
    }

    #[allow(clippy::disallowed_methods)] // Instant::now: console elapsed display only
    fn open_fresh(dir: PathBuf) -> Result<Self> {
        let curve = BufWriter::new(File::create(dir.join("curve.csv"))?);
        let mut w = Self {
            dir,
            curve,
            // lint:allow(wall-clock): feeds only the human console line's elapsed column; curve.csv carries no wall time.
            started: Instant::now(),
            quiet: std::env::var("FEDAVG_QUIET").is_ok(),
        };
        writeln!(w.curve, "{CURVE_HEADER}")?;
        w.curve.flush()?;
        Ok(w)
    }

    /// Reopen an existing run directory to resume it: truncate curve.csv
    /// back to rows with `round <= last_round` (atomically, tmp+rename —
    /// rows past the checkpoint belong to a future the resumed run will
    /// re-create, possibly differently if flags changed) and append from
    /// there. The resume path of `crate::runstate` (DESIGN.md §8).
    ///
    /// The file is append-only with rows flushed whole, so the only
    /// damage a crash can leave is a torn **final** row (SIGKILL between
    /// the partial write and the flush). Any row that is short, fails to
    /// parse, or breaks the strictly-increasing round order is therefore
    /// treated — together with everything after it — as the lost future
    /// and dropped, not kept verbatim or turned into a hard error.
    #[allow(clippy::disallowed_methods)] // Instant::now: console elapsed display only
    pub fn reopen(run_dir: impl AsRef<Path>, last_round: u64) -> Result<Self> {
        let dir = run_dir.as_ref().to_path_buf();
        let path = dir.join("curve.csv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("resume: reading {path:?}"))?;
        let n_fields = CURVE_HEADER.split(',').count();
        let mut kept = String::new();
        let mut prev_round = 0u64;
        for (i, line) in text.lines().enumerate() {
            if i == 0 {
                anyhow::ensure!(
                    line == CURVE_HEADER,
                    "resume: {path:?} has an unrecognized header (different \
                     telemetry schema?): {line:?}"
                );
            } else {
                let round = line.split(',').next().unwrap_or("").parse::<u64>();
                match round {
                    Ok(r) if line.split(',').count() == n_fields && r > prev_round => {
                        if r > last_round {
                            break; // rounds increase: all later rows are future too
                        }
                        prev_round = r;
                    }
                    _ => break, // torn/corrupt tail
                }
            }
            kept.push_str(line);
            kept.push('\n');
        }
        anyhow::ensure!(
            !kept.is_empty(),
            "resume: {path:?} is empty — not a run this writer produced"
        );
        let tmp = dir.join("curve.csv.tmp");
        std::fs::write(&tmp, &kept)?;
        std::fs::rename(&tmp, &path)?;
        let curve = BufWriter::new(File::options().append(true).open(&path)?);
        Ok(Self {
            dir,
            curve,
            // lint:allow(wall-clock): feeds only the human console line's elapsed column; curve.csv carries no wall time.
            started: Instant::now(),
            quiet: std::env::var("FEDAVG_QUIET").is_ok(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn record(&mut self, r: &RoundRecord<'_>) -> Result<()> {
        writeln!(
            self.curve,
            "{},{:.6},{:.6},{},{},{:.6},{},{},{},{:.3},{},{},{},{},{:.3},{}",
            r.round,
            r.test_accuracy,
            r.test_loss,
            r.train_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
            r.clients,
            r.lr,
            r.up_bytes,
            r.down_bytes,
            r.codec,
            r.sim_seconds,
            r.dropped,
            r.deadline_misses,
            r.agg,
            r.server_state,
            r.staleness_mean,
            r.buffer_fill
        )?;
        // durability: a crashed run must keep every completed row — a
        // row-per-eval stream buffered until finish() loses everything
        self.curve.flush()?;
        if !self.quiet {
            let tl = r
                .train_loss
                .map(|v| format!(" train_loss={v:.4}"))
                .unwrap_or_default();
            let fleet = if r.dropped > 0 || r.deadline_misses > 0 {
                format!(" dropped={} misses={}", r.dropped, r.deadline_misses)
            } else {
                String::new()
            };
            println!(
                "[{:>7.1}s] round {:>5}  acc={:.4} loss={:.4}{tl}  (m={}, lr={:.4}){fleet}",
                self.started.elapsed().as_secs_f64(),
                r.round,
                r.test_accuracy,
                r.test_loss,
                r.clients,
                r.lr
            );
        }
        Ok(())
    }

    /// Write the final summary JSON (flat string→string map + numbers).
    pub fn finish(mut self, fields: &[(&str, String)]) -> Result<PathBuf> {
        self.curve.flush()?;
        write_summary(&self.dir, fields)
    }
}

/// Write `<dir>/summary.json` as a flat map (numbers pass through bare
/// if they parse; strings escaped). Shared by [`RunWriter`] and
/// [`FleetWriter`].
pub fn write_summary(dir: &Path, fields: &[(&str, String)]) -> Result<PathBuf> {
    let mut out = String::from("{\n");
    for (i, (k, v)) in fields.iter().enumerate() {
        let comma = if i + 1 == fields.len() { "" } else { "," };
        if v.parse::<f64>().is_ok() || v == "true" || v == "false" || v == "null" {
            out.push_str(&format!("  {}: {v}{comma}\n", escape(k)));
        } else {
            out.push_str(&format!("  {}: {}{comma}\n", escape(k), escape(v)));
        }
    }
    out.push_str("}\n");
    let path = dir.join("summary.json");
    std::fs::write(&path, out)?;
    Ok(path)
}

/// Per-round record of a training-free fleet simulation
/// (`fedavg fleet --sim-only`, `examples/fleet_stress.rs`).
#[derive(Debug, Clone, Copy)]
pub struct FleetRoundRecord {
    pub round: u64,
    pub online: usize,
    pub dispatched: usize,
    pub completed: usize,
    pub dropped: usize,
    pub deadline_miss: bool,
    pub round_seconds: f64,
}

/// Writer for fleet-simulation runs: `runs/<name>/fleet.csv` + the same
/// summary JSON as [`RunWriter`].
pub struct FleetWriter {
    dir: PathBuf,
    csv: BufWriter<File>,
}

impl FleetWriter {
    /// Create `runs/<name>/` and a fresh fleet.csv, refusing to clobber
    /// an existing one (same collision rule as [`RunWriter::create`]).
    pub fn create(root: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = run_dir(root, name)?;
        refuse_existing(&dir, "fleet.csv")?;
        Self::open_fresh(dir)
    }

    /// Like [`create`](Self::create), but knowingly replaces any
    /// existing fleet.csv.
    pub fn create_overwrite(root: impl AsRef<Path>, name: &str) -> Result<Self> {
        Self::open_fresh(run_dir(root, name)?)
    }

    fn open_fresh(dir: PathBuf) -> Result<Self> {
        let mut csv = BufWriter::new(File::create(dir.join("fleet.csv"))?);
        writeln!(
            csv,
            "round,online,dispatched,completed,dropped,deadline_miss,round_seconds"
        )?;
        csv.flush()?;
        Ok(Self { dir, csv })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn record(&mut self, r: &FleetRoundRecord) -> Result<()> {
        writeln!(
            self.csv,
            "{},{},{},{},{},{},{:.3}",
            r.round, r.online, r.dispatched, r.completed, r.dropped, r.deadline_miss as u8,
            r.round_seconds
        )?;
        self.csv.flush()?; // same crash-durability rule as RunWriter
        Ok(())
    }

    pub fn finish(mut self, fields: &[(&str, String)]) -> Result<PathBuf> {
        self.csv.flush()?;
        write_summary(&self.dir, fields)
    }
}

/// One aggregation-tier row of a sharded run (`--shards S`, DESIGN.md
/// §11): tier 0 rows partition the client links across edge aggregators
/// (one row per shard), tier 1 rows carry the edge↔root cascade frames
/// (one row per round).
#[derive(Debug, Clone, Copy)]
pub struct TierRecord {
    pub round: u64,
    /// 0 = client↔edge, 1 = edge↔root (the frame-header tier tag).
    pub tier: u8,
    /// Shard index for tier 0 rows; 0 for the single tier-1 (root) row.
    pub shard: usize,
    /// Tier 0: aggregated clients in this shard. Tier 1: non-empty
    /// shards (= edge frames cascaded through the root).
    pub clients: usize,
    pub up_bytes: u64,
    pub down_bytes: u64,
    /// Tier 0: the round's straggler-bound client wall-clock (shared —
    /// the synchronous round waits for the slowest tier-0 client). Tier
    /// 1: the cascade's summed deterministic transfer time.
    pub seconds: f64,
}

/// Writer for `runs/<name>/tiers.csv`, opened inside an existing run dir
/// (the parent [`FleetWriter`]/[`RunWriter`] already settled collision
/// rules for the directory). Sharded sim runs only; tier bytes NEVER
/// land in fleet.csv/curve.csv, which stay byte-identical to a flat run.
pub struct TierWriter {
    csv: BufWriter<File>,
}

impl TierWriter {
    pub fn create_in(dir: &Path) -> Result<Self> {
        let mut csv = BufWriter::new(File::create(dir.join("tiers.csv"))?);
        writeln!(csv, "round,tier,shard,clients,up_bytes,down_bytes,seconds")?;
        csv.flush()?;
        Ok(Self { csv })
    }

    pub fn record(&mut self, r: &TierRecord) -> Result<()> {
        writeln!(
            self.csv,
            "{},{},{},{},{},{},{:.3}",
            r.round, r.tier, r.shard, r.clients, r.up_bytes, r.down_bytes, r.seconds
        )?;
        self.csv.flush()?; // same crash-durability rule as RunWriter
        Ok(())
    }
}

/// Null telemetry sink for benches/tests (writes to a temp-ish dir under
/// target/; overwrites — the same tag may be reused within a process).
pub fn scratch_writer(tag: &str) -> Result<RunWriter> {
    let pid = std::process::id();
    RunWriter::create_overwrite("target/test-runs", &format!("{tag}-{pid}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_curve_and_summary() {
        let mut w = scratch_writer("telemetry-test").unwrap();
        let dir = w.dir().to_path_buf();
        w.record(&RoundRecord {
            round: 1,
            test_accuracy: 0.5,
            test_loss: 1.2,
            train_loss: Some(1.1),
            clients: 10,
            lr: 0.1,
            up_bytes: 123,
            down_bytes: 999,
            codec: "dense/dense",
            sim_seconds: 4.5,
            dropped: 0,
            deadline_misses: 0,
            agg: "fedavg",
            server_state: "",
            staleness_mean: 0.0,
            buffer_fill: 0,
        })
        .unwrap();
        w.record(&RoundRecord {
            round: 2,
            test_accuracy: 0.6,
            test_loss: 1.0,
            train_loss: None,
            clients: 10,
            lr: 0.1,
            up_bytes: 456,
            down_bytes: 888,
            codec: "topk:0.01|q8/delta",
            sim_seconds: 9.0,
            dropped: 3,
            deadline_misses: 1,
            agg: "fedavgm:0.9",
            server_state: "momentum=1.000000e0",
            staleness_mean: 1.25,
            buffer_fill: 4,
        })
        .unwrap();
        let summary = w
            .finish(&[("rounds", "2".into()), ("model", "mnist_2nn".into())])
            .unwrap();
        let csv = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
        assert!(csv.starts_with("round,"));
        assert!(csv.lines().next().unwrap().contains("up_bytes,down_bytes,codec"));
        assert!(csv
            .lines()
            .next()
            .unwrap()
            .ends_with("dropped,deadline_misses,agg,server_state,staleness_mean,buffer_fill"));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("2,0.600000"));
        assert!(csv.contains("123,999,dense/dense"));
        assert!(csv.contains("456,888,topk:0.01|q8/delta"));
        assert!(csv.lines().nth(1).unwrap().ends_with(",0,0,fedavg,,0.000,0"));
        assert!(csv
            .lines()
            .nth(2)
            .unwrap()
            .ends_with(",3,1,fedavgm:0.9,momentum=1.000000e0,1.250,4"));
        let json = std::fs::read_to_string(summary).unwrap();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("rounds").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("model").unwrap().as_str().unwrap(), "mnist_2nn");
        std::fs::remove_dir_all(dir).ok();
    }

    fn record(round: u64) -> RoundRecord<'static> {
        RoundRecord {
            round,
            test_accuracy: 0.5,
            test_loss: 1.2,
            train_loss: None,
            clients: 10,
            lr: 0.1,
            up_bytes: 1,
            down_bytes: 2,
            codec: "dense/dense",
            sim_seconds: 1.0,
            dropped: 0,
            deadline_misses: 0,
            agg: "fedavg",
            server_state: "",
            staleness_mean: 0.0,
            buffer_fill: 0,
        }
    }

    #[test]
    fn rows_survive_drop_without_finish() {
        // regression: records used to sit in the BufWriter until
        // finish(), so a crashed/killed run lost its entire curve
        let mut w = scratch_writer("telemetry-drop-test").unwrap();
        let dir = w.dir().to_path_buf();
        w.record(&record(1)).unwrap();
        w.record(&record(2)).unwrap();
        drop(w); // no finish(): simulate a killed process
        let csv = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
        assert_eq!(csv.lines().count(), 3, "rows lost on drop:\n{csv}");
        assert!(csv.lines().nth(2).unwrap().starts_with("2,"));
        assert!(!dir.join("summary.json").exists());

        let pid = std::process::id();
        let name = format!("fleet-drop-test-{pid}");
        let mut fw = FleetWriter::create_overwrite("target/test-runs", &name).unwrap();
        let fdir = fw.dir().to_path_buf();
        fw.record(&FleetRoundRecord {
            round: 1,
            online: 5,
            dispatched: 2,
            completed: 2,
            dropped: 0,
            deadline_miss: false,
            round_seconds: 1.0,
        })
        .unwrap();
        drop(fw);
        let csv = std::fs::read_to_string(fdir.join("fleet.csv")).unwrap();
        assert_eq!(csv.lines().count(), 2, "fleet rows lost on drop:\n{csv}");
        std::fs::remove_dir_all(dir).ok();
        std::fs::remove_dir_all(fdir).ok();
    }

    #[test]
    fn colliding_run_names_refused_not_truncated() {
        let pid = std::process::id();
        let root = format!("target/test-runs/collide-{pid}");
        std::fs::remove_dir_all(&root).ok(); // leftovers from a failed run
        // "C=0.1" and "C 0.1" both sanitize to "C_0.1" — the second run
        // would silently truncate the first's curve
        let mut w = RunWriter::create(&root, "C=0.1").unwrap();
        w.record(&record(1)).unwrap();
        let dir = w.dir().to_path_buf();
        drop(w);
        let before = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
        let err = RunWriter::create(&root, "C 0.1").unwrap_err();
        assert!(format!("{err:#}").contains("--overwrite"), "{err:#}");
        // the first run's data is untouched by the refused create
        let after = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
        assert_eq!(before, after, "refused create still destroyed data");
        // explicit overwrite is allowed — and clears a stale checkpoints
        // dir, whose higher-round snapshots would otherwise win the
        // keep-last-K rotation against the new run's own snapshots
        std::fs::create_dir_all(dir.join("checkpoints")).unwrap();
        std::fs::write(dir.join("checkpoints/ckpt-0000000900.bin"), b"stale").unwrap();
        RunWriter::create_overwrite(&root, "C 0.1").unwrap();
        assert!(
            !dir.join("checkpoints").exists(),
            "--overwrite left stale checkpoints behind"
        );
        // fleet writer: same rule
        let mut fw = FleetWriter::create(&root, "sim").unwrap();
        fw.record(&FleetRoundRecord {
            round: 1,
            online: 1,
            dispatched: 1,
            completed: 1,
            dropped: 0,
            deadline_miss: false,
            round_seconds: 0.1,
        })
        .unwrap();
        drop(fw);
        assert!(FleetWriter::create(&root, "sim").is_err());
        std::fs::remove_dir_all(root).ok();
    }

    #[test]
    fn reopen_truncates_to_round_and_appends() {
        let pid = std::process::id();
        let root = format!("target/test-runs/reopen-{pid}");
        std::fs::remove_dir_all(&root).ok(); // leftovers from a failed run
        let mut w = RunWriter::create(&root, "r").unwrap();
        let dir = w.dir().to_path_buf();
        for round in 1..=5 {
            w.record(&record(round)).unwrap();
        }
        drop(w);
        // resume from round 3: rows 4 and 5 belong to a lost future
        let mut w = RunWriter::reopen(&dir, 3).unwrap();
        let truncated = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
        assert_eq!(truncated.lines().count(), 4, "{truncated}");
        assert!(truncated.lines().last().unwrap().starts_with("3,"));
        w.record(&record(4)).unwrap();
        w.finish(&[("rounds", "4".into())]).unwrap();
        let full = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
        assert_eq!(full.lines().count(), 5);
        assert_eq!(full.lines().next().unwrap(), CURVE_HEADER);
        assert!(full.lines().last().unwrap().starts_with("4,"));

        // a SIGKILL mid-write leaves a torn final row; reopen must drop
        // it as lost future — even when its fragment parses as a small
        // round ("1" torn from "12,...") — not keep it or hard-error
        for torn in ["1", "12,0.51", ",0.5,junk"] {
            let mut contents = full.clone();
            contents.push_str(torn); // no trailing newline: mid-write kill
            std::fs::write(dir.join("curve.csv"), &contents).unwrap();
            let w = RunWriter::reopen(&dir, 4).unwrap();
            drop(w);
            let after = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
            assert_eq!(after, full, "torn row {torn:?} survived reopen");
        }

        // reopening a directory with no curve is an error, not a create
        assert!(RunWriter::reopen(dir.join("nope"), 1).is_err());
        std::fs::remove_dir_all(root).ok();
    }

    #[test]
    fn tier_writer_rows() {
        let pid = std::process::id();
        let dir = std::path::PathBuf::from(format!("target/test-runs/tiers-{pid}"));
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = TierWriter::create_in(&dir).unwrap();
        w.record(&TierRecord {
            round: 1,
            tier: 0,
            shard: 2,
            clients: 25,
            up_bytes: 100,
            down_bytes: 130,
            seconds: 41.5,
        })
        .unwrap();
        w.record(&TierRecord {
            round: 1,
            tier: 1,
            shard: 0,
            clients: 4,
            up_bytes: 96,
            down_bytes: 72,
            seconds: 0.25,
        })
        .unwrap();
        drop(w); // rows must survive without an explicit finish
        let csv = std::fs::read_to_string(dir.join("tiers.csv")).unwrap();
        assert!(csv.starts_with("round,tier,shard,clients,up_bytes,down_bytes,seconds"));
        assert!(csv.contains("1,0,2,25,100,130,41.500"));
        assert!(csv.contains("1,1,0,4,96,72,0.250"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fleet_writer_csv_and_summary() {
        let pid = std::process::id();
        let mut w =
            FleetWriter::create_overwrite("target/test-runs", &format!("fleet-test-{pid}"))
                .unwrap();
        let dir = w.dir().to_path_buf();
        w.record(&FleetRoundRecord {
            round: 1,
            online: 900,
            dispatched: 130,
            completed: 100,
            dropped: 30,
            deadline_miss: false,
            round_seconds: 41.5,
        })
        .unwrap();
        let summary = w.finish(&[("rounds", "1".into())]).unwrap();
        let csv = std::fs::read_to_string(dir.join("fleet.csv")).unwrap();
        assert!(csv.starts_with("round,online,dispatched,"));
        assert!(csv.contains("1,900,130,100,30,0,41.500"));
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(summary).unwrap()).unwrap();
        assert_eq!(parsed.get("rounds").unwrap().as_usize().unwrap(), 1);
        std::fs::remove_dir_all(dir).ok();
    }
}
