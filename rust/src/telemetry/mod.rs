//! Run telemetry: per-round CSV curves + JSON run summaries under
//! `runs/<name>/`, plus a console progress logger. Everything the
//! experiment harnesses print is also persisted so figures can be
//! re-plotted without re-running.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Context;

use crate::util::json::escape;
use crate::Result;

/// Writer for one training run's outputs.
pub struct RunWriter {
    dir: PathBuf,
    curve: BufWriter<File>,
    started: Instant,
    quiet: bool,
}

/// One evaluated round's record.
#[derive(Debug, Clone, Copy)]
pub struct RoundRecord {
    pub round: u64,
    pub test_accuracy: f64,
    pub test_loss: f64,
    pub train_loss: Option<f64>,
    pub clients: usize,
    pub lr: f64,
    pub bytes_up: u64,
    pub sim_seconds: f64,
}

impl RunWriter {
    /// Create `runs/<name>/` (name sanitized) and open curve.csv.
    pub fn create(root: impl AsRef<Path>, name: &str) -> Result<Self> {
        let safe: String = name
            .chars()
            .map(|c| if c.is_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' })
            .collect();
        let dir = root.as_ref().join(safe);
        std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
        let curve = BufWriter::new(File::create(dir.join("curve.csv"))?);
        let mut w = Self {
            dir,
            curve,
            started: Instant::now(),
            quiet: std::env::var("FEDAVG_QUIET").is_ok(),
        };
        writeln!(
            w.curve,
            "round,test_accuracy,test_loss,train_loss,clients,lr,bytes_up,sim_seconds"
        )?;
        Ok(w)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn record(&mut self, r: &RoundRecord) -> Result<()> {
        writeln!(
            self.curve,
            "{},{:.6},{:.6},{},{},{:.6},{},{:.3}",
            r.round,
            r.test_accuracy,
            r.test_loss,
            r.train_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
            r.clients,
            r.lr,
            r.bytes_up,
            r.sim_seconds
        )?;
        if !self.quiet {
            let tl = r
                .train_loss
                .map(|v| format!(" train_loss={v:.4}"))
                .unwrap_or_default();
            println!(
                "[{:>7.1}s] round {:>5}  acc={:.4} loss={:.4}{tl}  (m={}, lr={:.4})",
                self.started.elapsed().as_secs_f64(),
                r.round,
                r.test_accuracy,
                r.test_loss,
                r.clients,
                r.lr
            );
        }
        Ok(())
    }

    /// Write the final summary JSON (flat string→string map + numbers).
    pub fn finish(mut self, fields: &[(&str, String)]) -> Result<PathBuf> {
        self.curve.flush()?;
        let mut out = String::from("{\n");
        for (i, (k, v)) in fields.iter().enumerate() {
            let comma = if i + 1 == fields.len() { "" } else { "," };
            // numbers pass through bare if they parse; strings escaped
            if v.parse::<f64>().is_ok() || v == "true" || v == "false" || v == "null" {
                out.push_str(&format!("  {}: {v}{comma}\n", escape(k)));
            } else {
                out.push_str(&format!("  {}: {}{comma}\n", escape(k), escape(v)));
            }
        }
        out.push_str("}\n");
        let path = self.dir.join("summary.json");
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

/// Null telemetry sink for benches/tests (writes to a temp-ish dir under
/// target/).
pub fn scratch_writer(tag: &str) -> Result<RunWriter> {
    let pid = std::process::id();
    RunWriter::create("target/test-runs", &format!("{tag}-{pid}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_curve_and_summary() {
        let mut w = scratch_writer("telemetry-test").unwrap();
        let dir = w.dir().to_path_buf();
        w.record(&RoundRecord {
            round: 1,
            test_accuracy: 0.5,
            test_loss: 1.2,
            train_loss: Some(1.1),
            clients: 10,
            lr: 0.1,
            bytes_up: 123,
            sim_seconds: 4.5,
        })
        .unwrap();
        w.record(&RoundRecord {
            round: 2,
            test_accuracy: 0.6,
            test_loss: 1.0,
            train_loss: None,
            clients: 10,
            lr: 0.1,
            bytes_up: 456,
            sim_seconds: 9.0,
        })
        .unwrap();
        let summary = w
            .finish(&[("rounds", "2".into()), ("model", "mnist_2nn".into())])
            .unwrap();
        let csv = std::fs::read_to_string(dir.join("curve.csv")).unwrap();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("2,0.600000"));
        let json = std::fs::read_to_string(summary).unwrap();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("rounds").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("model").unwrap().as_str().unwrap(), "mnist_2nn");
        std::fs::remove_dir_all(dir).ok();
    }
}
