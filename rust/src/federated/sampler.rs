//! Per-round client sampling — `S_t ← (random set of m clients)`.
//!
//! Uniform without replacement over the (optionally availability-filtered)
//! client population, with a deterministic per-round stream so runs are
//! reproducible and rounds are independent of evaluation cadence. Both the
//! selection stream (`root.child(round)`) and the availability coin
//! (`hash3(seed, round, client)`) are pure functions of the round, so this
//! independence holds end to end. The fleet coordinator selects from an
//! explicit online pool via [`ClientSampler::sample_from`].

use crate::comms::Availability;
use crate::data::rng::{Rng, RngState};

pub struct ClientSampler {
    root: Rng,
    availability: Option<Availability>,
}

impl ClientSampler {
    pub fn new(seed: u64) -> Self {
        Self {
            root: Rng::new(seed ^ 0x5A3B1E),
            availability: None,
        }
    }

    /// Enable the availability trace (clients online w.p. `p` per round).
    pub fn with_availability(mut self, p_online: f64, seed: u64) -> Self {
        self.availability = Some(Availability::new(p_online, seed));
        self
    }

    /// Snapshot the selection stream's RNG state (`crate::runstate`,
    /// DESIGN.md §8). The availability coin is a stateless hash and is
    /// reconstructed from config on resume, so it is not part of this.
    ///
    /// Today the root stream never advances (each round derives a child),
    /// making this reconstructible from the seed — but the snapshot
    /// captures it anyway so a future sampler that *does* consume root
    /// draws cannot silently break the resume bit-identity guarantee.
    pub fn state(&self) -> RngState {
        self.root.state()
    }

    /// Restore the selection stream captured by [`state`](Self::state).
    pub fn restore_state(&mut self, st: RngState) {
        self.root = Rng::from_state(st);
    }

    /// Sample `m` distinct clients out of `k` for `round`.
    /// If fewer than `m` clients are online, all online clients are used
    /// (the synchronous protocol proceeds with who showed up).
    pub fn sample(&mut self, round: u64, k: usize, m: usize) -> Vec<usize> {
        let mut rng = self.root.child(round.wrapping_add(1));
        match &self.availability {
            None => rng.sample_indices(k, m.min(k)),
            Some(av) => {
                let online = av.online(round, k);
                let take = m.min(online.len());
                let picks = rng.sample_indices(online.len(), take);
                picks.into_iter().map(|i| online[i]).collect()
            }
        }
    }

    /// Sample up to `m` distinct clients from an explicit candidate pool
    /// (the fleet coordinator's online set for `round`). Uses the same
    /// per-round stream as [`sample`](Self::sample).
    pub fn sample_from(&mut self, round: u64, pool: &[usize], m: usize) -> Vec<usize> {
        let mut rng = self.root.child(round.wrapping_add(1));
        let take = m.min(pool.len());
        rng.sample_indices(pool.len(), take)
            .into_iter()
            .map(|i| pool[i])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_and_in_range() {
        let mut s = ClientSampler::new(1);
        for round in 0..20 {
            let picks = s.sample(round, 100, 10);
            assert_eq!(picks.len(), 10);
            let mut p = picks.clone();
            p.sort_unstable();
            p.dedup();
            assert_eq!(p.len(), 10);
            assert!(p.iter().all(|&c| c < 100));
        }
    }

    #[test]
    fn deterministic_per_round_independent_of_history() {
        let mut a = ClientSampler::new(7);
        let mut b = ClientSampler::new(7);
        // advance `a` through extra rounds first — round 5 must not change
        for r in 0..5 {
            a.sample(r, 50, 5);
        }
        assert_eq!(a.sample(5, 50, 5), b.sample(5, 50, 5));
    }

    #[test]
    fn covers_population_over_time() {
        let mut s = ClientSampler::new(3);
        let mut seen = vec![false; 20];
        for round in 0..200 {
            for c in s.sample(round, 20, 2) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&v| v), "some client never sampled");
    }

    #[test]
    fn availability_limits_sample() {
        let mut s = ClientSampler::new(5).with_availability(0.2, 9);
        for round in 0..10 {
            let picks = s.sample(round, 30, 30);
            // with p=0.2 it's (astronomically) unlikely all 30 are online
            assert!(picks.len() < 30);
            assert!(!picks.is_empty());
        }
    }

    #[test]
    fn availability_rounds_independent_of_history() {
        // regression: the old Bernoulli coin advanced a sequential RNG per
        // call, so skipping rounds changed later rounds' online sets
        let mut a = ClientSampler::new(7).with_availability(0.4, 3);
        let mut b = ClientSampler::new(7).with_availability(0.4, 3);
        for r in 0..5 {
            a.sample(r, 50, 5); // advance `a` through extra history
        }
        assert_eq!(a.sample(9, 50, 5), b.sample(9, 50, 5));
    }

    #[test]
    fn sample_from_pool_distinct_and_deterministic() {
        let pool: Vec<usize> = (0..40).map(|i| i * 3).collect();
        let mut a = ClientSampler::new(11);
        let mut b = ClientSampler::new(11);
        let x = a.sample_from(4, &pool, 12);
        assert_eq!(x, b.sample_from(4, &pool, 12));
        assert_eq!(x.len(), 12);
        let mut d = x.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 12, "duplicates in pool sample");
        assert!(x.iter().all(|c| pool.contains(c)));
        // asking for more than the pool returns the whole pool
        assert_eq!(a.sample_from(5, &pool[..3], 10).len(), 3);
    }
}
