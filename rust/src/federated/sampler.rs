//! Per-round client sampling — `S_t ← (random set of m clients)`.
//!
//! Uniform without replacement over the (optionally availability-filtered)
//! client population, with a deterministic per-round stream so runs are
//! reproducible and rounds are independent of evaluation cadence.

use crate::comms::Availability;
use crate::data::rng::Rng;

pub struct ClientSampler {
    root: Rng,
    availability: Option<Availability>,
}

impl ClientSampler {
    pub fn new(seed: u64) -> Self {
        Self {
            root: Rng::new(seed ^ 0x5A3B1E),
            availability: None,
        }
    }

    /// Enable the availability trace (clients online w.p. `p` per round).
    pub fn with_availability(mut self, p_online: f64, seed: u64) -> Self {
        self.availability = Some(Availability::new(p_online, seed));
        self
    }

    /// Sample `m` distinct clients out of `k` for `round`.
    /// If fewer than `m` clients are online, all online clients are used
    /// (the synchronous protocol proceeds with who showed up).
    pub fn sample(&mut self, round: u64, k: usize, m: usize) -> Vec<usize> {
        let mut rng = self.root.child(round.wrapping_add(1));
        match &mut self.availability {
            None => rng.sample_indices(k, m.min(k)),
            Some(av) => {
                let online = av.online(k);
                let take = m.min(online.len());
                let picks = rng.sample_indices(online.len(), take);
                picks.into_iter().map(|i| online[i]).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_and_in_range() {
        let mut s = ClientSampler::new(1);
        for round in 0..20 {
            let picks = s.sample(round, 100, 10);
            assert_eq!(picks.len(), 10);
            let mut p = picks.clone();
            p.sort_unstable();
            p.dedup();
            assert_eq!(p.len(), 10);
            assert!(p.iter().all(|&c| c < 100));
        }
    }

    #[test]
    fn deterministic_per_round_independent_of_history() {
        let mut a = ClientSampler::new(7);
        let mut b = ClientSampler::new(7);
        // advance `a` through extra rounds first — round 5 must not change
        for r in 0..5 {
            a.sample(r, 50, 5);
        }
        assert_eq!(a.sample(5, 50, 5), b.sample(5, 50, 5));
    }

    #[test]
    fn covers_population_over_time() {
        let mut s = ClientSampler::new(3);
        let mut seen = vec![false; 20];
        for round in 0..200 {
            for c in s.sample(round, 20, 2) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&v| v), "some client never sampled");
    }

    #[test]
    fn availability_limits_sample() {
        let mut s = ClientSampler::new(5).with_availability(0.2, 9);
        for round in 0..10 {
            let picks = s.sample(round, 30, 30);
            // with p=0.2 it's (astronomically) unlikely all 30 are online
            assert!(picks.len() < 30);
            assert!(!picks.is_empty());
        }
    }
}
