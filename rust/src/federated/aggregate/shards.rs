//! Hierarchical (two-tier) aggregation: the `--shards S` combine path
//! (DESIGN.md §11).
//!
//! The cohort's dispatch slots are split across S edge aggregators by
//! contiguous ranges ([`shard_ranges`]). The root then runs a
//! **cascade**: it walks the non-empty shards in index order, handing
//! each the current running accumulator as a tier-1 dense wire frame;
//! the edge folds its slot range onto the accumulator with
//! [`params::weighted_fold`] — per-item scales taken from the *global*
//! f64 weight total, the shard-weight bookkeeping — and returns the
//! updated accumulator as a tier-1 frame. The final up frame decodes to
//! the combined delta.
//!
//! Why a cascade and not independent per-shard partial means? f32
//! addition is not associative: independently-reduced partials can never
//! be bit-identical to [`params::weighted_mean`]'s strictly sequential
//! fold. The cascade *relocates* the flat fold across shard boundaries
//! without reordering a single operation — and dense f32 frames
//! round-trip bit-exactly — so sharded combine equals flat combine
//! bit-for-bit, for any S (property-tested in `rust/tests/shards.rs`).
//!
//! Tier-1 frames are always **dense** (lossless): a lossy codec between
//! tiers would break the identity. Client-tier codecs (`topk`/`q<b>`)
//! are unaffected — they run before aggregation on either path.
//!
//! Robust rules (`trimmed:<β>`, `median`) are refused: coordinate-wise
//! order statistics do not compose across tiers (the median of shard
//! medians is not the cohort median), so only
//! [`Aggregator::mean_combine`] rules may shard.

use crate::comms::wire::{write_dense_frame_into, Frame};
use crate::coordinator::shards::{shard_ranges, tier_transfer_seconds, TierLink};
use crate::params::{self, ParamVec};
use crate::Result;

use super::Aggregator;

/// Tier tag stamped into edge↔root frame headers (byte 7).
pub const EDGE_TIER: u8 = 1;

/// The combined delta plus the edge tier's transfer accounting.
#[derive(Debug, Clone)]
pub struct ShardCombine {
    /// The aggregate delta — bit-identical to `agg.combine(deltas)`.
    pub delta: ParamVec,
    /// Shards that received at least one slot (≤ S; `S > m` leaves
    /// trailing shards empty, with no frames and no fold).
    pub shards_used: usize,
    /// Edge→root bytes (one dense frame per non-empty shard).
    pub up_bytes: u64,
    /// Root→edge bytes (`shards_used - 1` frames: the first shard starts
    /// from the zero accumulator and receives nothing).
    pub down_bytes: u64,
    /// Total tier-1 frames shipped.
    pub frames: u64,
    /// Deterministic tier-1 transfer time ([`tier_transfer_seconds`];
    /// the cascade serializes the exchanges, so times sum).
    pub seconds: f64,
}

/// Run `agg`'s combine hierarchically over `shards` edge aggregators.
/// `deltas` are the round's weighted client deltas in dispatch-slot
/// order — the same slice the flat path hands to
/// [`Aggregator::combine`]. Errors if the rule is not mean-family, the
/// cohort is empty, or `shards == 0` (callers gate on `shards > 0`).
pub fn combine_sharded(
    agg: &dyn Aggregator,
    deltas: &[(f32, &[f32])],
    shards: usize,
    link: &TierLink,
) -> Result<ShardCombine> {
    anyhow::ensure!(shards >= 1, "combine_sharded: --shards must be >= 1");
    anyhow::ensure!(
        agg.mean_combine(),
        "--agg {} cannot run under --shards: coordinate-wise order statistics \
         do not compose across aggregation tiers — only mean-family rules \
         (fedavg/fedavgm/fedadam) shard (DESIGN.md §11)",
        agg.label()
    );
    anyhow::ensure!(!deltas.is_empty(), "combine_sharded: empty cohort");
    let dim = deltas[0].1.len();
    let total = params::weight_total(deltas);
    anyhow::ensure!(total > 0.0, "combine_sharded: non-positive total weight");

    let mut acc = vec![0.0f32; dim];
    // One reusable tier-1 frame for every exchange in the cascade: each
    // hop re-frames the accumulator in place via `write_dense_frame_into`
    // (byte-identical to `Repr::dense(..).to_frame_tagged`) and decodes
    // it back into the same accumulator spine, so the whole cascade
    // touches O(1) buffers instead of allocating per hop (DESIGN.md §14).
    // The frames are still fully materialized — the byte/second
    // accounting prices real wire images, not estimates.
    // lint:allow(hot-alloc): one frame allocation per cascade, reused across all 2S-1 exchanges.
    let mut frame = Frame { bytes: Vec::new() };
    let mut out = ShardCombine {
        delta: ParamVec::new(),
        shards_used: 0,
        up_bytes: 0,
        down_bytes: 0,
        frames: 0,
        seconds: 0.0,
    };
    for range in shard_ranges(deltas.len(), shards) {
        if range.is_empty() {
            continue;
        }
        if out.shards_used > 0 {
            // root → edge: ship the running accumulator through a real
            // tier-1 frame (dense f32 round-trips bit-exactly)
            write_dense_frame_into(&acc, EDGE_TIER, &mut frame);
            out.down_bytes += frame.wire_bytes();
            out.frames += 1;
            out.seconds += tier_transfer_seconds(link, frame.wire_bytes());
            frame.decode_into(None, &mut acc)?;
        }
        params::weighted_fold(&mut acc, &deltas[range], total);
        // edge → root: the updated accumulator comes back the same way
        write_dense_frame_into(&acc, EDGE_TIER, &mut frame);
        out.up_bytes += frame.wire_bytes();
        out.frames += 1;
        out.seconds += tier_transfer_seconds(link, frame.wire_bytes());
        frame.decode_into(None, &mut acc)?;
        out.shards_used += 1;
    }
    out.delta = acc;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::wire::HEADER_BYTES;
    use crate::federated::aggregate::AggConfig;

    fn cohort(m: usize, dim: usize) -> Vec<(f32, Vec<f32>)> {
        (0..m)
            .map(|c| {
                let v: Vec<f32> = (0..dim)
                    .map(|i| ((c * 131 + i * 17) % 251) as f32 * 0.004 - 0.5)
                    .collect();
                ((c % 5 + 1) as f32 * 60.0, v)
            })
            .collect()
    }

    fn refs(cohort: &[(f32, Vec<f32>)]) -> Vec<(f32, &[f32])> {
        cohort.iter().map(|(w, d)| (*w, d.as_slice())).collect()
    }

    #[test]
    fn sharded_combine_is_bit_identical_to_flat_for_any_s() {
        let link = TierLink::default();
        for spec in ["fedavg", "fedavgm:0.9", "fedadam"] {
            let agg = AggConfig { spec: spec.into(), ..Default::default() }.build().unwrap();
            for (m, dim) in [(1usize, 33usize), (4, 301), (10, 128), (23, 77)] {
                let c = cohort(m, dim);
                let r = refs(&c);
                let flat = agg.combine(&r).unwrap();
                for s in [1usize, 2, 3, 7, 16, 64] {
                    let sharded = combine_sharded(agg.as_ref(), &r, s, &link).unwrap();
                    let same = flat
                        .iter()
                        .zip(&sharded.delta)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "{spec} m={m} dim={dim} S={s}: sharded != flat");
                    assert_eq!(sharded.shards_used, s.min(m));
                }
            }
        }
    }

    #[test]
    fn tier_accounting_counts_dense_frames() {
        let agg = AggConfig::default().build().unwrap();
        let link = TierLink { bps: 1e6, latency_s: 0.5 };
        let (m, dim, s) = (10usize, 64usize, 4usize);
        let c = cohort(m, dim);
        let out = combine_sharded(agg.as_ref(), &refs(&c), s, &link).unwrap();
        let frame_bytes = HEADER_BYTES + 4 * dim as u64;
        assert_eq!(out.shards_used, 4);
        assert_eq!(out.up_bytes, 4 * frame_bytes);
        assert_eq!(out.down_bytes, 3 * frame_bytes);
        assert_eq!(out.frames, 7);
        let per = tier_transfer_seconds(&link, frame_bytes);
        assert!((out.seconds - 7.0 * per).abs() < 1e-12);
        // S > m: empty shards ship nothing
        let out = combine_sharded(agg.as_ref(), &refs(&c[..2]), 7, &link).unwrap();
        assert_eq!(out.shards_used, 2);
        assert_eq!(out.frames, 3, "2 up + 1 down");
    }

    #[test]
    fn robust_rules_and_degenerate_inputs_are_refused() {
        let link = TierLink::default();
        let c = cohort(4, 16);
        for spec in ["trimmed:0.1", "median"] {
            let agg = AggConfig { spec: spec.into(), ..Default::default() }.build().unwrap();
            let err = combine_sharded(agg.as_ref(), &refs(&c), 2, &link).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("order statistics"), "{spec}: {msg}");
            assert!(msg.contains("DESIGN.md"), "{spec}: {msg}");
        }
        let agg = AggConfig::default().build().unwrap();
        assert!(combine_sharded(agg.as_ref(), &refs(&c), 0, &link).is_err());
        assert!(combine_sharded(agg.as_ref(), &[], 2, &link).is_err());
    }
}
