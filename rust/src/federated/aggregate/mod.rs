//! Pluggable server-side aggregation — the update rule of Algorithm 1,
//! generalized (DESIGN.md §7).
//!
//! The paper hardcodes one rule: `w_{t+1} ← Σ_k (n_k/n)·w_{t+1}^k`,
//! equivalently `w_{t+1} ← w_t + Δ̄_t` with the weighted mean delta
//! `Δ̄_t = Σ_k (n_k/n)·(w_{t+1}^k − w_t)`. This module factors that rule
//! behind the [`Aggregator`] trait and a registry (parallel to the codec
//! registry in [`crate::comms::wire`]) so the server loop can swap in:
//!
//! | rule (`--agg`)    | update |
//! |-------------------|--------|
//! | `fedavg`          | `w_{t+1} = w_t + η_s·Δ̄_t` (the paper's rule at `η_s = 1`) |
//! | `fedavgm[:β]`     | `v_t = β·v_{t-1} + Δ̄_t`; `w_{t+1} = w_t + η_s·v_t` (server momentum, Hsu et al.) |
//! | `fedadam[:τ]`     | `m_t = β₁·m_{t-1} + (1−β₁)·Δ̄_t`; `u_t = β₂·u_{t-1} + (1−β₂)·Δ̄_t²`; `w_{t+1} = w_t + η_s·m_t/(√u_t + τ)` (Reddi et al.) |
//! | `trimmed:<β>`     | coordinate-wise β-trimmed mean of the `Δ_t^k` (unweighted) |
//! | `median`          | coordinate-wise median of the `Δ_t^k` (unweighted) |
//!
//! Every rule decomposes as **combine ∘ step**: [`Aggregator::combine`]
//! reduces the cohort's weighted deltas `(n_k, Δ_t^k)` to one aggregate
//! delta (weighted mean for the server optimizers, an order statistic
//! for the robust rules), and [`Aggregator::step`] turns that delta into
//! the increment actually added to `w_t` (identity by default; the
//! stateful server optimizers treat the aggregate delta as a
//! pseudo-gradient here). The split is what lets DP noise land between
//! the two stages and secure aggregation replace the combine
//! (see DESIGN.md §7 for the interaction rules).
//!
//! The default [`AggConfig`] builds `fedavg` with `η_s = 1`, which
//! reproduces the seed's inlined `weighted_mean` + `axpy` trajectory
//! **bit-for-bit** (regression-tested in `rust/tests/aggregate.rs`).
//!
//! The client-side half of this subsystem is the FedProx proximal term
//! ([`AggConfig::prox_mu`], Li et al.), applied inside
//! [`crate::federated::client::local_update`].

use crate::config::ConfigFile;
use crate::params::{self, ParamVec};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::Result;

pub mod shards;

pub use shards::{combine_sharded, ShardCombine, EDGE_TIER};

// ---------------------------------------------------------------- trait

/// One server-side aggregation rule: how a round's client updates become
/// the increment applied to the global model.
///
/// Implementations receive the cohort as weighted **deltas**
/// `(n_k, Δ_t^k = w_{t+1}^k − w_t)` — the natural unit after clipping,
/// codecs, and secure aggregation — and return the vector the server
/// adds to `w_t`. Custom rules only need [`label`](Self::label) and
/// [`combine`](Self::combine):
///
/// ```
/// use fedavg::federated::aggregate::Aggregator;
/// use fedavg::params::{weighted_mean, ParamVec};
///
/// /// A toy robust rule: the weighted mean, clamped to ±1 per coordinate.
/// struct ClampedMean;
///
/// impl Aggregator for ClampedMean {
///     fn label(&self) -> String {
///         "clamped".into()
///     }
///     fn combine(&self, deltas: &[(f32, &[f32])]) -> fedavg::Result<ParamVec> {
///         let mut d = weighted_mean(deltas);
///         for v in &mut d {
///             *v = v.clamp(-1.0, 1.0);
///         }
///         Ok(d)
///     }
/// }
///
/// let mut agg = ClampedMean;
/// let (a, b) = ([2.0f32, -0.5], [4.0f32, 0.5]);
/// let combined = agg.combine(&[(1.0, &a[..]), (1.0, &b[..])]).unwrap();
/// assert_eq!(combined, vec![1.0, 0.0]); // mean [3.0, 0.0], clamped
/// // the default server step is the identity:
/// assert_eq!(agg.step(1, combined).unwrap(), vec![1.0, 0.0]);
/// ```
pub trait Aggregator {
    /// Canonical rule id, resolved arguments included (`"fedavgm:0.9"`).
    /// This is what telemetry records in curve.csv's `agg` column.
    fn label(&self) -> String;

    /// Stage 1 — reduce the cohort's weighted deltas to one aggregate
    /// delta `Δ̄_t`. Must not depend on internal state (it may run on a
    /// secure-aggregation mean instead; see
    /// [`mean_combine`](Self::mean_combine)).
    fn combine(&self, deltas: &[(f32, &[f32])]) -> Result<ParamVec>;

    /// [`combine`](Self::combine) into a caller-owned buffer — the round
    /// loop's scratch path (DESIGN.md §14): the server clears and refills
    /// one aggregate buffer per round instead of allocating. Must fill
    /// `out` with **bit-identical** contents to what `combine` returns.
    /// The default routes through `combine`, so custom rules that only
    /// implement the two required methods keep working unchanged; the
    /// built-in rules override it with allocation-free kernels.
    fn combine_into(&self, deltas: &[(f32, &[f32])], out: &mut ParamVec) -> Result<()> {
        *out = self.combine(deltas)?;
        Ok(())
    }

    /// Worker threads the rule may use inside its combine kernels (the
    /// order-statistic rules split coordinate blocks across threads; see
    /// `params::trimmed_mean_into`). Purely an execution knob: results
    /// must stay bit-identical at any worker count, and it is
    /// deliberately **not** part of [`AggConfig`] — worker counts are
    /// excluded from the run fingerprint, so a resumed run may use a
    /// different machine's parallelism. Default: ignored (rules whose
    /// kernels are inherently sequential).
    fn set_workers(&mut self, workers: usize) {
        let _ = workers;
    }

    /// Stage 2 — turn the (possibly DP-noised) aggregate delta into the
    /// increment added to `w_t`. Stateful server optimizers update their
    /// moments here, keyed by `round` only for labeling/debugging — the
    /// rules themselves are cadence-free. Default: identity.
    fn step(&mut self, round: u64, delta: ParamVec) -> Result<ParamVec> {
        let _ = round;
        Ok(delta)
    }

    /// True iff [`combine`](Self::combine) is exactly the weighted mean
    /// `Σ n_k·Δ_t^k / Σ n_k`. Only such rules compose with secure
    /// aggregation (which hands the server the masked mean and nothing
    /// else) or with DP noise (whose σ is calibrated to the mean's
    /// `clip/m` sensitivity). Default `false` (conservative for custom
    /// rules).
    fn mean_combine(&self) -> bool {
        false
    }

    /// `(name, ‖state‖₂)` of each internal optimizer moment, for
    /// telemetry (empty when stateless, and before the first step).
    fn state_norms(&self) -> Vec<(&'static str, f64)> {
        Vec::new()
    }

    /// Serialize the rule's internal optimizer state for a run-state
    /// snapshot (`crate::runstate`, DESIGN.md §8) — an opaque blob whose
    /// layout only [`state_load`](Self::state_load) needs to understand.
    /// Configuration knobs (η_s, β, τ) are *not* state: they come back
    /// from the `--agg` spec on resume, and the snapshot's rule label is
    /// checked against it. Default: no state (stateless rules).
    fn state_save(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore the state written by [`state_save`](Self::state_save),
    /// erroring on any mismatch (a stateless rule must receive an empty
    /// blob; a stateful one must find its exact moment layout). A
    /// successful load makes the rule's future steps bit-identical to
    /// the run that wrote the snapshot.
    fn state_load(&mut self, bytes: &[u8]) -> Result<()> {
        anyhow::ensure!(
            bytes.is_empty(),
            "aggregator {} is stateless but the snapshot carries {} state bytes",
            self.label(),
            bytes.len()
        );
        Ok(())
    }
}

/// Render [`Aggregator::state_norms`] for the telemetry CSV:
/// `;`-joined `name=norm` pairs (comma-free), empty for stateless rules.
pub fn fmt_state_norms(norms: &[(&'static str, f64)]) -> String {
    norms
        .iter()
        .map(|(n, v)| format!("{n}={v:.6e}"))
        .collect::<Vec<_>>()
        .join(";")
}

// ----------------------------------------------- staleness (async rounds)

/// Staleness-discounted aggregation weight for one buffered client delta
/// (DESIGN.md §12): an update dispatched `staleness` server applies ago
/// enters the combine with weight `n_k · decay^staleness`.
///
/// `staleness == 0` or `decay == 1` return `weight` **unchanged** — the
/// bit-identity guard (same idiom as `lr_step`'s η_s = 1 short-circuit)
/// that makes `--staleness-decay 1.0` with a cohort-sized buffer
/// reproduce the synchronous path byte-for-byte. The power is computed
/// by exact binary exponentiation, not `powf`, so the discount is a pure
/// function of `(weight, decay, staleness)` on every platform.
pub fn staleness_weight(weight: f32, decay: f64, staleness: u64) -> f32 {
    if staleness == 0 || decay == 1.0 {
        return weight;
    }
    let mut pow = 1.0f64;
    let mut base = decay;
    let mut e = staleness;
    while e > 0 {
        if e & 1 == 1 {
            pow *= base;
        }
        base *= base;
        e >>= 1;
    }
    (weight as f64 * pow) as f32
}

/// Overall attenuation of a buffered apply, landed between
/// [`Aggregator::combine`] and [`Aggregator::step`] — the same seam DP
/// noise uses: `Σ n_k·decay^s_k / Σ n_k` over the `(weight, staleness)`
/// pairs of the applied buffer. The combine itself normalizes by the
/// *discounted* mass (the existing weighted-mean normalization), so this
/// scale is what makes an all-stale buffer move θ less than a fresh one.
/// Returns exactly `1.0` when every delta is fresh or `decay == 1`
/// (bit-identity guard), and `0.0` when the discounted mass underflows —
/// the caller must then skip the combine (a zero-mass mean is 0/0) and
/// apply a zero delta, keeping θ finite for any decay in (0, 1].
pub fn staleness_scale(entries: &[(f32, u64)], decay: f64) -> f64 {
    if decay == 1.0 || entries.iter().all(|&(_, s)| s == 0) {
        return 1.0;
    }
    // lint:allow(float-fold): the buffer is drained in canonical arrival order fixed by the semi-sync barrier, so this fold sequence is deterministic.
    let raw: f64 = entries.iter().map(|&(w, _)| w as f64).sum();
    if !(raw > 0.0) {
        return 1.0; // degenerate zero-mass buffer: nothing to attenuate
    }
    let disc: f64 = entries
        .iter()
        .map(|&(w, s)| staleness_weight(w, decay, s) as f64)
        .sum(); // lint:allow(float-fold): same canonical buffer order as `raw` above.
    if !(disc > 0.0 && disc.is_finite()) {
        return 0.0;
    }
    (disc / raw).min(1.0)
}

// ----------------------------------------------------------------- rules

/// Shared stateless server step: scale the combined delta by `η_s`.
/// `η_s = 1` must return the input unchanged (the bit-identity guard
/// every stateless rule relies on).
fn lr_step(server_lr: f64, mut delta: ParamVec) -> ParamVec {
    if server_lr != 1.0 {
        params::scale(&mut delta, server_lr as f32);
    }
    delta
}

/// `fedavg` — the paper's rule: weighted mean delta, scaled by the
/// server learning rate (`η_s = 1` reproduces Algorithm 1 bit-for-bit).
struct FedAvg {
    server_lr: f64,
}

impl Aggregator for FedAvg {
    fn label(&self) -> String {
        "fedavg".into()
    }

    fn combine(&self, deltas: &[(f32, &[f32])]) -> Result<ParamVec> {
        Ok(params::weighted_mean(deltas))
    }

    fn combine_into(&self, deltas: &[(f32, &[f32])], out: &mut ParamVec) -> Result<()> {
        params::weighted_mean_into(out, deltas);
        Ok(())
    }

    fn step(&mut self, _round: u64, delta: ParamVec) -> Result<ParamVec> {
        Ok(lr_step(self.server_lr, delta))
    }

    fn mean_combine(&self) -> bool {
        true
    }
}

/// `fedavgm[:β]` — server momentum (Hsu et al., arXiv:1909.06335):
/// `v_t = β·v_{t-1} + Δ̄_t`, `w_{t+1} = w_t + η_s·v_t`. `β = 0, η_s = 1`
/// degenerates to `fedavg`.
struct FedAvgM {
    server_lr: f64,
    beta: f64,
    /// momentum buffer `v` (lazily sized on the first step).
    v: ParamVec,
}

impl Aggregator for FedAvgM {
    fn label(&self) -> String {
        format!("fedavgm:{}", self.beta)
    }

    fn combine(&self, deltas: &[(f32, &[f32])]) -> Result<ParamVec> {
        Ok(params::weighted_mean(deltas))
    }

    fn combine_into(&self, deltas: &[(f32, &[f32])], out: &mut ParamVec) -> Result<()> {
        params::weighted_mean_into(out, deltas);
        Ok(())
    }

    fn step(&mut self, _round: u64, delta: ParamVec) -> Result<ParamVec> {
        if self.v.is_empty() {
            self.v = vec![0.0; delta.len()];
        }
        anyhow::ensure!(self.v.len() == delta.len(), "momentum dim changed mid-run");
        let (beta, lr) = (self.beta as f32, self.server_lr as f32);
        let mut out = delta;
        for (v, d) in self.v.iter_mut().zip(out.iter_mut()) {
            *v = beta * *v + *d;
            *d = lr * *v;
        }
        Ok(out)
    }

    fn mean_combine(&self) -> bool {
        true
    }

    fn state_norms(&self) -> Vec<(&'static str, f64)> {
        if self.v.is_empty() {
            Vec::new()
        } else {
            vec![("momentum", params::l2_norm(&self.v))]
        }
    }

    fn state_save(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f32s(&self.v);
        w.into_inner()
    }

    fn state_load(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        self.v = r.f32s()?;
        r.expect_end()
    }
}

/// `fedadam[:τ]` — server Adam (Reddi et al., arXiv:2003.00295),
/// treating the aggregate delta as a pseudo-gradient:
/// `m_t = β₁·m_{t-1} + (1−β₁)·Δ̄_t`, `u_t = β₂·u_{t-1} + (1−β₂)·Δ̄_t²`,
/// `w_{t+1} = w_t + η_s·m_t/(√u_t + τ)`. No bias correction, matching
/// the reference recipe; β₁ comes from `--server-momentum`, β₂ = 0.99.
struct FedAdam {
    server_lr: f64,
    beta1: f64,
    beta2: f64,
    tau: f64,
    m: ParamVec,
    u: ParamVec,
}

impl Aggregator for FedAdam {
    fn label(&self) -> String {
        if self.tau == 1e-3 {
            "fedadam".into()
        } else {
            format!("fedadam:{}", self.tau)
        }
    }

    fn combine(&self, deltas: &[(f32, &[f32])]) -> Result<ParamVec> {
        Ok(params::weighted_mean(deltas))
    }

    fn combine_into(&self, deltas: &[(f32, &[f32])], out: &mut ParamVec) -> Result<()> {
        params::weighted_mean_into(out, deltas);
        Ok(())
    }

    fn step(&mut self, _round: u64, delta: ParamVec) -> Result<ParamVec> {
        if self.m.is_empty() {
            self.m = vec![0.0; delta.len()];
            self.u = vec![0.0; delta.len()];
        }
        anyhow::ensure!(self.m.len() == delta.len(), "adam moment dim changed mid-run");
        let (b1, b2) = (self.beta1 as f32, self.beta2 as f32);
        let (lr, tau) = (self.server_lr as f32, self.tau as f32);
        let mut out = delta;
        for ((m, u), d) in self.m.iter_mut().zip(self.u.iter_mut()).zip(out.iter_mut()) {
            *m = b1 * *m + (1.0 - b1) * *d;
            *u = b2 * *u + (1.0 - b2) * *d * *d;
            *d = lr * *m / (u.sqrt() + tau);
        }
        Ok(out)
    }

    fn mean_combine(&self) -> bool {
        true
    }

    fn state_norms(&self) -> Vec<(&'static str, f64)> {
        if self.m.is_empty() {
            Vec::new()
        } else {
            vec![("m", params::l2_norm(&self.m)), ("u", params::l2_norm(&self.u))]
        }
    }

    fn state_save(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_f32s(&self.m);
        w.put_f32s(&self.u);
        w.into_inner()
    }

    fn state_load(&mut self, bytes: &[u8]) -> Result<()> {
        // decode fully before assigning: a rejected blob must leave the
        // moments untouched, never half-applied
        let mut r = ByteReader::new(bytes);
        let m = r.f32s()?;
        let u = r.f32s()?;
        r.expect_end()?;
        anyhow::ensure!(
            m.len() == u.len(),
            "fedadam snapshot: m/u moment dims differ ({} vs {})",
            m.len(),
            u.len()
        );
        self.m = m;
        self.u = u;
        Ok(())
    }
}

/// `trimmed:<β>` — coordinate-wise β-trimmed mean
/// ([`params::trimmed_mean`]), scaled by `η_s`. Unweighted: a corrupted
/// client could lie about `n_k`, so robust rules count every client
/// once. Tolerates up to `⌊β·m⌋` arbitrary clients per coordinate.
struct TrimmedMean {
    server_lr: f64,
    frac: f64,
    /// Threads for the blocked per-coordinate kernel (execution knob
    /// only — bit-identical at any count; see `Aggregator::set_workers`).
    workers: usize,
}

impl Aggregator for TrimmedMean {
    fn label(&self) -> String {
        format!("trimmed:{}", self.frac)
    }

    fn combine(&self, deltas: &[(f32, &[f32])]) -> Result<ParamVec> {
        let mut out = ParamVec::new();
        self.combine_into(deltas, &mut out)?;
        Ok(out)
    }

    fn combine_into(&self, deltas: &[(f32, &[f32])], out: &mut ParamVec) -> Result<()> {
        let vecs: Vec<&[f32]> = deltas.iter().map(|(_, d)| *d).collect();
        params::trimmed_mean_into(out, &vecs, self.frac, self.workers);
        Ok(())
    }

    fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
    }

    fn step(&mut self, _round: u64, delta: ParamVec) -> Result<ParamVec> {
        Ok(lr_step(self.server_lr, delta))
    }
}

/// `median` — coordinate-wise median ([`params::median`]), scaled by
/// `η_s`: the maximal trim, robust to just under half the cohort.
struct Median {
    server_lr: f64,
    /// Threads for the blocked per-coordinate kernel (execution knob
    /// only — bit-identical at any count; see `Aggregator::set_workers`).
    workers: usize,
}

impl Aggregator for Median {
    fn label(&self) -> String {
        "median".into()
    }

    fn combine(&self, deltas: &[(f32, &[f32])]) -> Result<ParamVec> {
        let mut out = ParamVec::new();
        self.combine_into(deltas, &mut out)?;
        Ok(out)
    }

    fn combine_into(&self, deltas: &[(f32, &[f32])], out: &mut ParamVec) -> Result<()> {
        let vecs: Vec<&[f32]> = deltas.iter().map(|(_, d)| *d).collect();
        params::median_into(out, &vecs, self.workers);
        Ok(())
    }

    fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
    }

    fn step(&mut self, _round: u64, delta: ParamVec) -> Result<ParamVec> {
        Ok(lr_step(self.server_lr, delta))
    }
}

// -------------------------------------------------------------- registry

/// One row of the aggregator registry: rule name, argument syntax, and a
/// parser that claims matching `--agg` tokens (mirrors
/// [`crate::comms::wire::CodecEntry`]).
pub struct AggEntry {
    pub name: &'static str,
    pub syntax: &'static str,
    pub help: &'static str,
    parse: fn(&str, &AggConfig) -> Result<Option<Box<dyn Aggregator>>>,
}

fn parse_fedavg(tok: &str, cfg: &AggConfig) -> Result<Option<Box<dyn Aggregator>>> {
    Ok((tok == "fedavg").then(|| {
        Box::new(FedAvg {
            server_lr: cfg.lr_or(1.0),
        }) as Box<dyn Aggregator>
    }))
}

fn parse_fedavgm(tok: &str, cfg: &AggConfig) -> Result<Option<Box<dyn Aggregator>>> {
    let beta = if tok == "fedavgm" {
        cfg.server_momentum
    } else if let Some(arg) = tok.strip_prefix("fedavgm:") {
        let b: f64 = arg
            .parse()
            .map_err(|_| anyhow::anyhow!("fedavgm: bad momentum {arg:?}"))?;
        anyhow::ensure!(
            b.is_finite() && (0.0..1.0).contains(&b),
            "fedavgm: momentum must be in [0, 1), got {arg}"
        );
        b
    } else {
        return Ok(None);
    };
    Ok(Some(Box::new(FedAvgM {
        server_lr: cfg.lr_or(1.0),
        beta,
        v: Vec::new(),
    })))
}

fn parse_fedadam(tok: &str, cfg: &AggConfig) -> Result<Option<Box<dyn Aggregator>>> {
    let tau = if tok == "fedadam" {
        1e-3
    } else if let Some(arg) = tok.strip_prefix("fedadam:") {
        let t: f64 = arg
            .parse()
            .map_err(|_| anyhow::anyhow!("fedadam: bad adaptivity τ {arg:?}"))?;
        anyhow::ensure!(t.is_finite() && t > 0.0, "fedadam: τ must be positive, got {arg}");
        t
    } else {
        return Ok(None);
    };
    Ok(Some(Box::new(FedAdam {
        server_lr: cfg.lr_or(0.01),
        beta1: cfg.server_momentum,
        beta2: 0.99,
        tau,
        m: Vec::new(),
        u: Vec::new(),
    })))
}

fn parse_trimmed(tok: &str, cfg: &AggConfig) -> Result<Option<Box<dyn Aggregator>>> {
    let Some(arg) = tok.strip_prefix("trimmed:") else {
        return Ok(None);
    };
    let frac: f64 = arg
        .parse()
        .map_err(|_| anyhow::anyhow!("trimmed: bad trim fraction {arg:?}"))?;
    anyhow::ensure!(
        frac.is_finite() && frac > 0.0 && frac < 0.5,
        "trimmed: trim fraction must be in (0, 0.5), got {arg}"
    );
    Ok(Some(Box::new(TrimmedMean {
        server_lr: cfg.lr_or(1.0),
        frac,
        workers: 1,
    })))
}

fn parse_median(tok: &str, cfg: &AggConfig) -> Result<Option<Box<dyn Aggregator>>> {
    Ok((tok == "median").then(|| {
        Box::new(Median {
            server_lr: cfg.lr_or(1.0),
            workers: 1,
        }) as Box<dyn Aggregator>
    }))
}

/// The rule registry `--agg` specs resolve against.
pub static REGISTRY: &[AggEntry] = &[
    AggEntry {
        name: "fedavg",
        syntax: "fedavg",
        help: "the paper's weighted mean of client models (default; η_s=1 is Algorithm 1)",
        parse: parse_fedavg,
    },
    AggEntry {
        name: "fedavgm",
        syntax: "fedavgm[:<beta>]",
        help: "server momentum on the mean delta (beta from --server-momentum when omitted)",
        parse: parse_fedavgm,
    },
    AggEntry {
        name: "fedadam",
        syntax: "fedadam[:<tau>]",
        help: "server Adam over the mean delta as pseudo-gradient (β1=--server-momentum, β2=0.99, unset η_s=0.01)",
        parse: parse_fedadam,
    },
    AggEntry {
        name: "trimmed",
        syntax: "trimmed:<frac>",
        help: "coordinate-wise trimmed mean, dropping frac of each tail (robust, unweighted)",
        parse: parse_trimmed,
    },
    AggEntry {
        name: "median",
        syntax: "median",
        help: "coordinate-wise median (robust to just under half the cohort, unweighted)",
        parse: parse_median,
    },
];

/// Human-readable registry listing for CLI help and parse errors.
pub fn registry_help() -> String {
    REGISTRY
        .iter()
        .map(|e| format!("  {:<18} {}", e.syntax, e.help))
        .collect::<Vec<_>>()
        .join("\n")
}

// --------------------------------------------------------------- config

/// The aggregation subsystem's knobs, CLI-shaped (`--agg`, `--server-lr`,
/// `--server-momentum`, `--prox-mu`). The default is Algorithm 1
/// verbatim: `fedavg` at `η_s = 1`, no proximal term — bit-identical to
/// the pre-subsystem server loop.
#[derive(Debug, Clone)]
pub struct AggConfig {
    /// Rule spec resolved against [`REGISTRY`] (e.g. `"trimmed:0.1"`).
    pub spec: String,
    /// Server learning rate η_s scaling the applied increment. `None`
    /// resolves per rule: 1.0 everywhere (Algorithm 1), **except 0.01
    /// for `fedadam`** — its step is Adam-normalized to ~±η_s per
    /// coordinate, so η_s = 1 diverges where the mean-delta rules
    /// expect exactly 1.
    pub server_lr: Option<f64>,
    /// Server momentum: β for bare `fedavgm`, β₁ for `fedadam`.
    pub server_momentum: f64,
    /// FedProx proximal coefficient μ added to every client's local
    /// objective: `ℓ_k(w) + (μ/2)·‖w − w_t‖²` (0 = plain ClientUpdate).
    pub prox_mu: f64,
}

impl Default for AggConfig {
    fn default() -> Self {
        Self {
            spec: "fedavg".into(),
            server_lr: None,
            server_momentum: 0.9,
            prox_mu: 0.0,
        }
    }
}

impl AggConfig {
    /// η_s for a rule whose unset-default is `rule_default`
    /// (1.0 for every rule except `fedadam`'s 0.01).
    fn lr_or(&self, rule_default: f64) -> f64 {
        self.server_lr.unwrap_or(rule_default)
    }

    /// Resolve the spec against the registry and build a fresh (state at
    /// zero) aggregator. Errors on unknown rules or out-of-range knobs.
    pub fn build(&self) -> Result<Box<dyn Aggregator>> {
        if let Some(lr) = self.server_lr {
            anyhow::ensure!(
                lr.is_finite() && lr > 0.0,
                "--server-lr must be positive, got {lr}"
            );
        }
        anyhow::ensure!(
            self.server_momentum.is_finite() && (0.0..1.0).contains(&self.server_momentum),
            "--server-momentum must be in [0, 1), got {}",
            self.server_momentum
        );
        anyhow::ensure!(
            self.prox_mu.is_finite() && self.prox_mu >= 0.0,
            "--prox-mu must be non-negative, got {}",
            self.prox_mu
        );
        let tok = self.spec.trim();
        for entry in REGISTRY {
            if let Some(agg) = (entry.parse)(tok, self)? {
                return Ok(agg);
            }
        }
        anyhow::bail!("unknown aggregator {tok:?}; known rules:\n{}", registry_help())
    }

    /// Cheap validation (build and discard) for CLI parse time, so a bad
    /// `--agg` fails before any dataset is synthesized.
    pub fn validate(&self) -> Result<()> {
        self.build().map(drop)
    }

    /// Layer the `agg`, `server_lr`, `server_momentum`, `prox_mu` keys of
    /// a [`ConfigFile`] over the defaults (CLI flags override on top; see
    /// `fedavg run --config`).
    pub fn from_config(cf: &ConfigFile) -> Result<AggConfig> {
        let d = AggConfig::default();
        Ok(AggConfig {
            spec: cf.get("agg").unwrap_or(&d.spec).to_string(),
            server_lr: cf.get_parse("server_lr")?.or(d.server_lr),
            server_momentum: cf.get_parse("server_momentum")?.unwrap_or(d.server_momentum),
            prox_mu: cf.get_parse("prox_mu")?.unwrap_or(d.prox_mu),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_every_rule_and_canonicalizes_labels() {
        for (spec, label) in [
            ("fedavg", "fedavg"),
            ("fedavgm", "fedavgm:0.9"),
            ("fedavgm:0.5", "fedavgm:0.5"),
            ("fedadam", "fedadam"),
            ("fedadam:0.01", "fedadam:0.01"),
            ("trimmed:0.1", "trimmed:0.1"),
            ("median", "median"),
        ] {
            let agg = AggConfig {
                spec: spec.into(),
                ..Default::default()
            }
            .build()
            .unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(agg.label(), label, "{spec}");
        }
    }

    #[test]
    fn registry_rejects_bad_specs_and_knobs() {
        for bad in [
            "", "magic", "trimmed", "trimmed:0", "trimmed:0.5", "trimmed:x",
            "fedavgm:1.0", "fedavgm:-0.1", "fedadam:0", "fedadam:-1",
        ] {
            let cfg = AggConfig {
                spec: bad.into(),
                ..Default::default()
            };
            assert!(cfg.validate().is_err(), "{bad:?} accepted");
        }
        for (lr, mom, mu) in [(0.0, 0.9, 0.0), (1.0, 1.0, 0.0), (1.0, 0.9, -1.0)] {
            let cfg = AggConfig {
                server_lr: Some(lr),
                server_momentum: mom,
                prox_mu: mu,
                ..Default::default()
            };
            assert!(cfg.validate().is_err(), "lr={lr} mom={mom} mu={mu} accepted");
        }
        assert!(AggConfig::default().validate().is_ok());
    }

    #[test]
    fn registry_help_lists_every_rule() {
        let help = registry_help();
        for e in REGISTRY {
            assert!(help.contains(e.name), "{} missing from:\n{help}", e.name);
        }
    }

    #[test]
    fn secure_agg_compatibility_flags() {
        for (spec, ok) in [
            ("fedavg", true),
            ("fedavgm", true),
            ("fedadam", true),
            ("trimmed:0.2", false),
            ("median", false),
        ] {
            let agg = AggConfig {
                spec: spec.into(),
                ..Default::default()
            }
            .build()
            .unwrap();
            assert_eq!(agg.mean_combine(), ok, "{spec}");
        }
    }

    #[test]
    fn combine_into_matches_combine_bitwise_for_every_rule() {
        let deltas: Vec<(f32, ParamVec)> = (0..7)
            .map(|c| {
                let v: ParamVec = (0..33).map(|i| ((c * 31 + i) as f32 * 0.7).sin()).collect();
                ((c + 1) as f32, v)
            })
            .collect();
        let refs: Vec<(f32, &[f32])> = deltas.iter().map(|(w, d)| (*w, d.as_slice())).collect();
        for spec in ["fedavg", "fedavgm", "fedadam", "trimmed:0.2", "median"] {
            for workers in [1usize, 3] {
                let mut agg = AggConfig {
                    spec: spec.into(),
                    ..Default::default()
                }
                .build()
                .unwrap();
                agg.set_workers(workers); // must never change bits
                let owned = agg.combine(&refs).unwrap();
                let mut out = vec![5.0f32; 3]; // stale scratch must be cleared
                agg.combine_into(&refs, &mut out).unwrap();
                let same = owned.len() == out.len()
                    && owned.iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "{spec} workers={workers}: combine_into != combine");
            }
        }
    }

    #[test]
    fn state_norm_formatting() {
        assert_eq!(fmt_state_norms(&[]), "");
        let s = fmt_state_norms(&[("momentum", 0.25), ("u", 1.0)]);
        assert_eq!(s, "momentum=2.500000e-1;u=1.000000e0");
        assert!(!s.contains(','), "must stay CSV-safe");
    }

    #[test]
    fn state_save_load_roundtrips_and_resumes_bit_identically() {
        // run a stateful rule for a few steps, snapshot, run a fresh
        // instance restored from the snapshot: steps must match exactly
        for spec in ["fedavgm:0.7", "fedadam:0.01"] {
            let cfg = AggConfig {
                spec: spec.into(),
                ..Default::default()
            };
            let mut live = cfg.build().unwrap();
            let deltas: Vec<ParamVec> = (0..6)
                .map(|r| (0..8).map(|i| ((r * 8 + i) as f32).sin()).collect())
                .collect();
            for (r, d) in deltas[..3].iter().enumerate() {
                live.step(r as u64 + 1, d.clone()).unwrap();
            }
            let blob = live.state_save();
            assert!(!blob.is_empty(), "{spec}: no state after 3 steps");
            let mut resumed = cfg.build().unwrap();
            resumed.state_load(&blob).unwrap();
            for (r, d) in deltas[3..].iter().enumerate() {
                let a = live.step(r as u64 + 4, d.clone()).unwrap();
                let b = resumed.step(r as u64 + 4, d.clone()).unwrap();
                assert_eq!(a, b, "{spec}: diverged after state_load");
            }
            // truncated blobs are rejected, never half-loaded
            let mut bad = cfg.build().unwrap();
            assert!(bad.state_load(&blob[..blob.len() - 1]).is_err(), "{spec}");
        }
        // stateless rules: empty blob round-trips, junk is rejected
        for spec in ["fedavg", "median", "trimmed:0.1"] {
            let cfg = AggConfig {
                spec: spec.into(),
                ..Default::default()
            };
            let mut agg = cfg.build().unwrap();
            assert!(agg.state_save().is_empty(), "{spec}");
            agg.state_load(&[]).unwrap();
            assert!(agg.state_load(&[1, 2, 3]).is_err(), "{spec}");
        }
    }

    #[test]
    fn staleness_weight_guards_and_decays() {
        // the bit-identity guards: fresh deltas and decay=1 pass through
        for w in [0.0f32, 1.0, 3.5, 1e-3] {
            assert_eq!(staleness_weight(w, 0.5, 0).to_bits(), w.to_bits());
            assert_eq!(staleness_weight(w, 1.0, 7).to_bits(), w.to_bits());
        }
        // exact binary exponentiation: decay^s with no libm involved
        assert_eq!(staleness_weight(2.0, 0.5, 1), 1.0);
        assert_eq!(staleness_weight(2.0, 0.5, 3), 0.25);
        assert_eq!(staleness_weight(1.0, 0.25, 2), 0.0625);
        // monotone non-increasing in staleness for decay in (0, 1]
        for decay in [0.1, 0.5, 0.9, 1.0] {
            let mut prev = staleness_weight(3.0, decay, 0);
            for s in 1..40u64 {
                let w = staleness_weight(3.0, decay, s);
                assert!(w <= prev, "decay={decay} s={s}: {w} > {prev}");
                assert!(w.is_finite() && w >= 0.0);
                prev = w;
            }
        }
    }

    #[test]
    fn staleness_scale_attenuates_between_combine_and_step() {
        // all-fresh or decay=1: exactly 1.0 (the sync-identity guard)
        assert_eq!(staleness_scale(&[(2.0, 0), (3.0, 0)], 0.5), 1.0);
        assert_eq!(staleness_scale(&[(2.0, 5), (3.0, 9)], 1.0), 1.0);
        // mixed buffer: Σ n_k·d^s_k / Σ n_k
        let s = staleness_scale(&[(1.0, 0), (1.0, 1)], 0.5);
        assert!((s - 0.75).abs() < 1e-12, "{s}");
        // underflowed mass signals "skip the combine"
        assert_eq!(staleness_scale(&[(1.0, 100_000)], 0.5), 0.0);
        // scale never exceeds 1 and stays finite for any decay in (0,1]
        for decay in [0.01, 0.3, 0.999, 1.0] {
            let s = staleness_scale(&[(5.0, 2), (0.5, 0), (2.0, 17)], decay);
            assert!((0.0..=1.0).contains(&s) && s.is_finite(), "decay={decay}: {s}");
        }
    }

    #[test]
    fn config_file_keys_layer_over_defaults() {
        let cf = ConfigFile::parse("agg = trimmed:0.2\nserver_lr = 0.5\nprox_mu = 0.01\n").unwrap();
        let cfg = AggConfig::from_config(&cf).unwrap();
        assert_eq!(cfg.spec, "trimmed:0.2");
        assert_eq!(cfg.server_lr, Some(0.5));
        assert_eq!(cfg.server_momentum, 0.9); // untouched default
        assert_eq!(cfg.prox_mu, 0.01);
        assert!(cfg.validate().is_ok());
        let bad = ConfigFile::parse("server_lr = fast\n").unwrap();
        assert!(AggConfig::from_config(&bad).is_err());
    }
}
