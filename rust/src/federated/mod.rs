//! FederatedAveraging (Algorithm 1) and its machinery.
//!
//! * [`server`] — the round loop: client selection, ClientUpdate
//!   fan-out, then the round's updates flow through the pluggable
//!   aggregation subsystem (the paper's weighted model averaging is the
//!   default rule).
//! * [`aggregate`] — the [`Aggregator`] trait + registry behind
//!   `--agg`: weighted FedAvg, stateful server optimizers (FedAvgM,
//!   FedAdam), and robust rules (coordinate-wise trimmed mean, median);
//!   DESIGN.md §7.
//! * [`client`] — ClientUpdate: E local epochs of B-sized SGD, with the
//!   exact `B = ∞` path via gradient accumulation and an optional
//!   FedProx proximal term ([`client::prox_step`]).
//! * [`sampler`] — per-round client selection (`m = max(C·K, 1)`),
//!   optionally availability-filtered.
//!
//! FedSGD is not a separate implementation: it is the `E=1, B=∞` point of
//! the family (`FedConfig::fedsgd()`), exactly as the paper defines it.

pub mod aggregate;
pub mod client;
pub mod sampler;
pub mod server;

pub use aggregate::{AggConfig, Aggregator};
pub use client::{local_update, updates_per_round, LocalResult, LocalSpec};
pub use sampler::ClientSampler;
pub use server::{run, RunResult, ServerOptions};
