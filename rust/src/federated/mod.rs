//! FederatedAveraging (Algorithm 1) and its machinery.
//!
//! * [`server`] — the round loop + weighted model averaging (the paper's
//!   contribution).
//! * [`client`] — ClientUpdate: E local epochs of B-sized SGD, with the
//!   exact `B = ∞` path via gradient accumulation.
//! * [`sampler`] — per-round client selection (`m = max(C·K, 1)`),
//!   optionally availability-filtered.
//!
//! FedSGD is not a separate implementation: it is the `E=1, B=∞` point of
//! the family (`FedConfig::fedsgd()`), exactly as the paper defines it.

pub mod client;
pub mod sampler;
pub mod server;

pub use client::{local_update, updates_per_round, LocalResult, LocalSpec};
pub use sampler::ClientSampler;
pub use server::{run, RunResult, ServerOptions};
