//! ClientUpdate — the on-device half of Algorithm 1.
//!
//! ```text
//! ClientUpdate(k, w):
//!   B ← split P_k into batches of size B
//!   for each local epoch i from 1 to E:
//!     for batch b ∈ B:  w ← w − η ∇ℓ(w; b)
//!   return w
//! ```
//!
//! `B = ∞` (the paper's full-batch setting, and all of FedSGD) is executed
//! exactly via chunked gradient accumulation + a fused apply: per-example
//! gradients are linear, so summing fixed-capacity `gradacc` chunks and
//! dividing by `n_k` reproduces the full-batch gradient bit-for-bit up to
//! f32 addition order (verified by the integration tests).
//!
//! With [`LocalSpec::prox_mu`] > 0 the local objective gains FedProx's
//! proximal term (Li et al., arXiv:1812.06127) anchoring the client to
//! the broadcast model: `ℓ_k(w) + (μ/2)·‖w − w_t‖²`. Its gradient
//! contribution `μ·(w − w_t)` is applied by [`prox_step`] after every
//! SGD step; `μ = 0` leaves ClientUpdate bit-identical to the paper's.

use crate::config::BatchSize;
use crate::data::rng::Rng;
use crate::data::Dataset;
use crate::params::ParamVec;
use crate::runtime::Model;
use crate::Result;

/// Specification of one client's local work in one round.
#[derive(Debug, Clone)]
pub struct LocalSpec {
    pub epochs: usize,
    pub batch: BatchSize,
    pub lr: f32,
    /// FedProx proximal coefficient μ (0 = the paper's ClientUpdate,
    /// bit-identical; see [`prox_step`]).
    pub prox_mu: f32,
    /// seed domain-separating (run, round, client).
    pub shuffle_seed: u64,
}

/// Result of a local update: new parameters + the client's example weight
/// (`n_k`) + how many SGD steps it took (the paper's `u_k` accounting).
#[derive(Debug, Clone)]
pub struct LocalResult {
    pub theta: ParamVec,
    pub weight: f64,
    pub steps: u64,
}

/// Run ClientUpdate for client data `idxs` starting from `theta0`.
pub fn local_update(
    model: &Model<'_>,
    data: &Dataset,
    idxs: &[usize],
    theta0: &[f32],
    spec: &LocalSpec,
) -> Result<LocalResult> {
    assert!(!idxs.is_empty(), "client with no data");
    let mut theta = theta0.to_vec();
    let mut steps = 0u64;
    let weight = data.weight_of(idxs);

    match spec.batch {
        BatchSize::Full => {
            // E epochs of exact full-batch gradient descent
            for _ in 0..spec.epochs {
                let (g, _) = model.full_gradient(&theta, data, idxs)?;
                theta = model.apply(&theta, &g, spec.lr)?;
                prox_step(&mut theta, theta0, spec.lr, spec.prox_mu);
                steps += 1;
            }
        }
        BatchSize::Fixed(b) => {
            let cap = model
                .meta()
                .step_capacity_for(b)
                .ok_or_else(|| anyhow::anyhow!(
                    "no step executable for B={b} on {} (capacities {:?})",
                    model.meta().name,
                    model.meta().step_batches
                ))?;
            let mut order = idxs.to_vec();
            let mut rng = Rng::new(spec.shuffle_seed);
            for _ in 0..spec.epochs {
                rng.shuffle(&mut order);
                for chunk in order.chunks(b) {
                    let batch = data.padded_batch(chunk, cap);
                    theta = model.step(&theta, &batch, spec.lr)?;
                    prox_step(&mut theta, theta0, spec.lr, spec.prox_mu);
                    steps += 1;
                }
            }
        }
    }
    Ok(LocalResult {
        theta,
        weight,
        steps,
    })
}

/// FedProx's proximal correction, folded into the SGD step: after the
/// model's gradient step `w ← w − η·∇ℓ(w; b)`, pull toward the round's
/// broadcast anchor `w_t` with the proximal gradient `μ·(w − w_t)`:
///
/// ```text
/// w ← w − η·μ·(w − w_t)
/// ```
///
/// (The standard first-order treatment: the proximal gradient is
/// evaluated at the post-step iterate.) `μ = 0` returns without touching
/// `theta`, keeping the default path bit-identical.
pub fn prox_step(theta: &mut [f32], anchor: &[f32], lr: f32, mu: f32) {
    if mu == 0.0 {
        return;
    }
    debug_assert_eq!(theta.len(), anchor.len());
    let c = lr * mu;
    for (w, a) in theta.iter_mut().zip(anchor) {
        *w -= c * (*w - *a);
    }
}

/// Expected local updates per round for a client of size `n_k` —
/// the paper's `u_k = E · n_k / B` statistic (Table 2's `u` column).
pub fn updates_per_round(e: usize, n_k: usize, b: BatchSize) -> f64 {
    match b {
        BatchSize::Full => e as f64,
        BatchSize::Fixed(b) => e as f64 * (n_k as f64 / b as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u_statistic_matches_paper() {
        // paper Table 2: MNIST CNN n/K=600: (E,B)=(1,50) -> u=12;
        // (5,10) -> u=300 ; (E,B)=(5,inf) -> 5; (20, inf) -> 20
        assert_eq!(updates_per_round(1, 600, BatchSize::Fixed(50)), 12.0);
        assert_eq!(updates_per_round(5, 600, BatchSize::Fixed(10)), 300.0);
        assert_eq!(updates_per_round(5, 600, BatchSize::Full), 5.0);
        assert_eq!(updates_per_round(20, 600, BatchSize::Full), 20.0);
    }

    #[test]
    fn prox_step_math_and_mu_zero_noop() {
        let anchor = vec![1.0f32, -2.0, 0.0];
        let mut w = vec![2.0f32, -2.0, -4.0];
        let before = w.clone();
        prox_step(&mut w, &anchor, 0.1, 0.0);
        assert_eq!(w, before, "μ=0 must not touch the iterate");
        // w ← w − η·μ·(w − w_t), η·μ = 0.5
        prox_step(&mut w, &anchor, 0.5, 1.0);
        assert_eq!(w, vec![1.5, -2.0, -2.0]);
        // repeated application converges toward the anchor
        for _ in 0..200 {
            prox_step(&mut w, &anchor, 0.5, 1.0);
        }
        for (a, b) in w.iter().zip(&anchor) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
