//! The FedAvg server — Algorithm 1 of the paper.
//!
//! ```text
//! initialize w_0
//! for each round t = 1, 2, ...:
//!   m ← max(C·K, 1)
//!   S_t ← (random set of m clients)
//!   for each client k ∈ S_t in parallel:
//!     w_{t+1}^k ← ClientUpdate(k, w_t)
//!   w_{t+1} ← Σ_k (n_k/n) · w_{t+1}^k
//! ```
//!
//! The averaging weights use `n` = total examples across the *selected*
//! clients (the standard reading of Algorithm 1, since unselected clients
//! produce no update). FedSGD is exactly this loop with `E=1, B=∞`.
//!
//! The last line — the server update rule — is pluggable: every round's
//! updates flow through an [`Aggregator`](crate::federated::aggregate)
//! selected by [`ServerOptions::agg`]. The default `fedavg` rule at
//! `η_s = 1` is the paper's rule, bit-for-bit.

use std::path::PathBuf;
use std::sync::Arc;

use crate::comms::{CommModel, CommSim, CommTotals, Transport, TransportConfig};
use crate::config::FedConfig;
use crate::coordinator::{
    plan_async_wave, plan_round, ClientJob, ExecScratch, Fleet, FleetConfig, FleetTotals,
    LatePolicy, ParallelExec, RoundPlan, TierLink, WavePlan,
};
use crate::data::Federated;
use crate::federated::aggregate::{
    combine_sharded, fmt_state_norms, staleness_scale, staleness_weight, AggConfig,
    Aggregator as _,
};
use crate::federated::client::{local_update, updates_per_round, LocalResult, LocalSpec};
use crate::federated::sampler::ClientSampler;
use crate::metrics::LearningCurve;
use crate::obs::{Metrics, Tracer};
use crate::params::ParamVec;
use crate::privacy::{clip, GaussianMechanism, SecureAggregator};
use crate::runstate::{
    checkpoint_dir, AggState, AsyncState, BufferedDelta, CheckpointConfig, FleetState, ResumeFrom,
    RunMeta, Snapshot, TierState,
};
use crate::runtime::Engine;
use crate::telemetry::{RoundRecord, RunWriter};
use crate::Result;

/// Differential-privacy knobs (paper §4 future work; Abadi et al. recipe).
#[derive(Debug, Clone, Copy)]
pub struct DpConfig {
    /// per-client update L2 clip bound.
    pub clip_norm: f64,
    /// Gaussian noise multiplier σ.
    pub sigma: f64,
}

/// Harness options orthogonal to the algorithm itself.
pub struct ServerOptions {
    pub telemetry: Option<RunWriter>,
    /// network model for the legacy comm simulator. A fleet profile
    /// supersedes it: round timing then comes from per-device profiles
    /// and `FleetConfig`'s latency/step-cost, and this model only labels
    /// the byte totals.
    pub comm_model: CommModel,
    /// client online-probability per round (None = always available).
    pub availability: Option<f64>,
    /// evaluate on at most this many test examples (None = all).
    pub eval_cap: Option<usize>,
    /// evaluate training loss on at most this many examples.
    pub train_eval_cap: usize,
    /// differentially-private aggregation (clip + Gaussian noise).
    pub dp: Option<DpConfig>,
    /// aggregate via pairwise-mask secure aggregation (server never sees
    /// an individual update).
    pub secure_agg: bool,
    /// codec pipelines for both link directions (uplink compression,
    /// delta downlink). The default routes bytes exactly like the
    /// pre-transport legacy path (unframed dense both ways).
    pub transport: TransportConfig,
    /// fleet coordinator: device profiles, over-selection, deadlines,
    /// worker parallelism. The default is the legacy sequential,
    /// always-available path.
    pub fleet: FleetConfig,
    /// server update rule (`--agg` registry spec + server-optimizer
    /// knobs + client-side FedProx μ). The default is Algorithm 1's
    /// weighted averaging, bit-for-bit.
    pub agg: AggConfig,
    /// write a run-state snapshot every N rounds under the telemetry run
    /// dir (`--checkpoint-every`; needs `telemetry`). See
    /// [`runstate`](crate::runstate) / DESIGN.md §8.
    pub checkpoint: Option<CheckpointConfig>,
    /// restored snapshot to continue from (`--resume`): the run starts
    /// at `snapshot.round + 1` with every stateful subsystem rewound,
    /// and the resulting trajectory — including `curve.csv` — is
    /// bit-identical to a run that never stopped. The snapshot's config
    /// fingerprint must match this invocation; only then does the
    /// server reopen (and truncate) the run dir's curve, so `telemetry`
    /// must be left `None` here.
    pub resume: Option<ResumeFrom>,
    /// Silence the per-round console line on a writer the server opens
    /// itself (the resume path) — parallel grid cells would interleave
    /// their chatter on stdout. Rows still land in curve.csv.
    pub quiet_rounds: bool,
    /// span tracer (`--trace`, DESIGN.md §10). The default is disabled:
    /// [`Tracer::begin`] returns `None` without reading the clock, so
    /// the untraced round loop is byte-identical and overhead-free.
    pub trace: Tracer,
    /// metrics registry (DESIGN.md §10). The server feeds its round
    /// counters (wire bytes, drops, deadline misses, client SGD steps)
    /// here; curve.csv reads the same values back out, and resume
    /// re-seeds them from the snapshot's existing sections. Pass a
    /// shared handle to read them after the run.
    pub metrics: Metrics,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            telemetry: None,
            comm_model: CommModel::default(),
            availability: None,
            eval_cap: None,
            train_eval_cap: 2000,
            dp: None,
            secure_agg: false,
            transport: TransportConfig::default(),
            fleet: FleetConfig::default(),
            agg: AggConfig::default(),
            checkpoint: None,
            resume: None,
            quiet_rounds: false,
            trace: Tracer::default(),
            metrics: Metrics::default(),
        }
    }
}

/// Everything a finished run reports.
pub struct RunResult {
    /// (ε, δ=1e-5) consumed, if DP was enabled.
    pub epsilon: Option<f64>,
    /// test accuracy by round (at eval cadence).
    pub accuracy: LearningCurve,
    /// test mean loss by round.
    pub test_loss: LearningCurve,
    /// training-set mean loss by round (if tracked).
    pub train_loss: Option<LearningCurve>,
    pub comm: CommTotals,
    pub final_theta: ParamVec,
    /// total client-side SGD steps executed (all rounds, all clients).
    pub client_steps: u64,
    /// rounds actually run (early stop shortens this).
    pub rounds_run: u64,
    /// fleet accounting (all zeros on the legacy path).
    pub fleet: FleetTotals,
}

impl RunResult {
    pub fn final_accuracy(&self) -> f64 {
        self.accuracy.last_value().unwrap_or(0.0)
    }
}

/// Run FederatedAveraging (or FedSGD via `cfg.fedsgd()`).
pub fn run(
    engine: &Engine,
    fed: &Federated,
    cfg: &FedConfig,
    mut opts: ServerOptions,
) -> Result<RunResult> {
    // Build the aggregation rule first: a bad --agg spec (or a robust
    // rule under secure aggregation, which hides the individual updates
    // the order statistics need) must fail before any work happens.
    let mut aggregator = opts.agg.build()?;
    // Execution knob only — combine kernels are bit-identical at any
    // worker count, which is why workers stay out of AggConfig and the
    // RunMeta fingerprint (resume may change machines).
    aggregator.set_workers(opts.fleet.workers.max(1));
    let agg_label = aggregator.label();
    if opts.secure_agg {
        anyhow::ensure!(
            aggregator.mean_combine(),
            "--agg {agg_label} needs individual client updates, which secure \
             aggregation withholds from the server (DESIGN.md §7)"
        );
    }
    // The Gaussian mechanism's noise is calibrated to the weighted
    // mean's sensitivity (clip/m). An order-statistic combine has
    // per-client sensitivity O(clip) — adding mean-calibrated noise
    // would report an ε the mechanism does not provide, so refuse.
    if opts.dp.is_some() {
        anyhow::ensure!(
            aggregator.mean_combine(),
            "--agg {agg_label}: DP noise is calibrated for the weighted-mean \
             combine; robust order statistics need their own sensitivity \
             analysis (DESIGN.md §7)"
        );
    }
    // Hierarchical aggregation composes only for mean-family rules
    // (combine_sharded re-checks per call, but a bad pairing must fail
    // before any work happens), and is incompatible with secure
    // aggregation: pairwise masks cancel only over the full cohort's
    // modular sum, never over per-shard partials (DESIGN.md §11).
    if opts.fleet.shards > 0 {
        anyhow::ensure!(
            aggregator.mean_combine(),
            "--agg {agg_label} cannot run under --shards: coordinate-wise \
             order statistics do not compose across aggregation tiers — only \
             mean-family rules (fedavg/fedavgm/fedadam) shard (DESIGN.md §11)"
        );
        anyhow::ensure!(
            !opts.secure_agg,
            "--secure-agg cannot run under --shards: pairwise masks only \
             cancel over the full cohort, not per-shard partial sums \
             (DESIGN.md §11)"
        );
    }
    // Alternative round modes (DESIGN.md §12): buffered-async applies a
    // partial cohort whenever K deltas have arrived; semi-sync splices
    // staleness-discounted stragglers into later cohorts. Both break the
    // one-full-cohort-per-round premise that robust order statistics,
    // secure-aggregation masking, and the edge tier all rest on — every
    // bad pairing is refused here, before any work happens.
    let async_buf = opts.fleet.async_buffer;
    let semi_sync = opts.fleet.late_policy == LatePolicy::Discount;
    let decay = opts.fleet.staleness_decay;
    if let Some(buf) = async_buf {
        anyhow::ensure!(buf >= 1, "--async-buffer must be at least 1");
        anyhow::ensure!(
            aggregator.mean_combine(),
            "--agg {agg_label} cannot run under --async-buffer: a K-delta \
             buffer is a partial cohort, and coordinate-wise order statistics \
             are only defined over a full round cohort (DESIGN.md §12)"
        );
        anyhow::ensure!(
            !opts.secure_agg,
            "--secure-agg cannot run under --async-buffer: pairwise masks \
             cancel only over the full dispatched cohort's modular sum, never \
             over a K-delta partial buffer (DESIGN.md §12)"
        );
        anyhow::ensure!(
            opts.fleet.shards == 0,
            "--async-buffer cannot run under --shards: the edge tier frames \
             one combine per round over that round's cohort, not \
             buffer-paced partial applies (DESIGN.md §12)"
        );
        anyhow::ensure!(
            opts.fleet.overselect == 0.0 && opts.fleet.deadline_s.is_none(),
            "--async-buffer replaces the synchronous barrier: \
             --overselect/--deadline do not apply (DESIGN.md §12)"
        );
        anyhow::ensure!(
            !semi_sync,
            "--async-buffer and --late-policy are alternative round modes \
             (DESIGN.md §12)"
        );
        anyhow::ensure!(
            opts.fleet.fleet_active(),
            "--async-buffer needs a fleet profile: completion order comes \
             from the per-device virtual clock (--fleet-profile \
             uniform|mobile|flaky)"
        );
    }
    if semi_sync {
        anyhow::ensure!(
            aggregator.mean_combine(),
            "--agg {agg_label} cannot run under --late-policy discount: \
             staleness discounting reweights the mean combine; coordinate-wise \
             order statistics have no per-update weights to discount \
             (DESIGN.md §12)"
        );
        anyhow::ensure!(
            !opts.secure_agg,
            "--secure-agg cannot run under --late-policy discount: a late \
             update joins a later round's cohort, and pairwise masks cancel \
             only within one round's full cohort (DESIGN.md §12)"
        );
        anyhow::ensure!(
            opts.fleet.shards == 0,
            "--late-policy discount cannot run under --shards: the edge tier \
             frames one combine per round over that round's cohort, which the \
             late queue splices prior-round deltas into (DESIGN.md §12)"
        );
        anyhow::ensure!(
            opts.fleet.fleet_active(),
            "--late-policy discount needs a fleet profile: lateness is \
             measured on the fleet's virtual clock (--fleet-profile \
             uniform|mobile|flaky)"
        );
        anyhow::ensure!(
            opts.fleet.deadline_s.is_some(),
            "--late-policy discount needs --deadline: without one nobody is \
             late (DESIGN.md §12)"
        );
    }
    anyhow::ensure!(
        decay.is_finite() && decay > 0.0 && decay <= 1.0,
        "--staleness-decay must be in (0, 1], got {decay}"
    );
    let prox_mu = opts.agg.prox_mu as f32;

    let model = engine.model(&cfg.model)?;
    anyhow::ensure!(
        fed.train.is_tokens() == model.meta().is_tokens(),
        "dataset kind {:?} does not match model {} kind {:?}",
        fed.train.name,
        cfg.model,
        model.meta().kind
    );
    let k = fed.num_clients();
    let mut theta: ParamVec = model.init(cfg.seed as i32)?;
    let mut sampler = ClientSampler::new(cfg.seed);
    if let Some(p) = opts.availability {
        sampler = sampler.with_availability(p, cfg.seed ^ 0xAB1E);
    }
    let mut comms = CommSim::new(opts.comm_model.clone(), cfg.seed);

    // fleet coordinator state (None on the legacy path, which keeps the
    // seed's sequential, always-available round loop bit-for-bit).
    // Fleet::build does its own domain separation from cfg.seed, so a
    // `fleet --sim-only` run with the same seed builds the same fleet.
    anyhow::ensure!(
        !(opts.fleet.fleet_active() && opts.availability.is_some()),
        "ServerOptions.availability conflicts with fleet profile {:?}: device \
         reachability comes from the fleet's diurnal model",
        opts.fleet.profile
    );
    let fleet = opts
        .fleet
        .fleet_active()
        .then(|| Fleet::build(&opts.fleet, k, cfg.seed));
    // All byte metering routes through the transport: the scheduler
    // prices each link direction from the same codec pipeline that later
    // encodes the real payload, so estimates and telemetry-reported wire
    // bytes cannot drift. The default TransportConfig reproduces the
    // legacy unframed-dense accounting bit-for-bit.
    let mut transport = Transport::new(opts.transport.clone(), k, model.param_count(), cfg.seed);
    let codec_label = transport.codec_label();
    let est_up_bytes = transport.up_plan_bytes();
    // NB: the pool needs 'static data, so requesting workers > 1 pays a
    // one-time copy of the training set + partition into an Arc for the
    // run (sharing at zero copy needs Arc inside `Federated` itself — a
    // wider refactor than this subsystem).
    let exec = if opts.fleet.workers > 1 {
        Some(ParallelExec::new(
            opts.fleet.workers,
            engine.dir().to_path_buf(),
            cfg.model.clone(),
            Arc::new(fed.train.clone()),
            Arc::new(fed.clients.clone()),
            opts.trace.clone(),
        )?)
    } else {
        None
    };
    // Round accounting lives in the metrics registry (DESIGN.md §10):
    // cumulative counters, with the counter *mark* standing in for the
    // old "events since the last telemetry record" locals (the curve is
    // written at eval cadence, drops happen every round). The registry
    // produces the same u64 arithmetic the locals did, so curve.csv is
    // byte-identical.
    let metrics = opts.metrics.clone();
    // Edge-tier accounting (`--shards S`, DESIGN.md §11): cumulative
    // totals mirrored into `tier.*` metrics. Seconds need the local f64
    // (registry counters are u64); the whole struct rides snapshots —
    // per-round frame counts depend on cohort size, so resume cannot
    // recompute them. Tier-1 bytes/seconds stay out of `comms.ingest`
    // and curve.csv: the curve is pinned byte-identical to a flat run.
    let tier_link = TierLink::default();
    let mut tier = (opts.fleet.shards > 0).then(TierState::default);

    let mut accuracy = LearningCurve::new();
    let mut test_loss = LearningCurve::new();
    let mut train_loss_curve = if cfg.track_train_loss {
        Some(LearningCurve::new())
    } else {
        None
    };
    let mut rounds_run = 0u64;
    let mut mech = opts
        .dp
        .map(|d| GaussianMechanism::new(d.clip_norm, d.sigma, cfg.seed ^ 0xD11F));
    let sec_agg = opts.secure_agg.then(|| SecureAggregator::new(cfg.seed ^ 0x5EC));

    let eval_idxs: Option<Vec<usize>> = opts
        .eval_cap
        .map(|cap| (0..fed.test.len().min(cap)).collect());
    // training-loss eval subset: spread across clients
    let train_eval_idxs: Vec<usize> = {
        let total = fed.total_examples();
        let stride = (total / opts.train_eval_cap.max(1)).max(1);
        fed.clients
            .iter()
            .flatten()
            .copied()
            .step_by(stride)
            .take(opts.train_eval_cap)
            .collect()
    };

    // Dataset-identity fingerprint: `--partition` and `--scale` change
    // *which* examples each client holds without moving the client count
    // or parameter dim, so the coarse shape fields cannot catch them.
    // Hash the dataset names, the test-set size, and the exact
    // per-client index assignment (clients are in id order, indices in
    // their stored order — both deterministic).
    let data_fp = {
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(fed.train.name.as_bytes());
        bytes.push(0);
        bytes.extend_from_slice(fed.test.name.as_bytes());
        bytes.push(0);
        bytes.extend_from_slice(&(fed.test.len() as u64).to_le_bytes());
        for idxs in &fed.clients {
            bytes.extend_from_slice(&(idxs.len() as u64).to_le_bytes());
            for &i in idxs {
                bytes.extend_from_slice(&(i as u64).to_le_bytes());
            }
        }
        crate::runstate::fnv1a64(&bytes)
    };

    // Configuration fingerprint stamped into every snapshot and checked
    // on resume: a checkpoint must not silently continue under different
    // flags (DESIGN.md §8). Dataset shape is covered by the client count
    // and parameter dim, dataset *identity* by `data_fp`; every other
    // trajectory-affecting knob — availability, DP clip/σ, fleet shape,
    // eval caps, the comm model, train-loss tracking — rides in the
    // harness string (Debug-formatted, so any value change is caught).
    // `fleet.workers` is deliberately absent: worker count is
    // bit-identical by design, so resuming at a different parallelism
    // is legitimate. `fleet.shards` IS present even though sharding is
    // also bit-identical: the snapshot carries cumulative tier-1 byte
    // totals, and continuing under a different S would silently blend
    // two topologies' accounting (DESIGN.md §11).
    let meta = RunMeta {
        label: cfg.label(),
        agg: agg_label.clone(),
        codec: codec_label.clone(),
        seed: cfg.seed,
        clients: k as u64,
        dim: model.param_count() as u64,
        lr_decay: cfg.lr_decay,
        eval_every: cfg.eval_every as u64,
        harness: format!(
            "availability={:?} dp={:?} secure_agg={} prox_mu={:?} \
             fleet=({},{:?},{:?},{:?},{:?},{:?}) shards={} \
             async=({:?},{:?},{:?}) eval_cap={:?} \
             train_eval_cap={} comm=({:?},{:?},{:?},{:?}) \
             data={data_fp:#018x} track_train_loss={}",
            opts.availability,
            opts.dp.map(|d| (d.clip_norm, d.sigma)),
            opts.secure_agg,
            opts.agg.prox_mu,
            opts.fleet.profile.label(),
            opts.fleet.overselect,
            opts.fleet.deadline_s,
            opts.fleet.step_cost_s,
            opts.fleet.diurnal_period,
            opts.fleet.latency_s,
            opts.fleet.shards,
            opts.fleet.async_buffer,
            opts.fleet.staleness_decay,
            opts.fleet.late_policy,
            opts.eval_cap,
            opts.train_eval_cap,
            opts.comm_model.up_bps,
            opts.comm_model.down_bps,
            opts.comm_model.latency_s,
            opts.comm_model.jitter,
            cfg.track_train_loss,
        ),
    };

    // Resume: validate the fingerprint FIRST — only a request that will
    // actually be honored may touch the run dir (reopening truncates
    // curve.csv past the checkpoint; a refused resume must leave the
    // original run's telemetry untouched). Then rewind every stateful
    // subsystem. Each state_load validates before it applies, and any
    // failure aborts the run before training starts, so a partial
    // restore can never yield a silently-wrong trajectory.
    // Buffered-async / semi-sync holding state (DESIGN.md §12): the
    // apply counter staleness is measured against, the arrival buffer,
    // and the late queue. `Some` only when one of the alternative round
    // modes is on, so the synchronous path stays byte-identical.
    let mut astate: Option<AsyncState> =
        (async_buf.is_some() || semi_sync).then(AsyncState::default);

    let mut start_round = 1u64;
    if let Some(ResumeFrom { snapshot: snap, run_dir }) = opts.resume.take() {
        anyhow::ensure!(
            opts.telemetry.is_none(),
            "resume opens the run dir's own telemetry; leave ServerOptions.telemetry unset"
        );
        anyhow::ensure!(
            snap.meta == meta,
            "--resume: the checkpoint was written by a different configuration\n  \
             checkpoint: {:?}\n  this run:   {:?}",
            snap.meta,
            meta
        );
        anyhow::ensure!(
            (snap.round as usize) < cfg.rounds,
            "--resume: checkpoint is already at round {} — raise --rounds past it \
             (got {})",
            snap.round,
            cfg.rounds
        );
        anyhow::ensure!(
            snap.dp.is_some() == opts.dp.is_some(),
            "--resume: checkpoint {} DP state but this run {} --dp-sigma",
            if snap.dp.is_some() { "carries" } else { "has no" },
            if opts.dp.is_some() { "sets" } else { "does not set" },
        );
        anyhow::ensure!(
            snap.curves.train_loss.is_some() == cfg.track_train_loss,
            "--resume: checkpoint and --track-train-loss disagree"
        );
        anyhow::ensure!(
            snap.async_state.is_some() == astate.is_some(),
            "--resume: checkpoint {} async-round state but this run {} an \
             async round mode (--async-buffer / --late-policy discount)",
            if snap.async_state.is_some() { "carries" } else { "has no" },
            if astate.is_some() { "sets" } else { "does not set" },
        );
        anyhow::ensure!(
            snap.theta.len() == model.param_count(),
            "--resume: model dim changed ({} vs {})",
            snap.theta.len(),
            model.param_count()
        );
        // All checks passed: this resume WILL run. Only now reopen the
        // run's curve, truncated back to the checkpointed round.
        let mut w = RunWriter::reopen(&run_dir, snap.round)?;
        w.set_quiet(opts.quiet_rounds);
        opts.telemetry = Some(w);
        theta = snap.theta;
        sampler.restore_state(snap.sampler);
        aggregator.state_load(&snap.agg.bytes)?;
        transport.state_load(snap.transport)?;
        comms.state_load(snap.comms);
        if let (Some(m), Some(st)) = (mech.as_mut(), snap.dp) {
            m.state_load(st);
        }
        if let Some(a) = snap.async_state {
            astate = Some(a);
        }
        accuracy = LearningCurve::from_points(snap.curves.accuracy)?;
        test_loss = LearningCurve::from_points(snap.curves.test_loss)?;
        if let Some(pts) = snap.curves.train_loss {
            train_loss_curve = Some(LearningCurve::from_points(pts)?);
        }
        rounds_run = snap.round;
        // Re-seed the metrics registry from the snapshot's existing
        // sections — cumulative totals ride the state_save/state_load
        // surface without a snapshot-format change (DESIGN.md §8/§10).
        // marked = the portion already written to curve.csv, so pending()
        // resumes exactly where the since-eval accumulation stopped.
        let totals = comms.totals();
        metrics.seed_counter("wire.up_bytes", totals.bytes_up, totals.bytes_up);
        metrics.seed_counter("wire.down_bytes", totals.bytes_down, totals.bytes_down);
        metrics.seed_counter("client.steps", snap.client_steps, snap.client_steps);
        metrics.seed_counter("rounds", snap.round, snap.round);
        let ft = snap.fleet.totals;
        metrics.seed_counter("fleet.dispatched", ft.dispatched, ft.dispatched);
        metrics.seed_counter("fleet.completed", ft.completed, ft.completed);
        metrics.seed_counter(
            "fleet.dropped",
            ft.dropped_stragglers,
            ft.dropped_stragglers.saturating_sub(snap.fleet.dropped_since_eval),
        );
        metrics.seed_counter(
            "fleet.deadline_misses",
            ft.deadline_misses,
            ft.deadline_misses.saturating_sub(snap.fleet.misses_since_eval),
        );
        // Sharded runs: restore the edge-tier totals (the meta check
        // above guarantees the checkpoint's shard count matches, so a
        // sharded run's snapshot always carries the TIER section).
        if let Some(t) = tier.as_mut() {
            let ts = snap.tier.unwrap_or_default();
            *t = ts;
            metrics.seed_counter("tier.edge_up_bytes", ts.up_bytes, ts.up_bytes);
            metrics.seed_counter("tier.edge_down_bytes", ts.down_bytes, ts.down_bytes);
            metrics.seed_counter("tier.edge_frames", ts.frames, ts.frames);
        }
        start_round = snap.round + 1;
    }

    // Resolved after the resume block: a resumed run's writer is the
    // reopened run dir.
    let ckpt_dir: Option<PathBuf> = match (&opts.checkpoint, &opts.telemetry) {
        (Some(ck), Some(w)) => {
            ck.validate()?;
            Some(checkpoint_dir(w.dir()))
        }
        (Some(_), None) => anyhow::bail!(
            "checkpointing needs a run directory to write under — enable telemetry"
        ),
        (None, _) => None,
    };

    let tr = opts.trace.clone();
    // Per-round scratch (DESIGN.md §14): cleared every round, reallocated
    // never. Dispatch jobs + the pool's slot-tagged staging + the sorted
    // results cover the fan-out; `agg_buf` is the combine target, whose
    // spine round-trips through `Aggregator::step` (stateless steps move
    // the same vec through) and is reclaimed after the axpy.
    let mut scratch_jobs: Vec<ClientJob> = Vec::new();
    let mut exec_scratch = ExecScratch::default();
    let mut results_buf: Vec<LocalResult> = Vec::new();
    let mut agg_buf: ParamVec = ParamVec::new();
    for round in start_round..=cfg.rounds as u64 {
        let sp_round = tr.begin(round, "round", 0);
        rounds_run = round;
        metrics.inc("rounds");
        let m = cfg.clients_per_round(k);
        // Publish this round's model to the version store (no-op without
        // a delta downlink codec) before any client is priced against it.
        let sp = tr.begin(round, "publish", 1);
        transport.publish(round, &theta);
        tr.end(sp);
        // Σ downlink bytes over every client the model is sent to
        // (fleet path: dispatched, incl. stragglers later dropped; the
        // legacy path's comm accounting sums its own links, so there
        // this total only labels the sample span).
        let mut down_bytes_round = 0u64;
        // Legacy path: per-pick (down, up) wire bytes for the jitter
        // model (which sums its own totals).
        let mut links: Vec<(u64, u64)> = Vec::new();

        // Selection. Fleet path: over-select from the diurnal online
        // pool, run the event-queue schedule, and aggregate only the
        // first `m` finishers inside the deadline; every dispatched
        // client's links are priced by the transport (delta downlinks
        // differ per client). Async path: dispatch a barrier-free wave —
        // every arrival lands in the staleness buffer, ordered purely by
        // the seeded fleet's event times (DESIGN.md §12). Legacy path:
        // uniform sample over the (optionally availability-filtered)
        // population.
        let sp = tr.begin(round, "sample", 1);
        let mut wave: Option<WavePlan> = None;
        let (picks, plan): (Vec<usize>, Option<RoundPlan>) = match &fleet {
            None => {
                let picks = sampler.sample(round, k, m);
                for &c in &picks {
                    let down = transport.downlink(c, round, &theta);
                    down_bytes_round += down;
                    links.push((down, est_up_bytes));
                }
                (picks, None)
            }
            Some(fl) if async_buf.is_some() => {
                let (_online, w) = plan_async_wave(
                    fl,
                    &mut sampler,
                    round,
                    m,
                    |c| {
                        let down = transport.downlink(c, round, &theta);
                        down_bytes_round += down;
                        (down, est_up_bytes)
                    },
                    |c| updates_per_round(cfg.e, fed.clients[c].len(), cfg.b),
                );
                let picks = w.dispatched.clone();
                wave = Some(w);
                (picks, None)
            }
            Some(fl) => {
                let (_online, plan) = plan_round(
                    fl,
                    &mut sampler,
                    round,
                    m,
                    opts.fleet.overselect,
                    opts.fleet.deadline_s,
                    |c| {
                        let down = transport.downlink(c, round, &theta);
                        down_bytes_round += down;
                        (down, est_up_bytes)
                    },
                    |c| updates_per_round(cfg.e, fed.clients[c].len(), cfg.b),
                );
                (plan.completed.clone(), Some(plan))
            }
        };
        tr.end(sp.map(|s| s.bytes(down_bytes_round)));
        // Virtual clock before this round's transfer time is folded in —
        // the reference point for semi-sync due times (DESIGN.md §12).
        let clock0 = comms.totals().sim_seconds;
        // Semi-sync: past-deadline stragglers keep training this round's
        // model; their raw deltas queue for a later round's combine
        // instead of being dropped.
        let late_now: Vec<(usize, f64)> = match &plan {
            Some(p) if semi_sync => p.late.clone(),
            _ => Vec::new(),
        };
        let train_list: Vec<usize> = picks
            .iter()
            .copied()
            .chain(late_now.iter().map(|&(c, _)| c))
            .collect();
        let lr = (cfg.lr * cfg.lr_decay.powi(round as i32 - 1)) as f32;

        // The model each aggregated client actually starts from: `None`
        // (= theta, zero copies) unless a lossy downlink codec means the
        // client reconstructs an approximation.
        let sp = tr.begin(round, "broadcast", 1);
        let mut start_models: Vec<Option<ParamVec>> = train_list
            .iter()
            .map(|&c| transport.downlink_model(c, &theta))
            .collect::<Result<_>>()?;
        tr.end(sp);

        // ClientUpdate for every aggregating client — inline, or fanned
        // out over the worker pool (per-thread engines; reduction in
        // dispatch-slot order keeps parallel runs bit-identical to
        // sequential). Dropped stragglers never execute: their simulated
        // work is wasted, not ours.
        let sp_dispatch = tr.begin(round, "dispatch", 1);
        let specs: Vec<LocalSpec> = train_list
            .iter()
            .map(|&ck| LocalSpec {
                epochs: cfg.e,
                batch: cfg.b,
                lr,
                prox_mu,
                shuffle_seed: cfg.seed
                    ^ round.wrapping_mul(0x9E3779B97F4A7C15)
                    ^ (ck as u64).wrapping_mul(0xD1B54A32D192ED03),
            })
            .collect();
        match &exec {
            Some(pool) => {
                let theta0 = Arc::new(theta.clone());
                scratch_jobs.clear();
                scratch_jobs.extend(train_list.iter().zip(&specs).enumerate().map(
                    |(slot, (&client, spec))| ClientJob {
                        slot,
                        round,
                        client,
                        theta: match start_models[slot].take() {
                            Some(start) => Arc::new(start),
                            None => theta0.clone(),
                        },
                        spec: spec.clone(),
                    },
                ));
                pool.run_round_into(&mut scratch_jobs, &mut exec_scratch, &mut results_buf)?;
            }
            None => {
                results_buf.clear();
                results_buf.reserve(train_list.len());
                for (slot, (&ck, spec)) in train_list.iter().zip(&specs).enumerate() {
                    let start = start_models[slot].as_deref().unwrap_or(&theta);
                    let sp = tr
                        .begin(round, "local_train", 2)
                        .map(|s| s.client(ck as u64));
                    let res = local_update(&model, &fed.train, &fed.clients[ck], start, spec);
                    tr.end(sp);
                    results_buf.push(res?);
                }
            }
        };
        tr.end(sp_dispatch);

        // Server-side post-processing per update, in slot order.
        // Updates travel as DELTAS (θ_k − θ_t): identical average, and the
        // natural unit for clipping / codecs / secure aggregation. Only
        // aggregated updates reach the uplink codec: straggler-dropped
        // clients never encode, so their error-feedback residuals stay
        // put (the dropped mass was never delivered — re-injecting it
        // later would double-count).
        let sp = tr.begin(round, "encode_up", 1);
        let mut deltas: Vec<(f32, ParamVec)> = Vec::with_capacity(picks.len());
        let mut wire_up_bytes = 0u64;
        for (slot, (&ck, res)) in train_list.iter().zip(results_buf.drain(..)).enumerate() {
            metrics.add("client.steps", res.steps);
            let mut delta = res.theta;
            for (d, t) in delta.iter_mut().zip(&theta) {
                *d -= *t;
            }
            if slot < picks.len() {
                if let Some(dp) = &opts.dp {
                    clip(&mut delta, dp.clip_norm);
                }
                wire_up_bytes += transport.encode_up(ck, &mut delta)?;
                deltas.push((res.weight as f32, delta));
            } else {
                // semi-sync late straggler: hold the RAW delta — the
                // clip, the uplink encode, and the error-feedback
                // advance all happen at the round that applies it, so a
                // still-queued update has touched no server state
                let (_, finish_t) = late_now[slot - picks.len()];
                let a = astate.as_mut().expect("semi-sync allocates state");
                a.late.push(BufferedDelta {
                    dispatch_round: round,
                    slot: slot as u64,
                    client: ck as u64,
                    basis: 0,
                    weight: res.weight as f32,
                    due_s: clock0 + finish_t,
                    delta,
                });
            }
        }
        tr.end(sp.map(|s| s.bytes(wire_up_bytes)));

        // w_{t+1} ← w_t + step(combine({(n_k, Δ^k)})) — the pluggable
        // server update (DESIGN.md §7). Default: combine = weighted mean
        // Σ (n_k/n) Δ^k, step = identity — Algorithm 1 bit-for-bit.
        // Under --async-buffer the same combine∘step fires once per K
        // buffered arrivals instead of once per round (DESIGN.md §12).
        let (rc, n_clients) = if let Some(buf) = async_buf {
            let a = astate.as_mut().expect("async mode allocates state");
            let w = wave.as_ref().expect("async mode plans a wave");
            // Arrivals enter the buffer in (finish time, dispatch slot)
            // order — a pure function of the seeded fleet's event times,
            // so the buffer sequence is identical under any --workers N.
            // Encoding already happened in slot order above; transport
            // state is per-client, so cross-client encode order cannot
            // change a single bit of any delta.
            let mut by_slot: Vec<Option<(f32, ParamVec)>> =
                deltas.into_iter().map(Some).collect();
            for arr in &w.arrivals {
                let (weight, delta) = by_slot[arr.slot].take().expect("one arrival per slot");
                a.pending.push(BufferedDelta {
                    dispatch_round: round,
                    slot: arr.slot as u64,
                    client: arr.client as u64,
                    basis: a.applies_done,
                    weight,
                    due_s: 0.0,
                    delta,
                });
            }
            while a.pending.len() >= buf {
                let sp = tr.begin(round, "combine", 1);
                let mut batch: Vec<BufferedDelta> = a.pending.drain(..buf).collect();
                // The combine folds in (dispatch round, slot) order —
                // the synchronous reduction order — so `--async-buffer
                // m --staleness-decay 1.0` reproduces the synchronous
                // trajectory bit-for-bit (rust/tests/async_rounds.rs).
                batch.sort_by_key(|e| (e.dispatch_round, e.slot));
                let stale: Vec<(f32, u64)> = batch
                    .iter()
                    .map(|e| (e.weight, a.applies_done - e.basis))
                    .collect();
                let scale = staleness_scale(&stale, decay);
                let mut agg_delta: ParamVec = if scale > 0.0 {
                    let refs: Vec<(f32, &[f32])> = batch
                        .iter()
                        .zip(&stale)
                        .map(|(e, &(wt, s))| {
                            (staleness_weight(wt, decay, s), e.delta.as_slice())
                        })
                        .collect();
                    aggregator.combine_into(&refs, &mut agg_buf)?;
                    let mut d = std::mem::take(&mut agg_buf);
                    // overall staleness attenuation Σn·dᔆ/Σn at the
                    // combine∘step seam, before the DP noise — guarded
                    // so decay 1.0 never rounds through f64
                    if scale != 1.0 {
                        for v in d.iter_mut() {
                            *v = (*v as f64 * scale) as f32;
                        }
                    }
                    d
                } else {
                    // the whole batch's discounted mass underflowed:
                    // contribute nothing, but still run the stateful
                    // step so the optimizer's clock advances and θ
                    // stays finite
                    vec![0.0f32; theta.len()]
                };
                tr.end(sp);
                let sp = tr.begin(round, "step", 1);
                if let Some(mech) = mech.as_mut() {
                    mech.apply(&mut agg_delta, buf);
                }
                let step = aggregator.step(a.applies_done + 1, agg_delta)?;
                crate::params::axpy(&mut theta, 1.0, &step);
                agg_buf = step; // reclaim the spine for the next combine
                tr.end(sp);
                a.applies_done += 1;
                a.deltas_since_eval += buf as u64;
                for &(_, s) in &stale {
                    a.stale_sum_since_eval += s;
                }
            }
            let sp = tr.begin(round, "account", 1);
            // barrier-free wave: every dispatched client completes —
            // there are no stragglers to drop and no deadline to miss
            metrics.add("fleet.dispatched", picks.len() as u64);
            metrics.add("fleet.completed", picks.len() as u64);
            let rc = comms.ingest(wire_up_bytes, down_bytes_round, w.round_seconds);
            tr.end(sp);
            (rc, picks.len())
        } else {
            // Semi-sync: late-queue entries whose virtual finish time
            // falls inside this round's window join the combine FIRST,
            // staleness-discounted by their age in rounds, ahead of the
            // round's own completions (DESIGN.md §12).
            let mut due_deltas: Vec<(f32, ParamVec)> = Vec::new();
            let mut stale: Vec<(f32, u64)> = Vec::new();
            if let (Some(a), Some(p)) = (astate.as_mut(), &plan) {
                let cut = clock0 + p.round_seconds;
                let (due, keep): (Vec<BufferedDelta>, Vec<BufferedDelta>) =
                    a.late.drain(..).partition(|e| e.due_s <= cut);
                a.late = keep;
                for e in due {
                    let mut d = e.delta;
                    if let Some(dp) = &opts.dp {
                        clip(&mut d, dp.clip_norm);
                    }
                    wire_up_bytes += transport.encode_up(e.client as usize, &mut d)?;
                    let s = round - e.dispatch_round;
                    due_deltas.push((staleness_weight(e.weight, decay, s), d));
                    stale.push((e.weight, s));
                    a.late_applied += 1;
                }
                for (wt, _) in &deltas {
                    stale.push((*wt, 0));
                }
                a.deltas_since_eval += (due_deltas.len() + deltas.len()) as u64;
                for &(_, s) in &stale {
                    a.stale_sum_since_eval += s;
                }
            }
            let n_apply = due_deltas.len() + picks.len();
            let scale = if astate.is_some() {
                staleness_scale(&stale, decay)
            } else {
                1.0
            };
            let sp = tr.begin(round, "combine", 1);
            let mut agg_delta: ParamVec = if let Some(agg) = &sec_agg {
                // clients upload masked fixed-point (w·Δ ‖ w); server only
                // ever sees the modular sum — i.e. the weighted mean. Only
                // mean-combine rules reach here (checked at startup); their
                // server-optimizer step still applies below.
                // lint:allow(float-fold): `deltas` is already in canonical client-id order (sorted at collect), so this fold sequence is deterministic.
                let total_w: f64 = deltas.iter().map(|(w, _)| *w as f64).sum();
                let masked: Vec<Vec<u32>> = deltas
                    .iter()
                    .enumerate()
                    .map(|(i, (w, d))| {
                        let mut payload: Vec<f32> =
                            d.iter().map(|v| v * *w / total_w as f32).collect();
                        payload.push(*w);
                        agg.mask(picks[i], &picks, &payload)
                    })
                    .collect();
                let mut summed = agg.aggregate(&masked);
                summed.pop(); // total weight slot (available to the server)
                summed
            } else {
                let refs: Vec<(f32, &[f32])> = due_deltas
                    .iter()
                    .map(|(w, d)| (*w, d.as_slice()))
                    .chain(deltas.iter().map(|(w, d)| (*w, d.as_slice())))
                    .collect();
                match tier.as_mut() {
                    // hierarchical path (--shards S): cascade the combine
                    // across S edge aggregators — bit-identical to the flat
                    // fold below (pinned in rust/tests/shards.rs). Tier-1
                    // transfers land in `tier.*`, never in curve.csv.
                    Some(t) => {
                        let sc = combine_sharded(
                            aggregator.as_ref(),
                            &refs,
                            opts.fleet.shards,
                            &tier_link,
                        )?;
                        t.up_bytes += sc.up_bytes;
                        t.down_bytes += sc.down_bytes;
                        t.frames += sc.frames;
                        t.seconds += sc.seconds;
                        metrics.add("tier.edge_up_bytes", sc.up_bytes);
                        metrics.add("tier.edge_down_bytes", sc.down_bytes);
                        metrics.add("tier.edge_frames", sc.frames);
                        metrics.observe("tier.seconds", sc.seconds);
                        sc.delta
                    }
                    None => {
                        aggregator.combine_into(&refs, &mut agg_buf)?;
                        std::mem::take(&mut agg_buf)
                    }
                }
            };
            // overall staleness attenuation at the combine∘step seam,
            // BEFORE the DP noise — `!= 1.0` guarded so a run with no
            // late arrivals never rounds through f64 (the bit-identity
            // pin in rust/tests/async_rounds.rs)
            if scale != 1.0 {
                for v in agg_delta.iter_mut() {
                    *v = (*v as f64 * scale) as f32;
                }
            }
            tr.end(sp);
            // DP noise lands on the combined delta, *before* the stateful
            // server step: the optimizer moments then only ever see the
            // privatized aggregate (post-processing preserves the guarantee).
            let sp = tr.begin(round, "step", 1);
            if let Some(mech) = mech.as_mut() {
                mech.apply(&mut agg_delta, n_apply);
            }
            let step = aggregator.step(round, agg_delta)?;
            crate::params::axpy(&mut theta, 1.0, &step);
            agg_buf = step; // reclaim the spine for the next combine
            tr.end(sp);
            let sp = tr.begin(round, "account", 1);
            let rc = match &plan {
                None => comms.round_links(&links),
                Some(p) => {
                    metrics.add("fleet.dispatched", p.dispatched.len() as u64);
                    // late-discounted stragglers leave the drop column at
                    // dispatch and join completed at their apply round
                    metrics.add("fleet.completed", n_apply as u64);
                    metrics.add("fleet.dropped", (p.dropped.len() - late_now.len()) as u64);
                    metrics.add("fleet.deadline_misses", p.deadline_miss as u64);
                    // every dispatched client downloaded the model (dropped
                    // stragglers waste downlink); only completed uplinks land
                    comms.ingest(wire_up_bytes, down_bytes_round, p.round_seconds)
                }
            };
            tr.end(sp);
            (rc, n_apply)
        };
        metrics.add("wire.up_bytes", rc.bytes_up);
        metrics.add("wire.down_bytes", rc.bytes_down);
        metrics.observe("round.seconds", rc.transfer_s);

        let mut hit_target = false;
        if round % cfg.eval_every as u64 == 0 || round == cfg.rounds as u64 {
            let sp = tr.begin(round, "eval", 1);
            let sums = model.eval_dataset(&theta, &fed.test, eval_idxs.as_deref())?;
            accuracy.push(round, sums.accuracy());
            test_loss.push(round, sums.mean_loss());
            let tl = if let Some(curve) = train_loss_curve.as_mut() {
                let ts = model.eval_dataset(&theta, &fed.train, Some(&train_eval_idxs))?;
                curve.push(round, ts.mean_loss());
                Some(ts.mean_loss())
            } else {
                None
            };
            // EF residual mass is a full scan over per-client residuals,
            // so the gauge is only refreshed when someone will read it.
            if tr.enabled() {
                metrics.gauge("transport.ef_residual_l2", transport.residual_l2_total());
            }
            if let Some(w) = opts.telemetry.as_mut() {
                let server_state = fmt_state_norms(&aggregator.state_norms());
                // per-record staleness stats (DESIGN.md §12): mean
                // staleness over the deltas applied since the previous
                // row, and the holding-queue depth as of this row
                // (async: buffer fill; semi-sync: late-queue length).
                // The synchronous path writes 0.000/0, which the async
                // sync-identity tests rely on.
                let (staleness_mean, buffer_fill) = match astate.as_ref() {
                    Some(a) => (
                        if a.deltas_since_eval > 0 {
                            a.stale_sum_since_eval as f64 / a.deltas_since_eval as f64
                        } else {
                            0.0
                        },
                        if async_buf.is_some() { a.pending.len() } else { a.late.len() },
                    ),
                    None => (0.0, 0),
                };
                w.record(&RoundRecord {
                    round,
                    test_accuracy: sums.accuracy(),
                    test_loss: sums.mean_loss(),
                    train_loss: tl,
                    clients: n_clients,
                    lr: lr as f64,
                    up_bytes: rc.bytes_up,
                    down_bytes: rc.bytes_down,
                    codec: &codec_label,
                    sim_seconds: comms.totals().sim_seconds,
                    dropped: metrics.pending("fleet.dropped") as usize,
                    deadline_misses: metrics.pending("fleet.deadline_misses") as usize,
                    agg: &agg_label,
                    server_state: &server_state,
                    staleness_mean,
                    buffer_fill,
                })?;
                metrics.mark("fleet.dropped");
                metrics.mark("fleet.deadline_misses");
                if let Some(a) = astate.as_mut() {
                    a.stale_sum_since_eval = 0;
                    a.deltas_since_eval = 0;
                }
            }
            if let Some(target) = cfg.target_accuracy {
                hit_target = sums.accuracy() >= target;
            }
            tr.end(sp);
        }

        // Snapshot AFTER the round's telemetry so curve.csv and the
        // checkpoint agree on "state as of round r"; resume truncates
        // the curve to this round and continues at r+1 (DESIGN.md §8).
        // The last executed round (final round or early stop) snapshots
        // even off-cadence — the terminal snapshot is what lets a
        // finished run be *extended* (`--resume` with a larger
        // `--rounds`) without replaying anything.
        if let (Some(ck), Some(dir)) = (&opts.checkpoint, &ckpt_dir) {
            let terminal = hit_target || round == cfg.rounds as u64;
            if round % ck.every == 0 || terminal {
                let sp = tr.begin(round, "checkpoint", 1);
                let snap = Snapshot {
                    round,
                    meta: meta.clone(),
                    theta: theta.clone(),
                    client_steps: metrics.counter("client.steps"),
                    sampler: sampler.state(),
                    agg: AggState {
                        label: agg_label.clone(),
                        bytes: aggregator.state_save(),
                    },
                    transport: transport.state_save(),
                    comms: comms.state_save(),
                    fleet: FleetState {
                        totals: fleet_totals(&metrics),
                        dropped_since_eval: metrics.pending("fleet.dropped"),
                        misses_since_eval: metrics.pending("fleet.deadline_misses"),
                    },
                    curves: crate::runstate::CurveState {
                        accuracy: accuracy.points().to_vec(),
                        test_loss: test_loss.points().to_vec(),
                        train_loss: train_loss_curve.as_ref().map(|c| c.points().to_vec()),
                    },
                    dp: mech.as_ref().map(|m| m.state_save()),
                    tier,
                    async_state: astate.clone(),
                };
                snap.write(dir, ck.keep)?;
                tr.end(sp);
            }
        }
        tr.end(sp_round.map(|s| s.bytes(rc.bytes_up + rc.bytes_down).sim(rc.transfer_s)));
        if hit_target {
            break;
        }
    }

    // Trace epilogue: flush trace.jsonl (surfacing any deferred write
    // error) and print the per-round phase breakdown + metrics registry.
    // Wall-clock numbers stop here — nothing below touches curve.csv.
    if let Some(table) = tr.finish(&metrics)? {
        if !opts.quiet_rounds {
            eprint!("{table}");
        }
    }

    if let Some(w) = opts.telemetry.take() {
        let totals = comms.totals();
        let mut fields = vec![
            ("model", cfg.model.clone()),
            ("label", cfg.label()),
            ("rounds_run", rounds_run.to_string()),
            ("client_steps", metrics.counter("client.steps").to_string()),
            ("final_accuracy", format!("{:.6}", accuracy.last_value().unwrap_or(0.0))),
            ("bytes_up", totals.bytes_up.to_string()),
            ("bytes_down", totals.bytes_down.to_string()),
            ("codec", codec_label.clone()),
            ("sim_seconds", format!("{:.1}", totals.sim_seconds)),
            ("agg", agg_label.clone()),
        ];
        let server_state = fmt_state_norms(&aggregator.state_norms());
        if !server_state.is_empty() {
            fields.push(("server_state", server_state));
        }
        if fleet.is_some() {
            let ft = fleet_totals(&metrics);
            fields.push(("fleet_profile", opts.fleet.profile.label().to_string()));
            fields.push(("dispatched", ft.dispatched.to_string()));
            fields.push(("completed", ft.completed.to_string()));
            fields.push(("dropped_stragglers", ft.dropped_stragglers.to_string()));
            fields.push(("deadline_misses", ft.deadline_misses.to_string()));
        }
        if let Some(t) = &tier {
            fields.push(("shards", opts.fleet.shards.to_string()));
            fields.push(("tier_up_bytes", t.up_bytes.to_string()));
            fields.push(("tier_down_bytes", t.down_bytes.to_string()));
            fields.push(("tier_frames", t.frames.to_string()));
            fields.push(("tier_seconds", format!("{:.3}", t.seconds)));
        }
        if let Some(a) = &astate {
            if let Some(buf) = async_buf {
                fields.push(("async_buffer", buf.to_string()));
                fields.push(("buffer_applies", a.applies_done.to_string()));
                fields.push(("buffer_fill", a.pending.len().to_string()));
            } else {
                fields.push(("late_policy", "discount".to_string()));
                fields.push(("late_applied", a.late_applied.to_string()));
                fields.push(("late_queued", a.late.len().to_string()));
            }
            fields.push(("staleness_decay", format!("{decay:?}")));
        }
        w.finish(&fields)?;
    }

    Ok(RunResult {
        epsilon: mech.as_ref().map(|m| m.epsilon(1e-5)),
        accuracy,
        test_loss,
        train_loss: train_loss_curve,
        comm: comms.totals(),
        final_theta: theta,
        client_steps: metrics.counter("client.steps"),
        rounds_run,
        fleet: fleet_totals(&metrics),
    })
}

/// The fleet accounting view of the metrics registry (the counters the
/// round loop feeds under `fleet.*`).
fn fleet_totals(metrics: &Metrics) -> FleetTotals {
    FleetTotals {
        dispatched: metrics.counter("fleet.dispatched"),
        completed: metrics.counter("fleet.completed"),
        dropped_stragglers: metrics.counter("fleet.dropped"),
        deadline_misses: metrics.counter("fleet.deadline_misses"),
    }
}
