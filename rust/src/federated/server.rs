//! The FedAvg server — Algorithm 1 of the paper.
//!
//! ```text
//! initialize w_0
//! for each round t = 1, 2, ...:
//!   m ← max(C·K, 1)
//!   S_t ← (random set of m clients)
//!   for each client k ∈ S_t in parallel:
//!     w_{t+1}^k ← ClientUpdate(k, w_t)
//!   w_{t+1} ← Σ_k (n_k/n) · w_{t+1}^k
//! ```
//!
//! The averaging weights use `n` = total examples across the *selected*
//! clients (the standard reading of Algorithm 1, since unselected clients
//! produce no update). FedSGD is exactly this loop with `E=1, B=∞`.

use crate::comms::{CommModel, CommSim, CommTotals};
use crate::compression::{dequantize, quantize, top_k, ErrorFeedback};
use crate::config::FedConfig;
use crate::data::rng::Rng;
use crate::data::Federated;
use crate::federated::client::{local_update, LocalSpec};
use crate::federated::sampler::ClientSampler;
use crate::metrics::LearningCurve;
use crate::params::{weighted_mean, ParamVec};
use crate::privacy::{clip, GaussianMechanism, SecureAggregator};
use crate::runtime::Engine;
use crate::telemetry::{RoundRecord, RunWriter};
use crate::Result;

/// Differential-privacy knobs (paper §4 future work; Abadi et al. recipe).
#[derive(Debug, Clone, Copy)]
pub struct DpConfig {
    /// per-client update L2 clip bound.
    pub clip_norm: f64,
    /// Gaussian noise multiplier σ.
    pub sigma: f64,
}

/// Uplink compression knobs (Konečný et al. follow-up).
#[derive(Debug, Clone, Copy, Default)]
pub struct CompressionConfig {
    /// keep this fraction of coordinates by magnitude (with server-side
    /// error feedback), e.g. 0.01.
    pub top_k_frac: Option<f64>,
    /// quantize kept values to this many bits (1..=8), stochastic.
    pub quant_bits: Option<u8>,
}

/// Harness options orthogonal to the algorithm itself.
pub struct ServerOptions {
    pub telemetry: Option<RunWriter>,
    pub comm_model: CommModel,
    /// client online-probability per round (None = always available).
    pub availability: Option<f64>,
    /// evaluate on at most this many test examples (None = all).
    pub eval_cap: Option<usize>,
    /// evaluate training loss on at most this many examples.
    pub train_eval_cap: usize,
    /// differentially-private aggregation (clip + Gaussian noise).
    pub dp: Option<DpConfig>,
    /// aggregate via pairwise-mask secure aggregation (server never sees
    /// an individual update).
    pub secure_agg: bool,
    /// compress client uplinks (exact byte accounting in `comm`).
    pub compression: Option<CompressionConfig>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            telemetry: None,
            comm_model: CommModel::default(),
            availability: None,
            eval_cap: None,
            train_eval_cap: 2000,
            dp: None,
            secure_agg: false,
            compression: None,
        }
    }
}

/// Everything a finished run reports.
pub struct RunResult {
    /// (ε, δ=1e-5) consumed, if DP was enabled.
    pub epsilon: Option<f64>,
    /// test accuracy by round (at eval cadence).
    pub accuracy: LearningCurve,
    /// test mean loss by round.
    pub test_loss: LearningCurve,
    /// training-set mean loss by round (if tracked).
    pub train_loss: Option<LearningCurve>,
    pub comm: CommTotals,
    pub final_theta: ParamVec,
    /// total client-side SGD steps executed (all rounds, all clients).
    pub client_steps: u64,
    /// rounds actually run (early stop shortens this).
    pub rounds_run: u64,
}

impl RunResult {
    pub fn final_accuracy(&self) -> f64 {
        self.accuracy.last_value().unwrap_or(0.0)
    }
}

/// Run FederatedAveraging (or FedSGD via `cfg.fedsgd()`).
pub fn run(
    engine: &Engine,
    fed: &Federated,
    cfg: &FedConfig,
    mut opts: ServerOptions,
) -> Result<RunResult> {
    let model = engine.model(&cfg.model)?;
    anyhow::ensure!(
        fed.train.is_tokens() == model.meta().is_tokens(),
        "dataset kind {:?} does not match model {} kind {:?}",
        fed.train.name,
        cfg.model,
        model.meta().kind
    );
    let k = fed.num_clients();
    let mut theta: ParamVec = model.init(cfg.seed as i32)?;
    let mut sampler = ClientSampler::new(cfg.seed);
    if let Some(p) = opts.availability {
        sampler = sampler.with_availability(p, cfg.seed ^ 0xAB1E);
    }
    let mut comms = CommSim::new(opts.comm_model.clone(), cfg.seed);
    let model_bytes = crate::comms::model_bytes(model.param_count());

    let mut accuracy = LearningCurve::new();
    let mut test_loss = LearningCurve::new();
    let mut train_loss_curve = if cfg.track_train_loss {
        Some(LearningCurve::new())
    } else {
        None
    };
    let mut client_steps = 0u64;
    let mut rounds_run = 0u64;
    let mut mech = opts
        .dp
        .map(|d| GaussianMechanism::new(d.clip_norm, d.sigma, cfg.seed ^ 0xD11F));
    let sec_agg = opts.secure_agg.then(|| SecureAggregator::new(cfg.seed ^ 0x5EC));
    // per-client error feedback for top-k sparsification
    let mut feedback: Vec<ErrorFeedback> = vec![ErrorFeedback::default(); k];
    let mut qrng = Rng::new(cfg.seed ^ 0x0_B175);

    let eval_idxs: Option<Vec<usize>> = opts
        .eval_cap
        .map(|cap| (0..fed.test.len().min(cap)).collect());
    // training-loss eval subset: spread across clients
    let train_eval_idxs: Vec<usize> = {
        let total = fed.total_examples();
        let stride = (total / opts.train_eval_cap.max(1)).max(1);
        fed.clients
            .iter()
            .flatten()
            .copied()
            .step_by(stride)
            .take(opts.train_eval_cap)
            .collect()
    };

    for round in 1..=cfg.rounds as u64 {
        rounds_run = round;
        let m = cfg.clients_per_round(k);
        let picks = sampler.sample(round, k, m);
        let lr = (cfg.lr * cfg.lr_decay.powi(round as i32 - 1)) as f32;

        // ClientUpdate for each selected client (sequential on this
        // single-core testbed; the pool topology is exercised in tests).
        // Updates travel as DELTAS (θ_k − θ_t): identical average, and the
        // natural unit for clipping / compression / secure aggregation.
        let mut deltas: Vec<(f32, ParamVec)> = Vec::with_capacity(picks.len());
        let mut wire_up_bytes = 0u64;
        for &ck in &picks {
            let spec = LocalSpec {
                epochs: cfg.e,
                batch: cfg.b,
                lr,
                shuffle_seed: cfg.seed
                    ^ round.wrapping_mul(0x9E3779B97F4A7C15)
                    ^ (ck as u64).wrapping_mul(0xD1B54A32D192ED03),
            };
            let res = local_update(&model, &fed.train, &fed.clients[ck], &theta, &spec)?;
            client_steps += res.steps;
            let mut delta = res.theta;
            for (d, t) in delta.iter_mut().zip(&theta) {
                *d -= *t;
            }
            if let Some(dp) = &opts.dp {
                clip(&mut delta, dp.clip_norm);
            }
            if let Some(cmp) = &opts.compression {
                let mut bytes = model_bytes;
                if let Some(frac) = cmp.top_k_frac {
                    let kk = ((delta.len() as f64 * frac).ceil() as usize).max(1);
                    feedback[ck].fold_in(&mut delta);
                    let sparse = top_k(&delta, kk);
                    feedback[ck].record(&delta, &sparse);
                    bytes = sparse.wire_bytes();
                    delta = sparse.densify();
                }
                if let Some(bits) = cmp.quant_bits {
                    let q = quantize(&delta, bits, &mut qrng);
                    // top-k already paid index bytes; quantization shrinks
                    // the value payload
                    bytes = bytes.min(q.wire_bytes());
                    delta = dequantize(&q);
                }
                wire_up_bytes += bytes;
            } else {
                wire_up_bytes += model_bytes;
            }
            deltas.push((res.weight as f32, delta));
        }

        // w_{t+1} ← w_t + Σ (n_k / n) Δ^k
        let mut avg_delta: ParamVec = if let Some(agg) = &sec_agg {
            // clients upload masked fixed-point (w·Δ ‖ w); server only
            // ever sees the modular sum
            let total_w: f64 = deltas.iter().map(|(w, _)| *w as f64).sum();
            let masked: Vec<Vec<u32>> = deltas
                .iter()
                .enumerate()
                .map(|(i, (w, d))| {
                    let mut payload: Vec<f32> = d.iter().map(|v| v * *w / total_w as f32).collect();
                    payload.push(*w);
                    agg.mask(picks[i], &picks, &payload)
                })
                .collect();
            let mut summed = agg.aggregate(&masked);
            summed.pop(); // total weight slot (available to the server)
            summed
        } else {
            let refs: Vec<(f32, &[f32])> = deltas
                .iter()
                .map(|(w, d)| (*w, d.as_slice()))
                .collect();
            weighted_mean(&refs)
        };
        if let Some(mech) = mech.as_mut() {
            mech.apply(&mut avg_delta, picks.len());
        }
        crate::params::axpy(&mut theta, 1.0, &avg_delta);
        let rc = comms.round_asym(
            picks.len(),
            model_bytes,
            wire_up_bytes / picks.len().max(1) as u64,
        );

        if round % cfg.eval_every as u64 == 0 || round == cfg.rounds as u64 {
            let sums = model.eval_dataset(&theta, &fed.test, eval_idxs.as_deref())?;
            accuracy.push(round, sums.accuracy());
            test_loss.push(round, sums.mean_loss());
            let tl = if let Some(curve) = train_loss_curve.as_mut() {
                let ts = model.eval_dataset(&theta, &fed.train, Some(&train_eval_idxs))?;
                curve.push(round, ts.mean_loss());
                Some(ts.mean_loss())
            } else {
                None
            };
            if let Some(w) = opts.telemetry.as_mut() {
                w.record(&RoundRecord {
                    round,
                    test_accuracy: sums.accuracy(),
                    test_loss: sums.mean_loss(),
                    train_loss: tl,
                    clients: picks.len(),
                    lr: lr as f64,
                    bytes_up: rc.bytes_up,
                    sim_seconds: comms.totals().sim_seconds,
                })?;
            }
            if let Some(target) = cfg.target_accuracy {
                if sums.accuracy() >= target {
                    break;
                }
            }
        }
    }

    if let Some(w) = opts.telemetry.take() {
        let totals = comms.totals();
        w.finish(&[
            ("model", cfg.model.clone()),
            ("label", cfg.label()),
            ("rounds_run", rounds_run.to_string()),
            ("client_steps", client_steps.to_string()),
            ("final_accuracy", format!("{:.6}", accuracy.last_value().unwrap_or(0.0))),
            ("bytes_up", totals.bytes_up.to_string()),
            ("sim_seconds", format!("{:.1}", totals.sim_seconds)),
        ])?;
    }

    Ok(RunResult {
        epsilon: mech.as_ref().map(|m| m.epsilon(1e-5)),
        accuracy,
        test_loss,
        train_loss: train_loss_curve,
        comm: comms.totals(),
        final_theta: theta,
        client_steps,
        rounds_run,
    })
}
