//! Experiment configuration: typed configs plus a small key=value config
//! file format (`configs/*.cfg`; offline image — no toml crate).
//!
//! The same [`FedConfig`] drives FedAvg, FedSGD (a fixed point of the
//! family: `E=1, B=∞`), and the experiment harnesses. `ScaleProfile`
//! shrinks the paper-scale workloads to this single-core testbed while
//! preserving their structure (client counts scale, partition shapes and
//! algorithm knobs do not).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context};

use crate::Result;

/// Local batch-size knob `B` — `Full` is the paper's `B = ∞`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchSize {
    Fixed(usize),
    Full,
}

impl BatchSize {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "inf" | "full" | "∞" => Ok(BatchSize::Full),
            _ => Ok(BatchSize::Fixed(
                s.parse().map_err(|_| anyhow!("bad batch size {s:?}"))?,
            )),
        }
    }

    pub fn label(&self) -> String {
        match self {
            BatchSize::Full => "inf".to_string(),
            BatchSize::Fixed(b) => b.to_string(),
        }
    }
}

/// How the training data is spread over clients (paper §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    Iid,
    /// sort-by-label shards; the field is shards per client (2 = paper's
    /// pathological MNIST split).
    Pathological(usize),
    /// Zipf-unbalanced IID-content shards.
    Unbalanced,
    /// the dataset's natural grouping (Shakespeare roles, social authors).
    Natural,
}

impl Partition {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "iid" => Ok(Partition::Iid),
            "noniid" | "pathological" => Ok(Partition::Pathological(2)),
            "unbalanced" => Ok(Partition::Unbalanced),
            "natural" => Ok(Partition::Natural),
            _ => bail!("unknown partition {s:?} (iid|noniid|unbalanced|natural)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Partition::Iid => "iid",
            Partition::Pathological(_) => "noniid",
            Partition::Unbalanced => "unbalanced",
            Partition::Natural => "natural",
        }
    }
}

/// One federated training configuration (Algorithm 1's knobs + harness).
#[derive(Debug, Clone)]
pub struct FedConfig {
    pub model: String,
    /// client fraction per round (C); 0.0 means "one client per round".
    pub c: f64,
    /// local epochs (E).
    pub e: usize,
    /// local minibatch size (B).
    pub b: BatchSize,
    /// learning rate η.
    pub lr: f64,
    /// multiplicative per-round lr decay (1.0 = none; Table 3 uses 0.99…).
    pub lr_decay: f64,
    /// max communication rounds.
    pub rounds: usize,
    /// evaluate every this many rounds (1 = every round).
    pub eval_every: usize,
    /// stop early once test accuracy reaches this (None = run all rounds).
    pub target_accuracy: Option<f64>,
    /// also record training loss each eval (Figures 6/8).
    pub track_train_loss: bool,
    pub seed: u64,
}

impl Default for FedConfig {
    fn default() -> Self {
        Self {
            model: "mnist_2nn".into(),
            c: 0.1,
            e: 1,
            b: BatchSize::Fixed(10),
            lr: 0.1,
            lr_decay: 1.0,
            rounds: 100,
            eval_every: 1,
            target_accuracy: None,
            track_train_loss: false,
            seed: 17,
        }
    }
}

impl FedConfig {
    /// FedSGD is the `E=1, B=∞` endpoint of the FedAvg family (paper §2).
    pub fn fedsgd(mut self) -> Self {
        self.e = 1;
        self.b = BatchSize::Full;
        self
    }

    /// `m = max(C·K, 1)` — Algorithm 1's per-round client count.
    pub fn clients_per_round(&self, k: usize) -> usize {
        ((self.c * k as f64) as usize).max(1).min(k)
    }

    pub fn label(&self) -> String {
        format!(
            "{} C={} E={} B={} lr={}",
            self.model,
            self.c,
            self.e,
            self.b.label(),
            self.lr
        )
    }
}

/// Scales paper-sized workloads down to the testbed. `scale=1.0` is the
/// paper's configuration; the experiment harnesses default lower.
#[derive(Debug, Clone, Copy)]
pub struct ScaleProfile {
    pub scale: f64,
}

impl ScaleProfile {
    pub fn new(scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0, "scale in (0,1]: {scale}");
        Self { scale }
    }

    /// Scaled count with a floor.
    pub fn count(&self, paper: usize, min: usize) -> usize {
        ((paper as f64 * self.scale) as usize).max(min)
    }
}

/// Flat key=value config files (sections via `a.b.c = v`), `#` comments.
#[derive(Debug, Clone, Default)]
pub struct ConfigFile {
    values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", ln + 1))?;
            values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Self { values })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed getter: parse `key`'s value if present (`Ok(None)` when the
    /// key is absent, an error naming the key on a malformed value).
    /// Used by config consumers outside [`FedConfig`] — e.g. the
    /// aggregation keys (`agg`, `server_lr`, `server_momentum`,
    /// `prox_mu`) read by `federated::aggregate::AggConfig::from_config`.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow!("config key {key}: bad value {v:?}")),
        }
    }

    /// The [`FedConfig`]-shaped keys. Keys owned by other subsystems are
    /// ignored here and read by their own consumers: the aggregation
    /// keys (`agg`, `server_lr`, `server_momentum`, `prox_mu`) by
    /// `AggConfig::from_config`, the checkpoint keys (`checkpoint_every`,
    /// `checkpoint_keep` — see `crate::runstate`, DESIGN.md §8) by the
    /// CLI layer, and dataset keys by the harness.
    pub fn fed_config(&self) -> Result<FedConfig> {
        let mut cfg = FedConfig::default();
        for (k, v) in &self.values {
            match k.as_str() {
                "model" => cfg.model = v.clone(),
                "c" => cfg.c = v.parse()?,
                "e" => cfg.e = v.parse()?,
                "b" => cfg.b = BatchSize::parse(v)?,
                "lr" => cfg.lr = v.parse()?,
                "lr_decay" => cfg.lr_decay = v.parse()?,
                "rounds" => cfg.rounds = v.parse()?,
                "eval_every" => cfg.eval_every = v.parse()?,
                "target_accuracy" => cfg.target_accuracy = Some(v.parse()?),
                "track_train_loss" => cfg.track_train_loss = v.parse()?,
                "seed" => cfg.seed = v.parse()?,
                _ => {} // dataset keys handled by the harness
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_size_parse() {
        assert_eq!(BatchSize::parse("10").unwrap(), BatchSize::Fixed(10));
        assert_eq!(BatchSize::parse("inf").unwrap(), BatchSize::Full);
        assert!(BatchSize::parse("ten").is_err());
    }

    #[test]
    fn clients_per_round_matches_algorithm1() {
        let mut cfg = FedConfig::default();
        for (c, k, want) in [
            (0.0, 100, 1),  // paper: C=0 means one client
            (0.1, 100, 10),
            (0.2, 100, 20),
            (1.0, 100, 100),
            (0.5, 3, 1),
            (1.0, 1, 1),
        ] {
            cfg.c = c;
            assert_eq!(cfg.clients_per_round(k), want, "C={c} K={k}");
        }
    }

    #[test]
    fn fedsgd_is_family_endpoint() {
        let cfg = FedConfig {
            e: 20,
            b: BatchSize::Fixed(10),
            ..Default::default()
        }
        .fedsgd();
        assert_eq!(cfg.e, 1);
        assert_eq!(cfg.b, BatchSize::Full);
    }

    #[test]
    fn config_file_roundtrip() {
        let cf = ConfigFile::parse(
            "# experiment\nmodel = mnist_cnn\nc = 0.2\ne=5\nb = inf\nlr = 0.05 # swept\nrounds = 42\ntarget_accuracy = 0.97\n",
        )
        .unwrap();
        let fc = cf.fed_config().unwrap();
        assert_eq!(fc.model, "mnist_cnn");
        assert_eq!(fc.c, 0.2);
        assert_eq!(fc.e, 5);
        assert_eq!(fc.b, BatchSize::Full);
        assert_eq!(fc.rounds, 42);
        assert_eq!(fc.target_accuracy, Some(0.97));
    }

    #[test]
    fn config_file_rejects_bad_lines() {
        assert!(ConfigFile::parse("model mnist").is_err());
    }

    #[test]
    fn config_file_typed_getter() {
        let cf = ConfigFile::parse("server_lr = 0.5\nrounds = 40\n").unwrap();
        assert_eq!(cf.get_parse::<f64>("server_lr").unwrap(), Some(0.5));
        assert_eq!(cf.get_parse::<usize>("rounds").unwrap(), Some(40));
        assert_eq!(cf.get_parse::<f64>("absent").unwrap(), None);
        assert!(cf.get_parse::<usize>("server_lr").is_err());
    }

    #[test]
    fn scale_profile() {
        let s = ScaleProfile::new(0.2);
        assert_eq!(s.count(100, 10), 20);
        assert_eq!(s.count(20, 10), 10); // floor
    }
}
