//! In-tree utilities replacing crates unavailable in this offline image
//! (serde → [`json`] for output, [`bytes`] for binary state; clap →
//! [`args`], criterion → [`bench`]).

pub mod args;
pub mod bench;
pub mod bytes;
pub mod json;
