//! In-tree utilities replacing crates unavailable in this offline image
//! (serde → [`json`], clap → [`args`], criterion → [`bench`]).

pub mod args;
pub mod bench;
pub mod json;
