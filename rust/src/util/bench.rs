//! Micro-bench harness (offline image: no criterion).
//!
//! Criterion-style methodology, hand-rolled: warmup, then timed batches
//! until a wall-clock budget is spent; reports mean / p50 / p95 per
//! iteration with simple jackknife-free robustness (median over batches).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// tail quantiles recorded into `BENCH_<area>.json` snapshots
    /// (DESIGN.md §10)
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// throughput hint: elements (or bytes) per iteration, if set
    pub elems_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<42} {:>10} it  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
        if let Some(e) = self.elems_per_iter {
            let per_s = e / (self.mean_ns / 1e9);
            s.push_str(&format!("  ({} elem/s)", fmt_rate(per_s)));
        }
        s
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.1}k", r / 1e3)
    } else {
        format!("{r:.1}")
    }
}

pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(Duration::from_millis(300), Duration::from_secs(2))
    }
}

impl Bencher {
    pub fn new(warmup: Duration, budget: Duration) -> Self {
        Self {
            warmup,
            budget,
            results: Vec::new(),
        }
    }

    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Self::new(Duration::from_millis(50), Duration::from_millis(700))
    }

    /// Time `f`, which performs ONE iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_elems(name, None, &mut f)
    }

    /// Like [`bench`], reporting `elems` units of work per iteration.
    pub fn bench_elems<F: FnMut()>(&mut self, name: &str, elems: f64, mut f: F) -> &BenchResult {
        self.bench_with_elems(name, Some(elems), &mut f)
    }

    #[allow(clippy::disallowed_methods)] // Instant::now: measuring wall time is this harness's whole job
    fn bench_with_elems(
        &mut self,
        name: &str,
        elems: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        // warmup
        // lint:allow(wall-clock): the bench harness exists to measure wall time; results go to BENCH_*.json, never into a run.
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed() < self.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
        }
        // estimate per-iter cost from warmup to choose batch size
        let per_iter = t0.elapsed().as_nanos() as f64 / warm_iters as f64;
        let batch = ((5e6 / per_iter).ceil() as u64).clamp(1, 10_000);

        let mut samples: Vec<f64> = Vec::new(); // per-iteration ns, per batch
        let mut iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.budget || samples.is_empty() {
            let b0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(b0.elapsed().as_nanos() as f64 / batch as f64);
            iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // lint:allow(float-fold): wall-clock measurement summary — bench reporting never participates in a training trajectory.
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let quantile =
            |frac: f64| samples[((samples.len() as f64 * frac) as usize).min(samples.len() - 1)];
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: samples[samples.len() / 2],
            p95_ns: quantile(0.95),
            p10_ns: quantile(0.10),
            p90_ns: quantile(0.90),
            elems_per_iter: elems,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(Duration::from_millis(5), Duration::from_millis(30));
        let mut acc = 0u64;
        let r = b
            .bench("spin", || {
                for i in 0..100u64 {
                    acc = acc.wrapping_add(i * i);
                }
                std::hint::black_box(acc);
            })
            .clone();
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns * 0.5);
        assert!(r.p10_ns <= r.p50_ns && r.p50_ns <= r.p90_ns);
    }

    #[test]
    fn format_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
