//! Minimal JSON parser (offline image: no serde) — just enough for
//! `artifacts/manifest.json` and telemetry output. Supports the full JSON
//! value grammar minus exotic number forms; strings handle the standard
//! escapes (`\" \\ \/ \b \f \n \r \t \uXXXX`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::Result;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("get({key:?}) on non-object"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
            _ => bail!("expected non-negative integer"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number"),
        }
    }
}

/// Escape a string for JSON output (telemetry writers).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // raw UTF-8 passthrough: collect continuation bytes
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"models":{"m":{"param_count":199210,"step_batches":[10,50],
                "entries":{"init":{"file":"m.init.hlo.txt"}}}}}"#,
        )
        .unwrap();
        let m = j.get("models").unwrap().get("m").unwrap();
        assert_eq!(m.get("param_count").unwrap().as_usize().unwrap(), 199210);
        let bs: Vec<usize> = m
            .get("step_batches")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(bs, vec![10, 50]);
    }

    #[test]
    fn parses_scalars_arrays_nesting() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#"[1, [2, {"a": 3}]]"#).unwrap(),
            Json::Arr(vec![
                Json::Num(1.0),
                Json::Arr(vec![
                    Json::Num(2.0),
                    Json::Obj([("a".to_string(), Json::Num(3.0))].into_iter().collect())
                ])
            ])
        );
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j, Json::Str("a\"b\\c\ndA".into()));
        assert_eq!(escape("a\"b\\c\nd"), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo — ok""#).unwrap();
        assert_eq!(j, Json::Str("héllo — ok".into()));
    }
}
