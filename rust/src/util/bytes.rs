//! Little-endian byte encoding primitives (offline image: no serde).
//!
//! Shared by the run-state snapshot format ([`crate::runstate`]) and the
//! opaque per-subsystem state blobs it embeds (e.g. the server-optimizer
//! moments behind [`Aggregator::state_save`]). Writes are infallible;
//! every read is bounds-checked and returns an error — never a panic —
//! on truncated input, which is what lets a torn snapshot be *rejected*
//! instead of half-loaded (DESIGN.md §8).
//!
//! [`Aggregator::state_save`]: crate::federated::aggregate::Aggregator::state_save

use anyhow::ensure;

use crate::Result;

/// Append-only little-endian encoder.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_inner(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Length-prefixed raw bytes (u64 count + payload).
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Length-prefixed f32 vector (u64 count + LE f32 payload).
    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed u64 vector.
    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_u64(v.len() as u64);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Bounds-checked little-endian decoder over a borrowed buffer.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Borrow the next `n` bytes, erroring (not panicking) past the end.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "truncated buffer: wanted {n} bytes at offset {}, {} left",
            self.pos,
            self.remaining()
        );
        // lint:allow(panic-surface): range just proven in-bounds by the ensure! above.
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        // lint:allow(panic-surface): take(2) returned exactly 2 bytes, so the array conversion is infallible.
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        // lint:allow(panic-surface): take(4) returned exactly 4 bytes, so the array conversion is infallible.
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        // lint:allow(panic-surface): take(8) returned exactly 8 bytes, so the array conversion is infallible.
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Length-prefixed count, sanity-bounded so a corrupt length cannot
    /// drive an allocation past the buffer it claims to describe.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        ensure!(
            n.checked_mul(elem_bytes).map_or(false, |b| b <= self.remaining()),
            "corrupt length prefix: {n} x {elem_bytes}B elements but only {} bytes left",
            self.remaining()
        );
        Ok(n)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.len_prefix(1)?;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes()?;
        Ok(std::str::from_utf8(b)
            .map_err(|e| anyhow::anyhow!("non-UTF-8 string in buffer: {e}"))?
            .to_string())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            // lint:allow(panic-surface): chunks_exact(4) yields only 4-byte slices.
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.len_prefix(8)?;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            // lint:allow(panic-surface): chunks_exact(8) yields only 8-byte slices.
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Assert the buffer is fully consumed — trailing garbage means the
    /// encoder and decoder disagree, which must fail loudly.
    pub fn expect_end(&self) -> Result<()> {
        ensure!(
            self.is_empty(),
            "{} trailing bytes after decode",
            self.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_primitives() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u16(65535);
        w.put_u64(u64::MAX - 1);
        w.put_f64(-0.125);
        w.put_bytes(b"blob");
        w.put_str("naïve");
        w.put_f32s(&[1.5, -2.25, 0.0]);
        w.put_u64s(&[9, 8]);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 65535);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f64().unwrap(), -0.125);
        assert_eq!(r.bytes().unwrap(), b"blob");
        assert_eq!(r.str().unwrap(), "naïve");
        assert_eq!(r.f32s().unwrap(), vec![1.5, -2.25, 0.0]);
        assert_eq!(r.u64s().unwrap(), vec![9, 8]);
        r.expect_end().unwrap();
    }

    #[test]
    fn truncation_errors_instead_of_panicking() {
        let mut w = ByteWriter::new();
        w.put_f32s(&[1.0, 2.0, 3.0]);
        let buf = w.into_inner();
        // every proper prefix must fail cleanly
        for cut in 0..buf.len() {
            let mut r = ByteReader::new(&buf[..cut]);
            assert!(r.f32s().is_err(), "prefix of {cut} bytes decoded");
        }
        // a lying length prefix is caught before allocation
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX); // claims 2^64-1 elements
        let buf = w.into_inner();
        assert!(ByteReader::new(&buf).f32s().is_err());
    }

    #[test]
    fn expect_end_rejects_trailing_garbage() {
        let mut w = ByteWriter::new();
        w.put_u8(1);
        w.put_u8(2);
        let buf = w.into_inner();
        let mut r = ByteReader::new(&buf);
        r.u8().unwrap();
        assert!(r.expect_end().is_err());
        r.u8().unwrap();
        r.expect_end().unwrap();
    }
}
