//! Tiny CLI argument helper (offline image: no clap).
//!
//! Parses `--key value`, `--key=value` and `--flag` forms plus positional
//! arguments, with typed getters and an unknown-flag check.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::Result;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `--key v`, `--key=v`,
    /// bare `--flag` (value "true"), positionals otherwise.
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse process args (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad number {v:?}")),
        }
    }

    /// Like [`f64_or`](Self::f64_or) but with no default: `Ok(None)`
    /// when the flag is absent (for knobs whose default is resolved
    /// downstream, e.g. the per-rule `--server-lr`).
    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow!("--{key}: bad number {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad integer {v:?}")),
        }
    }

    /// Error out on flags not in `known` (catches typos in experiment
    /// invocations, where a silently-ignored flag wastes a long run).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn forms() {
        let a = parse("table1 --scale 0.1 --model=mnist_2nn --verbose --rounds 20");
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.f64_or("scale", 1.0).unwrap(), 0.1);
        assert_eq!(a.str_or("model", "x"), "mnist_2nn");
        assert!(a.has("verbose"));
        assert_eq!(a.usize_or("rounds", 5).unwrap(), 20);
        assert_eq!(a.usize_or("absent", 5).unwrap(), 5);
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("--scal 0.1");
        assert!(a.check_known(&["scale"]).is_err());
        assert!(a.check_known(&["scal"]).is_ok());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("--rounds ten");
        assert!(a.usize_or("rounds", 1).is_err());
    }

    #[test]
    fn f64_opt_absent_present_bad() {
        let a = parse("--server-lr 0.5");
        assert_eq!(a.f64_opt("server-lr").unwrap(), Some(0.5));
        assert_eq!(a.f64_opt("absent").unwrap(), None);
        assert!(parse("--server-lr fast").f64_opt("server-lr").is_err());
    }
}
