//! The server's transport endpoint: versioned model store, delta
//! downlink, uplink codec with error feedback — and the **single source
//! of wire-byte truth** (DESIGN.md §6).
//!
//! Every byte the federated server meters flows through [`Transport`]:
//!
//! * **Downlink** — [`Transport::downlink`] prices the broadcast to one
//!   client. With a `delta` downlink codec, the server consults the
//!   [`ModelStore`] for the last version that client acked and ships an
//!   overwrite patch against it; when the ack aged out of the store (or
//!   the patch would not be smaller) it falls back to a dense frame.
//!   Downlink bytes therefore scale with round-to-round model change,
//!   not model size.
//! * **Uplink** — [`Transport::up_plan_bytes`] prices a client upload
//!   *before* training (the fleet scheduler needs durations up front)
//!   and [`Transport::encode_up`] later encodes the real update through
//!   the same pipeline, producing exactly the priced byte count: the
//!   scheduler's estimate and the telemetry-reported wire bytes cannot
//!   drift apart.
//!
//! Error feedback for sparsifying uplink codecs is keyed per client and
//! advances **only** in [`Transport::encode_up`] — which the server
//! calls only for updates that are actually aggregated. A client whose
//! update was straggler-dropped by the scheduler never reaches the wire,
//! so its residual must not change: the dropped mass was never
//! delivered, and folding it in anyway would double-count once the
//! client retrains from a newer model (regression-tested in
//! `rust/tests/transport_wire.rs`).

use std::collections::VecDeque;

use crate::comms::wire::Pipeline;
use crate::compression::ErrorFeedback;
use crate::data::rng::{Rng, RngState};
use crate::params::ParamVec;
use crate::Result;

/// The transport's complete inter-round mutable state, as captured by a
/// run-state snapshot (`crate::runstate`, DESIGN.md §8): the quantizer's
/// stochastic-rounding stream, every client's error-feedback residual,
/// and the model store's retained version ring + per-client acks.
/// Within-round scratch (pending delta bases, the per-round measure
/// memo) is intentionally absent: snapshots are taken between rounds,
/// where it is dead state that the next `downlink` call rebuilds.
#[derive(Debug, Clone, PartialEq)]
pub struct TransportState {
    pub rng: RngState,
    /// Per-client uplink residual (empty vec = pristine, no feedback yet).
    pub feedback: Vec<Vec<f32>>,
    /// Retained `(version, model)` ring, oldest first.
    pub versions: Vec<(u64, ParamVec)>,
    /// Per-client last-acked version (0 = never contacted).
    pub acked: Vec<u64>,
}

/// Ring of recently published model versions plus per-client ack state —
/// what makes the delta downlink possible.
pub struct ModelStore {
    cap: usize,
    versions: VecDeque<(u64, ParamVec)>,
    acked: Vec<u64>,
}

impl ModelStore {
    /// Store retaining at most `cap` versions for `num_clients` clients
    /// (ack version 0 = "never received anything").
    pub fn new(num_clients: usize, cap: usize) -> ModelStore {
        assert!(cap >= 1, "model store needs at least one version slot");
        ModelStore {
            cap,
            versions: VecDeque::new(),
            acked: vec![0; num_clients],
        }
    }

    /// Publish `theta` as `version` (strictly increasing), evicting the
    /// oldest retained version beyond capacity.
    pub fn publish(&mut self, version: u64, theta: &[f32]) {
        assert!(
            version > self.latest_version(),
            "model versions must increase: {} after {}",
            version,
            self.latest_version()
        );
        // lint:allow(hot-alloc): the ModelStore is the one sanctioned owned-conversion boundary — retained versions must outlive the caller's buffer (DESIGN.md §14).
        self.versions.push_back((version, theta.to_vec()));
        while self.versions.len() > self.cap {
            self.versions.pop_front();
        }
    }

    /// Most recently published version (0 when empty).
    pub fn latest_version(&self) -> u64 {
        self.versions.back().map(|(v, _)| *v).unwrap_or(0)
    }

    /// The retained model for `version`, unless it aged out.
    pub fn get(&self, version: u64) -> Option<&[f32]> {
        self.versions
            .iter()
            .find(|(v, _)| *v == version)
            .map(|(_, t)| t.as_slice())
    }

    /// Last version `client` received (0 = never).
    pub fn acked(&self, client: usize) -> u64 {
        self.acked[client]
    }

    pub fn ack(&mut self, client: usize, version: u64) {
        self.acked[client] = version;
    }

    /// Number of versions currently retained.
    pub fn retained(&self) -> usize {
        self.versions.len()
    }
}

/// Codec configuration for a run, carried in
/// [`ServerOptions`](crate::federated::ServerOptions). The default (no
/// pipelines) is the legacy unframed-dense path, bit-identical to the
/// pre-transport byte accounting.
#[derive(Debug, Clone)]
pub struct TransportConfig {
    /// Uplink codec (client update → server); `None` = unframed dense.
    pub up: Option<Pipeline>,
    /// Downlink codec (server model → client); `None` = unframed dense.
    pub down: Option<Pipeline>,
    /// Model versions the store retains for delta downlinks; clients
    /// whose ack aged out get a dense fallback broadcast.
    pub store_cap: usize,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            up: None,
            down: None,
            store_cap: 8,
        }
    }
}

impl TransportConfig {
    /// Parse CLI specs: `--codec` (uplink) and `--down-codec` (downlink).
    pub fn parse(up: Option<&str>, down: Option<&str>) -> Result<TransportConfig> {
        let up = up.map(Pipeline::parse).transpose()?;
        let down = down.map(Pipeline::parse).transpose()?;
        if let Some(p) = &up {
            anyhow::ensure!(
                !p.has_delta(),
                "uplink codec {p:?}: client updates already travel as deltas \
                 against the broadcast model; `delta` is a downlink stage"
            );
        }
        if let Some(p) = &down {
            anyhow::ensure!(
                !p.has_topk() || p.has_delta(),
                "downlink codec {p:?}: `topk` needs a `delta` base — sparsifying \
                 a full model broadcast would zero every unsent coordinate"
            );
        }
        Ok(TransportConfig {
            up,
            down,
            ..Default::default()
        })
    }

    /// True when any codec is configured (the transport replaces the
    /// legacy byte accounting).
    pub fn active(&self) -> bool {
        self.up.is_some() || self.down.is_some()
    }
}

/// Per-run transport endpoint: owns the codec pipelines, the model
/// store, the per-client error feedback, and the quantizer's
/// stochastic-rounding stream.
pub struct Transport {
    cfg: TransportConfig,
    dim: usize,
    /// Legacy unframed-dense size (`4·dim`), used whenever a direction
    /// has no codec.
    dense_bytes: u64,
    store: ModelStore,
    feedback: Vec<ErrorFeedback>,
    rng: Rng,
    /// Per-client base version of this round's downlink frame
    /// (0 = dense broadcast), recorded by [`downlink`](Self::downlink)
    /// for [`downlink_model`](Self::downlink_model).
    pending_base: Vec<u64>,
    /// Round the memo below is valid for.
    cache_version: u64,
    /// Per-round memo of delta-frame sizes keyed by base version: the
    /// patch depends only on `(theta, base)`, so clients sharing an
    /// acked version share one O(dim) scan (at most `store_cap` distinct
    /// bases exist per round).
    measure_cache: Vec<(u64, u64)>,
    /// Uplink decode scratch for [`encode_up`](Self::encode_up): the
    /// decoded update lands here and is swapped into the caller's
    /// `delta`, so the hot path reuses one buffer per endpoint instead
    /// of allocating per aggregated client (DESIGN.md §14). Within-round
    /// scratch — not part of [`TransportState`].
    up_scratch: ParamVec,
}

impl Transport {
    pub fn new(cfg: TransportConfig, num_clients: usize, dim: usize, seed: u64) -> Transport {
        let store = ModelStore::new(num_clients, cfg.store_cap.max(1));
        Transport {
            dense_bytes: 4 * dim as u64,
            store,
            feedback: vec![ErrorFeedback::default(); num_clients],
            // same domain separation as the seed implementation's
            // quantizer stream
            rng: Rng::new(seed ^ 0x0_B175),
            pending_base: vec![0; num_clients],
            cache_version: 0,
            // lint:allow(hot-alloc): one-time endpoint construction, not the round loop.
            measure_cache: Vec::new(),
            up_scratch: ParamVec::new(),
            cfg,
            dim,
        }
    }

    pub fn config(&self) -> &TransportConfig {
        &self.cfg
    }

    pub fn store(&self) -> &ModelStore {
        &self.store
    }

    /// Telemetry label: `"<up>/<down>"` specs, `dense/dense` when unset.
    pub fn codec_label(&self) -> String {
        let name = |p: &Option<Pipeline>| {
            p.as_ref().map(|p| p.spec().to_string()).unwrap_or_else(|| "dense".into())
        };
        format!("{}/{}", name(&self.cfg.up), name(&self.cfg.down))
    }

    /// Publish this round's model as `version` so later rounds can delta
    /// against it. No-op unless the downlink codec has a `delta` stage.
    pub fn publish(&mut self, version: u64, theta: &[f32]) {
        if self.cfg.down.as_ref().map_or(false, |d| d.has_delta()) {
            debug_assert_eq!(theta.len(), self.dim);
            self.store.publish(version, theta);
        }
    }

    /// Downlink wire bytes for `client` receiving `theta` (published as
    /// `version`), choosing delta vs dense fallback and recording the
    /// ack. This one number is both what the scheduler prices and what
    /// telemetry reports.
    pub fn downlink(&mut self, client: usize, version: u64, theta: &[f32]) -> u64 {
        debug_assert_eq!(theta.len(), self.dim);
        let Some(down) = &self.cfg.down else {
            self.pending_base[client] = 0;
            return self.dense_bytes;
        };
        let fallback = down.fallback_bytes(self.dim);
        let mut base_v = 0u64;
        let mut bytes = fallback;
        if down.has_delta() {
            if self.cache_version != version {
                self.cache_version = version;
                self.measure_cache.clear();
            }
            let acked = self.store.acked(client);
            if acked > 0 && acked < version {
                if let Some(base) = self.store.get(acked) {
                    let cached: Option<u64> = self
                        .measure_cache
                        .iter()
                        .find(|(v, _)| *v == acked)
                        .map(|&(_, b)| b);
                    let delta_bytes = match cached {
                        Some(b) => b,
                        None => {
                            let b = down
                                .measure(theta, Some(base))
                                .expect("transport invariant: store dims match the model");
                            self.measure_cache.push((acked, b));
                            b
                        }
                    };
                    if delta_bytes < fallback {
                        bytes = delta_bytes;
                        base_v = acked;
                    }
                }
            }
        }
        self.pending_base[client] = base_v;
        self.store.ack(client, version);
        bytes
    }

    /// The model `client` reconstructs from this round's downlink
    /// (decided by the preceding [`downlink`](Self::downlink) call) —
    /// `None` when it is bit-identical to `theta` (no downlink codec, or
    /// a lossless one: dense frames and pure `delta` patches reproduce
    /// the broadcast exactly), else the decoded approximation the client
    /// actually trains from.
    pub fn downlink_model(&mut self, client: usize, theta: &[f32]) -> Result<Option<ParamVec>> {
        let Some(down) = &self.cfg.down else {
            return Ok(None);
        };
        if down.lossless() {
            return Ok(None);
        }
        let base_v = self.pending_base[client];
        let decoded = if base_v == 0 {
            let repr = down.run_fallback(theta, &mut self.rng)?;
            repr.decode(None)?
        } else {
            let base = self
                .store
                .get(base_v)
                .ok_or_else(|| anyhow::anyhow!("base version {base_v} evicted mid-round"))?;
            let repr = down.run(theta, Some((base_v, base)), &mut self.rng)?;
            repr.decode(Some(base))?
        };
        Ok(Some(decoded))
    }

    /// Uplink planning size — what the scheduler prices a client upload
    /// at *before* it trains. Exactly equals the byte count
    /// [`encode_up`](Self::encode_up) later returns for the real payload.
    pub fn up_plan_bytes(&self) -> u64 {
        match &self.cfg.up {
            None => self.dense_bytes,
            Some(p) => p.plan_bytes(self.dim),
        }
    }

    /// Encode one **aggregated** client's update through the uplink
    /// codec: fold in the client's error-feedback residual (sparsifying
    /// pipelines only), run the stages, and replace `delta` with what
    /// the server decodes — i.e. what actually lands in the aggregate.
    /// Returns the exact wire bytes.
    ///
    /// Must only be called for updates that are aggregated this round:
    /// straggler-dropped updates never reach the wire, so their
    /// residuals must not advance (see the module docs).
    pub fn encode_up(&mut self, client: usize, delta: &mut ParamVec) -> Result<u64> {
        let Some(up) = &self.cfg.up else {
            return Ok(self.dense_bytes);
        };
        // error feedback corrects sparsification bias; quantization alone
        // is unbiased and gets none (matching the seed implementation)
        let use_ef = up.has_topk();
        if use_ef {
            self.feedback[client].fold_in(delta);
        }
        let repr = up.run(delta, None, &mut self.rng)?;
        let bytes = repr.wire_bytes();
        debug_assert_eq!(bytes, up.plan_bytes(self.dim), "estimate/actual drift");
        if !up.lossless() {
            // decode into the endpoint scratch and swap it with `delta`:
            // the same bits the owned decode produced, without a per-client
            // allocation (the old `delta` spine becomes next call's scratch)
            repr.decode_into(None, &mut self.up_scratch)?;
            if use_ef {
                self.feedback[client].record_dense(delta, &self.up_scratch);
            }
            std::mem::swap(delta, &mut self.up_scratch);
        }
        Ok(bytes)
    }

    /// L2 norm of `client`'s error-feedback residual (diagnostics, and
    /// the straggler-drop regression tests).
    pub fn residual_norm(&self, client: usize) -> f64 {
        self.feedback[client].residual_norm()
    }

    /// Total error-feedback residual mass across the fleet: the L2 norm
    /// of the concatenated per-client residuals. Feeds the
    /// `transport.ef_residual_l2` gauge when tracing is on (DESIGN.md
    /// §10); 0.0 without a sparsifying uplink codec. Full fleet scan —
    /// call at eval cadence, not per round.
    pub fn residual_l2_total(&self) -> f64 {
        self.feedback
            .iter()
            .map(|f| {
                let n = f.residual_norm();
                n * n
            })
            // lint:allow(float-fold): observability gauge only — never feeds back into the trajectory, and the fold order over client ids is itself fixed.
            .sum::<f64>()
            .sqrt()
    }

    /// Capture the endpoint's inter-round mutable state for a run-state
    /// snapshot (DESIGN.md §8).
    pub fn state_save(&self) -> TransportState {
        TransportState {
            rng: self.rng.state(),
            feedback: self
                .feedback
                .iter()
                // lint:allow(hot-alloc): snapshot capture runs between rounds at checkpoint cadence, never inside the round loop.
                .map(|f| f.residual().to_vec())
                .collect(),
            versions: self.store.versions.iter().cloned().collect(),
            // lint:allow(hot-alloc): snapshot capture runs between rounds at checkpoint cadence, never inside the round loop.
            acked: self.store.acked.clone(),
        }
    }

    /// Restore the state captured by [`state_save`](Self::state_save),
    /// validating every dimension against this endpoint's configuration
    /// before touching anything — a mismatched snapshot is rejected
    /// whole, never half-applied.
    pub fn state_load(&mut self, st: TransportState) -> Result<()> {
        let n = self.feedback.len();
        anyhow::ensure!(
            st.feedback.len() == n && st.acked.len() == n,
            "transport snapshot is for {} clients, endpoint has {n}",
            st.feedback.len().max(st.acked.len())
        );
        for (c, r) in st.feedback.iter().enumerate() {
            anyhow::ensure!(
                r.is_empty() || r.len() == self.dim,
                "client {c}: residual dim {} != model dim {}",
                r.len(),
                self.dim
            );
        }
        anyhow::ensure!(
            st.versions.len() <= self.store.cap,
            "snapshot retains {} model versions, store cap is {}",
            st.versions.len(),
            self.store.cap
        );
        let mut prev = 0u64;
        for (v, theta) in &st.versions {
            anyhow::ensure!(
                *v > prev && theta.len() == self.dim,
                "corrupt model-store ring: version {v} after {prev}, dim {}",
                theta.len()
            );
            prev = *v;
        }
        self.rng = Rng::from_state(st.rng);
        self.feedback = st.feedback.into_iter().map(ErrorFeedback::from_residual).collect();
        self.store.versions = st.versions.into();
        self.store.acked = st.acked;
        // within-round scratch: reset; the next downlink() rebuilds it
        self.pending_base = vec![0; n];
        self.cache_version = 0;
        self.measure_cache.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn theta(dim: usize, round: u64) -> Vec<f32> {
        // model that drifts a little every round: 5% of coords change
        (0..dim)
            .map(|i| {
                let changed = (i as u64 + round) % 20 == 0;
                i as f32 * 0.01 + if changed { round as f32 * 0.1 } else { 0.0 }
            })
            .collect()
    }

    fn delta_transport(store_cap: usize) -> Transport {
        let cfg = TransportConfig {
            up: None,
            down: Some(Pipeline::parse("delta").unwrap()),
            store_cap,
        };
        Transport::new(cfg, 4, 400, 1)
    }

    #[test]
    fn store_retains_cap_versions_and_evicts_oldest() {
        let mut s = ModelStore::new(2, 3);
        for v in 1..=5u64 {
            s.publish(v, &[v as f32; 4]);
        }
        assert_eq!(s.retained(), 3);
        assert_eq!(s.latest_version(), 5);
        assert!(s.get(2).is_none(), "evicted version still retained");
        assert_eq!(s.get(3).unwrap()[0], 3.0);
        s.ack(1, 5);
        assert_eq!(s.acked(1), 5);
        assert_eq!(s.acked(0), 0);
    }

    #[test]
    fn first_contact_is_dense_then_delta_shrinks() {
        let mut t = delta_transport(8);
        let t1 = theta(400, 1);
        t.publish(1, &t1);
        let dense = t.downlink(0, 1, &t1);
        assert_eq!(dense, 24 + 4 * 400, "first contact must be a dense frame");
        let t2 = theta(400, 2);
        t.publish(2, &t2);
        let delta = t.downlink(0, 2, &t2);
        assert!(delta < dense / 2, "delta downlink did not shrink: {delta} vs {dense}");
        // a client that never acked still gets dense
        assert_eq!(t.downlink(1, 2, &t2), dense);
    }

    #[test]
    fn aged_out_ack_falls_back_to_dense() {
        let mut t = delta_transport(2);
        let t1 = theta(400, 1);
        t.publish(1, &t1);
        t.downlink(0, 1, &t1); // client 0 acks v1
        for v in 2..=4u64 {
            let tv = theta(400, v);
            t.publish(v, &tv); // cap 2: v1 evicted once v3 lands
        }
        let t4 = theta(400, 4);
        assert_eq!(t.store().get(1), None);
        let bytes = t.downlink(0, 4, &t4);
        assert_eq!(bytes, 24 + 4 * 400, "aged-out ack must fall back to dense");
    }

    #[test]
    fn legacy_directions_price_unframed_dense() {
        let mut t = Transport::new(TransportConfig::default(), 2, 100, 3);
        let x = theta(100, 1);
        assert_eq!(t.downlink(0, 1, &x), 400);
        assert_eq!(t.up_plan_bytes(), 400);
        let mut d = x.clone();
        assert_eq!(t.encode_up(0, &mut d).unwrap(), 400);
        assert_eq!(d, x, "legacy uplink must not transform the update");
        assert_eq!(t.codec_label(), "dense/dense");
    }

    #[test]
    fn uplink_delta_stage_rejected() {
        assert!(TransportConfig::parse(Some("delta|q8"), None).is_err());
        assert!(TransportConfig::parse(Some("topk:0.01|q8"), Some("delta")).is_ok());
    }

    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        let cfg = TransportConfig::parse(Some("topk:20|q8"), Some("delta")).unwrap();
        let mk = || Transport::new(cfg.clone(), 3, 400, 9);
        let mut live = mk();
        let drive = |t: &mut Transport, round: u64| -> (u64, Vec<f32>) {
            let th = theta(400, round);
            t.publish(round, &th);
            let down = t.downlink((round % 3) as usize, round, &th);
            let mut d: Vec<f32> = (0..400).map(|i| ((i as u64 + round) as f32).cos()).collect();
            let up = t.encode_up((round % 3) as usize, &mut d).unwrap();
            (down + up, d)
        };
        for round in 1..=5 {
            drive(&mut live, round);
        }
        let st = live.state_save();
        assert_eq!(st, live.state_save(), "state_save not pure");
        let mut resumed = mk();
        resumed.state_load(st.clone()).unwrap();
        for round in 6..=10 {
            let a = drive(&mut live, round);
            let b = drive(&mut resumed, round);
            assert_eq!(a, b, "round {round}: resumed transport diverged");
        }
        // validation: wrong client count / dim rejected whole
        let mut wrong_n = Transport::new(cfg.clone(), 4, 400, 9);
        assert!(wrong_n.state_load(st.clone()).is_err());
        let mut wrong_dim = Transport::new(cfg.clone(), 3, 200, 9);
        assert!(wrong_dim.state_load(st).is_err());
    }

    #[test]
    fn encode_up_matches_plan_and_feeds_back() {
        let cfg = TransportConfig::parse(Some("topk:10|q8"), None).unwrap();
        let mut t = Transport::new(cfg, 2, 500, 7);
        let plan = t.up_plan_bytes();
        let mut d: Vec<f32> = (0..500).map(|i| (i as f32 * 0.37).sin()).collect();
        let orig = d.clone();
        let bytes = t.encode_up(0, &mut d).unwrap();
        assert_eq!(bytes, plan, "scheduler-priced bytes != encoded bytes");
        assert!(t.residual_norm(0) > 0.0, "sparsification left no residual");
        assert_eq!(t.residual_norm(1), 0.0, "untouched client's residual moved");
        // delivered + residual ≈ folded update (conservation)
        let resid = t.residual_norm(0);
        let delivered_err: f64 = orig
            .iter()
            .zip(&d)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!((delivered_err - resid).abs() < 1e-3, "{delivered_err} vs {resid}");
    }
}
