//! Framed wire messages + the composable codec pipeline (DESIGN.md §6).
//!
//! Every model or update that crosses the simulated network is priced —
//! and, in tests, actually serialized — as a self-describing **frame**:
//! a fixed 24-byte header followed by the payload a codec [`Pipeline`]
//! produced. A pipeline is a `|`-separated composition of registry-named
//! stages, e.g. `--codec "topk:1000|q8"`:
//!
//! | stage          | role |
//! |----------------|------|
//! | `dense`        | identity: full f32 payload |
//! | `delta`        | overwrite patch vs the receiver's acked model version (downlink) |
//! | `topk:<k\|f>`  | magnitude sparsification to `k` coords (or fraction `f` of dim) |
//! | `q<bits>`      | stochastic uniform quantization (1..=8 bits) |
//!
//! Stage order is enforced (`delta` first, `topk` next, `q<b>` last).
//! Three views of a frame's size share one formula and are pinned
//! together by tests: [`SizePlan::wire_bytes`] (pre-encode pricing),
//! [`Repr::wire_bytes`] (post-stage accounting), and the serialized
//! [`Frame`]'s actual length. The scheduler prices a transfer from the
//! same pipeline that later encodes it, so estimate and actual can never
//! drift. At run scope the same byte streams feed the
//! `wire.up_bytes`/`wire.down_bytes` counters of the
//! [`obs`](crate::obs) metrics registry and the byte labels on
//! `--trace` spans (DESIGN.md §10) — observation rides the one source
//! of truth rather than re-metering.
//!
//! Decoding needs no pipeline object: frames are self-describing, and
//! [`decode_frame`] inverts any stage composition from the header alone
//! (plus the base model for delta frames).
//!
//! Hot paths decode without owning anything: [`FrameRef`] borrows a
//! frame's bytes, and the `*_into` variants ([`decode_frame_into`],
//! [`Repr::decode_into`], [`write_dense_frame_into`]) stream straight
//! into caller-owned scratch — bit-identical to their allocating twins
//! (pinned in `rust/tests/params_fused.rs`), with owned conversion
//! deferred to the ModelStore boundary (DESIGN.md §14).

use std::fmt;
use std::sync::Arc;

use crate::compression::{
    dequantize, dequantize_into, dequantize_raw_into, quantize, quantized_value_bytes,
    QuantizedUpdate, QCHUNK,
};
use crate::data::rng::Rng;
use crate::Result;

/// Frame magic: `b"FWIR"` read as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"FWIR");
/// Current wire-format version.
pub const WIRE_VERSION: u8 = 1;
/// Fixed frame-header size (layout documented in DESIGN.md §6).
pub const HEADER_BYTES: u64 = 24;

const FLAG_DELTA: u8 = 0b001;
const FLAG_SPARSE: u8 = 0b010;
const FLAG_QUANT: u8 = 0b100;

// ----------------------------------------------------------------- repr

/// Value payload of an in-flight [`Repr`]: raw f32s, or the packed
/// output of the quantize stage.
#[derive(Debug, Clone)]
pub enum Vals {
    F32(Vec<f32>),
    Quantized(QuantizedUpdate),
}

impl Vals {
    fn payload_bytes(&self) -> u64 {
        match self {
            Vals::F32(v) => 4 * v.len() as u64,
            Vals::Quantized(q) => quantized_value_bytes(q.dim, q.bits),
        }
    }

}

/// Coordinate layout of a [`Repr`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReprKind {
    /// All `dim` coordinates, in order.
    Dense,
    /// Additive sparse: listed coordinates carry values, the rest are
    /// zero (uplink top-k).
    Sparse,
    /// Overwrite patch vs a base model version: listed coordinates carry
    /// replacement values, the rest keep the base's (delta downlink).
    Patch,
}

/// The in-flight representation [`Codec`] stages transform, between the
/// dense vector and the serialized [`Frame`].
#[derive(Debug, Clone)]
pub struct Repr {
    /// Decoded dimensionality.
    pub dim: usize,
    pub kind: ReprKind,
    /// Sorted coordinate indices; empty when `kind == Dense`.
    pub idx: Vec<u32>,
    pub vals: Vals,
    /// Base model version (`kind == Patch` only, else 0).
    pub base_version: u64,
}

impl Repr {
    /// The start of every encode: the dense vector itself.
    pub fn dense(x: &[f32]) -> Repr {
        Repr::dense_owned(x.to_vec())
    }

    /// As [`dense`](Self::dense), taking ownership of the vector — the
    /// zero-copy entry when the caller is done with it.
    pub fn dense_owned(x: Vec<f32>) -> Repr {
        Repr {
            dim: x.len(),
            kind: ReprKind::Dense,
            idx: Vec::new(),
            vals: Vals::F32(x),
            base_version: 0,
        }
    }

    fn flags(&self) -> u8 {
        let mut f = match self.kind {
            ReprKind::Dense => 0,
            ReprKind::Sparse => FLAG_SPARSE,
            ReprKind::Patch => FLAG_DELTA,
        };
        if matches!(self.vals, Vals::Quantized(_)) {
            f |= FLAG_QUANT;
        }
        f
    }

    /// Exact length of [`to_frame`](Self::to_frame)'s output.
    pub fn wire_bytes(&self) -> u64 {
        let idx_bytes = if self.kind == ReprKind::Dense {
            0
        } else {
            4 * self.idx.len() as u64
        };
        HEADER_BYTES + idx_bytes + self.vals.payload_bytes()
    }

    /// Serialize to the frame layout (DESIGN.md §6) at tier 0 — the
    /// client↔server tier every pre-hierarchy frame belongs to.
    pub fn to_frame(&self) -> Frame {
        self.to_frame_tagged(0)
    }

    /// Serialize with an explicit aggregation-tier tag in header byte 7
    /// (formerly reserved-zero, so tier-0 frames are byte-identical to
    /// the untagged format and old frames parse as tier 0). Tier 1 =
    /// edge↔root frames of hierarchical aggregation (DESIGN.md §11).
    pub fn to_frame_tagged(&self, tier: u8) -> Frame {
        let mut b = Vec::with_capacity(self.wire_bytes() as usize);
        b.extend_from_slice(&MAGIC.to_le_bytes());
        b.push(WIRE_VERSION);
        b.push(self.flags());
        b.push(match &self.vals {
            Vals::Quantized(q) => q.bits,
            Vals::F32(_) => 0,
        });
        b.push(tier);
        b.extend_from_slice(&(self.dim as u32).to_le_bytes());
        let k = if self.kind == ReprKind::Dense {
            self.dim
        } else {
            self.idx.len()
        };
        b.extend_from_slice(&(k as u32).to_le_bytes());
        b.extend_from_slice(&self.base_version.to_le_bytes());
        if self.kind != ReprKind::Dense {
            for &i in &self.idx {
                b.extend_from_slice(&i.to_le_bytes());
            }
        }
        match &self.vals {
            Vals::F32(v) => {
                for &x in v {
                    b.extend_from_slice(&x.to_le_bytes());
                }
            }
            Vals::Quantized(q) => {
                debug_assert_eq!(q.chunk, QCHUNK, "wire format fixes the quant chunk");
                for &(lo, step) in &q.scales {
                    b.extend_from_slice(&lo.to_le_bytes());
                    b.extend_from_slice(&step.to_le_bytes());
                }
                b.extend_from_slice(&q.codes);
            }
        }
        debug_assert_eq!(b.len() as u64, self.wire_bytes());
        Frame { bytes: b }
    }

    /// Recover the dense vector this repr describes. `base` is required
    /// for (and only used by) `Patch` reprs.
    pub fn decode(&self, base: Option<&[f32]>) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.dim);
        self.decode_into(base, &mut out)?;
        Ok(out)
    }

    /// [`decode`](Self::decode) into a caller-owned buffer (cleared,
    /// reused) — the zero-copy decode→apply path (DESIGN.md §14). Dense
    /// quantized payloads dequantize straight into `out`; sparse and
    /// patch payloads seed `out` (zeros / the base) and scatter in the
    /// same index order as [`decode`](Self::decode), so the produced
    /// bits cannot differ (twin-tested in `rust/tests/params_fused.rs`).
    pub fn decode_into(&self, base: Option<&[f32]>, out: &mut Vec<f32>) -> Result<()> {
        match self.kind {
            ReprKind::Dense => {
                match &self.vals {
                    Vals::F32(v) => {
                        anyhow::ensure!(v.len() == self.dim, "dense repr with {} of {} values", v.len(), self.dim);
                        out.clear();
                        out.extend_from_slice(v);
                    }
                    Vals::Quantized(q) => {
                        dequantize_into(q, out);
                        anyhow::ensure!(out.len() == self.dim, "dense repr with {} of {} values", out.len(), self.dim);
                    }
                }
                Ok(())
            }
            ReprKind::Sparse => {
                out.clear();
                out.resize(self.dim, 0.0);
                self.scatter(out);
                Ok(())
            }
            ReprKind::Patch => {
                let base = base.ok_or_else(|| {
                    anyhow::anyhow!("patch repr (base version {}) needs the base model", self.base_version)
                })?;
                anyhow::ensure!(base.len() == self.dim, "base dim {} != repr dim {}", base.len(), self.dim);
                out.clear();
                out.extend_from_slice(base);
                self.scatter(out);
                Ok(())
            }
        }
    }

    /// Scatter this repr's `(idx, vals)` pairs into a seeded `out`.
    /// Quantized sparse values dequantize into one transient buffer —
    /// the only allocation left on the borrowed decode path.
    fn scatter(&self, out: &mut [f32]) {
        let owned;
        let vals: &[f32] = match &self.vals {
            Vals::F32(v) => v,
            Vals::Quantized(q) => {
                owned = dequantize(q);
                &owned
            }
        };
        for (&i, &v) in self.idx.iter().zip(vals) {
            out[i as usize] = v;
        }
    }
}

// ---------------------------------------------------------------- frame

/// A serialized wire message: self-describing 24-byte header + payload.
#[derive(Debug, Clone)]
pub struct Frame {
    pub bytes: Vec<u8>,
}

impl Frame {
    pub fn wire_bytes(&self) -> u64 {
        self.bytes.len() as u64
    }

    pub fn header(&self) -> Result<FrameHeader> {
        FrameHeader::parse(&self.bytes)
    }

    /// Decode back to the dense vector (`base` for delta frames).
    pub fn decode(&self, base: Option<&[f32]>) -> Result<Vec<f32>> {
        decode_frame(&self.bytes, base)
    }

    /// [`decode`](Self::decode) into a caller-owned buffer — see
    /// [`decode_frame_into`].
    pub fn decode_into(&self, base: Option<&[f32]>, out: &mut Vec<f32>) -> Result<()> {
        decode_frame_into(&self.bytes, base, out)
    }

    /// Borrow this frame's bytes as a [`FrameRef`].
    pub fn view(&self) -> FrameRef<'_> {
        FrameRef { bytes: &self.bytes }
    }
}

/// A borrowed view of a serialized frame — the zero-copy twin of
/// [`Frame`] for decode→apply paths and the §11 tier cascade, which
/// re-frame and decode without owning bytes (DESIGN.md §14). Carries no
/// state beyond the borrowed slice, so it is `Copy` and free to pass
/// around.
#[derive(Debug, Clone, Copy)]
pub struct FrameRef<'a> {
    pub bytes: &'a [u8],
}

impl FrameRef<'_> {
    pub fn wire_bytes(&self) -> u64 {
        self.bytes.len() as u64
    }

    pub fn header(&self) -> Result<FrameHeader> {
        FrameHeader::parse(self.bytes)
    }

    /// Decode back to an owned dense vector (`base` for delta frames).
    pub fn decode(&self, base: Option<&[f32]>) -> Result<Vec<f32>> {
        decode_frame(self.bytes, base)
    }

    /// Decode into a caller-owned buffer — see [`decode_frame_into`].
    pub fn decode_into(&self, base: Option<&[f32]>, out: &mut Vec<f32>) -> Result<()> {
        decode_frame_into(self.bytes, base, out)
    }
}

/// Serialize `x` as a dense frame, tier-tagged, straight into `frame`'s
/// byte buffer (cleared, reused) — byte-identical to
/// `Repr::dense(x).to_frame_tagged(tier)` without staging a [`Repr`] or
/// allocating. The §11 cascade re-frames its accumulator with this at
/// every shard boundary.
pub fn write_dense_frame_into(x: &[f32], tier: u8, frame: &mut Frame) {
    let out = &mut frame.bytes;
    out.clear();
    out.reserve(HEADER_BYTES as usize + 4 * x.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(WIRE_VERSION);
    out.push(0); // flags: dense, unquantized
    out.push(0); // quant bits
    out.push(tier);
    out.extend_from_slice(&(x.len() as u32).to_le_bytes());
    out.extend_from_slice(&(x.len() as u32).to_le_bytes());
    out.extend_from_slice(&0u64.to_le_bytes());
    for &v in x {
        out.extend_from_slice(&v.to_le_bytes());
    }
    debug_assert_eq!(out.len() as u64, HEADER_BYTES + 4 * x.len() as u64);
}

/// Parsed frame header.
#[derive(Debug, Clone, Copy)]
pub struct FrameHeader {
    /// Payload is an overwrite patch vs `base_version`.
    pub delta: bool,
    /// Payload is additive sparse (zeros elsewhere).
    pub sparse: bool,
    /// 0 = raw f32 values.
    pub quant_bits: u8,
    /// Decoded dimensionality.
    pub dim: usize,
    /// Coordinates on the wire (== `dim` for dense frames).
    pub k: usize,
    /// Delta base version (0 when `!delta`).
    pub base_version: u64,
    /// Aggregation tier (header byte 7): 0 = client↔server, 1 =
    /// edge↔root (hierarchical aggregation, DESIGN.md §11). Frames
    /// written before the tag existed carry the reserved zero and parse
    /// as tier 0.
    pub tier: u8,
}

// Checked little-endian reads: frames are untrusted input, so a
// truncated or lying buffer must surface as a typed error, never a
// panic (rule `panic-surface` — DESIGN.md §13).

fn rd_slice<const N: usize>(b: &[u8], off: usize) -> Result<[u8; N]> {
    let s = b
        .get(off..off + N)
        .ok_or_else(|| anyhow::anyhow!("frame truncated: {N} bytes at offset {off}, len {}", b.len()))?;
    let mut out = [0u8; N];
    out.copy_from_slice(s);
    Ok(out)
}

fn rd_u8(b: &[u8], off: usize) -> Result<u8> {
    Ok(rd_slice::<1>(b, off)?[0])
}

fn rd_u32(b: &[u8], off: usize) -> Result<u32> {
    Ok(u32::from_le_bytes(rd_slice(b, off)?))
}

fn rd_u64(b: &[u8], off: usize) -> Result<u64> {
    Ok(u64::from_le_bytes(rd_slice(b, off)?))
}

fn rd_f32(b: &[u8], off: usize) -> Result<f32> {
    Ok(f32::from_le_bytes(rd_slice(b, off)?))
}

impl FrameHeader {
    pub fn parse(bytes: &[u8]) -> Result<FrameHeader> {
        anyhow::ensure!(
            bytes.len() >= HEADER_BYTES as usize,
            "frame shorter than its header: {} bytes",
            bytes.len()
        );
        let magic = rd_u32(bytes, 0)?;
        anyhow::ensure!(magic == MAGIC, "bad frame magic {magic:#010x}");
        let version = rd_u8(bytes, 4)?;
        anyhow::ensure!(version == WIRE_VERSION, "unsupported wire version {version}");
        let flags = rd_u8(bytes, 5)?;
        let delta = flags & FLAG_DELTA != 0;
        let sparse = flags & FLAG_SPARSE != 0;
        anyhow::ensure!(!(delta && sparse), "frame flags {flags:#04x}: delta and sparse are exclusive");
        let quant = flags & FLAG_QUANT != 0;
        let bits = rd_u8(bytes, 6)?;
        anyhow::ensure!(
            quant == (bits > 0) && bits <= 8,
            "inconsistent quant bits {bits} for flags {flags:#04x}"
        );
        let dim = rd_u32(bytes, 8)? as usize;
        let k = rd_u32(bytes, 12)? as usize;
        anyhow::ensure!(k <= dim, "frame k {k} exceeds dim {dim}");
        anyhow::ensure!(delta || sparse || k == dim, "dense frame with k {k} != dim {dim}");
        let base_version = rd_u64(bytes, 16)?;
        anyhow::ensure!(
            delta == (base_version != 0),
            "base version {base_version} inconsistent with flags {flags:#04x}"
        );
        Ok(FrameHeader {
            delta,
            sparse,
            quant_bits: bits,
            dim,
            k,
            base_version,
            tier: rd_u8(bytes, 7)?,
        })
    }

    /// The exact frame length this header implies — the same formula as
    /// [`SizePlan::wire_bytes`] and [`Repr::wire_bytes`].
    pub fn expect_bytes(&self) -> u64 {
        let idx = if self.delta || self.sparse { 4 * self.k as u64 } else { 0 };
        let vals = if self.quant_bits > 0 {
            quantized_value_bytes(self.k, self.quant_bits)
        } else {
            4 * self.k as u64
        };
        HEADER_BYTES + idx + vals
    }
}

/// Decode a serialized frame back to its dense vector. Frames are
/// self-describing: no pipeline object is needed, only the base model
/// for delta frames (caller matches [`FrameHeader::base_version`]).
pub fn decode_frame(bytes: &[u8], base: Option<&[f32]>) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    decode_frame_into(bytes, base, &mut out)?;
    Ok(out)
}

/// [`decode_frame`] into a caller-owned buffer (cleared, reused) — the
/// borrowed decode path: no index staging, no value staging for f32
/// payloads (values stream from the wire bytes straight into the seeded
/// destination), and dense quantized payloads unpack directly into
/// `out`. Validation checks, value order, and scatter order all match
/// [`decode_frame`]'s staging decoder, so the produced bits cannot
/// differ (twin-tested in `rust/tests/params_fused.rs`).
pub fn decode_frame_into(bytes: &[u8], base: Option<&[f32]>, out: &mut Vec<f32>) -> Result<()> {
    let h = FrameHeader::parse(bytes)?;
    anyhow::ensure!(
        bytes.len() as u64 == h.expect_bytes(),
        "frame length {} != header-implied {}",
        bytes.len(),
        h.expect_bytes()
    );
    let idx_off = HEADER_BYTES as usize;
    let mut off = idx_off;
    if h.delta || h.sparse {
        for i in 0..h.k {
            let v = rd_u32(bytes, off + 4 * i)?;
            anyhow::ensure!((v as usize) < h.dim, "frame index {v} out of range for dim {}", h.dim);
        }
        off += 4 * h.k;
    }
    // seed the destination the values land in
    if h.delta {
        let base = base.ok_or_else(|| {
            anyhow::anyhow!("delta frame (base version {}) needs the base model", h.base_version)
        })?;
        anyhow::ensure!(base.len() == h.dim, "base dim {} != frame dim {}", base.len(), h.dim);
        out.clear();
        out.extend_from_slice(base);
    } else if h.sparse {
        out.clear();
        out.resize(h.dim, 0.0);
    }
    if h.quant_bits > 0 {
        let n_chunks = (h.k + QCHUNK - 1) / QCHUNK;
        let mut scales = Vec::with_capacity(n_chunks);
        for c in 0..n_chunks {
            scales.push((rd_f32(bytes, off + 8 * c)?, rd_f32(bytes, off + 8 * c + 4)?));
        }
        off += 8 * n_chunks;
        let codes = bytes
            .get(off..)
            .ok_or_else(|| anyhow::anyhow!("frame truncated: codes at offset {off}, len {}", bytes.len()))?;
        if h.delta || h.sparse {
            // one transient dequantize: quantized values cannot stream
            let mut vals = Vec::with_capacity(h.k);
            dequantize_raw_into(h.k, h.quant_bits, QCHUNK, &scales, codes, &mut vals);
            for (i, &v) in vals.iter().enumerate().take(h.k) {
                let at = rd_u32(bytes, idx_off + 4 * i)? as usize;
                out[at] = v;
            }
        } else {
            dequantize_raw_into(h.k, h.quant_bits, QCHUNK, &scales, codes, out);
        }
    } else if h.delta || h.sparse {
        for i in 0..h.k {
            let v = rd_f32(bytes, off + 4 * i)?;
            let at = rd_u32(bytes, idx_off + 4 * i)? as usize;
            out[at] = v;
        }
    } else {
        out.clear();
        out.reserve(h.k);
        for i in 0..h.k {
            out.push(rd_f32(bytes, off + 4 * i)?);
        }
    }
    Ok(())
}

// ------------------------------------------------------------ size plan

/// Wire-size plan a pipeline folds through its stages (one
/// [`Codec::plan`] call per stage) to price a payload before encoding.
#[derive(Debug, Clone, Copy)]
pub struct SizePlan {
    pub dim: usize,
    /// Coordinates on the wire after the stages so far.
    pub coords: usize,
    /// Whether indices accompany the values.
    pub sparse: bool,
    /// 0 = raw f32 values.
    pub quant_bits: u8,
}

impl SizePlan {
    pub fn dense(dim: usize) -> SizePlan {
        SizePlan {
            dim,
            coords: dim,
            sparse: false,
            quant_bits: 0,
        }
    }

    /// Exact frame length the plan implies — the same formula
    /// [`Repr::wire_bytes`] and [`FrameHeader::expect_bytes`] use.
    pub fn wire_bytes(&self) -> u64 {
        let idx = if self.sparse { 4 * self.coords as u64 } else { 0 };
        let vals = if self.quant_bits > 0 {
            quantized_value_bytes(self.coords, self.quant_bits)
        } else {
            4 * self.coords as u64
        };
        HEADER_BYTES + idx + vals
    }
}

// ---------------------------------------------------------- codec trait

/// Encode-time context: the delta base (version + model) for `delta`
/// pipelines, and the stochastic-rounding stream for `q<b>` stages.
pub struct EncodeCtx<'a> {
    pub base: Option<(u64, &'a [f32])>,
    pub rng: &'a mut Rng,
}

/// One registry-named stage of a codec [`Pipeline`].
///
/// Stages transform the in-flight [`Repr`] at encode time and fold a
/// [`SizePlan`] for pre-encode pricing. Decoding needs no trait method:
/// frames are self-describing, and [`decode_frame`] inverts any stage
/// composition from the header alone.
pub trait Codec: Send + Sync {
    /// The stage's label exactly as written in a pipeline spec.
    fn label(&self) -> String;

    /// Transform the representation at encode time.
    fn encode(&self, repr: Repr, ctx: &mut EncodeCtx<'_>) -> Result<Repr>;

    /// Fold the wire-size plan. `delta_coords` carries the pre-counted
    /// patch size for the `delta` stage (data-dependent, so the caller
    /// counts it; `None` plans a non-delta pipeline).
    fn plan(&self, plan: SizePlan, delta_coords: Option<usize>) -> SizePlan;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StageKind {
    Dense,
    Delta,
    TopK,
    Quant,
}

/// `dense` — explicit identity: the full f32 vector in a frame.
struct DenseCodec;

impl Codec for DenseCodec {
    fn label(&self) -> String {
        "dense".into()
    }

    fn encode(&self, repr: Repr, _ctx: &mut EncodeCtx<'_>) -> Result<Repr> {
        Ok(repr)
    }

    fn plan(&self, plan: SizePlan, _delta_coords: Option<usize>) -> SizePlan {
        plan
    }
}

/// `delta` — overwrite patch vs the receiver's acked base version: ships
/// only coordinates whose bit patterns differ, so reconstruction is
/// bit-exact and downlink bytes scale with round-to-round change.
struct DeltaCodec;

impl Codec for DeltaCodec {
    fn label(&self) -> String {
        "delta".into()
    }

    fn encode(&self, repr: Repr, ctx: &mut EncodeCtx<'_>) -> Result<Repr> {
        anyhow::ensure!(repr.kind == ReprKind::Dense, "delta must be the first stage");
        let (version, base) = ctx
            .base
            .ok_or_else(|| anyhow::anyhow!("delta stage needs a base model version"))?;
        anyhow::ensure!(version != 0, "delta base version must be nonzero");
        anyhow::ensure!(
            base.len() == repr.dim,
            "delta base dim {} != payload dim {}",
            base.len(),
            repr.dim
        );
        let x = match &repr.vals {
            Vals::F32(v) => v,
            Vals::Quantized(_) => anyhow::bail!("delta cannot follow quantization"),
        };
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        for (i, (&a, &b)) in x.iter().zip(base.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                idx.push(i as u32);
                vals.push(a);
            }
        }
        Ok(Repr {
            dim: repr.dim,
            kind: ReprKind::Patch,
            idx,
            vals: Vals::F32(vals),
            base_version: version,
        })
    }

    fn plan(&self, mut plan: SizePlan, delta_coords: Option<usize>) -> SizePlan {
        // lint:allow(panic-surface): encode path — the caller computed the patch itself; a missing count is a local programming error, not untrusted input.
        plan.coords = delta_coords.expect("planning a delta pipeline needs the counted patch size");
        plan.sparse = true;
        plan
    }
}

/// `topk:<count|frac>` argument.
#[derive(Debug, Clone, Copy)]
pub enum TopKSpec {
    Count(usize),
    /// Fraction of the decoded dimensionality.
    Frac(f64),
}

impl TopKSpec {
    /// Kept-coordinate budget for a `dim`-vector (always ≥ 1, ≤ dim).
    pub fn k(&self, dim: usize) -> usize {
        let raw = match *self {
            TopKSpec::Count(k) => k,
            TopKSpec::Frac(f) => (dim as f64 * f).ceil() as usize,
        };
        raw.max(1).min(dim.max(1))
    }
}

/// `topk:<k|f>` — magnitude sparsification: on a dense update, keep the
/// k largest-|coordinate|s; on a delta patch, keep the k
/// largest-|change| entries.
struct TopKCodec {
    spec: TopKSpec,
}

impl Codec for TopKCodec {
    fn label(&self) -> String {
        match self.spec {
            TopKSpec::Count(k) => format!("topk:{k}"),
            TopKSpec::Frac(f) => format!("topk:{f}"),
        }
    }

    fn encode(&self, repr: Repr, ctx: &mut EncodeCtx<'_>) -> Result<Repr> {
        match repr.kind {
            ReprKind::Dense => {
                let x = match &repr.vals {
                    Vals::F32(v) => v,
                    Vals::Quantized(_) => anyhow::bail!("topk cannot follow quantization"),
                };
                let s = crate::compression::top_k(x, self.spec.k(repr.dim));
                Ok(Repr {
                    dim: repr.dim,
                    kind: ReprKind::Sparse,
                    idx: s.idx,
                    vals: Vals::F32(s.val),
                    base_version: 0,
                })
            }
            ReprKind::Patch => {
                let vals = match &repr.vals {
                    Vals::F32(v) => v,
                    Vals::Quantized(_) => anyhow::bail!("topk cannot follow quantization"),
                };
                let (_, base) = ctx
                    .base
                    .ok_or_else(|| anyhow::anyhow!("topk over a delta patch needs the base model"))?;
                let k = self.spec.k(repr.dim).min(repr.idx.len());
                if k == repr.idx.len() {
                    return Ok(repr);
                }
                // rank patch entries by |new - base| (the change magnitude)
                let change = |e: usize| (vals[e] - base[repr.idx[e] as usize]).abs();
                let mut order: Vec<usize> = (0..repr.idx.len()).collect();
                order.select_nth_unstable_by(k - 1, |&a, &b| {
                    // lint:allow(panic-surface): encode path over locally-trained floats; a NaN here means the trainer diverged and aborting beats shipping a corrupt frame.
                    change(b).partial_cmp(&change(a)).expect("non-finite change")
                });
                let mut keep = order[..k].to_vec();
                keep.sort_unstable();
                Ok(Repr {
                    dim: repr.dim,
                    kind: ReprKind::Patch,
                    idx: keep.iter().map(|&e| repr.idx[e]).collect(),
                    vals: Vals::F32(keep.iter().map(|&e| vals[e]).collect()),
                    base_version: repr.base_version,
                })
            }
            ReprKind::Sparse => anyhow::bail!("at most one topk stage"),
        }
    }

    fn plan(&self, mut plan: SizePlan, _delta_coords: Option<usize>) -> SizePlan {
        plan.coords = self.spec.k(plan.dim).min(plan.coords);
        plan.sparse = true;
        plan
    }
}

/// `q<bits>` — unbiased stochastic uniform quantization of the value
/// payload (whatever the earlier stages left of it).
struct QuantCodec {
    bits: u8,
}

impl Codec for QuantCodec {
    fn label(&self) -> String {
        format!("q{}", self.bits)
    }

    fn encode(&self, repr: Repr, ctx: &mut EncodeCtx<'_>) -> Result<Repr> {
        let q = match &repr.vals {
            Vals::F32(v) => quantize(v, self.bits, ctx.rng),
            Vals::Quantized(_) => anyhow::bail!("at most one quantize stage"),
        };
        Ok(Repr {
            vals: Vals::Quantized(q),
            ..repr
        })
    }

    fn plan(&self, mut plan: SizePlan, _delta_coords: Option<usize>) -> SizePlan {
        plan.quant_bits = self.bits;
        plan
    }
}

// ------------------------------------------------------------- registry

/// One row of the codec registry: the stage's name, argument syntax, and
/// a parser that claims matching spec tokens.
pub struct CodecEntry {
    pub name: &'static str,
    pub syntax: &'static str,
    pub help: &'static str,
    parse: fn(&str) -> Result<Option<(Arc<dyn Codec>, StageKind)>>,
}

fn parse_dense(tok: &str) -> Result<Option<(Arc<dyn Codec>, StageKind)>> {
    Ok((tok == "dense").then(|| (Arc::new(DenseCodec) as Arc<dyn Codec>, StageKind::Dense)))
}

fn parse_delta(tok: &str) -> Result<Option<(Arc<dyn Codec>, StageKind)>> {
    Ok((tok == "delta").then(|| (Arc::new(DeltaCodec) as Arc<dyn Codec>, StageKind::Delta)))
}

fn parse_topk(tok: &str) -> Result<Option<(Arc<dyn Codec>, StageKind)>> {
    let Some(arg) = tok.strip_prefix("topk:") else {
        return Ok(None);
    };
    let v: f64 = arg
        .parse()
        .map_err(|_| anyhow::anyhow!("topk: bad argument {arg:?}"))?;
    anyhow::ensure!(v.is_finite() && v > 0.0, "topk: argument must be positive, got {arg}");
    let spec = if v < 1.0 {
        TopKSpec::Frac(v)
    } else {
        anyhow::ensure!(v.fract() == 0.0, "topk: count must be an integer, got {arg}");
        TopKSpec::Count(v as usize)
    };
    Ok(Some((Arc::new(TopKCodec { spec }) as Arc<dyn Codec>, StageKind::TopK)))
}

fn parse_quant(tok: &str) -> Result<Option<(Arc<dyn Codec>, StageKind)>> {
    let arg = match tok.strip_prefix("quant:") {
        Some(a) => a,
        None => match tok.strip_prefix('q') {
            Some(rest) if !rest.is_empty() && rest.chars().all(|c| c.is_ascii_digit()) => rest,
            _ => return Ok(None),
        },
    };
    let bits: u8 = arg
        .parse()
        .map_err(|_| anyhow::anyhow!("quant: bad bit count {arg:?}"))?;
    anyhow::ensure!((1..=8).contains(&bits), "quant: bits must be in 1..=8, got {bits}");
    Ok(Some((Arc::new(QuantCodec { bits }) as Arc<dyn Codec>, StageKind::Quant)))
}

/// The stage registry `--codec` specs resolve against.
pub static REGISTRY: &[CodecEntry] = &[
    CodecEntry {
        name: "dense",
        syntax: "dense",
        help: "identity: full f32 payload in a frame",
        parse: parse_dense,
    },
    CodecEntry {
        name: "delta",
        syntax: "delta",
        help: "overwrite patch vs the receiver's acked model version (downlink)",
        parse: parse_delta,
    },
    CodecEntry {
        name: "topk",
        syntax: "topk:<count|frac>",
        help: "keep the k largest-magnitude coordinates (count, or fraction of dim)",
        parse: parse_topk,
    },
    CodecEntry {
        name: "q",
        syntax: "q<bits>",
        help: "stochastic uniform quantization to 1..=8 bits",
        parse: parse_quant,
    },
];

/// Human-readable registry listing for CLI help and parse errors.
pub fn registry_help() -> String {
    REGISTRY
        .iter()
        .map(|e| format!("  {:<18} {}", e.syntax, e.help))
        .collect::<Vec<_>>()
        .join("\n")
}

fn parse_stage(token: &str) -> Result<(Arc<dyn Codec>, StageKind)> {
    for entry in REGISTRY {
        if let Some(hit) = (entry.parse)(token)? {
            return Ok(hit);
        }
    }
    anyhow::bail!("unknown codec stage {token:?}; known stages:\n{}", registry_help())
}

// ------------------------------------------------------------- pipeline

/// A composable codec pipeline: zero or more registry stages applied in
/// order at encode time. Parsed from a `|`-separated spec
/// (`"delta|topk:1000|q8"`); `"dense"` is the explicit identity.
#[derive(Clone)]
pub struct Pipeline {
    stages: Vec<(StageKind, Arc<dyn Codec>)>,
    spec: String,
    has_delta: bool,
    has_topk: bool,
    has_quant: bool,
}

impl fmt::Debug for Pipeline {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Pipeline({})", self.spec)
    }
}

impl fmt::Display for Pipeline {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.spec)
    }
}

impl Pipeline {
    /// Parse a `|`-separated pipeline spec. Stage order is enforced:
    /// `delta` first, at most one `topk` (before any `q<b>`), at most
    /// one `q<b>`; `dense` only stands alone.
    pub fn parse(spec: &str) -> Result<Pipeline> {
        let tokens: Vec<&str> = spec.split('|').map(str::trim).collect();
        let mut stages: Vec<(StageKind, Arc<dyn Codec>)> = Vec::new();
        let (mut has_delta, mut has_topk, mut has_quant) = (false, false, false);
        for token in &tokens {
            anyhow::ensure!(!token.is_empty(), "empty stage in codec spec {spec:?}");
            let (stage, kind) = parse_stage(token)?;
            match kind {
                StageKind::Dense => {
                    anyhow::ensure!(
                        tokens.len() == 1,
                        "`dense` is the identity pipeline and cannot compose ({spec:?})"
                    );
                }
                StageKind::Delta => {
                    anyhow::ensure!(
                        stages.is_empty() && !has_delta,
                        "`delta` must be the first stage ({spec:?})"
                    );
                    has_delta = true;
                    stages.push((kind, stage));
                }
                StageKind::TopK => {
                    anyhow::ensure!(!has_topk, "at most one `topk` stage ({spec:?})");
                    anyhow::ensure!(!has_quant, "`topk` must precede `q<bits>` ({spec:?})");
                    has_topk = true;
                    stages.push((kind, stage));
                }
                StageKind::Quant => {
                    anyhow::ensure!(!has_quant, "at most one `q<bits>` stage ({spec:?})");
                    has_quant = true;
                    stages.push((kind, stage));
                }
            }
        }
        let spec = if stages.is_empty() {
            "dense".to_string()
        } else {
            stages.iter().map(|(_, s)| s.label()).collect::<Vec<_>>().join("|")
        };
        Ok(Pipeline {
            stages,
            spec,
            has_delta,
            has_topk,
            has_quant,
        })
    }

    /// The explicit identity pipeline (`"dense"`).
    pub fn identity() -> Pipeline {
        // lint:allow(panic-surface): constant spec string, parsed at startup; cannot fail unless the registry itself is broken.
        Pipeline::parse("dense").expect("identity pipeline")
    }

    /// Canonical spec string (stage labels joined with `|`).
    pub fn spec(&self) -> &str {
        &self.spec
    }

    pub fn has_delta(&self) -> bool {
        self.has_delta
    }

    pub fn has_topk(&self) -> bool {
        self.has_topk
    }

    /// True when `decode(encode(x))` reproduces `x` bit-for-bit for every
    /// input (no lossy stage).
    pub fn lossless(&self) -> bool {
        !self.has_topk && !self.has_quant
    }

    pub fn is_identity(&self) -> bool {
        self.stages.is_empty()
    }

    /// In fallback mode the broadcast must stay *dense*: `delta` has no
    /// base to patch against, and `topk` without a base would zero the
    /// unsent coordinates of a full model. Only value-space stages
    /// (`q<b>`) still apply.
    fn fallback_keeps(kind: StageKind) -> bool {
        !matches!(kind, StageKind::Delta | StageKind::TopK)
    }

    fn run_stages(
        &self,
        x: &[f32],
        base: Option<(u64, &[f32])>,
        rng: &mut Rng,
        fallback: bool,
    ) -> Result<Repr> {
        let mut ctx = EncodeCtx { base, rng };
        let mut repr = Repr::dense(x);
        for (kind, s) in &self.stages {
            if fallback && !Self::fallback_keeps(*kind) {
                continue;
            }
            repr = s.encode(repr, &mut ctx)?;
        }
        Ok(repr)
    }

    /// Run the stages over `x` and return the final in-flight repr
    /// (serialize with [`Repr::to_frame`]; the server's hot path uses the
    /// repr directly and only prices the frame).
    pub fn run(&self, x: &[f32], base: Option<(u64, &[f32])>, rng: &mut Rng) -> Result<Repr> {
        self.run_stages(x, base, rng, false)
    }

    /// As [`run`](Self::run) in dense-fallback mode — the broadcast when
    /// the receiver's acked version aged out. Structural stages (`delta`,
    /// `topk`) are skipped so every coordinate ships; `q<b>` still
    /// applies.
    pub fn run_fallback(&self, x: &[f32], rng: &mut Rng) -> Result<Repr> {
        self.run_stages(x, None, rng, true)
    }

    /// Encode `x` into a serialized frame.
    pub fn encode(&self, x: &[f32], base: Option<(u64, &[f32])>, rng: &mut Rng) -> Result<Frame> {
        Ok(self.run(x, base, rng)?.to_frame())
    }

    fn fold_plan(&self, dim: usize, delta_coords: Option<usize>, fallback: bool) -> SizePlan {
        let mut p = SizePlan::dense(dim);
        for (kind, s) in &self.stages {
            if fallback && !Self::fallback_keeps(*kind) {
                continue;
            }
            p = s.plan(p, delta_coords);
        }
        p
    }

    /// Deterministic wire size for any `dim`-vector. Only valid for
    /// non-delta pipelines (a delta frame's size depends on the payload —
    /// use [`measure`](Self::measure)). The transport prices uplinks with
    /// this *before* any client trains; the later encode of the real
    /// payload produces exactly this many bytes.
    pub fn plan_bytes(&self, dim: usize) -> u64 {
        assert!(
            !self.has_delta,
            "plan_bytes on delta pipeline {}: size is payload-dependent, use measure()",
            self.spec
        );
        self.fold_plan(dim, None, false).wire_bytes()
    }

    /// Wire size of the dense fallback frame
    /// ([`run_fallback`](Self::run_fallback)'s output).
    pub fn fallback_bytes(&self, dim: usize) -> u64 {
        self.fold_plan(dim, None, true).wire_bytes()
    }

    /// Exact wire size of encoding `x` (vs `base` for delta pipelines)
    /// without materializing the frame.
    pub fn measure(&self, x: &[f32], base: Option<&[f32]>) -> Result<u64> {
        let delta_coords = if self.has_delta {
            let base = base
                .ok_or_else(|| anyhow::anyhow!("measuring a delta pipeline needs the base model"))?;
            anyhow::ensure!(
                base.len() == x.len(),
                "base dim {} != payload dim {}",
                base.len(),
                x.len()
            );
            Some(
                x.iter()
                    .zip(base.iter())
                    .filter(|(a, b)| a.to_bits() != b.to_bits())
                    .count(),
            )
        } else {
            None
        };
        Ok(self.fold_plan(x.len(), delta_coords, false).wire_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rngs() -> (Rng, Rng) {
        (Rng::new(7), Rng::new(7))
    }

    fn gauss(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.gauss_f32()).collect()
    }

    #[test]
    fn parse_canonicalizes_and_enforces_order() {
        assert_eq!(Pipeline::parse("dense").unwrap().spec(), "dense");
        assert_eq!(Pipeline::parse("topk:1000|q8").unwrap().spec(), "topk:1000|q8");
        assert_eq!(Pipeline::parse("quant:4").unwrap().spec(), "q4");
        assert_eq!(Pipeline::parse(" delta | topk:0.01 ").unwrap().spec(), "delta|topk:0.01");
        assert!(Pipeline::parse("q8|topk:10").is_err(), "topk after quant");
        assert!(Pipeline::parse("topk:10|delta").is_err(), "delta not first");
        assert!(Pipeline::parse("q8|q4").is_err(), "two quant stages");
        assert!(Pipeline::parse("topk:0").is_err());
        assert!(Pipeline::parse("topk:1.5").is_err());
        assert!(Pipeline::parse("q0").is_err());
        assert!(Pipeline::parse("q9").is_err());
        assert!(Pipeline::parse("gzip").is_err());
        assert!(Pipeline::parse("dense|q8").is_err(), "dense composes");
        assert!(Pipeline::parse("").is_err());
        assert!(Pipeline::identity().is_identity());
        assert!(Pipeline::parse("delta").unwrap().lossless());
        assert!(!Pipeline::parse("delta|q8").unwrap().lossless());
    }

    #[test]
    fn frame_sizes_agree_across_all_three_views() {
        // every non-delta registry pipeline: plan == repr == frame length
        let x = gauss(5000, 1);
        for spec in ["dense", "q8", "q1", "topk:100", "topk:0.05", "topk:100|q4"] {
            let p = Pipeline::parse(spec).unwrap();
            let (mut r1, _) = rngs();
            let repr = p.run(&x, None, &mut r1).unwrap();
            let frame = repr.to_frame();
            assert_eq!(repr.wire_bytes(), frame.wire_bytes(), "{spec}");
            assert_eq!(p.plan_bytes(x.len()), frame.wire_bytes(), "{spec}");
            assert_eq!(p.measure(&x, None).unwrap(), frame.wire_bytes(), "{spec}");
            assert_eq!(frame.header().unwrap().expect_bytes(), frame.wire_bytes(), "{spec}");
        }
    }

    #[test]
    fn tier_tag_rides_the_reserved_byte() {
        let x = gauss(800, 12);
        let repr = Repr::dense(&x);
        // untagged and tier-0 are the same bytes — old frames parse as tier 0
        let plain = repr.to_frame();
        let t0 = repr.to_frame_tagged(0);
        assert_eq!(plain.bytes, t0.bytes);
        assert_eq!(plain.header().unwrap().tier, 0);
        // a tier-1 frame differs only at header byte 7 and decodes bit-exactly
        let t1 = repr.to_frame_tagged(1);
        assert_eq!(t1.header().unwrap().tier, 1);
        assert_eq!(t1.wire_bytes(), plain.wire_bytes());
        let diff: Vec<usize> = plain
            .bytes
            .iter()
            .zip(&t1.bytes)
            .enumerate()
            .filter(|(_, (a, b))| a != b)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(diff, vec![7]);
        let back = t1.decode(None).unwrap();
        for (a, b) in x.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn delta_pipeline_sizes_agree_and_scale_with_change() {
        let base = gauss(4000, 2);
        let mut x = base.clone();
        for i in (0..x.len()).step_by(40) {
            x[i] += 1.0; // 100 changed coords
        }
        for spec in ["delta", "delta|q8", "delta|topk:50", "delta|topk:50|q4"] {
            let p = Pipeline::parse(spec).unwrap();
            let mut rng = Rng::new(3);
            let frame = p.encode(&x, Some((9, &base)), &mut rng).unwrap();
            assert_eq!(p.measure(&x, Some(&base)).unwrap(), frame.wire_bytes(), "{spec}");
            assert!(
                frame.wire_bytes() < 4 * x.len() as u64,
                "{spec}: patch no smaller than dense"
            );
            assert_eq!(frame.header().unwrap().base_version, 9);
        }
        // pure delta: bytes track the number of changed coordinates
        let p = Pipeline::parse("delta").unwrap();
        assert_eq!(
            p.measure(&x, Some(&base)).unwrap(),
            HEADER_BYTES + 100 * 8
        );
        assert_eq!(p.measure(&base, Some(&base)).unwrap(), HEADER_BYTES);
    }

    #[test]
    fn lossless_pipelines_roundtrip_bit_for_bit() {
        let base = gauss(3000, 4);
        let mut x = base.clone();
        x[7] = 12.5;
        x[2999] = -3.25;
        let p = Pipeline::parse("delta").unwrap();
        let mut rng = Rng::new(5);
        let frame = p.encode(&x, Some((3, &base)), &mut rng).unwrap();
        let back = frame.decode(Some(&base)).unwrap();
        assert_eq!(back.len(), x.len());
        for (a, b) in x.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // dense identity likewise
        let d = Pipeline::identity();
        let back = d.encode(&x, None, &mut rng).unwrap().decode(None).unwrap();
        for (a, b) in x.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn lossy_pipelines_bounded_per_delivered_coordinate() {
        let x = gauss(6000, 6);
        let (lo, hi) = x
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
        for (spec, bits) in [("q8", 8u8), ("topk:200", 0), ("topk:200|q8", 8)] {
            let p = Pipeline::parse(spec).unwrap();
            let mut rng = Rng::new(8);
            let frame = p.encode(&x, None, &mut rng).unwrap();
            let back = frame.decode(None).unwrap();
            let bound = if bits > 0 {
                (hi - lo) / ((1u32 << bits) - 1) as f32 * 1.01
            } else {
                0.0
            };
            for (i, (&a, &b)) in x.iter().zip(&back).enumerate() {
                // delivered coords are within the quantization bound;
                // sparsified-away coords decode to exactly zero
                assert!(
                    (a - b).abs() <= bound || b == 0.0,
                    "{spec} coord {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn quant_after_topk_quantizes_only_kept_values() {
        let x = gauss(10_000, 9);
        let p = Pipeline::parse("topk:100|q8").unwrap();
        // 100 idx (400B) + 100 codes + 1 chunk scale (8B) + header
        assert_eq!(p.plan_bytes(x.len()), HEADER_BYTES + 400 + 100 + 8);
    }

    #[test]
    fn fallback_broadcast_is_dense_even_for_structural_pipelines() {
        // the dense fallback must ship every coordinate: delta has no
        // base and topk would zero what it drops — only q<b> survives
        let x = gauss(3000, 12);
        for spec in ["delta", "delta|topk:50", "delta|topk:50|q8"] {
            let p = Pipeline::parse(spec).unwrap();
            let mut rng = Rng::new(13);
            let repr = p.run_fallback(&x, &mut rng).unwrap();
            assert_eq!(repr.kind, ReprKind::Dense, "{spec}");
            assert_eq!(repr.to_frame().wire_bytes(), p.fallback_bytes(x.len()), "{spec}");
            let back = repr.to_frame().decode(None).unwrap();
            let bound = if spec.ends_with("q8") { 1.0 } else { 0.0 };
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() <= bound, "{spec}: fallback dropped a coordinate");
            }
        }
        // quant still applies in fallback mode
        let p = Pipeline::parse("delta|q4").unwrap();
        assert!(p.fallback_bytes(3000) < 4 * 3000, "fallback lost its quant stage");
    }

    #[test]
    fn decode_rejects_corrupt_frames() {
        let x = gauss(100, 10);
        let p = Pipeline::parse("q8").unwrap();
        let mut rng = Rng::new(11);
        let mut frame = p.encode(&x, None, &mut rng).unwrap();
        assert!(decode_frame(&frame.bytes[..10], None).is_err(), "truncated header");
        assert!(decode_frame(&frame.bytes[..30], None).is_err(), "truncated payload");
        frame.bytes[0] ^= 0xFF;
        assert!(decode_frame(&frame.bytes, None).is_err(), "bad magic");
        // delta frame without a base
        let q = Pipeline::parse("delta").unwrap();
        let f = q.encode(&x, Some((1, &x)), &mut rng).unwrap();
        assert!(f.decode(None).is_err());
    }

    #[test]
    fn borrowed_decode_matches_owned_bitwise() {
        // every pipeline shape: Frame::decode vs FrameRef::decode_into
        // into a stale buffer must agree byte-for-byte
        let base = gauss(5000, 21);
        let mut x = base.clone();
        for i in (0..x.len()).step_by(17) {
            x[i] += 0.25;
        }
        for spec in ["dense", "q8", "topk:300", "topk:300|q4", "delta", "delta|q8"] {
            let p = Pipeline::parse(spec).unwrap();
            let needs_base = p.has_delta();
            let mut rng = Rng::new(23);
            let frame = p
                .encode(&x, needs_base.then_some((5, &base[..])), &mut rng)
                .unwrap();
            let dec_base = needs_base.then_some(&base[..]);
            let owned = frame.decode(dec_base).unwrap();
            let mut borrowed = vec![9.0f32; 17]; // stale scratch
            frame.view().decode_into(dec_base, &mut borrowed).unwrap();
            assert_eq!(owned.len(), borrowed.len(), "{spec}");
            for (i, (a, b)) in owned.iter().zip(&borrowed).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{spec} coord {i}");
            }
        }
    }

    #[test]
    fn repr_decode_into_matches_decode_bitwise() {
        let base = gauss(3000, 31);
        let mut x = base.clone();
        x[11] = 4.5;
        for spec in ["dense", "q8", "topk:40", "topk:40|q8", "delta"] {
            let p = Pipeline::parse(spec).unwrap();
            let needs_base = p.has_delta();
            let mut rng = Rng::new(33);
            let repr = p.run(&x, needs_base.then_some((2, &base[..])), &mut rng).unwrap();
            let dec_base = needs_base.then_some(&base[..]);
            let owned = repr.decode(dec_base).unwrap();
            let mut out = vec![1.0f32; 5];
            repr.decode_into(dec_base, &mut out).unwrap();
            assert_eq!(owned.len(), out.len(), "{spec}");
            for (a, b) in owned.iter().zip(&out) {
                assert_eq!(a.to_bits(), b.to_bits(), "{spec}");
            }
        }
    }

    #[test]
    fn dense_frame_writer_is_byte_identical_to_repr_path() {
        let x = gauss(2500, 41);
        for tier in [0u8, 1] {
            let staged = Repr::dense(&x).to_frame_tagged(tier);
            let mut streamed = Frame { bytes: vec![0xAB; 3] }; // stale scratch
            write_dense_frame_into(&x, tier, &mut streamed);
            assert_eq!(staged.bytes, streamed.bytes, "tier {tier}");
        }
        // reuse across sizes: shrinking payload must not leave a tail
        let y = gauss(100, 42);
        let mut f = Frame { bytes: Vec::new() };
        write_dense_frame_into(&x, 1, &mut f);
        write_dense_frame_into(&y, 1, &mut f);
        assert_eq!(f.bytes, Repr::dense(&y).to_frame_tagged(1).bytes);
    }

    #[test]
    fn registry_lists_every_stage() {
        let help = registry_help();
        for name in ["dense", "delta", "topk", "q<bits>"] {
            assert!(help.contains(name), "{name} missing from:\n{help}");
        }
    }
}
