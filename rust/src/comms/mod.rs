//! The transport subsystem — the constraint the paper optimizes for.
//!
//! The paper's premise: federated clients sit behind ~1 MB/s uplinks, so
//! *rounds of communication* dominate cost and wall-clock. This module
//! owns everything that crosses the simulated network:
//!
//! * [`wire`] — framed wire messages and the composable codec pipeline
//!   (`Codec` trait, stage registry, `--codec "topk:1000|q8"` parsing),
//!   with one `wire_bytes` formula shared by planning, encoding, and
//!   serialization (DESIGN.md §6).
//! * [`transport`] — the server endpoint: versioned model store, delta
//!   downlink with dense fallback, uplink error feedback, and the byte
//!   metering both the scheduler and telemetry read.
//! * this file — the bandwidth/latency cost model ([`CommSim`]) that
//!   converts wire bytes into simulated wall-clock, plus availability
//!   traces (the "clients are frequently offline" reality, DESIGN.md §2,
//!   which the fleet coordinator deepens with per-device profiles).

pub mod transport;
pub mod wire;

pub use transport::{ModelStore, Transport, TransportConfig, TransportState};
pub use wire::Pipeline;

use crate::data::rng::{hash3_unit, Rng, RngState};

/// Network model for the synchronous-round protocol.
#[derive(Debug, Clone)]
pub struct CommModel {
    /// Client uplink bytes/second (paper: "1 MB/s or less").
    pub up_bps: f64,
    /// Client downlink bytes/second.
    pub down_bps: f64,
    /// Per-transfer fixed latency (seconds).
    pub latency_s: f64,
    /// Multiplicative per-client bandwidth jitter: each transfer's rate is
    /// scaled by a factor drawn uniformly from `[1 - jitter, 1.0]`.
    pub jitter: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        Self {
            up_bps: 1.0e6,    // the paper's 1 MB/s uplink
            down_bps: 8.0e6,  // typical asymmetric mobile link
            latency_s: 0.1,
            jitter: 0.5,
        }
    }
}

/// Running totals over a training run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommTotals {
    pub rounds: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Simulated wall-clock (s): Σ per-round max over participating
    /// clients (synchronous protocol waits for the straggler).
    pub sim_seconds: f64,
}

impl CommTotals {
    pub fn gigabytes(&self) -> f64 {
        (self.bytes_up + self.bytes_down) as f64 / 1e9
    }
}

/// Per-round accounting.
#[derive(Debug, Clone, Copy)]
pub struct RoundComm {
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Straggler-bound transfer time for this round (s).
    pub transfer_s: f64,
}

pub struct CommSim {
    model: CommModel,
    totals: CommTotals,
    rng: Rng,
}

impl CommSim {
    pub fn new(model: CommModel, seed: u64) -> Self {
        Self {
            model,
            totals: CommTotals::default(),
            rng: Rng::new(seed ^ 0xC0111_5EED),
        }
    }

    /// Account one synchronous round: `m` clients each download and upload
    /// the full `model_bytes` model. Returns this round's accounting and
    /// folds it into the running totals.
    pub fn round(&mut self, m: usize, model_bytes: u64) -> RoundComm {
        self.round_asym(m, model_bytes, model_bytes)
    }

    /// Asymmetric variant: compressed uplinks upload fewer bytes than the
    /// full model the server broadcasts down.
    pub fn round_asym(&mut self, m: usize, down_bytes: u64, up_bytes: u64) -> RoundComm {
        self.round_links(&vec![(down_bytes, up_bytes); m])
    }

    /// Per-link variant: one `(down, up)` byte pair per participating
    /// client, as produced by the transport layer (delta downlinks give
    /// every client a different byte count). `round_asym(m, d, u)` is
    /// exactly `round_links(&[(d, u); m])` — same jitter draws, same
    /// totals.
    pub fn round_links(&mut self, links: &[(u64, u64)]) -> RoundComm {
        let mut worst = 0.0f64;
        let (mut bytes_up, mut bytes_down) = (0u64, 0u64);
        for &(down_bytes, up_bytes) in links {
            let scale = 1.0 - self.model.jitter * self.rng.f64();
            let down = down_bytes as f64 / (self.model.down_bps * scale);
            let up = up_bytes as f64 / (self.model.up_bps * scale);
            let t = 2.0 * self.model.latency_s + down + up;
            worst = worst.max(t);
            bytes_up += up_bytes;
            bytes_down += down_bytes;
        }
        let rc = RoundComm {
            bytes_up,
            bytes_down,
            transfer_s: worst,
        };
        self.totals.rounds += 1;
        self.totals.bytes_up += rc.bytes_up;
        self.totals.bytes_down += rc.bytes_down;
        self.totals.sim_seconds += rc.transfer_s;
        rc
    }

    /// Fold an externally-simulated round into the running totals. The
    /// fleet coordinator computes its own per-client transfer times from
    /// persistent device profiles (see `coordinator::fleet`), so it hands
    /// the finished accounting here instead of using the jitter model.
    pub fn ingest(&mut self, bytes_up: u64, bytes_down: u64, transfer_s: f64) -> RoundComm {
        let rc = RoundComm {
            bytes_up,
            bytes_down,
            transfer_s,
        };
        self.totals.rounds += 1;
        self.totals.bytes_up += rc.bytes_up;
        self.totals.bytes_down += rc.bytes_down;
        self.totals.sim_seconds += rc.transfer_s;
        rc
    }

    pub fn totals(&self) -> CommTotals {
        self.totals
    }

    /// Capture the simulator's mutable state — running totals plus the
    /// jitter stream position — for a run-state snapshot (DESIGN.md §8).
    /// The [`CommModel`] itself is config, rebuilt from flags on resume.
    pub fn state_save(&self) -> CommState {
        CommState {
            totals: self.totals,
            rng: self.rng.state(),
        }
    }

    /// Restore the state captured by [`state_save`](Self::state_save):
    /// subsequent rounds draw the same jitter and extend the same totals
    /// bit-for-bit.
    pub fn state_load(&mut self, st: CommState) {
        self.totals = st.totals;
        self.rng = Rng::from_state(st.rng);
    }
}

/// [`CommSim`]'s snapshot payload (`crate::runstate`, DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommState {
    pub totals: CommTotals,
    pub rng: RngState,
}

/// Bytes on the wire for a model of `param_count` f32 parameters.
pub fn model_bytes(param_count: usize) -> u64 {
    (param_count * std::mem::size_of::<f32>()) as u64
}

/// Client-availability trace: client `c` is online in round `r` with
/// probability `p_online`, decided by a stateless `hash3(seed, r, c)`
/// coin — a pure function of its coordinates, NOT a sequential RNG
/// stream. This makes online status independent of query order, so
/// changing the evaluation cadence (or any other consumer of randomness)
/// cannot desync which clients a given round sees. Models the paper's
/// "clients ... frequently offline" constraint.
pub struct Availability {
    p_online: f64,
    seed: u64,
}

impl Availability {
    pub fn new(p_online: f64, seed: u64) -> Self {
        // p = 0 would make the non-empty guarantee unsatisfiable
        assert!(
            p_online > 0.0 && p_online <= 1.0,
            "p_online must be in (0, 1], got {p_online}"
        );
        Self {
            p_online,
            seed: seed ^ 0xA7A11AB1E,
        }
    }

    /// Which of `k` clients are reachable in `round`. Guarantees at least
    /// one (deterministic salted re-roll otherwise, like a production
    /// scheduler waiting for some device to check in).
    pub fn online(&self, round: u64, k: usize) -> Vec<usize> {
        salted_online_set(self.seed, round, k, |_| self.p_online)
    }
}

/// Clients of `0..k` online in `round` under per-client probability
/// `p_online(c)`, decided by the stateless hash coin and guaranteed
/// non-empty via a deterministic salted re-roll (salt 0 is the plain
/// coin). Shared by [`Availability`] and the fleet coordinator so this
/// reproducibility-affecting salt scheme has exactly one definition.
pub fn salted_online_set(
    seed: u64,
    round: u64,
    k: usize,
    p_online: impl Fn(usize) -> f64,
) -> Vec<usize> {
    // expected salts until non-empty ≈ 1/(k·p̄); this bound covers
    // k·p̄ down to ~1e-6 and turns a zero-probability configuration
    // into a diagnosable panic instead of an infinite spin
    for salt in 0..10_000_000u64 {
        let s = seed ^ salt.wrapping_mul(0xA0B428DB);
        let up: Vec<usize> = (0..k)
            .filter(|&c| hash3_unit(s, round, c as u64) < p_online(c))
            .collect();
        if !up.is_empty() {
            return up;
        }
    }
    panic!("no client ever online in round {round}: availability is ~zero across all {k} clients");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_accounting_accumulates() {
        let mut sim = CommSim::new(CommModel::default(), 1);
        let mb = model_bytes(1_000_000); // 4 MB model
        let rc = sim.round(10, mb);
        assert_eq!(rc.bytes_up, 40_000_000);
        assert_eq!(rc.bytes_down, 40_000_000);
        // uplink at <=1MB/s: 4MB upload takes >= 4s
        assert!(rc.transfer_s >= 4.0, "{}", rc.transfer_s);
        let t = sim.totals();
        assert_eq!(t.rounds, 1);
        assert!((t.gigabytes() - 0.08).abs() < 1e-9);
    }

    #[test]
    fn straggler_bound_grows_with_clients() {
        // more clients -> worse straggler (max over more draws)
        let mut a = CommSim::new(CommModel::default(), 7);
        let mut b = CommSim::new(CommModel::default(), 7);
        let mb = model_bytes(100_000);
        let mut sum_small = 0.0;
        let mut sum_big = 0.0;
        for _ in 0..50 {
            sum_small += a.round(2, mb).transfer_s;
            sum_big += b.round(64, mb).transfer_s;
        }
        assert!(sum_big > sum_small);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = CommSim::new(CommModel::default(), 42);
        let mut b = CommSim::new(CommModel::default(), 42);
        for _ in 0..10 {
            let (x, y) = (a.round(5, 1000).transfer_s, b.round(5, 1000).transfer_s);
            assert_eq!(x, y);
        }
    }

    #[test]
    fn availability_subset_and_nonempty() {
        let av = Availability::new(0.3, 9);
        for round in 0..20 {
            let up = av.online(round, 40);
            assert!(!up.is_empty());
            assert!(up.iter().all(|&c| c < 40));
        }
        let never = Availability::new(0.0001, 11);
        assert!(!never.online(0, 3).is_empty()); // re-rolls until someone shows
    }

    #[test]
    fn availability_is_independent_of_query_order() {
        // the old sequential-RNG coin desynced when rounds were queried in
        // a different order (e.g. under a different eval cadence); the
        // hash coin is a pure function of (seed, round, client)
        let a = Availability::new(0.5, 21);
        let b = Availability::new(0.5, 21);
        let forward: Vec<Vec<usize>> = (0..10).map(|r| a.online(r, 64)).collect();
        let backward: Vec<Vec<usize>> = (0..10).rev().map(|r| b.online(r, 64)).collect();
        for (r, got) in backward.into_iter().rev().enumerate() {
            assert_eq!(forward[r], got, "round {r} depends on query order");
        }
        // and rounds actually differ from each other
        assert_ne!(forward[0], forward[1]);
    }

    #[test]
    fn round_links_matches_round_asym_bit_for_bit() {
        let mut a = CommSim::new(CommModel::default(), 33);
        let mut b = CommSim::new(CommModel::default(), 33);
        for _ in 0..10 {
            let ra = a.round_asym(7, 4_000_000, 800_000);
            let rb = b.round_links(&[(4_000_000, 800_000); 7]);
            assert_eq!(ra.bytes_up, rb.bytes_up);
            assert_eq!(ra.bytes_down, rb.bytes_down);
            assert_eq!(ra.transfer_s, rb.transfer_s);
        }
        // heterogeneous links sum their own bytes
        let rc = a.round_links(&[(100, 10), (200, 20), (300, 30)]);
        assert_eq!(rc.bytes_down, 600);
        assert_eq!(rc.bytes_up, 60);
    }

    #[test]
    fn ingest_folds_external_round() {
        let mut sim = CommSim::new(CommModel::default(), 1);
        sim.ingest(1000, 4000, 2.5);
        sim.ingest(500, 2000, 1.5);
        let t = sim.totals();
        assert_eq!(t.rounds, 2);
        assert_eq!(t.bytes_up, 1500);
        assert_eq!(t.bytes_down, 6000);
        assert!((t.sim_seconds - 4.0).abs() < 1e-12);
    }

    #[test]
    fn model_bytes_f32() {
        assert_eq!(model_bytes(199_210), 796_840);
    }
}
