//! Flat parameter-vector math — the coordinator's numeric hot path.
//!
//! Model parameters cross the rust/XLA boundary as a single flat `f32`
//! vector (L2 ravels the pytree), so the server-side FedAvg update
//! `w_{t+1} = Σ_k (n_k/n) w^k` is a weighted mean of plain vectors.
//! These routines are written to stay memory-bandwidth-bound: single
//! pass, chunk-unrolled so LLVM auto-vectorizes them. The order-statistic
//! kernels ([`trimmed_mean`], [`median`]) under the robust aggregators
//! (`federated::aggregate`, DESIGN.md §7) are the exception: they sort
//! per coordinate, O(dim · m log m) for an m-client cohort — so they are
//! blocked for cache locality and can fan out across workers
//! (coordinates are independent, so threading cannot reorder any float
//! fold; DESIGN.md §14).
//!
//! Every fused or parallel kernel here has an unfused twin in
//! [`reference`] and a byte-for-byte identity test in
//! `rust/tests/params_fused.rs`.

/// A model's parameters (or a gradient) as a flat dense vector.
pub type ParamVec = Vec<f32>;

/// Column-block width for the order-statistic kernels: the m×block slab
/// keeps gather reads in short contiguous runs per client vector and is
/// the unit of work handed to each worker.
const COL_BLOCK: usize = 64;

/// Weighted mean of parameter vectors: `Σ w_i · x_i / Σ w_i`.
///
/// This is Algorithm 1's server update with `w_i = n_k` over the selected
/// clients. Panics if inputs are empty, lengths mismatch, or `Σ w_i <= 0`.
pub fn weighted_mean(items: &[(f32, &[f32])]) -> ParamVec {
    let mut out = Vec::with_capacity(items.first().map_or(0, |(_, x)| x.len()));
    weighted_mean_into(&mut out, items);
    out
}

/// Fused [`weighted_mean`] into a caller-owned buffer (cleared, reused —
/// the round loop's scratch; DESIGN.md §14). One traversal per input
/// vector and no zero-fill pass: the first item is folded as
/// `0.0 + s₀·x₀[j]`, which is exactly the op sequence the reference's
/// zeros-then-[`axpy`] performs — the explicit `0.0 +` keeps the IEEE
/// `-0.0 → +0.0` normalisation a bare `s₀·x₀[j]` would lose — and the
/// remaining items go through the same [`weighted_fold`]. Bit-identical
/// to [`reference::weighted_mean`] by construction.
pub fn weighted_mean_into(out: &mut ParamVec, items: &[(f32, &[f32])]) {
    assert!(!items.is_empty(), "weighted_mean of nothing");
    let total: f64 = weight_total(items);
    assert!(total > 0.0, "weighted_mean: non-positive total weight");
    let (w0, x0) = items[0];
    let s0 = (w0 as f64 / total) as f32;
    out.clear();
    out.reserve(x0.len());
    out.extend(x0.iter().map(|&v| 0.0 + s0 * v));
    weighted_fold(out, &items[1..], total);
}

/// Sum of the weights in f64 — the denominator [`weighted_mean`] and
/// [`weighted_fold`] share. Hierarchical aggregation must compute this
/// over the *whole* cohort before folding any shard, or the per-item
/// scales (and therefore the bits) diverge from the flat mean.
pub fn weight_total(items: &[(f32, &[f32])]) -> f64 {
    items.iter().map(|(w, _)| *w as f64).sum()
}

/// Fold `items` onto a running accumulator with the exact per-item
/// arithmetic of [`weighted_mean`]: each term is scaled by
/// `(w as f64 / total) as f32` and accumulated via [`axpy`], in slice
/// order. `weighted_mean(all)` ≡ zeros then `weighted_fold` over any
/// contiguous partition of `all` folded in order with the global
/// `total` — the identity hierarchical (sharded) aggregation relies on
/// (DESIGN.md §11), which holds *by construction* because this is the
/// same op sequence, merely resumable across shard boundaries.
pub fn weighted_fold(acc: &mut [f32], items: &[(f32, &[f32])], total: f64) {
    assert!(total > 0.0, "weighted_fold: non-positive total weight");
    for (w, x) in items {
        assert_eq!(x.len(), acc.len(), "weighted_fold: length mismatch");
        let scale = (*w as f64 / total) as f32;
        axpy(acc, scale, x);
    }
}

/// `y += a * x`, the fused accumulate used by the averaging loop.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    // 8-wide unroll: keeps LLVM on the autovectorized path.
    let n = y.len();
    let chunks = n / 8;
    let (yc, yr) = y.split_at_mut(chunks * 8);
    let (xc, xr) = x.split_at(chunks * 8);
    for (yv, xv) in yc.chunks_exact_mut(8).zip(xc.chunks_exact(8)) {
        for i in 0..8 {
            yv[i] += a * xv[i];
        }
    }
    for (yv, xv) in yr.iter_mut().zip(xr) {
        *yv += a * xv;
    }
}

/// `θ(λ) = (1-λ)·a + λ·b` — the Figure-1 interpolation path.
pub fn interpolate(a: &[f32], b: &[f32], lambda: f32) -> ParamVec {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&av, &bv)| (1.0 - lambda) * av + lambda * bv)
        .collect()
}

/// Euclidean norm (f64 accumulation for stability).
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Euclidean distance between two parameter vectors.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// In-place scale: `x *= a`.
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Mean of unweighted vectors (convenience for one-shot averaging).
pub fn mean(items: &[&[f32]]) -> ParamVec {
    let weighted: Vec<(f32, &[f32])> = items.iter().map(|x| (1.0, *x)).collect();
    weighted_mean(&weighted)
}

/// Shared scaffold of the coordinate-wise order-statistic reducers,
/// blocked and optionally parallel. Coordinates are gathered a
/// [`COL_BLOCK`]-wide slab at a time (each client vector is read in
/// short contiguous runs instead of one strided element per column),
/// each column is sorted with `total_cmp` (a total order ⇒ the sorted
/// column is independent of gather order), and reduced to one value.
/// Block-aligned coordinate ranges are split across `workers` threads;
/// per-coordinate results are independent, so neither blocking nor
/// threading can move a bit relative to [`reference`]'s flat loop.
fn columnwise_sorted_into(
    out: &mut ParamVec,
    items: &[&[f32]],
    what: &str,
    workers: usize,
    reduce: impl Fn(&[f32]) -> f32 + Sync,
) {
    assert!(!items.is_empty(), "{what} of nothing");
    let dim = items[0].len();
    for x in items {
        assert_eq!(x.len(), dim, "{what}: length mismatch");
    }
    out.clear();
    out.resize(dim, 0.0);
    let workers = workers.max(1).min(dim.div_ceil(COL_BLOCK).max(1));
    if workers <= 1 {
        sorted_block_range(items, 0, out, &reduce);
        return;
    }
    let per = dim.div_ceil(COL_BLOCK).div_ceil(workers) * COL_BLOCK;
    std::thread::scope(|s| {
        for (ti, chunk) in out.chunks_mut(per).enumerate() {
            let reduce = &reduce;
            s.spawn(move || sorted_block_range(items, ti * per, chunk, reduce));
        }
    });
}

/// One worker's share of [`columnwise_sorted_into`]: columns
/// `[start, start + out.len())`, gathered block-by-block into an
/// m×[`COL_BLOCK`] slab, sorted and reduced per column.
fn sorted_block_range(
    items: &[&[f32]],
    start: usize,
    out: &mut [f32],
    reduce: &(impl Fn(&[f32]) -> f32 + Sync),
) {
    let m = items.len();
    let mut slab = vec![0.0f32; m * COL_BLOCK.min(out.len().max(1))];
    for (bi, ob) in out.chunks_mut(COL_BLOCK).enumerate() {
        let c0 = start + bi * COL_BLOCK;
        for (r, x) in items.iter().enumerate() {
            for (c, v) in x[c0..c0 + ob.len()].iter().enumerate() {
                slab[c * m + r] = *v;
            }
        }
        for (c, o) in ob.iter_mut().enumerate() {
            let col = &mut slab[c * m..(c + 1) * m];
            col.sort_unstable_by(f32::total_cmp);
            *o = reduce(col);
        }
    }
}

/// The trim count `t` shared by [`trimmed_mean`] and its reference
/// twin: `min(⌊β·m⌋, ⌈m/2⌉-1)`, clamped so at least one value per
/// coordinate survives however small the cohort gets.
fn trim_count(m: usize, trim_frac: f64) -> usize {
    assert!(
        (0.0..0.5).contains(&trim_frac),
        "trimmed_mean: trim fraction must be in [0, 0.5), got {trim_frac}"
    );
    ((m as f64 * trim_frac) as usize).min(m.saturating_sub(1) / 2)
}

/// Coordinate-wise β-trimmed mean over client vectors (unweighted).
///
/// For each coordinate `j`, sort the m client values, drop the
/// `t = min(⌊β·m⌋, ⌈m/2⌉-1)` smallest and `t` largest, and average the
/// rest (f64 accumulation). `β ∈ [0, 0.5)`; `β = 0` is the plain
/// unweighted mean, and the clamp on `t` keeps at least one value per
/// coordinate however small the cohort gets (straggler drops shrink `m`
/// round to round). Yin et al.'s Byzantine-robust rule: up to `t`
/// arbitrarily corrupted clients per coordinate cannot move the result
/// outside the honest values' range.
///
/// Panics if `items` is empty, lengths mismatch, or `β ∉ [0, 0.5)`.
pub fn trimmed_mean(items: &[&[f32]], trim_frac: f64) -> ParamVec {
    let mut out = Vec::with_capacity(items.first().map_or(0, |x| x.len()));
    trimmed_mean_into(&mut out, items, trim_frac, 1);
    out
}

/// [`trimmed_mean`] into a caller-owned buffer, fanned out across
/// `workers` threads (1 = serial). Bit-identical at every worker count.
pub fn trimmed_mean_into(out: &mut ParamVec, items: &[&[f32]], trim_frac: f64, workers: usize) {
    let m = items.len();
    let t = trim_count(m, trim_frac);
    columnwise_sorted_into(out, items, "trimmed_mean", workers, |col| {
        let kept = &col[t..m - t];
        (kept.iter().map(|&v| v as f64).sum::<f64>() / kept.len() as f64) as f32
    });
}

/// Coordinate-wise median over client vectors (unweighted): the maximal
/// trim, tolerating just under half the cohort being corrupted. Even
/// cohorts average the two middle values.
///
/// Panics if `items` is empty or lengths mismatch.
pub fn median(items: &[&[f32]]) -> ParamVec {
    let mut out = Vec::with_capacity(items.first().map_or(0, |x| x.len()));
    median_into(&mut out, items, 1);
    out
}

/// [`median`] into a caller-owned buffer, fanned out across `workers`
/// threads (1 = serial). Bit-identical at every worker count.
pub fn median_into(out: &mut ParamVec, items: &[&[f32]], workers: usize) {
    let m = items.len();
    columnwise_sorted_into(out, items, "median", workers, |col| {
        if m % 2 == 1 {
            col[m / 2]
        } else {
            ((col[m / 2 - 1] as f64 + col[m / 2] as f64) / 2.0) as f32
        }
    });
}

/// Unfused, unblocked reference kernels — the pre-fusion implementations
/// kept verbatim as the "before" side of the bit-identity twin tests
/// (`rust/tests/params_fused.rs`) and the paired `fedavg bench` cases
/// that record the trajectory (DESIGN.md §14). Never called on a hot
/// path.
pub mod reference {
    use super::{weight_total, weighted_fold, ParamVec};

    /// Two-pass weighted mean: zero-fill `out`, then fold every item —
    /// the walk [`super::weighted_mean`] fuses into one traversal.
    pub fn weighted_mean(items: &[(f32, &[f32])]) -> ParamVec {
        assert!(!items.is_empty(), "weighted_mean of nothing");
        let dim = items[0].1.len();
        let total: f64 = weight_total(items);
        assert!(total > 0.0, "weighted_mean: non-positive total weight");
        let mut out = vec![0.0f32; dim];
        weighted_fold(&mut out, items, total);
        out
    }

    /// Flat per-coordinate gather/sort/reduce: one strided pass over the
    /// whole m×d transpose per coordinate, no blocking, no threading.
    fn columnwise_sorted(
        items: &[&[f32]],
        what: &str,
        mut reduce: impl FnMut(&[f32]) -> f32,
    ) -> ParamVec {
        assert!(!items.is_empty(), "{what} of nothing");
        let dim = items[0].len();
        for x in items {
            assert_eq!(x.len(), dim, "{what}: length mismatch");
        }
        let mut col = vec![0.0f32; items.len()];
        let mut out = vec![0.0f32; dim];
        for (j, o) in out.iter_mut().enumerate() {
            for (slot, x) in col.iter_mut().zip(items) {
                *slot = x[j];
            }
            col.sort_unstable_by(f32::total_cmp);
            *o = reduce(&col);
        }
        out
    }

    /// Unblocked twin of [`super::trimmed_mean`].
    pub fn trimmed_mean(items: &[&[f32]], trim_frac: f64) -> ParamVec {
        let m = items.len();
        let t = super::trim_count(m, trim_frac);
        columnwise_sorted(items, "trimmed_mean", |col| {
            let kept = &col[t..m - t];
            (kept.iter().map(|&v| v as f64).sum::<f64>() / kept.len() as f64) as f32
        })
    }

    /// Unblocked twin of [`super::median`].
    pub fn median(items: &[&[f32]]) -> ParamVec {
        let m = items.len();
        columnwise_sorted(items, "median", |col| {
            if m % 2 == 1 {
                col[m / 2]
            } else {
                ((col[m / 2 - 1] as f64 + col[m / 2] as f64) / 2.0) as f32
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_mean_two_vectors() {
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![5.0, 6.0, 7.0];
        // weights 1:3 -> 0.25*a + 0.75*b
        let m = weighted_mean(&[(1.0, &a[..]), (3.0, &b[..])]);
        assert_eq!(m, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn weighted_mean_identity_single() {
        let a = vec![0.5f32; 100];
        let m = weighted_mean(&[(42.0, &a[..])]);
        for (got, want) in m.iter().zip(&a) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn weighted_mean_rejects_mismatch() {
        let a = vec![1.0; 3];
        let b = vec![1.0; 4];
        weighted_mean(&[(1.0, &a[..]), (1.0, &b[..])]);
    }

    #[test]
    fn fused_mean_matches_reference_bitwise() {
        // includes ±0.0 inputs: the fused first pass must keep the
        // reference's `0.0 + s·x` op so `-0.0` normalises to `+0.0`
        for dim in [1usize, 7, 8, 257] {
            let vecs: Vec<Vec<f32>> = (0..5)
                .map(|i| {
                    (0..dim)
                        .map(|j| match (i + j) % 5 {
                            0 => 0.0,
                            1 => -0.0,
                            k => (i * 13 + j * 7 + k) as f32 * 0.01 - 0.3,
                        })
                        .collect()
                })
                .collect();
            let items: Vec<(f32, &[f32])> =
                vecs.iter().enumerate().map(|(i, v)| ((i + 1) as f32, v.as_slice())).collect();
            let fused = weighted_mean(&items);
            let unfused = reference::weighted_mean(&items);
            let fb: Vec<u32> = fused.iter().map(|v| v.to_bits()).collect();
            let ub: Vec<u32> = unfused.iter().map(|v| v.to_bits()).collect();
            assert_eq!(fb, ub, "fused weighted_mean diverged at dim {dim}");
        }
    }

    #[test]
    fn weighted_mean_into_reuses_buffer() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![5.0f32, 6.0, 7.0];
        let mut out = vec![9.0f32; 40]; // stale, larger than needed
        weighted_mean_into(&mut out, &[(1.0, &a[..]), (3.0, &b[..])]);
        assert_eq!(out, vec![4.0, 5.0, 6.0]);
        weighted_mean_into(&mut out, &[(2.0, &b[..])]);
        assert_eq!(out.len(), 3);
        assert!((out[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn weighted_fold_partition_is_bit_identical_to_mean() {
        // any contiguous partition, folded in order with the global
        // total, must reproduce weighted_mean bit-for-bit
        let m = 9;
        let dim = 257; // not a multiple of the axpy unroll
        let vecs: Vec<Vec<f32>> = (0..m)
            .map(|i| (0..dim).map(|j| ((i * 31 + j * 7) % 113) as f32 * 0.013 - 0.6).collect())
            .collect();
        let items: Vec<(f32, &[f32])> = vecs
            .iter()
            .enumerate()
            .map(|(i, v)| ((i % 4 + 1) as f32 * 100.0, v.as_slice()))
            .collect();
        let flat = weighted_mean(&items);
        let total = weight_total(&items);
        for cuts in [vec![m], vec![4, m], vec![2, 3, 7, m], vec![1, 2, 3, 4, 5, 6, 7, 8, m]] {
            let mut acc = vec![0.0f32; dim];
            let mut start = 0;
            for end in cuts {
                weighted_fold(&mut acc, &items[start..end], total);
                start = end;
            }
            assert_eq!(acc, flat, "partition diverged from flat mean");
        }
    }

    #[test]
    fn axpy_matches_scalar_loop() {
        let x: Vec<f32> = (0..1001).map(|i| i as f32 * 0.01).collect();
        let mut y: Vec<f32> = (0..1001).map(|i| (1000 - i) as f32 * 0.02).collect();
        let mut y2 = y.clone();
        axpy(&mut y, 0.3, &x);
        for (yv, xv) in y2.iter_mut().zip(&x) {
            *yv += 0.3 * xv;
        }
        assert_eq!(y, y2);
    }

    #[test]
    fn interpolate_endpoints_and_outside() {
        let a = vec![0.0f32, 10.0];
        let b = vec![1.0f32, 20.0];
        assert_eq!(interpolate(&a, &b, 0.0), a);
        assert_eq!(interpolate(&a, &b, 1.0), b);
        // Figure 1 sweeps θ ∈ [-0.2, 1.2] — outside the hull must work
        let out = interpolate(&a, &b, 1.2);
        assert!((out[0] - 1.2).abs() < 1e-6);
        assert!((out[1] - 22.0).abs() < 1e-5);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((l2_dist(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn trimmed_mean_drops_tails() {
        // 5 clients, one wildly corrupted: β=0.2 trims exactly the
        // extremes, leaving the honest middle three
        let vs: Vec<Vec<f32>> = vec![
            vec![1.0, -9000.0],
            vec![2.0, 1.0],
            vec![3.0, 2.0],
            vec![4.0, 3.0],
            vec![1e6, 9000.0],
        ];
        let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
        let tm = trimmed_mean(&refs, 0.2);
        assert_eq!(tm, vec![3.0, 2.0]);
        // β=0 is the plain unweighted mean
        let m0 = trimmed_mean(&refs[..4], 0.0);
        assert!((m0[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn trimmed_mean_tiny_cohorts_keep_a_value() {
        // m=1 and m=2: the trim clamp must leave at least one value
        let a = vec![5.0f32];
        let b = vec![7.0f32];
        assert_eq!(trimmed_mean(&[&a[..]], 0.4), vec![5.0]);
        assert_eq!(trimmed_mean(&[&a[..], &b[..]], 0.4), vec![6.0]);
    }

    #[test]
    fn median_odd_even_and_outlier() {
        let vs: Vec<Vec<f32>> = vec![vec![1.0], vec![2.0], vec![1e9]];
        let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
        assert_eq!(median(&refs), vec![2.0]); // odd: middle, outlier gone
        assert_eq!(median(&refs[..2]), vec![1.5]); // even: mean of middles
    }

    #[test]
    fn blocked_order_stats_match_reference_across_workers() {
        // dims straddle the block width; workers straddle the block count
        for dim in [1usize, 63, 64, 65, 200] {
            let vs: Vec<Vec<f32>> = (0..7)
                .map(|i| (0..dim).map(|j| ((i * 37 + j * 11) % 101) as f32 * 0.07 - 3.0).collect())
                .collect();
            let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
            let tm_ref = reference::trimmed_mean(&refs, 0.2);
            let md_ref = reference::median(&refs);
            for workers in [1usize, 2, 3, 8] {
                let mut tm = Vec::new();
                let mut md = Vec::new();
                trimmed_mean_into(&mut tm, &refs, 0.2, workers);
                median_into(&mut md, &refs, workers);
                let eq = |a: &[f32], b: &[f32]| {
                    a.iter().map(|v| v.to_bits()).eq(b.iter().map(|v| v.to_bits()))
                };
                assert!(eq(&tm, &tm_ref), "trimmed diverged dim={dim} workers={workers}");
                assert!(eq(&md, &md_ref), "median diverged dim={dim} workers={workers}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn robust_kernels_reject_mismatch() {
        let a = vec![1.0f32; 3];
        let b = vec![1.0f32; 4];
        median(&[&a[..], &b[..]]);
    }
}
