//! Learning-rate grid search — the paper's tuning methodology (§3):
//! "a sufficiently wide grid of learning rates (typically 11-13 values
//! for η on a multiplicative grid of resolution 10^(1/3) or 10^(1/6))",
//! picking the best rate per configuration and *checking the optimum is
//! interior to the grid*.
//!
//! Used standalone (`examples/lr_sweep.rs`) or under the experiment
//! drivers in [`exper`](crate::exper); each grid point is a full
//! [`federated::run`](crate::federated::run), so sweeps inherit every
//! harness feature (telemetry, fleet, transport codecs).

use crate::config::FedConfig;
use crate::data::Federated;
use crate::federated::{self, RunResult, ServerOptions};
use crate::runtime::Engine;
use crate::Result;

/// A multiplicative learning-rate grid centered at `center`.
#[derive(Debug, Clone)]
pub struct LrGrid {
    pub values: Vec<f64>,
}

impl LrGrid {
    /// `count` points at resolution `10^(1/res_den)` around `center`
    /// (paper: res_den = 3 or 6, count 11-13).
    pub fn new(center: f64, res_den: u32, count: usize) -> Self {
        assert!(count >= 1 && res_den >= 1);
        let step = 10f64.powf(1.0 / res_den as f64);
        let half = (count / 2) as i32;
        let values = (-half..=(count as i32 - half - 1))
            .map(|i| center * step.powi(i))
            .collect();
        Self { values }
    }

    /// The quick 5-point grid the scaled harnesses default to.
    pub fn quick(center: f64) -> Self {
        Self::new(center, 3, 5)
    }
}

/// Outcome of a sweep: best run + diagnostics.
pub struct SweepResult {
    pub best_lr: f64,
    pub best: RunResult,
    /// (lr, rounds_to_target or None, final_accuracy) per grid point.
    pub table: Vec<(f64, Option<f64>, f64)>,
    /// true iff the best lr is strictly interior to the grid (the paper's
    /// sanity check that the grid was wide enough).
    pub interior: bool,
}

/// Score used for selection: fewest rounds to target if a target is set
/// (ties → higher final accuracy), else highest final accuracy.
fn better(
    a: (Option<f64>, f64),
    b: (Option<f64>, f64),
) -> bool {
    match (a.0, b.0) {
        (Some(x), Some(y)) if x != y => x < y,
        (Some(_), None) => true,
        (None, Some(_)) => false,
        _ => a.1 > b.1,
    }
}

/// Run `cfg` once per grid value (all other knobs fixed), return the best.
pub fn sweep_lr<F>(
    engine: &Engine,
    fed: &Federated,
    base: &FedConfig,
    grid: &LrGrid,
    mut opts_for: F,
) -> Result<SweepResult>
where
    F: FnMut(f64) -> ServerOptions,
{
    anyhow::ensure!(!grid.values.is_empty(), "empty lr grid");
    let mut best: Option<(usize, RunResult)> = None;
    let mut table = Vec::new();
    for (i, &lr) in grid.values.iter().enumerate() {
        let cfg = FedConfig {
            lr,
            ..base.clone()
        };
        let run = federated::run(engine, fed, &cfg, opts_for(lr))?;
        let rtt = base
            .target_accuracy
            .and_then(|t| run.accuracy.rounds_to_target(t));
        let fin = run.final_accuracy();
        table.push((lr, rtt, fin));
        let is_better = match &best {
            None => true,
            Some((bi, brun)) => {
                let b_rtt = base
                    .target_accuracy
                    .and_then(|t| brun.accuracy.rounds_to_target(t));
                let _ = bi;
                better((rtt, fin), (b_rtt, brun.final_accuracy()))
            }
        };
        if is_better {
            best = Some((i, run));
        }
    }
    let (bi, best_run) = best.unwrap();
    Ok(SweepResult {
        best_lr: grid.values[bi],
        best: best_run,
        interior: bi > 0 && bi + 1 < grid.values.len(),
        table,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_multiplicative_and_centered() {
        let g = LrGrid::new(0.1, 3, 5);
        assert_eq!(g.values.len(), 5);
        let step = 10f64.powf(1.0 / 3.0);
        assert!((g.values[2] - 0.1).abs() < 1e-12, "{:?}", g.values);
        for w in g.values.windows(2) {
            assert!((w[1] / w[0] - step).abs() < 1e-9);
        }
    }

    #[test]
    fn grid_resolution_matches_paper() {
        // 13 points at 10^(1/6) spans 10^2 = two decades
        let g = LrGrid::new(1.0, 6, 13);
        let span = g.values.last().unwrap() / g.values.first().unwrap();
        assert!((span - 100.0).abs() / 100.0 < 1e-9);
    }

    #[test]
    fn selection_prefers_fewer_rounds_then_accuracy() {
        assert!(better((Some(10.0), 0.9), (Some(20.0), 0.99)));
        assert!(better((Some(10.0), 0.9), (None, 0.99)));
        assert!(!better((None, 0.9), (Some(500.0), 0.2)));
        assert!(better((None, 0.95), (None, 0.9)));
        assert!(better((Some(10.0), 0.95), (Some(10.0), 0.9)));
    }
}
