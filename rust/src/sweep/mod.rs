//! Learning-rate grid search — the paper's tuning methodology (§3):
//! "a sufficiently wide grid of learning rates (typically 11-13 values
//! for η on a multiplicative grid of resolution 10^(1/3) or 10^(1/6))",
//! picking the best rate per configuration and *checking the optimum is
//! interior to the grid*.
//!
//! Two entry points share the [`LrGrid`] and selection rule:
//!
//! * [`run_cli`] — the `fedavg sweep` subcommand: each η is a
//!   fingerprinted cell in the [grid engine](crate::exper::grid), so the
//!   sweep is restartable (`--resume`), parallel (`--workers`), and
//!   deduplicated against every other grid's cells (DESIGN.md §9);
//! * [`sweep_lr`] — the in-process library path (`examples/lr_sweep.rs`)
//!   over an already-built [`Federated`] workload, for callers composing
//!   their own harness. Each grid point is a full
//!   [`federated::run`](crate::federated::run), so both paths inherit
//!   every harness feature (telemetry, fleet, transport codecs).

use crate::config::{BatchSize, FedConfig, Partition};
use crate::data::Federated;
use crate::exper::cells::{FedCell, GridCell, Workload};
use crate::exper::grid::{self, GridDef};
use crate::exper::{print_table, ExpOptions, COMMON_FLAGS};
use crate::federated::{self, RunResult, ServerOptions};
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

/// A multiplicative learning-rate grid centered at `center`.
#[derive(Debug, Clone)]
pub struct LrGrid {
    pub values: Vec<f64>,
}

impl LrGrid {
    /// `count` points at resolution `10^(1/res_den)` around `center`
    /// (paper: res_den = 3 or 6, count 11-13).
    pub fn new(center: f64, res_den: u32, count: usize) -> Self {
        assert!(count >= 1 && res_den >= 1);
        let step = 10f64.powf(1.0 / res_den as f64);
        let half = (count / 2) as i32;
        let values = (-half..=(count as i32 - half - 1))
            .map(|i| center * step.powi(i))
            .collect();
        Self { values }
    }

    /// The quick 5-point grid the scaled harnesses default to.
    pub fn quick(center: f64) -> Self {
        Self::new(center, 3, 5)
    }
}

/// Outcome of a sweep: best run + diagnostics.
pub struct SweepResult {
    pub best_lr: f64,
    pub best: RunResult,
    /// (lr, rounds_to_target or None, final_accuracy) per grid point.
    pub table: Vec<(f64, Option<f64>, f64)>,
    /// true iff the best lr is strictly interior to the grid (the paper's
    /// sanity check that the grid was wide enough).
    pub interior: bool,
}

/// `fedavg sweep` — the lr grid as a restartable, parallel grid of
/// cells: `--center/--points/--res` shape the multiplicative grid,
/// `--model/--partition/--c/--e/--b` the configuration under tune, and
/// the uniform sweep flags (`--workers/--resume/--dry-run/...`) come
/// from [`ExpOptions`].
pub fn run_cli(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(
        &[COMMON_FLAGS, &["center", "points", "res", "model", "partition", "c", "e", "b"]]
            .concat(),
    )?;
    let opts = ExpOptions::from_args(args)?;
    let center = args.f64_or("center", 0.1)?;
    let points = args.usize_or("points", 5)?;
    let res_den = args.usize_or("res", 3)? as u32;
    anyhow::ensure!(points >= 1 && res_den >= 1, "--points and --res must be >= 1");
    let model = args.str_or("model", "mnist_2nn");
    let part = Partition::parse(&args.str_or("partition", "iid"))?;
    let workload = match model.as_str() {
        "mnist_2nn" | "mnist_cnn" => Workload::Mnist {
            scale: opts.scale,
            part,
            seed: opts.seed,
        },
        "cifar_cnn" => Workload::Cifar {
            scale: opts.scale,
            seed: opts.seed,
        },
        "shakespeare_lstm" => Workload::Shakespeare {
            scale: opts.scale,
            natural: part == Partition::Natural,
            seed: opts.seed,
        },
        "word_lstm" => Workload::Social {
            scale: opts.scale,
            seed: opts.seed,
        },
        other => anyhow::bail!("sweep: unknown model {other}"),
    };
    let base = FedConfig {
        model: model.clone(),
        c: args.f64_or("c", 0.1)?,
        e: args.usize_or("e", 1)?,
        b: BatchSize::parse(&args.str_or("b", "10"))?,
        rounds: opts.rounds,
        target_accuracy: opts.target,
        seed: opts.seed,
        ..Default::default()
    };
    let lr_grid = LrGrid::new(center, res_den, points);
    println!(
        "lr sweep: {} — η over {:?} (10^(1/{res_den}) grid, paper §3 methodology)",
        base.label(),
        lr_grid
            .values
            .iter()
            .map(|v| format!("{v:.4}"))
            .collect::<Vec<_>>()
    );

    // per-model grid name: tuning several models in sequence must not
    // trip the stale-manifest refusal (cells dedupe via the shared pool
    // regardless)
    let mut def = GridDef::new(format!("sweep-{model}"));
    for &lr in &lr_grid.values {
        let cfg = FedConfig { lr, ..base.clone() };
        def.cell(
            format!("sweep-{model}-lr{lr}"),
            GridCell::Fed(FedCell::new(workload.clone(), cfg, opts.eval_cap)),
        );
    }
    let Some(report) = grid::run(def, Some(engine), &opts.grid_options())? else {
        return Ok(()); // --dry-run
    };

    let mut rows = Vec::new();
    let mut best: Option<(usize, (Option<f64>, f64))> = None;
    for (i, (&lr, out)) in lr_grid.values.iter().zip(&report.outcomes).enumerate() {
        let rtt = out.num("rtt");
        let fin = out.num("final_acc").unwrap_or(0.0);
        rows.push(vec![
            format!("{lr:.4}"),
            rtt.map(|r| format!("{r:.1}")).unwrap_or_else(|| "—".into()),
            format!("{fin:.4}"),
        ]);
        if best.map_or(true, |(_, b)| better((rtt, fin), b)) {
            best = Some((i, (rtt, fin)));
        }
    }
    let (bi, (_, best_fin)) = best.expect("at least one grid point");
    print_table(
        &format!(
            "LR sweep — {} (target {}, scale {})",
            base.label(),
            opts.target
                .map(|t| format!("{:.0}%", t * 100.0))
                .unwrap_or_else(|| "none".into()),
            opts.scale
        ),
        &["lr", "rds-to-target", "final acc"],
        &rows,
    );
    let interior = bi > 0 && bi + 1 < lr_grid.values.len();
    println!(
        "best η = {:.4} (final acc {best_fin:.4}); optimum interior to grid: {}",
        lr_grid.values[bi],
        if interior { "yes ✓" } else { "NO — widen the grid" }
    );
    Ok(())
}

/// Score used for selection: fewest rounds to target if a target is set
/// (ties → higher final accuracy), else highest final accuracy.
fn better(
    a: (Option<f64>, f64),
    b: (Option<f64>, f64),
) -> bool {
    match (a.0, b.0) {
        (Some(x), Some(y)) if x != y => x < y,
        (Some(_), None) => true,
        (None, Some(_)) => false,
        _ => a.1 > b.1,
    }
}

/// Run `cfg` once per grid value (all other knobs fixed), return the best.
pub fn sweep_lr<F>(
    engine: &Engine,
    fed: &Federated,
    base: &FedConfig,
    grid: &LrGrid,
    mut opts_for: F,
) -> Result<SweepResult>
where
    F: FnMut(f64) -> ServerOptions,
{
    anyhow::ensure!(!grid.values.is_empty(), "empty lr grid");
    let mut best: Option<(usize, RunResult)> = None;
    let mut table = Vec::new();
    for (i, &lr) in grid.values.iter().enumerate() {
        let cfg = FedConfig {
            lr,
            ..base.clone()
        };
        let run = federated::run(engine, fed, &cfg, opts_for(lr))?;
        let rtt = base
            .target_accuracy
            .and_then(|t| run.accuracy.rounds_to_target(t));
        let fin = run.final_accuracy();
        table.push((lr, rtt, fin));
        let is_better = match &best {
            None => true,
            Some((bi, brun)) => {
                let b_rtt = base
                    .target_accuracy
                    .and_then(|t| brun.accuracy.rounds_to_target(t));
                let _ = bi;
                better((rtt, fin), (b_rtt, brun.final_accuracy()))
            }
        };
        if is_better {
            best = Some((i, run));
        }
    }
    let (bi, best_run) = best.unwrap();
    Ok(SweepResult {
        best_lr: grid.values[bi],
        best: best_run,
        interior: bi > 0 && bi + 1 < grid.values.len(),
        table,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_multiplicative_and_centered() {
        let g = LrGrid::new(0.1, 3, 5);
        assert_eq!(g.values.len(), 5);
        let step = 10f64.powf(1.0 / 3.0);
        assert!((g.values[2] - 0.1).abs() < 1e-12, "{:?}", g.values);
        for w in g.values.windows(2) {
            assert!((w[1] / w[0] - step).abs() < 1e-9);
        }
    }

    #[test]
    fn grid_resolution_matches_paper() {
        // 13 points at 10^(1/6) spans 10^2 = two decades
        let g = LrGrid::new(1.0, 6, 13);
        let span = g.values.last().unwrap() / g.values.first().unwrap();
        assert!((span - 100.0).abs() / 100.0 < 1e-9);
    }

    #[test]
    fn selection_prefers_fewer_rounds_then_accuracy() {
        assert!(better((Some(10.0), 0.9), (Some(20.0), 0.99)));
        assert!(better((Some(10.0), 0.9), (None, 0.99)));
        assert!(!better((None, 0.9), (Some(500.0), 0.2)));
        assert!(better((None, 0.95), (None, 0.9)));
        assert!(better((Some(10.0), 0.95), (Some(10.0), 0.9)));
    }
}
