//! Synthetic MNIST stand-in (no network access in this image — DESIGN.md
//! §2 substitution note).
//!
//! Ten classes of 28x28 grayscale "digits": each class is a fixed template
//! built from seeded Gaussian strokes; each example is its class template
//! under a random sub-pixel shift, intensity scale, elastic wobble and
//! additive noise. The result is linearly non-trivial but comfortably
//! learnable by the paper's 2NN and CNN — what the MNIST experiments need
//! (relative round counts, not absolute accuracy, are the reproduction
//! target).

use crate::data::rng::Rng;
use crate::data::{Dataset, Examples};

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

/// Class template: sum of a few oriented Gaussian strokes.
struct Template {
    // stroke params: (cx, cy, sx, sy, angle, amplitude)
    strokes: Vec<(f32, f32, f32, f32, f32, f32)>,
}

impl Template {
    fn new(rng: &mut Rng) -> Self {
        // 4-7 strokes per digit-ish glyph
        let n = 4 + rng.below(4);
        let strokes = (0..n)
            .map(|_| {
                let cx = 6.0 + 16.0 * rng.f32();
                let cy = 6.0 + 16.0 * rng.f32();
                let sx = 1.2 + 3.5 * rng.f32();
                let sy = 0.8 + 1.6 * rng.f32();
                let angle = std::f32::consts::PI * rng.f32();
                let amp = 0.6 + 0.4 * rng.f32();
                (cx, cy, sx, sy, angle, amp)
            })
            .collect();
        Template { strokes }
    }

    /// Render at sub-pixel offset (dx, dy) with elastic wobble `wob`.
    fn render(&self, dx: f32, dy: f32, wob: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), DIM);
        for (i, px) in out.iter_mut().enumerate() {
            let x = (i % SIDE) as f32;
            let y = (i / SIDE) as f32;
            let mut v = 0.0f32;
            for &(cx, cy, sx, sy, a, amp) in &self.strokes {
                // wobble bends stroke centers slightly, varying per example
                let wx = cx + dx + wob * (0.35 * y).sin();
                let wy = cy + dy + wob * (0.35 * x).cos();
                let (sa, ca) = a.sin_cos();
                let rx = ca * (x - wx) + sa * (y - wy);
                let ry = -sa * (x - wx) + ca * (y - wy);
                let d = (rx / sx) * (rx / sx) + (ry / sy) * (ry / sy);
                v += amp * (-0.5 * d).exp();
            }
            *px = v.min(1.0);
        }
    }
}

/// Deterministic generator for train+test splits sharing class templates.
pub struct MnistLike {
    templates: Vec<Template>,
    seed: u64,
}

impl MnistLike {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x13371);
        // Confusable structure: classes c and c+5 share a base glyph and
        // differ only by two extra strokes — like 3/8 or 4/9 in MNIST.
        // This keeps the task hard enough that round counts spread out.
        let bases: Vec<Template> = (0..CLASSES / 2).map(|_| Template::new(&mut rng)).collect();
        let templates = (0..CLASSES)
            .map(|c| {
                let mut t = Template {
                    strokes: bases[c % (CLASSES / 2)].strokes.clone(),
                };
                for _ in 0..2 {
                    let cx = 6.0 + 16.0 * rng.f32();
                    let cy = 6.0 + 16.0 * rng.f32();
                    let sx = 1.0 + 2.5 * rng.f32();
                    let sy = 0.8 + 1.2 * rng.f32();
                    let angle = std::f32::consts::PI * rng.f32();
                    let amp = 0.5 + 0.3 * rng.f32();
                    t.strokes.push((cx, cy, sx, sy, angle, amp));
                }
                t
            })
            .collect();
        Self { templates, seed }
    }

    /// Generate `n` examples with balanced labels. `stream` separates
    /// train (0) from test (1) draws.
    pub fn dataset(&self, n: usize, stream: u64) -> Dataset {
        let mut rng = Rng::new(self.seed ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        let mut x = vec![0.0f32; n * DIM];
        let mut y = vec![0i32; n];
        let mut buf = vec![0.0f32; DIM];
        for i in 0..n {
            let class = i % CLASSES; // balanced
            let dx = 5.0 * rng.f32() - 2.5;
            let dy = 5.0 * rng.f32() - 2.5;
            let wob = 2.0 * rng.f32();
            let gain = 0.6 + 0.7 * rng.f32();
            self.templates[class].render(dx, dy, wob, &mut buf);
            let dst = &mut x[i * DIM..(i + 1) * DIM];
            for (d, &s) in dst.iter_mut().zip(&buf) {
                let noise = 0.18 * rng.gauss_f32();
                *d = (gain * s + noise).clamp(0.0, 1.0);
            }
            y[i] = class as i32;
        }
        // shuffle example order so "sorted by label" is a real operation
        // for the pathological partitioner (mirrors the real MNIST layout)
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let mut xs = vec![0.0f32; n * DIM];
        let mut ys = vec![0i32; n];
        for (new, &old) in perm.iter().enumerate() {
            xs[new * DIM..(new + 1) * DIM].copy_from_slice(&x[old * DIM..(old + 1) * DIM]);
            ys[new] = y[old];
        }
        Dataset {
            name: format!("mnist_like(seed={}, n={n}, stream={stream})", self.seed),
            examples: Examples::Image {
                x: xs,
                y: ys,
                dim: DIM,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_separated() {
        let g = MnistLike::new(5);
        let a = g.dataset(50, 0);
        let b = g.dataset(50, 0);
        let t = g.dataset(50, 1);
        match (&a.examples, &b.examples, &t.examples) {
            (
                Examples::Image { x: xa, .. },
                Examples::Image { x: xb, .. },
                Examples::Image { x: xt, .. },
            ) => {
                assert_eq!(xa, xb);
                assert_ne!(xa, xt, "test stream must differ from train");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn labels_balanced_and_pixels_in_range() {
        let g = MnistLike::new(6);
        let d = g.dataset(200, 0);
        let Examples::Image { x, y, dim } = &d.examples else {
            unreachable!()
        };
        assert_eq!(*dim, 784);
        let mut counts = [0usize; 10];
        for &l in y {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
        assert!(x.iter().all(|&p| (0.0..=1.0).contains(&p)));
        // images are not blank
        let mean: f32 = x.iter().sum::<f32>() / x.len() as f32;
        assert!(mean > 0.02 && mean < 0.8, "mean pixel {mean}");
    }

    #[test]
    fn classes_are_visually_distinct() {
        // within-class distance should be smaller than between-class
        let g = MnistLike::new(7);
        let d = g.dataset(100, 0);
        let Examples::Image { x, y, dim } = &d.examples else {
            unreachable!()
        };
        let ex = |i: usize| &x[i * dim..(i + 1) * dim];
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum()
        };
        let c0: Vec<usize> = (0..100).filter(|&i| y[i] == 0).collect();
        let c1: Vec<usize> = (0..100).filter(|&i| y[i] == 1).collect();
        let within = dist(ex(c0[0]), ex(c0[1]));
        let between = dist(ex(c0[0]), ex(c1[0]));
        assert!(
            between > 1.2 * within,
            "classes not separable: within {within} between {between}"
        );
    }
}
