//! Synthetic social-network post corpus — the large-scale word-LM data
//! (paper §3 "Large-scale LSTM experiments"; proprietary, so synthesized —
//! DESIGN.md §2).
//!
//! Structural properties preserved: posts grouped by author (clients),
//! 10k-word vocabulary, unroll 10, per-author topic skew (non-IID), author
//! dataset size capped (paper: 5000 words), and a test set drawn from
//! *held-out authors* (paper: "a test set of 1e5 posts from different
//! (non-training) authors").
//!
//! Generative process: a handful of topics, each a permutation-successor
//! bigram model over a Zipf unigram; authors mix 1-2 topics.

use crate::data::rng::Rng;
use crate::data::{Dataset, Examples, Federated};

pub const VOCAB: usize = 10_000;
pub const UNROLL: usize = 10;
pub const TOPICS: usize = 8;

#[derive(Debug, Clone)]
pub struct SocialConfig {
    pub authors: usize,
    /// Mean posts per author (Zipf-skewed).
    pub mean_posts: usize,
    /// Held-out authors for the test set.
    pub test_authors: usize,
    pub seed: u64,
}

impl Default for SocialConfig {
    fn default() -> Self {
        // paper scale is 500k authors / 10M posts; scaled default keeps
        // the shape (hundreds of authors) — configs can raise it.
        Self {
            authors: 400,
            mean_posts: 30,
            test_authors: 60,
            seed: 0,
        }
    }
}

struct Topic {
    /// successor word for strong-bigram draws
    next: Vec<u32>,
    /// Zipf skew for unigram draws
    zipf_s: f64,
    /// topic's vocabulary offset (rotates the Zipf head per topic)
    offset: u32,
}

impl Topic {
    fn new(rng: &mut Rng) -> Self {
        // a pseudo-random permutation via affine map (a odd => bijection
        // mod 2^k not vocab; use mul-mod with prime vocab-close modulus)
        let a = 2 * (1 + rng.below(4999)) as u32 + 1;
        let b = rng.below(VOCAB) as u32;
        let next = (0..VOCAB as u32)
            .map(|w| (w.wrapping_mul(a).wrapping_add(b)) % VOCAB as u32)
            .collect();
        Topic {
            next,
            zipf_s: 1.05 + 0.2 * rng.f64(),
            offset: rng.below(VOCAB) as u32,
        }
    }

    fn unigram(&self, rng: &mut Rng) -> u32 {
        let r = rng.zipf(2000, self.zipf_s) as u32; // head of 2000 words
        (r - 1 + self.offset) % VOCAB as u32
    }

    fn step(&self, prev: u32, rng: &mut Rng) -> u32 {
        if rng.f64() < 0.65 {
            self.next[prev as usize]
        } else {
            self.unigram(rng)
        }
    }
}

/// Build the by-author federated corpus plus held-out-author test set.
pub fn by_author(cfg: &SocialConfig) -> Federated {
    let mut rng = Rng::new(cfg.seed ^ 0x50C1A1);
    let topics: Vec<Topic> = (0..TOPICS).map(|_| Topic::new(&mut rng)).collect();

    let gen_author_rows = |author: u64, rng: &mut Rng, out: &mut Vec<(Vec<i32>, Vec<i32>, Vec<f32>)>| {
        let mut arng = rng.child(author + 1);
        let t_main = arng.below(TOPICS);
        let t_alt = arng.below(TOPICS);
        let z = arng.zipf(40, 1.1);
        let posts = 1 + (cfg.mean_posts * z) / 8;
        // cap: paper limits each client to 5000 words
        let posts = posts.min(5000 / (UNROLL + 1));
        for _ in 0..posts {
            let topic = if arng.f64() < 0.8 { t_main } else { t_alt };
            let tp = &topics[topic];
            let mut words = Vec::with_capacity(UNROLL + 1);
            words.push(tp.unigram(&mut arng));
            for _ in 0..UNROLL {
                let prev = *words.last().unwrap();
                words.push(tp.step(prev, &mut arng));
            }
            let x: Vec<i32> = words[..UNROLL].iter().map(|&w| w as i32).collect();
            let y: Vec<i32> = words[1..].iter().map(|&w| w as i32).collect();
            let w = vec![1.0f32; UNROLL];
            out.push((x, y, w));
        }
    };

    let mut train_rows = Vec::new();
    let mut clients = Vec::with_capacity(cfg.authors);
    for a in 0..cfg.authors {
        let base = train_rows.len();
        gen_author_rows(a as u64, &mut rng, &mut train_rows);
        clients.push((base..train_rows.len()).collect());
    }
    // held-out authors (ids beyond the training range) form the test set
    let mut test_rows = Vec::new();
    for a in 0..cfg.test_authors {
        gen_author_rows((cfg.authors + a) as u64, &mut rng, &mut test_rows);
    }

    Federated {
        train: rows_to_dataset(train_rows, format!("social_like/train(seed={})", cfg.seed)),
        test: rows_to_dataset(test_rows, format!("social_like/test(seed={})", cfg.seed)),
        clients,
    }
}

fn rows_to_dataset(rows: Vec<(Vec<i32>, Vec<i32>, Vec<f32>)>, name: String) -> Dataset {
    let n = rows.len();
    let mut x = Vec::with_capacity(n * UNROLL);
    let mut y = Vec::with_capacity(n * UNROLL);
    let mut w = Vec::with_capacity(n * UNROLL);
    for (rx, ry, rw) in rows {
        x.extend(rx);
        y.extend(ry);
        w.extend(rw);
    }
    Dataset {
        name,
        examples: Examples::Tokens {
            x,
            y,
            w,
            t: UNROLL,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SocialConfig {
        SocialConfig {
            authors: 50,
            mean_posts: 10,
            test_authors: 10,
            seed: 4,
        }
    }

    #[test]
    fn structure_and_caps() {
        let fed = by_author(&cfg());
        assert_eq!(fed.num_clients(), 50);
        assert!(fed.test.len() > 0);
        for c in &fed.clients {
            assert!(!c.is_empty());
            // word cap per client (paper: 5000)
            assert!(c.len() * (UNROLL + 1) <= 5000 + UNROLL);
        }
    }

    #[test]
    fn vocab_in_range_and_bigram_structure() {
        let fed = by_author(&cfg());
        let Examples::Tokens { x, y, w, t } = &fed.train.examples else {
            unreachable!()
        };
        assert_eq!(*t, UNROLL);
        assert!(x.iter().all(|&v| (0..VOCAB as i32).contains(&v)));
        assert!(y.iter().all(|&v| (0..VOCAB as i32).contains(&v)));
        assert!(w.iter().all(|&v| v == 1.0));
        // shifted alignment within rows
        for r in 0..fed.train.len().min(30) {
            for i in 0..*t - 1 {
                assert_eq!(x[r * t + i + 1], y[r * t + i]);
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = by_author(&cfg());
        let b = by_author(&cfg());
        match (&a.train.examples, &b.train.examples) {
            (Examples::Tokens { x: xa, .. }, Examples::Tokens { x: xb, .. }) => {
                assert_eq!(xa, xb)
            }
            _ => unreachable!(),
        }
    }
}
