//! Synthetic playwright — the Shakespeare corpus stand-in (DESIGN.md §2).
//!
//! The paper builds a client per *speaking role* (1146 clients), with
//! heavily unbalanced line counts and a temporal 80/20 train/test split
//! per role. We reproduce those *structural* properties with a seeded
//! generative process:
//!
//! * a global order-1 character Markov model (shared linguistic core — a
//!   global next-char model is learnable across clients),
//! * per-role style: each role interpolates toward its own private
//!   successor preferences (non-IID: a role's local distribution is a
//!   biased, narrow slice of the global one),
//! * Zipf-distributed lines-per-role (unbalanced),
//! * per-role 80/20 temporal split (test = last 20% of lines, >=1).
//!
//! Characters are ids in `[0, VOCAB)`; lines become next-char LM rows of
//! unroll `T` with per-token weights (0 = padding).

use crate::data::rng::Rng;
use crate::data::{Dataset, Examples, Federated};

pub const VOCAB: usize = 90;
pub const UNROLL: usize = 80;

/// Global + per-role character transition structure.
struct Style {
    /// For each prev char: a few strongly preferred successors.
    global: Vec<[u8; 4]>,
}

impl Style {
    fn new(rng: &mut Rng) -> Self {
        let global = (0..VOCAB)
            .map(|_| {
                let mut succ = [0u8; 4];
                for s in succ.iter_mut() {
                    *s = rng.below(VOCAB) as u8;
                }
                succ
            })
            .collect();
        Style { global }
    }

    /// Sample the next char: global preference (60%), role preference
    /// (25%), uniform exploration (15%).
    fn next(&self, prev: usize, role_pref: &[u8], rng: &mut Rng) -> usize {
        let r = rng.f64();
        if r < 0.60 {
            self.global[prev][rng.below(4)] as usize
        } else if r < 0.85 {
            role_pref[(prev + rng.below(3)) % role_pref.len()] as usize
        } else {
            rng.below(VOCAB)
        }
    }
}

/// Configuration for corpus synthesis.
#[derive(Debug, Clone)]
pub struct PlayConfig {
    pub roles: usize,
    /// Mean lines per role (actual counts are Zipf-skewed around this).
    pub mean_lines: usize,
    /// Zipf exponent for the lines-per-role distribution.
    pub zipf_s: f64,
    pub seed: u64,
}

impl Default for PlayConfig {
    fn default() -> Self {
        // paper scale: 1146 roles; our scaled default keeps the shape
        Self {
            roles: 1146,
            mean_lines: 60,
            zipf_s: 1.1,
            seed: 0,
        }
    }
}

/// Build the by-role (natural, unbalanced, non-IID) federated corpus.
pub fn by_role(cfg: &PlayConfig) -> Federated {
    let (train_rows, test_rows, clients) = synthesize(cfg);
    pack(train_rows, test_rows, clients, cfg, "by_role")
}

/// Build the balanced IID counterpart: same lines, shuffled and dealt
/// evenly over the same number of clients (paper §3).
pub fn iid(cfg: &PlayConfig) -> Federated {
    let (train_rows, test_rows, _) = synthesize(cfg);
    let n = train_rows.len();
    let mut rng = Rng::new(cfg.seed ^ 0x11D);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let k = cfg.roles;
    let mut clients = vec![Vec::new(); k];
    for (pos, &row) in idx.iter().enumerate() {
        clients[pos % k].push(row);
    }
    pack(train_rows, test_rows, clients, cfg, "iid")
}

type Row = (Vec<i32>, Vec<i32>, Vec<f32>); // (x, y, w) each UNROLL long

fn synthesize(cfg: &PlayConfig) -> (Vec<Row>, Vec<Row>, Vec<Vec<usize>>) {
    let mut rng = Rng::new(cfg.seed ^ 0x5A4E5);
    let style = Style::new(&mut rng);

    let mut train_rows: Vec<Row> = Vec::new();
    let mut test_rows: Vec<Row> = Vec::new();
    let mut clients: Vec<Vec<usize>> = Vec::with_capacity(cfg.roles);

    for role in 0..cfg.roles {
        let mut role_rng = rng.child(role as u64 + 1);
        // role style: private preferred-successor table
        let role_pref: Vec<u8> = (0..16).map(|_| role_rng.below(VOCAB) as u8).collect();
        // Zipf line count, always >= 2 (paper: roles with >= 2 lines)
        let z = role_rng.zipf(50, cfg.zipf_s); // 1..=50, mean ~ small
        let lines = 2 + (cfg.mean_lines * z) / 8;

        let mut role_train = Vec::new();
        let n_test = ((lines as f64 * 0.2).ceil() as usize).max(1);
        let n_train = lines - n_test;
        for line_i in 0..lines {
            // a line: random start char then Markov walk
            let len = 12 + role_rng.below(UNROLL - 12); // 12..80 chars
            let mut chars = Vec::with_capacity(len + 1);
            chars.push(role_rng.below(VOCAB));
            for _ in 0..len {
                let prev = *chars.last().unwrap();
                chars.push(style.next(prev, &role_pref, &mut role_rng));
            }
            let mut x = vec![0i32; UNROLL];
            let mut y = vec![0i32; UNROLL];
            let mut w = vec![0.0f32; UNROLL];
            for i in 0..len.min(UNROLL) {
                x[i] = chars[i] as i32;
                y[i] = chars[i + 1] as i32;
                w[i] = 1.0;
            }
            let row = (x, y, w);
            if line_i < n_train {
                role_train.push(row);
            } else {
                test_rows.push(row); // temporal split: last 20% per role
            }
        }
        let base = train_rows.len();
        let idxs: Vec<usize> = (0..role_train.len()).map(|i| base + i).collect();
        train_rows.extend(role_train);
        clients.push(idxs);
    }
    (train_rows, test_rows, clients)
}

fn pack(
    train_rows: Vec<Row>,
    test_rows: Vec<Row>,
    clients: Vec<Vec<usize>>,
    cfg: &PlayConfig,
    tag: &str,
) -> Federated {
    Federated {
        train: rows_to_dataset(train_rows, format!("shakespeare_like/{tag}/train(seed={})", cfg.seed)),
        test: rows_to_dataset(test_rows, format!("shakespeare_like/{tag}/test(seed={})", cfg.seed)),
        clients,
    }
}

fn rows_to_dataset(rows: Vec<Row>, name: String) -> Dataset {
    let n = rows.len();
    let mut x = Vec::with_capacity(n * UNROLL);
    let mut y = Vec::with_capacity(n * UNROLL);
    let mut w = Vec::with_capacity(n * UNROLL);
    for (rx, ry, rw) in rows {
        x.extend(rx);
        y.extend(ry);
        w.extend(rw);
    }
    Dataset {
        name,
        examples: Examples::Tokens {
            x,
            y,
            w,
            t: UNROLL,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> PlayConfig {
        PlayConfig {
            roles: 40,
            mean_lines: 20,
            zipf_s: 1.1,
            seed: 3,
        }
    }

    #[test]
    fn by_role_structure() {
        let fed = by_role(&small_cfg());
        assert_eq!(fed.num_clients(), 40);
        // every client holds >= 1 train line; every index valid & unique
        let mut seen = vec![false; fed.train.len()];
        for c in &fed.clients {
            assert!(!c.is_empty());
            for &i in c {
                assert!(!seen[i], "index {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "orphan training rows");
        assert!(fed.test.len() > 0);
    }

    #[test]
    fn unbalanced_line_counts() {
        let fed = by_role(&PlayConfig {
            roles: 200,
            ..small_cfg()
        });
        let mut sizes = fed.client_sizes();
        sizes.sort_unstable();
        // Zipf: the head must dominate the median
        assert!(
            sizes[199] >= 4 * sizes[100].max(1),
            "not unbalanced: max {} median {}",
            sizes[199],
            sizes[100]
        );
    }

    #[test]
    fn iid_is_balanced_same_rows() {
        let cfg = small_cfg();
        let nat = by_role(&cfg);
        let flat = iid(&cfg);
        assert_eq!(nat.train.len(), flat.train.len());
        let sizes = flat.client_sizes();
        let (min, max) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "iid not balanced: {min}..{max}");
    }

    #[test]
    fn rows_are_valid_next_char_pairs() {
        let fed = by_role(&small_cfg());
        let Examples::Tokens { x, y, w, t } = &fed.train.examples else {
            unreachable!()
        };
        assert_eq!(*t, UNROLL);
        for r in 0..fed.train.len().min(50) {
            let row = r * t;
            let mut in_pad = false;
            for i in 0..*t {
                let wi = w[row + i];
                assert!(wi == 0.0 || wi == 1.0);
                if wi == 0.0 {
                    in_pad = true;
                } else {
                    assert!(!in_pad, "weight rises after padding at row {r}");
                    assert!((0..VOCAB as i32).contains(&x[row + i]));
                    assert!((0..VOCAB as i32).contains(&y[row + i]));
                }
                // x shifted by one equals y where both valid
                if i + 1 < *t && w[row + i] == 1.0 && w[row + i + 1] == 1.0 {
                    assert_eq!(x[row + i + 1], y[row + i], "not a next-char row");
                }
            }
        }
    }

    #[test]
    fn roles_have_distinct_styles() {
        // role-conditional successor histograms should differ across roles
        let fed = by_role(&PlayConfig {
            roles: 2,
            mean_lines: 400,
            zipf_s: 0.01, // near-equal sizes: isolate style difference
            seed: 9,
        });
        let Examples::Tokens { x, y, w, t } = &fed.train.examples else {
            unreachable!()
        };
        let mut hist = [[0f64; VOCAB]; 2];
        for (cl, idxs) in fed.clients.iter().enumerate() {
            for &r in idxs {
                for i in 0..*t {
                    if w[r * t + i] == 1.0 && x[r * t + i] == 7 {
                        hist[cl][y[r * t + i] as usize] += 1.0;
                    }
                }
            }
        }
        for h in hist.iter_mut() {
            let s: f64 = h.iter().sum();
            if s > 0.0 {
                h.iter_mut().for_each(|v| *v /= s);
            }
        }
        let l1: f64 = (0..VOCAB).map(|v| (hist[0][v] - hist[1][v]).abs()).sum();
        assert!(l1 > 0.15, "roles statistically identical: L1 {l1}");
    }
}
