//! Client partitioning schemes — §3 of the paper.
//!
//! * **IID**: shuffle, then split into `k` equal shards.
//! * **Pathological non-IID**: sort by label, cut into `k * s` shards,
//!   deal each client `s` shards — "most clients will only have examples
//!   of two digits" for s=2 on MNIST.
//! * **Unbalanced**: Zipf-sized client datasets (footnote 4).

use crate::data::rng::Rng;

/// IID: shuffle and deal `n` examples into `k` equal(±1) shards.
pub fn iid(n: usize, k: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(k >= 1 && n >= k, "iid: n={n} k={k}");
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    split_even(idx, k)
}

/// Pathological non-IID: sort by label, `k*shards_per_client` contiguous
/// shards, assign each client `shards_per_client` shards at random.
/// With `shards_per_client = 2` on MNIST this is the paper's
/// 2-digits-per-client partition.
pub fn pathological(
    labels: &[i32],
    k: usize,
    shards_per_client: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let n = labels.len();
    let total_shards = k * shards_per_client;
    assert!(total_shards <= n, "pathological: {total_shards} shards > {n} examples");
    let mut idx: Vec<usize> = (0..n).collect();
    // stable sort by label keeps determinism
    idx.sort_by_key(|&i| labels[i]);
    let shard_size = n / total_shards;
    let mut shard_ids: Vec<usize> = (0..total_shards).collect();
    rng.shuffle(&mut shard_ids);
    let mut clients = vec![Vec::new(); k];
    for (pos, &shard) in shard_ids.iter().enumerate() {
        let client = pos / shards_per_client;
        let lo = shard * shard_size;
        let hi = if shard == total_shards - 1 { n } else { lo + shard_size };
        clients[client].extend_from_slice(&idx[lo..hi]);
    }
    clients
}

/// Unbalanced: Zipf-distributed client sizes over a shuffled pool
/// (every example assigned exactly once; every client gets >= 1).
pub fn unbalanced_zipf(n: usize, k: usize, s: f64, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(n >= k);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    // raw Zipf weights, normalized to sizes summing to n with min 1
    let raw: Vec<f64> = (1..=k).map(|r| 1.0 / (r as f64).powf(s)).collect();
    // lint:allow(float-fold): fold over ranks 1..=k in ascending order — a fixed sequence, identical everywhere.
    let total: f64 = raw.iter().sum();
    let mut sizes: Vec<usize> = raw
        .iter()
        .map(|w| ((w / total) * n as f64).floor().max(1.0) as usize)
        .collect();
    // fix rounding drift
    let mut assigned: usize = sizes.iter().sum();
    let mut i = 0;
    while assigned < n {
        sizes[i % k] += 1;
        assigned += 1;
        i += 1;
    }
    while assigned > n {
        let j = sizes.iter().position(|&s| s > 1).expect("shrinkable");
        sizes[j] -= 1;
        assigned -= 1;
    }
    // deal in shuffled-client order so size rank isn't tied to client id
    let mut order: Vec<usize> = (0..k).collect();
    rng.shuffle(&mut order);
    let mut clients = vec![Vec::new(); k];
    let mut cursor = 0;
    for (&client, &size) in order.iter().zip(&sizes) {
        clients[client] = idx[cursor..cursor + size].to_vec();
        cursor += size;
    }
    clients
}

fn split_even(idx: Vec<usize>, k: usize) -> Vec<Vec<usize>> {
    let n = idx.len();
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut cursor = 0;
    for c in 0..k {
        let size = base + usize::from(c < extra);
        out.push(idx[cursor..cursor + size].to_vec());
        cursor += size;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_exact_partition(clients: &[Vec<usize>], n: usize) {
        let mut all: Vec<usize> = clients.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "not an exact partition");
    }

    #[test]
    fn iid_partition_exact_and_even() {
        let mut rng = Rng::new(1);
        let c = iid(1000, 100, &mut rng);
        is_exact_partition(&c, 1000);
        assert!(c.iter().all(|cl| cl.len() == 10));
    }

    #[test]
    fn iid_uneven_remainder() {
        let mut rng = Rng::new(2);
        let c = iid(103, 10, &mut rng);
        is_exact_partition(&c, 103);
        let sizes: Vec<usize> = c.iter().map(|v| v.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().all(|&s| s == 10 || s == 11));
    }

    #[test]
    fn pathological_two_digits_per_client() {
        // 600 examples, 10 labels, 20 clients x 2 shards of 15
        let labels: Vec<i32> = (0..600).map(|i| (i / 60) as i32).collect();
        let mut rng = Rng::new(3);
        let clients = pathological(&labels, 20, 2, &mut rng);
        is_exact_partition(&clients, 600);
        for cl in &clients {
            let mut ls: Vec<i32> = cl.iter().map(|&i| labels[i]).collect();
            ls.sort_unstable();
            ls.dedup();
            // each client holds at most 2 distinct labels + shard boundaries
            // can straddle a label change, so allow <= 4 but typical 1-2
            assert!(ls.len() <= 4, "client sees {} labels", ls.len());
        }
        // crucially: the vast majority see <= 2 labels (paper's "most
        // clients will only have examples of two digits")
        let le2 = clients
            .iter()
            .filter(|cl| {
                let mut ls: Vec<i32> = cl.iter().map(|&i| labels[i]).collect();
                ls.sort_unstable();
                ls.dedup();
                ls.len() <= 2
            })
            .count();
        assert!(le2 >= 15, "only {le2}/20 clients are <=2-label");
    }

    #[test]
    fn unbalanced_sizes_are_zipfy() {
        let mut rng = Rng::new(4);
        let clients = unbalanced_zipf(10_000, 100, 1.2, &mut rng);
        is_exact_partition(&clients, 10_000);
        let mut sizes: Vec<usize> = clients.iter().map(|c| c.len()).collect();
        assert!(sizes.iter().all(|&s| s >= 1));
        sizes.sort_unstable();
        // heavy head: biggest client much bigger than median
        assert!(sizes[99] > 5 * sizes[50], "{:?}", &sizes[90..]);
    }

    #[test]
    fn partitions_deterministic() {
        let a = iid(100, 7, &mut Rng::new(9));
        let b = iid(100, 7, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
