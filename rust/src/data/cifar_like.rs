//! Synthetic CIFAR-10 stand-in (DESIGN.md §2 substitution note).
//!
//! Ten classes of 24x24 RGB images (the paper's preprocessed crop size).
//! Each class pairs a color palette with a textural signature (sinusoidal
//! gratings at class-specific frequency/orientation plus blob structure),
//! so classes are separable by a conv net but not by mean color alone.

use crate::data::rng::Rng;
use crate::data::{Dataset, Examples};

pub const SIDE: usize = 24;
pub const DIM: usize = SIDE * SIDE * 3;
pub const CLASSES: usize = 10;

struct ClassSpec {
    base: [f32; 3],
    freq: f32,
    orient: f32,
    blob_cx: f32,
    blob_cy: f32,
    blob_s: f32,
    blob_color: [f32; 3],
}

pub struct CifarLike {
    specs: Vec<ClassSpec>,
    seed: u64,
}

impl CifarLike {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1FA5);
        let specs = (0..CLASSES)
            .map(|c| ClassSpec {
                base: [rng.f32() * 0.6, rng.f32() * 0.6, rng.f32() * 0.6],
                freq: 0.3 + 0.25 * (c as f32) + 0.2 * rng.f32(),
                orient: std::f32::consts::PI * rng.f32(),
                blob_cx: 4.0 + 16.0 * rng.f32(),
                blob_cy: 4.0 + 16.0 * rng.f32(),
                blob_s: 2.0 + 4.0 * rng.f32(),
                blob_color: [rng.f32(), rng.f32(), rng.f32()],
            })
            .collect();
        Self { specs, seed }
    }

    pub fn dataset(&self, n: usize, stream: u64) -> Dataset {
        let mut rng = Rng::new(self.seed ^ stream.wrapping_mul(0x51CF7));
        let mut x = vec![0.0f32; n * DIM];
        let mut y = vec![0i32; n];
        for i in 0..n {
            let class = i % CLASSES;
            let s = &self.specs[class];
            let phase = 2.0 * std::f32::consts::PI * rng.f32();
            let bright = 0.8 + 0.4 * rng.f32();
            let (dx, dy) = (4.0 * rng.f32() - 2.0, 4.0 * rng.f32() - 2.0);
            let flip = rng.f32() < 0.5; // paper's pipeline randomly flips
            let dst = &mut x[i * DIM..(i + 1) * DIM];
            let (so, co) = s.orient.sin_cos();
            for py in 0..SIDE {
                for px_ in 0..SIDE {
                    let px = if flip { SIDE - 1 - px_ } else { px_ };
                    let u = co * px as f32 + so * py as f32;
                    let grating = 0.5 + 0.5 * (s.freq * u + phase).sin();
                    let bx = px as f32 - (s.blob_cx + dx);
                    let by = py as f32 - (s.blob_cy + dy);
                    let blob = (-(bx * bx + by * by) / (2.0 * s.blob_s * s.blob_s)).exp();
                    for ch in 0..3 {
                        let v = bright
                            * (s.base[ch] + 0.35 * grating + 0.5 * blob * s.blob_color[ch])
                            + 0.1 * rng.gauss_f32();
                        dst[(py * SIDE + px_) * 3 + ch] = v.clamp(0.0, 1.0);
                    }
                }
            }
            y[i] = class as i32;
        }
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let mut xs = vec![0.0f32; n * DIM];
        let mut ys = vec![0i32; n];
        for (new, &old) in perm.iter().enumerate() {
            xs[new * DIM..(new + 1) * DIM].copy_from_slice(&x[old * DIM..(old + 1) * DIM]);
            ys[new] = y[old];
        }
        Dataset {
            name: format!("cifar_like(seed={}, n={n}, stream={stream})", self.seed),
            examples: Examples::Image {
                x: xs,
                y: ys,
                dim: DIM,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_range_balance() {
        let g = CifarLike::new(1);
        let d = g.dataset(100, 0);
        let Examples::Image { x, y, dim } = &d.examples else {
            unreachable!()
        };
        assert_eq!(*dim, 1728);
        assert_eq!(x.len(), 100 * 1728);
        assert!(x.iter().all(|&p| (0.0..=1.0).contains(&p)));
        let mut counts = [0usize; 10];
        for &l in y {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn deterministic() {
        let g = CifarLike::new(2);
        let a = g.dataset(20, 0);
        let b = g.dataset(20, 0);
        match (&a.examples, &b.examples) {
            (Examples::Image { x: xa, .. }, Examples::Image { x: xb, .. }) => {
                assert_eq!(xa, xb)
            }
            _ => unreachable!(),
        }
    }
}
