//! Deterministic, dependency-free PRNG stack.
//!
//! Everything stochastic in the coordinator — dataset synthesis, client
//! partitioning, client sampling per round, minibatch shuffling — draws
//! from these seeded generators, so every experiment row is reproducible
//! bit-for-bit from its config seed (DESIGN.md §5.7).
//!
//! `SplitMix64` seeds `Xoshiro256**` (the reference construction from
//! Blackman & Vigna); normal deviates via Box–Muller.

/// Stateless 3-input mix (SplitMix64 finalizer over a golden-ratio
/// combine). Used wherever a decision must be a *pure function* of its
/// coordinates — e.g. "is client `c` online in round `r`?" — so the
/// answer cannot depend on how many other draws happened first.
#[inline]
pub fn hash3(seed: u64, a: u64, b: u64) -> u64 {
    let mut z = seed
        ^ a.wrapping_mul(0x9E3779B97F4A7C15)
        ^ b.wrapping_mul(0xD1B54A32D192ED03);
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// [`hash3`] mapped to a uniform f64 in [0, 1).
#[inline]
pub fn hash3_unit(seed: u64, a: u64, b: u64) -> f64 {
    (hash3(seed, a, b) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// The complete mutable state of an [`Rng`] — what a run-state snapshot
/// captures so a resumed run replays the *same* stream from the same
/// position (`crate::runstate`, DESIGN.md §8). `gauss_spare` matters:
/// Box–Muller caches its second deviate, so two generators with equal
/// `s` but different spares diverge on the very next [`Rng::gauss`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

/// Xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller deviate.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Snapshot the generator's full state (position in the stream).
    pub fn state(&self) -> RngState {
        RngState {
            s: self.s,
            gauss_spare: self.gauss_spare,
        }
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`state`](Self::state) — the resume half of the snapshot contract:
    /// `Rng::from_state(r.state())` continues bit-identically to `r`.
    pub fn from_state(st: RngState) -> Rng {
        Rng {
            s: st.s,
            gauss_spare: st.gauss_spare,
        }
    }

    /// Derive an independent child generator (stable under reordering).
    pub fn child(&self, stream: u64) -> Rng {
        let mut sm = SplitMix64::new(self.s[0] ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    #[inline]
    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "sample {m} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// Zipf-distributed sample in [1, n] with exponent `s` (inverse-CDF on
    /// precomputed weights is overkill for our sizes; rejection-free scan).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // draw by inverse CDF over H_{n,s}
        // lint:allow(float-fold): fold over 1..=n in ascending order — a fixed sequence, identical everywhere.
        let h: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k;
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash3_is_pure_and_sensitive() {
        assert_eq!(hash3(1, 2, 3), hash3(1, 2, 3));
        assert_ne!(hash3(1, 2, 3), hash3(1, 3, 2));
        assert_ne!(hash3(1, 2, 3), hash3(2, 2, 3));
        let u = hash3_unit(7, 8, 9);
        assert!((0.0..1.0).contains(&u));
        // roughly uniform: mean of many draws near 0.5
        let n = 10_000;
        let mean: f64 = (0..n).map(|i| hash3_unit(42, i, 0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn state_roundtrip_continues_bit_identically() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        a.gauss(); // leave a cached Box–Muller spare in the state
        let st = a.state();
        assert!(st.gauss_spare.is_some(), "expected a cached spare");
        let mut b = Rng::from_state(st);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.gauss(), b.gauss()); // both consume the spare
        assert_eq!(a.gauss(), b.gauss()); // ...and the fresh pair after it
        // the spare is part of the state: dropping it must be visible
        let mut full = Rng::from_state(st);
        let mut bare = Rng::from_state(RngState {
            gauss_spare: None,
            ..st
        });
        assert_ne!(full.gauss(), bare.gauss());
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn child_streams_are_independent() {
        let root = Rng::new(7);
        let mut c1 = root.child(1);
        let mut c2 = root.child(2);
        let same: usize = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(19);
        let draws: Vec<usize> = (0..2000).map(|_| r.zipf(100, 1.2)).collect();
        assert!(draws.iter().all(|&k| (1..=100).contains(&k)));
        let ones = draws.iter().filter(|&&k| k == 1).count();
        let hundreds = draws.iter().filter(|&&k| k == 100).count();
        assert!(ones > 10 * hundreds.max(1) / 2, "zipf not skewed: {ones} vs {hundreds}");
    }
}
