//! Datasets, client partitions, and batch assembly.
//!
//! The paper's four data sources are rebuilt as deterministic synthetic
//! generators (see DESIGN.md §2 — substitution note): [`mnist_like`],
//! [`cifar_like`], [`shakespeare_like`], [`social_like`]. Partitioning
//! schemes (IID / pathological non-IID / unbalanced / natural) live in
//! [`partition`].

pub mod cifar_like;
pub mod mnist_like;
pub mod partition;
pub mod rng;
pub mod shakespeare_like;
pub mod social_like;

/// Raw example storage — images carry dense f32 features, token datasets
/// carry fixed-unroll id sequences with per-token weights (0 on padding).
#[derive(Debug, Clone)]
pub enum Examples {
    Image {
        /// Row-major features, `n * dim` long.
        x: Vec<f32>,
        /// Labels, `n` long.
        y: Vec<i32>,
        dim: usize,
    },
    Tokens {
        /// Input ids, `n * t` long.
        x: Vec<i32>,
        /// Next-token targets, `n * t` long.
        y: Vec<i32>,
        /// Per-token weights (0.0 marks padding), `n * t` long.
        w: Vec<f32>,
        /// Unroll length.
        t: usize,
    },
}

/// A dataset: examples plus a human-readable provenance tag.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub examples: Examples,
}

impl Dataset {
    pub fn len(&self) -> usize {
        match &self.examples {
            Examples::Image { y, .. } => y.len(),
            Examples::Tokens { y, t, .. } => y.len() / t,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_tokens(&self) -> bool {
        matches!(self.examples, Examples::Tokens { .. })
    }

    /// Label of example `i` (image datasets only).
    pub fn label(&self, i: usize) -> i32 {
        match &self.examples {
            Examples::Image { y, .. } => y[i],
            Examples::Tokens { .. } => panic!("label() on token dataset"),
        }
    }

    /// Gather rows `idxs` into a zero-padded batch of capacity `cap`.
    ///
    /// Rows beyond `idxs.len()` have weight 0 everywhere, which the L2
    /// entry points are contractually required to ignore (verified by
    /// `python/tests/test_entries.py` and the rust integration tests).
    pub fn padded_batch(&self, idxs: &[usize], cap: usize) -> PaddedBatch {
        assert!(idxs.len() <= cap, "batch {} > capacity {cap}", idxs.len());
        match &self.examples {
            Examples::Image { x, y, dim } => {
                let mut xf = vec![0.0f32; cap * dim];
                let mut yb = vec![0i32; cap];
                let mut wb = vec![0.0f32; cap];
                for (row, &i) in idxs.iter().enumerate() {
                    xf[row * dim..(row + 1) * dim]
                        .copy_from_slice(&x[i * dim..(i + 1) * dim]);
                    yb[row] = y[i];
                    wb[row] = 1.0;
                }
                PaddedBatch {
                    xf,
                    xi: Vec::new(),
                    y: yb,
                    w: wb,
                    cap,
                    row_dim: *dim,
                    tokens: false,
                    logical: idxs.len(),
                }
            }
            Examples::Tokens { x, y, w, t } => {
                let mut xb = vec![0i32; cap * t];
                let mut yb = vec![0i32; cap * t];
                let mut wb = vec![0.0f32; cap * t];
                for (row, &i) in idxs.iter().enumerate() {
                    xb[row * t..(row + 1) * t].copy_from_slice(&x[i * t..(i + 1) * t]);
                    yb[row * t..(row + 1) * t].copy_from_slice(&y[i * t..(i + 1) * t]);
                    wb[row * t..(row + 1) * t].copy_from_slice(&w[i * t..(i + 1) * t]);
                }
                PaddedBatch {
                    xf: Vec::new(),
                    xi: xb,
                    y: yb,
                    w: wb,
                    cap,
                    row_dim: *t,
                    tokens: true,
                    logical: idxs.len(),
                }
            }
        }
    }

    /// Total example weight of rows `idxs` (tokens: sum of token weights;
    /// images: count). This is the `n_k` FedAvg weighs clients by.
    pub fn weight_of(&self, idxs: &[usize]) -> f64 {
        match &self.examples {
            Examples::Image { .. } => idxs.len() as f64,
            Examples::Tokens { w, t, .. } => idxs
                .iter()
                // lint:allow(float-fold): inner fold runs in slice order over a fixed token row — the same sequence on every host and replay.
                .map(|&i| w[i * t..(i + 1) * t].iter().map(|&v| v as f64).sum::<f64>())
                .sum(), // lint:allow(float-fold): outer fold follows the caller's fixed index list order.
        }
    }
}

/// A capacity-padded batch ready for literal construction.
#[derive(Debug, Clone)]
pub struct PaddedBatch {
    pub xf: Vec<f32>,
    pub xi: Vec<i32>,
    pub y: Vec<i32>,
    pub w: Vec<f32>,
    pub cap: usize,
    pub row_dim: usize,
    pub tokens: bool,
    pub logical: usize,
}

impl PaddedBatch {
    /// Sum of example weights (denominator of the weighted-mean loss).
    pub fn weight_sum(&self) -> f64 {
        // lint:allow(float-fold): slice-order fold over one batch's weight vector; the layout is deterministic per batch plan.
        self.w.iter().map(|&v| v as f64).sum()
    }
}

/// A federated dataset: shared example store + per-client index sets +
/// a held-out test set, as in the paper's experimental setup.
#[derive(Debug, Clone)]
pub struct Federated {
    pub train: Dataset,
    pub test: Dataset,
    /// `clients[k]` = indices into `train` owned by client `k`.
    pub clients: Vec<Vec<usize>>,
}

impl Federated {
    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Total training examples across clients (the paper's `n`).
    pub fn total_examples(&self) -> usize {
        self.clients.iter().map(|c| c.len()).sum()
    }

    /// `n_k` for every client.
    pub fn client_sizes(&self) -> Vec<usize> {
        self.clients.iter().map(|c| c.len()).collect()
    }
}

/// Deterministically corrupt a `frac`-fraction of clients for
/// robustness experiments (`fedavg agg`, DESIGN.md §7): every training
/// example of `⌊frac·K⌋` seed-sampled clients has its label replaced by
/// a uniformly random **wrong** label — the classic label-flipping
/// adversary robust aggregators are built to survive. Returns the
/// corrupted client ids, sorted.
///
/// Image datasets only (token datasets have no single label to flip);
/// panics otherwise, or when the label universe has fewer than two
/// classes (no wrong label exists).
pub fn corrupt_clients(fed: &mut Federated, frac: f64, seed: u64) -> Vec<usize> {
    assert!(
        (0.0..=1.0).contains(&frac),
        "corrupt fraction must be in [0, 1], got {frac}"
    );
    let k = fed.num_clients();
    let n_bad = (k as f64 * frac) as usize;
    if n_bad == 0 {
        return Vec::new();
    }
    let mut r = rng::Rng::new(seed ^ 0xBAD1ABE1);
    let mut bad = r.sample_indices(k, n_bad);
    bad.sort_unstable();
    let clients = &fed.clients;
    match &mut fed.train.examples {
        Examples::Image { y, .. } => {
            let classes = y.iter().copied().max().unwrap_or(-1) + 1;
            assert!(classes >= 2, "corrupt_clients needs >= 2 classes, got {classes}");
            for &c in &bad {
                for &i in &clients[c] {
                    let shift = 1 + r.below(classes as usize - 1) as i32;
                    y[i] = (y[i] + shift) % classes;
                }
            }
        }
        Examples::Tokens { .. } => {
            panic!("corrupt_clients needs labeled image data (token datasets have no label to flip)")
        }
    }
    bad
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_image() -> Dataset {
        Dataset {
            name: "t".into(),
            examples: Examples::Image {
                x: (0..12).map(|v| v as f32).collect(),
                y: vec![0, 1, 2],
                dim: 4,
            },
        }
    }

    #[test]
    fn padded_batch_layout_and_weights() {
        let d = tiny_image();
        let b = d.padded_batch(&[2, 0], 4);
        assert_eq!(b.logical, 2);
        assert_eq!(b.xf.len(), 16);
        assert_eq!(&b.xf[0..4], &[8.0, 9.0, 10.0, 11.0]); // row 0 = example 2
        assert_eq!(&b.xf[4..8], &[0.0, 1.0, 2.0, 3.0]); // row 1 = example 0
        assert_eq!(&b.xf[8..], &[0.0; 8]); // padding zeroed
        assert_eq!(b.y, vec![2, 0, 0, 0]);
        assert_eq!(b.w, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(b.weight_sum(), 2.0);
    }

    #[test]
    fn token_batch_and_weight_of() {
        let d = Dataset {
            name: "tok".into(),
            examples: Examples::Tokens {
                x: vec![1, 2, 3, 4, 5, 6],
                y: vec![2, 3, 0, 5, 6, 0],
                w: vec![1.0, 1.0, 0.0, 1.0, 1.0, 1.0],
                t: 3,
            },
        };
        assert_eq!(d.len(), 2);
        assert_eq!(d.weight_of(&[0]), 2.0);
        assert_eq!(d.weight_of(&[0, 1]), 5.0);
        let b = d.padded_batch(&[1], 2);
        assert_eq!(b.xi, vec![4, 5, 6, 0, 0, 0]);
        assert_eq!(b.weight_sum(), 3.0);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn padded_batch_overflow_panics() {
        tiny_image().padded_batch(&[0, 1, 2], 2);
    }

    fn four_client_fed() -> Federated {
        // 8 examples, labels 0..=3 twice, 4 clients x 2 examples
        let n = 8;
        Federated {
            train: Dataset {
                name: "corrupt-test".into(),
                examples: Examples::Image {
                    x: vec![0.0; n],
                    y: (0..n).map(|i| (i % 4) as i32).collect(),
                    dim: 1,
                },
            },
            test: tiny_image(),
            clients: (0..4).map(|c| vec![2 * c, 2 * c + 1]).collect(),
        }
    }

    #[test]
    fn corrupt_clients_flips_only_the_sampled_clients() {
        let mut fed = four_client_fed();
        let clean: Vec<i32> = (0..fed.train.len()).map(|i| fed.train.label(i)).collect();
        let bad = corrupt_clients(&mut fed, 0.5, 7);
        assert_eq!(bad.len(), 2);
        assert!(bad.windows(2).all(|w| w[0] < w[1]), "ids sorted");
        let bad_idx: Vec<usize> = bad.iter().flat_map(|&c| fed.clients[c].clone()).collect();
        for i in 0..fed.train.len() {
            let (was, now) = (clean[i], fed.train.label(i));
            assert!((0..4).contains(&now), "label {now} out of range");
            if bad_idx.contains(&i) {
                assert_ne!(was, now, "corrupted example {i} kept its label");
            } else {
                assert_eq!(was, now, "honest example {i} changed");
            }
        }
        // deterministic in the seed; frac=0 is a no-op
        let mut fed2 = four_client_fed();
        assert_eq!(corrupt_clients(&mut fed2, 0.5, 7), bad);
        let mut fed3 = four_client_fed();
        assert!(corrupt_clients(&mut fed3, 0.0, 7).is_empty());
    }
}
