//! Parallel ClientUpdate dispatch — Algorithm 1's "for each client k ∈
//! S_t **in parallel**", for real.
//!
//! PJRT engines are not `Send`, so parallelism runs over
//! [`WorkerPool`]: each worker thread constructs its own [`Engine`] from
//! the artifacts directory and keeps its executable cache warm across
//! rounds. Jobs carry `(slot, client, θ_t, spec)`; results come back
//! tagged with their dispatch slot and are **reduced in slot order**, so
//! the aggregation consumes updates in exactly the sequence the
//! sequential path would — `--workers N` is bit-identical to
//! `--workers 1` (each ClientUpdate is deterministic given `(θ_t, spec)`
//! and f32 accumulation order is fixed by the slot sort).
//!
//! The buffered-async round mode (DESIGN.md §12) leans on the same
//! invariant: "arrival order" is the virtual-clock `(t, slot)` sort of a
//! wave's completions, never the wall-clock order worker threads happen
//! to finish in, so the K-delta buffer fills — and combine∘step fires —
//! in a worker-count-independent sequence.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::anyhow;

use crate::data::Dataset;
use crate::federated::client::{local_update, LocalResult, LocalSpec};
use crate::obs::Tracer;
use crate::params::ParamVec;
use crate::runtime::pool::WorkerPool;
use crate::runtime::Engine;
use crate::Result;

/// One client's work order for a round.
pub struct ClientJob {
    /// Dispatch slot — the reduction position of this result.
    pub slot: usize,
    /// Round this job belongs to (trace span labelling only).
    pub round: u64,
    /// Client index into the federated partition.
    pub client: usize,
    /// Global parameters at the start of the round.
    pub theta: Arc<ParamVec>,
    pub spec: LocalSpec,
}

type Out = (usize, std::result::Result<LocalResult, String>);

/// A persistent pool of ClientUpdate workers, one engine per thread.
pub struct ParallelExec {
    pool: WorkerPool<ClientJob, Out>,
}

impl ParallelExec {
    /// Spawn `workers` threads, each loading its own engine from
    /// `artifacts_dir` and serving `model` over the shared `train` set
    /// and client partition. `trace` (usually disabled) emits a
    /// `local_train` span per job, tagged with client + worker ids —
    /// span *records* interleave by completion time, but the span
    /// multiset is identical to the serial path's (the determinism the
    /// trace tests pin).
    pub fn new(
        workers: usize,
        artifacts_dir: PathBuf,
        model: String,
        train: Arc<Dataset>,
        clients: Arc<Vec<Vec<usize>>>,
        trace: Tracer,
    ) -> Result<Self> {
        anyhow::ensure!(workers >= 1, "exec pool needs >= 1 worker");
        // WorkerPool::new is a readiness barrier: each worker's factory
        // runs exactly once and a failure comes back from new() with the
        // real error, so no validate-by-loading probe (and no second
        // Engine::load per process) is needed here.
        let dir_label = artifacts_dir.display().to_string();
        let pool = WorkerPool::new(
            workers,
            move |id| Engine::load(&artifacts_dir).map(|eng| (eng, id)),
            move |(eng, wid): &mut (Engine, usize), job: ClientJob| {
                // A panic here would unwind one worker while the rest keep
                // the pool alive, deadlocking map()'s result count — catch
                // it and report as a failed round instead.
                let slot = job.slot;
                let sp = trace
                    .begin(job.round, "local_train", 2)
                    .map(|s| s.client(job.client as u64).worker(*wid as u64));
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || -> Result<LocalResult> {
                        let model = eng.model(&model)?;
                        local_update(&model, &train, &clients[job.client], &job.theta, &job.spec)
                    },
                ));
                trace.end(sp);
                let out = match out {
                    Ok(r) => r.map_err(|e| format!("{e:#}")),
                    Err(panic) => Err(match panic.downcast_ref::<&str>() {
                        Some(s) => format!("client update panicked: {s}"),
                        None => match panic.downcast_ref::<String>() {
                            Some(s) => format!("client update panicked: {s}"),
                            None => "client update panicked".to_string(),
                        },
                    }),
                };
                (slot, out)
            },
        )
        .map_err(|e| e.context(format!("exec pool cannot load engine from {dir_label}")))?;
        Ok(Self { pool })
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Run all jobs across the pool and return results **sorted by
    /// dispatch slot** (the deterministic reduction order). Any worker
    /// failure fails the round.
    pub fn run_round(&self, mut jobs: Vec<ClientJob>) -> Result<Vec<LocalResult>> {
        let mut scratch = ExecScratch::default();
        let mut outs = Vec::with_capacity(jobs.len());
        self.run_round_into(&mut jobs, &mut scratch, &mut outs)?;
        Ok(outs)
    }

    /// [`Self::run_round`] through caller-owned buffers — the round
    /// loop's scratch path (DESIGN.md §14). `jobs` is drained (its spine
    /// survives for next round), slot-tagged results stage in `scratch`,
    /// and the sorted [`LocalResult`]s land in `outs`. The slot sort is
    /// the same reduction order as [`Self::run_round`]: buffer reuse
    /// changes where results live, never the sequence they fold in.
    pub fn run_round_into(
        &self,
        jobs: &mut Vec<ClientJob>,
        scratch: &mut ExecScratch,
        outs: &mut Vec<LocalResult>,
    ) -> Result<()> {
        let n = jobs.len();
        self.pool.map_into(jobs.drain(..), &mut scratch.tagged)?;
        anyhow::ensure!(
            scratch.tagged.len() == n,
            "pool returned {} of {n} results",
            scratch.tagged.len()
        );
        scratch.tagged.sort_by_key(|(slot, _)| *slot);
        outs.clear();
        outs.reserve(n);
        for (slot, r) in scratch.tagged.drain(..) {
            outs.push(r.map_err(|e| anyhow!("client update (slot {slot}): {e}"))?);
        }
        Ok(())
    }
}

/// Reusable per-round dispatch buffers for
/// [`ParallelExec::run_round_into`] — cleared each round, reallocated
/// never (the scratch-reuse front of DESIGN.md §14).
#[derive(Default)]
pub struct ExecScratch {
    tagged: Vec<Out>,
}
