//! Parallel ClientUpdate dispatch — Algorithm 1's "for each client k ∈
//! S_t **in parallel**", for real.
//!
//! PJRT engines are not `Send`, so parallelism runs over
//! [`WorkerPool`]: each worker thread constructs its own [`Engine`] from
//! the artifacts directory and keeps its executable cache warm across
//! rounds. Jobs carry `(slot, client, θ_t, spec)`; results come back
//! tagged with their dispatch slot and are **reduced in slot order**, so
//! the aggregation consumes updates in exactly the sequence the
//! sequential path would — `--workers N` is bit-identical to
//! `--workers 1` (each ClientUpdate is deterministic given `(θ_t, spec)`
//! and f32 accumulation order is fixed by the slot sort).
//!
//! The buffered-async round mode (DESIGN.md §12) leans on the same
//! invariant: "arrival order" is the virtual-clock `(t, slot)` sort of a
//! wave's completions, never the wall-clock order worker threads happen
//! to finish in, so the K-delta buffer fills — and combine∘step fires —
//! in a worker-count-independent sequence.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::anyhow;

use crate::data::Dataset;
use crate::federated::client::{local_update, LocalResult, LocalSpec};
use crate::obs::Tracer;
use crate::params::ParamVec;
use crate::runtime::pool::WorkerPool;
use crate::runtime::Engine;
use crate::Result;

/// One client's work order for a round.
pub struct ClientJob {
    /// Dispatch slot — the reduction position of this result.
    pub slot: usize,
    /// Round this job belongs to (trace span labelling only).
    pub round: u64,
    /// Client index into the federated partition.
    pub client: usize,
    /// Global parameters at the start of the round.
    pub theta: Arc<ParamVec>,
    pub spec: LocalSpec,
}

type Out = (usize, std::result::Result<LocalResult, String>);

/// A persistent pool of ClientUpdate workers, one engine per thread.
pub struct ParallelExec {
    pool: WorkerPool<ClientJob, Out>,
}

impl ParallelExec {
    /// Spawn `workers` threads, each loading its own engine from
    /// `artifacts_dir` and serving `model` over the shared `train` set
    /// and client partition. `trace` (usually disabled) emits a
    /// `local_train` span per job, tagged with client + worker ids —
    /// span *records* interleave by completion time, but the span
    /// multiset is identical to the serial path's (the determinism the
    /// trace tests pin).
    pub fn new(
        workers: usize,
        artifacts_dir: PathBuf,
        model: String,
        train: Arc<Dataset>,
        clients: Arc<Vec<Vec<usize>>>,
        trace: Tracer,
    ) -> Result<Self> {
        anyhow::ensure!(workers >= 1, "exec pool needs >= 1 worker");
        // Fail fast with the real error: a worker thread's factory
        // failure only logs to stderr (the pool reports it later as an
        // opaque "workers gone"), so validate the load here first.
        Engine::load(&artifacts_dir)
            .map(drop)
            .map_err(|e| e.context(format!("exec pool cannot load engine from {artifacts_dir:?}")))?;
        let pool = WorkerPool::new(
            workers,
            move |id| Engine::load(&artifacts_dir).map(|eng| (eng, id)),
            move |(eng, wid): &mut (Engine, usize), job: ClientJob| {
                // A panic here would unwind one worker while the rest keep
                // the pool alive, deadlocking map()'s result count — catch
                // it and report as a failed round instead.
                let slot = job.slot;
                let sp = trace
                    .begin(job.round, "local_train", 2)
                    .map(|s| s.client(job.client as u64).worker(*wid as u64));
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || -> Result<LocalResult> {
                        let model = eng.model(&model)?;
                        local_update(&model, &train, &clients[job.client], &job.theta, &job.spec)
                    },
                ));
                trace.end(sp);
                let out = match out {
                    Ok(r) => r.map_err(|e| format!("{e:#}")),
                    Err(panic) => Err(match panic.downcast_ref::<&str>() {
                        Some(s) => format!("client update panicked: {s}"),
                        None => match panic.downcast_ref::<String>() {
                            Some(s) => format!("client update panicked: {s}"),
                            None => "client update panicked".to_string(),
                        },
                    }),
                };
                (slot, out)
            },
        )?;
        Ok(Self { pool })
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Run all jobs across the pool and return results **sorted by
    /// dispatch slot** (the deterministic reduction order). Any worker
    /// failure fails the round.
    pub fn run_round(&self, jobs: Vec<ClientJob>) -> Result<Vec<LocalResult>> {
        let n = jobs.len();
        let mut outs = self.pool.map(jobs)?;
        anyhow::ensure!(outs.len() == n, "pool returned {} of {n} results", outs.len());
        outs.sort_by_key(|(slot, _)| *slot);
        outs.into_iter()
            .map(|(slot, r)| r.map_err(|e| anyhow!("client update (slot {slot}): {e}")))
            .collect()
    }
}
