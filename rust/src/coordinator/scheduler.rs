//! Event-queue round execution: over-selection, straggler drops, and
//! deadlines.
//!
//! The synchronous protocol's wall-clock is bound by its slowest
//! participant, so production FedAvg over-selects — dispatch
//! `⌈m·(1+ρ)⌉` clients, aggregate the first `m` to finish, discard the
//! stragglers — and bounds each round with a deadline. [`schedule_round`]
//! simulates exactly that over a discrete-event queue of client finish
//! times; [`FleetSim`] drives it for thousands of rounds with no training
//! attached (the `fedavg fleet --sim-only` / bench / stress-example
//! path).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::data::rng::hash3_unit;
use crate::federated::sampler::ClientSampler;
use crate::Result;

use super::fleet::{Fleet, FleetProfile};
use super::{FleetConfig, FleetTotals, LatePolicy};

/// Over-selection count: `⌈m·(1+ρ)⌉`, capped at the candidate pool.
pub fn overselect_count(m: usize, rho: f64, pool: usize) -> usize {
    let sel = (m as f64 * (1.0 + rho.max(0.0))).ceil() as usize;
    sel.max(m).min(pool)
}

/// One simulated round's outcome.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Clients the server sent the model to, in selection order.
    pub dispatched: Vec<usize>,
    /// Clients whose updates are aggregated (first `m` finishers inside
    /// the deadline), in dispatch order — the deterministic reduction
    /// order.
    pub completed: Vec<usize>,
    /// Dispatched clients whose updates were discarded.
    pub dropped: Vec<usize>,
    /// The past-deadline subset of `dropped`, with each straggler's
    /// virtual finish time (seconds from round start), in dispatch
    /// order. Empty without a deadline. Under `--late-policy discount`
    /// the server moves these from the drop list into the late queue
    /// (DESIGN.md §12); under the default drop policy they stay dropped.
    pub late: Vec<(usize, f64)>,
    /// True when the deadline fired before `m` finishers arrived.
    pub deadline_miss: bool,
    /// Straggler-bound simulated wall-clock of the round: the `m`-th
    /// finish time, or the deadline when it fired first.
    pub round_seconds: f64,
}

/// A client-finished event in the round's event queue.
#[derive(Debug, PartialEq)]
struct Finish {
    t: f64,
    slot: usize,
}

impl Eq for Finish {}

impl Ord for Finish {
    fn cmp(&self, other: &Self) -> Ordering {
        // finish times are finite by construction; tie-break on dispatch
        // slot for a total, deterministic order
        self.t
            .partial_cmp(&other.t)
            .expect("non-finite finish time")
            .then(self.slot.cmp(&other.slot))
            .reverse() // BinaryHeap is a max-heap; we pop earliest first
    }
}

impl PartialOrd for Finish {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate one synchronous round over `durations` — `(client, seconds)`
/// pairs in dispatch order. Aggregates the first `m` finishers, drops the
/// rest, and cuts at `deadline_s` if set. If nobody meets the deadline
/// the server waits for the single earliest finisher (the protocol cannot
/// proceed with zero updates), still flagged as a deadline miss.
pub fn schedule_round(
    m: usize,
    deadline_s: Option<f64>,
    durations: &[(usize, f64)],
) -> RoundPlan {
    assert!(!durations.is_empty(), "scheduling an empty dispatch set");
    assert!(m >= 1, "round must aggregate at least one update");
    if let Some(d) = deadline_s {
        // NaN would silently never fire (`t > NaN` is false); negative
        // would make every round a guaranteed miss
        assert!(d.is_finite() && d > 0.0, "bad deadline {d}");
    }
    let mut queue: BinaryHeap<Finish> = durations
        .iter()
        .enumerate()
        .map(|(slot, &(_, t))| {
            assert!(t.is_finite() && t >= 0.0, "bad duration {t}");
            Finish { t, slot }
        })
        .collect();

    let mut done = vec![false; durations.len()];
    let mut n_done = 0usize;
    let mut round_seconds = 0.0f64;
    let mut deadline_miss = false;
    while let Some(ev) = queue.pop() {
        if let Some(d) = deadline_s {
            if ev.t > d {
                if n_done == 0 {
                    // nobody made it: wait for the earliest straggler
                    done[ev.slot] = true;
                    n_done = 1;
                    round_seconds = ev.t;
                } else {
                    round_seconds = d;
                }
                deadline_miss = true;
                break;
            }
        }
        done[ev.slot] = true;
        n_done += 1;
        round_seconds = ev.t;
        if n_done == m {
            break;
        }
    }

    let dispatched: Vec<usize> = durations.iter().map(|&(c, _)| c).collect();
    let completed: Vec<usize> = durations
        .iter()
        .enumerate()
        .filter(|(slot, _)| done[*slot])
        .map(|(_, &(c, _))| c)
        .collect();
    let dropped: Vec<usize> = durations
        .iter()
        .enumerate()
        .filter(|(slot, _)| !done[*slot])
        .map(|(_, &(c, _))| c)
        .collect();
    // late = dropped ∧ past-deadline: a pure function of the durations,
    // independent of the event-loop break order (surplus finishers that
    // beat the deadline but lost the race to m are *not* late)
    let late: Vec<(usize, f64)> = match deadline_s {
        Some(d) => durations
            .iter()
            .enumerate()
            .filter(|&(slot, &(_, t))| !done[slot] && t > d)
            .map(|(_, &(c, t))| (c, t))
            .collect(),
        None => Vec::new(),
    };
    RoundPlan {
        dispatched,
        completed,
        dropped,
        late,
        deadline_miss,
        round_seconds,
    }
}

// -------------------------------------------------- buffered-async waves

/// One client-delta arrival in a buffered-async wave.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Dispatch slot within the wave (the sync reduction order).
    pub slot: usize,
    pub client: usize,
    /// Virtual finish time, seconds from wave start.
    pub t: f64,
}

/// One buffered-async wave's outcome: every dispatched client completes
/// (no deadline, no drops), and the arrivals are totally ordered by
/// `(t, slot)` — a pure function of the seeded fleet's event times,
/// never of wall clock or worker scheduling. This order is the sequence
/// in which deltas enter the server's staleness buffer (DESIGN.md §12).
#[derive(Debug, Clone)]
pub struct WavePlan {
    /// Clients the server sent the model to, in selection order.
    pub dispatched: Vec<usize>,
    /// All finishers, sorted by `(finish time, dispatch slot)`.
    pub arrivals: Vec<Arrival>,
    /// Virtual wall-clock of the wave: the last arrival's finish time.
    pub round_seconds: f64,
}

/// Order one async wave's arrivals. Mirrors [`schedule_round`]'s
/// validation, but aggregation-free: buffered-async applies are the
/// server's business, the scheduler only fixes the arrival order.
pub fn schedule_async_wave(durations: &[(usize, f64)]) -> WavePlan {
    assert!(!durations.is_empty(), "scheduling an empty dispatch set");
    let mut arrivals: Vec<Arrival> = durations
        .iter()
        .enumerate()
        .map(|(slot, &(client, t))| {
            assert!(t.is_finite() && t >= 0.0, "bad duration {t}");
            Arrival { slot, client, t }
        })
        .collect();
    arrivals.sort_by(|a, b| {
        a.t.partial_cmp(&b.t)
            .expect("non-finite finish time")
            .then(a.slot.cmp(&b.slot))
    });
    let round_seconds = arrivals.last().map(|a| a.t).unwrap_or(0.0);
    WavePlan {
        dispatched: durations.iter().map(|&(c, _)| c).collect(),
        arrivals,
        round_seconds,
    }
}

/// Async twin of [`plan_round`]: diurnal online scan, plain `m`-sample
/// (no over-selection — every dispatched update is eventually applied),
/// per-client durations, arrival ordering. Returns the online-pool size
/// alongside the wave plan.
pub fn plan_async_wave(
    fleet: &Fleet,
    sampler: &mut ClientSampler,
    round: u64,
    m: usize,
    mut link_bytes: impl FnMut(usize) -> (u64, u64),
    steps_of: impl Fn(usize) -> f64,
) -> (usize, WavePlan) {
    let online = fleet.online_set(round);
    let dispatched = sampler.sample_from(round, &online, m.min(online.len()));
    let durations: Vec<(usize, f64)> = dispatched
        .iter()
        .map(|&c| {
            let (down, up) = link_bytes(c);
            (c, fleet.client_seconds(c, down, up, steps_of(c)))
        })
        .collect();
    (online.len(), schedule_async_wave(&durations))
}

// -------------------------------------------------------- fault injection

/// Seeded client-fault model for the virtual-clock simulator and the
/// async test harness (DESIGN.md §12): per `(round, client)`, a client
/// may **abort** (its update never arrives; its error-feedback residual
/// must stay untouched) or **duplicate** (its delta is delivered twice;
/// the second copy must be refused — applies are idempotent).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// P(abort) per dispatched client per round.
    pub abort_p: f64,
    /// P(duplicate delivery) per arriving delta.
    pub duplicate_p: f64,
    /// Fault stream seed, independent of the fleet/sampler seeds.
    pub seed: u64,
}

impl FaultConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.abort_p) && (0.0..=1.0).contains(&self.duplicate_p),
            "fault probabilities must be in [0, 1]"
        );
        anyhow::ensure!(
            self.abort_p + self.duplicate_p <= 1.0,
            "abort_p + duplicate_p must not exceed 1"
        );
        Ok(())
    }
}

/// What the fault stream does to one `(round, client)` dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    None,
    Abort,
    Duplicate,
}

/// Domain separator so the fault coin never correlates with the
/// availability coin or the sampler stream at equal seeds.
const FAULT_SALT: u64 = 0xFA17_5EED_0A5B_11E9;

/// The fault stream: a pure function of `(seed, round, client)` — like
/// every other source of scheduling randomness, it replays identically
/// under any worker count and across kill/resume.
pub fn fault_of(cfg: &FaultConfig, round: u64, client: u64) -> Fault {
    let u = hash3_unit(cfg.seed ^ FAULT_SALT, round, client);
    if u < cfg.abort_p {
        Fault::Abort
    } else if u < cfg.abort_p + cfg.duplicate_p {
        Fault::Duplicate
    } else {
        Fault::None
    }
}

/// One round of the fleet protocol — diurnal online scan, over-selected
/// sample, per-client durations, event-queue schedule. The single
/// implementation behind both the training server and [`FleetSim`]: at
/// equal seeds the two build the same fleet and select the same clients;
/// the resulting plans coincide exactly when the duration inputs match
/// too (uncompressed links, uniform per-client step counts), and
/// otherwise differ only through `link_bytes`/`steps_of`.
///
/// `link_bytes(client) -> (down, up)` prices both link directions per
/// dispatched client. The training server passes the transport layer's
/// metering here, so the scheduler prices a transfer from the *same
/// codec* that later encodes it — per-client delta downlinks included —
/// and the estimate can never drift from the telemetry-reported bytes.
/// Returns the online-pool size alongside the plan.
#[allow(clippy::too_many_arguments)]
pub fn plan_round(
    fleet: &Fleet,
    sampler: &mut ClientSampler,
    round: u64,
    m: usize,
    overselect: f64,
    deadline_s: Option<f64>,
    mut link_bytes: impl FnMut(usize) -> (u64, u64),
    steps_of: impl Fn(usize) -> f64,
) -> (usize, RoundPlan) {
    let online = fleet.online_set(round);
    let n_sel = overselect_count(m, overselect, online.len());
    let dispatched = sampler.sample_from(round, &online, n_sel);
    let durations: Vec<(usize, f64)> = dispatched
        .iter()
        .map(|&c| {
            let (down, up) = link_bytes(c);
            (c, fleet.client_seconds(c, down, up, steps_of(c)))
        })
        .collect();
    (online.len(), schedule_round(m, deadline_s, &durations))
}

// ------------------------------------------------------------- fleet sim

/// Run-level totals for a training-free fleet simulation: the same
/// [`FleetTotals`] counters a training run reports, plus wire/wall-clock
/// sums.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimTotals {
    pub rounds: u64,
    pub fleet: FleetTotals,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub sim_seconds: f64,
    /// Buffered-async combine∘step applies (0 in sync mode).
    pub buffer_applies: u64,
    /// Past-deadline updates applied late under `--late-policy discount`.
    pub late_applied: u64,
    /// Injected client aborts (update never arrived; see [`fault_of`]).
    pub aborted: u64,
    /// Injected duplicate deliveries refused by the idempotent apply.
    pub duplicates_refused: u64,
}

/// One simulated round's report.
#[derive(Debug, Clone)]
pub struct SimRound {
    pub round: u64,
    /// Size of the online pool this round.
    pub online: usize,
    pub plan: RoundPlan,
}

/// Training-free fleet simulator: select → schedule → account, round
/// after round, over a [`Fleet`] of any size. This is the event-queue
/// subsystem isolated from learning, so 10k–100k-client scenarios run in
/// milliseconds per round with no artifacts or engine.
pub struct FleetSim {
    fleet: Fleet,
    cfg: FleetConfig,
    m: usize,
    model_bytes: u64,
    steps_per_client: f64,
    sampler: ClientSampler,
    round: u64,
    totals: SimTotals,
    faults: Option<FaultConfig>,
    /// Deltas waiting in the async buffer (buffered-async mode only).
    pending: usize,
    /// Semi-sync late queue: `(client, absolute due-time seconds)`.
    late_queue: Vec<(usize, f64)>,
}

impl FleetSim {
    /// `m` updates aggregated per round out of `k` simulated clients,
    /// each running `steps_per_client` local SGD steps on a
    /// `model_bytes`-sized model.
    pub fn new(
        cfg: &FleetConfig,
        k: usize,
        m: usize,
        model_bytes: u64,
        steps_per_client: f64,
        seed: u64,
    ) -> Result<Self> {
        anyhow::ensure!(
            cfg.profile != FleetProfile::Legacy,
            "fleet sim needs a device profile (uniform|mobile|flaky)"
        );
        anyhow::ensure!(k >= 1 && m >= 1 && m <= k, "bad fleet shape k={k} m={m}");
        if let Some(buf) = cfg.async_buffer {
            anyhow::ensure!(buf >= 1, "--async-buffer must be at least 1");
            anyhow::ensure!(
                cfg.overselect == 0.0 && cfg.deadline_s.is_none(),
                "--async-buffer replaces the synchronous barrier: \
                 --overselect/--deadline do not apply (DESIGN.md §12)"
            );
            anyhow::ensure!(
                cfg.late_policy == LatePolicy::Drop,
                "--async-buffer and --late-policy are alternative round modes \
                 (DESIGN.md §12)"
            );
        }
        if cfg.late_policy == LatePolicy::Discount {
            anyhow::ensure!(
                cfg.deadline_s.is_some(),
                "--late-policy discount needs --deadline: without one nobody is late \
                 (DESIGN.md §12)"
            );
        }
        anyhow::ensure!(
            cfg.staleness_decay.is_finite()
                && cfg.staleness_decay > 0.0
                && cfg.staleness_decay <= 1.0,
            "--staleness-decay must be in (0, 1], got {}",
            cfg.staleness_decay
        );
        Ok(Self {
            fleet: Fleet::build(cfg, k, seed),
            cfg: cfg.clone(),
            m,
            model_bytes,
            steps_per_client,
            sampler: ClientSampler::new(seed),
            round: 0,
            totals: SimTotals::default(),
            faults: None,
            pending: 0,
            late_queue: Vec::new(),
        })
    }

    /// Attach a seeded fault stream (aborts / duplicate deliveries).
    pub fn with_faults(mut self, faults: FaultConfig) -> Result<Self> {
        faults.validate()?;
        self.faults = Some(faults);
        Ok(self)
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// Deltas currently waiting in the async buffer (0 in sync mode).
    pub fn buffer_fill(&self) -> usize {
        self.pending
    }

    /// Late stragglers still queued for a future round (semi-sync only).
    pub fn late_queued(&self) -> usize {
        self.late_queue.len()
    }

    /// The fault stream's verdict for one arriving update, folded into
    /// the totals: `true` iff the update actually lands (duplicates land
    /// once — the wasted second uplink is billed, the copy refused).
    fn deliverable(&mut self, round: u64, client: usize) -> bool {
        match self.faults.as_ref().map(|f| fault_of(f, round, client as u64)) {
            Some(Fault::Abort) => {
                self.totals.aborted += 1;
                false
            }
            Some(Fault::Duplicate) => {
                self.totals.duplicates_refused += 1;
                self.totals.bytes_up += self.model_bytes;
                true
            }
            _ => true,
        }
    }

    /// Advance one round and fold it into the totals.
    pub fn step(&mut self) -> SimRound {
        self.round += 1;
        let round = self.round;
        let steps = self.steps_per_client;
        let mb = self.model_bytes;
        let clock0 = self.totals.sim_seconds;

        let (online, plan) = if let Some(buf) = self.cfg.async_buffer {
            // buffered-async wave: everyone finishes, arrivals feed the
            // buffer in (t, slot) order, applies fire as it fills
            let (online, wave) = plan_async_wave(
                &self.fleet,
                &mut self.sampler,
                round,
                self.m,
                |_| (mb, mb),
                |_| steps,
            );
            let mut completed = Vec::new();
            let mut dropped = Vec::new();
            for a in &wave.arrivals {
                if self.deliverable(round, a.client) {
                    completed.push(a.client);
                } else {
                    dropped.push(a.client);
                }
            }
            self.pending += completed.len();
            self.totals.buffer_applies += (self.pending / buf) as u64;
            self.pending %= buf;
            let plan = RoundPlan {
                dispatched: wave.dispatched,
                completed,
                dropped,
                late: Vec::new(),
                deadline_miss: false,
                round_seconds: wave.round_seconds,
            };
            (online, plan)
        } else {
            let (online, mut plan) = plan_round(
                &self.fleet,
                &mut self.sampler,
                round,
                self.m,
                self.cfg.overselect,
                self.cfg.deadline_s,
                |_| (mb, mb),
                |_| steps,
            );
            let in_time = std::mem::take(&mut plan.completed);
            for c in in_time {
                if self.deliverable(round, c) {
                    plan.completed.push(c);
                } else {
                    plan.dropped.push(c);
                }
            }
            if self.cfg.late_policy == LatePolicy::Discount {
                // past-deadline stragglers leave the drop list and queue
                // for a later round, keyed by absolute finish time
                for &(c, t) in &plan.late {
                    plan.dropped.retain(|&d| d != c);
                    if self.deliverable(round, c) {
                        self.late_queue.push((c, clock0 + t));
                    }
                }
                let cut = clock0 + plan.round_seconds;
                let due: Vec<usize> = self
                    .late_queue
                    .iter()
                    .filter(|&&(_, t)| t <= cut)
                    .map(|&(c, _)| c)
                    .collect();
                self.late_queue.retain(|&(_, t)| t > cut);
                self.totals.late_applied += due.len() as u64;
                plan.completed.extend(due);
            }
            (online, plan)
        };

        self.totals.rounds += 1;
        self.totals.fleet.dispatched += plan.dispatched.len() as u64;
        self.totals.fleet.completed += plan.completed.len() as u64;
        self.totals.fleet.dropped_stragglers += plan.dropped.len() as u64;
        self.totals.fleet.deadline_misses += plan.deadline_miss as u64;
        self.totals.bytes_up += mb * plan.completed.len() as u64;
        self.totals.bytes_down += mb * plan.dispatched.len() as u64;
        self.totals.sim_seconds += plan.round_seconds;

        SimRound {
            round,
            online,
            plan,
        }
    }

    pub fn totals(&self) -> SimTotals {
        self.totals
    }

    /// Fast-forward so the next [`step`](Self::step) executes
    /// `start_round`: rounds `1..start_round` are folded into the totals
    /// without emitting per-round reports (no telemetry rows, no
    /// printing). Every round is a pure function of `(seed, round)` —
    /// profiles, the diurnal clock, and the per-round selection stream
    /// carry no history — so the recomputed schedule is exactly what a
    /// full replay would have produced, and `fast_forward(r)` followed
    /// by stepping is bit-identical to stepping from round 1
    /// (regression-tested below). Behind `fedavg fleet --sim-only
    /// --start-round`, where multi-day 100k-client sims skip re-emitting
    /// a lost run's prefix.
    pub fn fast_forward(&mut self, start_round: u64) -> SimTotals {
        while self.round + 1 < start_round {
            self.step();
        }
        self.totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn durs(ts: &[f64]) -> Vec<(usize, f64)> {
        ts.iter().enumerate().map(|(c, &t)| (c * 10, t)).collect()
    }

    #[test]
    fn overselect_count_rounds_up_and_caps() {
        assert_eq!(overselect_count(10, 0.0, 100), 10);
        assert_eq!(overselect_count(10, 0.3, 100), 13);
        assert_eq!(overselect_count(10, 0.01, 100), 11); // ceil
        assert_eq!(overselect_count(10, 0.3, 11), 11); // pool cap
        assert_eq!(overselect_count(10, 0.3, 4), 4); // tiny pool
        assert_eq!(overselect_count(1, 2.0, 50), 3);
    }

    #[test]
    fn first_m_finishers_aggregate_rest_drop() {
        // finish order: slot2 (1s), slot0 (2s), slot3 (3s), slot1 (9s)
        let p = schedule_round(2, None, &durs(&[2.0, 9.0, 1.0, 3.0]));
        assert_eq!(p.completed, vec![0, 20]); // dispatch order, clients 0 & 20
        assert_eq!(p.dropped, vec![10, 30]);
        assert!(!p.deadline_miss);
        assert!((p.round_seconds - 2.0).abs() < 1e-12); // 2nd finisher bound
        assert_eq!(p.dispatched.len(), 4);
    }

    #[test]
    fn never_aggregates_more_than_m() {
        let mut rng = crate::data::rng::Rng::new(5);
        for case in 0..200 {
            let n = 1 + rng.below(40);
            let m = 1 + rng.below(n);
            let ts: Vec<f64> = (0..n).map(|_| rng.f64() * 100.0).collect();
            let deadline = if case % 2 == 0 {
                Some(0.01 + rng.f64() * 100.0)
            } else {
                None
            };
            let p = schedule_round(m, deadline, &durs(&ts));
            assert!(p.completed.len() <= m, "case {case}");
            assert!(!p.completed.is_empty(), "case {case}");
            assert_eq!(
                p.completed.len() + p.dropped.len(),
                p.dispatched.len(),
                "case {case}"
            );
        }
    }

    #[test]
    fn deadline_drops_stragglers_and_flags_miss() {
        // want 3, deadline at 4s: only 1s and 3s make it
        let p = schedule_round(3, Some(4.0), &durs(&[1.0, 8.0, 3.0, 9.0]));
        assert_eq!(p.completed, vec![0, 20]);
        assert_eq!(p.dropped, vec![10, 30]);
        assert!(p.deadline_miss);
        assert!((p.round_seconds - 4.0).abs() < 1e-12); // server waited out the deadline
    }

    #[test]
    fn deadline_met_is_not_a_miss() {
        // m finishers arrive before the deadline: surplus drop, no miss
        let p = schedule_round(2, Some(100.0), &durs(&[1.0, 2.0, 3.0]));
        assert_eq!(p.completed.len(), 2);
        assert_eq!(p.dropped, vec![20]);
        assert!(!p.deadline_miss);
        assert!((p.round_seconds - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_deadline_waits_for_first_finisher() {
        let p = schedule_round(2, Some(0.5), &durs(&[7.0, 3.0, 5.0]));
        assert_eq!(p.completed, vec![10]); // earliest straggler only
        assert!(p.deadline_miss);
        assert!((p.round_seconds - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ties_resolve_by_dispatch_slot() {
        let p = schedule_round(1, None, &durs(&[2.0, 2.0, 2.0]));
        assert_eq!(p.completed, vec![0]); // lowest slot wins the tie
    }

    #[test]
    fn fleet_sim_is_deterministic_and_accounts() {
        let cfg = FleetConfig {
            profile: FleetProfile::Mobile,
            overselect: 0.3,
            deadline_s: Some(30.0),
            ..Default::default()
        };
        let mut a = FleetSim::new(&cfg, 500, 20, 800_000, 60.0, 9).unwrap();
        let mut b = FleetSim::new(&cfg, 500, 20, 800_000, 60.0, 9).unwrap();
        for _ in 0..20 {
            let ra = a.step();
            let rb = b.step();
            assert_eq!(ra.plan.dispatched, rb.plan.dispatched);
            assert_eq!(ra.plan.completed, rb.plan.completed);
            assert!(ra.plan.completed.len() <= 20);
            // over-selection actually dispatches extras when the pool allows
            if ra.online >= 26 {
                assert_eq!(ra.plan.dispatched.len(), 26);
            }
        }
        let t = a.totals();
        assert_eq!(t.rounds, 20);
        assert_eq!(t.fleet.completed + t.fleet.dropped_stragglers, t.fleet.dispatched);
        assert_eq!(t.bytes_up, 800_000 * t.fleet.completed);
        assert_eq!(t.bytes_down, 800_000 * t.fleet.dispatched);
        assert!(t.sim_seconds > 0.0);
    }

    #[test]
    fn sim_rejects_legacy_profile() {
        assert!(FleetSim::new(&FleetConfig::default(), 10, 2, 1000, 1.0, 1).is_err());
    }

    #[test]
    fn fast_forward_equals_full_replay() {
        let cfg = FleetConfig {
            profile: FleetProfile::Flaky, // small online pools stress selection
            overselect: 0.4,
            deadline_s: Some(40.0),
            ..Default::default()
        };
        let mk = || FleetSim::new(&cfg, 400, 12, 700_000, 30.0, 13).unwrap();
        let (start, last) = (21u64, 30u64);

        // reference: full replay of rounds 1..=last
        let mut full = mk();
        let mut tail = Vec::new();
        for r in 1..=last {
            let sr = full.step();
            assert_eq!(sr.round, r);
            if r >= start {
                tail.push(sr);
            }
        }

        // fast-forwarded: totals folded for 1..start without reports
        let mut ff = mk();
        ff.fast_forward(start);
        for want in &tail {
            let got = ff.step();
            assert_eq!(got.round, want.round);
            assert_eq!(got.online, want.online);
            assert_eq!(got.plan.dispatched, want.plan.dispatched);
            assert_eq!(got.plan.completed, want.plan.completed);
            assert_eq!(got.plan.dropped, want.plan.dropped);
            assert_eq!(got.plan.deadline_miss, want.plan.deadline_miss);
            assert_eq!(got.plan.round_seconds, want.plan.round_seconds);
        }
        let (a, b) = (full.totals(), ff.totals());
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.fleet, b.fleet);
        assert_eq!(a.bytes_up, b.bytes_up);
        assert_eq!(a.bytes_down, b.bytes_down);
        assert_eq!(a.sim_seconds, b.sim_seconds);

        // degenerate targets: 0 and 1 are both "start at round 1"
        let mut z = mk();
        z.fast_forward(1);
        assert_eq!(z.totals().rounds, 0);
        assert_eq!(z.step().round, 1);
    }

    // ------------------------------------------- async / semi-sync / faults

    #[test]
    fn async_wave_orders_arrivals_by_time_then_slot() {
        let w = schedule_async_wave(&durs(&[5.0, 2.0, 5.0, 1.0]));
        let order: Vec<(usize, usize)> = w.arrivals.iter().map(|a| (a.slot, a.client)).collect();
        // 1.0 (slot3), 2.0 (slot1), then the 5.0 tie resolves slot0 < slot2
        assert_eq!(order, vec![(3, 30), (1, 10), (0, 0), (2, 20)]);
        assert!((w.round_seconds - 5.0).abs() < 1e-12, "wave ends at the last arrival");
        assert_eq!(w.dispatched, vec![0, 10, 20, 30]);
    }

    #[test]
    fn late_stragglers_reported_with_finish_times() {
        // m=2, deadline 4: 1s and 3s complete; 8s and 9s are late; no surplus
        let p = schedule_round(2, Some(4.0), &durs(&[1.0, 8.0, 3.0, 9.0]));
        assert_eq!(p.late, vec![(10, 8.0), (30, 9.0)]);
        // surplus finisher inside the deadline is dropped but NOT late
        let p = schedule_round(1, Some(10.0), &durs(&[1.0, 2.0, 20.0]));
        assert_eq!(p.dropped, vec![10, 20]);
        assert_eq!(p.late, vec![(20, 20.0)]);
        // no deadline: nobody is late, whatever the durations
        let p = schedule_round(1, None, &durs(&[1.0, 99.0]));
        assert!(p.late.is_empty());
    }

    #[test]
    fn fault_stream_is_pure_and_partitioned() {
        let fc = FaultConfig { abort_p: 0.2, duplicate_p: 0.1, seed: 7 };
        fc.validate().unwrap();
        let (mut aborts, mut dups) = (0u32, 0u32);
        for round in 1..=20u64 {
            for client in 0..200u64 {
                let f = fault_of(&fc, round, client);
                assert_eq!(f, fault_of(&fc, round, client), "fault stream must replay");
                match f {
                    Fault::Abort => aborts += 1,
                    Fault::Duplicate => dups += 1,
                    Fault::None => {}
                }
            }
        }
        // 4000 draws: the empirical rates should land near 20% / 10%
        assert!((600..=1000).contains(&aborts), "aborts={aborts}");
        assert!((250..=550).contains(&dups), "dups={dups}");
        // a different seed reshuffles the stream
        let other = FaultConfig { seed: 8, ..fc };
        assert!((0..200u64).any(|c| fault_of(&fc, 1, c) != fault_of(&other, 1, c)));
        assert!(FaultConfig { abort_p: 0.7, duplicate_p: 0.5, seed: 0 }.validate().is_err());
        assert!(FaultConfig { abort_p: -0.1, duplicate_p: 0.0, seed: 0 }.validate().is_err());
    }

    #[test]
    fn async_sim_buffers_and_applies_deterministically() {
        let cfg = FleetConfig {
            profile: FleetProfile::Mobile,
            async_buffer: Some(7),
            ..Default::default()
        };
        let mk = || FleetSim::new(&cfg, 300, 10, 500_000, 40.0, 11).unwrap();
        let (mut a, mut b) = (mk(), mk());
        let mut arrived = 0u64;
        for _ in 0..15 {
            let ra = a.step();
            let rb = b.step();
            assert_eq!(ra.plan.completed, rb.plan.completed, "async sim must replay");
            assert!(ra.plan.dropped.is_empty(), "async mode never drops without faults");
            assert!(!ra.plan.deadline_miss);
            arrived += ra.plan.completed.len() as u64;
        }
        let t = a.totals();
        // the buffer arithmetic: applies ride the cumulative arrival count
        assert_eq!(t.buffer_applies, arrived / 7);
        assert_eq!(t.buffer_applies * 7 + a.buffer_fill() as u64, arrived);
        assert_eq!(t.fleet.completed, arrived);
        assert_eq!(t.fleet.dispatched, arrived); // everyone finishes
    }

    #[test]
    fn semi_sync_sim_requeues_late_stragglers() {
        let base = FleetConfig {
            profile: FleetProfile::Mobile,
            overselect: 0.2,
            deadline_s: Some(25.0),
            ..Default::default()
        };
        let drop_cfg = base.clone();
        let disc_cfg = FleetConfig { late_policy: LatePolicy::Discount, ..base };
        let mut dropper = FleetSim::new(&drop_cfg, 400, 15, 600_000, 50.0, 3).unwrap();
        let mut semi = FleetSim::new(&disc_cfg, 400, 15, 600_000, 50.0, 3).unwrap();
        for _ in 0..30 {
            let d = dropper.step();
            let s = semi.step();
            // the schedule itself is shared: same dispatch, same cut
            assert_eq!(d.plan.dispatched, s.plan.dispatched);
            assert_eq!(d.plan.round_seconds, s.plan.round_seconds);
        }
        let (td, ts) = (dropper.totals(), semi.totals());
        assert!(ts.late_applied > 0, "deadline 25s over mobile must produce stragglers");
        assert_eq!(td.late_applied, 0);
        // every late-applied update left the drop column and joined completed
        assert!(ts.fleet.dropped_stragglers < td.fleet.dropped_stragglers);
        assert!(ts.fleet.completed > td.fleet.completed);
        assert_eq!(ts.fleet.dispatched, td.fleet.dispatched);
        // conservation: applied + still-dropped + still-queued = dispatched
        assert_eq!(
            ts.fleet.completed + ts.fleet.dropped_stragglers + semi.late_queue.len() as u64,
            ts.fleet.dispatched
        );
    }

    #[test]
    fn sim_faults_abort_and_refuse_duplicates() {
        let cfg = FleetConfig {
            profile: FleetProfile::Uniform,
            async_buffer: Some(5),
            ..Default::default()
        };
        let faults = FaultConfig { abort_p: 0.25, duplicate_p: 0.15, seed: 99 };
        let mk = || {
            FleetSim::new(&cfg, 200, 12, 100_000, 20.0, 5)
                .unwrap()
                .with_faults(faults)
                .unwrap()
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..25 {
            let ra = a.step();
            let rb = b.step();
            assert_eq!(ra.plan.completed, rb.plan.completed, "faulty sim must replay too");
            assert_eq!(ra.plan.dropped, rb.plan.dropped);
        }
        let t = a.totals();
        assert!(t.aborted > 0 && t.duplicates_refused > 0, "{t:?}");
        // aborted updates never reach the buffer or the byte counters
        assert_eq!(t.fleet.completed + t.aborted, t.fleet.dispatched);
        assert_eq!(t.buffer_applies * 5 + a.buffer_fill() as u64, t.fleet.completed);
        // each duplicate billed one wasted uplink on top of the real ones
        assert_eq!(
            t.bytes_up,
            100_000 * (t.fleet.completed + t.duplicates_refused)
        );
    }

    #[test]
    fn sim_rejects_contradictory_round_modes() {
        let base = FleetConfig { profile: FleetProfile::Uniform, ..Default::default() };
        let cases = [
            FleetConfig { async_buffer: Some(0), ..base.clone() },
            FleetConfig { async_buffer: Some(4), overselect: 0.3, ..base.clone() },
            FleetConfig { async_buffer: Some(4), deadline_s: Some(10.0), ..base.clone() },
            FleetConfig {
                async_buffer: Some(4),
                late_policy: LatePolicy::Discount,
                ..base.clone()
            },
            FleetConfig { late_policy: LatePolicy::Discount, ..base.clone() },
            FleetConfig { staleness_decay: 0.0, ..base.clone() },
            FleetConfig { staleness_decay: 1.5, ..base.clone() },
        ];
        for cfg in cases {
            assert!(
                FleetSim::new(&cfg, 50, 5, 1000, 1.0, 1).is_err(),
                "accepted: {cfg:?}"
            );
        }
        assert!(FleetSim::new(&FleetConfig { async_buffer: Some(4), ..base }, 50, 5, 1000, 1.0, 1)
            .is_ok());
    }
}
