//! Event-queue round execution: over-selection, straggler drops, and
//! deadlines.
//!
//! The synchronous protocol's wall-clock is bound by its slowest
//! participant, so production FedAvg over-selects — dispatch
//! `⌈m·(1+ρ)⌉` clients, aggregate the first `m` to finish, discard the
//! stragglers — and bounds each round with a deadline. [`schedule_round`]
//! simulates exactly that over a discrete-event queue of client finish
//! times; [`FleetSim`] drives it for thousands of rounds with no training
//! attached (the `fedavg fleet --sim-only` / bench / stress-example
//! path).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::federated::sampler::ClientSampler;
use crate::Result;

use super::fleet::{Fleet, FleetProfile};
use super::{FleetConfig, FleetTotals};

/// Over-selection count: `⌈m·(1+ρ)⌉`, capped at the candidate pool.
pub fn overselect_count(m: usize, rho: f64, pool: usize) -> usize {
    let sel = (m as f64 * (1.0 + rho.max(0.0))).ceil() as usize;
    sel.max(m).min(pool)
}

/// One simulated round's outcome.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Clients the server sent the model to, in selection order.
    pub dispatched: Vec<usize>,
    /// Clients whose updates are aggregated (first `m` finishers inside
    /// the deadline), in dispatch order — the deterministic reduction
    /// order.
    pub completed: Vec<usize>,
    /// Dispatched clients whose updates were discarded.
    pub dropped: Vec<usize>,
    /// True when the deadline fired before `m` finishers arrived.
    pub deadline_miss: bool,
    /// Straggler-bound simulated wall-clock of the round: the `m`-th
    /// finish time, or the deadline when it fired first.
    pub round_seconds: f64,
}

/// A client-finished event in the round's event queue.
#[derive(Debug, PartialEq)]
struct Finish {
    t: f64,
    slot: usize,
}

impl Eq for Finish {}

impl Ord for Finish {
    fn cmp(&self, other: &Self) -> Ordering {
        // finish times are finite by construction; tie-break on dispatch
        // slot for a total, deterministic order
        self.t
            .partial_cmp(&other.t)
            .expect("non-finite finish time")
            .then(self.slot.cmp(&other.slot))
            .reverse() // BinaryHeap is a max-heap; we pop earliest first
    }
}

impl PartialOrd for Finish {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate one synchronous round over `durations` — `(client, seconds)`
/// pairs in dispatch order. Aggregates the first `m` finishers, drops the
/// rest, and cuts at `deadline_s` if set. If nobody meets the deadline
/// the server waits for the single earliest finisher (the protocol cannot
/// proceed with zero updates), still flagged as a deadline miss.
pub fn schedule_round(
    m: usize,
    deadline_s: Option<f64>,
    durations: &[(usize, f64)],
) -> RoundPlan {
    assert!(!durations.is_empty(), "scheduling an empty dispatch set");
    assert!(m >= 1, "round must aggregate at least one update");
    if let Some(d) = deadline_s {
        // NaN would silently never fire (`t > NaN` is false); negative
        // would make every round a guaranteed miss
        assert!(d.is_finite() && d > 0.0, "bad deadline {d}");
    }
    let mut queue: BinaryHeap<Finish> = durations
        .iter()
        .enumerate()
        .map(|(slot, &(_, t))| {
            assert!(t.is_finite() && t >= 0.0, "bad duration {t}");
            Finish { t, slot }
        })
        .collect();

    let mut done = vec![false; durations.len()];
    let mut n_done = 0usize;
    let mut round_seconds = 0.0f64;
    let mut deadline_miss = false;
    while let Some(ev) = queue.pop() {
        if let Some(d) = deadline_s {
            if ev.t > d {
                if n_done == 0 {
                    // nobody made it: wait for the earliest straggler
                    done[ev.slot] = true;
                    n_done = 1;
                    round_seconds = ev.t;
                } else {
                    round_seconds = d;
                }
                deadline_miss = true;
                break;
            }
        }
        done[ev.slot] = true;
        n_done += 1;
        round_seconds = ev.t;
        if n_done == m {
            break;
        }
    }

    let dispatched: Vec<usize> = durations.iter().map(|&(c, _)| c).collect();
    let completed: Vec<usize> = durations
        .iter()
        .enumerate()
        .filter(|(slot, _)| done[*slot])
        .map(|(_, &(c, _))| c)
        .collect();
    let dropped: Vec<usize> = durations
        .iter()
        .enumerate()
        .filter(|(slot, _)| !done[*slot])
        .map(|(_, &(c, _))| c)
        .collect();
    RoundPlan {
        dispatched,
        completed,
        dropped,
        deadline_miss,
        round_seconds,
    }
}

/// One round of the fleet protocol — diurnal online scan, over-selected
/// sample, per-client durations, event-queue schedule. The single
/// implementation behind both the training server and [`FleetSim`]: at
/// equal seeds the two build the same fleet and select the same clients;
/// the resulting plans coincide exactly when the duration inputs match
/// too (uncompressed links, uniform per-client step counts), and
/// otherwise differ only through `link_bytes`/`steps_of`.
///
/// `link_bytes(client) -> (down, up)` prices both link directions per
/// dispatched client. The training server passes the transport layer's
/// metering here, so the scheduler prices a transfer from the *same
/// codec* that later encodes it — per-client delta downlinks included —
/// and the estimate can never drift from the telemetry-reported bytes.
/// Returns the online-pool size alongside the plan.
#[allow(clippy::too_many_arguments)]
pub fn plan_round(
    fleet: &Fleet,
    sampler: &mut ClientSampler,
    round: u64,
    m: usize,
    overselect: f64,
    deadline_s: Option<f64>,
    mut link_bytes: impl FnMut(usize) -> (u64, u64),
    steps_of: impl Fn(usize) -> f64,
) -> (usize, RoundPlan) {
    let online = fleet.online_set(round);
    let n_sel = overselect_count(m, overselect, online.len());
    let dispatched = sampler.sample_from(round, &online, n_sel);
    let durations: Vec<(usize, f64)> = dispatched
        .iter()
        .map(|&c| {
            let (down, up) = link_bytes(c);
            (c, fleet.client_seconds(c, down, up, steps_of(c)))
        })
        .collect();
    (online.len(), schedule_round(m, deadline_s, &durations))
}

// ------------------------------------------------------------- fleet sim

/// Run-level totals for a training-free fleet simulation: the same
/// [`FleetTotals`] counters a training run reports, plus wire/wall-clock
/// sums.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimTotals {
    pub rounds: u64,
    pub fleet: FleetTotals,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub sim_seconds: f64,
}

/// One simulated round's report.
#[derive(Debug, Clone)]
pub struct SimRound {
    pub round: u64,
    /// Size of the online pool this round.
    pub online: usize,
    pub plan: RoundPlan,
}

/// Training-free fleet simulator: select → schedule → account, round
/// after round, over a [`Fleet`] of any size. This is the event-queue
/// subsystem isolated from learning, so 10k–100k-client scenarios run in
/// milliseconds per round with no artifacts or engine.
pub struct FleetSim {
    fleet: Fleet,
    cfg: FleetConfig,
    m: usize,
    model_bytes: u64,
    steps_per_client: f64,
    sampler: ClientSampler,
    round: u64,
    totals: SimTotals,
}

impl FleetSim {
    /// `m` updates aggregated per round out of `k` simulated clients,
    /// each running `steps_per_client` local SGD steps on a
    /// `model_bytes`-sized model.
    pub fn new(
        cfg: &FleetConfig,
        k: usize,
        m: usize,
        model_bytes: u64,
        steps_per_client: f64,
        seed: u64,
    ) -> Result<Self> {
        anyhow::ensure!(
            cfg.profile != FleetProfile::Legacy,
            "fleet sim needs a device profile (uniform|mobile|flaky)"
        );
        anyhow::ensure!(k >= 1 && m >= 1 && m <= k, "bad fleet shape k={k} m={m}");
        Ok(Self {
            fleet: Fleet::build(cfg, k, seed),
            cfg: cfg.clone(),
            m,
            model_bytes,
            steps_per_client,
            sampler: ClientSampler::new(seed),
            round: 0,
            totals: SimTotals::default(),
        })
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// Advance one round and fold it into the totals.
    pub fn step(&mut self) -> SimRound {
        self.round += 1;
        let round = self.round;
        let steps = self.steps_per_client;
        let mb = self.model_bytes;
        let (online, plan) = plan_round(
            &self.fleet,
            &mut self.sampler,
            round,
            self.m,
            self.cfg.overselect,
            self.cfg.deadline_s,
            |_| (mb, mb),
            |_| steps,
        );

        self.totals.rounds += 1;
        self.totals.fleet.dispatched += plan.dispatched.len() as u64;
        self.totals.fleet.completed += plan.completed.len() as u64;
        self.totals.fleet.dropped_stragglers += plan.dropped.len() as u64;
        self.totals.fleet.deadline_misses += plan.deadline_miss as u64;
        self.totals.bytes_up += self.model_bytes * plan.completed.len() as u64;
        self.totals.bytes_down += self.model_bytes * plan.dispatched.len() as u64;
        self.totals.sim_seconds += plan.round_seconds;

        SimRound {
            round,
            online,
            plan,
        }
    }

    pub fn totals(&self) -> SimTotals {
        self.totals
    }

    /// Fast-forward so the next [`step`](Self::step) executes
    /// `start_round`: rounds `1..start_round` are folded into the totals
    /// without emitting per-round reports (no telemetry rows, no
    /// printing). Every round is a pure function of `(seed, round)` —
    /// profiles, the diurnal clock, and the per-round selection stream
    /// carry no history — so the recomputed schedule is exactly what a
    /// full replay would have produced, and `fast_forward(r)` followed
    /// by stepping is bit-identical to stepping from round 1
    /// (regression-tested below). Behind `fedavg fleet --sim-only
    /// --start-round`, where multi-day 100k-client sims skip re-emitting
    /// a lost run's prefix.
    pub fn fast_forward(&mut self, start_round: u64) -> SimTotals {
        while self.round + 1 < start_round {
            self.step();
        }
        self.totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn durs(ts: &[f64]) -> Vec<(usize, f64)> {
        ts.iter().enumerate().map(|(c, &t)| (c * 10, t)).collect()
    }

    #[test]
    fn overselect_count_rounds_up_and_caps() {
        assert_eq!(overselect_count(10, 0.0, 100), 10);
        assert_eq!(overselect_count(10, 0.3, 100), 13);
        assert_eq!(overselect_count(10, 0.01, 100), 11); // ceil
        assert_eq!(overselect_count(10, 0.3, 11), 11); // pool cap
        assert_eq!(overselect_count(10, 0.3, 4), 4); // tiny pool
        assert_eq!(overselect_count(1, 2.0, 50), 3);
    }

    #[test]
    fn first_m_finishers_aggregate_rest_drop() {
        // finish order: slot2 (1s), slot0 (2s), slot3 (3s), slot1 (9s)
        let p = schedule_round(2, None, &durs(&[2.0, 9.0, 1.0, 3.0]));
        assert_eq!(p.completed, vec![0, 20]); // dispatch order, clients 0 & 20
        assert_eq!(p.dropped, vec![10, 30]);
        assert!(!p.deadline_miss);
        assert!((p.round_seconds - 2.0).abs() < 1e-12); // 2nd finisher bound
        assert_eq!(p.dispatched.len(), 4);
    }

    #[test]
    fn never_aggregates_more_than_m() {
        let mut rng = crate::data::rng::Rng::new(5);
        for case in 0..200 {
            let n = 1 + rng.below(40);
            let m = 1 + rng.below(n);
            let ts: Vec<f64> = (0..n).map(|_| rng.f64() * 100.0).collect();
            let deadline = if case % 2 == 0 {
                Some(0.01 + rng.f64() * 100.0)
            } else {
                None
            };
            let p = schedule_round(m, deadline, &durs(&ts));
            assert!(p.completed.len() <= m, "case {case}");
            assert!(!p.completed.is_empty(), "case {case}");
            assert_eq!(
                p.completed.len() + p.dropped.len(),
                p.dispatched.len(),
                "case {case}"
            );
        }
    }

    #[test]
    fn deadline_drops_stragglers_and_flags_miss() {
        // want 3, deadline at 4s: only 1s and 3s make it
        let p = schedule_round(3, Some(4.0), &durs(&[1.0, 8.0, 3.0, 9.0]));
        assert_eq!(p.completed, vec![0, 20]);
        assert_eq!(p.dropped, vec![10, 30]);
        assert!(p.deadline_miss);
        assert!((p.round_seconds - 4.0).abs() < 1e-12); // server waited out the deadline
    }

    #[test]
    fn deadline_met_is_not_a_miss() {
        // m finishers arrive before the deadline: surplus drop, no miss
        let p = schedule_round(2, Some(100.0), &durs(&[1.0, 2.0, 3.0]));
        assert_eq!(p.completed.len(), 2);
        assert_eq!(p.dropped, vec![20]);
        assert!(!p.deadline_miss);
        assert!((p.round_seconds - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_deadline_waits_for_first_finisher() {
        let p = schedule_round(2, Some(0.5), &durs(&[7.0, 3.0, 5.0]));
        assert_eq!(p.completed, vec![10]); // earliest straggler only
        assert!(p.deadline_miss);
        assert!((p.round_seconds - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ties_resolve_by_dispatch_slot() {
        let p = schedule_round(1, None, &durs(&[2.0, 2.0, 2.0]));
        assert_eq!(p.completed, vec![0]); // lowest slot wins the tie
    }

    #[test]
    fn fleet_sim_is_deterministic_and_accounts() {
        let cfg = FleetConfig {
            profile: FleetProfile::Mobile,
            overselect: 0.3,
            deadline_s: Some(30.0),
            ..Default::default()
        };
        let mut a = FleetSim::new(&cfg, 500, 20, 800_000, 60.0, 9).unwrap();
        let mut b = FleetSim::new(&cfg, 500, 20, 800_000, 60.0, 9).unwrap();
        for _ in 0..20 {
            let ra = a.step();
            let rb = b.step();
            assert_eq!(ra.plan.dispatched, rb.plan.dispatched);
            assert_eq!(ra.plan.completed, rb.plan.completed);
            assert!(ra.plan.completed.len() <= 20);
            // over-selection actually dispatches extras when the pool allows
            if ra.online >= 26 {
                assert_eq!(ra.plan.dispatched.len(), 26);
            }
        }
        let t = a.totals();
        assert_eq!(t.rounds, 20);
        assert_eq!(t.fleet.completed + t.fleet.dropped_stragglers, t.fleet.dispatched);
        assert_eq!(t.bytes_up, 800_000 * t.fleet.completed);
        assert_eq!(t.bytes_down, 800_000 * t.fleet.dispatched);
        assert!(t.sim_seconds > 0.0);
    }

    #[test]
    fn sim_rejects_legacy_profile() {
        assert!(FleetSim::new(&FleetConfig::default(), 10, 2, 1000, 1.0, 1).is_err());
    }

    #[test]
    fn fast_forward_equals_full_replay() {
        let cfg = FleetConfig {
            profile: FleetProfile::Flaky, // small online pools stress selection
            overselect: 0.4,
            deadline_s: Some(40.0),
            ..Default::default()
        };
        let mk = || FleetSim::new(&cfg, 400, 12, 700_000, 30.0, 13).unwrap();
        let (start, last) = (21u64, 30u64);

        // reference: full replay of rounds 1..=last
        let mut full = mk();
        let mut tail = Vec::new();
        for r in 1..=last {
            let sr = full.step();
            assert_eq!(sr.round, r);
            if r >= start {
                tail.push(sr);
            }
        }

        // fast-forwarded: totals folded for 1..start without reports
        let mut ff = mk();
        ff.fast_forward(start);
        for want in &tail {
            let got = ff.step();
            assert_eq!(got.round, want.round);
            assert_eq!(got.online, want.online);
            assert_eq!(got.plan.dispatched, want.plan.dispatched);
            assert_eq!(got.plan.completed, want.plan.completed);
            assert_eq!(got.plan.dropped, want.plan.dropped);
            assert_eq!(got.plan.deadline_miss, want.plan.deadline_miss);
            assert_eq!(got.plan.round_seconds, want.plan.round_seconds);
        }
        let (a, b) = (full.totals(), ff.totals());
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.fleet, b.fleet);
        assert_eq!(a.bytes_up, b.bytes_up);
        assert_eq!(a.bytes_down, b.bytes_down);
        assert_eq!(a.sim_seconds, b.sim_seconds);

        // degenerate targets: 0 and 1 are both "start at round 1"
        let mut z = mk();
        z.fast_forward(1);
        assert_eq!(z.totals().rounds, 0);
        assert_eq!(z.step().round, 1);
    }
}
