//! Shard assignment and tier-1 link timing for hierarchical (edge-tier)
//! aggregation (`--shards S`, DESIGN.md §11).
//!
//! The cohort selected for a round is split across S edge aggregators by
//! **contiguous dispatch-slot ranges**: shard `j` owns slots
//! `[⌊j·m/S⌋, ⌊(j+1)·m/S⌋)`. Contiguity is what makes the edge tier
//! bit-identical to flat aggregation — the root walks shards in index
//! order and each shard folds its slots in slot order, so the global f32
//! accumulation sequence is exactly the flat path's (see
//! [`params::weighted_fold`](crate::params::weighted_fold)). When
//! `S > m` the trailing shards receive empty ranges; they ship no frames
//! and fold nothing.
//!
//! Tier-1 (edge↔root) transfers are timed with a **deterministic** fixed
//! latency-plus-bandwidth formula — deliberately *not* the jittered
//! [`CommModel`](crate::comms::CommModel) draw, which would consume RNG
//! state and desync every subsequent client-link draw, breaking the
//! flat-vs-sharded bit-identity the suite in `rust/tests/shards.rs`
//! pins. Tier-1 bytes/seconds are reported via `obs::metrics`
//! (`tier.*`) and the run summary, never into curve.csv rows.

use std::ops::Range;

/// Deterministic tier-1 link parameters (edge↔root backhaul). Edge
/// aggregators sit on provisioned links, so the defaults are an order of
/// magnitude faster than the client-tier [`CommModel`](crate::comms::CommModel).
#[derive(Debug, Clone, Copy)]
pub struct TierLink {
    /// Link bandwidth, bytes/second (both directions; backhaul links are
    /// symmetric, unlike client last-mile links).
    pub bps: f64,
    /// Fixed per-transfer latency, seconds.
    pub latency_s: f64,
}

impl Default for TierLink {
    fn default() -> Self {
        Self {
            bps: 12.5e6, // 100 Mbit/s backhaul
            latency_s: 0.02,
        }
    }
}

/// Seconds for one tier-1 transfer of `bytes`: `latency + bytes/bps`.
/// No RNG, no jitter — see the module docs for why.
pub fn tier_transfer_seconds(link: &TierLink, bytes: u64) -> f64 {
    link.latency_s + bytes as f64 / link.bps
}

/// Contiguous slot ranges assigning `n` dispatch slots to `s` shards:
/// shard `j` gets `[⌊j·n/s⌋, ⌊(j+1)·n/s⌋)`. Ranges tile `0..n` in order;
/// sizes differ by at most one; `s > n` leaves the tail empty.
///
/// Panics if `s == 0` — shard count 0 means "flat", which has no
/// assignment to compute.
pub fn shard_ranges(n: usize, s: usize) -> Vec<Range<usize>> {
    assert!(s > 0, "shard_ranges: shard count must be >= 1");
    (0..s)
        .map(|j| (j * n / s)..((j + 1) * n / s))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_tile_in_order_with_balanced_sizes() {
        for n in [0usize, 1, 2, 7, 10, 100, 101] {
            for s in [1usize, 2, 3, 7, 32] {
                let ranges = shard_ranges(n, s);
                assert_eq!(ranges.len(), s);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "gap/overlap at n={n} s={s}");
                    assert!(r.end >= r.start);
                    next = r.end;
                }
                assert_eq!(next, n, "ranges do not cover 0..{n}");
                let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "unbalanced: {sizes:?}");
            }
        }
    }

    #[test]
    fn single_shard_owns_everything() {
        assert_eq!(shard_ranges(17, 1), vec![0..17]);
    }

    #[test]
    fn more_shards_than_slots_leaves_empty_tails() {
        let ranges = shard_ranges(3, 7);
        let non_empty: Vec<_> = ranges.iter().filter(|r| !r.is_empty()).collect();
        assert_eq!(non_empty.len(), 3);
        assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>(), 3);
    }

    #[test]
    #[should_panic(expected = "shard count")]
    fn zero_shards_is_a_caller_bug() {
        shard_ranges(5, 0);
    }

    #[test]
    fn transfer_seconds_is_deterministic_latency_plus_bandwidth() {
        let link = TierLink { bps: 1e6, latency_s: 0.5 };
        assert_eq!(tier_transfer_seconds(&link, 0), 0.5);
        assert_eq!(tier_transfer_seconds(&link, 2_000_000), 2.5);
        // same inputs, same answer — no hidden state
        assert_eq!(
            tier_transfer_seconds(&link, 1234),
            tier_transfer_seconds(&link, 1234)
        );
    }
}
