//! Persistent per-client device profiles — the simulated fleet.
//!
//! Each client gets a [`DeviceProfile`] drawn once per run from a seeded
//! distribution keyed by `hash3(seed, client, ·)`, so profiles are stable
//! across rounds, independent of construction order, and unchanged for
//! existing clients when the fleet grows. This replaces two memoryless
//! mechanisms from the seed implementation:
//!
//! * the per-round Bernoulli availability coin (`comms::Availability`) —
//!   here a device's reachability follows a **diurnal cycle** with a
//!   per-device phase (phones charge at night in their own timezone);
//! * the per-transfer uniform bandwidth jitter (`CommSim`) — here a slow
//!   uplink belongs to a specific device and stays slow, which is what
//!   makes straggler handling (over-selection, deadlines) meaningful.

use crate::data::rng::{hash3, hash3_unit, Rng};
use crate::Result;

use super::FleetConfig;

/// Device-population shapes for [`Fleet::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetProfile {
    /// No fleet: the seed's sequential, always-available round loop.
    Legacy,
    /// Identical reference devices (paper's 1 MB/s uplink), always online.
    Uniform,
    /// Heterogeneous phone fleet: log-uniform bandwidth spread, 2–8×
    /// compute spread, diurnal availability. The default for `fedavg
    /// fleet`.
    Mobile,
    /// Mobile bandwidth/compute spread but rarely reachable — stresses
    /// over-selection with tiny online pools.
    Flaky,
}

impl FleetProfile {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "legacy" => Ok(FleetProfile::Legacy),
            "uniform" => Ok(FleetProfile::Uniform),
            "mobile" => Ok(FleetProfile::Mobile),
            "flaky" => Ok(FleetProfile::Flaky),
            _ => anyhow::bail!("unknown fleet profile {s:?} (legacy|uniform|mobile|flaky)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FleetProfile::Legacy => "legacy",
            FleetProfile::Uniform => "uniform",
            FleetProfile::Mobile => "mobile",
            FleetProfile::Flaky => "flaky",
        }
    }
}

/// One client's fixed hardware + connectivity characteristics.
#[derive(Debug, Clone, Copy)]
pub struct DeviceProfile {
    /// Uplink bytes/second.
    pub up_bps: f64,
    /// Downlink bytes/second.
    pub down_bps: f64,
    /// Compute-time multiplier (1.0 = reference device, 4.0 = 4× slower).
    pub compute_mult: f64,
    /// Reachability probability at the device's diurnal peak.
    pub p_online_peak: f64,
    /// Phase offset of the diurnal cycle in [0, 1) — the device's
    /// "timezone".
    pub diurnal_phase: f64,
}

impl DeviceProfile {
    /// Reference device: the paper's 1 MB/s uplink, asymmetric downlink.
    fn reference() -> Self {
        Self {
            up_bps: 1.0e6,
            down_bps: 8.0e6,
            compute_mult: 1.0,
            p_online_peak: 1.0,
            diurnal_phase: 0.0,
        }
    }

    fn draw(kind: FleetProfile, rng: &mut Rng) -> Self {
        match kind {
            FleetProfile::Legacy | FleetProfile::Uniform => Self::reference(),
            FleetProfile::Mobile | FleetProfile::Flaky => {
                // log-uniform uplink in [0.05, 2.0] MB/s: the paper's
                // "1 MB/s or less", with a heavy slow tail
                let up_bps = 5.0e4 * 40.0f64.powf(rng.f64());
                // log-uniform compute multiplier in [0.5, 4.0]
                let compute_mult = 0.5 * 8.0f64.powf(rng.f64());
                let p_online_peak = match kind {
                    FleetProfile::Flaky => 0.10 + 0.20 * rng.f64(),
                    _ => 0.60 + 0.35 * rng.f64(),
                };
                Self {
                    up_bps,
                    down_bps: 8.0 * up_bps,
                    compute_mult,
                    p_online_peak,
                    diurnal_phase: rng.f64(),
                }
            }
        }
    }
}

/// The simulated device population for one run.
pub struct Fleet {
    kind: FleetProfile,
    profiles: Vec<DeviceProfile>,
    seed: u64,
    diurnal_period: f64,
    latency_s: f64,
    step_cost_s: f64,
}

impl Fleet {
    /// Draw `k` device profiles from `cfg.profile`'s distribution. Each
    /// client's profile is a pure function of `(seed, client)`.
    pub fn build(cfg: &FleetConfig, k: usize, seed: u64) -> Fleet {
        let profiles = (0..k)
            .map(|c| {
                let mut rng = Rng::new(hash3(seed, c as u64, 0xD5F11E));
                DeviceProfile::draw(cfg.profile, &mut rng)
            })
            .collect();
        Fleet {
            kind: cfg.profile,
            profiles,
            seed: seed ^ 0xF1EE7,
            diurnal_period: cfg.diurnal_period.max(1.0),
            latency_s: cfg.latency_s,
            step_cost_s: cfg.step_cost_s,
        }
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    pub fn kind(&self) -> FleetProfile {
        self.kind
    }

    pub fn profile(&self, client: usize) -> &DeviceProfile {
        &self.profiles[client]
    }

    /// Reachability probability of `client` in `round` under its diurnal
    /// cycle: the peak probability scaled by a sinusoidal daylight factor
    /// that bottoms out at 25% of peak on the device's night side.
    pub fn p_online(&self, round: u64, client: usize) -> f64 {
        let p = &self.profiles[client];
        if p.p_online_peak >= 1.0 {
            return 1.0;
        }
        let angle = (round as f64 / self.diurnal_period + p.diurnal_phase)
            * std::f64::consts::TAU;
        let daylight = 0.25 + 0.75 * (0.5 + 0.5 * angle.sin());
        (p.p_online_peak * daylight).clamp(0.0, 1.0)
    }

    /// Stateless online coin for `(round, client)` — same hash-coin
    /// construction as `comms::Availability`, so reachability is
    /// independent of query order and evaluation cadence.
    pub fn is_online(&self, round: u64, client: usize) -> bool {
        hash3_unit(self.seed, round, client as u64) < self.p_online(round, client)
    }

    /// All clients reachable in `round`. Guarantees at least one via the
    /// shared deterministic salted re-roll (salt 0 agrees with
    /// [`is_online`](Self::is_online)).
    pub fn online_set(&self, round: u64) -> Vec<usize> {
        crate::comms::salted_online_set(self.seed, round, self.profiles.len(), |c| {
            self.p_online(round, c)
        })
    }

    /// Simulated seconds for `client` to complete one round: model down,
    /// `local_steps` SGD steps at its compute speed, model (or compressed
    /// update) up, plus fixed latency each way.
    pub fn client_seconds(
        &self,
        client: usize,
        down_bytes: u64,
        up_bytes: u64,
        local_steps: f64,
    ) -> f64 {
        let p = &self.profiles[client];
        2.0 * self.latency_s
            + down_bytes as f64 / p.down_bps
            + local_steps * self.step_cost_s * p.compute_mult
            + up_bytes as f64 / p.up_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mobile_cfg() -> FleetConfig {
        FleetConfig {
            profile: FleetProfile::Mobile,
            ..Default::default()
        }
    }

    #[test]
    fn profiles_are_persistent_and_heterogeneous() {
        let cfg = mobile_cfg();
        let a = Fleet::build(&cfg, 200, 7);
        let b = Fleet::build(&cfg, 200, 7);
        for c in 0..200 {
            assert_eq!(a.profile(c).up_bps, b.profile(c).up_bps, "client {c}");
        }
        // growing the fleet must not reshuffle existing clients
        let bigger = Fleet::build(&cfg, 400, 7);
        for c in 0..200 {
            assert_eq!(a.profile(c).up_bps, bigger.profile(c).up_bps);
        }
        // heterogeneous: bandwidths spread over more than one order
        let ups: Vec<f64> = (0..200).map(|c| a.profile(c).up_bps).collect();
        let min = ups.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = ups.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 5.0, "no bandwidth spread: {min}..{max}");
        // within the documented envelope
        assert!(min >= 5.0e4 && max <= 2.0e6, "{min}..{max}");
    }

    #[test]
    fn uniform_fleet_is_reference_and_always_online() {
        let cfg = FleetConfig {
            profile: FleetProfile::Uniform,
            ..Default::default()
        };
        let f = Fleet::build(&cfg, 50, 3);
        for round in 0..20 {
            assert_eq!(f.online_set(round).len(), 50);
        }
        assert_eq!(f.profile(0).up_bps, 1.0e6);
        assert_eq!(f.p_online(5, 0), 1.0);
    }

    #[test]
    fn diurnal_cycle_moves_availability() {
        let cfg = mobile_cfg();
        let f = Fleet::build(&cfg, 1, 11);
        let period = cfg.diurnal_period as u64;
        let ps: Vec<f64> = (0..period).map(|r| f.p_online(r, 0)).collect();
        let peak = ps.iter().cloned().fold(0.0, f64::max);
        let trough = ps.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(peak > 2.0 * trough, "no diurnal swing: {trough}..{peak}");
        // and the cycle repeats
        assert!((f.p_online(0, 0) - f.p_online(period * 3, 0)).abs() < 1e-9);
    }

    #[test]
    fn online_set_order_independent_and_nonempty() {
        let cfg = FleetConfig {
            profile: FleetProfile::Flaky,
            ..Default::default()
        };
        let f = Fleet::build(&cfg, 30, 5);
        let forward: Vec<Vec<usize>> = (0..10).map(|r| f.online_set(r)).collect();
        for r in (0..10).rev() {
            assert_eq!(f.online_set(r), forward[r as usize]);
            assert!(!forward[r as usize].is_empty());
        }
    }

    #[test]
    fn client_seconds_composes_link_and_compute() {
        let cfg = FleetConfig {
            profile: FleetProfile::Uniform,
            latency_s: 0.1,
            step_cost_s: 0.02,
            ..Default::default()
        };
        let f = Fleet::build(&cfg, 1, 1);
        // 8 MB down at 8 MB/s (1s) + 10 steps (0.2s) + 1 MB up at 1 MB/s
        // (1s) + 2x latency (0.2s)
        let t = f.client_seconds(0, 8_000_000, 1_000_000, 10.0);
        assert!((t - 2.4).abs() < 1e-9, "{t}");
    }
}
