//! The fleet coordinator — the paper's L3 systems contribution, grown
//! into a subsystem (DESIGN.md §4).
//!
//! The paper's premise is that federated clients are slow, heterogeneous,
//! and frequently offline, yet Algorithm 1 abstracts all of that behind a
//! synchronous round. This module owns the systems half the paper assumes
//! away:
//!
//! * [`fleet`] — persistent per-client **device profiles** (uplink /
//!   downlink bandwidth, compute speed, diurnal availability phase) drawn
//!   once per run from seeded distributions. Replaces the order-dependent
//!   per-round Bernoulli coin and the memoryless bandwidth jitter with a
//!   fleet whose slow devices stay slow and whose night-side devices stay
//!   offline.
//! * [`scheduler`] — discrete-event **round execution**: the server
//!   over-selects `⌈m·(1+ρ)⌉` clients, aggregates the first `m`
//!   finishers from the event queue, and drops stragglers past a round
//!   deadline — the production FedAvg recipe (Bonawitz et al.,
//!   "Towards Federated Learning at Scale"). Also hosts [`FleetSim`],
//!   the training-free fleet simulator behind `fedavg fleet --sim-only`,
//!   `examples/fleet_stress.rs`, and `benches/fleet_round.rs`.
//! * [`exec`] — **parallel ClientUpdate dispatch** over
//!   [`runtime::pool::WorkerPool`](crate::runtime::pool::WorkerPool)
//!   (one PJRT engine per worker thread, since engines are not `Send`),
//!   with reduction in dispatch-slot order so `--workers N` is
//!   bit-identical to sequential execution.
//!
//! [`federated::server::run`](crate::federated::server::run) is wired
//! through this module: the default [`FleetConfig`] (`Legacy` profile,
//! one worker) reproduces the original sequential, always-available
//! round loop bit-for-bit.

pub mod exec;
pub mod fleet;
pub mod scheduler;
pub mod shards;

pub use exec::{ClientJob, ExecScratch, ParallelExec};
pub use fleet::{DeviceProfile, Fleet, FleetProfile};
pub use scheduler::{
    fault_of, overselect_count, plan_async_wave, plan_round, schedule_async_wave, schedule_round,
    Arrival, Fault, FaultConfig, FleetSim, RoundPlan, SimRound, SimTotals, WavePlan,
};
pub use shards::{shard_ranges, tier_transfer_seconds, TierLink};

/// What happens to a dispatched straggler that finishes after the round
/// deadline (DESIGN.md §12). `Drop` is the paper's synchronous barrier;
/// `Discount` is the semi-sync mode: the late update keeps training,
/// waits in a queue keyed by its virtual finish time, and joins a later
/// round's combine with a staleness-discounted weight instead of being
/// discarded.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum LatePolicy {
    /// Discard past-deadline updates (the synchronous protocol).
    #[default]
    Drop,
    /// Apply past-deadline updates late, weighted by
    /// `--staleness-decay` per round of lateness.
    Discount,
}

impl LatePolicy {
    /// Parse the `--late-policy` CLI token.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "drop" => Ok(Self::Drop),
            "discount" => Ok(Self::Discount),
            _ => anyhow::bail!("unknown --late-policy {s:?} (want drop|discount)"),
        }
    }
}

/// Knobs for fleet-aware round execution, carried in
/// [`ServerOptions`](crate::federated::ServerOptions). The default is the
/// legacy path: no device profiles, no over-selection, no deadline, one
/// inline worker.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Device-population shape; `Legacy` bypasses the coordinator.
    pub profile: FleetProfile,
    /// Over-selection factor ρ: dispatch `⌈m·(1+ρ)⌉`, aggregate `m`.
    pub overselect: f64,
    /// Round deadline (simulated seconds); stragglers past it are dropped.
    pub deadline_s: Option<f64>,
    /// ClientUpdate worker threads (1 = inline sequential execution).
    pub workers: usize,
    /// Simulated seconds per local SGD step on a reference device
    /// (`compute_mult = 1.0`); per-client cost scales by the profile.
    pub step_cost_s: f64,
    /// Rounds per diurnal availability cycle.
    pub diurnal_period: f64,
    /// Fixed per-transfer latency (seconds), as in `CommModel`.
    pub latency_s: f64,
    /// Edge-aggregator count for hierarchical aggregation (`--shards S`);
    /// 0 = flat single-tier aggregation (DESIGN.md §11).
    pub shards: usize,
    /// Buffered-async aggregation (`--async-buffer K`): the server runs
    /// combine∘step whenever K client deltas have arrived, instead of
    /// waiting out a synchronous cohort. `None` = synchronous rounds
    /// (DESIGN.md §12).
    pub async_buffer: Option<usize>,
    /// Per-apply staleness discount d ∈ (0, 1]: a delta dispatched s
    /// server applies ago is weighted `n_k·d^s`. 1.0 = no discount (and
    /// the bit-exact sync-identity guard).
    pub staleness_decay: f64,
    /// Semi-sync straggler handling past the deadline (`--late-policy`).
    pub late_policy: LatePolicy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            profile: FleetProfile::Legacy,
            overselect: 0.0,
            deadline_s: None,
            workers: 1,
            step_cost_s: 0.02,
            diurnal_period: 48.0,
            latency_s: 0.1,
            shards: 0,
            async_buffer: None,
            staleness_decay: 1.0,
            late_policy: LatePolicy::Drop,
        }
    }
}

impl FleetConfig {
    /// True when the coordinator fleet path is active (any non-legacy
    /// device profile).
    pub fn fleet_active(&self) -> bool {
        self.profile != FleetProfile::Legacy
    }
}

/// Run-level fleet accounting, reported in
/// [`RunResult`](crate::federated::RunResult) and the run summary, and
/// captured by run-state snapshots (`crate::runstate`, DESIGN.md §8) —
/// unlike the [`Fleet`] itself, whose device profiles and diurnal clock
/// are pure functions of `(seed, client, round)` and need no snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetTotals {
    /// Clients the server dispatched the model to (incl. over-selection).
    pub dispatched: u64,
    /// Client updates that made it into an aggregate.
    pub completed: u64,
    /// Dispatched clients whose updates were discarded (over-selection
    /// surplus or past-deadline stragglers).
    pub dropped_stragglers: u64,
    /// Rounds where the deadline fired before `m` finishers arrived.
    pub deadline_misses: u64,
}
