//! Privacy extensions — the paper's §4 future-work direction, built out:
//!
//! * [`clip`]/[`GaussianMechanism`] — differentially-private FedAvg:
//!   per-client update L2-clipping followed by Gaussian noise on the
//!   *aggregate*, the (ε, δ)-DP recipe of Abadi et al. [1] the paper
//!   cites. Accounting uses basic composition over rounds (documented —
//!   a moments accountant would be tighter).
//! * [`SecureAggregator`] — pairwise additive masking in fixed point
//!   (the Bonawitz et al. protocol the paper's footnote 7 anticipates):
//!   each pair of clients shares a seeded mask that cancels in the sum,
//!   so the server learns only Σ updates, never an individual update.
//!
//! Both compose with the plain FedAvg loop: they transform client deltas
//! before averaging (see [`ServerOptions`](crate::federated::ServerOptions)
//! wiring and the `fedavg run --dp-*` / `--secure-agg` flags). In the
//! server's per-update order, clipping runs *before* the uplink codec
//! pipeline (DESIGN.md §6) — codecs see already-clipped deltas.

use crate::data::rng::{Rng, RngState};
use crate::params::ParamVec;

/// [`GaussianMechanism`]'s snapshot payload (`crate::runstate`,
/// DESIGN.md §8): the noise stream position and the rounds-applied
/// counter the ε accounting multiplies over. Dropping either on resume
/// would silently re-use noise or under-report the privacy spend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MechState {
    pub rng: RngState,
    pub rounds_applied: u64,
}

/// L2-clip an update in place; returns the pre-clip norm.
pub fn clip(update: &mut [f32], max_norm: f64) -> f64 {
    let norm = crate::params::l2_norm(update);
    if norm > max_norm && norm > 0.0 {
        let s = (max_norm / norm) as f32;
        for v in update.iter_mut() {
            *v *= s;
        }
    }
    norm
}

/// Gaussian mechanism over the averaged update.
#[derive(Debug, Clone)]
pub struct GaussianMechanism {
    /// per-client clip bound (L2) — the sensitivity unit.
    pub clip_norm: f64,
    /// noise multiplier σ (std = σ · clip / m for an m-client average).
    pub sigma: f64,
    rng: Rng,
    rounds_applied: u64,
}

impl GaussianMechanism {
    pub fn new(clip_norm: f64, sigma: f64, seed: u64) -> Self {
        assert!(clip_norm > 0.0 && sigma >= 0.0);
        Self {
            clip_norm,
            sigma,
            rng: Rng::new(seed ^ 0xD9),
            rounds_applied: 0,
        }
    }

    /// Noise the m-client *average* update in place.
    /// Sensitivity of the average to one client is `clip_norm / m`
    /// (weights equal; weighted averages bound similarly by max wᵢ/Σw).
    pub fn apply(&mut self, avg_update: &mut [f32], m: usize) {
        let std = (self.sigma * self.clip_norm / m.max(1) as f64) as f32;
        for v in avg_update.iter_mut() {
            *v += std * self.rng.gauss_f32();
        }
        self.rounds_applied += 1;
    }

    /// (ε, δ) after `rounds_applied` rounds under *basic* composition of
    /// the analytic single-shot Gaussian bound ε₀ = √(2 ln(1.25/δ))/σ.
    /// (Simplification documented in DESIGN.md; a moments accountant
    /// gives ~√T scaling instead of T.)
    pub fn epsilon(&self, delta: f64) -> f64 {
        if self.sigma == 0.0 {
            return f64::INFINITY;
        }
        let eps0 = (2.0 * (1.25 / delta).ln()).sqrt() / self.sigma;
        eps0 * self.rounds_applied as f64
    }

    pub fn rounds_applied(&self) -> u64 {
        self.rounds_applied
    }

    /// Capture the mechanism's mutable state for a run-state snapshot.
    pub fn state_save(&self) -> MechState {
        MechState {
            rng: self.rng.state(),
            rounds_applied: self.rounds_applied,
        }
    }

    /// Restore the state captured by [`state_save`](Self::state_save);
    /// the noise stream and ε accounting continue exactly where the
    /// checkpointed run left off. `clip_norm`/`sigma` are config and
    /// come back from the `--dp-*` flags (verified by the caller).
    pub fn state_load(&mut self, st: MechState) {
        self.rng = Rng::from_state(st.rng);
        self.rounds_applied = st.rounds_applied;
    }
}

/// Pairwise-mask secure aggregation (semi-honest, no dropouts — the
/// dropout-recovery shares of the full Bonawitz protocol are out of
/// scope; DESIGN.md notes the simplification).
///
/// Values are encoded in fixed point mod 2^32; for every client pair
/// (i, j), i<j, a shared seeded mask Mᵢⱼ is added by i and subtracted by
/// j. Individual masked updates are (computationally) independent of the
/// plaintexts; the modular sum telescopes the masks away exactly.
pub struct SecureAggregator {
    /// fixed-point scale: value = round(x * SCALE) mod 2^32.
    scale: f64,
    session_seed: u64,
}

impl SecureAggregator {
    pub fn new(session_seed: u64) -> Self {
        Self {
            scale: (1u64 << 20) as f64, // ~1e-6 resolution, ±2k range
            session_seed,
        }
    }

    fn mask_rng(&self, i: usize, j: usize) -> Rng {
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        Rng::new(
            self.session_seed
                ^ (lo as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (hi as u64).wrapping_mul(0xD1B54A32D192ED03),
        )
    }

    /// Client `id`'s masked, fixed-point encoding of `update`, given the
    /// participating client set.
    pub fn mask(&self, id: usize, participants: &[usize], update: &[f32]) -> Vec<u32> {
        let mut out: Vec<u32> = update
            .iter()
            .map(|&v| (v as f64 * self.scale).round() as i64 as u32)
            .collect();
        for &other in participants {
            if other == id {
                continue;
            }
            let mut rng = self.mask_rng(id, other);
            let sign_add = id < other; // lower id adds, higher subtracts
            for slot in out.iter_mut() {
                let m = rng.next_u64() as u32;
                *slot = if sign_add {
                    slot.wrapping_add(m)
                } else {
                    slot.wrapping_sub(m)
                };
            }
        }
        out
    }

    /// Server-side: sum masked vectors (masks cancel), decode to floats.
    pub fn aggregate(&self, masked: &[Vec<u32>]) -> ParamVec {
        assert!(!masked.is_empty());
        let dim = masked[0].len();
        let mut acc = vec![0u32; dim];
        for v in masked {
            assert_eq!(v.len(), dim);
            for (a, &x) in acc.iter_mut().zip(v) {
                *a = a.wrapping_add(x);
            }
        }
        acc.into_iter()
            .map(|u| (u as i32) as f64 / self.scale)
            .map(|v| v as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_preserves_small_and_bounds_large() {
        let mut small = vec![0.1f32, 0.2];
        let n = clip(&mut small, 10.0);
        assert!(n < 10.0);
        assert_eq!(small, vec![0.1, 0.2]);

        let mut large = vec![30.0f32, 40.0]; // norm 50
        clip(&mut large, 5.0);
        let norm = crate::params::l2_norm(&large);
        assert!((norm - 5.0).abs() < 1e-4);
        // direction preserved
        assert!((large[0] / large[1] - 0.75).abs() < 1e-5);
    }

    #[test]
    fn gaussian_mechanism_noise_scale_and_accounting() {
        let mut mech = GaussianMechanism::new(1.0, 2.0, 7);
        let mut zeros = vec![0.0f32; 40_000];
        mech.apply(&mut zeros, 10);
        let std_emp = (zeros.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
            / zeros.len() as f64)
            .sqrt();
        let want = 2.0 * 1.0 / 10.0;
        assert!(
            (std_emp - want).abs() / want < 0.05,
            "std {std_emp} vs {want}"
        );
        assert_eq!(mech.rounds_applied(), 1);
        let e1 = mech.epsilon(1e-5);
        mech.apply(&mut zeros, 10);
        assert!((mech.epsilon(1e-5) - 2.0 * e1).abs() < 1e-9, "linear comp");
        assert!(e1 > 0.0 && e1.is_finite());
    }

    #[test]
    fn sigma_zero_is_infinite_epsilon_and_noiseless() {
        let mut mech = GaussianMechanism::new(1.0, 0.0, 1);
        let mut v = vec![1.0f32; 8];
        mech.apply(&mut v, 4);
        assert_eq!(v, vec![1.0f32; 8]);
        assert_eq!(mech.epsilon(1e-5), f64::INFINITY);
    }

    #[test]
    fn secure_aggregation_sum_exact_and_masking_hides() {
        let agg = SecureAggregator::new(99);
        let participants = vec![0, 1, 2, 3];
        let updates: Vec<Vec<f32>> = vec![
            vec![0.5, -1.25, 3.0],
            vec![-0.5, 0.25, 1.0],
            vec![2.0, 2.0, -4.0],
            vec![0.0, -1.0, 0.5],
        ];
        let masked: Vec<Vec<u32>> = participants
            .iter()
            .map(|&id| agg.mask(id, &participants, &updates[id]))
            .collect();
        // masked vector differs wildly from plain encoding (hides value)
        let plain0: Vec<u32> = updates[0]
            .iter()
            .map(|&v| (v as f64 * (1u64 << 20) as f64).round() as i64 as u32)
            .collect();
        assert_ne!(masked[0], plain0);

        let sum = agg.aggregate(&masked);
        for d in 0..3 {
            let want: f32 = updates.iter().map(|u| u[d]).sum();
            assert!(
                (sum[d] - want).abs() < 1e-4,
                "dim {d}: {} vs {want}",
                sum[d]
            );
        }
    }

    #[test]
    fn secure_aggregation_two_clients_and_negative_values() {
        let agg = SecureAggregator::new(3);
        let ps = vec![7, 11];
        let a = vec![-2.5f32, 0.0];
        let b = vec![2.5f32, -0.125];
        let sum = agg.aggregate(&[agg.mask(7, &ps, &a), agg.mask(11, &ps, &b)]);
        assert!((sum[0] - 0.0).abs() < 1e-4);
        assert!((sum[1] + 0.125).abs() < 1e-4);
    }

    #[test]
    fn masks_are_pair_symmetric() {
        // i's add-mask against j equals j's subtract-mask against i,
        // so a 2-party sum is exactly unmasked
        let agg = SecureAggregator::new(5);
        let ps = vec![1, 2];
        let zero = vec![0.0f32; 16];
        let sum = agg.aggregate(&[agg.mask(1, &ps, &zero), agg.mask(2, &ps, &zero)]);
        assert!(sum.iter().all(|&v| v.abs() < 1e-6), "{sum:?}");
    }
}
