//! The bench trajectory harness (DESIGN.md §10): the five bench areas as
//! library functions, plus the committed `BENCH_<area>.json` snapshot
//! format they record into.
//!
//! `cargo bench` still works — each file under `rust/benches/` is now a
//! thin wrapper over the corresponding function here — but the canonical
//! entry point is **`fedavg bench`**, which runs the areas and writes
//! machine-tagged snapshots (median/p10/p90 ns per case) meant to be
//! committed at the repo root, seeding the perf trajectory the README
//! tracks. `fedavg bench --check` runs every case exactly once on a
//! millisecond budget and validates the emitted JSON against
//! [`validate_snapshot`] — the CI smoke mode. See `BENCH_schema.md`.
//!
//! Wall-clock numbers live only in these snapshots (and trace.jsonl) —
//! never in curve.csv or grid manifests (DESIGN.md §8/§9).

use std::path::Path;
use std::time::{Duration, SystemTime};

use crate::comms::wire::Pipeline;
use crate::config::BatchSize;
use crate::coordinator::{schedule_round, FleetConfig, FleetProfile, FleetSim, TierLink};
use crate::data::rng::Rng;
use crate::data::{Dataset, Examples};
use crate::federated::aggregate::{combine_sharded, AggConfig, Aggregator as _};
use crate::federated::{local_update, LocalSpec};
use crate::params;
use crate::runstate::atomic_write;
use crate::runtime::Engine;
use crate::util::bench::{BenchResult, Bencher};
use crate::util::json::{escape, Json};
use crate::Result;

/// Snapshot schema identifier (`BENCH_schema.md`).
pub const BENCH_SCHEMA: &str = "fedavg-bench-v1";

/// The five recorded areas, in canonical order.
pub const AREAS: &[&str] = &[
    "params_hot_path",
    "codec_pipeline",
    "fleet_round",
    "aggregators",
    "client_update",
];

/// Whether an area produced results worth snapshotting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AreaStatus {
    Recorded,
    /// Cleanly skipped (e.g. `client_update` without AOT artifacts) —
    /// no snapshot is written.
    Skipped(&'static str),
}

/// A `--check`-profile bencher: one warmup iteration, ~1 ms budget per
/// case — every case executes at least once, nothing is measured
/// meaningfully. CI smoke material.
pub fn check_bencher() -> Bencher {
    Bencher::new(Duration::ZERO, Duration::from_millis(1))
}

/// Run one named area into `b`.
pub fn run_area(area: &str, b: &mut Bencher) -> Result<AreaStatus> {
    match area {
        "params_hot_path" => {
            params_hot_path(b);
            Ok(AreaStatus::Recorded)
        }
        "codec_pipeline" => codec_pipeline(b).map(|_| AreaStatus::Recorded),
        "fleet_round" => fleet_round(b).map(|_| AreaStatus::Recorded),
        "aggregators" => aggregators(b).map(|_| AreaStatus::Recorded),
        "client_update" => client_update(b),
        other => anyhow::bail!("unknown bench area {other:?} (known: {})", AREAS.join(", ")),
    }
}

/// The server's parameter-vector hot path (weighted averaging, axpy,
/// interpolation) across the paper's model sizes (§Perf L3).
pub fn params_hot_path(b: &mut Bencher) {
    // paper model sizes: 2NN, char-LSTM, CIFAR CNN, MNIST CNN, word-LSTM
    for (name, p) in [
        ("2nn_199k", 199_210usize),
        ("lstm_820k", 820_522),
        ("cifar_1.07m", 1_068_298),
        ("cnn_1.66m", 1_663_370),
        ("word_4.36m", 4_359_120),
    ] {
        let vecs: Vec<Vec<f32>> = (0..10)
            .map(|i| (0..p).map(|j| ((i * j) % 97) as f32 * 0.01).collect())
            .collect();
        let weighted: Vec<(f32, &[f32])> = vecs
            .iter()
            .enumerate()
            .map(|(i, v)| (1.0 + i as f32, v.as_slice()))
            .collect();

        b.bench_elems(
            &format!("weighted_mean/10clients/{name}"),
            (10 * p) as f64,
            || {
                std::hint::black_box(params::weighted_mean(&weighted));
            },
        );

        // the recorded before/after pair (DESIGN.md §14): the pre-fusion
        // two-pass kernel kept in params::reference vs the fused
        // single-traversal kernel reusing one output buffer
        b.bench_elems(
            &format!("ref/weighted_mean/10clients/{name}"),
            (10 * p) as f64,
            || {
                std::hint::black_box(params::reference::weighted_mean(&weighted));
            },
        );
        let mut wm_out = params::ParamVec::new();
        b.bench_elems(
            &format!("weighted_mean_into/10clients/{name}"),
            (10 * p) as f64,
            || {
                params::weighted_mean_into(&mut wm_out, &weighted);
                std::hint::black_box(wm_out.len());
            },
        );

        let mut acc = vec![0.0f32; p];
        b.bench_elems(&format!("axpy/{name}"), p as f64, || {
            params::axpy(&mut acc, 0.5, &vecs[0]);
            std::hint::black_box(&acc);
        });

        b.bench_elems(&format!("interpolate/{name}"), p as f64, || {
            std::hint::black_box(params::interpolate(&vecs[0], &vecs[1], 0.37));
        });
    }

    // GB/s summary for the averaging loop (reads 10 vecs + writes out per accumulate)
    if let Some(r) = b
        .results()
        .iter()
        .find(|r| r.name == "weighted_mean/10clients/cnn_1.66m")
    {
        let bytes = (2 * 10) as f64 * 1_663_370.0 * 4.0; // read acc+src per axpy
        println!(
            "\nweighted_mean(cnn) effective bandwidth: {:.2} GB/s",
            bytes / (r.mean_ns / 1e9) / 1e9
        );
    }
}

/// Codec-pipeline encode/measure/decode throughput at CNN size (1.66M
/// params) — the transport runs once per aggregated client per round on
/// the server's critical path.
pub fn codec_pipeline(b: &mut Bencher) -> Result<()> {
    let dim = 1_663_370; // MNIST CNN parameter count
    let mut rng = Rng::new(3);
    let base: Vec<f32> = (0..dim).map(|_| rng.gauss_f32() * 0.1).collect();
    let mut theta = base.clone();
    for i in (0..dim).step_by(100) {
        theta[i] += 0.05; // ~1% round-to-round change
    }

    for spec in ["q8", "topk:0.01", "topk:0.01|q8"] {
        let p = Pipeline::parse(spec)?;
        let mut enc_rng = Rng::new(7);
        b.bench_elems(&format!("run/{spec}"), dim as f64, || {
            std::hint::black_box(p.run(&theta, None, &mut enc_rng).unwrap());
        });
    }

    // delta downlink: measure (pricing pass, no allocation of the frame)
    // vs full encode+serialize
    let delta = Pipeline::parse("delta")?;
    b.bench_elems("measure/delta", dim as f64, || {
        std::hint::black_box(delta.measure(&theta, Some(&base)).unwrap());
    });
    let mut enc_rng = Rng::new(9);
    b.bench_elems("encode/delta", dim as f64, || {
        std::hint::black_box(delta.encode(&theta, Some((1, &base)), &mut enc_rng).unwrap());
    });

    // frame round-trip at the wire level
    let p = Pipeline::parse("topk:0.01|q8")?;
    let frame = p.encode(&theta, None, &mut Rng::new(11))?;
    println!(
        "\n  topk:0.01|q8 frame: {} bytes (dense {})",
        frame.wire_bytes(),
        4 * dim
    );
    b.bench_elems("decode/topk:0.01|q8", dim as f64, || {
        std::hint::black_box(frame.decode(None).unwrap());
    });
    // the recorded before/after pair (DESIGN.md §14): owned decode above
    // vs the borrowed-frame view decoding into a reused buffer
    let mut dec_buf = Vec::new();
    b.bench_elems("decode_into/topk:0.01|q8", dim as f64, || {
        frame.view().decode_into(None, &mut dec_buf).unwrap();
        std::hint::black_box(dec_buf.len());
    });
    Ok(())
}

/// Event-queue scheduling overhead at fleet scale: the select →
/// over-select → schedule → account pipeline at 1k/10k/100k clients.
pub fn fleet_round(b: &mut Bencher) -> Result<()> {
    // full round pipeline: diurnal online scan + sample + schedule
    for k in [1_000usize, 10_000, 100_000] {
        let cfg = FleetConfig {
            profile: FleetProfile::Mobile,
            overselect: 0.3,
            deadline_s: Some(90.0),
            ..Default::default()
        };
        let m = (k / 100).max(1); // C = 0.01
        let mut sim = FleetSim::new(&cfg, k, m, 6_653_480, 300.0, 7)?;
        b.bench_elems(&format!("fleet_round/k={k}"), k as f64, || {
            std::hint::black_box(sim.step());
        });
    }

    // scheduler alone: the event queue at growing dispatch sizes
    for n in [1_000usize, 10_000, 100_000] {
        let mut rng = Rng::new(11);
        let durations: Vec<(usize, f64)> = (0..n).map(|c| (c, 1.0 + 99.0 * rng.f64())).collect();
        let m = n * 3 / 4;
        b.bench_elems(&format!("schedule_round/n={n}"), n as f64, || {
            std::hint::black_box(schedule_round(m, Some(80.0), &durations));
        });
    }

    // hierarchical combine (DESIGN.md §11): the sharded cascade's
    // overhead over flat weighted averaging at 2NN size — S extra dense
    // frame round-trips per combine, same arithmetic
    let dim = 199_210usize;
    let m = 50usize;
    let mut rng = Rng::new(13);
    let deltas: Vec<Vec<f32>> = (0..m)
        .map(|_| (0..dim).map(|_| rng.gauss_f32() * 0.01).collect())
        .collect();
    let refs: Vec<(f32, &[f32])> = deltas.iter().map(|d| (600.0, d.as_slice())).collect();
    let agg = AggConfig::default().build()?;
    let link = TierLink::default();
    b.bench_elems("combine_flat/50clients/2nn_199k", (m * dim) as f64, || {
        std::hint::black_box(agg.combine(&refs).unwrap());
    });
    // the recorded before/after pair (DESIGN.md §14): allocating combine
    // above vs combine_into refilling the round loop's scratch buffer
    let mut flat_buf = Vec::new();
    b.bench_elems(
        "combine_into_flat/50clients/2nn_199k",
        (m * dim) as f64,
        || {
            agg.combine_into(&refs, &mut flat_buf).unwrap();
            std::hint::black_box(flat_buf.len());
        },
    );
    for s in [1usize, 8] {
        b.bench_elems(
            &format!("combine_sharded/s={s}/50clients/2nn_199k"),
            (m * dim) as f64,
            || {
                std::hint::black_box(combine_sharded(agg.as_ref(), &refs, s, &link).unwrap());
            },
        );
    }
    Ok(())
}

/// Aggregation rules at paper-model sizes: combine (weighted mean vs the
/// robust order statistics) and the stateful server-optimizer steps.
pub fn aggregators(b: &mut Bencher) -> Result<()> {
    let dim = 199_210; // MNIST 2NN parameter count
    let m = 50;
    let mut rng = Rng::new(3);
    let deltas: Vec<Vec<f32>> = (0..m)
        .map(|_| (0..dim).map(|_| rng.gauss_f32() * 0.01).collect())
        .collect();
    let refs: Vec<(f32, &[f32])> = deltas.iter().map(|d| (600.0, d.as_slice())).collect();

    for spec in ["fedavg", "trimmed:0.1", "median"] {
        let agg = AggConfig {
            spec: spec.into(),
            ..Default::default()
        }
        .build()?;
        b.bench_elems(&format!("combine/{spec}"), dim as f64, || {
            std::hint::black_box(agg.combine(&refs).unwrap());
        });
    }

    // the recorded before/after pairs (DESIGN.md §14): the pre-fusion
    // kernels kept in params::reference vs the blocked kernels above,
    // plus the blocked order statistics threaded at 4 workers
    // (bit-identical at any worker count — speed is the only difference)
    let vec_refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
    b.bench_elems("ref/combine/fedavg", dim as f64, || {
        std::hint::black_box(params::reference::weighted_mean(&refs));
    });
    b.bench_elems("ref/combine/trimmed:0.1", dim as f64, || {
        std::hint::black_box(params::reference::trimmed_mean(&vec_refs, 0.1));
    });
    b.bench_elems("ref/combine/median", dim as f64, || {
        std::hint::black_box(params::reference::median(&vec_refs));
    });
    for spec in ["trimmed:0.1", "median"] {
        let mut agg = AggConfig {
            spec: spec.into(),
            ..Default::default()
        }
        .build()?;
        agg.set_workers(4);
        let mut out = params::ParamVec::new();
        b.bench_elems(&format!("combine_into/{spec}/workers=4"), dim as f64, || {
            agg.combine_into(&refs, &mut out).unwrap();
            std::hint::black_box(out.len());
        });
    }

    // stateful server steps at CNN size (the heavyweight image model).
    // step() consumes its input, so feed the returned buffer back in —
    // no per-iteration clone polluting the measurement (the values drift
    // as the optimizer reprocesses its own output; only timing matters).
    let big = 1_663_370;
    let delta: Vec<f32> = (0..big).map(|_| rng.gauss_f32() * 0.01).collect();
    for spec in ["fedavgm", "fedadam"] {
        let mut agg = AggConfig {
            spec: spec.into(),
            ..Default::default()
        }
        .build()?;
        let mut round = 0u64;
        let mut buf = delta.clone();
        b.bench_elems(&format!("step/{spec} (1.66M params)"), big as f64, || {
            round += 1;
            buf = agg.step(round, std::mem::take(&mut buf)).unwrap();
            std::hint::black_box(buf.len());
        });
    }
    Ok(())
}

fn toy_image(n: usize, dim: usize) -> Dataset {
    let mut rng = Rng::new(5);
    Dataset {
        name: "bench".into(),
        examples: Examples::Image {
            x: (0..n * dim).map(|_| rng.f32()).collect(),
            y: (0..n).map(|_| rng.below(10) as i32).collect(),
            dim,
        },
    }
}

/// ClientUpdate latency per model/batch-size — one local SGD step, a
/// full-batch gradient, an apply, and a full E=1 ClientUpdate through
/// the PJRT executables. Skips cleanly without `make artifacts`.
pub fn client_update(b: &mut Bencher) -> Result<AreaStatus> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        return Ok(AreaStatus::Skipped("no artifacts — run `make artifacts`"));
    }
    let engine = Engine::load(dir)?;

    for (mname, dim) in [("mnist_2nn", 784usize), ("mnist_cnn", 784)] {
        let model = engine.model(mname)?;
        let theta = model.init(1)?;
        let data = toy_image(60, dim);
        let idxs: Vec<usize> = (0..60).collect();

        let batch10 = data.padded_batch(&idxs[..10], 10);
        b.bench(&format!("{mname}/step_b10"), || {
            std::hint::black_box(model.step(&theta, &batch10, 0.05).unwrap());
        });

        let cap = model.meta().acc_batch;
        let batch_acc = data.padded_batch(&idxs[..cap.min(60)], cap);
        b.bench(&format!("{mname}/gradacc_b{cap}"), || {
            std::hint::black_box(model.gradacc(&theta, &batch_acc).unwrap());
        });

        let g = vec![0.01f32; theta.len()];
        b.bench(&format!("{mname}/apply"), || {
            std::hint::black_box(model.apply(&theta, &g, 0.05).unwrap());
        });

        b.bench(&format!("{mname}/eval_b{cap}"), || {
            std::hint::black_box(model.eval_batch(&theta, &batch_acc).unwrap());
        });

        // one full ClientUpdate: E=1, B=10 over 60 examples (6 steps)
        let spec = LocalSpec {
            epochs: 1,
            batch: BatchSize::Fixed(10),
            lr: 0.05,
            prox_mu: 0.0,
            shuffle_seed: 3,
        };
        b.bench(&format!("{mname}/client_update_E1_B10_n60"), || {
            std::hint::black_box(local_update(&model, &data, &idxs, &theta, &spec).unwrap());
        });
    }

    let stats = engine.stats();
    println!(
        "\nengine: {} steps / {} gradaccs / {} evals, compile {:.1}s, execute {:.1}s",
        stats.steps,
        stats.gradaccs,
        stats.evals,
        stats.compile_ms as f64 / 1e3,
        stats.execute_ms as f64 / 1e3
    );
    Ok(AreaStatus::Recorded)
}

// -------------------------------------------------------------- snapshots

/// `os-arch[-hostname]` — enough to tell trajectories from different
/// machines apart without leaking anything else.
pub fn machine_tag() -> String {
    let mut tag = format!("{}-{}", std::env::consts::OS, std::env::consts::ARCH);
    if let Ok(host) = std::env::var("HOSTNAME") {
        if !host.is_empty() {
            tag.push('-');
            tag.push_str(&host);
        }
    }
    tag
}

fn fmt_case(r: &BenchResult) -> String {
    let elems = match r.elems_per_iter {
        Some(e) => format!("{e}"),
        None => "null".into(),
    };
    format!(
        "    {{\"name\": {}, \"iters\": {}, \"mean_ns\": {}, \"median_ns\": {}, \
         \"p10_ns\": {}, \"p90_ns\": {}, \"elems_per_iter\": {}}}",
        escape(&r.name),
        r.iters,
        r.mean_ns,
        r.p50_ns,
        r.p10_ns,
        r.p90_ns,
        elems
    )
}

/// Render one area's snapshot JSON (`BENCH_schema.md`).
pub fn snapshot_json(
    area: &str,
    machine: &str,
    recorded_unix_s: u64,
    results: &[BenchResult],
) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"schema\": {},\n", escape(BENCH_SCHEMA)));
    out.push_str(&format!("  \"area\": {},\n", escape(area)));
    out.push_str(&format!("  \"machine\": {},\n", escape(machine)));
    out.push_str(&format!("  \"recorded_unix_s\": {recorded_unix_s},\n"));
    out.push_str("  \"cases\": [\n");
    for (i, r) in results.iter().enumerate() {
        out.push_str(&fmt_case(r));
        out.push_str(if i + 1 == results.len() { "\n" } else { ",\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write `BENCH_<area>.json` atomically, self-validating the emitted
/// text against the schema first (a malformed snapshot must fail the
/// recording run, not the next reader).
#[allow(clippy::disallowed_methods)] // SystemTime::now: snapshot recorded-at stamp only
pub fn write_snapshot(path: &Path, area: &str, results: &[BenchResult]) -> Result<()> {
    anyhow::ensure!(!results.is_empty(), "area {area}: no cases to snapshot");
    // lint:allow(wall-clock): recorded-at metadata in the BENCH_<area>.json header; comparisons key on machine_tag, not this stamp.
    let now = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let text = snapshot_json(area, &machine_tag(), now, results);
    validate_snapshot(&text)?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    atomic_write(path, text.as_bytes())
}

/// Validate snapshot text against the `fedavg-bench-v1` schema. Returns
/// the case count.
pub fn validate_snapshot(text: &str) -> Result<usize> {
    let j = Json::parse(text)?;
    let schema = j.get("schema")?.as_str()?;
    anyhow::ensure!(schema == BENCH_SCHEMA, "schema {schema:?}, expected {BENCH_SCHEMA:?}");
    let area = j.get("area")?.as_str()?;
    anyhow::ensure!(!area.is_empty(), "empty area");
    anyhow::ensure!(!j.get("machine")?.as_str()?.is_empty(), "empty machine tag");
    j.get("recorded_unix_s")?.as_usize()?;
    let cases = j.get("cases")?.as_arr()?;
    anyhow::ensure!(!cases.is_empty(), "area {area}: no cases");
    let mut names = Vec::with_capacity(cases.len());
    for c in cases {
        let name = c.get("name")?.as_str()?;
        anyhow::ensure!(!name.is_empty(), "case with empty name");
        anyhow::ensure!(!names.contains(&name), "duplicate case {name:?}");
        names.push(name);
        anyhow::ensure!(c.get("iters")?.as_usize()? >= 1, "case {name:?}: zero iters");
        let mut ns = [0.0; 4];
        for (slot, k) in ["mean_ns", "median_ns", "p10_ns", "p90_ns"].iter().enumerate() {
            let v = c.get(k)?.as_f64()?;
            anyhow::ensure!(v.is_finite() && v >= 0.0, "case {name:?}: bad {k} {v}");
            ns[slot] = v;
        }
        anyhow::ensure!(
            ns[2] <= ns[1] && ns[1] <= ns[3],
            "case {name:?}: p10/median/p90 out of order"
        );
        match c.get("elems_per_iter")? {
            Json::Null => {}
            v => {
                let e = v.as_f64()?;
                anyhow::ensure!(e.is_finite() && e > 0.0, "case {name:?}: bad elems {e}");
            }
        }
    }
    Ok(names.len())
}

// --------------------------------------------------------------- compare

/// One case's old-vs-new movement from [`compare_snapshot`].
#[derive(Debug, Clone)]
pub struct CaseDelta {
    pub name: String,
    /// Mean-time change in percent (positive = slower than the snapshot).
    pub mean_pct: f64,
    pub p10_pct: f64,
    pub p90_pct: f64,
    pub old_mean_ns: f64,
    pub new_mean_ns: f64,
}

/// Compare freshly-measured `results` against a committed snapshot's
/// text (`fedavg bench --compare`).
///
/// **Schema drift is a hard error** (`Err`): a wrong schema id, a
/// different area, or a case-set mismatch in either direction means the
/// snapshot and the code no longer describe the same benchmark — the fix
/// is to re-record, not to compare. **Timing movement is not an error**:
/// the returned flag is `true` when any case's mean grew by more than
/// `tolerance_pct`, and the caller decides how loudly to fail (CI's
/// `bench-smoke` treats it as a warning on the noisy shared runner; see
/// `.github/workflows/ci.yml`).
pub fn compare_snapshot(
    old_text: &str,
    area: &str,
    results: &[BenchResult],
    tolerance_pct: f64,
) -> Result<(Vec<CaseDelta>, bool)> {
    validate_snapshot(old_text)?;
    let j = Json::parse(old_text)?;
    let old_area = j.get("area")?.as_str()?;
    anyhow::ensure!(
        old_area == area,
        "snapshot is for area {old_area:?}, comparing against {area:?}"
    );
    let cases = j.get("cases")?.as_arr()?;
    let mut old: Vec<(String, f64, f64, f64)> = Vec::with_capacity(cases.len());
    for c in cases {
        old.push((
            c.get("name")?.as_str()?.to_string(),
            c.get("mean_ns")?.as_f64()?,
            c.get("p10_ns")?.as_f64()?,
            c.get("p90_ns")?.as_f64()?,
        ));
    }
    for (name, ..) in &old {
        anyhow::ensure!(
            results.iter().any(|r| &r.name == name),
            "schema drift: snapshot case {name:?} was not measured this run — \
             re-record the snapshot"
        );
    }
    let mut deltas = Vec::with_capacity(results.len());
    let mut regressed = false;
    for r in results {
        let Some((_, om, op10, op90)) = old.iter().find(|(n, ..)| n == &r.name) else {
            anyhow::bail!(
                "schema drift: case {:?} is not in the snapshot — re-record the snapshot",
                r.name
            );
        };
        let pct = |new: f64, old: f64| {
            if old > 0.0 {
                (new - old) / old * 100.0
            } else {
                0.0
            }
        };
        let d = CaseDelta {
            name: r.name.clone(),
            mean_pct: pct(r.mean_ns, *om),
            p10_pct: pct(r.p10_ns, *op10),
            p90_pct: pct(r.p90_ns, *op90),
            old_mean_ns: *om,
            new_mean_ns: r.mean_ns,
        };
        if d.mean_pct > tolerance_pct {
            regressed = true;
        }
        deltas.push(d);
    }
    Ok((deltas, regressed))
}

/// Render [`compare_snapshot`]'s deltas as an aligned report.
pub fn fmt_deltas(area: &str, deltas: &[CaseDelta], tolerance_pct: f64) -> String {
    let mut out = format!("area {area}: change vs snapshot (tolerance {tolerance_pct}%)\n");
    for d in deltas {
        out.push_str(&format!(
            "  {:<44} mean {:>12.1} -> {:>12.1} ns ({:+7.1}%)  p10 {:+7.1}%  p90 {:+7.1}%{}\n",
            d.name,
            d.old_mean_ns,
            d.new_mean_ns,
            d.mean_pct,
            d.p10_pct,
            d.p90_pct,
            if d.mean_pct > tolerance_pct {
                "  <-- REGRESSION"
            } else {
                ""
            }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(name: &str) -> BenchResult {
        BenchResult {
            name: name.into(),
            iters: 100,
            mean_ns: 1500.0,
            p50_ns: 1400.0,
            p95_ns: 2000.0,
            p10_ns: 1200.0,
            p90_ns: 1900.0,
            elems_per_iter: Some(199_210.0),
        }
    }

    #[test]
    fn snapshot_validates_and_rejects() {
        let good = snapshot_json("params_hot_path", "linux-x86_64", 1, &[result("axpy")]);
        assert_eq!(validate_snapshot(&good).unwrap(), 1);

        let wrong_schema = good.replace(BENCH_SCHEMA, "fedavg-bench-v0");
        assert!(validate_snapshot(&wrong_schema).is_err());

        let empty = snapshot_json("params_hot_path", "m", 1, &[]);
        assert!(validate_snapshot(&empty).is_err());

        let dup = snapshot_json("a", "m", 1, &[result("x"), result("x")]);
        assert!(validate_snapshot(&dup).is_err());

        let mut bad = result("y");
        bad.p10_ns = 9999.0; // p10 > median
        let out_of_order = snapshot_json("a", "m", 1, &[bad]);
        assert!(validate_snapshot(&out_of_order).is_err());
    }

    #[test]
    fn write_snapshot_roundtrips_on_disk() {
        let path = std::path::PathBuf::from(format!(
            "target/test-runs/bench-snap-{}/BENCH_test.json",
            std::process::id()
        ));
        let mut r = result("weighted_mean/10clients/2nn_199k");
        r.elems_per_iter = None;
        write_snapshot(&path, "params_hot_path", &[r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_snapshot(&text).unwrap(), 1);
        assert!(text.contains("\"elems_per_iter\": null"));
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn unknown_area_is_refused() {
        let mut b = check_bencher();
        assert!(run_area("nope", &mut b).is_err());
    }

    #[test]
    fn compare_reports_deltas_and_flags_regressions() {
        let old = snapshot_json("a", "m", 1, &[result("x")]);
        // identical timings: zero delta, no regression
        let (d, reg) = compare_snapshot(&old, "a", &[result("x")], 5.0).unwrap();
        assert!(!reg);
        assert!(d[0].mean_pct.abs() < 1e-9, "{:?}", d[0]);
        // 50% slower mean: flagged above a 5% tolerance...
        let mut slow = result("x");
        slow.mean_ns *= 1.5;
        let (d, reg) = compare_snapshot(&old, "a", &[slow.clone()], 5.0).unwrap();
        assert!(reg && d[0].mean_pct > 49.0, "{:?}", d[0]);
        assert!(fmt_deltas("a", &d, 5.0).contains("REGRESSION"));
        // ...but tolerated at 60%
        let (_, reg) = compare_snapshot(&old, "a", &[slow], 60.0).unwrap();
        assert!(!reg);
        // schema drift is a hard error: wrong area, renamed case, or a
        // case added/removed on either side
        assert!(compare_snapshot(&old, "b", &[result("x")], 5.0).is_err());
        assert!(compare_snapshot(&old, "a", &[result("y")], 5.0).is_err());
        assert!(compare_snapshot(&old, "a", &[result("x"), result("y")], 5.0).is_err());
        let two = snapshot_json("a", "m", 1, &[result("x"), result("y")]);
        assert!(compare_snapshot(&two, "a", &[result("x")], 5.0).is_err());
    }
}
