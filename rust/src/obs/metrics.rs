//! The metrics registry (DESIGN.md §10): counters, gauges, and
//! histograms behind one cloneable [`Metrics`] handle.
//!
//! This absorbs the ad-hoc counters that used to live as locals in the
//! server round loop (`wire_bytes`, `dropped`, `deadline_misses`, fleet
//! dispatch totals, client SGD steps) and the grid engine's cache-hit
//! accounting. Counters carry a **mark**: `pending()` returns the growth
//! since the last `mark()`, which is exactly the "events since the last
//! telemetry record" semantics the curve's `dropped`/`deadline_misses`
//! columns need — the registry produces the same u64 arithmetic the old
//! locals did, so curve.csv stays byte-identical.
//!
//! Resume: the server re-seeds its counters from the snapshot's existing
//! `FleetState`/`CommState`/`client_steps` sections ([`Metrics::
//! seed_counter`]) — cumulative totals ride the `state_save/state_load`
//! surface of DESIGN.md §8 without a snapshot-format change. The
//! registry also serializes wholesale ([`Metrics::state_save`]) for
//! callers that own their persistence.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::bytes::{ByteReader, ByteWriter};
use crate::Result;

/// Histogram summary: count/sum/min/max plus coarse log2 buckets
/// covering ~1e-9 .. ~5e2 (seconds-scale observations; anything outside
/// clamps to the end buckets).
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    buckets: Vec<u64>,
}

const HIST_BUCKETS: usize = 40;

impl Default for Hist {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: vec![0; HIST_BUCKETS],
        }
    }
}

impl Hist {
    fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        let idx = (v.max(1e-9).log2().floor() as i64 + 30).clamp(0, HIST_BUCKETS as i64 - 1);
        self.buckets[idx as usize] += 1;
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Metric {
    Counter { value: u64, marked: u64 },
    Gauge(f64),
    Hist(Hist),
}

/// A metric's public view ([`Metrics::snapshot`]).
#[derive(Debug, Clone, PartialEq)]
pub enum MetricValue {
    Counter { value: u64, marked: u64 },
    Gauge(f64),
    Hist { count: u64, sum: f64, min: f64, max: f64 },
}

/// Cloneable, thread-safe registry handle. `Metrics::default()` is an
/// empty registry; clones share storage.
#[derive(Clone, Default)]
pub struct Metrics(Arc<Mutex<BTreeMap<String, Metric>>>);

impl std::fmt::Debug for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Metrics({} entries)", self.0.lock().expect("metrics poisoned").len())
    }
}

impl Metrics {
    /// Add `n` to counter `name` (created at zero).
    pub fn add(&self, name: &str, n: u64) {
        let mut m = self.0.lock().expect("metrics poisoned");
        match m
            .entry(name.to_string())
            .or_insert(Metric::Counter { value: 0, marked: 0 })
        {
            Metric::Counter { value, .. } => *value += n,
            other => panic!("metric {name:?} is not a counter: {other:?}"),
        }
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Current counter value (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        match self.0.lock().expect("metrics poisoned").get(name) {
            Some(Metric::Counter { value, .. }) => *value,
            _ => 0,
        }
    }

    /// Counter growth since the last [`mark`](Self::mark) — the
    /// "since the last telemetry record" view.
    pub fn pending(&self, name: &str) -> u64 {
        match self.0.lock().expect("metrics poisoned").get(name) {
            Some(Metric::Counter { value, marked }) => value - marked,
            _ => 0,
        }
    }

    /// Consume the pending growth: the next [`pending`](Self::pending)
    /// counts from here.
    pub fn mark(&self, name: &str) {
        if let Some(Metric::Counter { value, marked }) =
            self.0.lock().expect("metrics poisoned").get_mut(name)
        {
            *marked = *value;
        }
    }

    /// Install a counter at an absolute state (resume seeding): `value`
    /// cumulative, `marked` the portion already recorded to telemetry.
    pub fn seed_counter(&self, name: &str, value: u64, marked: u64) {
        assert!(marked <= value, "metric {name:?}: marked {marked} > value {value}");
        self.0
            .lock()
            .expect("metrics poisoned")
            .insert(name.to_string(), Metric::Counter { value, marked });
    }

    /// Set gauge `name`.
    pub fn gauge(&self, name: &str, v: f64) {
        self.0
            .lock()
            .expect("metrics poisoned")
            .insert(name.to_string(), Metric::Gauge(v));
    }

    /// Last gauge value, if set.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        match self.0.lock().expect("metrics poisoned").get(name) {
            Some(Metric::Gauge(v)) => Some(*v),
            _ => None,
        }
    }

    /// Record one observation into histogram `name`.
    pub fn observe(&self, name: &str, v: f64) {
        let mut m = self.0.lock().expect("metrics poisoned");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Hist(Hist::default()))
        {
            Metric::Hist(h) => h.observe(v),
            other => panic!("metric {name:?} is not a histogram: {other:?}"),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.0.lock().expect("metrics poisoned").is_empty()
    }

    pub fn len(&self) -> usize {
        self.0.lock().expect("metrics poisoned").len()
    }

    /// Name-ordered view of every metric (the registry section of the
    /// trace table; deterministic by BTreeMap order).
    pub fn snapshot(&self) -> Vec<(String, MetricValue)> {
        self.0
            .lock()
            .expect("metrics poisoned")
            .iter()
            .map(|(k, v)| {
                let mv = match v {
                    Metric::Counter { value, marked } => MetricValue::Counter {
                        value: *value,
                        marked: *marked,
                    },
                    Metric::Gauge(g) => MetricValue::Gauge(*g),
                    Metric::Hist(h) => MetricValue::Hist {
                        count: h.count,
                        sum: h.sum,
                        min: h.min,
                        max: h.max,
                    },
                };
                (k.clone(), mv)
            })
            .collect()
    }

    /// Serialize the whole registry (tagged entries; the same additive
    /// byte discipline as the snapshot sections of DESIGN.md §8).
    pub fn state_save(&self) -> Vec<u8> {
        let m = self.0.lock().expect("metrics poisoned");
        let mut w = ByteWriter::new();
        w.put_u8(1); // registry format version
        w.put_u64(m.len() as u64);
        for (name, v) in m.iter() {
            w.put_str(name);
            match v {
                Metric::Counter { value, marked } => {
                    w.put_u8(0);
                    w.put_u64(*value);
                    w.put_u64(*marked);
                }
                Metric::Gauge(g) => {
                    w.put_u8(1);
                    w.put_f64(*g);
                }
                Metric::Hist(h) => {
                    w.put_u8(2);
                    w.put_u64(h.count);
                    w.put_f64(h.sum);
                    w.put_f64(h.min);
                    w.put_f64(h.max);
                    w.put_u64s(&h.buckets);
                }
            }
        }
        w.into_inner()
    }

    /// Replace the registry's contents from [`state_save`](Self::state_save) bytes.
    pub fn state_load(&self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        let ver = r.u8()?;
        anyhow::ensure!(ver == 1, "metrics registry: unknown format version {ver}");
        let n = r.u64()?;
        let mut loaded = BTreeMap::new();
        for _ in 0..n {
            let name = r.str()?;
            let metric = match r.u8()? {
                0 => {
                    let value = r.u64()?;
                    let marked = r.u64()?;
                    anyhow::ensure!(marked <= value, "metric {name:?}: marked > value");
                    Metric::Counter { value, marked }
                }
                1 => Metric::Gauge(r.f64()?),
                2 => {
                    let count = r.u64()?;
                    let sum = r.f64()?;
                    let min = r.f64()?;
                    let max = r.f64()?;
                    let buckets = r.u64s()?;
                    anyhow::ensure!(
                        buckets.len() == HIST_BUCKETS,
                        "metric {name:?}: {} histogram buckets",
                        buckets.len()
                    );
                    Metric::Hist(Hist { count, sum, min, max, buckets })
                }
                t => anyhow::bail!("metric {name:?}: unknown tag {t}"),
            };
            loaded.insert(name, metric);
        }
        r.expect_end()?;
        *self.0.lock().expect("metrics poisoned") = loaded;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_mark_and_pend() {
        let m = Metrics::default();
        m.add("drops", 3);
        m.inc("drops");
        assert_eq!(m.counter("drops"), 4);
        assert_eq!(m.pending("drops"), 4);
        m.mark("drops");
        assert_eq!(m.pending("drops"), 0);
        m.add("drops", 2);
        assert_eq!((m.counter("drops"), m.pending("drops")), (6, 2));
        assert_eq!(m.counter("absent"), 0);
        assert_eq!(m.pending("absent"), 0);
    }

    #[test]
    fn seed_counter_restores_resume_state() {
        let m = Metrics::default();
        // cumulative 10 drops, 7 of them already written to curve.csv
        m.seed_counter("drops", 10, 7);
        assert_eq!(m.counter("drops"), 10);
        assert_eq!(m.pending("drops"), 3);
        m.add("drops", 1);
        assert_eq!(m.pending("drops"), 4);
    }

    #[test]
    fn gauges_and_hists() {
        let m = Metrics::default();
        m.gauge("ef", 1.25);
        assert_eq!(m.gauge_value("ef"), Some(1.25));
        m.gauge("ef", 2.5);
        assert_eq!(m.gauge_value("ef"), Some(2.5));
        for v in [0.5, 1.0, 8.0] {
            m.observe("round_s", v);
        }
        match m.snapshot().iter().find(|(k, _)| k == "round_s").map(|(_, v)| v.clone()) {
            Some(MetricValue::Hist { count, sum, min, max }) => {
                assert_eq!(count, 3);
                assert_eq!(sum, 9.5);
                assert_eq!((min, max), (0.5, 8.0));
            }
            other => panic!("expected histogram, got {other:?}"),
        }
    }

    #[test]
    fn state_roundtrips_bit_exactly() {
        let m = Metrics::default();
        m.add("a.count", 42);
        m.mark("a.count");
        m.add("a.count", 5);
        m.gauge("b.gauge", -0.125);
        m.observe("c.hist", 3.5);
        m.observe("c.hist", 0.25);
        let bytes = m.state_save();

        let back = Metrics::default();
        back.add("stale", 1); // replaced wholesale by load
        back.state_load(&bytes).unwrap();
        assert_eq!(back.snapshot(), m.snapshot());
        assert_eq!(back.counter("a.count"), 47);
        assert_eq!(back.pending("a.count"), 5);
        assert_eq!(back.counter("stale"), 0);
        // and the reserialization is byte-identical
        assert_eq!(back.state_save(), bytes);
    }

    #[test]
    fn state_load_rejects_garbage() {
        let m = Metrics::default();
        assert!(m.state_load(&[9]).is_err());
        assert!(m.state_load(&[]).is_err());
        let mut good = Metrics::default();
        good.add("x", 1);
        let mut bytes = good.state_save();
        bytes.push(0); // trailing garbage
        good = Metrics::default();
        assert!(good.state_load(&bytes).is_err());
    }
}
