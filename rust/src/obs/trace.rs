//! Hierarchical span tracer (DESIGN.md §10).
//!
//! A [`Tracer`] is a cheap, cloneable handle shared by the server round
//! loop, the worker pool, and the fleet simulator. Disabled (the
//! default), [`Tracer::begin`] returns `None` without ever reading the
//! clock — the hot path is overhead-free and byte-identical to a
//! tracer-less build. Enabled (`--trace`), every finished span appends
//! one JSONL record to `trace.jsonl` under the run dir:
//!
//! ```json
//! {"seq":17,"round":3,"phase":"local_train","depth":2,"wall_ns":81233,
//!  "client":12,"worker":1,"bytes":796680}
//! ```
//!
//! `seq` is the record's append order (a tie-breaker for tooling; wall
//! ordering under `--workers N` is nondeterministic by nature), `depth`
//! the structural nesting (0 = the round itself, 1 = a round phase,
//! 2 = per-client work inside a phase). `bytes` and `sim_s` carry the
//! span's wire bytes and simulated seconds where they apply. Wall-clock
//! values live **only** here — never in curve.csv or grid manifests —
//! preserving the byte-identity guarantees of DESIGN.md §8/§9.
//!
//! [`Tracer::finish`] renders the per-phase breakdown table printed at
//! run end, including the coverage line (what share of measured round
//! wall time the depth-1 phases account for — the §10 acceptance bar is
//! ≥ 90%).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::metrics::{MetricValue, Metrics};
use crate::util::bench::fmt_ns;
use crate::util::json::Json;
use crate::Result;

/// An in-flight span: started by [`Tracer::begin`], finished by
/// [`Tracer::end`]. Builder methods attach optional fields; the clock
/// was read at `begin`, so attaching fields costs nothing extra.
#[derive(Debug)]
pub struct Span {
    round: u64,
    phase: &'static str,
    depth: u8,
    client: Option<u64>,
    worker: Option<u64>,
    bytes: Option<u64>,
    sim_s: Option<f64>,
    t0: Instant,
}

impl Span {
    pub fn client(mut self, client: u64) -> Self {
        self.client = Some(client);
        self
    }

    pub fn worker(mut self, worker: u64) -> Self {
        self.worker = Some(worker);
        self
    }

    pub fn bytes(mut self, bytes: u64) -> Self {
        self.bytes = Some(bytes);
        self
    }

    pub fn sim(mut self, sim_s: f64) -> Self {
        self.sim_s = Some(sim_s);
        self
    }
}

/// Per-(depth, phase) aggregate for the end-of-run table.
#[derive(Debug, Default, Clone, Copy)]
struct PhaseAgg {
    spans: u64,
    total_ns: u128,
}

struct TraceState {
    out: BufWriter<File>,
    seq: u64,
    agg: BTreeMap<(u8, &'static str), PhaseAgg>,
    /// First write error, surfaced by [`Tracer::finish`] — span ends on
    /// the hot path stay infallible.
    error: Option<String>,
}

struct Inner {
    path: PathBuf,
    state: Mutex<TraceState>,
}

/// Cloneable tracer handle. `Tracer::default()` is disabled: `begin`
/// returns `None`, `end(None)` is a no-op, and no file is touched.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<Inner>>);

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "Tracer(off)"),
            Some(i) => write!(f, "Tracer({:?})", i.path),
        }
    }
}

impl Tracer {
    /// Enabled tracer appending JSONL records to `path` (truncated; the
    /// parent directory is created).
    pub fn to_file(path: &Path) -> Result<Tracer> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = File::create(path)?;
        Ok(Tracer(Some(Arc::new(Inner {
            path: path.to_path_buf(),
            state: Mutex::new(TraceState {
                out: BufWriter::new(file),
                seq: 0,
                agg: BTreeMap::new(),
                error: None,
            }),
        }))))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// The trace file's path (enabled tracers only).
    pub fn path(&self) -> Option<&Path> {
        self.0.as_ref().map(|i| i.path.as_path())
    }

    /// Start a span. Disabled: returns `None` without reading the clock
    /// — callers attach expensive fields via `.map(|s| s.bytes(..))` so
    /// the disabled path computes nothing.
    #[allow(clippy::disallowed_methods)] // Instant::now: span timing is observability output only
    pub fn begin(&self, round: u64, phase: &'static str, depth: u8) -> Option<Span> {
        self.0.as_ref()?;
        Some(Span {
            round,
            phase,
            depth,
            client: None,
            worker: None,
            bytes: None,
            sim_s: None,
            // lint:allow(wall-clock): span durations land in trace.jsonl for humans; the round loop never branches on them.
            t0: Instant::now(),
        })
    }

    /// Finish a span: append its record and fold it into the table
    /// aggregates. Infallible on the hot path — the first write error is
    /// remembered and surfaced by [`finish`](Self::finish).
    pub fn end(&self, span: Option<Span>) {
        let (inner, sp) = match (self.0.as_ref(), span) {
            (Some(i), Some(s)) => (i, s),
            _ => return,
        };
        let wall_ns = sp.t0.elapsed().as_nanos();
        let mut line = format!(
            "{{\"seq\":@,\"round\":{},\"phase\":\"{}\",\"depth\":{},\"wall_ns\":{}",
            sp.round, sp.phase, sp.depth, wall_ns
        );
        if let Some(c) = sp.client {
            line.push_str(&format!(",\"client\":{c}"));
        }
        if let Some(w) = sp.worker {
            line.push_str(&format!(",\"worker\":{w}"));
        }
        if let Some(b) = sp.bytes {
            line.push_str(&format!(",\"bytes\":{b}"));
        }
        if let Some(s) = sp.sim_s {
            line.push_str(&format!(",\"sim_s\":{s}"));
        }
        line.push_str("}\n");
        let mut st = inner.state.lock().expect("tracer poisoned");
        let line = line.replacen('@', &st.seq.to_string(), 1);
        st.seq += 1;
        let a = st.agg.entry((sp.depth, sp.phase)).or_default();
        a.spans += 1;
        a.total_ns += wall_ns;
        let res = st.out.write_all(line.as_bytes()).and_then(|_| {
            // round records (depth 0) close a durable unit: flush so a
            // killed run's trace is readable up to its last full round
            if sp.depth == 0 {
                st.out.flush()
            } else {
                Ok(())
            }
        });
        if let (Err(e), None) = (res, st.error.as_ref()) {
            st.error = Some(e.to_string());
        }
    }

    /// Flush the trace and render the per-phase breakdown table
    /// (`None` when disabled). Any write error deferred from the hot
    /// path surfaces here. Counters/gauges from `metrics` are appended
    /// as a registry section when the registry is non-empty.
    pub fn finish(&self, metrics: &Metrics) -> Result<Option<String>> {
        let inner = match self.0.as_ref() {
            Some(i) => i,
            None => return Ok(None),
        };
        let mut st = inner.state.lock().expect("tracer poisoned");
        st.out.flush()?;
        if let Some(e) = st.error.take() {
            anyhow::bail!("trace {:?}: deferred write error: {e}", inner.path);
        }
        let mut out = format!("\n-- trace: per-phase breakdown ({}) --\n", inner.path.display());
        let root_ns: u128 = st
            .agg
            .iter()
            .filter(|((d, _), _)| *d == 0)
            .map(|(_, a)| a.total_ns)
            .sum();
        let phase_ns: u128 = st
            .agg
            .iter()
            .filter(|((d, _), _)| *d == 1)
            .map(|(_, a)| a.total_ns)
            .sum();
        out.push_str(&format!(
            "{:<26} {:>5} {:>8} {:>12} {:>12} {:>8}\n",
            "phase", "depth", "spans", "total", "mean", "share"
        ));
        for (&(depth, phase), a) in &st.agg {
            let mean = a.total_ns as f64 / a.spans.max(1) as f64;
            let share = if root_ns > 0 {
                100.0 * a.total_ns as f64 / root_ns as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<26} {:>5} {:>8} {:>12} {:>12} {:>7.1}%\n",
                format!("{}{}", "  ".repeat(depth as usize), phase),
                depth,
                a.spans,
                fmt_ns(a.total_ns as f64),
                fmt_ns(mean),
                share,
            ));
        }
        if root_ns > 0 {
            out.push_str(&format!(
                "coverage: depth-1 phases account for {:.1}% of measured round wall time\n",
                100.0 * phase_ns as f64 / root_ns as f64
            ));
        }
        let snap = metrics.snapshot();
        if !snap.is_empty() {
            out.push_str("-- metrics registry --\n");
            for (name, v) in snap {
                match v {
                    MetricValue::Counter { value, .. } => {
                        out.push_str(&format!("{name:<34} {value}\n"));
                    }
                    MetricValue::Gauge(g) => out.push_str(&format!("{name:<34} {g:.6}\n")),
                    MetricValue::Hist {
                        count,
                        sum,
                        min,
                        max,
                    } => {
                        let mean = if count > 0 { sum / count as f64 } else { 0.0 };
                        out.push_str(&format!(
                            "{name:<34} n={count} mean={mean:.6} min={min:.6} max={max:.6}\n"
                        ));
                    }
                }
            }
        }
        Ok(Some(out))
    }
}

/// One parsed `trace.jsonl` record (tests + tooling).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub seq: u64,
    pub round: u64,
    pub phase: String,
    pub depth: u8,
    pub wall_ns: u64,
    pub client: Option<u64>,
    pub worker: Option<u64>,
    pub bytes: Option<u64>,
    pub sim_s: Option<f64>,
}

impl TraceRecord {
    /// The wall-clock-free identity of a span: what `--workers N` must
    /// reproduce exactly (worker ids and append order legitimately
    /// differ across schedules; the work itself must not).
    pub fn key(&self) -> (u64, String, u8, Option<u64>, Option<u64>) {
        (self.round, self.phase.clone(), self.depth, self.client, self.bytes)
    }

    pub fn parse(line: &str) -> Result<TraceRecord> {
        let j = Json::parse(line)?;
        let num = |k: &str| -> Result<u64> { Ok(j.get(k)?.as_f64()? as u64) };
        let opt = |k: &str| -> Option<u64> {
            j.get(k).ok().and_then(|v| v.as_f64().ok()).map(|v| v as u64)
        };
        Ok(TraceRecord {
            seq: num("seq")?,
            round: num("round")?,
            phase: j.get("phase")?.as_str()?.to_string(),
            depth: num("depth")? as u8,
            wall_ns: num("wall_ns")?,
            client: opt("client"),
            worker: opt("worker"),
            bytes: opt("bytes"),
            sim_s: j.get("sim_s").ok().and_then(|v| v.as_f64().ok()),
        })
    }
}

/// Read and parse a whole trace file.
pub fn read_trace(path: &Path) -> Result<Vec<TraceRecord>> {
    let text = std::fs::read_to_string(path)?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(TraceRecord::parse)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_path(tag: &str) -> PathBuf {
        PathBuf::from(format!("target/test-runs/trace-{tag}-{}.jsonl", std::process::id()))
    }

    #[test]
    fn disabled_tracer_is_inert() {
        let tr = Tracer::default();
        assert!(!tr.enabled());
        assert!(tr.begin(1, "round", 0).is_none());
        tr.end(None);
        let mx = Metrics::default();
        assert!(tr.finish(&mx).unwrap().is_none());
    }

    #[test]
    fn records_roundtrip_through_jsonl() {
        let path = test_path("roundtrip");
        let tr = Tracer::to_file(&path).unwrap();
        let root = tr.begin(1, "round", 0);
        let sp = tr.begin(1, "local_train", 2).map(|s| s.client(3).worker(0).bytes(128));
        tr.end(sp);
        tr.end(root.map(|s| s.bytes(256).sim(12.5)));
        let mx = Metrics::default();
        mx.add("wire.up_bytes", 128);
        let table = tr.finish(&mx).unwrap().expect("enabled");
        assert!(table.contains("coverage:"), "{table}");
        assert!(table.contains("wire.up_bytes"), "{table}");

        let recs = read_trace(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].phase, "local_train");
        assert_eq!(recs[0].client, Some(3));
        assert_eq!(recs[0].bytes, Some(128));
        assert_eq!(recs[1].phase, "round");
        assert_eq!(recs[1].depth, 0);
        assert_eq!(recs[1].sim_s, Some(12.5));
        assert_eq!(recs[0].seq + 1, recs[1].seq);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn span_keys_ignore_schedule_noise() {
        let a = TraceRecord {
            seq: 0,
            round: 2,
            phase: "local_train".into(),
            depth: 2,
            wall_ns: 10,
            client: Some(1),
            worker: Some(0),
            bytes: Some(64),
            sim_s: None,
        };
        let mut b = a.clone();
        b.seq = 99;
        b.wall_ns = 77_000;
        b.worker = Some(3);
        assert_eq!(a.key(), b.key());
    }
}
