//! Observability (DESIGN.md §10): span tracing, the metrics registry,
//! and the bench trajectory harness.
//!
//! Three parts, one constraint:
//!
//! - [`trace`] — a hierarchical span tracer around every round phase.
//!   Enabled with `--trace` on `run`/`fleet`/grid subcommands; appends
//!   structured records to `runs/<name>/trace.jsonl` and prints a
//!   per-round phase breakdown table (plus the metrics registry) at run
//!   end.
//! - [`metrics`] — counters/gauges/histograms behind one cloneable
//!   [`Metrics`] handle, absorbing the ad-hoc counters the server and
//!   grid engine used to carry as locals; counters survive
//!   checkpoint/resume via the snapshot's existing sections.
//! - [`bench`] — the five bench areas as library functions plus the
//!   committed `BENCH_<area>.json` snapshot format (`fedavg bench`,
//!   `BENCH_schema.md`).
//!
//! The constraint: with tracing disabled the hot path is byte-identical
//! and overhead-free — a disabled [`Tracer`] is a `None` and
//! [`Tracer::begin`] never reads the clock. Wall-clock numbers live
//! ONLY in trace.jsonl and BENCH files, never in curve.csv or grid
//! manifests, preserving the byte-identity guarantees of §8/§9.

pub mod bench;
pub mod metrics;
pub mod trace;

pub use metrics::{MetricValue, Metrics};
pub use trace::{read_trace, Span, TraceRecord, Tracer};
