//! `fedavg` — CLI launcher for the FedAvg reproduction.
//!
//! ```text
//! fedavg table1|table2|table3|table4 [--scale F] [--rounds N] ...
//! fedavg figure <1..10|all>          [--scale F] [--rounds N] ...
//! fedavg run --config configs/xxx.cfg [overrides]
//! fedavg oneshot [--model M] [--scale F]
//! fedavg info
//! ```
//!
//! All experiments print paper-formatted tables/series and persist curves
//! under `runs/`. `--scale 1.0` is the paper-sized configuration; defaults
//! are scaled for this single-core testbed.

use anyhow::{anyhow, bail};

use fedavg::baselines::oneshot;
use fedavg::config::{BatchSize, ConfigFile, FedConfig, Partition};
use fedavg::coordinator::{
    shard_ranges, tier_transfer_seconds, FaultConfig, FleetConfig, FleetProfile, FleetSim,
    LatePolicy, TierLink,
};
use fedavg::federated::{AggConfig, ServerOptions};
use fedavg::exper::{self};
use fedavg::obs::{Metrics, Tracer};
use fedavg::runstate::{CheckpointConfig, Snapshot};
use fedavg::runtime::Engine;
use fedavg::telemetry::{FleetRoundRecord, FleetWriter, RunWriter, TierRecord, TierWriter};
use fedavg::util::args::Args;
use fedavg::Result;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "table1" => exper::table1::run(&engine()?, &args),
        "table2" => exper::table2::run(&engine()?, &args),
        "table3" => exper::table3::run(&engine()?, &args),
        "table4" => exper::table4::run(&engine()?, &args),
        "comm" => exper::table_comm::run(&engine()?, &args),
        "agg" => exper::table_agg::run(&engine()?, &args),
        "async" => exper::table_async::run(&engine()?, &args),
        "sweep" => fedavg::sweep::run_cli(&engine()?, &args),
        "figure" | "figures" => exper::figures::run(&engine()?, &args),
        "run" => cmd_run(&args),
        "fleet" => cmd_fleet(&args),
        "bench" => cmd_bench(&args),
        "lint" => cmd_lint(&args),
        "oneshot" => cmd_oneshot(&args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

fn engine() -> Result<Engine> {
    Engine::load(Engine::default_dir())
}

/// `fedavg run` — a single federated training run, fully configurable.
fn cmd_run(args: &Args) -> Result<()> {
    args.check_known(&[
        "config", "model", "c", "e", "b", "lr", "lr-decay", "rounds", "eval-every",
        "target", "partition", "scale", "eval-cap", "seed", "out", "availability",
        "track-train-loss", "name", "dp-clip", "dp-sigma", "secure-agg", "topk",
        "quant-bits", "codec", "down-codec", "agg", "server-lr", "server-momentum",
        "prox-mu", "checkpoint-every", "checkpoint-keep", "resume", "overwrite", "trace",
    ])?;
    let file = config_file_from_args(args)?;
    let cfg = fed_config_from(file.as_ref(), args)?;

    let scale = args.f64_or("scale", 0.05)?;
    let part = Partition::parse(&args.str_or("partition", "iid"))?;
    let fed = build_fed(&cfg.model, scale, part, cfg.seed)?;

    let engine = engine()?;
    let mut opts = fedavg::federated::ServerOptions {
        eval_cap: Some(args.usize_or("eval-cap", 1000)?),
        ..Default::default()
    };
    if let Some(p) = args.str_opt("availability") {
        let p: f64 = p.parse()?;
        if !p.is_finite() || p <= 0.0 || p > 1.0 {
            bail!("--availability must be an online probability in (0, 1], got {p}");
        }
        opts.availability = Some(p);
    }
    if let Some(sigma) = args.str_opt("dp-sigma") {
        opts.dp = Some(fedavg::federated::server::DpConfig {
            clip_norm: args.f64_or("dp-clip", 1.0)?,
            sigma: sigma.parse()?,
        });
    }
    opts.secure_agg = args.has("secure-agg");
    opts.transport = transport_from_args(args)?;
    opts.agg = agg_config_from(file.as_ref(), args)?;
    let default_name = format!("run-{}", cfg.label().replace(' ', "_"));
    let ckpt = checkpoint_from(file.as_ref(), args)?;
    attach_run_outputs(args, ckpt, &mut opts, &default_name)?;

    println!(
        "run: {} on {} ({} clients, {} train / {} test examples)",
        cfg.label(),
        fed.train.name,
        fed.num_clients(),
        fed.train.len(),
        fed.test.len()
    );
    let res = fedavg::federated::run(&engine, &fed, &cfg, opts)?;
    println!(
        "done: {} rounds, final acc {:.4}, best {:.4}, {:.3} GB comm, sim {:.0}s",
        res.rounds_run,
        res.final_accuracy(),
        res.accuracy.best_value().unwrap_or(0.0),
        res.comm.gigabytes(),
        res.comm.sim_seconds,
    );
    if let Some(t) = cfg.target_accuracy {
        match res.accuracy.rounds_to_target(t) {
            Some(r) => println!("rounds to {:.1}%: {:.1}", t * 100.0, r),
            None => println!("target {:.1}% not reached", t * 100.0),
        }
    }
    if let Some(eps) = res.epsilon {
        println!("differential privacy: ({eps:.2}, 1e-5)-DP consumed");
    }
    Ok(())
}

/// Parse the transport flags shared by `run` and `fleet`: `--codec`
/// (uplink pipeline spec, see the registry in `comms::wire`) and
/// `--down-codec` (downlink, e.g. `delta`). The pre-pipeline flags
/// `--topk FRAC` / `--quant-bits B` are kept as shorthands that map onto
/// the same registry (`topk:FRAC|qB`).
fn transport_from_args(args: &Args) -> Result<fedavg::comms::TransportConfig> {
    let mut up = args.str_opt("codec").map(str::to_string);
    if up.is_some() && (args.has("topk") || args.has("quant-bits")) {
        bail!("--codec conflicts with the --topk/--quant-bits shorthands; fold them into the --codec spec");
    }
    if up.is_none() {
        if let Some(f) = args.str_opt("topk") {
            let v: f64 = f.parse()?;
            if !v.is_finite() || v <= 0.0 || v >= 1.0 {
                bail!("--topk must be a fraction in (0, 1), got {f:?}");
            }
            up = Some(format!("topk:{f}"));
        }
        if let Some(b) = args.str_opt("quant-bits") {
            let _: u8 = b.parse()?;
            up = Some(match up {
                Some(spec) => format!("{spec}|q{b}"),
                None => format!("q{b}"),
            });
        }
    }
    fedavg::comms::TransportConfig::parse(up.as_deref(), args.str_opt("down-codec"))
}

/// Checkpoint cadence shared by `run` and `fleet`: `--checkpoint-every N`
/// (config key `checkpoint_every`) turns on run-state snapshots under
/// `runs/<name>/checkpoints/`, rotated to the newest `--checkpoint-keep`
/// (default 3). See DESIGN.md §8.
fn checkpoint_from(file: Option<&ConfigFile>, args: &Args) -> Result<Option<CheckpointConfig>> {
    let cf_every: Option<u64> = match file {
        Some(cf) => cf.get_parse("checkpoint_every")?,
        None => None,
    };
    let every = match args.str_opt("checkpoint-every") {
        Some(v) => Some(
            v.parse::<u64>()
                .map_err(|_| anyhow!("--checkpoint-every: bad integer {v:?}"))?,
        ),
        None => cf_every,
    };
    let cf_keep: Option<usize> = match file {
        Some(cf) => cf.get_parse("checkpoint_keep")?,
        None => None,
    };
    let keep = args.usize_or("checkpoint-keep", cf_keep.unwrap_or(3))?;
    match every {
        None => {
            if args.has("checkpoint-keep") {
                bail!("--checkpoint-keep needs --checkpoint-every");
            }
            Ok(None)
        }
        Some(every) => {
            let ck = CheckpointConfig { every, keep };
            ck.validate()?;
            Ok(Some(ck))
        }
    }
}

/// Telemetry + checkpoint/resume wiring shared by `run` and `fleet`.
/// `--resume <run-dir>` loads the newest valid snapshot and hands it to
/// the server, which truncates/reopens the run's curve.csv only after
/// the config fingerprint is verified (a refused resume must not touch
/// the original telemetry); otherwise a fresh run dir is created
/// (refusing to clobber an existing one unless `--overwrite`). `--trace`
/// opens `runs/<name>/trace.jsonl` through the span tracer (DESIGN.md
/// §10; truncated each run — wall-clock data is never resumed).
fn attach_run_outputs(
    args: &Args,
    checkpoint: Option<CheckpointConfig>,
    opts: &mut ServerOptions,
    default_name: &str,
) -> Result<()> {
    opts.checkpoint = checkpoint;
    if let Some(rdir) = args.str_opt("resume") {
        for f in ["name", "out", "overwrite"] {
            if args.has(f) {
                bail!("--{f} conflicts with --resume (which names an existing run dir)");
            }
        }
        let run_dir = std::path::Path::new(rdir);
        let (path, snap) = Snapshot::load_latest(run_dir)?.ok_or_else(|| {
            anyhow!(
                "--resume {rdir}: no checkpoints under {:?} — was the run started \
                 with --checkpoint-every?",
                fedavg::runstate::checkpoint_dir(run_dir)
            )
        })?;
        println!(
            "resuming {rdir} from {:?} (state after round {})",
            path.file_name().unwrap_or_default(),
            snap.round
        );
        if args.has("trace") {
            opts.trace = Tracer::to_file(&run_dir.join("trace.jsonl"))?;
        }
        opts.resume = Some(fedavg::runstate::ResumeFrom {
            snapshot: snap,
            run_dir: run_dir.to_path_buf(),
        });
    } else {
        let name = args.str_or("name", default_name);
        let out = args.str_or("out", "runs");
        let w = if args.has("overwrite") {
            RunWriter::create_overwrite(&out, &name)?
        } else {
            RunWriter::create(&out, &name)?
        };
        if args.has("trace") {
            opts.trace = Tracer::to_file(&w.dir().join("trace.jsonl"))?;
        }
        opts.telemetry = Some(w);
    }
    Ok(())
}

/// Load `--config FILE` once; `run`/`fleet` layer both the FedConfig
/// and the aggregation keys out of it.
fn config_file_from_args(args: &Args) -> Result<Option<ConfigFile>> {
    match args.str_opt("config") {
        Some(path) => Ok(Some(ConfigFile::load(std::path::Path::new(path))?)),
        None => Ok(None),
    }
}

/// Aggregation knobs shared by `run` and `fleet`: defaults ← config-file
/// keys (`agg`, `server_lr`, …) ← CLI flags, validated against the
/// `federated::aggregate` registry so a bad `--agg` fails fast.
fn agg_config_from(file: Option<&ConfigFile>, args: &Args) -> Result<AggConfig> {
    let base = match file {
        Some(cf) => AggConfig::from_config(cf)?,
        None => AggConfig::default(),
    };
    let cfg = AggConfig {
        spec: args.str_or("agg", &base.spec),
        // unset resolves per rule (1.0; 0.01 for fedadam, whose
        // Adam-normalized step diverges at η_s = 1)
        server_lr: args.f64_opt("server-lr")?.or(base.server_lr),
        server_momentum: args.f64_or("server-momentum", base.server_momentum)?,
        prox_mu: args.f64_or("prox-mu", base.prox_mu)?,
    };
    cfg.validate()?;
    Ok(cfg)
}

/// Parse the FedConfig-shaped flags shared by `run` and `fleet`.
fn fed_config_from(file: Option<&ConfigFile>, args: &Args) -> Result<FedConfig> {
    let mut cfg = match file {
        Some(cf) => cf.fed_config()?,
        None => FedConfig::default(),
    };
    if let Some(m) = args.str_opt("model") {
        cfg.model = m.to_string();
    }
    cfg.c = args.f64_or("c", cfg.c)?;
    cfg.e = args.usize_or("e", cfg.e)?;
    if let Some(b) = args.str_opt("b") {
        cfg.b = BatchSize::parse(b)?;
    }
    cfg.lr = args.f64_or("lr", cfg.lr)?;
    cfg.lr_decay = args.f64_or("lr-decay", cfg.lr_decay)?;
    cfg.rounds = args.usize_or("rounds", cfg.rounds)?;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every)?;
    if let Some(t) = args.str_opt("target") {
        cfg.target_accuracy = Some(t.parse()?);
    }
    cfg.track_train_loss = args.has("track-train-loss") || cfg.track_train_loss;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    Ok(cfg)
}

/// `fedavg fleet` — fleet-aware federated training (device profiles,
/// over-selection, deadlines, worker parallelism). Without artifacts —
/// or with `--sim-only` — runs the training-free event-queue simulation,
/// which scales to 100k+ clients.
fn cmd_fleet(args: &Args) -> Result<()> {
    args.check_known(&[
        "config", "model", "c", "e", "b", "lr", "lr-decay", "rounds", "eval-every",
        "target", "partition", "scale", "eval-cap", "seed", "out", "name",
        "track-train-loss", "fleet-profile", "overselect", "deadline", "workers", "shards",
        "async-buffer", "staleness-decay", "late-policy", "abort-p", "duplicate-p",
        "step-cost", "clients", "sim-only", "start-round", "model-bytes", "steps", "codec",
        "down-codec", "topk", "quant-bits", "agg", "server-lr", "server-momentum",
        "prox-mu", "checkpoint-every", "checkpoint-keep", "resume", "overwrite", "trace",
    ])?;
    let file = config_file_from_args(args)?;
    let cfg = fed_config_from(file.as_ref(), args)?;
    let fleet = FleetConfig {
        profile: FleetProfile::parse(&args.str_or("fleet-profile", "mobile"))?,
        overselect: args.f64_or("overselect", 0.0)?,
        deadline_s: match args.str_opt("deadline") {
            None => None,
            Some(v) => {
                let d: f64 = v.parse()?;
                if !d.is_finite() || d <= 0.0 {
                    bail!("--deadline must be a positive number of seconds, got {v:?}");
                }
                Some(d)
            }
        },
        workers: args.usize_or("workers", 1)?,
        step_cost_s: args.f64_or("step-cost", FleetConfig::default().step_cost_s)?,
        shards: args.usize_or("shards", 0)?,
        async_buffer: match args.str_opt("async-buffer") {
            None => None,
            Some(v) => Some(
                v.parse::<usize>()
                    .map_err(|_| anyhow!("--async-buffer: bad integer {v:?}"))?,
            ),
        },
        staleness_decay: args.f64_or("staleness-decay", 1.0)?,
        late_policy: LatePolicy::parse(&args.str_or("late-policy", "drop"))?,
        ..FleetConfig::default()
    };
    if !fleet.step_cost_s.is_finite() || fleet.step_cost_s < 0.0 {
        bail!("--step-cost must be a non-negative number of seconds");
    }
    if !fleet.overselect.is_finite() || fleet.overselect < 0.0 {
        bail!("--overselect must be a non-negative factor (e.g. 0.3)");
    }

    // Parse (and validate) the aggregation + checkpoint config up front:
    // a bad --agg, and checkpoint/resume settings from EITHER the flags
    // or the config-file keys, must fail fast on the sim-only path too,
    // not be silently ignored.
    let agg = agg_config_from(file.as_ref(), args)?;
    // A robust rule cannot shard (order statistics do not compose across
    // aggregation tiers) — refuse the pairing at startup on every path,
    // the sim-only one included (DESIGN.md §11).
    if fleet.shards > 0 {
        let rule = agg.build()?;
        if !rule.mean_combine() {
            bail!(
                "--agg {} cannot run under --shards: coordinate-wise order \
                 statistics do not compose across aggregation tiers — only \
                 mean-family rules (fedavg/fedavgm/fedadam) shard (DESIGN.md §11)",
                rule.label()
            );
        }
    }
    // The async round modes rescale deltas by staleness, which only a
    // mean-family combine absorbs — refuse robust rules on every path,
    // the sim-only one included (DESIGN.md §12).
    if fleet.async_buffer.is_some() || fleet.late_policy == LatePolicy::Discount {
        let mode = if fleet.async_buffer.is_some() {
            "--async-buffer"
        } else {
            "--late-policy discount"
        };
        let rule = agg.build()?;
        if !rule.mean_combine() {
            bail!(
                "--agg {} cannot run under {mode}: a staleness-weighted partial \
                 buffer is not a full round cohort, and coordinate-wise order \
                 statistics are only defined over one — only mean-family rules \
                 (fedavg/fedavgm/fedadam) run async/semi-sync (DESIGN.md §12)",
                rule.label()
            );
        }
        if fleet.shards > 0 {
            bail!(
                "--shards assumes the synchronous round barrier: a tier-1 \
                 cascade aggregates one full cohort per round, not a \
                 staleness-weighted buffer or late arrivals — {mode} cannot \
                 shard (DESIGN.md §12)"
            );
        }
    }
    let ckpt = checkpoint_from(file.as_ref(), args)?;

    let have_artifacts = Engine::default_dir().join("manifest.json").exists();
    if args.has("sim-only") || !have_artifacts {
        if args.has("resume") || ckpt.is_some() {
            bail!(
                "checkpoint/resume applies to training runs; the training-free \
                 simulation needs no checkpoints — each round is a pure function \
                 of the seed, so rerunning it IS resuming it (DESIGN.md §8)"
            );
        }
        if !args.has("sim-only") {
            println!(
                "no artifacts at {:?} — running the fleet simulation without training \
                 (event-queue schedule + accounting only)",
                Engine::default_dir()
            );
        }
        for f in ["agg", "server-lr", "server-momentum", "prox-mu"] {
            if args.has(f) {
                println!(
                    "note: --{f} only applies to training runs; the training-free \
                     simulation schedules rounds without an aggregation step"
                );
            }
        }
        return cmd_fleet_sim(args, &cfg, &fleet);
    }

    if args.has("start-round") {
        bail!(
            "--start-round fast-forwards the training-free simulation only \
             (--sim-only); a training run continues from a checkpoint via --resume"
        );
    }
    for f in ["clients", "model-bytes", "steps", "abort-p", "duplicate-p"] {
        if args.has(f) {
            println!(
                "note: --{f} only applies to the training-free simulation \
                 (--sim-only); the training run derives it from the dataset"
            );
        }
    }
    let scale = args.f64_or("scale", 0.05)?;
    let part = Partition::parse(&args.str_or("partition", "iid"))?;
    let fed = build_fed(&cfg.model, scale, part, cfg.seed)?;
    let engine = engine()?;
    let mut opts = fedavg::federated::ServerOptions {
        eval_cap: Some(args.usize_or("eval-cap", 1000)?),
        fleet: fleet.clone(),
        transport: transport_from_args(args)?,
        agg,
        ..Default::default()
    };
    let default_name = format!("fleet-{}", cfg.label().replace(' ', "_"));
    attach_run_outputs(args, ckpt, &mut opts, &default_name)?;

    println!(
        "fleet run: {} on {} — {} clients, profile {}, overselect {:.0}%, deadline {}, workers {}",
        cfg.label(),
        fed.train.name,
        fed.num_clients(),
        fleet.profile.label(),
        fleet.overselect * 100.0,
        fleet
            .deadline_s
            .map(|d| format!("{d}s"))
            .unwrap_or_else(|| "none".into()),
        fleet.workers,
    );
    let res = fedavg::federated::run(&engine, &fed, &cfg, opts)?;
    println!(
        "done: {} rounds, final acc {:.4}, dispatched {}, aggregated {}, \
         dropped stragglers {}, deadline misses {}, sim {:.0}s",
        res.rounds_run,
        res.final_accuracy(),
        res.fleet.dispatched,
        res.fleet.completed,
        res.fleet.dropped_stragglers,
        res.fleet.deadline_misses,
        res.comm.sim_seconds,
    );
    Ok(())
}

/// Run totals of the sim's edge-tier (tier-1) accounting — the summary's
/// `tier1_*` fields.
#[derive(Default)]
struct TierTotals {
    up_bytes: u64,
    down_bytes: u64,
    frames: u64,
    seconds: f64,
}

/// One sim round's tier-1 cascade accounting, mirroring
/// `federated::aggregate::combine_sharded`'s frame pattern: each of the
/// `non_empty` edges ships one dense up frame, and every edge after the
/// first receives one down frame. Returns
/// `(non_empty, up_bytes, down_bytes, frames, seconds)`.
fn tier1_round(
    shards: usize,
    completed: usize,
    frame_bytes: u64,
    link: &TierLink,
) -> (usize, u64, u64, u64, f64) {
    let non_empty = shards.min(completed); // the scheduler guarantees >= 1
    let frames = (2 * non_empty - 1) as u64;
    let up = non_empty as u64 * frame_bytes;
    let down = (non_empty as u64 - 1) * frame_bytes;
    let seconds = frames as f64 * tier_transfer_seconds(link, frame_bytes);
    (non_empty, up, down, frames, seconds)
}

/// Training-free fleet simulation — scales to fleets far beyond what
/// training can touch (10k clients by default, 100k+ fine).
fn cmd_fleet_sim(args: &Args, cfg: &FedConfig, fleet: &FleetConfig) -> Result<()> {
    let k = args.usize_or("clients", 10_000)?;
    let m = cfg.clients_per_round(k);
    // default model: the MNIST CNN (1,663,370 params), the paper's
    // heavyweight image model — ~6.7 MB on the wire
    let model_bytes = args.u64_or("model-bytes", fedavg::comms::model_bytes(1_663_370))?;
    // default local work: u = E·(n/K)/B with the paper's 600 examples per
    // client
    let steps = args.f64_or(
        "steps",
        fedavg::federated::updates_per_round(cfg.e, 600, cfg.b),
    )?;
    if !steps.is_finite() || steps < 0.0 {
        bail!("--steps must be a non-negative local step count");
    }
    let start_round = args.u64_or("start-round", 1)?;
    if start_round < 1 || start_round > cfg.rounds as u64 {
        bail!(
            "--start-round must be in 1..={} (the sim's --rounds), got {start_round}",
            cfg.rounds
        );
    }
    let mut sim = FleetSim::new(fleet, k, m, model_bytes, steps, cfg.seed)?;
    // Seeded fault stream (sim-only): client aborts and duplicate
    // deliveries drawn from a pure per-(round, client) coin.
    if args.has("abort-p") || args.has("duplicate-p") {
        sim = sim.with_faults(FaultConfig {
            abort_p: args.f64_or("abort-p", 0.0)?,
            duplicate_p: args.f64_or("duplicate-p", 0.0)?,
            seed: cfg.seed,
        })?;
    }
    let name = args.str_or("name", &format!("fleet-sim-{}-k{k}", fleet.profile.label()));
    let out = args.str_or("out", "runs");
    let mut w = if args.has("overwrite") {
        FleetWriter::create_overwrite(&out, &name)?
    } else {
        FleetWriter::create(&out, &name)?
    };
    // --trace on the training-free path: spans around the event-queue
    // schedule + the telemetry write, fleet counters in the registry.
    // fleet.csv itself stays byte-identical (wall-clock only ever lands
    // in trace.jsonl, DESIGN.md §10).
    let tracer = if args.has("trace") {
        Tracer::to_file(&w.dir().join("trace.jsonl"))?
    } else {
        Tracer::default()
    };
    let metrics = Metrics::default();
    // Hierarchical aggregation (--shards S): tier-0 client links are
    // partitioned across the S edges (per-shard bytes sum exactly to the
    // flat totals), and the edge↔root cascade ships dense tier-1 frames
    // (wire header + model payload). Rows land in tiers.csv; fleet.csv
    // stays byte-identical to a flat run (DESIGN.md §11).
    let shards = fleet.shards;
    let tier_frame_bytes = fedavg::comms::wire::HEADER_BYTES + model_bytes;
    let tier_link = TierLink::default();
    let mut tiers = (shards > 0)
        .then(|| TierWriter::create_in(w.dir()))
        .transpose()?;
    let mut tier_totals = TierTotals::default();
    println!(
        "fleet sim: {} clients ({} profile), m={m} +{:.0}% over-selection, deadline {}, \
         model {:.1} MB, {} local steps, {} rounds",
        k,
        fleet.profile.label(),
        fleet.overselect * 100.0,
        fleet
            .deadline_s
            .map(|d| format!("{d}s"))
            .unwrap_or_else(|| "none".into()),
        model_bytes as f64 / 1e6,
        steps,
        cfg.rounds,
    );
    if shards > 0 {
        println!(
            "hierarchical aggregation: {shards} edge shards, {:.1} MB dense tier-1 \
             frames (tiers.csv; fleet.csv stays flat-identical)",
            tier_frame_bytes as f64 / 1e6,
        );
    }
    if let Some(buf) = fleet.async_buffer {
        println!(
            "buffered-async rounds: apply every {buf} deltas, staleness decay {}",
            fleet.staleness_decay,
        );
    } else if fleet.late_policy == LatePolicy::Discount {
        println!(
            "semi-sync rounds: late stragglers staleness-discounted (decay {}) \
             instead of dropped",
            fleet.staleness_decay,
        );
    }
    if start_round > 1 {
        // each sim round is a pure function of (seed, round): scheduling
        // for the skipped prefix is recomputed into the totals, but
        // nothing is re-recorded or re-printed (DESIGN.md §8)
        let t = if shards > 0 {
            // tier-1 totals need each skipped round's cohort size, so
            // step the prefix explicitly; per-round rows are still not
            // re-emitted (the same rule fast_forward applies to fleet.csv)
            for _ in 1..start_round {
                let r = sim.step();
                let (_, up, down, frames, secs) =
                    tier1_round(shards, r.plan.completed.len(), tier_frame_bytes, &tier_link);
                tier_totals.up_bytes += up;
                tier_totals.down_bytes += down;
                tier_totals.frames += frames;
                tier_totals.seconds += secs;
            }
            sim.totals()
        } else {
            sim.fast_forward(start_round)
        };
        println!(
            "fast-forwarded rounds 1..{start_round}: {} dispatched, {} aggregated, \
             {} dropped, sim {:.1}h",
            t.fleet.dispatched,
            t.fleet.completed,
            t.fleet.dropped_stragglers,
            t.sim_seconds / 3600.0,
        );
    }
    for round in start_round..=cfg.rounds as u64 {
        let sp_round = tracer.begin(round, "sim_round", 0);
        let sp = tracer.begin(round, "schedule", 1);
        let r = sim.step();
        tracer.end(sp);
        metrics.inc("rounds");
        metrics.add("fleet.dispatched", r.plan.dispatched.len() as u64);
        metrics.add("fleet.completed", r.plan.completed.len() as u64);
        metrics.add("fleet.dropped", r.plan.dropped.len() as u64);
        metrics.add("fleet.deadline_misses", r.plan.deadline_miss as u64);
        metrics.observe("round.seconds", r.plan.round_seconds);
        let sp = tracer.begin(round, "record", 1);
        w.record(&FleetRoundRecord {
            round: r.round,
            online: r.online,
            dispatched: r.plan.dispatched.len(),
            completed: r.plan.completed.len(),
            dropped: r.plan.dropped.len(),
            deadline_miss: r.plan.deadline_miss,
            round_seconds: r.plan.round_seconds,
        })?;
        if let Some(tw) = tiers.as_mut() {
            // edge j serves the j-th contiguous slice of each cohort:
            // aggregated clients for the uplink, dispatched (incl.
            // later-dropped stragglers) for the downlink — shard_ranges
            // tiles each cohort, so per-shard bytes sum exactly to the
            // flat run's totals
            let up = shard_ranges(r.plan.completed.len(), shards);
            let down = shard_ranges(r.plan.dispatched.len(), shards);
            for j in 0..shards {
                tw.record(&TierRecord {
                    round: r.round,
                    tier: 0,
                    shard: j,
                    clients: up[j].len(),
                    up_bytes: up[j].len() as u64 * model_bytes,
                    down_bytes: down[j].len() as u64 * model_bytes,
                    seconds: r.plan.round_seconds,
                })?;
            }
            let (non_empty, t1_up, t1_down, frames, secs) =
                tier1_round(shards, r.plan.completed.len(), tier_frame_bytes, &tier_link);
            tw.record(&TierRecord {
                round: r.round,
                tier: 1,
                shard: 0,
                clients: non_empty,
                up_bytes: t1_up,
                down_bytes: t1_down,
                seconds: secs,
            })?;
            metrics.add("tier.edge_up_bytes", t1_up);
            metrics.add("tier.edge_down_bytes", t1_down);
            metrics.add("tier.edge_frames", frames);
            metrics.observe("tier.seconds", secs);
            tier_totals.up_bytes += t1_up;
            tier_totals.down_bytes += t1_down;
            tier_totals.frames += frames;
            tier_totals.seconds += secs;
        }
        if r.round % cfg.eval_every as u64 == 0 || r.round == cfg.rounds as u64 {
            println!(
                "round {:>5}: online {:>6}  dispatched {:>5}  aggregated {:>5}  \
                 dropped {:>4}{}  t={:.1}s",
                r.round,
                r.online,
                r.plan.dispatched.len(),
                r.plan.completed.len(),
                r.plan.dropped.len(),
                if r.plan.deadline_miss { "  DEADLINE MISS" } else { "" },
                r.plan.round_seconds,
            );
        }
        tracer.end(sp);
        tracer.end(sp_round.map(|s| s.sim(r.plan.round_seconds)));
    }
    if let Some(table) = tracer.finish(&metrics)? {
        eprint!("{table}");
    }
    let t = sim.totals();
    let mut fields = vec![
        ("fleet_profile", fleet.profile.label().to_string()),
        ("clients", k.to_string()),
        ("rounds", t.rounds.to_string()),
        ("dispatched", t.fleet.dispatched.to_string()),
        ("completed", t.fleet.completed.to_string()),
        ("dropped_stragglers", t.fleet.dropped_stragglers.to_string()),
        ("deadline_misses", t.fleet.deadline_misses.to_string()),
        ("bytes_up", t.bytes_up.to_string()),
        ("sim_seconds", format!("{:.1}", t.sim_seconds)),
    ];
    if fleet.async_buffer.is_some() {
        fields.push(("async_buffer", fleet.async_buffer.unwrap().to_string()));
        fields.push(("buffer_applies", t.buffer_applies.to_string()));
        fields.push(("buffer_fill", sim.buffer_fill().to_string()));
        fields.push(("staleness_decay", format!("{:?}", fleet.staleness_decay)));
    }
    if fleet.late_policy == LatePolicy::Discount {
        fields.push(("late_policy", "discount".to_string()));
        fields.push(("late_applied", t.late_applied.to_string()));
        fields.push(("staleness_decay", format!("{:?}", fleet.staleness_decay)));
    }
    if args.has("abort-p") || args.has("duplicate-p") {
        fields.push(("aborted", t.aborted.to_string()));
        fields.push(("duplicates_refused", t.duplicates_refused.to_string()));
    }
    if shards > 0 {
        // tier-0 totals ARE the flat run's wire totals — sharding
        // repartitions the client links without adding a byte to them
        fields.push(("shards", shards.to_string()));
        fields.push(("tier0_up_bytes", t.bytes_up.to_string()));
        fields.push(("tier0_down_bytes", t.bytes_down.to_string()));
        fields.push(("tier1_up_bytes", tier_totals.up_bytes.to_string()));
        fields.push(("tier1_down_bytes", tier_totals.down_bytes.to_string()));
        fields.push(("tier1_frames", tier_totals.frames.to_string()));
        fields.push(("tier1_seconds", format!("{:.3}", tier_totals.seconds)));
    }
    w.finish(&fields)?;
    println!(
        "done: {} rounds — {} dispatched, {} aggregated, {} stragglers dropped, \
         {} deadline misses, {:.2} GB up, sim {:.1}h",
        t.rounds,
        t.fleet.dispatched,
        t.fleet.completed,
        t.fleet.dropped_stragglers,
        t.fleet.deadline_misses,
        t.bytes_up as f64 / 1e9,
        t.sim_seconds / 3600.0,
    );
    if shards > 0 {
        println!(
            "tiers: {} edge shards — tier-1 {:.3} GB over {} frames, {:.1}s backhaul \
             (tier-0 client bytes unchanged: {:.2} GB up)",
            shards,
            (tier_totals.up_bytes + tier_totals.down_bytes) as f64 / 1e9,
            tier_totals.frames,
            tier_totals.seconds,
            t.bytes_up as f64 / 1e9,
        );
    }
    if fleet.async_buffer.is_some() {
        println!(
            "async: {} buffer applies, {} delta(s) still pending",
            t.buffer_applies,
            sim.buffer_fill(),
        );
    }
    if fleet.late_policy == LatePolicy::Discount {
        println!(
            "semi-sync: {} late update(s) applied with staleness discounts, {} still queued",
            t.late_applied,
            sim.late_queued(),
        );
    }
    if t.aborted + t.duplicates_refused > 0 {
        println!(
            "faults: {} abort(s), {} duplicate delivery(ies) refused",
            t.aborted, t.duplicates_refused,
        );
    }
    Ok(())
}

/// `fedavg bench` — the bench trajectory harness (DESIGN.md §10): run
/// the bench areas and record committed `BENCH_<area>.json` snapshots
/// (median/p10/p90 ns per case, machine-tagged; see `BENCH_schema.md`).
/// `--check` runs every case once on a millisecond budget into
/// `target/bench-check/` and validates the emitted JSON — the CI smoke
/// mode. Wall-clock numbers belong in these snapshots (and trace.jsonl)
/// only, never in curve.csv or grid manifests.
fn cmd_bench(args: &Args) -> Result<()> {
    use fedavg::obs::bench::{self, AreaStatus};
    use fedavg::util::bench::Bencher;
    args.check_known(&["areas", "out", "check", "quick", "compare", "tolerance"])?;
    let areas: Vec<String> = match args.str_opt("areas") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => bench::AREAS.iter().map(|s| s.to_string()).collect(),
    };
    anyhow::ensure!(!areas.is_empty(), "--areas: empty area list");
    let check = args.has("check");
    // --compare: re-measure and diff against committed snapshots instead
    // of re-recording them. Exit codes split the failure modes for CI:
    // schema drift (snapshot and code disagree on the case set) is a
    // hard error (exit 1); timing past --tolerance exits 2, which the
    // bench-smoke job downgrades to a warning on its noisy runner.
    let compare = args.str_opt("compare");
    let tolerance = args.f64_or("tolerance", 10.0)?;
    anyhow::ensure!(
        tolerance.is_finite() && tolerance >= 0.0,
        "--tolerance: want a non-negative percent, got {tolerance}"
    );
    let out = args.str_or(
        "out",
        // compare mode must not clobber the committed snapshots it reads
        if check || compare.is_some() { "target/bench-check" } else { "." },
    );
    let out = std::path::Path::new(&out);
    println!(
        "bench harness — {} area(s), {} profile, snapshots under {}\n",
        areas.len(),
        if check {
            "--check (single-shot)"
        } else if args.has("quick") {
            "quick"
        } else {
            "full"
        },
        out.display()
    );
    let mut recorded = 0usize;
    let mut regressions = 0usize;
    for area in &areas {
        // fresh bencher per area: each snapshot holds only its own cases
        let mut b = if check {
            bench::check_bencher()
        } else if args.has("quick") || area == "client_update" {
            // client_update drives PJRT end-to-end; the quick profile is
            // its standalone default too
            Bencher::quick()
        } else {
            Bencher::default()
        };
        println!("== {area} ==");
        if let AreaStatus::Skipped(why) = bench::run_area(area, &mut b)? {
            println!("SKIP {area}: {why}\n");
            continue;
        }
        let path = out.join(format!("BENCH_{area}.json"));
        bench::write_snapshot(&path, area, b.results())?;
        let cases = bench::validate_snapshot(&std::fs::read_to_string(&path)?)?;
        println!("wrote {} ({cases} cases)\n", path.display());
        recorded += 1;
        if let Some(cmp) = &compare {
            let cmp_path = std::path::Path::new(cmp);
            let snap_path = if cmp_path.is_dir() {
                cmp_path.join(format!("BENCH_{area}.json"))
            } else {
                anyhow::ensure!(
                    areas.len() == 1,
                    "--compare {cmp}: a single snapshot file compares a single \
                     area — use --areas <one> or point --compare at a directory"
                );
                cmp_path.to_path_buf()
            };
            let old = std::fs::read_to_string(&snap_path).map_err(|e| {
                anyhow::anyhow!("--compare: cannot read {}: {e}", snap_path.display())
            })?;
            let (deltas, reg) = bench::compare_snapshot(&old, area, b.results(), tolerance)?;
            print!("{}", bench::fmt_deltas(area, &deltas, tolerance));
            println!();
            if reg {
                regressions += 1;
            }
        }
    }
    println!(
        "bench: {recorded}/{} areas recorded, snapshots validated against {:?}",
        areas.len(),
        bench::BENCH_SCHEMA
    );
    if regressions > 0 {
        eprintln!(
            "bench: {regressions} area(s) slower than the snapshot beyond \
             --tolerance {tolerance}%"
        );
        std::process::exit(2);
    }
    Ok(())
}

/// `fedavg lint` — the invariant catalog as a static-analysis pass
/// (DESIGN.md §13). Exits nonzero on any finding; `--json` prints the
/// machine-readable report (the CI artifact); `--fix-allow` inserts
/// placeholder escape hatches so a burn-down starts from a green tree.
fn cmd_lint(args: &Args) -> Result<()> {
    args.check_known(&["json", "fix-allow"])?;
    let paths = fedavg::analysis::Paths::from_manifest_dir(std::path::Path::new(env!(
        "CARGO_MANIFEST_DIR"
    )));
    let mut findings = fedavg::analysis::lint_tree(&paths)?;
    if args.has("fix-allow") && !findings.is_empty() {
        let n = fedavg::analysis::fix_allow(&paths.repo_root, &findings)?;
        eprintln!(
            "lint: inserted {n} placeholder lint:allow hatches — replace every \
             FIXME justification before committing"
        );
        findings = fedavg::analysis::lint_tree(&paths)?;
    }
    if args.has("json") {
        print!("{}", fedavg::analysis::render_json(&findings));
    } else {
        print!("{}", fedavg::analysis::render_text(&findings));
    }
    if findings.is_empty() {
        eprintln!("lint: clean — every invariant in the catalog holds");
        Ok(())
    } else {
        bail!("lint: {} finding(s)", findings.len())
    }
}

fn cmd_oneshot(args: &Args) -> Result<()> {
    args.check_known(&["model", "scale", "e", "lr", "seed", "eval-cap"])?;
    let model = args.str_or("model", "mnist_2nn");
    let scale = args.f64_or("scale", 0.05)?;
    let seed = args.u64_or("seed", 42)?;
    let fed = build_fed(&model, scale, Partition::Iid, seed)?;
    let engine = engine()?;
    let cfg = oneshot::OneShotConfig {
        model: model.clone(),
        epochs: args.usize_or("e", 20)?,
        batch: BatchSize::Fixed(10),
        lr: args.f64_or("lr", 0.1)?,
        seed,
    };
    let res = oneshot::run(&engine, &fed, &cfg, Some(args.usize_or("eval-cap", 1000)?))?;
    println!(
        "one-shot averaging on {model}: averaged acc {:.4}, best single-client acc {:.4}",
        res.averaged.accuracy(),
        res.best_single.accuracy()
    );
    Ok(())
}

fn build_fed(
    model: &str,
    scale: f64,
    part: Partition,
    seed: u64,
) -> Result<fedavg::data::Federated> {
    Ok(match model {
        "mnist_2nn" | "mnist_cnn" => exper::mnist_fed(scale, part, seed),
        "cifar_cnn" => exper::cifar_fed(scale, seed),
        "shakespeare_lstm" => {
            exper::shakespeare_fed(scale, part == Partition::Natural, seed)
        }
        "word_lstm" => exper::social_fed(scale, seed),
        other => bail!("unknown model {other}"),
    })
}

fn cmd_info() -> Result<()> {
    let dir = Engine::default_dir();
    println!("artifacts dir: {}", dir.display());
    let engine = Engine::load(&dir)?;
    println!("platform: PJRT CPU");
    for (name, m) in &engine.manifest().models {
        println!(
            "  {name:<18} {:>9} params  kind={:<6} steps@{:?} acc@{}",
            m.param_count, m.kind, m.step_batches, m.acc_batch
        );
    }
    Ok(())
}

const HELP: &str = "\
fedavg — Communication-Efficient Learning of Deep Networks from
Decentralized Data (McMahan et al., AISTATS 2017) reproduction.

USAGE:
  fedavg table1 [--scale F] [--rounds N] [--target A] [--models m1,m2]
  fedavg table2 [--scale F] [--rounds N] [--models mnist_cnn,shakespeare_lstm]
  fedavg table3 [--scale F] [--rounds N] [--targets a,b,c]
  fedavg table4 [--scale F] [--rounds N]
  fedavg comm   [--codecs c1,c2,..] [--down delta|dense|legacy] [--target A]
             [--model M] [--scale F] [--rounds N]
  fedavg agg    [--aggs a1,a2,..] [--corrupt FRAC] [--partitions iid,noniid]
             [--target A] [--model M] [--scale F] [--rounds N]
             [--server-lr F] [--server-momentum B] [--prox-mu MU]
  fedavg async  [--modes sync,semi,async] [--profiles p1,p2,..] [--buffer K]
             [--staleness-decay D] [--target A] [--model M] [--scale F]
             [--rounds N]
  fedavg sweep  [--center F] [--points N] [--res 3|6] [--model M]
             [--partition P] [--c F] [--e N] [--b N|inf] [--target A]
  fedavg figure <N|all> [--scale F] [--rounds N]
    every sweep subcommand above also takes the uniform grid flags:
             [--workers N] [--resume] [--dry-run] [--overwrite]
             [--checkpoint-every N] [--checkpoint-keep K] [--trace]
  fedavg run [--config FILE] [--model M] [--c F] [--e N] [--b N|inf]
             [--lr F] [--rounds N] [--partition iid|noniid|unbalanced|natural]
             [--availability P] [--target A] [--track-train-loss]
             [--dp-sigma S --dp-clip C] [--secure-agg]
             [--codec SPEC] [--down-codec SPEC]
             [--topk FRAC] [--quant-bits B]
             [--agg RULE] [--server-lr F] [--server-momentum B] [--prox-mu MU]
             [--checkpoint-every N] [--checkpoint-keep K] [--overwrite]
             [--trace]
  fedavg run --resume runs/<name> [--rounds N] [+ the original run's flags]
  fedavg fleet [--fleet-profile uniform|mobile|flaky] [--overselect RHO]
             [--deadline SECONDS] [--workers N] [--shards S] [--clients K]
             [--async-buffer K] [--staleness-decay D]
             [--late-policy drop|discount] [--abort-p P] [--duplicate-p P]
             [--sim-only] [--start-round R] [--step-cost S] [--model-bytes B]
             [--steps U] [--trace] [+ run flags]
  fedavg bench [--areas a1,a2,..] [--out DIR] [--check] [--quick]
             [--compare PATH] [--tolerance PCT]
  fedavg lint [--json] [--fix-allow]
  fedavg oneshot [--model M] [--e N]
  fedavg info

Codec SPECs compose registry stages with `|`: `dense`, `delta` (downlink
overwrite patch vs the client's acked model version), `topk:<count|frac>`,
`q<bits>` — e.g. --codec "topk:1000|q8" --down-codec delta. The scheduler
prices every link from the same pipeline that encodes it; per-round
up_bytes/down_bytes/codec land in runs/<name>/curve.csv. `comm` sweeps
codecs and prints rounds-to-target x bytes-per-round.

Aggregation RULEs come from the federated::aggregate registry: `fedavg`
(the paper's weighted averaging, the default), `fedavgm[:beta]` (server
momentum), `fedadam[:tau]` (server Adam over the mean delta), and the
robust `trimmed:<frac>` / `median` (coordinate-wise, for corrupted or
noisy cohorts; these need individual updates, so they refuse
--secure-agg and --dp-sigma) — e.g. --agg trimmed:0.1 --server-lr 0.5.
--server-lr left unset resolves per rule (1.0; 0.01 for fedadam's
Adam-normalized steps). `--prox-mu MU` adds FedProx's proximal term to
every ClientUpdate. The rule + server
optimizer state norms land in runs/<name>/curve.csv; `agg` sweeps rules
across IID/non-IID partitions with label-corrupted clients.

`fleet` trains through the fleet coordinator: persistent device profiles
(bandwidth/compute/diurnal availability), over-selection with straggler
drops, round deadlines, and parallel client updates. Without artifacts
(or with --sim-only) it runs the training-free event-queue simulation —
10k clients by default, 100k+ fine.

Async round modes (DESIGN.md §12): `--async-buffer K` replaces the
synchronous barrier — the server applies combine+step whenever K client
deltas have arrived (in virtual-clock order), weighting each by
d^staleness with d = --staleness-decay (default 1.0). `--late-policy
discount` keeps the barrier but staleness-discounts past-deadline
stragglers into a later round instead of dropping them (needs
--deadline). Both modes are a pure function of the seeded virtual clock:
byte-identical across --workers N, checkpointable between buffer
applies, and with decay 1.0 + buffer == cohort the async run reproduces
the synchronous curve.csv byte-for-byte. Robust rules, --secure-agg,
and --shards refuse both modes; DP composes at the combine+step seam.
Per-apply staleness_mean/buffer_fill land in curve.csv. The sim-only
path adds a seeded fault stream: --abort-p / --duplicate-p inject
client aborts and duplicate deliveries (duplicates are refused
idempotently, the wasted uplink billed). `fedavg async` sweeps
sync x semi-sync x async over the fleet profiles on the grid engine. `--start-round R` fast-forwards the
simulation: rounds 1..R fold into the totals without being re-recorded
(each round is a pure function of the seed). `--shards S` aggregates
hierarchically through S edge aggregators — bit-identical to flat
aggregation for the mean-family rules (robust rules refuse it, DESIGN.md
§11); edge<->root bytes/latency land in tiers.csv, tier.* metrics, and
the summary, never in curve.csv or fleet.csv.

Sweeps run on the grid engine (DESIGN.md S9): every cell (one table row
x partition, one figure series, one lr point) is a fingerprinted config
with its own run dir under runs/cells/<fingerprint>/, tracked by an
atomically-updated manifest under runs/grid-<name>/. Killing a sweep and
rerunning the same command skips finished cells and resumes in-flight
ones (with --checkpoint-every, mid-cell); the reprinted tables and every
curve.csv are byte-identical to an uninterrupted run. Identical cells
across sweeps run once and are reused as cache hits. --workers N runs
cells in parallel (one PJRT engine per worker thread; tables are
assembled after completion, so output is order-independent). --dry-run
lists cells and their cached status; --resume requires the manifest to
exist; --overwrite replaces a manifest left by a different command.

Observability (DESIGN.md §10): --trace wraps every round phase (sample,
dispatch, per-worker local training, codec encode, combine/step, eval,
checkpoint) in wall-clock spans appended to runs/<name>/trace.jsonl and
prints a per-round phase breakdown + the metrics registry at run end.
Tracing off is the default and costs nothing — untraced runs produce
byte-identical curve.csv/manifests (wall-clock lives only in trace.jsonl
and BENCH files). `fedavg bench` runs the bench areas (params_hot_path,
codec_pipeline, fleet_round, aggregators, client_update) and records
committed BENCH_<area>.json snapshots — median/p10/p90 ns per case,
machine-tagged (schema: BENCH_schema.md); --check is the CI smoke mode.
`--compare PATH` (a snapshot file, or a directory holding
BENCH_<area>.json) re-measures and prints per-case mean/p10/p90 deltas
against the committed trajectory without touching it (--out defaults to
target/bench-check): exit 2 when any area's mean regresses past
--tolerance PCT (default 10), exit 1 on schema drift — a renamed,
added, or removed case means the snapshot must be re-recorded.

Crash safety: --checkpoint-every N snapshots the complete run state
(model, optimizer moments, RNG streams, error-feedback residuals, model
store, byte totals, curves) every N rounds under runs/<name>/checkpoints/
(atomic writes, newest --checkpoint-keep retained). `--resume runs/<name>`
— with the original flags and a larger --rounds — continues from the
newest snapshot; the resumed trajectory and curve.csv are bit-identical
to a run that never stopped (DESIGN.md §8). Run dirs are never silently
reused: a colliding --name errors unless --overwrite (or --resume).

Defaults are scaled to this single-core testbed (--scale 0.05);
--scale 1.0 reproduces the paper-sized workloads. Curves land in runs/.
";
