//! # fedavg-rs
//!
//! A rust + JAX + Pallas reproduction of *"Communication-Efficient Learning
//! of Deep Networks from Decentralized Data"* (McMahan, Moore, Ramage,
//! Hampson, Agüera y Arcas — AISTATS 2017): the **FederatedAveraging**
//! paper.
//!
//! Architecture (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the federated coordinator: round loop, client
//!   sampling, pluggable server-side aggregation, data partitioning,
//!   communication accounting, LR sweeps, and every experiment harness in
//!   the paper's evaluation. Python never runs at this layer.
//! * **L2/L1 (build time)** — the paper's five model families written in
//!   JAX with Pallas kernels on the hot path, AOT-lowered to HLO text in
//!   `artifacts/` by `make artifacts` and executed here via PJRT
//!   ([`runtime`]).
//!
//! The public API is organised so a downstream user can assemble a custom
//! federated experiment from parts: pick a [`data`] source + partition,
//! a model bundle from [`runtime`], an algorithm from [`federated`] or
//! [`baselines`], a fleet scenario from [`coordinator`], and drive it
//! with [`metrics`]/[`telemetry`] attached.
//!
//! Module map:
//!
//! * [`federated`] — Algorithm 1: server round loop, ClientUpdate,
//!   per-round sampling, and the pluggable aggregation registry
//!   ([`federated::aggregate`]: server optimizers + robust rules).
//! * [`coordinator`] — the simulated device fleet: per-client profiles,
//!   event-queue scheduling (over-selection, deadlines, straggler
//!   drops), parallel ClientUpdate dispatch.
//! * [`baselines`] — one-shot averaging and centralized SGD.
//! * [`data`] — synthetic datasets + client partitions.
//! * [`comms`] — the transport subsystem: framed wire messages + the
//!   composable codec pipeline ([`comms::wire`]), the versioned model
//!   store with delta downlink ([`comms::transport`]), and the
//!   byte/wall-clock cost model with availability traces.
//! * [`compression`], [`privacy`] — sparsification/quantization
//!   primitives under the codecs, DP + secure aggregation.
//! * [`runstate`] — checkpoint/resume: versioned run-state snapshots
//!   with a bit-identical resume guarantee (crash-safe long runs).
//! * [`runtime`] — PJRT engine over the AOT artifacts + worker pool.
//! * [`exper`] — the paper's tables and figures, declared as cells into
//!   the restartable, parallel grid engine ([`exper::grid`] +
//!   [`exper::cells`], DESIGN.md §9); [`sweep`] — the lr-grid
//!   methodology on the same engine.
//! * [`obs`] — observability: the `--trace` span tracer, the metrics
//!   registry, and the `fedavg bench` trajectory harness (DESIGN.md
//!   §10).
//! * [`config`], [`metrics`], [`telemetry`], [`util`] — harness
//!   plumbing.

pub mod analysis;
pub mod baselines;
pub mod comms;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod federated;
pub mod metrics;
pub mod obs;
pub mod params;
pub mod privacy;
pub mod runstate;
pub mod runtime;
pub mod sweep;
pub mod telemetry;
pub mod util;

pub mod exper;

/// Crate-wide result type (eyre for rich error context).
pub type Result<T> = anyhow::Result<T>;
