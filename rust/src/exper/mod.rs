//! Experiment harnesses: one driver per table/figure in the paper's
//! evaluation (DESIGN.md §3 maps them), plus the subsystem sweeps —
//! [`table_comm`], the codec sweep behind `fedavg comm` (the
//! communication-efficiency framing the paper's footnote 7 points at),
//! and [`table_agg`], the aggregation-rule sweep behind `fedavg agg`
//! (server optimizers + robust rules, DESIGN.md §7).
//!
//! Every driver is a **grid declaration**: it lists its cells (named,
//! fingerprinted run configs — [`cells`]) into the [`grid`] engine,
//! which executes them restartably and in parallel, then formats the
//! paper's table/series from the outcome rows (DESIGN.md §9). The
//! per-table round loops of the pre-grid drivers are gone; what remains
//! in each `tableN.rs` is the declaration plus a row formatter. All
//! sweep subcommands therefore share one flag surface:
//! `--workers N` (parallel cells over per-thread engines), `--resume`
//! (continue an interrupted grid), `--dry-run` (list cells + cached
//! status), `--overwrite` (replace a stale manifest), and
//! `--checkpoint-every`/`--checkpoint-keep` (per-cell run-state
//! snapshots, DESIGN.md §8). Killing a grid and rerunning the same
//! command reproduces byte-identical tables and per-cell `curve.csv`
//! files versus an uninterrupted run.
//!
//! Every driver accepts `--scale` (default well below 1.0 — this testbed
//! is a single CPU core; `--scale 1.0` is the paper-sized configuration)
//! plus `--rounds`, `--target`, `--eval-cap` overrides, and prints a
//! paper-formatted table/series while persisting per-cell curves under
//! `runs/cells/`.

pub mod cells;
pub mod figures;
pub mod grid;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table_agg;
pub mod table_async;
pub mod table_comm;

use crate::config::{Partition, ScaleProfile};
use crate::data::rng::Rng;
use crate::data::{cifar_like, mnist_like, partition, shakespeare_like, social_like, Federated};
use crate::runstate::CheckpointConfig;
use crate::Result;

/// Harness-wide options parsed from the CLI — uniform across all sweep
/// subcommands (`table1`–`table4`, `comm`, `agg`, `figure`, `sweep`).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub scale: f64,
    /// hard cap on rounds per run (on top of config's own).
    pub rounds: usize,
    /// test-set eval cap (examples) for speed.
    pub eval_cap: usize,
    /// override the accuracy target (fraction).
    pub target: Option<f64>,
    pub seed: u64,
    pub out_root: String,
    /// grid-cell worker threads (`--workers`, one engine per thread).
    pub workers: usize,
    /// require an existing grid manifest (`--resume`).
    pub resume: bool,
    /// replace a manifest from a different cell set (`--overwrite`).
    pub overwrite: bool,
    /// list cells + cached status, run nothing (`--dry-run`).
    pub dry_run: bool,
    /// per-cell run-state checkpoint cadence (`--checkpoint-every`).
    pub checkpoint: Option<CheckpointConfig>,
    /// per-cell span tracing into each cell dir's trace.jsonl
    /// (`--trace`, DESIGN.md §10). Never part of a cell's fingerprint:
    /// tracing cannot change outputs.
    pub trace: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            scale: 0.05,
            rounds: 60,
            eval_cap: 600,
            target: None,
            seed: 42,
            out_root: "runs".into(),
            workers: 1,
            resume: false,
            overwrite: false,
            dry_run: false,
            checkpoint: None,
            trace: false,
        }
    }
}

impl ExpOptions {
    pub fn from_args(args: &crate::util::args::Args) -> Result<Self> {
        let d = Self::default();
        let checkpoint = match args.str_opt("checkpoint-every") {
            None => {
                anyhow::ensure!(
                    !args.has("checkpoint-keep"),
                    "--checkpoint-keep needs --checkpoint-every"
                );
                None
            }
            Some(v) => {
                let every: u64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--checkpoint-every: bad integer {v:?}"))?;
                let ck = CheckpointConfig {
                    every,
                    keep: args.usize_or("checkpoint-keep", 3)?,
                };
                ck.validate()?;
                Some(ck)
            }
        };
        Ok(Self {
            scale: args.f64_or("scale", d.scale)?,
            rounds: args.usize_or("rounds", d.rounds)?,
            eval_cap: args.usize_or("eval-cap", d.eval_cap)?,
            target: match args.str_opt("target") {
                Some(t) => Some(t.parse()?),
                None => None,
            },
            seed: args.u64_or("seed", d.seed)?,
            out_root: args.str_or("out", &d.out_root),
            workers: args.usize_or("workers", 1)?,
            resume: args.has("resume"),
            overwrite: args.has("overwrite"),
            dry_run: args.has("dry-run"),
            checkpoint,
            trace: args.has("trace"),
        })
    }

    /// The grid-engine knobs these options carry (DESIGN.md §9).
    pub fn grid_options(&self) -> grid::GridOptions {
        grid::GridOptions {
            out_root: self.out_root.clone(),
            workers: self.workers,
            resume: self.resume,
            overwrite: self.overwrite,
            dry_run: self.dry_run,
            checkpoint: self.checkpoint,
            trace: self.trace,
        }
    }
}

/// Flags shared by the table/figure/sweep drivers.
pub const COMMON_FLAGS: &[&str] = &[
    "scale",
    "rounds",
    "eval-cap",
    "target",
    "seed",
    "out",
    "rows",
    "lr",
    "quiet",
    "workers",
    "resume",
    "overwrite",
    "dry-run",
    "checkpoint-every",
    "checkpoint-keep",
    "trace",
];

// ---------------------------------------------------------------- workloads

/// MNIST-like federated workload (paper: K=100 clients x 600 examples).
pub fn mnist_fed(scale: f64, part: Partition, seed: u64) -> Federated {
    let sp = ScaleProfile::new(scale);
    // floor K at 20 so C=0.1 still selects m=2 clients — with m=1 the
    // pathological partition degenerates (each round sees 2 digits only),
    // which the paper's K=100 never exhibits.
    let k = sp.count(100, 20);
    let per_client = sp.count(600, 60);
    let n = k * per_client;
    let test_n = sp.count(10_000, 600);
    let gen = mnist_like::MnistLike::new(seed);
    let train = gen.dataset(n, 0);
    let test = gen.dataset(test_n, 1);
    let mut rng = Rng::new(seed ^ 0x9A27);
    let labels: Vec<i32> = (0..n).map(|i| train.label(i)).collect();
    let clients = match part {
        Partition::Iid => partition::iid(n, k, &mut rng),
        Partition::Pathological(s) => partition::pathological(&labels, k, s, &mut rng),
        Partition::Unbalanced => partition::unbalanced_zipf(n, k, 1.2, &mut rng),
        Partition::Natural => panic!("mnist has no natural partition"),
    };
    Federated {
        train,
        test,
        clients,
    }
}

/// CIFAR-like federated workload (paper: 100 clients x 500, IID only).
pub fn cifar_fed(scale: f64, seed: u64) -> Federated {
    let sp = ScaleProfile::new(scale);
    let k = sp.count(100, 10);
    let per_client = sp.count(500, 50);
    let n = k * per_client;
    let test_n = sp.count(10_000, 500);
    let gen = cifar_like::CifarLike::new(seed);
    let train = gen.dataset(n, 0);
    let test = gen.dataset(test_n, 1);
    let mut rng = Rng::new(seed ^ 0xC1F);
    let clients = partition::iid(n, k, &mut rng);
    Federated {
        train,
        test,
        clients,
    }
}

/// Shakespeare-like workload; `natural=true` = by-role (unbalanced,
/// non-IID), else the balanced IID re-deal (paper §3).
pub fn shakespeare_fed(scale: f64, natural: bool, seed: u64) -> Federated {
    let sp = ScaleProfile::new(scale);
    let cfg = shakespeare_like::PlayConfig {
        roles: sp.count(1146, 24),
        mean_lines: 24,
        zipf_s: 1.1,
        seed,
    };
    if natural {
        shakespeare_like::by_role(&cfg)
    } else {
        shakespeare_like::iid(&cfg)
    }
}

/// Social-post word-LM workload (paper: 500k authors; structurally scaled).
pub fn social_fed(scale: f64, seed: u64) -> Federated {
    let sp = ScaleProfile::new(scale);
    let cfg = social_like::SocialConfig {
        authors: sp.count(4000, 60),
        mean_posts: 24,
        test_authors: sp.count(400, 20),
        seed,
    };
    social_like::by_author(&cfg)
}

// ------------------------------------------------------------------ helpers

/// Render a markdown-ish table row list with an aligned header.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_fed_scales_and_partitions() {
        let fed = mnist_fed(0.05, Partition::Iid, 1);
        assert_eq!(fed.num_clients(), 20); // floored so C=0.1 keeps m>=2
        assert_eq!(fed.total_examples(), fed.train.len());
        let noniid = mnist_fed(0.05, Partition::Pathological(2), 1);
        // pathological: most clients see <= 2 labels
        let mut le2 = 0;
        for c in &noniid.clients {
            let mut ls: Vec<i32> = c.iter().map(|&i| noniid.train.label(i)).collect();
            ls.sort_unstable();
            ls.dedup();
            if ls.len() <= 2 {
                le2 += 1;
            }
        }
        assert!(le2 * 2 >= noniid.num_clients(), "{le2}");
    }

    #[test]
    fn shakespeare_fed_shapes() {
        let nat = shakespeare_fed(0.02, true, 3);
        let iid = shakespeare_fed(0.02, false, 3);
        assert_eq!(nat.num_clients(), iid.num_clients());
        assert_eq!(nat.train.len(), iid.train.len());
        assert!(nat.test.len() > 0);
    }

    #[test]
    fn exp_options_parse() {
        let args = crate::util::args::Args::parse_from(
            ["--scale", "0.1", "--rounds", "9", "--target", "0.5"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let o = ExpOptions::from_args(&args).unwrap();
        assert_eq!(o.scale, 0.1);
        assert_eq!(o.rounds, 9);
        assert_eq!(o.target, Some(0.5));
        assert_eq!(o.workers, 1);
        assert!(!o.resume && !o.overwrite && !o.dry_run && !o.trace);
        assert!(o.checkpoint.is_none());
    }

    #[test]
    fn exp_options_parse_grid_flags() {
        let args = crate::util::args::Args::parse_from(
            [
                "--workers", "4", "--resume", "--dry-run", "--trace",
                "--checkpoint-every", "10", "--checkpoint-keep", "2",
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let o = ExpOptions::from_args(&args).unwrap();
        assert_eq!(o.workers, 4);
        assert!(o.resume && o.dry_run && !o.overwrite);
        let ck = o.checkpoint.expect("cadence set");
        assert_eq!((ck.every, ck.keep), (10, 2));
        assert!(o.trace);
        let g = o.grid_options();
        assert_eq!(g.workers, 4);
        assert!(g.resume && g.dry_run && g.trace);

        // --checkpoint-keep without a cadence is a config error
        let args = crate::util::args::Args::parse_from(
            ["--checkpoint-keep", "2"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(ExpOptions::from_args(&args).is_err());
    }
}
