//! Table 4 (appendix) — the 2NN (E, B) grid at C=0.1, same methodology
//! as Table 2 but for the MNIST 2NN at its own target accuracy. Declared
//! through [`table2::run_specs`](super::table2::run_specs) as its own
//! grid (`grid-table4`), so Table 2 and Table 4 cells cache
//! independently while still sharing the cell pool.

use crate::config::BatchSize;
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::table2::{run_specs, GridSpec};
use super::{ExpOptions, COMMON_FLAGS};

/// Paper Table 4 rows (E, B); first row is FedSGD.
pub const ROWS_2NN: [(usize, BatchSize); 9] = [
    (1, BatchSize::Full), // FedSGD
    (10, BatchSize::Full),
    (1, BatchSize::Fixed(50)),
    (20, BatchSize::Full),
    (1, BatchSize::Fixed(10)),
    (10, BatchSize::Fixed(50)),
    (20, BatchSize::Fixed(50)),
    (10, BatchSize::Fixed(10)),
    (20, BatchSize::Fixed(10)),
];

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(&[COMMON_FLAGS, &["lr", "target-noniid"]].concat())?;
    let opts = ExpOptions::from_args(args)?;
    let mut rows: &[(usize, BatchSize)] = &ROWS_2NN;
    let nrows = args.usize_or("rows", rows.len())?;
    rows = &rows[..nrows.min(rows.len())];
    let spec = GridSpec {
        model: "mnist_2nn",
        rows,
        target: opts.target.unwrap_or(0.80),
        target_noniid: args.f64_or("target-noniid", 0.55)?,
        lr: args.f64_or("lr", 0.1)?,
    };
    run_specs(engine, &opts, "table4", &[spec])
}
