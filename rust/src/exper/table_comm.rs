//! `fedavg comm` — the communication-efficiency sweep: codec pipelines ×
//! rounds-to-target-accuracy × wire bytes per round.
//!
//! This reproduces the paper's headline framing from the communication
//! side: FedAvg already buys a 10–100× reduction in *rounds*; the codec
//! pipelines (footnote 7's compressed-updates direction, Konečný et al.)
//! multiply in a per-round byte reduction on top — sparsified/quantized
//! uplinks and delta downlinks — while the table tracks what that costs
//! in rounds to the accuracy target. Every row runs the same federated
//! workload through `federated::run` with a different
//! [`TransportConfig`]; bytes come from the transport's single metering
//! path, so the table's numbers equal the telemetry CSVs under `runs/`.

use crate::comms::transport::TransportConfig;
use crate::comms::wire::registry_help;
use crate::config::{BatchSize, FedConfig, Partition};
use crate::federated::{self, ServerOptions};
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::{mnist_fed, print_table, shakespeare_fed, ExpOptions, COMMON_FLAGS};

/// Default codec sweep: the legacy dense baseline, framed dense, then
/// increasingly aggressive uplink pipelines.
pub const DEFAULT_CODECS: &str = "legacy,dense,q8,topk:0.05,topk:0.01|q8";

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(
        &[COMMON_FLAGS, &["model", "codecs", "down", "c", "e", "b", "partition"]].concat(),
    )?;
    let opts = ExpOptions::from_args(args)?;
    let model = args.str_or("model", "mnist_2nn");
    let codecs = args.str_or("codecs", DEFAULT_CODECS);
    let down_spec = args.str_or("down", "delta");
    let part = Partition::parse(&args.str_or("partition", "iid"))?;

    let fed = match model.as_str() {
        "mnist_2nn" | "mnist_cnn" => mnist_fed(opts.scale, part, opts.seed),
        "shakespeare_lstm" => shakespeare_fed(opts.scale, part == Partition::Natural, opts.seed),
        other => anyhow::bail!("comm: unsupported model {other} (mnist_2nn|mnist_cnn|shakespeare_lstm)"),
    };
    let cfg = FedConfig {
        model: model.clone(),
        c: args.f64_or("c", 0.1)?,
        e: args.usize_or("e", 5)?,
        b: BatchSize::parse(&args.str_or("b", "10"))?,
        lr: args.f64_or("lr", 0.1)?,
        rounds: opts.rounds,
        target_accuracy: opts.target,
        seed: opts.seed,
        ..Default::default()
    };
    println!(
        "comm sweep: {} on {} ({} clients), downlink codec {:?}, codecs: {}\nregistry stages:\n{}",
        cfg.label(),
        fed.train.name,
        fed.num_clients(),
        down_spec,
        codecs,
        registry_help(),
    );

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut baseline_per_round: Option<f64> = None;
    for spec in codecs.split(',') {
        let spec = spec.trim();
        if spec.is_empty() {
            continue;
        }
        let (tcfg, label) = if spec == "legacy" {
            (TransportConfig::default(), "legacy".to_string())
        } else {
            // parse() owns direction validation (delta is downlink-only,
            // downlink topk needs a delta base, ...)
            let down = (down_spec != "legacy").then_some(down_spec.as_str());
            (TransportConfig::parse(Some(spec), down)?, spec.to_string())
        };
        let mut sopts = ServerOptions {
            transport: tcfg,
            ..opts.server_options()
        };
        sopts.telemetry = Some(crate::telemetry::RunWriter::create_overwrite(
            &opts.out_root,
            &format!("comm-{label}"),
        )?);
        let res = federated::run(engine, &fed, &cfg, sopts)?;

        let rounds = res.rounds_run.max(1);
        let up_pr = res.comm.bytes_up as f64 / rounds as f64;
        let down_pr = res.comm.bytes_down as f64 / rounds as f64;
        let per_round = up_pr + down_pr;
        let reduction = match baseline_per_round {
            None => {
                baseline_per_round = Some(per_round);
                1.0
            }
            Some(base) => base / per_round.max(1.0),
        };
        let rtt = opts
            .target
            .and_then(|t| res.accuracy.rounds_to_target(t))
            .map(|r| format!("{r:.0}"))
            .unwrap_or_else(|| "-".into());
        rows.push(vec![
            label,
            format!("{:.1}", up_pr / 1e3),
            format!("{:.1}", down_pr / 1e3),
            format!("{reduction:.1}x"),
            rtt,
            format!("{:.4}", res.final_accuracy()),
            format!("{:.4}", res.comm.gigabytes()),
        ]);
    }
    print_table(
        &format!(
            "Communication — codec sweep on {} (target {}, scale {})",
            model,
            opts.target.map(|t| format!("{:.0}%", t * 100.0)).unwrap_or_else(|| "none".into()),
            opts.scale
        ),
        &["codec", "up KB/rd", "down KB/rd", "reduction", "rds-to-target", "final acc", "total GB"],
        &rows,
    );
    println!(
        "(uplink codec per row; downlink {} for all non-legacy rows — \
         per-round details in {}/comm-*/curve.csv)",
        down_spec, opts.out_root
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_specs_all_parse_as_uplinks() {
        for spec in DEFAULT_CODECS.split(',') {
            if spec == "legacy" {
                continue;
            }
            // the same validation path run() uses, default downlink
            let t = TransportConfig::parse(Some(spec), Some("delta")).unwrap();
            assert!(t.active(), "{spec}");
        }
        // delta stays downlink-only
        assert!(TransportConfig::parse(Some("delta"), None).is_err());
    }
}
