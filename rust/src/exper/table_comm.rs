//! `fedavg comm` — the communication-efficiency sweep: codec pipelines ×
//! rounds-to-target-accuracy × wire bytes per round.
//!
//! This reproduces the paper's headline framing from the communication
//! side: FedAvg already buys a 10–100× reduction in *rounds*; the codec
//! pipelines (footnote 7's compressed-updates direction, Konečný et al.)
//! multiply in a per-round byte reduction on top — sparsified/quantized
//! uplinks and delta downlinks — while the table tracks what that costs
//! in rounds to the accuracy target. Every row is a grid cell running
//! the same federated workload with a different [`TransportConfig`];
//! bytes come from the transport's single metering path, so the table's
//! numbers equal the telemetry CSVs under `runs/cells/`.

use crate::comms::transport::TransportConfig;
use crate::comms::wire::registry_help;
use crate::config::{BatchSize, FedConfig, Partition};
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::cells::{FedCell, GridCell, Workload};
use super::grid::{self, GridDef};
use super::{print_table, ExpOptions, COMMON_FLAGS};

/// Default codec sweep: the legacy dense baseline, framed dense, then
/// increasingly aggressive uplink pipelines.
pub const DEFAULT_CODECS: &str = "legacy,dense,q8,topk:0.05,topk:0.01|q8";

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(
        &[COMMON_FLAGS, &["model", "codecs", "down", "c", "e", "b", "partition"]].concat(),
    )?;
    let opts = ExpOptions::from_args(args)?;
    let model = args.str_or("model", "mnist_2nn");
    let codecs = args.str_or("codecs", DEFAULT_CODECS);
    let down_spec = args.str_or("down", "delta");
    let part = Partition::parse(&args.str_or("partition", "iid"))?;

    let workload = match model.as_str() {
        "mnist_2nn" | "mnist_cnn" => Workload::Mnist {
            scale: opts.scale,
            part,
            seed: opts.seed,
        },
        "shakespeare_lstm" => Workload::Shakespeare {
            scale: opts.scale,
            natural: part == Partition::Natural,
            seed: opts.seed,
        },
        other => anyhow::bail!(
            "comm: unsupported model {other} (mnist_2nn|mnist_cnn|shakespeare_lstm)"
        ),
    };
    let cfg = FedConfig {
        model: model.clone(),
        c: args.f64_or("c", 0.1)?,
        e: args.usize_or("e", 5)?,
        b: BatchSize::parse(&args.str_or("b", "10"))?,
        lr: args.f64_or("lr", 0.1)?,
        rounds: opts.rounds,
        target_accuracy: opts.target,
        seed: opts.seed,
        ..Default::default()
    };

    // parse every codec spec up front (a bad --codecs entry fails before
    // any training), preserving row order for the table
    let mut labels: Vec<String> = Vec::new();
    let mut def = GridDef::new("comm");
    for spec in codecs.split(',') {
        let spec = spec.trim();
        if spec.is_empty() {
            continue;
        }
        let (tcfg, label) = if spec == "legacy" {
            (TransportConfig::default(), "legacy".to_string())
        } else {
            // parse() owns direction validation (delta is downlink-only,
            // downlink topk needs a delta base, ...)
            let down = (down_spec != "legacy").then_some(down_spec.as_str());
            (TransportConfig::parse(Some(spec), down)?, spec.to_string())
        };
        let mut cell = FedCell::new(workload.clone(), cfg.clone(), opts.eval_cap);
        cell.transport = tcfg;
        def.cell(format!("comm-{label}"), GridCell::Fed(cell));
        labels.push(label);
    }
    println!(
        "comm sweep: {} ({} rows), downlink codec {:?}, codecs: {}\nregistry stages:\n{}",
        cfg.label(),
        labels.len(),
        down_spec,
        codecs,
        registry_help(),
    );
    let Some(report) = grid::run(def, Some(engine), &opts.grid_options())? else {
        return Ok(()); // --dry-run
    };

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut baseline_per_round: Option<f64> = None;
    for (label, out) in labels.into_iter().zip(&report.outcomes) {
        let rounds = out.int("rounds_run").unwrap_or(0).max(1);
        let up_pr = out.num("bytes_up").unwrap_or(0.0) / rounds as f64;
        let down_pr = out.num("bytes_down").unwrap_or(0.0) / rounds as f64;
        let per_round = up_pr + down_pr;
        let reduction = match baseline_per_round {
            None => {
                baseline_per_round = Some(per_round);
                1.0
            }
            Some(base) => base / per_round.max(1.0),
        };
        let rtt = out
            .num("rtt")
            .map(|r| format!("{r:.0}"))
            .unwrap_or_else(|| "-".into());
        // sum in u64 first — matches CommTotals::gigabytes bit-for-bit
        let gigabytes = (out.int("bytes_up").unwrap_or(0) + out.int("bytes_down").unwrap_or(0))
            as f64
            / 1e9;
        rows.push(vec![
            label,
            format!("{:.1}", up_pr / 1e3),
            format!("{:.1}", down_pr / 1e3),
            format!("{reduction:.1}x"),
            rtt,
            format!("{:.4}", out.num("final_acc").unwrap_or(0.0)),
            format!("{gigabytes:.4}"),
        ]);
    }
    print_table(
        &format!(
            "Communication — codec sweep on {} (target {}, scale {})",
            model,
            opts.target.map(|t| format!("{:.0}%", t * 100.0)).unwrap_or_else(|| "none".into()),
            opts.scale
        ),
        &["codec", "up KB/rd", "down KB/rd", "reduction", "rds-to-target", "final acc", "total GB"],
        &rows,
    );
    println!(
        "(uplink codec per row; downlink {} for all non-legacy rows — \
         per-round details in {}/cells/<fingerprint>/curve.csv, rows mapped \
         by {}/grid-comm/manifest.json)",
        down_spec, opts.out_root, opts.out_root
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_specs_all_parse_as_uplinks() {
        for spec in DEFAULT_CODECS.split(',') {
            if spec == "legacy" {
                continue;
            }
            // the same validation path run() uses, default downlink
            let t = TransportConfig::parse(Some(spec), Some("delta")).unwrap();
            assert!(t.active(), "{spec}");
        }
        // delta stays downlink-only
        assert!(TransportConfig::parse(Some("delta"), None).is_err());
    }
}
