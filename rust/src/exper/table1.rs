//! Table 1 — effect of the client fraction `C`.
//!
//! Paper: rounds to reach a target test accuracy for the MNIST 2NN (E=1)
//! and CNN (E=5), sweeping C ∈ {0, 0.1, 0.2, 0.5, 1.0} with B ∈ {∞, 10},
//! on the IID and pathological non-IID partitions; speedups are relative
//! to the C=0 row.
//!
//! A grid declaration (DESIGN.md §9): one [`FedCell`] per
//! (model, C, partition, B), executed by the grid engine, then formatted
//! from the outcome rows in declaration order.

use crate::config::{BatchSize, FedConfig, Partition};
use crate::metrics::format_cell;
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::cells::{FedCell, GridCell, Workload};
use super::grid::{self, GridDef};
use super::{print_table, ExpOptions, COMMON_FLAGS};

const CS: [f64; 5] = [0.0, 0.1, 0.2, 0.5, 1.0];

/// Default scaled-down targets (the paper's 97%/99% assume real MNIST;
/// the synthetic task reaches lower ceilings at these round budgets —
/// shape, not absolute accuracy, is the reproduction target).
fn default_targets(model: &str) -> (f64, f64) {
    match model {
        "mnist_2nn" => (0.80, 0.55),
        _ => (0.85, 0.60),
    }
}

/// Per-model table parameters resolved once, shared by the declaration
/// and formatting passes (both iterate the identical cell order).
struct ModelPlan {
    model: String,
    e: usize,
    t_iid: f64,
    t_non: f64,
    lr: f64,
}

fn plans(args: &Args, opts: &ExpOptions) -> Result<Vec<ModelPlan>> {
    let models = args.str_or("models", "mnist_2nn,mnist_cnn");
    models
        .split(',')
        .map(|model| {
            let e = if model == "mnist_2nn" { 1 } else { 5 };
            let (t_iid, t_non) = default_targets(model);
            Ok(ModelPlan {
                model: model.to_string(),
                e,
                t_iid: opts.target.unwrap_or(t_iid),
                t_non: args.f64_or("target-noniid", t_non)?,
                lr: args.f64_or("lr", 0.1)?,
            })
        })
        .collect()
}

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(&[COMMON_FLAGS, &["models", "bs", "target-noniid"]].concat())?;
    let opts = ExpOptions::from_args(args)?;
    let plans = plans(args, &opts)?;
    let bs = args.str_or("bs", "inf,10");
    let batches: Vec<BatchSize> = bs.split(',').map(BatchSize::parse).collect::<Result<_>>()?;

    let mut def = GridDef::new("table1");
    for plan in &plans {
        for &c in &CS {
            for (part, target) in [
                (Partition::Iid, plan.t_iid),
                (Partition::Pathological(2), plan.t_non),
            ] {
                for &b in &batches {
                    let cfg = FedConfig {
                        model: plan.model.clone(),
                        c,
                        e: plan.e,
                        b,
                        lr: plan.lr,
                        rounds: opts.rounds,
                        target_accuracy: Some(target),
                        seed: opts.seed,
                        ..Default::default()
                    };
                    let name = format!(
                        "table1-{}-{}-B{}-C{c}",
                        plan.model,
                        part.label(),
                        b.label()
                    );
                    def.cell(
                        name,
                        GridCell::Fed(FedCell::new(
                            Workload::Mnist {
                                scale: opts.scale,
                                part,
                                seed: opts.seed,
                            },
                            cfg,
                            opts.eval_cap,
                        )),
                    );
                }
            }
        }
    }
    let Some(report) = grid::run(def, Some(engine), &opts.grid_options())? else {
        return Ok(()); // --dry-run
    };

    let mut it = report.outcomes.iter();
    for plan in &plans {
        let mut rows = Vec::new();
        for c in &CS {
            let mut row_cells = vec![format!("{c:.1}")];
            for _part in 0..2 {
                for _b in &batches {
                    let out = it.next().expect("outcome per declared cell");
                    row_cells.push(format!(
                        "{} [acc {:.3}]",
                        out.num("rtt")
                            .map(|r| format!("{:.0}", r.ceil()))
                            .unwrap_or_else(|| "—".into()),
                        out.num("final_acc").unwrap_or(0.0)
                    ));
                }
            }
            rows.push(row_cells);
        }
        // add speedups vs C=0 per column
        annotate_speedups(&mut rows);
        let mut header = vec!["C"];
        for part in ["IID", "Non-IID"] {
            for b in bs.split(',') {
                header.push(Box::leak(format!("{part} B={b}").into_boxed_str()));
            }
        }
        print_table(
            &format!(
                "Table 1 — {} (E={}), targets {:.0}%/{:.0}% (IID/non-IID), scale {}",
                plan.model,
                plan.e,
                plan.t_iid * 100.0,
                plan.t_non * 100.0,
                opts.scale
            ),
            &header,
            &rows,
        );
    }
    Ok(())
}

/// Rewrite cells to `rounds (speedup×)` against the C=0 row of each column.
fn annotate_speedups(rows: &mut [Vec<String>]) {
    if rows.is_empty() {
        return;
    }
    let cols = rows[0].len();
    for col in 1..cols {
        let base: Option<f64> = parse_rounds(&rows[0][col]);
        for row in rows.iter_mut() {
            let r = parse_rounds(&row[col]);
            let acc = row[col]
                .split("[acc ")
                .nth(1)
                .unwrap_or("?]")
                .trim_end_matches(']')
                .to_string();
            row[col] = format!("{} acc={}", format_cell(r, base), acc);
        }
    }
}

fn parse_rounds(cell: &str) -> Option<f64> {
    cell.split_whitespace().next()?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_annotation() {
        let mut rows = vec![
            vec!["0.0".into(), "100 [acc 0.950]".into()],
            vec!["0.1".into(), "25 [acc 0.960]".into()],
            vec!["1.0".into(), "— [acc 0.700]".into()],
        ];
        annotate_speedups(&mut rows);
        assert!(rows[1][1].starts_with("25 (4.0x)"), "{}", rows[1][1]);
        assert!(rows[2][1].starts_with("— (—)"), "{}", rows[2][1]);
        assert!(rows[0][1].contains("acc=0.950"));
    }
}
