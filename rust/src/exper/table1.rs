//! Table 1 — effect of the client fraction `C`.
//!
//! Paper: rounds to reach a target test accuracy for the MNIST 2NN (E=1)
//! and CNN (E=5), sweeping C ∈ {0, 0.1, 0.2, 0.5, 1.0} with B ∈ {∞, 10},
//! on the IID and pathological non-IID partitions; speedups are relative
//! to the C=0 row.

use crate::config::{BatchSize, FedConfig, Partition};
use crate::metrics::format_cell;
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::{mnist_fed, print_table, run_one, ExpOptions, COMMON_FLAGS};

const CS: [f64; 5] = [0.0, 0.1, 0.2, 0.5, 1.0];

/// Default scaled-down targets (the paper's 97%/99% assume real MNIST;
/// the synthetic task reaches lower ceilings at these round budgets —
/// shape, not absolute accuracy, is the reproduction target).
fn default_targets(model: &str) -> (f64, f64) {
    match model {
        "mnist_2nn" => (0.80, 0.55),
        _ => (0.85, 0.60),
    }
}

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(&[COMMON_FLAGS, &["models", "bs", "target-noniid"]].concat())?;
    let opts = ExpOptions::from_args(args)?;
    let models = args.str_or("models", "mnist_2nn,mnist_cnn");
    let bs = args.str_or("bs", "inf,10");
    let batches: Vec<BatchSize> = bs
        .split(',')
        .map(BatchSize::parse)
        .collect::<Result<_>>()?;

    for model in models.split(',') {
        let e = if model == "mnist_2nn" { 1 } else { 5 };
        let (t_iid, t_non) = default_targets(model);
        let t_iid = opts.target.unwrap_or(t_iid);
        let t_non = args.f64_or("target-noniid", t_non)?;
        let lr = args.f64_or("lr", 0.1)?;
        let mut rows = Vec::new();
        for &c in &CS {
            let mut cells = vec![format!("{c:.1}")];
            for (part, target) in [
                (Partition::Iid, t_iid),
                (Partition::Pathological(2), t_non),
            ] {
                let fed = mnist_fed(opts.scale, part, opts.seed);
                for &b in &batches {
                    let cfg = FedConfig {
                        model: model.to_string(),
                        c,
                        e,
                        b,
                        lr,
                        rounds: opts.rounds,
                        target_accuracy: Some(target),
                        seed: opts.seed,
                        ..Default::default()
                    };
                    let name = format!(
                        "table1-{model}-{}-B{}-C{c}",
                        part.label(),
                        b.label()
                    );
                    let (res, rtt) = run_one(engine, &fed, &cfg, &opts, &name)?;
                    // baseline = this column's C=0 row
                    cells.push(format!(
                        "{} [acc {:.3}]",
                        rtt.map(|r| format!("{:.0}", r.ceil()))
                            .unwrap_or_else(|| "—".into()),
                        res.final_accuracy()
                    ));
                }
            }
            rows.push(cells);
        }
        // add speedups vs C=0 per column
        annotate_speedups(&mut rows);
        let mut header = vec!["C"];
        for part in ["IID", "Non-IID"] {
            for b in bs.split(',') {
                header.push(Box::leak(format!("{part} B={b}").into_boxed_str()));
            }
        }
        print_table(
            &format!(
                "Table 1 — {model} (E={e}), targets {:.0}%/{:.0}% (IID/non-IID), scale {}",
                t_iid * 100.0, t_non * 100.0, opts.scale
            ),
            &header,
            &rows,
        );
    }
    Ok(())
}

/// Rewrite cells to `rounds (speedup×)` against the C=0 row of each column.
fn annotate_speedups(rows: &mut [Vec<String>]) {
    if rows.is_empty() {
        return;
    }
    let cols = rows[0].len();
    for col in 1..cols {
        let base: Option<f64> = parse_rounds(&rows[0][col]);
        for row in rows.iter_mut() {
            let r = parse_rounds(&row[col]);
            let acc = row[col]
                .split("[acc ")
                .nth(1)
                .unwrap_or("?]")
                .trim_end_matches(']')
                .to_string();
            row[col] = format!("{} acc={}", format_cell(r, base), acc);
        }
    }
}

fn parse_rounds(cell: &str) -> Option<f64> {
    cell.split_whitespace().next()?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_annotation() {
        let mut rows = vec![
            vec!["0.0".into(), "100 [acc 0.950]".into()],
            vec!["0.1".into(), "25 [acc 0.960]".into()],
            vec!["1.0".into(), "— [acc 0.700]".into()],
        ];
        annotate_speedups(&mut rows);
        assert!(rows[1][1].starts_with("25 (4.0x)"), "{}", rows[1][1]);
        assert!(rows[2][1].starts_with("— (—)"), "{}", rows[2][1]);
        assert!(rows[0][1].contains("acc=0.950"));
    }
}
