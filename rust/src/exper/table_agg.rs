//! `fedavg agg` — the aggregation-rule sweep: server optimizers +
//! robust aggregators × IID vs pathological non-IID partitions × a
//! configurable fraction of label-corrupted clients.
//!
//! The scenario complement to [`super::table_comm`]: where the codec
//! sweep varies *what crosses the wire*, this sweep varies *what the
//! server does with it* (DESIGN.md §7). Each row is a grid cell running
//! the same federated workload with a different `--agg` registry rule;
//! with `--corrupt F`, `⌊F·K⌋` clients flip every label
//! ([`crate::data::corrupt_clients`]) — the regime where plain FedAvg
//! degrades and the coordinate-wise trimmed mean / median hold, while on
//! clean partitions the server optimizers (FedAvgM, FedAdam) chase
//! fewer rounds-to-target per communication round.

use crate::config::{BatchSize, FedConfig, Partition};
use crate::federated::aggregate::{registry_help, AggConfig};
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::cells::{FedCell, GridCell, Workload};
use super::grid::{self, GridDef};
use super::{print_table, ExpOptions, COMMON_FLAGS};

/// Default rule sweep: the paper's baseline, both server optimizers,
/// then the robust order statistics. The trim fraction must exceed the
/// corrupted-client fraction to actually shield the mean — and at the
/// sweep's default cohort (`m = 4`) the realized trim count is
/// `⌊β·m⌋`, so `β` must also clear `1/m` before anything is trimmed at
/// all; `trimmed:0.3` trims one client per tail there, covering the
/// default `--corrupt 0.2`.
pub const DEFAULT_AGGS: &str = "fedavg,fedavgm,fedadam,trimmed:0.3,median";

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(
        &[
            COMMON_FLAGS,
            &[
                "model", "aggs", "partitions", "corrupt", "c", "e", "b",
                "server-lr", "server-momentum", "prox-mu",
            ],
        ]
        .concat(),
    )?;
    let opts = ExpOptions::from_args(args)?;
    let model = args.str_or("model", "mnist_2nn");
    anyhow::ensure!(
        matches!(model.as_str(), "mnist_2nn" | "mnist_cnn"),
        "agg: label corruption needs a labeled image workload (mnist_2nn|mnist_cnn), got {model}"
    );
    let aggs = args.str_or("aggs", DEFAULT_AGGS);
    let corrupt = args.f64_or("corrupt", 0.2)?;
    anyhow::ensure!(
        (0.0..1.0).contains(&corrupt),
        "--corrupt must be a client fraction in [0, 1), got {corrupt}"
    );
    let parts: Vec<Partition> = args
        .str_or("partitions", "iid,noniid")
        .split(',')
        .map(Partition::parse)
        .collect::<Result<_>>()?;
    anyhow::ensure!(
        !parts.iter().any(|p| *p == Partition::Natural),
        "agg: mnist has no natural partition (iid|noniid|unbalanced)"
    );

    let base_agg = AggConfig {
        // unset η_s resolves per rule inside AggConfig (1.0; 0.01 for
        // fedadam, whose Adam-normalized step diverges at η_s = 1)
        server_lr: args.f64_opt("server-lr")?,
        server_momentum: args.f64_or("server-momentum", 0.9)?,
        prox_mu: args.f64_or("prox-mu", 0.0)?,
        ..Default::default()
    };
    let rule_cfg = |spec: &str| AggConfig {
        spec: spec.to_string(),
        ..base_agg.clone()
    };
    // resolve every spec up front so a bad --aggs entry fails before any
    // training happens
    let specs: Vec<&str> = aggs
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!specs.is_empty(), "--aggs lists no rules");
    for spec in &specs {
        rule_cfg(spec).validate()?;
    }
    let cfg = FedConfig {
        model: model.clone(),
        c: args.f64_or("c", 0.2)?,
        e: args.usize_or("e", 5)?,
        b: BatchSize::parse(&args.str_or("b", "10"))?,
        lr: args.f64_or("lr", 0.1)?,
        rounds: opts.rounds,
        target_accuracy: opts.target,
        seed: opts.seed,
        ..Default::default()
    };
    println!(
        "agg sweep: {} — {:.0}% of clients label-corrupted, rules: {}\nregistry rules:\n{}",
        cfg.label(),
        corrupt * 100.0,
        aggs,
        registry_help(),
    );

    let mut def = GridDef::new("agg");
    for part in &parts {
        for spec in &specs {
            let mut cell = FedCell::new(
                Workload::Mnist {
                    scale: opts.scale,
                    part: *part,
                    seed: opts.seed,
                },
                cfg.clone(),
                opts.eval_cap,
            );
            cell.agg = rule_cfg(spec);
            cell.corrupt = corrupt;
            def.cell(format!("agg-{}-{spec}", part.label()), GridCell::Fed(cell));
        }
    }
    let Some(report) = grid::run(def, Some(engine), &opts.grid_options())? else {
        return Ok(()); // --dry-run
    };

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut it = report.outcomes.iter();
    for part in &parts {
        for spec in &specs {
            let out = it.next().expect("outcome per declared cell");
            let rtt = out
                .num("rtt")
                .map(|r| format!("{r:.0}"))
                .unwrap_or_else(|| "-".into());
            rows.push(vec![
                spec.to_string(),
                part.label().to_string(),
                format!(
                    "{}/{}",
                    out.int("corrupted").unwrap_or(0),
                    out.int("clients_total").unwrap_or(0)
                ),
                rtt,
                format!("{:.4}", out.num("final_acc").unwrap_or(0.0)),
                format!("{:.4}", out.num("best_acc").unwrap_or(0.0)),
            ]);
        }
    }
    print_table(
        &format!(
            "Aggregation — rule sweep on {} (target {}, scale {})",
            model,
            opts.target
                .map(|t| format!("{:.0}%", t * 100.0))
                .unwrap_or_else(|| "none".into()),
            opts.scale
        ),
        &["agg", "partition", "corrupted", "rds-to-target", "final acc", "best acc"],
        &rows,
    );
    println!(
        "(rules resolved by the federated::aggregate registry; per-round \
         agg/server_state in {}/cells/<fingerprint>/curve.csv — the manifest \
         under {}/grid-agg/ maps rows to cells)",
        opts.out_root, opts.out_root
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_specs_all_resolve() {
        for spec in DEFAULT_AGGS.split(',') {
            let cfg = AggConfig {
                spec: spec.into(),
                ..Default::default()
            };
            assert!(cfg.validate().is_ok(), "{spec}");
        }
    }
}
