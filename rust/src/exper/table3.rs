//! Table 3 — CIFAR: rounds (minibatch updates for the SGD baseline) to
//! reach target accuracies, for SGD / FedSGD / FedAvg(E=5, B=50), C=0.1,
//! with tuned lr decay (paper: FedSGD 0.9934, FedAvg 0.99 per round).
//!
//! Three grid cells — an [`SgdCell`] baseline plus two [`FedCell`]s —
//! formatted against each target from the recorded accuracy curves.

use crate::baselines::sgd::SgdConfig;
use crate::config::{BatchSize, FedConfig};
use crate::metrics::format_cell;
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::cells::{FedCell, GridCell, SgdCell, Workload};
use super::grid::{self, GridDef};
use super::{print_table, ExpOptions, COMMON_FLAGS};

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(&[COMMON_FLAGS, &["targets", "sgd-updates"]].concat())?;
    let opts = ExpOptions::from_args(args)?;
    // paper targets 80/82/85%; scaled synthetic defaults lower
    let targets_s = args.str_or("targets", "0.5,0.6,0.7");
    let targets: Vec<f64> = targets_s
        .split(',')
        .map(|t| t.parse::<f64>())
        .collect::<std::result::Result<_, _>>()?;
    let lr = args.f64_or("lr", 0.1)?;
    let max_target = targets.iter().cloned().fold(0.0, f64::max);
    let workload = Workload::Cifar {
        scale: opts.scale,
        seed: opts.seed,
    };

    let sgd_updates = args.usize_or("sgd-updates", opts.rounds * 10)?;
    let mut def = GridDef::new("table3");
    // --- sequential SGD baseline (each update = one "round")
    def.cell(
        "table3-sgd",
        GridCell::Sgd(SgdCell {
            workload: workload.clone(),
            cfg: SgdConfig {
                model: "cifar_cnn".into(),
                batch: 100,
                lr,
                lr_decay: 0.9995,
                updates: sgd_updates,
                eval_every: (sgd_updates / 40).max(1),
                target_accuracy: Some(max_target),
                seed: opts.seed,
            },
            eval_cap: opts.eval_cap,
        }),
    );
    // --- FedSGD (lr decay per round, paper 0.9934)
    let fedsgd_cfg = FedConfig {
        model: "cifar_cnn".into(),
        c: 0.1,
        lr,
        lr_decay: 0.9934,
        rounds: opts.rounds,
        target_accuracy: Some(max_target),
        seed: opts.seed,
        ..Default::default()
    }
    .fedsgd();
    def.cell(
        "table3-fedsgd",
        GridCell::Fed(FedCell::new(workload.clone(), fedsgd_cfg, opts.eval_cap)),
    );
    // --- FedAvg (E=5, B=50, decay 0.99)
    let fedavg_cfg = FedConfig {
        model: "cifar_cnn".into(),
        c: 0.1,
        e: 5,
        b: BatchSize::Fixed(50),
        lr,
        lr_decay: 0.99,
        rounds: opts.rounds,
        target_accuracy: Some(max_target),
        seed: opts.seed,
        ..Default::default()
    };
    def.cell(
        "table3-fedavg",
        GridCell::Fed(FedCell::new(workload, fedavg_cfg, opts.eval_cap)),
    );

    let Some(report) = grid::run(def, Some(engine), &opts.grid_options())? else {
        return Ok(()); // --dry-run
    };
    let [sgd_out, fedsgd_out, fedavg_out] = &report.outcomes[..] else {
        anyhow::bail!("table3: expected 3 outcomes");
    };

    let sgd_curve = sgd_out.learning_curve("accuracy")?;
    let curves = [
        ("SGD", sgd_curve.clone()),
        ("FedSGD", fedsgd_out.learning_curve("accuracy")?),
        ("FedAvg", fedavg_out.learning_curve("accuracy")?),
    ];
    let mut rows = Vec::new();
    for (name, curve) in &curves {
        let mut row = vec![name.to_string()];
        for &t in &targets {
            let rtt = curve.rounds_to_target(t);
            let base = sgd_curve.rounds_to_target(t);
            row.push(format_cell(rtt, base));
        }
        rows.push(row);
    }
    let header: Vec<&str> = std::iter::once("Acc.").chain(targets_s.split(',')).collect();
    print_table(
        &format!(
            "Table 3 — CIFAR rounds to target (scale {}, SGD B=100, FedAvg E=5 B=50 C=0.1)",
            opts.scale
        ),
        &header,
        &rows,
    );
    println!(
        "final acc — SGD {:.3} ({} updates), FedSGD {:.3}, FedAvg {:.3}",
        sgd_out.num("best_acc").unwrap_or(0.0),
        sgd_out.int("updates_run").unwrap_or(0),
        fedsgd_out.num("best_acc").unwrap_or(0.0),
        fedavg_out.num("best_acc").unwrap_or(0.0),
    );
    Ok(())
}
