//! Table 3 — CIFAR: rounds (minibatch updates for the SGD baseline) to
//! reach target accuracies, for SGD / FedSGD / FedAvg(E=5, B=50), C=0.1,
//! with tuned lr decay (paper: FedSGD 0.9934, FedAvg 0.99 per round).

use crate::baselines::sgd::{self, SgdConfig};
use crate::config::{BatchSize, FedConfig};
use crate::metrics::format_cell;
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::{cifar_fed, print_table, run_one, ExpOptions, COMMON_FLAGS};

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(&[COMMON_FLAGS, &["targets", "sgd-updates"]].concat())?;
    let opts = ExpOptions::from_args(args)?;
    // paper targets 80/82/85%; scaled synthetic defaults lower
    let targets_s = args.str_or("targets", "0.5,0.6,0.7");
    let targets: Vec<f64> = targets_s
        .split(',')
        .map(|t| t.parse::<f64>())
        .collect::<std::result::Result<_, _>>()?;
    let lr = args.f64_or("lr", 0.1)?;
    let fed = cifar_fed(opts.scale, opts.seed);
    let max_target = targets.iter().cloned().fold(0.0, f64::max);

    // --- sequential SGD baseline (each update = one "round")
    let sgd_updates = args.usize_or("sgd-updates", opts.rounds * 10)?;
    let sgd_cfg = SgdConfig {
        model: "cifar_cnn".into(),
        batch: 100,
        lr,
        lr_decay: 0.9995,
        updates: sgd_updates,
        eval_every: (sgd_updates / 40).max(1),
        target_accuracy: Some(max_target),
        seed: opts.seed,
    };
    let sgd_res = sgd::run(
        engine,
        &fed.train,
        &fed.test,
        &sgd_cfg,
        Some(opts.eval_cap),
    )?;

    // --- FedSGD (lr decay per round, paper 0.9934)
    let fedsgd_cfg = FedConfig {
        model: "cifar_cnn".into(),
        c: 0.1,
        lr,
        lr_decay: 0.9934,
        rounds: opts.rounds,
        target_accuracy: Some(max_target),
        seed: opts.seed,
        ..Default::default()
    }
    .fedsgd();
    let (fedsgd_res, _) = run_one(engine, &fed, &fedsgd_cfg, &opts, "table3-fedsgd")?;

    // --- FedAvg (E=5, B=50, decay 0.99)
    let fedavg_cfg = FedConfig {
        model: "cifar_cnn".into(),
        c: 0.1,
        e: 5,
        b: BatchSize::Fixed(50),
        lr,
        lr_decay: 0.99,
        rounds: opts.rounds,
        target_accuracy: Some(max_target),
        seed: opts.seed,
        ..Default::default()
    };
    let (fedavg_res, _) = run_one(engine, &fed, &fedavg_cfg, &opts, "table3-fedavg")?;

    let mut rows = Vec::new();
    for (name, curve) in [
        ("SGD", &sgd_res.accuracy),
        ("FedSGD", &fedsgd_res.accuracy),
        ("FedAvg", &fedavg_res.accuracy),
    ] {
        let mut cells = vec![name.to_string()];
        for &t in &targets {
            let rtt = curve.rounds_to_target(t);
            let base = sgd_res.accuracy.rounds_to_target(t);
            cells.push(format_cell(rtt, base));
        }
        rows.push(cells);
    }
    let header: Vec<&str> = std::iter::once("Acc.")
        .chain(targets_s.split(','))
        .collect();
    print_table(
        &format!(
            "Table 3 — CIFAR rounds to target (scale {}, SGD B=100, FedAvg E=5 B=50 C=0.1)",
            opts.scale
        ),
        &header,
        &rows,
    );
    println!(
        "final acc — SGD {:.3} ({} updates), FedSGD {:.3}, FedAvg {:.3}",
        sgd_res.accuracy.best_value().unwrap_or(0.0),
        sgd_res.updates_run,
        fedsgd_res.accuracy.best_value().unwrap_or(0.0),
        fedavg_res.accuracy.best_value().unwrap_or(0.0),
    );
    Ok(())
}
