//! The grid engine — restartable, parallel experiment sweeps
//! (DESIGN.md §9).
//!
//! The paper's evaluation is a wall of grids: Tables 1–4 and the figure
//! sweeps each vary (E, B, C, partition, model) and report
//! rounds-to-target. Every `fedavg` sweep driver (`table1`–`table4`,
//! `agg`, `comm`, `figure`, `sweep`) declares its grid as data and hands
//! execution to this engine, which makes the multi-hour grids
//!
//! * **crash-safe** — a JSON *manifest* under
//!   `<out>/grid-<name>/manifest.json` tracks per-cell status
//!   (pending/running/done + the summary row), rewritten atomically
//!   ([`runstate::atomic_write`](crate::runstate::atomic_write)) after
//!   every cell completion. Rerunning the same command skips done cells
//!   and resumes in-flight ones from their per-cell checkpoints; the
//!   finished tables and every cell's `curve.csv` are byte-identical to
//!   an uninterrupted run (regression-tested in
//!   `rust/tests/grid_resume.rs`);
//! * **parallel** — `--workers N` executes cells over a pool of threads,
//!   each owning its own PJRT [`Engine`] (engines are not `Send`; the
//!   same per-thread-engine topology as
//!   [`coordinator::exec`](crate::coordinator::exec)). `--workers 1`
//!   runs cells inline on the caller's engine, in declaration order —
//!   exactly the pre-grid serial drivers;
//! * **deduplicated** — a cell is a named, *fingerprinted* run
//!   config: [`fnv1a64`] over the work's canonical spec string. Cell
//!   run dirs live in a pool shared by all grids
//!   (`<out>/cells/<fingerprint>/`), so identical cells across grids —
//!   or within one — run once and are reused as cache hits.
//!
//! The resume protocol, in order of authority: a cell dir's `cell.json`
//! (written atomically after the cell finishes, carrying the spec,
//! fingerprint, summary, and result curves) marks a cell **done** — any
//! grid that declares the same spec reuses it, and a record whose
//! spec/fingerprint disagrees with the declaration is *refused*, never
//! silently reused. A cell without a done record but with run-state
//! snapshots under its dir is **in-flight** and resumes through the
//! ordinary checkpoint machinery (DESIGN.md §8). Everything else runs
//! fresh. The manifest itself is fingerprinted over the declared cell
//! set, so a changed command refuses a stale manifest instead of mixing
//! two sweeps (`--overwrite` replaces the manifest; cached cells, keyed
//! by their own fingerprints, survive).
//!
//! Progress goes to **stderr**; stdout stays reserved for the drivers'
//! paper-formatted tables, which are assembled from the outcome rows
//! after the grid completes — so table output is independent of cell
//! completion order.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Context as _;

use crate::metrics::LearningCurve;
use crate::runstate::{atomic_write, fnv1a64, CheckpointConfig};
use crate::runtime::pool::WorkerPool;
use crate::runtime::Engine;
use crate::telemetry::sanitize_name;
use crate::util::json::{escape, Json};
use crate::Result;

/// One unit of grid work. Implementations are plain data (`Send`): with
/// `--workers N` cells execute on pool threads, each building its own
/// engine. The library ships [`GridCell`](super::cells::GridCell) (the
/// federated/SGD/interpolation cells behind every driver); tests and
/// examples implement their own engine-free cells.
pub trait CellWork: Send + Sync + 'static {
    /// Canonical config spec — the fingerprint input. Must cover every
    /// knob that affects the cell's outputs: two cells with equal specs
    /// are assumed interchangeable and share one run dir. (For
    /// engine-dependent cells the engine appends the artifacts identity
    /// itself, so a rebuilt model invalidates the cache.)
    fn spec(&self) -> String;

    /// Whether [`run`](Self::run) needs a PJRT engine (workload cells
    /// do; synthetic/test cells do not).
    fn needs_engine(&self) -> bool {
        true
    }

    /// Execute the cell: produce its artifacts under `ctx.dir` and
    /// return the outcome row. Called with an engine exactly when
    /// [`needs_engine`](Self::needs_engine) — on a worker thread the
    /// engine is the thread's own.
    fn run(&self, engine: Option<&Engine>, ctx: &CellCtx) -> Result<CellOutcome>;
}

/// Execution context handed to [`CellWork::run`].
#[derive(Debug, Clone)]
pub struct CellCtx {
    /// The cell's run dir (`<out>/cells/<fingerprint>/`) — telemetry,
    /// checkpoints, and the done record all land here.
    pub dir: PathBuf,
    /// Per-cell checkpoint cadence (`--checkpoint-every`), `None` = off.
    pub checkpoint: Option<CheckpointConfig>,
    /// Silence per-round console output (parallel grids interleave).
    pub quiet: bool,
    /// Span-trace this cell into `<dir>/trace.jsonl` (`--trace`,
    /// DESIGN.md §10). Deliberately NOT part of [`CellWork::spec`]:
    /// tracing cannot change a cell's outputs, so traced and untraced
    /// executions share a fingerprint (and cache slot).
    pub trace: bool,
}

/// A named result curve's points (x is a round/update index or an
/// interpolation coordinate; y the measured value).
pub type Series = Vec<(f64, f64)>;

/// What a finished cell reports: an ordered summary row (the table
/// material) plus named result curves (the figure material). Values are
/// round-trip formatted (`{}` on `f64` prints the shortest string that
/// parses back bit-exactly), so a reloaded outcome formats identically
/// to a fresh one — the grid's byte-identity guarantee leans on this.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CellOutcome {
    pub summary: Vec<(String, String)>,
    pub curves: Vec<(String, Series)>,
}

impl CellOutcome {
    pub fn put(&mut self, key: &str, value: impl std::fmt::Display) {
        self.summary.push((key.to_string(), value.to_string()));
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.summary
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Parse a summary value back to the exact `f64` it was formatted
    /// from (`None` when absent or empty — e.g. an unreached target).
    pub fn num(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn int(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn curve(&self, name: &str) -> Option<&[(f64, f64)]> {
        self.curves
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, pts)| pts.as_slice())
    }

    /// A round-keyed curve as a [`LearningCurve`] (x values are integral
    /// rounds/updates by construction).
    pub fn learning_curve(&self, name: &str) -> Result<LearningCurve> {
        let pts = self
            .curve(name)
            .ok_or_else(|| anyhow::anyhow!("outcome has no {name:?} curve"))?;
        LearningCurve::from_points(pts.iter().map(|&(x, y)| (x as u64, y)).collect())
    }
}

/// A declared grid: a name plus cells in declaration order. The order is
/// the contract formatters rely on — `GridReport::outcomes[i]` belongs
/// to the i-th declared cell regardless of execution order.
pub struct GridDef<W> {
    name: String,
    cells: Vec<(String, W)>,
}

impl<W: CellWork> GridDef<W> {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            cells: Vec::new(),
        }
    }

    /// Declare a cell. Names must be unique within the grid (checked at
    /// [`run`]); equal *specs* may repeat — aliases share one execution.
    pub fn cell(&mut self, name: impl Into<String>, work: W) {
        self.cells.push((name.into(), work));
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }
}

/// Engine knobs, parsed uniformly from every sweep subcommand
/// (`ExpOptions::grid_options`).
#[derive(Debug, Clone)]
pub struct GridOptions {
    /// Root under which `grid-<name>/` and the shared `cells/` pool live.
    pub out_root: String,
    /// Cell-execution threads (1 = inline on the caller's engine).
    pub workers: usize,
    /// Require an existing manifest (`--resume`); without it, a
    /// compatible manifest is continued automatically when present.
    pub resume: bool,
    /// Replace a manifest written by a *different* cell set
    /// (`--overwrite`). Cached cell results are never deleted — they are
    /// keyed by their own fingerprints.
    pub overwrite: bool,
    /// List the cells and their cached status without running anything.
    pub dry_run: bool,
    /// Per-cell run-state checkpoint cadence (DESIGN.md §8).
    pub checkpoint: Option<CheckpointConfig>,
    /// Span-trace executed cells into their cell dirs (DESIGN.md §10).
    pub trace: bool,
}

impl Default for GridOptions {
    fn default() -> Self {
        Self {
            out_root: "runs".into(),
            workers: 1,
            resume: false,
            overwrite: false,
            dry_run: false,
            checkpoint: None,
            trace: false,
        }
    }
}

/// A completed grid: outcomes in declaration order plus accounting.
pub struct GridReport {
    pub outcomes: Vec<CellOutcome>,
    /// Cells actually executed this invocation.
    pub executed: usize,
    /// Cells satisfied from done records (earlier runs, other grids) or
    /// in-grid aliases of an identical spec.
    pub cache_hits: usize,
    pub manifest_path: PathBuf,
}

/// Running totals over cached cells' recorded counters, for the
/// `--dry-run` "what did the cache save" line. Cells that never
/// recorded a counter (synthetic cells, non-fed grids) contribute zero.
#[derive(Default)]
struct CachedTally {
    cells: usize,
    rounds: u64,
    steps: u64,
    bytes: u64,
    sim_s: f64,
}

impl CachedTally {
    fn absorb(&mut self, out: &CellOutcome) {
        self.cells += 1;
        self.rounds += out.int("rounds_run").unwrap_or(0);
        self.steps += out.int("client_steps").unwrap_or(0);
        self.bytes += out.int("bytes_up").unwrap_or(0) + out.int("bytes_down").unwrap_or(0);
        self.sim_s += out.num("sim_seconds").unwrap_or(0.0);
    }
}

/// One-line view of a cached cell's recorded summary: the counters a
/// reader most wants first (accuracy, rounds-to-target, cost), falling
/// back to the first few recorded fields for cells that use other keys.
fn summary_brief(out: &CellOutcome) -> String {
    const PREFERRED: &[&str] = &[
        "final_acc",
        "best_acc",
        "rtt",
        "rounds_run",
        "client_steps",
        "sim_seconds",
        "bytes_up",
    ];
    let mut parts: Vec<String> = PREFERRED
        .iter()
        .filter_map(|k| {
            out.get(k)
                .filter(|v| !v.is_empty())
                .map(|v| format!("{k}={v}"))
        })
        .collect();
    if parts.is_empty() {
        parts = out
            .summary
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .take(4)
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
    }
    if parts.is_empty() {
        "(no summary recorded)".to_string()
    } else {
        parts.join("  ")
    }
}

/// A cell's identity: [`fnv1a64`] over its canonical spec.
pub fn cell_fingerprint(spec: &str) -> u64 {
    fnv1a64(spec.as_bytes())
}

/// The grid's identity: hash of its name and every declared cell's name
/// and fingerprint, in order. A changed command (different cells, rows,
/// flags) produces a different grid fingerprint and refuses a stale
/// manifest.
fn grid_fingerprint(name: &str, cells: &[(String, u64)]) -> u64 {
    let mut acc = String::new();
    acc.push_str(name);
    for (cell, fp) in cells {
        acc.push('\n');
        acc.push_str(cell);
        acc.push('\t');
        acc.push_str(&format!("{fp:016x}"));
    }
    fnv1a64(acc.as_bytes())
}

// --------------------------------------------------------------- records

const STATUS: [&str; 3] = ["pending", "running", "done"];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CellStatus {
    Pending,
    Running,
    Done,
}

impl CellStatus {
    fn label(self) -> &'static str {
        STATUS[self as usize]
    }
}

fn fmt_pairs(out: &mut String, pairs: &[(String, String)]) {
    out.push('[');
    for (i, (k, v)) in pairs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('[');
        out.push_str(&escape(k));
        out.push(',');
        out.push_str(&escape(v));
        out.push(']');
    }
    out.push(']');
}

/// One curve value as JSON. `{}` on f64 is shortest-round-trip, so
/// parsing the record back yields the exact value and resumed
/// formatting stays byte-identical. Non-finite values (a diverging
/// run's loss curve — exactly what Figures 3/8 study) are not valid
/// JSON numbers and go through strings (`"NaN"`, `"inf"`, `"-inf"`),
/// which `f64::from_str` round-trips.
fn fmt_curve_val(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push_str(&escape(&v.to_string()));
    }
}

fn parse_curve_val(j: &Json) -> Result<f64> {
    match j {
        Json::Str(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("bad curve value {s:?}")),
        _ => j.as_f64(),
    }
}

fn fmt_curves(out: &mut String, curves: &[(String, Series)]) {
    out.push('[');
    for (i, (name, pts)) in curves.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('[');
        out.push_str(&escape(name));
        out.push_str(",[");
        for (j, (x, y)) in pts.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push('[');
            fmt_curve_val(out, *x);
            out.push(',');
            fmt_curve_val(out, *y);
            out.push(']');
        }
        out.push_str("]]");
    }
    out.push(']');
}

fn parse_pairs(j: &Json) -> Result<Vec<(String, String)>> {
    j.as_arr()?
        .iter()
        .map(|pair| {
            let p = pair.as_arr()?;
            anyhow::ensure!(p.len() == 2, "summary pair with {} elements", p.len());
            Ok((p[0].as_str()?.to_string(), p[1].as_str()?.to_string()))
        })
        .collect()
}

fn parse_curves(j: &Json) -> Result<Vec<(String, Series)>> {
    j.as_arr()?
        .iter()
        .map(|c| {
            let c = c.as_arr()?;
            anyhow::ensure!(c.len() == 2, "curve entry with {} elements", c.len());
            let pts = c[1]
                .as_arr()?
                .iter()
                .map(|p| {
                    let p = p.as_arr()?;
                    anyhow::ensure!(p.len() == 2, "curve point with {} elements", p.len());
                    Ok((parse_curve_val(&p[0])?, parse_curve_val(&p[1])?))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok((c[0].as_str()?.to_string(), pts))
        })
        .collect()
}

/// Write a cell's done record (`cell.json`) atomically.
fn write_cell_record(
    dir: &Path,
    name: &str,
    fp: u64,
    spec: &str,
    outcome: &CellOutcome,
) -> Result<()> {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"name\": {},\n", escape(name)));
    out.push_str(&format!("  \"fingerprint\": \"{fp:016x}\",\n"));
    out.push_str(&format!("  \"spec\": {},\n", escape(spec)));
    out.push_str("  \"status\": \"done\",\n");
    out.push_str("  \"summary\": ");
    fmt_pairs(&mut out, &outcome.summary);
    out.push_str(",\n  \"curves\": ");
    fmt_curves(&mut out, &outcome.curves);
    out.push_str("\n}\n");
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
    atomic_write(&dir.join("cell.json"), out.as_bytes())
}

/// Load a cell dir's done record. `Ok(None)` when absent; an error when
/// a record exists but its fingerprint or spec disagrees with the
/// declared cell — a mismatched dir is refused, never silently reused.
/// Only a *missing* record maps to `Ok(None)`: any other read failure
/// (permissions on the shared pool, flaky filesystem) propagates rather
/// than silently re-executing — and overwriting — a dir that may hold a
/// valid result.
fn load_cell_record(dir: &Path, fp: u64, spec: &str) -> Result<Option<CellOutcome>> {
    let path = dir.join("cell.json");
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(anyhow::anyhow!("reading cell record {path:?}: {e}")),
    };
    let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
    let rec_fp = u64::from_str_radix(j.get("fingerprint")?.as_str()?, 16)
        .map_err(|_| anyhow::anyhow!("{path:?}: malformed fingerprint"))?;
    let rec_spec = j.get("spec")?.as_str()?;
    anyhow::ensure!(
        rec_fp == fp && rec_spec == spec,
        "refusing to reuse cell dir {dir:?}: its record was written by a \
         different configuration\n  recorded: {rec_spec}\n  declared: {spec}"
    );
    if j.get("status")?.as_str()? != "done" {
        return Ok(None);
    }
    Ok(Some(CellOutcome {
        summary: parse_pairs(j.get("summary")?)?,
        curves: parse_curves(j.get("curves")?)?,
    }))
}

struct ManifestRow {
    name: String,
    fp: u64,
    spec: String,
    dir: String,
    status: CellStatus,
    summary: Vec<(String, String)>,
}

/// Write the grid manifest atomically. Deterministic: declaration order,
/// no timestamps — the manifest of a killed-and-rerun grid is
/// byte-identical to an uninterrupted one.
fn write_manifest(path: &Path, grid: &str, grid_fp: u64, rows: &[ManifestRow]) -> Result<()> {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"grid\": {},\n", escape(grid)));
    out.push_str(&format!("  \"fingerprint\": \"{grid_fp:016x}\",\n"));
    out.push_str("  \"cells\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str("    {");
        out.push_str(&format!("\"name\": {}, ", escape(&r.name)));
        out.push_str(&format!("\"fingerprint\": \"{:016x}\", ", r.fp));
        out.push_str(&format!("\"spec\": {}, ", escape(&r.spec)));
        out.push_str(&format!("\"dir\": {}, ", escape(&r.dir)));
        out.push_str(&format!("\"status\": \"{}\", ", r.status.label()));
        out.push_str("\"summary\": ");
        fmt_pairs(&mut out, &r.summary);
        out.push('}');
        out.push_str(if i + 1 == rows.len() { "\n" } else { ",\n" });
    }
    out.push_str("  ]\n}\n");
    atomic_write(path, out.as_bytes())
}

/// Read an existing manifest's grid fingerprint.
fn manifest_fingerprint(path: &Path) -> Result<u64> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading manifest {path:?}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing manifest {path:?}"))?;
    u64::from_str_radix(j.get("fingerprint")?.as_str()?, 16)
        .map_err(|_| anyhow::anyhow!("manifest {path:?}: malformed fingerprint"))
}

// -------------------------------------------------------------- executor

/// Run a declared grid. Returns `None` for `--dry-run` (the cell listing
/// is printed, nothing executes); drivers skip their formatting pass.
pub fn run<W: CellWork>(
    grid: GridDef<W>,
    engine: Option<&Engine>,
    opts: &GridOptions,
) -> Result<Option<GridReport>> {
    anyhow::ensure!(opts.workers >= 1, "--workers must be >= 1");
    anyhow::ensure!(
        !(opts.resume && opts.overwrite),
        "--resume continues a manifest; --overwrite replaces it — pick one"
    );
    let grid_name = grid.name;
    let n = grid.cells.len();
    let mut names = Vec::with_capacity(n);
    let mut works: Vec<Option<W>> = Vec::with_capacity(n);
    for (name, w) in grid.cells {
        anyhow::ensure!(
            !names.contains(&name),
            "grid {grid_name}: duplicate cell name {name:?}"
        );
        names.push(name);
        works.push(Some(w));
    }
    // Engine-dependent cells fold the artifacts identity (hash of the
    // AOT manifest) into their spec: rebuilt artifacts change every
    // fingerprint, so stale cached results from the previous build are
    // never silently reused — the spec really does cover every knob
    // that can change a cell's outputs.
    let artifacts_fp: Option<u64> = match engine {
        Some(e) => {
            let path = e.dir().join("manifest.json");
            let bytes = std::fs::read(&path)
                .with_context(|| format!("hashing artifacts manifest {path:?}"))?;
            Some(fnv1a64(&bytes))
        }
        None => None,
    };
    let specs: Vec<String> = works
        .iter()
        .map(|w| {
            let w = w.as_ref().expect("declared");
            let s = w.spec();
            if !w.needs_engine() {
                return Ok(s);
            }
            let a = artifacts_fp.ok_or_else(|| {
                anyhow::anyhow!(
                    "grid {grid_name}: cells need the PJRT engine but none was provided"
                )
            })?;
            Ok(format!("{s} | artifacts={a:016x}"))
        })
        .collect::<Result<_>>()?;
    let fps: Vec<u64> = specs.iter().map(|s| cell_fingerprint(s)).collect();
    let named: Vec<(String, u64)> = names.iter().cloned().zip(fps.iter().copied()).collect();
    let grid_fp = grid_fingerprint(&grid_name, &named);

    let out_root = PathBuf::from(&opts.out_root);
    let grid_dir = out_root.join(format!("grid-{}", sanitize_name(&grid_name)));
    let manifest_path = grid_dir.join("manifest.json");
    let cells_root = out_root.join("cells");
    let rel_dir = |i: usize| format!("cells/{:016x}", fps[i]);
    let cell_dir = |i: usize| cells_root.join(format!("{:016x}", fps[i]));

    // Manifest compatibility: continue a matching manifest, refuse a
    // mismatched one (unless --overwrite), require one under --resume.
    if manifest_path.exists() {
        let have = manifest_fingerprint(&manifest_path)?;
        if have != grid_fp && !opts.overwrite {
            anyhow::bail!(
                "grid {grid_name}: manifest {manifest_path:?} was written by a \
                 different cell set (fingerprint {have:016x}, this command is \
                 {grid_fp:016x}) — rerun with --overwrite to replace it (cached \
                 cell results are keyed by their own fingerprints and survive), \
                 or point --out elsewhere"
            );
        }
    } else if opts.resume {
        anyhow::bail!("--resume: no manifest at {manifest_path:?} to continue");
    }

    // Reconcile cached state: a done record in the shared cell pool
    // satisfies the cell, whatever grid produced it.
    let mut outcomes: Vec<Option<CellOutcome>> = vec![None; n];
    let mut cache_hits = 0usize;
    for i in 0..n {
        if let Some(out) = load_cell_record(&cell_dir(i), fps[i], &specs[i])? {
            outcomes[i] = Some(out);
            cache_hits += 1;
        }
    }

    // In-grid aliases: identical specs execute once; later occurrences
    // copy the representative's outcome.
    let mut rep_of: BTreeMap<u64, usize> = BTreeMap::new();
    let mut aliases: Vec<(usize, usize)> = Vec::new(); // (alias, representative)
    let mut run_list: Vec<usize> = Vec::new();
    for i in 0..n {
        if outcomes[i].is_some() {
            rep_of.entry(fps[i]).or_insert(i);
            continue;
        }
        match rep_of.get(&fps[i]) {
            Some(&r) => aliases.push((i, r)),
            None => {
                rep_of.insert(fps[i], i);
                run_list.push(i);
            }
        }
    }
    cache_hits += aliases.len();

    if opts.dry_run {
        eprintln!(
            "grid {grid_name}: {n} cells, {} to run, {cache_hits} cached/aliased \
             (dry run — nothing executed)",
            run_list.len()
        );
        // Cached cells carry the summary counters their original run
        // recorded — surface them here so a resumed grid shows what the
        // cache saved, instead of a bare status word.
        let mut cached = CachedTally::default();
        for i in 0..n {
            let status = if outcomes[i].is_some() {
                "done (cached)"
            } else if run_list.contains(&i) {
                "pending"
            } else {
                "alias"
            };
            eprintln!("  {:016x}  {:<13} {}", fps[i], status, names[i]);
            if let Some(out) = &outcomes[i] {
                eprintln!("                      {}", summary_brief(out));
                cached.absorb(out);
            }
        }
        if cached.cells > 0 {
            eprintln!(
                "  cached work on record: {} cells, {} rounds, {} client steps, \
                 {:.3} GB wire, sim {:.0} s",
                cached.cells,
                cached.rounds,
                cached.steps,
                cached.bytes as f64 / 1e9,
                cached.sim_s
            );
        }
        return Ok(None);
    }

    let needs_engine = run_list
        .iter()
        .any(|&i| works[i].as_ref().expect("declared").needs_engine());
    if needs_engine {
        anyhow::ensure!(
            engine.is_some(),
            "grid {grid_name}: cells need the PJRT engine but none was provided"
        );
    }

    let mut rows: Vec<ManifestRow> = (0..n)
        .map(|i| ManifestRow {
            name: names[i].clone(),
            fp: fps[i],
            spec: specs[i].clone(),
            dir: rel_dir(i),
            status: if outcomes[i].is_some() {
                CellStatus::Done
            } else {
                CellStatus::Pending
            },
            summary: outcomes[i]
                .as_ref()
                .map(|o| o.summary.clone())
                .unwrap_or_default(),
        })
        .collect();
    std::fs::create_dir_all(&grid_dir).with_context(|| format!("mkdir {grid_dir:?}"))?;
    write_manifest(&manifest_path, &grid_name, grid_fp, &rows)?;
    eprintln!(
        "grid {grid_name}: {n} cells — {} to run ({cache_hits} cached/aliased), \
         workers {}, manifest {}",
        run_list.len(),
        opts.workers,
        manifest_path.display()
    );

    let executed = run_list.len();
    let mut done_count = 0usize;
    let mut record_done = |i: usize,
                           out: CellOutcome,
                           rows: &mut Vec<ManifestRow>,
                           outcomes: &mut Vec<Option<CellOutcome>>|
     -> Result<()> {
        write_cell_record(&cell_dir(i), &names[i], fps[i], &specs[i], &out)?;
        rows[i].status = CellStatus::Done;
        rows[i].summary = out.summary.clone();
        outcomes[i] = Some(out);
        done_count += 1;
        eprintln!("  [{done_count}/{executed}] {} done", names[i]);
        write_manifest(&manifest_path, &grid_name, grid_fp, rows)?;
        Ok(())
    };

    let mut failures: Vec<(usize, String)> = Vec::new();
    if opts.workers == 1 {
        for &i in &run_list {
            // the running mark is a monitoring surface (an observer
            // tailing the manifest sees which cell a serial grid is
            // on), not crash-state — resume reconciles from cell.json
            // records; its cost is one small fsync per cell
            rows[i].status = CellStatus::Running;
            write_manifest(&manifest_path, &grid_name, grid_fp, &rows)?;
            let ctx = CellCtx {
                dir: cell_dir(i),
                checkpoint: opts.checkpoint,
                trace: opts.trace,
                quiet: false,
            };
            let w = works[i].as_ref().expect("declared");
            match w.run(engine.filter(|_| w.needs_engine()), &ctx) {
                Ok(out) => record_done(i, out, &mut rows, &mut outcomes)?,
                Err(e) => {
                    failures.push((i, format!("{e:#}")));
                    break; // inline: stop at the first failure
                }
            }
        }
    } else if !run_list.is_empty() {
        // Per-thread engines, like coordinator::exec. No pre-validation
        // load here: the caller's engine was loaded from this very dir
        // in-process (its manifest was hashed above), so per-worker
        // loads are expected to succeed.
        let artifacts: Option<PathBuf> = if needs_engine {
            Some(engine.expect("checked above").dir().to_path_buf())
        } else {
            None
        };
        type Out = (usize, std::result::Result<CellOutcome, String>);
        let pool: WorkerPool<(usize, W, CellCtx), Out> = WorkerPool::new(
            opts.workers,
            move |_id| {
                Ok(match &artifacts {
                    Some(d) => Some(Engine::load(d)?),
                    None => None,
                })
            },
            |eng: &mut Option<Engine>, (i, w, ctx): (usize, W, CellCtx)| {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || -> Result<CellOutcome> {
                        w.run(eng.as_ref().filter(|_| w.needs_engine()), &ctx)
                    },
                ));
                let out = match out {
                    Ok(r) => r.map_err(|e| format!("{e:#}")),
                    Err(panic) => Err(match panic.downcast_ref::<&str>() {
                        Some(s) => format!("cell panicked: {s}"),
                        None => match panic.downcast_ref::<String>() {
                            Some(s) => format!("cell panicked: {s}"),
                            None => "cell panicked".to_string(),
                        },
                    }),
                };
                (i, out)
            },
        )?;
        for &i in &run_list {
            rows[i].status = CellStatus::Running;
        }
        write_manifest(&manifest_path, &grid_name, grid_fp, &rows)?;
        for &i in &run_list {
            let ctx = CellCtx {
                dir: cell_dir(i),
                checkpoint: opts.checkpoint,
                trace: opts.trace,
                quiet: true,
            };
            pool.submit((i, works[i].take().expect("declared"), ctx))?;
        }
        for _ in 0..run_list.len() {
            let (i, res) = pool.recv()?;
            match res {
                Ok(out) => record_done(i, out, &mut rows, &mut outcomes)?,
                Err(e) => failures.push((i, e)),
            }
        }
    }

    if !failures.is_empty() {
        let list: Vec<String> = failures
            .iter()
            .map(|(i, e)| format!("  {}: {e}", names[*i]))
            .collect();
        anyhow::bail!(
            "grid {grid_name}: {} of {} cells failed (completed cells are \
             recorded — rerun the same command to continue):\n{}",
            failures.len(),
            n,
            list.join("\n")
        );
    }

    // Aliases inherit their representative's outcome (shared cell dir).
    for &(a, r) in &aliases {
        let out = outcomes[r].clone().expect("representative completed");
        rows[a].status = CellStatus::Done;
        rows[a].summary = out.summary.clone();
        outcomes[a] = Some(out);
    }
    if !aliases.is_empty() {
        write_manifest(&manifest_path, &grid_name, grid_fp, &rows)?;
    }

    let outcomes: Vec<CellOutcome> = outcomes
        .into_iter()
        .map(|o| o.expect("every cell done"))
        .collect();
    eprintln!(
        "grid {grid_name}: complete — {executed} executed, {cache_hits} reused"
    );
    Ok(Some(GridReport {
        outcomes,
        executed,
        cache_hits,
        manifest_path,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprints_are_spec_functions() {
        assert_eq!(cell_fingerprint("a"), cell_fingerprint("a"));
        assert_ne!(cell_fingerprint("a"), cell_fingerprint("b"));
        let cells = vec![("x".to_string(), 1u64), ("y".to_string(), 2u64)];
        assert_eq!(grid_fingerprint("g", &cells), grid_fingerprint("g", &cells));
        assert_ne!(grid_fingerprint("g", &cells), grid_fingerprint("h", &cells));
        let renamed = vec![("x2".to_string(), 1u64), ("y".to_string(), 2u64)];
        assert_ne!(grid_fingerprint("g", &cells), grid_fingerprint("g", &renamed));
    }

    #[test]
    fn cell_record_roundtrips_exactly() {
        let dir = PathBuf::from(format!(
            "target/test-runs/grid-record-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut out = CellOutcome::default();
        out.put("final_acc", 0.1f64 + 0.2f64); // 0.30000000000000004
        out.put("rtt", "");
        out.curves.push((
            "accuracy".into(),
            vec![(1.0, 0.5), (2.0, 1.0 / 3.0), (3.0, 1e-7)],
        ));
        let spec = "synth id=1 \"quoted\"";
        let fp = cell_fingerprint(spec);
        write_cell_record(&dir, "c1", fp, spec, &out).unwrap();
        let back = load_cell_record(&dir, fp, spec).unwrap().expect("done");
        assert_eq!(back, out, "record must round-trip bit-exactly");
        // exact f64 recovery through the JSON
        assert_eq!(back.num("final_acc"), Some(0.1f64 + 0.2f64));
        assert_eq!(back.curve("accuracy").unwrap()[2].1, 1e-7);
        // a mismatched declaration refuses the dir
        assert!(load_cell_record(&dir, fp, "synth id=2").is_err());
        assert!(load_cell_record(&dir, fp ^ 1, spec).is_err());

        // non-finite curve values (a diverging run's loss — Figures 3/8
        // territory) must round-trip instead of poisoning the cache
        // with JSON the reader cannot parse
        let mut div = CellOutcome::default();
        div.curves.push((
            "loss".into(),
            vec![(1.0, f64::INFINITY), (2.0, f64::NEG_INFINITY), (3.0, f64::NAN)],
        ));
        let ddir = dir.join("diverged");
        let dfp = cell_fingerprint("synth diverged");
        write_cell_record(&ddir, "c2", dfp, "synth diverged", &div).unwrap();
        let back = load_cell_record(&ddir, dfp, "synth diverged")
            .unwrap()
            .expect("done");
        let pts = back.curve("loss").unwrap();
        assert_eq!(pts[0].1, f64::INFINITY);
        assert_eq!(pts[1].1, f64::NEG_INFINITY);
        assert!(pts[2].1.is_nan());
        std::fs::remove_dir_all(&dir).ok();
    }
}
