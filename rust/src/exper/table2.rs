//! Table 2 (and Table 4 shares the machinery) — increasing computation
//! per client: rounds to target for an (E, B) grid at fixed C=0.1,
//! ordered by `u = E·n/(K·B)`, FedSGD (E=1, B=∞) as the baseline row.
//!
//! Declared as a grid (DESIGN.md §9): one [`FedCell`] per
//! (model, partition, E, B); the printed table is assembled from the
//! outcome rows, so `--workers N` changes nothing but wall-clock.

use crate::config::{BatchSize, FedConfig, Partition};
use crate::federated::updates_per_round;
use crate::metrics::format_cell;
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::cells::{FedCell, GridCell, Workload};
use super::grid::{self, CellOutcome, GridDef};
use super::{print_table, ExpOptions, COMMON_FLAGS};

/// The paper's Table 2 CNN rows: (E, B); first row is FedSGD.
pub const CNN_ROWS: [(usize, BatchSize); 9] = [
    (1, BatchSize::Full), // FedSGD
    (5, BatchSize::Full),
    (1, BatchSize::Fixed(50)),
    (20, BatchSize::Full),
    (1, BatchSize::Fixed(10)),
    (5, BatchSize::Fixed(50)),
    (20, BatchSize::Fixed(50)),
    (5, BatchSize::Fixed(10)),
    (20, BatchSize::Fixed(10)),
];

/// The paper's Table 2 LSTM rows.
pub const LSTM_ROWS: [(usize, BatchSize); 6] = [
    (1, BatchSize::Full), // FedSGD
    (1, BatchSize::Fixed(50)),
    (5, BatchSize::Full),
    (1, BatchSize::Fixed(10)),
    (5, BatchSize::Fixed(50)),
    (5, BatchSize::Fixed(10)),
];

pub struct GridSpec<'a> {
    pub model: &'a str,
    pub rows: &'a [(usize, BatchSize)],
    /// rounds-to-target accuracy for the IID column.
    pub target: f64,
    /// separate (lower) target for the pathological non-IID column — at
    /// scaled K the paper's single target would sit above the non-IID
    /// ceiling reachable inside the round budget.
    pub target_noniid: f64,
    pub lr: f64,
}

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(&[COMMON_FLAGS, &["models", "target-noniid"]].concat())?;
    let opts = ExpOptions::from_args(args)?;
    let models = args.str_or("models", "mnist_cnn,shakespeare_lstm");

    let mut specs = Vec::new();
    for model in models.split(',') {
        let spec = match model {
            "mnist_cnn" => GridSpec {
                model,
                rows: &CNN_ROWS,
                target: opts.target.unwrap_or(0.85),
                target_noniid: args.f64_or("target-noniid", 0.60)?,
                lr: args.f64_or("lr", 0.1)?,
            },
            "shakespeare_lstm" => GridSpec {
                model,
                rows: &LSTM_ROWS,
                target: opts.target.unwrap_or(0.22),
                target_noniid: args.f64_or("target-noniid", 0.22)?,
                lr: args.f64_or("lr", 1.0)?,
            },
            other => anyhow::bail!("table2: unsupported model {other}"),
        };
        let mut spec = spec;
        let nrows = args.usize_or("rows", spec.rows.len())?;
        spec.rows = &spec.rows[..nrows.min(spec.rows.len())];
        specs.push(spec);
    }
    run_specs(engine, &opts, "table2", &specs)
}

/// Declare, execute, and print one or more model specs as a single grid
/// (the Table 4 driver reuses this with its own rows and grid name).
pub fn run_specs(
    engine: &Engine,
    opts: &ExpOptions,
    grid_name: &str,
    specs: &[GridSpec<'_>],
) -> Result<()> {
    let mut def = GridDef::new(grid_name);
    for spec in specs {
        declare(&mut def, opts, spec);
    }
    let Some(report) = grid::run(def, Some(engine), &opts.grid_options())? else {
        return Ok(()); // --dry-run
    };
    let mut it = report.outcomes.iter();
    for spec in specs {
        let n = spec.rows.len() * 2;
        let block: Vec<&CellOutcome> = (&mut it).take(n).collect();
        format_table(opts, spec, &block);
    }
    Ok(())
}

/// Both partitions per (E, B) row, like the paper's IID / Non-IID
/// columns. The declaration order here is the contract `format_table`
/// consumes.
fn declare(def: &mut GridDef<GridCell>, opts: &ExpOptions, spec: &GridSpec<'_>) {
    let is_lstm = spec.model == "shakespeare_lstm";
    for &(e, b) in spec.rows {
        for (col, pname) in ["iid", "noniid"].iter().enumerate() {
            let col_target = if col == 0 { spec.target } else { spec.target_noniid };
            let workload = if is_lstm {
                Workload::Shakespeare {
                    scale: opts.scale,
                    natural: col == 1,
                    seed: opts.seed,
                }
            } else {
                Workload::Mnist {
                    scale: opts.scale,
                    part: if col == 0 {
                        Partition::Iid
                    } else {
                        Partition::Pathological(2)
                    },
                    seed: opts.seed,
                }
            };
            let cfg = FedConfig {
                model: spec.model.to_string(),
                c: 0.1,
                e,
                b,
                lr: spec.lr,
                rounds: opts.rounds,
                target_accuracy: Some(col_target),
                seed: opts.seed,
                ..Default::default()
            };
            let name = format!("table2-{}-{pname}-E{e}-B{}", spec.model, b.label());
            def.cell(
                name,
                GridCell::Fed(FedCell::new(workload, cfg, opts.eval_cap)),
            );
        }
    }
}

fn format_table(opts: &ExpOptions, spec: &GridSpec<'_>, block: &[&CellOutcome]) {
    // mean examples per client, from the IID cell's recorded population
    // (all cells of a model share the workload shape)
    let mean_nk = block
        .first()
        .map(|o| {
            o.num("examples_total").unwrap_or(0.0) / o.num("clients_total").unwrap_or(1.0).max(1.0)
        })
        .unwrap_or(0.0);

    let mut rows_out = Vec::new();
    let mut baselines: [Option<f64>; 2] = [None, None];
    for (i, &(e, b)) in spec.rows.iter().enumerate() {
        let u = updates_per_round(e, mean_nk.round() as usize, b);
        let algo = if i == 0 { "FedSGD" } else { "FedAvg" };
        let mut row_cells = vec![
            algo.to_string(),
            e.to_string(),
            b.label(),
            format!("{u:.1}"),
        ];
        for col in 0..2 {
            let out = block[i * 2 + col];
            let rtt = out.num("rtt");
            if i == 0 {
                baselines[col] = rtt;
            }
            row_cells.push(format!(
                "{} acc={:.3}",
                format_cell(rtt, baselines[col]),
                out.num("final_acc").unwrap_or(0.0)
            ));
        }
        rows_out.push(row_cells);
    }
    print_table(
        &format!(
            "Table 2 — {} @ {:.0}% IID / {:.0}% non-IID accuracy (C=0.1, scale {})",
            spec.model,
            spec.target * 100.0,
            spec.target_noniid * 100.0,
            opts.scale
        ),
        &["algo", "E", "B", "u", "IID", "Non-IID"],
        &rows_out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_ordered_by_u_like_the_paper() {
        // paper orders table sections by u = E*600/(B) for K=100,n=60000
        let u = |e: usize, b: BatchSize| updates_per_round(e, 600, b);
        let us: Vec<f64> = CNN_ROWS.iter().map(|&(e, b)| u(e, b)).collect();
        // FedSGD row first with u=1
        assert_eq!(us[0], 1.0);
        // strictly the paper's u values
        assert_eq!(us[2], 12.0);
        assert_eq!(us[4], 60.0);
        assert_eq!(us[8], 1200.0);
    }
}
