//! The library's grid cells — the work units every sweep driver declares
//! into the [grid engine](super::grid) (DESIGN.md §9).
//!
//! A cell is plain, `Send` data: it names its *workload* (dataset +
//! partition, built in-thread on whichever worker executes it) and its
//! complete run configuration, and its [`CellWork::spec`] string is the
//! fingerprint input — every knob that can change the cell's outputs
//! appears in it. Three kinds cover the paper's whole evaluation:
//!
//! * [`FedCell`] — one [`federated::run`]: the unit behind every table
//!   row and figure series. Owns the per-cell crash story: a done cell
//!   is finalized from its terminal snapshot without replay, an
//!   in-flight cell resumes through the ordinary checkpoint machinery
//!   (DESIGN.md §8), anything else restarts fresh (deterministic, so a
//!   restart reproduces the same bytes).
//! * [`SgdCell`] — the sequential-SGD baseline (Table 3, Figure 9).
//! * [`InterpCell`] — Figure 1's parameter-averaging interpolation
//!   study.

use std::path::Path;

use crate::baselines::sgd::{self, SgdConfig};
use crate::comms::{CommTotals, TransportConfig};
use crate::config::{BatchSize, FedConfig, Partition};
use crate::coordinator::FleetConfig;
use crate::data::{corrupt_clients, Federated};
use crate::federated::aggregate::{fmt_state_norms, AggConfig};
use crate::federated::{self, local_update, LocalSpec, ServerOptions};
use crate::metrics::LearningCurve;
use crate::obs::Tracer;
use crate::params::interpolate;
use crate::runstate::{atomic_write, ResumeFrom, Snapshot};
use crate::runtime::Engine;
use crate::telemetry::{write_summary, RunWriter};
use crate::Result;

use super::grid::{CellCtx, CellOutcome, CellWork, Series};

/// A federated workload, declared as data and built in-thread by
/// whichever worker runs the cell (datasets are synthetic and seeded, so
/// construction is cheap and deterministic).
#[derive(Debug, Clone)]
pub enum Workload {
    Mnist { scale: f64, part: Partition, seed: u64 },
    Cifar { scale: f64, seed: u64 },
    Shakespeare { scale: f64, natural: bool, seed: u64 },
    Social { scale: f64, seed: u64 },
}

impl Workload {
    pub fn build(&self) -> Federated {
        match *self {
            Workload::Mnist { scale, part, seed } => super::mnist_fed(scale, part, seed),
            Workload::Cifar { scale, seed } => super::cifar_fed(scale, seed),
            Workload::Shakespeare {
                scale,
                natural,
                seed,
            } => super::shakespeare_fed(scale, natural, seed),
            Workload::Social { scale, seed } => super::social_fed(scale, seed),
        }
    }

    /// Canonical sub-spec (`{:?}` on f64 prints round-trip values).
    pub fn spec(&self) -> String {
        format!("{self:?}")
    }
}

fn to_series(pts: &[(u64, f64)]) -> Series {
    pts.iter().map(|&(x, y)| (x as f64, y)).collect()
}

/// Per-run curve/accounting bundle shared by the fresh-run and
/// finalize-from-snapshot paths of [`FedCell`].
struct RunStats {
    accuracy: Vec<(u64, f64)>,
    test_loss: Vec<(u64, f64)>,
    train_loss: Option<Vec<(u64, f64)>>,
    comm: CommTotals,
    rounds_run: u64,
    client_steps: u64,
}

/// Workload shape recorded into every outcome row (formatters derive
/// `u = E·(n/K)/B` and cohort sizes from it instead of rebuilding data).
struct Population {
    clients: usize,
    examples: usize,
    corrupted: usize,
}

/// How a cell dir's prior state maps onto this execution.
enum Prior {
    Fresh,
    Resume(Box<Snapshot>),
    Finished(Box<Snapshot>),
}

/// One [`federated::run`] as a grid cell.
#[derive(Debug, Clone)]
pub struct FedCell {
    pub workload: Workload,
    pub cfg: FedConfig,
    pub eval_cap: usize,
    pub agg: AggConfig,
    pub transport: TransportConfig,
    /// Fraction of label-corrupted clients (`fedavg agg`); 0 = none.
    pub corrupt: f64,
    /// Fleet coordination (profiles, deadlines, round modes); the
    /// default Legacy profile is the plain synchronous server path.
    pub fleet: FleetConfig,
}

impl FedCell {
    pub fn new(workload: Workload, cfg: FedConfig, eval_cap: usize) -> FedCell {
        FedCell {
            workload,
            cfg,
            eval_cap,
            agg: AggConfig::default(),
            transport: TransportConfig::default(),
            corrupt: 0.0,
            fleet: FleetConfig::default(),
        }
    }

    fn codec_spec(&self) -> String {
        let name = |p: &Option<crate::comms::wire::Pipeline>| {
            p.as_ref()
                .map(|p| p.spec().to_string())
                .unwrap_or_else(|| "legacy".into())
        };
        format!(
            "{}/{}@{}",
            name(&self.transport.up),
            name(&self.transport.down),
            self.transport.store_cap
        )
    }

    /// Classify the cell dir's checkpoints. The dir is already keyed by
    /// this cell's fingerprint, but belt-and-braces: a snapshot that
    /// does not match the config restarts the cell instead of resuming
    /// into a wrong trajectory (the server re-verifies the full
    /// fingerprint on any actual resume).
    fn classify(&self, dir: &Path, clients: usize, dim: usize) -> Prior {
        let snap = match Snapshot::load_latest(dir) {
            Ok(Some((_, s))) => s,
            Ok(None) => return Prior::Fresh,
            Err(e) => {
                eprintln!(
                    "warning: {}: no usable checkpoint ({e:#}); cell restarts fresh",
                    dir.display()
                );
                return Prior::Fresh;
            }
        };
        let cfg = &self.cfg;
        if snap.meta.label != cfg.label()
            || snap.meta.seed != cfg.seed
            || snap.meta.clients != clients as u64
            || snap.meta.dim != dim as u64
            || snap.meta.lr_decay != cfg.lr_decay
            || snap.meta.eval_every != cfg.eval_every as u64
        {
            return Prior::Fresh;
        }
        // both continuation paths reopen the run's telemetry; without a
        // curve to reopen (externally deleted), restart from scratch
        if !dir.join("curve.csv").exists() {
            return Prior::Fresh;
        }
        // early stop counts as finished: the terminal snapshot's curve
        // already crossed the target, and blindly resuming would train
        // past the stop and change the curve
        let target_hit = cfg
            .target_accuracy
            .map_or(false, |t| snap.curves.accuracy.iter().any(|&(_, v)| v >= t));
        if snap.round >= cfg.rounds as u64 || target_hit {
            return Prior::Finished(Box::new(snap));
        }
        Prior::Resume(Box::new(snap))
    }

    /// A run that already finished (terminal snapshot, DESIGN.md §8) but
    /// whose done record was lost — e.g. the grid was killed between the
    /// server finishing and the manifest update. Recover the outcome
    /// from the snapshot without replaying: truncate any lost-future
    /// rows, then close out summary.json the way the server would have.
    fn finalize(&self, snap: Snapshot, ctx: &CellCtx, pop: Population) -> Result<CellOutcome> {
        let mut w = RunWriter::reopen(&ctx.dir, snap.round)?;
        w.set_quiet(true);
        let mut aggr = self.agg.build()?;
        aggr.state_load(&snap.agg.bytes)?;
        let totals = snap.comms.totals;
        let final_acc = snap.curves.accuracy.last().map(|&(_, v)| v).unwrap_or(0.0);
        let mut fields = vec![
            ("model", self.cfg.model.clone()),
            ("label", self.cfg.label()),
            ("rounds_run", snap.round.to_string()),
            ("client_steps", snap.client_steps.to_string()),
            ("final_accuracy", format!("{final_acc:.6}")),
            ("bytes_up", totals.bytes_up.to_string()),
            ("bytes_down", totals.bytes_down.to_string()),
            ("codec", snap.meta.codec.clone()),
            ("sim_seconds", format!("{:.1}", totals.sim_seconds)),
            ("agg", snap.meta.agg.clone()),
        ];
        let server_state = fmt_state_norms(&aggr.state_norms());
        if !server_state.is_empty() {
            fields.push(("server_state", server_state));
        }
        w.finish(&fields)?;
        let stats = RunStats {
            accuracy: snap.curves.accuracy,
            test_loss: snap.curves.test_loss,
            train_loss: snap.curves.train_loss,
            comm: totals,
            rounds_run: snap.round,
            client_steps: snap.client_steps,
        };
        Ok(self.outcome(stats, pop))
    }

    fn outcome(&self, stats: RunStats, pop: Population) -> CellOutcome {
        let curve = LearningCurve::from_points(stats.accuracy.clone())
            .expect("server curves are strictly increasing in rounds");
        let rtt = self
            .cfg
            .target_accuracy
            .and_then(|t| curve.rounds_to_target(t));
        let mut out = CellOutcome::default();
        out.put("final_acc", curve.last_value().unwrap_or(0.0));
        out.put("best_acc", curve.best_value().unwrap_or(0.0));
        out.put("rtt", rtt.map(|r| r.to_string()).unwrap_or_default());
        out.put("rounds_run", stats.rounds_run);
        out.put("client_steps", stats.client_steps);
        out.put("bytes_up", stats.comm.bytes_up);
        out.put("bytes_down", stats.comm.bytes_down);
        out.put("sim_seconds", stats.comm.sim_seconds);
        out.put("clients_total", pop.clients);
        out.put("examples_total", pop.examples);
        out.put("corrupted", pop.corrupted);
        out.curves.push(("accuracy".into(), to_series(&stats.accuracy)));
        out.curves
            .push(("test_loss".into(), to_series(&stats.test_loss)));
        if let Some(tl) = &stats.train_loss {
            out.curves.push(("train_loss".into(), to_series(tl)));
        }
        out
    }
}

impl CellWork for FedCell {
    fn spec(&self) -> String {
        // --workers is deliberately absent: worker parallelism is
        // bit-invariant (slot-ordered reduction), so a cell's bytes are
        // a pure function of everything else here.
        format!(
            "fed {} seed={} lr_decay={} rounds={} eval_every={} target={:?} \
             train_loss={} | {} | eval_cap={} agg={} server_lr={:?} \
             server_momentum={} prox_mu={} codec={} corrupt={} \
             fleet=({:?},{:?},{:?},{:?},{},{:?},{:?},{:?})",
            self.cfg.label(),
            self.cfg.seed,
            self.cfg.lr_decay,
            self.cfg.rounds,
            self.cfg.eval_every,
            self.cfg.target_accuracy,
            self.cfg.track_train_loss,
            self.workload.spec(),
            self.eval_cap,
            self.agg.spec,
            self.agg.server_lr,
            self.agg.server_momentum,
            self.agg.prox_mu,
            self.codec_spec(),
            self.corrupt,
            self.fleet.profile,
            self.fleet.overselect,
            self.fleet.deadline_s,
            self.fleet.step_cost_s,
            self.fleet.shards,
            self.fleet.async_buffer,
            self.fleet.staleness_decay,
            self.fleet.late_policy,
        )
    }

    fn run(&self, engine: Option<&Engine>, ctx: &CellCtx) -> Result<CellOutcome> {
        let engine =
            engine.ok_or_else(|| anyhow::anyhow!("federated cell needs the PJRT engine"))?;
        let mut fed = self.workload.build();
        let corrupted = if self.corrupt > 0.0 {
            corrupt_clients(&mut fed, self.corrupt, self.cfg.seed ^ 0xC0881).len()
        } else {
            0
        };
        let pop = Population {
            clients: fed.num_clients(),
            examples: fed.total_examples(),
            corrupted,
        };
        let dim = engine.model(&self.cfg.model)?.param_count();
        let mut sopts = ServerOptions {
            eval_cap: Some(self.eval_cap),
            transport: self.transport.clone(),
            agg: self.agg.clone(),
            fleet: self.fleet.clone(),
            checkpoint: ctx.checkpoint,
            // covers the resume path, whose writer the server reopens
            // itself; the fresh path's writer is quieted below
            quiet_rounds: ctx.quiet,
            ..Default::default()
        };
        if ctx.trace {
            // Trace is an observation channel, not a config knob: it is
            // absent from spec(), so a traced cell lands in the same
            // fingerprint-keyed dir as its untraced twin.
            sopts.trace = Tracer::to_file(&ctx.dir.join("trace.jsonl"))?;
        }
        match self.classify(&ctx.dir, pop.clients, dim) {
            Prior::Finished(snap) => return self.finalize(*snap, ctx, pop),
            Prior::Resume(snap) => {
                eprintln!(
                    "  resuming {} from its round-{} checkpoint",
                    ctx.dir.display(),
                    snap.round
                );
                sopts.resume = Some(ResumeFrom {
                    snapshot: *snap,
                    run_dir: ctx.dir.clone(),
                });
            }
            Prior::Fresh => {
                let mut w = RunWriter::create_dir_overwrite(&ctx.dir)?;
                w.set_quiet(ctx.quiet);
                sopts.telemetry = Some(w);
            }
        }
        let res = federated::run(engine, &fed, &self.cfg, sopts)?;
        let stats = RunStats {
            accuracy: res.accuracy.points().to_vec(),
            test_loss: res.test_loss.points().to_vec(),
            train_loss: res.train_loss.as_ref().map(|c| c.points().to_vec()),
            comm: res.comm,
            rounds_run: res.rounds_run,
            client_steps: res.client_steps,
        };
        Ok(self.outcome(stats, pop))
    }
}

/// The sequential-SGD baseline as a grid cell (Table 3, Figure 9): the
/// pooled training set, learning curve keyed by minibatch updates. No
/// mid-run checkpointing — an interrupted SGD cell restarts fresh, which
/// reproduces identical bytes (the run is a pure function of its spec).
#[derive(Debug, Clone)]
pub struct SgdCell {
    pub workload: Workload,
    pub cfg: SgdConfig,
    pub eval_cap: usize,
}

impl CellWork for SgdCell {
    fn spec(&self) -> String {
        let c = &self.cfg;
        format!(
            "sgd model={} batch={} lr={} lr_decay={} updates={} eval_every={} \
             target={:?} seed={} | {} | eval_cap={}",
            c.model,
            c.batch,
            c.lr,
            c.lr_decay,
            c.updates,
            c.eval_every,
            c.target_accuracy,
            c.seed,
            self.workload.spec(),
            self.eval_cap,
        )
    }

    fn run(&self, engine: Option<&Engine>, ctx: &CellCtx) -> Result<CellOutcome> {
        let engine = engine.ok_or_else(|| anyhow::anyhow!("SGD cell needs the PJRT engine"))?;
        let fed = self.workload.build();
        let res = sgd::run(engine, &fed.train, &fed.test, &self.cfg, Some(self.eval_cap))?;
        std::fs::create_dir_all(&ctx.dir)?;
        let mut csv = String::from("update,test_accuracy,test_loss\n");
        for (&(u, acc), &(_, loss)) in res.accuracy.points().iter().zip(res.test_loss.points()) {
            csv.push_str(&format!("{u},{acc},{loss}\n"));
        }
        atomic_write(&ctx.dir.join("sgd.csv"), csv.as_bytes())?;
        write_summary(
            &ctx.dir,
            &[
                ("model", self.cfg.model.clone()),
                ("updates_run", res.updates_run.to_string()),
                (
                    "final_accuracy",
                    format!("{:.6}", res.accuracy.last_value().unwrap_or(0.0)),
                ),
            ],
        )?;
        let mut out = CellOutcome::default();
        out.put("final_acc", res.accuracy.last_value().unwrap_or(0.0));
        out.put("best_acc", res.accuracy.best_value().unwrap_or(0.0));
        out.put("updates_run", res.updates_run);
        out.curves
            .push(("accuracy".into(), to_series(res.accuracy.points())));
        out.curves
            .push(("test_loss".into(), to_series(res.test_loss.points())));
        Ok(out)
    }
}

/// Figure 1's interpolation study as a grid cell: train two MNIST 2NN
/// models from shared vs independent initializations on disjoint shards,
/// then trace the training loss of `θ·w + (1−θ)·w'` across mixing
/// weights (the averaging-works phenomenon the whole paper rests on).
#[derive(Debug, Clone)]
pub struct InterpCell {
    pub scale: f64,
    pub seed: u64,
}

impl CellWork for InterpCell {
    fn spec(&self) -> String {
        format!("interp scale={} seed={}", self.scale, self.seed)
    }

    fn run(&self, engine: Option<&Engine>, ctx: &CellCtx) -> Result<CellOutcome> {
        let engine =
            engine.ok_or_else(|| anyhow::anyhow!("interpolation cell needs the PJRT engine"))?;
        let model = engine.model("mnist_2nn")?;
        let fed = super::mnist_fed(self.scale.max(0.02), Partition::Iid, self.seed);
        // two disjoint "clients": the paper trained on 600-example shards
        let a_idx = &fed.clients[0];
        let b_idx = &fed.clients[1 % fed.num_clients()];
        // paper: SGD lr=0.1, 240 updates of batch 50 (E=20 over 600)
        let train = |theta0: &[f32], idxs: &[usize], seed: u64| -> Result<Vec<f32>> {
            let spec = LocalSpec {
                epochs: (240 * 50 / idxs.len().max(1)).max(1),
                batch: BatchSize::Fixed(50),
                lr: 0.1,
                prox_mu: 0.0,
                shuffle_seed: seed,
            };
            Ok(local_update(&model, &fed.train, idxs, theta0, &spec)?.theta)
        };
        // loss over the *full* training set, as in the paper
        let full: Vec<usize> = (0..fed.train.len()).collect();
        let loss_of = |theta: &[f32]| -> Result<f64> {
            Ok(model
                .eval_dataset(theta, &fed.train, Some(&full))?
                .mean_loss())
        };

        let mut out = CellOutcome::default();
        for (tag, seed_a, seed_b) in [("independent", 100, 200), ("shared", 300, 300)] {
            let wa = train(&model.init(seed_a)?, a_idx, 1)?;
            let wb = train(&model.init(seed_b)?, b_idx, 2)?;
            let parent_best = loss_of(&wa)?.min(loss_of(&wb)?);
            let mut pts: Series = Vec::with_capacity(50);
            let mut min_mix = f64::INFINITY;
            for i in 0..50 {
                let theta = -0.2 + 1.4 * (i as f64 / 49.0);
                let mixed = interpolate(&wb, &wa, theta as f32); // θ on w (=wa)
                let l = loss_of(&mixed)?;
                min_mix = min_mix.min(l);
                pts.push((theta, l));
            }
            out.put(&format!("{tag}_parent_best"), parent_best);
            out.put(&format!("{tag}_best_mix"), min_mix);
            out.curves.push((tag.to_string(), pts));
        }
        std::fs::create_dir_all(&ctx.dir)?;
        Ok(out)
    }
}

/// The one work type every driver declares: federated runs, the SGD
/// baseline, and the interpolation study.
#[derive(Debug, Clone)]
pub enum GridCell {
    Fed(FedCell),
    Sgd(SgdCell),
    Interp(InterpCell),
}

impl CellWork for GridCell {
    fn spec(&self) -> String {
        match self {
            GridCell::Fed(c) => c.spec(),
            GridCell::Sgd(c) => c.spec(),
            GridCell::Interp(c) => c.spec(),
        }
    }

    fn run(&self, engine: Option<&Engine>, ctx: &CellCtx) -> Result<CellOutcome> {
        match self {
            GridCell::Fed(c) => c.run(engine, ctx),
            GridCell::Sgd(c) => c.run(engine, ctx),
            GridCell::Interp(c) => c.run(engine, ctx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fed_cell() -> FedCell {
        FedCell::new(
            Workload::Mnist {
                scale: 0.05,
                part: Partition::Iid,
                seed: 42,
            },
            FedConfig::default(),
            600,
        )
    }

    #[test]
    fn fed_spec_covers_every_knob() {
        let base = fed_cell();
        let mut tweaked: Vec<FedCell> = Vec::new();
        let tweaks: [fn(&mut FedCell); 17] = [
            |c: &mut FedCell| c.cfg.lr = 0.2,
            |c: &mut FedCell| c.cfg.seed = 43,
            |c: &mut FedCell| c.cfg.rounds += 1,
            |c: &mut FedCell| c.cfg.eval_every = 2,
            |c: &mut FedCell| c.cfg.lr_decay = 0.99,
            |c: &mut FedCell| c.cfg.target_accuracy = Some(0.5),
            |c: &mut FedCell| c.cfg.track_train_loss = true,
            |c: &mut FedCell| c.eval_cap = 601,
            |c: &mut FedCell| c.agg.spec = "fedavgm".into(),
            |c: &mut FedCell| c.agg.prox_mu = 0.1,
            |c: &mut FedCell| c.corrupt = 0.2,
            |c: &mut FedCell| {
                c.workload = Workload::Mnist {
                    scale: 0.05,
                    part: Partition::Pathological(2),
                    seed: 42,
                }
            },
            |c: &mut FedCell| {
                c.transport = TransportConfig::parse(Some("q8"), None).unwrap()
            },
            |c: &mut FedCell| {
                c.fleet.profile = crate::coordinator::FleetProfile::Mobile
            },
            |c: &mut FedCell| c.fleet.async_buffer = Some(4),
            |c: &mut FedCell| c.fleet.staleness_decay = 0.5,
            |c: &mut FedCell| {
                c.fleet.late_policy = crate::coordinator::LatePolicy::Discount
            },
        ];
        for f in tweaks {
            let mut c = fed_cell();
            f(&mut c);
            tweaked.push(c);
        }
        let mut specs: Vec<String> = tweaked.iter().map(|c| c.spec()).collect();
        specs.push(base.spec());
        let n = specs.len();
        specs.sort();
        specs.dedup();
        assert_eq!(specs.len(), n, "two distinct configs share a spec");
    }

    #[test]
    fn workload_specs_distinguish_shapes() {
        let a = Workload::Mnist {
            scale: 0.05,
            part: Partition::Iid,
            seed: 1,
        };
        let b = Workload::Mnist {
            scale: 0.05,
            part: Partition::Unbalanced,
            seed: 1,
        };
        let c = Workload::Shakespeare {
            scale: 0.05,
            natural: true,
            seed: 1,
        };
        assert_ne!(a.spec(), b.spec());
        assert_ne!(a.spec(), c.spec());
        assert_eq!(a.spec(), a.spec());
    }
}
