//! `fedavg async` — the round-mode sweep: synchronous barrier vs
//! semi-sync (staleness-discounted stragglers) vs buffered-async
//! (K-delta buffer) over the fleet device profiles (DESIGN.md §12).
//!
//! The scheduling complement to [`super::table_agg`]: where the rule
//! sweep varies *what the server does with a cohort*, this sweep varies
//! *what counts as a cohort*. Each cell trains the same federated
//! workload through the fleet coordinator with a different round mode:
//!
//! * `sync` — the barrier baseline: over-selection + a deadline, late
//!   stragglers dropped (their error-feedback residuals survive).
//! * `semi` — the same barrier, but `--late-policy discount`: late
//!   deltas are staleness-discounted into the round they arrive in.
//! * `async` — no barrier: `--async-buffer K` applies combine∘step
//!   whenever K deltas have arrived in virtual-clock order.
//!
//! Every mode is a pure function of the seeded virtual clock, so each
//! cell's curve.csv is byte-identical across `--workers N` and the
//! comparison is a scheduling comparison, not a nondeterminism lottery.

use crate::config::{BatchSize, FedConfig, Partition};
use crate::coordinator::{FleetConfig, FleetProfile, LatePolicy};
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::cells::{FedCell, GridCell, Workload};
use super::grid::{self, GridDef};
use super::{print_table, ExpOptions, COMMON_FLAGS};

/// Default mode sweep: all three round modes, head to head.
pub const DEFAULT_MODES: &str = "sync,semi,async";
/// Default profile sweep: the reference fleet and the heterogeneous one
/// (flaky's tiny online pools are a stress test, not a comparison axis).
pub const DEFAULT_PROFILES: &str = "uniform,mobile";

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(
        &[
            COMMON_FLAGS,
            &[
                "model", "modes", "profiles", "buffer", "staleness-decay",
                "deadline", "overselect", "c", "e", "b",
            ],
        ]
        .concat(),
    )?;
    let opts = ExpOptions::from_args(args)?;
    let model = args.str_or("model", "mnist_2nn");
    let modes: Vec<String> = args
        .str_or("modes", DEFAULT_MODES)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!modes.is_empty(), "--modes lists no round modes");
    for m in &modes {
        anyhow::ensure!(
            matches!(m.as_str(), "sync" | "semi" | "async"),
            "unknown round mode {m:?} (sync|semi|async)"
        );
    }
    let profiles: Vec<FleetProfile> = args
        .str_or("profiles", DEFAULT_PROFILES)
        .split(',')
        .map(|s| FleetProfile::parse(s.trim()))
        .collect::<Result<_>>()?;
    anyhow::ensure!(
        !profiles.iter().any(|p| *p == FleetProfile::Legacy),
        "async: the round modes schedule on the virtual clock — pick a \
         device profile (uniform|mobile|flaky)"
    );
    let buffer = args.usize_or("buffer", 3)?;
    anyhow::ensure!(buffer >= 1, "--buffer must be at least 1");
    let decay = args.f64_or("staleness-decay", 0.9)?;
    anyhow::ensure!(
        decay.is_finite() && decay > 0.0 && decay <= 1.0,
        "--staleness-decay must be in (0, 1], got {decay}"
    );
    let deadline = args.f64_or("deadline", 15.0)?;
    anyhow::ensure!(
        deadline.is_finite() && deadline > 0.0,
        "--deadline must be a positive number of virtual seconds"
    );
    let overselect = args.f64_or("overselect", 0.3)?;
    anyhow::ensure!(
        overselect.is_finite() && overselect >= 0.0,
        "--overselect must be a non-negative factor"
    );

    let cfg = FedConfig {
        model: model.clone(),
        c: args.f64_or("c", 0.2)?,
        e: args.usize_or("e", 5)?,
        b: BatchSize::parse(&args.str_or("b", "10"))?,
        lr: args.f64_or("lr", 0.1)?,
        rounds: opts.rounds,
        target_accuracy: opts.target,
        seed: opts.seed,
        ..Default::default()
    };
    // One FleetConfig per (mode, profile): the barrier modes share the
    // over-selection + deadline cohort; async replaces the barrier.
    let fleet_of = |mode: &str, profile: FleetProfile| -> FleetConfig {
        let mut f = FleetConfig {
            profile,
            ..FleetConfig::default()
        };
        match mode {
            "sync" | "semi" => {
                f.overselect = overselect;
                f.deadline_s = Some(deadline);
                if mode == "semi" {
                    f.late_policy = LatePolicy::Discount;
                    f.staleness_decay = decay;
                }
            }
            _ => {
                f.async_buffer = Some(buffer);
                f.staleness_decay = decay;
            }
        }
        f
    };
    println!(
        "async sweep: {} — modes: {}, profiles: {}, buffer {buffer}, \
         staleness decay {decay}, deadline {deadline}s (+{:.0}% over-selection)",
        cfg.label(),
        modes.join(","),
        profiles.iter().map(|p| p.label()).collect::<Vec<_>>().join(","),
        overselect * 100.0,
    );

    let mut def = GridDef::new("async");
    for profile in &profiles {
        for mode in &modes {
            let mut cell = FedCell::new(
                Workload::Mnist {
                    scale: opts.scale,
                    part: Partition::Iid,
                    seed: opts.seed,
                },
                cfg.clone(),
                opts.eval_cap,
            );
            cell.fleet = fleet_of(mode, *profile);
            def.cell(
                format!("async-{}-{mode}", profile.label()),
                GridCell::Fed(cell),
            );
        }
    }
    let Some(report) = grid::run(def, Some(engine), &opts.grid_options())? else {
        return Ok(()); // --dry-run
    };

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut it = report.outcomes.iter();
    for profile in &profiles {
        for mode in &modes {
            let out = it.next().expect("outcome per declared cell");
            let rtt = out
                .num("rtt")
                .map(|r| format!("{r:.0}"))
                .unwrap_or_else(|| "-".into());
            rows.push(vec![
                mode.to_string(),
                profile.label().to_string(),
                rtt,
                format!("{:.4}", out.num("final_acc").unwrap_or(0.0)),
                format!("{:.4}", out.num("best_acc").unwrap_or(0.0)),
                format!("{:.2}", out.num("sim_seconds").unwrap_or(0.0) / 3600.0),
                format!("{:.3}", out.num("bytes_up").unwrap_or(0.0) / 1e9),
            ]);
        }
    }
    print_table(
        &format!(
            "Round modes — sync vs semi-sync vs buffered-async on {} \
             (target {}, scale {})",
            model,
            opts.target
                .map(|t| format!("{:.0}%", t * 100.0))
                .unwrap_or_else(|| "none".into()),
            opts.scale
        ),
        &["mode", "profile", "rds-to-target", "final acc", "best acc", "sim hours", "GB up"],
        &rows,
    );
    println!(
        "(per-apply staleness_mean/buffer_fill in {}/cells/<fingerprint>/curve.csv — \
         the manifest under {}/grid-async/ maps rows to cells; with \
         --staleness-decay 1.0 and --buffer equal to the cohort size the \
         async rows reproduce the sync rows byte-for-byte, DESIGN.md §12)",
        opts.out_root, opts.out_root
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_fleet_configs_pass_server_validation_shape() {
        // mirror fleet_of: the three modes must produce configs the
        // server/scheduler validators accept
        for profile in [FleetProfile::Uniform, FleetProfile::Mobile] {
            let sync = FleetConfig {
                profile,
                overselect: 0.3,
                deadline_s: Some(15.0),
                ..FleetConfig::default()
            };
            let semi = FleetConfig {
                late_policy: LatePolicy::Discount,
                staleness_decay: 0.9,
                ..sync.clone()
            };
            let asynch = FleetConfig {
                profile,
                async_buffer: Some(3),
                staleness_decay: 0.9,
                ..FleetConfig::default()
            };
            for f in [sync, semi, asynch] {
                assert!(
                    crate::coordinator::FleetSim::new(&f, 20, 4, 1000, 5.0, 7).is_ok(),
                    "{f:?}"
                );
            }
        }
    }
}
