//! Figure drivers — regenerate the data series behind every figure in the
//! paper (1-10). Each writes CSVs under `runs/figN-*/` and prints a
//! compact summary; DESIGN.md §3 maps figure → experiment.

use crate::baselines::sgd::{self, SgdConfig};
use crate::config::{BatchSize, FedConfig, Partition};
use crate::data::Federated;
use crate::federated::{self, updates_per_round, LocalSpec};
use crate::params::interpolate;
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::{
    cifar_fed, mnist_fed, run_one, shakespeare_fed, social_fed, ExpOptions, COMMON_FLAGS,
};

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(&[COMMON_FLAGS, &["e-values"]].concat())?;
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = ExpOptions::from_args(args)?;
    let figs: Vec<u32> = if which == "all" {
        vec![1, 2, 3, 4, 6, 7, 8, 9] // 5 & 10 need word_lstm artifacts
    } else {
        vec![which.parse()?]
    };
    for f in figs {
        match f {
            1 => figure1(engine, &opts)?,
            2 => figure2(engine, &opts)?,
            3 => figure3(engine, &opts, args)?,
            4 => figure4(engine, &opts)?,
            5 => figure5(engine, &opts)?,
            6 => figure6(engine, &opts)?,
            7 => figure7(engine, &opts)?,
            8 => figure8(engine, &opts, args)?,
            9 => figure9(engine, &opts)?,
            10 => figure10(engine, &opts)?,
            other => anyhow::bail!("no figure {other}"),
        }
    }
    Ok(())
}

fn curve_csv(opts: &ExpOptions, name: &str, header: &str, rows: &[String]) -> Result<()> {
    let dir = std::path::Path::new(&opts.out_root).join(name);
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("series.csv");
    let mut out = String::from(header);
    out.push('\n');
    for r in rows {
        out.push_str(r);
        out.push('\n');
    }
    std::fs::write(&path, out)?;
    println!("  -> {}", path.display());
    Ok(())
}

/// Figure 1 — loss of θ·w + (1−θ)·w' for models trained from shared vs
/// independent initialization (the averaging-works phenomenon).
pub fn figure1(engine: &Engine, opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 1 — parameter-averaging interpolation ==");
    let model = engine.model("mnist_2nn")?;
    let fed = mnist_fed(opts.scale.max(0.02), Partition::Iid, opts.seed);
    // two disjoint "clients": paper trained on 600-example IID shards
    let a_idx = &fed.clients[0];
    let b_idx = &fed.clients[1 % fed.num_clients()];
    // paper: SGD lr=0.1, 240 updates of batch 50 (E=20 over 600 examples)
    let train = |theta0: &[f32], idxs: &[usize], seed: u64| -> Result<Vec<f32>> {
        let spec = LocalSpec {
            epochs: (240 * 50 / idxs.len().max(1)).max(1),
            batch: BatchSize::Fixed(50),
            lr: 0.1,
            prox_mu: 0.0,
            shuffle_seed: seed,
        };
        Ok(federated::local_update(&model, &fed.train, idxs, theta0, &spec)?.theta)
    };
    // loss over the *full* training set, as in the paper
    let full: Vec<usize> = (0..fed.train.len()).collect();
    let loss_of = |theta: &[f32]| -> Result<f64> {
        Ok(model
            .eval_dataset(theta, &fed.train, Some(&full))?
            .mean_loss())
    };

    let mut rows = Vec::new();
    for (tag, seed_a, seed_b) in [("independent", 100, 200), ("shared", 300, 300)] {
        let wa = train(&model.init(seed_a)?, a_idx, 1)?;
        let wb = train(&model.init(seed_b)?, b_idx, 2)?;
        let parent_best = loss_of(&wa)?.min(loss_of(&wb)?);
        let mut min_mix = f64::INFINITY;
        for i in 0..50 {
            let theta = -0.2 + 1.4 * (i as f64 / 49.0);
            let mixed = interpolate(&wb, &wa, theta as f32); // θ on w (=wa)
            let l = loss_of(&mixed)?;
            min_mix = min_mix.min(l);
            rows.push(format!("{tag},{theta:.4},{l:.6}"));
        }
        println!(
            "  {tag:<12} parents' best loss {parent_best:.4}; best mixture {min_mix:.4} {}",
            if min_mix < parent_best {
                "(averaging helps ✓)"
            } else {
                "(averaging hurts)"
            }
        );
    }
    curve_csv(opts, "fig1-interpolation", "init,theta,train_loss", &rows)
}

/// Figure 2 — test accuracy vs rounds, MNIST CNN (IID + non-IID) and
/// Shakespeare LSTM (IID + by-role), C=0.1.
pub fn figure2(engine: &Engine, opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 2 — accuracy vs communication rounds ==");
    let mut runs: Vec<(&str, Federated, FedConfig)> = Vec::new();
    for (pname, part) in [("iid", Partition::Iid), ("noniid", Partition::Pathological(2))] {
        for (e, b, label) in [
            (1usize, BatchSize::Full, "fedsgd"),
            (5, BatchSize::Fixed(10), "fedavg-E5-B10"),
        ] {
            runs.push((
                Box::leak(format!("cnn-{pname}-{label}").into_boxed_str()),
                mnist_fed(opts.scale, part, opts.seed),
                FedConfig {
                    model: "mnist_cnn".into(),
                    c: 0.1,
                    e,
                    b,
                    lr: 0.1,
                    rounds: opts.rounds,
                    seed: opts.seed,
                    ..Default::default()
                },
            ));
        }
    }
    for (natural, pname) in [(false, "iid"), (true, "role")] {
        for (e, b, label) in [
            (1usize, BatchSize::Full, "fedsgd"),
            (5, BatchSize::Fixed(10), "fedavg-E5-B10"),
        ] {
            runs.push((
                Box::leak(format!("lstm-{pname}-{label}").into_boxed_str()),
                shakespeare_fed(opts.scale, natural, opts.seed),
                FedConfig {
                    model: "shakespeare_lstm".into(),
                    c: 0.1,
                    e,
                    b,
                    lr: 1.0,
                    rounds: opts.rounds,
                    seed: opts.seed,
                    ..Default::default()
                },
            ));
        }
    }
    for (name, fed, cfg) in &runs {
        let (res, _) = run_one(engine, fed, cfg, opts, &format!("fig2-{name}"))?;
        println!(
            "  {name:<24} final acc {:.3} (best {:.3})",
            res.final_accuracy(),
            res.accuracy.best_value().unwrap_or(0.0)
        );
    }
    Ok(())
}

/// Figure 3 — many local epochs on the Shakespeare LSTM (B=10, C=0.1,
/// fixed η): large E can plateau or diverge.
pub fn figure3(engine: &Engine, opts: &ExpOptions, args: &Args) -> Result<()> {
    println!("\n== Figure 3 — effect of large E (Shakespeare LSTM) ==");
    let evals = args.str_or("e-values", "1,5,20,50");
    let fed = shakespeare_fed(opts.scale, true, opts.seed);
    let mut rows = Vec::new();
    for e in evals.split(',') {
        let e: usize = e.parse()?;
        let cfg = FedConfig {
            model: "shakespeare_lstm".into(),
            c: 0.1,
            e,
            b: BatchSize::Fixed(10),
            lr: 1.47, // the paper's fixed rate for this figure
            rounds: opts.rounds,
            seed: opts.seed,
            ..Default::default()
        };
        let (res, _) = run_one(engine, &fed, &cfg, opts, &format!("fig3-E{e}"))?;
        for &(r, v) in res.accuracy.points() {
            rows.push(format!("{e},{r},{v:.5}"));
        }
        println!("  E={e:<4} final acc {:.3}", res.final_accuracy());
    }
    curve_csv(opts, "fig3-large-E", "E,round,test_accuracy", &rows)
}

/// Figure 4 — CIFAR accuracy vs rounds: FedAvg(E=5,B=50,decay .99) vs
/// FedSGD(decay .9934).
pub fn figure4(engine: &Engine, opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 4 — CIFAR FedAvg vs FedSGD ==");
    let fed = cifar_fed(opts.scale, opts.seed);
    let fedsgd = FedConfig {
        model: "cifar_cnn".into(),
        c: 0.1,
        lr: 0.1,
        lr_decay: 0.9934,
        rounds: opts.rounds,
        seed: opts.seed,
        ..Default::default()
    }
    .fedsgd();
    let fedavg = FedConfig {
        model: "cifar_cnn".into(),
        c: 0.1,
        e: 5,
        b: BatchSize::Fixed(50),
        lr: 0.1,
        lr_decay: 0.99,
        rounds: opts.rounds,
        seed: opts.seed,
        ..Default::default()
    };
    let (r1, _) = run_one(engine, &fed, &fedsgd, opts, "fig4-fedsgd")?;
    let (r2, _) = run_one(engine, &fed, &fedavg, opts, "fig4-fedavg")?;
    println!(
        "  FedSGD final {:.3}; FedAvg final {:.3}",
        r1.final_accuracy(),
        r2.final_accuracy()
    );
    Ok(())
}

/// Figure 5 — large-scale word LM: FedAvg vs FedSGD at their best rates
/// (paper: FedSGD η=18, FedAvg η=9, 200 clients/round, E=1, B=8).
pub fn figure5(engine: &Engine, opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 5 — large-scale word-LSTM ==");
    if engine.manifest().model("word_lstm").is_err() {
        println!("  SKIP: word_lstm artifacts missing — run `make artifacts-full`");
        return Ok(());
    }
    let fed = social_fed(opts.scale, opts.seed);
    let k = fed.num_clients();
    let c = (200.0 / k as f64).min(1.0); // paper: 200 clients/round
    let fedsgd = FedConfig {
        model: "word_lstm".into(),
        c,
        lr: 18.0,
        rounds: opts.rounds,
        eval_every: 2,
        seed: opts.seed,
        ..Default::default()
    }
    .fedsgd();
    let fedavg = FedConfig {
        model: "word_lstm".into(),
        c,
        e: 1,
        b: BatchSize::Fixed(8),
        lr: 9.0,
        rounds: opts.rounds,
        eval_every: 2,
        seed: opts.seed,
        ..Default::default()
    };
    let (r1, _) = run_one(engine, &fed, &fedsgd, opts, "fig5-fedsgd")?;
    let (r2, _) = run_one(engine, &fed, &fedavg, opts, "fig5-fedavg")?;
    println!(
        "  FedSGD final {:.4}; FedAvg final {:.4}",
        r1.final_accuracy(),
        r2.final_accuracy()
    );
    Ok(())
}

/// Figure 6 — MNIST CNN *training loss* vs rounds (log-y in the paper).
pub fn figure6(engine: &Engine, opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 6 — training-loss convergence (MNIST CNN) ==");
    let mut rows = Vec::new();
    for (pname, part) in [("iid", Partition::Iid), ("noniid", Partition::Pathological(2))] {
        for (e, b, label) in [
            (1usize, BatchSize::Full, "fedsgd"),
            (5, BatchSize::Fixed(10), "fedavg-E5-B10"),
        ] {
            let fed = mnist_fed(opts.scale, part, opts.seed);
            let cfg = FedConfig {
                model: "mnist_cnn".into(),
                c: 0.1,
                e,
                b,
                lr: 0.1,
                rounds: opts.rounds,
                track_train_loss: true,
                seed: opts.seed,
                ..Default::default()
            };
            let (res, _) = run_one(engine, &fed, &cfg, opts, &format!("fig6-{pname}-{label}"))?;
            let tl = res.train_loss.as_ref().expect("tracked");
            for &(r, v) in tl.points() {
                rows.push(format!("{pname}-{label},{r},{v:.6}"));
            }
            println!(
                "  {pname}-{label:<14} final train loss {:.4}",
                tl.last_value().unwrap_or(f64::NAN)
            );
        }
    }
    curve_csv(opts, "fig6-train-loss", "series,round,train_loss", &rows)
}

/// Figure 7 — 2NN accuracy curves, IID and non-IID (appendix).
pub fn figure7(engine: &Engine, opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 7 — MNIST 2NN curves ==");
    for (pname, part) in [("iid", Partition::Iid), ("noniid", Partition::Pathological(2))] {
        for (e, b, label) in [
            (1usize, BatchSize::Full, "fedsgd"),
            (10, BatchSize::Fixed(10), "fedavg-E10-B10"),
        ] {
            let fed = mnist_fed(opts.scale, part, opts.seed);
            let cfg = FedConfig {
                model: "mnist_2nn".into(),
                c: 0.1,
                e,
                b,
                lr: 0.1,
                rounds: opts.rounds,
                seed: opts.seed,
                ..Default::default()
            };
            let (res, _) = run_one(engine, &fed, &cfg, opts, &format!("fig7-{pname}-{label}"))?;
            println!("  {pname}-{label:<15} final acc {:.3}", res.final_accuracy());
        }
    }
    Ok(())
}

/// Figure 8 — large-E training loss for the MNIST CNN (appendix).
pub fn figure8(engine: &Engine, opts: &ExpOptions, args: &Args) -> Result<()> {
    println!("\n== Figure 8 — effect of large E (MNIST CNN, train loss) ==");
    let evals = args.str_or("e-values", "1,5,20,50");
    let mut rows = Vec::new();
    for (pname, part) in [("iid", Partition::Iid), ("noniid", Partition::Pathological(2))] {
        let fed = mnist_fed(opts.scale, part, opts.seed);
        for e in evals.split(',') {
            let e: usize = e.parse()?;
            let cfg = FedConfig {
                model: "mnist_cnn".into(),
                c: 0.1,
                e,
                b: BatchSize::Fixed(10),
                lr: 0.1,
                rounds: opts.rounds,
                track_train_loss: true,
                seed: opts.seed,
                ..Default::default()
            };
            let (res, _) =
                run_one(engine, &fed, &cfg, opts, &format!("fig8-{pname}-E{e}"))?;
            let tl = res.train_loss.as_ref().expect("tracked");
            for &(r, v) in tl.points() {
                rows.push(format!("{pname},{e},{r},{v:.6}"));
            }
            println!(
                "  {pname} E={e:<4} final train loss {:.4}",
                tl.last_value().unwrap_or(f64::NAN)
            );
        }
    }
    curve_csv(opts, "fig8-large-E-cnn", "partition,E,round,train_loss", &rows)
}

/// Figure 9 — accuracy vs number of minibatch gradient computations
/// (B=50): sequential SGD vs FedAvg at various (C, E).
pub fn figure9(engine: &Engine, opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 9 — progress per minibatch computation (CIFAR) ==");
    let fed = cifar_fed(opts.scale, opts.seed);
    let mut rows = Vec::new();

    let sgd_cfg = SgdConfig {
        model: "cifar_cnn".into(),
        batch: 50,
        lr: 0.1,
        lr_decay: 1.0,
        updates: opts.rounds * 10,
        eval_every: (opts.rounds / 4).max(1),
        target_accuracy: None,
        seed: opts.seed,
    };
    let sgd_res = sgd::run(engine, &fed.train, &fed.test, &sgd_cfg, Some(opts.eval_cap))?;
    for &(u, v) in sgd_res.accuracy.points() {
        rows.push(format!("sgd,{u},{v:.5}"));
    }
    println!(
        "  SGD: final acc {:.3} after {} updates",
        sgd_res.accuracy.last_value().unwrap_or(0.0),
        sgd_res.updates_run
    );

    let nk = fed.total_examples() / fed.num_clients();
    for (c, e) in [(0.0, 1usize), (0.1, 1), (0.1, 5)] {
        let cfg = FedConfig {
            model: "cifar_cnn".into(),
            c,
            e,
            b: BatchSize::Fixed(50),
            lr: 0.1,
            rounds: opts.rounds,
            seed: opts.seed,
            ..Default::default()
        };
        let (res, _) = run_one(engine, &fed, &cfg, opts, &format!("fig9-C{c}-E{e}"))?;
        // x-axis: minibatch grads = round * m * u_k
        let m = cfg.clients_per_round(fed.num_clients());
        let per_round = updates_per_round(e, nk, cfg.b) * m as f64;
        for &(r, v) in res.accuracy.points() {
            rows.push(format!("fedavg-C{c}-E{e},{:.0},{v:.5}", r as f64 * per_round));
        }
        println!(
            "  FedAvg C={c} E={e}: final acc {:.3} ({:.0} grads/round)",
            res.final_accuracy(),
            per_round
        );
    }
    curve_csv(opts, "fig9-minibatch-grads", "series,minibatch_grads,test_accuracy", &rows)
}

/// Figure 10 — word-LSTM: E=1 vs E=5 and accuracy variance over rounds.
pub fn figure10(engine: &Engine, opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 10 — word-LSTM E=1 vs E=5 ==");
    if engine.manifest().model("word_lstm").is_err() {
        println!("  SKIP: word_lstm artifacts missing — run `make artifacts-full`");
        return Ok(());
    }
    let fed = social_fed(opts.scale, opts.seed);
    let k = fed.num_clients();
    let mut rows = Vec::new();
    for e in [1usize, 5] {
        let cfg = FedConfig {
            model: "word_lstm".into(),
            c: (200.0 / k as f64).min(1.0),
            e,
            b: BatchSize::Fixed(8),
            lr: 9.0,
            rounds: opts.rounds,
            eval_every: 2, // paper evaluates every 20 rounds at full scale
            seed: opts.seed,
            ..Default::default()
        };
        let (res, _) = run_one(engine, &fed, &cfg, opts, &format!("fig10-E{e}"))?;
        // variance of accuracy across eval points after warmup
        let pts: Vec<f64> = res.accuracy.points().iter().map(|&(_, v)| v).collect();
        let tail = &pts[pts.len() / 2..];
        let mean = tail.iter().sum::<f64>() / tail.len().max(1) as f64;
        let var = tail.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / tail.len().max(1) as f64;
        for &(r, v) in res.accuracy.points() {
            rows.push(format!("E{e},{r},{v:.5}"));
        }
        println!("  E={e}: final acc {:.4}, tail var {var:.2e}", res.final_accuracy());
    }
    curve_csv(opts, "fig10-word-lstm", "series,round,test_accuracy", &rows)
}
