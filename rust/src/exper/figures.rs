//! Figure drivers — regenerate the data series behind every figure in the
//! paper (1-10). Each figure declares its cells into one shared grid
//! (`fedavg figure all` runs everything in one restartable, parallel
//! sweep — DESIGN.md §9), then writes CSVs under `runs/figN-*/` and
//! prints a compact summary; DESIGN.md §3 maps figure → experiment.
//! Series files are assembled from the cells' recorded curves, so a
//! resumed grid reproduces them byte-for-byte.

use crate::baselines::sgd::SgdConfig;
use crate::config::{BatchSize, FedConfig, Partition};
use crate::federated::updates_per_round;
use crate::runtime::Engine;
use crate::util::args::Args;
use crate::Result;

use super::cells::{FedCell, GridCell, InterpCell, SgdCell, Workload};
use super::grid::{self, CellOutcome, GridDef};
use super::{ExpOptions, COMMON_FLAGS};

pub fn run(engine: &Engine, args: &Args) -> Result<()> {
    args.check_known(&[COMMON_FLAGS, &["e-values"]].concat())?;
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = ExpOptions::from_args(args)?;
    let figs: Vec<u32> = if which == "all" {
        vec![1, 2, 3, 4, 6, 7, 8, 9] // 5 & 10 need word_lstm artifacts
    } else {
        vec![which.parse()?]
    };

    // one grid for the whole invocation: cells across figures dedupe
    // against each other and the shared pool, and run in parallel
    let mut def = GridDef::new(format!("figures-{which}"));
    let mut plan: Vec<(u32, usize)> = Vec::new();
    let mut social_k: Option<usize> = None;
    for &f in &figs {
        let before = def.len();
        declare(f, &mut def, engine, &opts, args, &mut social_k)?;
        plan.push((f, def.len() - before));
    }
    let Some(report) = grid::run(def, Some(engine), &opts.grid_options())? else {
        return Ok(()); // --dry-run
    };
    let mut off = 0;
    for (f, n) in plan {
        format_figure(f, &report.outcomes[off..off + n], &opts, args)?;
        off += n;
    }
    Ok(())
}

/// Client count of the Social workload, built at most once per
/// invocation — Figures 5 and 10 both need K for `C = 200/K`, and the
/// fingerprinted configs must be identical whether or not the cells are
/// cached, so even `--dry-run` pays (one) build when they are declared.
fn social_clients(opts: &ExpOptions, memo: &mut Option<usize>) -> usize {
    *memo.get_or_insert_with(|| {
        Workload::Social {
            scale: opts.scale,
            seed: opts.seed,
        }
        .build()
        .num_clients()
    })
}

fn declare(
    f: u32,
    def: &mut GridDef<GridCell>,
    engine: &Engine,
    opts: &ExpOptions,
    args: &Args,
    social_k: &mut Option<usize>,
) -> Result<()> {
    match f {
        1 => def.cell(
            "fig1-interp",
            GridCell::Interp(InterpCell {
                scale: opts.scale,
                seed: opts.seed,
            }),
        ),
        2 => {
            for (label, workload, cfg) in fig2_list(opts) {
                def.cell(
                    format!("fig2-{label}"),
                    GridCell::Fed(FedCell::new(workload, cfg, opts.eval_cap)),
                );
            }
        }
        3 => {
            for (e, cfg) in fig3_list(opts, args)? {
                def.cell(
                    format!("fig3-E{e}"),
                    GridCell::Fed(FedCell::new(
                        Workload::Shakespeare {
                            scale: opts.scale,
                            natural: true,
                            seed: opts.seed,
                        },
                        cfg,
                        opts.eval_cap,
                    )),
                );
            }
        }
        4 => {
            for (label, cfg) in fig4_list(opts) {
                def.cell(
                    format!("fig4-{label}"),
                    GridCell::Fed(FedCell::new(
                        Workload::Cifar {
                            scale: opts.scale,
                            seed: opts.seed,
                        },
                        cfg,
                        opts.eval_cap,
                    )),
                );
            }
        }
        5 => {
            if word_lstm_ready(engine) {
                let k = social_clients(opts, social_k);
                for (label, cfg) in fig5_list(opts, k) {
                    def.cell(
                        format!("fig5-{label}"),
                        GridCell::Fed(FedCell::new(
                            Workload::Social {
                                scale: opts.scale,
                                seed: opts.seed,
                            },
                            cfg,
                            opts.eval_cap,
                        )),
                    );
                }
            }
        }
        6 | 7 | 8 => {
            for (pname, part, label, cfg) in mnist_series_list(f, opts, args)? {
                def.cell(
                    format!("fig{f}-{pname}-{label}"),
                    GridCell::Fed(FedCell::new(
                        Workload::Mnist {
                            scale: opts.scale,
                            part,
                            seed: opts.seed,
                        },
                        cfg,
                        opts.eval_cap,
                    )),
                );
            }
        }
        9 => {
            let (sgd_cfg, fed_cfgs) = fig9_list(opts);
            def.cell(
                "fig9-sgd",
                GridCell::Sgd(SgdCell {
                    workload: Workload::Cifar {
                        scale: opts.scale,
                        seed: opts.seed,
                    },
                    cfg: sgd_cfg,
                    eval_cap: opts.eval_cap,
                }),
            );
            for (c, e, cfg) in fed_cfgs {
                def.cell(
                    format!("fig9-C{c}-E{e}"),
                    GridCell::Fed(FedCell::new(
                        Workload::Cifar {
                            scale: opts.scale,
                            seed: opts.seed,
                        },
                        cfg,
                        opts.eval_cap,
                    )),
                );
            }
        }
        10 => {
            if word_lstm_ready(engine) {
                let k = social_clients(opts, social_k);
                for (e, cfg) in fig10_list(opts, k) {
                    def.cell(
                        format!("fig10-E{e}"),
                        GridCell::Fed(FedCell::new(
                            Workload::Social {
                                scale: opts.scale,
                                seed: opts.seed,
                            },
                            cfg,
                            opts.eval_cap,
                        )),
                    );
                }
            }
        }
        other => anyhow::bail!("no figure {other}"),
    }
    Ok(())
}

fn word_lstm_ready(engine: &Engine) -> bool {
    engine.manifest().model("word_lstm").is_ok()
}

fn curve_csv(opts: &ExpOptions, name: &str, header: &str, rows: &[String]) -> Result<()> {
    let dir = std::path::Path::new(&opts.out_root).join(name);
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("series.csv");
    let mut out = String::from(header);
    out.push('\n');
    for r in rows {
        out.push_str(r);
        out.push('\n');
    }
    std::fs::write(&path, out)?;
    println!("  -> {}", path.display());
    Ok(())
}

// ------------------------------------------------------- cell list builders
// Each list is built identically by the declaration and formatting
// passes, so outcome slices line up with labels by construction.

/// Figure 2 — MNIST CNN (IID + non-IID) and Shakespeare LSTM (IID +
/// by-role), FedSGD vs FedAvg(E=5, B=10), C=0.1.
fn fig2_list(opts: &ExpOptions) -> Vec<(String, Workload, FedConfig)> {
    let mut runs = Vec::new();
    for (pname, part) in [("iid", Partition::Iid), ("noniid", Partition::Pathological(2))] {
        for (e, b, label) in [
            (1usize, BatchSize::Full, "fedsgd"),
            (5, BatchSize::Fixed(10), "fedavg-E5-B10"),
        ] {
            runs.push((
                format!("cnn-{pname}-{label}"),
                Workload::Mnist {
                    scale: opts.scale,
                    part,
                    seed: opts.seed,
                },
                FedConfig {
                    model: "mnist_cnn".into(),
                    c: 0.1,
                    e,
                    b,
                    lr: 0.1,
                    rounds: opts.rounds,
                    seed: opts.seed,
                    ..Default::default()
                },
            ));
        }
    }
    for (natural, pname) in [(false, "iid"), (true, "role")] {
        for (e, b, label) in [
            (1usize, BatchSize::Full, "fedsgd"),
            (5, BatchSize::Fixed(10), "fedavg-E5-B10"),
        ] {
            runs.push((
                format!("lstm-{pname}-{label}"),
                Workload::Shakespeare {
                    scale: opts.scale,
                    natural,
                    seed: opts.seed,
                },
                FedConfig {
                    model: "shakespeare_lstm".into(),
                    c: 0.1,
                    e,
                    b,
                    lr: 1.0,
                    rounds: opts.rounds,
                    seed: opts.seed,
                    ..Default::default()
                },
            ));
        }
    }
    runs
}

/// Figure 3 — many local epochs on the Shakespeare LSTM (B=10, C=0.1,
/// fixed η = 1.47, the paper's rate for this figure).
fn fig3_list(opts: &ExpOptions, args: &Args) -> Result<Vec<(usize, FedConfig)>> {
    let evals = args.str_or("e-values", "1,5,20,50");
    evals
        .split(',')
        .map(|e| {
            let e: usize = e.parse()?;
            Ok((
                e,
                FedConfig {
                    model: "shakespeare_lstm".into(),
                    c: 0.1,
                    e,
                    b: BatchSize::Fixed(10),
                    lr: 1.47,
                    rounds: opts.rounds,
                    seed: opts.seed,
                    ..Default::default()
                },
            ))
        })
        .collect()
}

/// Figure 4 — CIFAR FedAvg(E=5,B=50,decay .99) vs FedSGD(decay .9934).
fn fig4_list(opts: &ExpOptions) -> Vec<(&'static str, FedConfig)> {
    let fedsgd = FedConfig {
        model: "cifar_cnn".into(),
        c: 0.1,
        lr: 0.1,
        lr_decay: 0.9934,
        rounds: opts.rounds,
        seed: opts.seed,
        ..Default::default()
    }
    .fedsgd();
    let fedavg = FedConfig {
        model: "cifar_cnn".into(),
        c: 0.1,
        e: 5,
        b: BatchSize::Fixed(50),
        lr: 0.1,
        lr_decay: 0.99,
        rounds: opts.rounds,
        seed: opts.seed,
        ..Default::default()
    };
    vec![("fedsgd", fedsgd), ("fedavg", fedavg)]
}

/// Figure 5 — large-scale word LM at the paper's best rates (FedSGD
/// η=18, FedAvg η=9, 200 clients/round, E=1, B=8).
fn fig5_list(opts: &ExpOptions, k: usize) -> Vec<(&'static str, FedConfig)> {
    let c = (200.0 / k as f64).min(1.0); // paper: 200 clients/round
    let fedsgd = FedConfig {
        model: "word_lstm".into(),
        c,
        lr: 18.0,
        rounds: opts.rounds,
        eval_every: 2,
        seed: opts.seed,
        ..Default::default()
    }
    .fedsgd();
    let fedavg = FedConfig {
        model: "word_lstm".into(),
        c,
        e: 1,
        b: BatchSize::Fixed(8),
        lr: 9.0,
        rounds: opts.rounds,
        eval_every: 2,
        seed: opts.seed,
        ..Default::default()
    };
    vec![("fedsgd", fedsgd), ("fedavg", fedavg)]
}

/// Figures 6/7/8 — the MNIST series: per-partition FedSGD/FedAvg curves
/// (6: CNN train loss; 7: 2NN accuracy; 8: CNN large-E train loss).
type MnistSeries = (&'static str, Partition, String, FedConfig);

fn mnist_series_list(f: u32, opts: &ExpOptions, args: &Args) -> Result<Vec<MnistSeries>> {
    let mut out = Vec::new();
    for (pname, part) in [("iid", Partition::Iid), ("noniid", Partition::Pathological(2))] {
        match f {
            6 | 7 => {
                let (model, alt): (&str, (usize, BatchSize, &str)) = if f == 6 {
                    ("mnist_cnn", (5, BatchSize::Fixed(10), "fedavg-E5-B10"))
                } else {
                    ("mnist_2nn", (10, BatchSize::Fixed(10), "fedavg-E10-B10"))
                };
                for (e, b, label) in [(1usize, BatchSize::Full, "fedsgd"), alt] {
                    out.push((
                        pname,
                        part,
                        label.to_string(),
                        FedConfig {
                            model: model.into(),
                            c: 0.1,
                            e,
                            b,
                            lr: 0.1,
                            rounds: opts.rounds,
                            track_train_loss: f == 6,
                            seed: opts.seed,
                            ..Default::default()
                        },
                    ));
                }
            }
            8 => {
                let evals = args.str_or("e-values", "1,5,20,50");
                for e in evals.split(',') {
                    let e: usize = e.parse()?;
                    out.push((
                        pname,
                        part,
                        format!("E{e}"),
                        FedConfig {
                            model: "mnist_cnn".into(),
                            c: 0.1,
                            e,
                            b: BatchSize::Fixed(10),
                            lr: 0.1,
                            rounds: opts.rounds,
                            track_train_loss: true,
                            seed: opts.seed,
                            ..Default::default()
                        },
                    ));
                }
            }
            _ => unreachable!("mnist series covers figures 6-8"),
        }
    }
    Ok(out)
}

/// Figure 9 — progress per minibatch gradient computation (B=50).
fn fig9_list(opts: &ExpOptions) -> (SgdConfig, Vec<(f64, usize, FedConfig)>) {
    let sgd_cfg = SgdConfig {
        model: "cifar_cnn".into(),
        batch: 50,
        lr: 0.1,
        lr_decay: 1.0,
        updates: opts.rounds * 10,
        eval_every: (opts.rounds / 4).max(1),
        target_accuracy: None,
        seed: opts.seed,
    };
    let fed_cfgs = [(0.0, 1usize), (0.1, 1), (0.1, 5)]
        .into_iter()
        .map(|(c, e)| {
            (
                c,
                e,
                FedConfig {
                    model: "cifar_cnn".into(),
                    c,
                    e,
                    b: BatchSize::Fixed(50),
                    lr: 0.1,
                    rounds: opts.rounds,
                    seed: opts.seed,
                    ..Default::default()
                },
            )
        })
        .collect();
    (sgd_cfg, fed_cfgs)
}

/// Figure 10 — word-LSTM E=1 vs E=5.
fn fig10_list(opts: &ExpOptions, k: usize) -> Vec<(usize, FedConfig)> {
    [1usize, 5]
        .into_iter()
        .map(|e| {
            (
                e,
                FedConfig {
                    model: "word_lstm".into(),
                    c: (200.0 / k as f64).min(1.0),
                    e,
                    b: BatchSize::Fixed(8),
                    lr: 9.0,
                    rounds: opts.rounds,
                    eval_every: 2, // paper evaluates every 20 rounds at full scale
                    seed: opts.seed,
                    ..Default::default()
                },
            )
        })
        .collect()
}

// -------------------------------------------------------------- formatters

fn format_figure(f: u32, outs: &[CellOutcome], opts: &ExpOptions, args: &Args) -> Result<()> {
    match f {
        1 => format_fig1(outs, opts),
        2 => format_fig2(outs, opts),
        3 => format_fig3(outs, opts, args),
        4 => format_fig4(outs),
        5 => format_fig5(outs),
        6 => format_fig6(outs, opts, args),
        7 => format_fig7(outs, opts, args),
        8 => format_fig8(outs, opts, args),
        9 => format_fig9(outs, opts),
        10 => format_fig10(outs, opts),
        other => anyhow::bail!("no figure {other}"),
    }
}

/// Figure 1 — loss of θ·w + (1−θ)·w' for models trained from shared vs
/// independent initialization (the averaging-works phenomenon).
fn format_fig1(outs: &[CellOutcome], opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 1 — parameter-averaging interpolation ==");
    let out = &outs[0];
    let mut rows = Vec::new();
    for tag in ["independent", "shared"] {
        let parent_best = out.num(&format!("{tag}_parent_best")).unwrap_or(f64::NAN);
        let min_mix = out.num(&format!("{tag}_best_mix")).unwrap_or(f64::NAN);
        for &(theta, l) in out.curve(tag).unwrap_or(&[]) {
            rows.push(format!("{tag},{theta:.4},{l:.6}"));
        }
        println!(
            "  {tag:<12} parents' best loss {parent_best:.4}; best mixture {min_mix:.4} {}",
            if min_mix < parent_best {
                "(averaging helps ✓)"
            } else {
                "(averaging hurts)"
            }
        );
    }
    curve_csv(opts, "fig1-interpolation", "init,theta,train_loss", &rows)
}

fn format_fig2(outs: &[CellOutcome], opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 2 — accuracy vs communication rounds ==");
    for ((label, _, _), out) in fig2_list(opts).iter().zip(outs) {
        println!(
            "  {label:<24} final acc {:.3} (best {:.3})",
            out.num("final_acc").unwrap_or(0.0),
            out.num("best_acc").unwrap_or(0.0)
        );
    }
    Ok(())
}

fn format_fig3(outs: &[CellOutcome], opts: &ExpOptions, args: &Args) -> Result<()> {
    println!("\n== Figure 3 — effect of large E (Shakespeare LSTM) ==");
    let mut rows = Vec::new();
    for ((e, _), out) in fig3_list(opts, args)?.iter().zip(outs) {
        for &(r, v) in out.curve("accuracy").unwrap_or(&[]) {
            rows.push(format!("{e},{r},{v:.5}"));
        }
        println!(
            "  E={e:<4} final acc {:.3}",
            out.num("final_acc").unwrap_or(0.0)
        );
    }
    curve_csv(opts, "fig3-large-E", "E,round,test_accuracy", &rows)
}

fn format_fig4(outs: &[CellOutcome]) -> Result<()> {
    println!("\n== Figure 4 — CIFAR FedAvg vs FedSGD ==");
    println!(
        "  FedSGD final {:.3}; FedAvg final {:.3}",
        outs[0].num("final_acc").unwrap_or(0.0),
        outs[1].num("final_acc").unwrap_or(0.0)
    );
    Ok(())
}

fn format_fig5(outs: &[CellOutcome]) -> Result<()> {
    println!("\n== Figure 5 — large-scale word-LSTM ==");
    if outs.is_empty() {
        println!("  SKIP: word_lstm artifacts missing — run `make artifacts-full`");
        return Ok(());
    }
    println!(
        "  FedSGD final {:.4}; FedAvg final {:.4}",
        outs[0].num("final_acc").unwrap_or(0.0),
        outs[1].num("final_acc").unwrap_or(0.0)
    );
    Ok(())
}

/// Figure 6 — MNIST CNN *training loss* vs rounds (log-y in the paper).
fn format_fig6(outs: &[CellOutcome], opts: &ExpOptions, args: &Args) -> Result<()> {
    println!("\n== Figure 6 — training-loss convergence (MNIST CNN) ==");
    let mut rows = Vec::new();
    for ((pname, _, label, _), out) in mnist_series_list(6, opts, args)?.iter().zip(outs) {
        let tl = out.curve("train_loss").unwrap_or(&[]);
        for &(r, v) in tl {
            rows.push(format!("{pname}-{label},{r},{v:.6}"));
        }
        println!(
            "  {pname}-{label:<14} final train loss {:.4}",
            tl.last().map(|&(_, v)| v).unwrap_or(f64::NAN)
        );
    }
    curve_csv(opts, "fig6-train-loss", "series,round,train_loss", &rows)
}

/// Figure 7 — 2NN accuracy curves, IID and non-IID (appendix).
fn format_fig7(outs: &[CellOutcome], opts: &ExpOptions, args: &Args) -> Result<()> {
    println!("\n== Figure 7 — MNIST 2NN curves ==");
    for ((pname, _, label, _), out) in mnist_series_list(7, opts, args)?.iter().zip(outs) {
        println!(
            "  {pname}-{label:<15} final acc {:.3}",
            out.num("final_acc").unwrap_or(0.0)
        );
    }
    Ok(())
}

/// Figure 8 — large-E training loss for the MNIST CNN (appendix).
fn format_fig8(outs: &[CellOutcome], opts: &ExpOptions, args: &Args) -> Result<()> {
    println!("\n== Figure 8 — effect of large E (MNIST CNN, train loss) ==");
    let mut rows = Vec::new();
    for ((pname, _, label, _), out) in mnist_series_list(8, opts, args)?.iter().zip(outs) {
        let e = label.trim_start_matches('E');
        let tl = out.curve("train_loss").unwrap_or(&[]);
        for &(r, v) in tl {
            rows.push(format!("{pname},{e},{r},{v:.6}"));
        }
        println!(
            "  {pname} E={e:<4} final train loss {:.4}",
            tl.last().map(|&(_, v)| v).unwrap_or(f64::NAN)
        );
    }
    curve_csv(opts, "fig8-large-E-cnn", "partition,E,round,train_loss", &rows)
}

/// Figure 9 — accuracy vs number of minibatch gradient computations
/// (B=50): sequential SGD vs FedAvg at various (C, E).
fn format_fig9(outs: &[CellOutcome], opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 9 — progress per minibatch computation (CIFAR) ==");
    let (_sgd_cfg, fed_cfgs) = fig9_list(opts);
    let mut rows = Vec::new();

    let sgd = &outs[0];
    for &(u, v) in sgd.curve("accuracy").unwrap_or(&[]) {
        rows.push(format!("sgd,{u},{v:.5}"));
    }
    println!(
        "  SGD: final acc {:.3} after {} updates",
        sgd.num("final_acc").unwrap_or(0.0),
        sgd.int("updates_run").unwrap_or(0)
    );

    for ((c, e, cfg), out) in fed_cfgs.iter().zip(&outs[1..]) {
        // x-axis: minibatch grads = round * m * u_k, with n/K and m from
        // the cell's recorded population (exact integers)
        let k = out.int("clients_total").unwrap_or(1).max(1) as usize;
        let nk = out.int("examples_total").unwrap_or(0) as usize / k;
        let m = cfg.clients_per_round(k);
        let per_round = updates_per_round(*e, nk, cfg.b) * m as f64;
        for &(r, v) in out.curve("accuracy").unwrap_or(&[]) {
            rows.push(format!("fedavg-C{c}-E{e},{:.0},{v:.5}", r * per_round));
        }
        println!(
            "  FedAvg C={c} E={e}: final acc {:.3} ({per_round:.0} grads/round)",
            out.num("final_acc").unwrap_or(0.0)
        );
    }
    curve_csv(opts, "fig9-minibatch-grads", "series,minibatch_grads,test_accuracy", &rows)
}

/// Figure 10 — word-LSTM: E=1 vs E=5 and accuracy variance over rounds.
fn format_fig10(outs: &[CellOutcome], opts: &ExpOptions) -> Result<()> {
    println!("\n== Figure 10 — word-LSTM E=1 vs E=5 ==");
    if outs.is_empty() {
        println!("  SKIP: word_lstm artifacts missing — run `make artifacts-full`");
        return Ok(());
    }
    let mut rows = Vec::new();
    // plain E values — fig10_list would rebuild the whole Social corpus
    // just to fill a config field this pass never reads
    for (&e, out) in [1usize, 5].iter().zip(outs) {
        let pts: Vec<f64> = out
            .curve("accuracy")
            .unwrap_or(&[])
            .iter()
            .map(|&(_, v)| v)
            .collect();
        let tail = &pts[pts.len() / 2..];
        // lint:allow(float-fold): figure post-processing over an already-recorded curve, in row order — reporting only, never part of a trajectory.
        let mean = tail.iter().sum::<f64>() / tail.len().max(1) as f64;
        let var =
            // lint:allow(float-fold): same reporting-only fold over the recorded curve tail.
            tail.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / tail.len().max(1) as f64;
        for &(r, v) in out.curve("accuracy").unwrap_or(&[]) {
            rows.push(format!("E{e},{r},{v:.5}"));
        }
        println!(
            "  E={e}: final acc {:.4}, tail var {var:.2e}",
            out.num("final_acc").unwrap_or(0.0)
        );
    }
    curve_csv(opts, "fig10-word-lstm", "series,round,test_accuracy", &rows)
}
