//! Run-state checkpointing — crash-safe training with a bit-identical
//! resume guarantee (DESIGN.md §8).
//!
//! The paper's experiments run hundreds to thousands of communication
//! rounds; a production federated system (Bonawitz et al., "Towards
//! Federated Learning at Scale") treats server restarts as routine, not
//! fatal. This module makes a training run durable: at a configurable
//! round cadence the server serializes **all** of its mutable round
//! state into a [`Snapshot`] — a versioned, checksummed binary file
//! written atomically under `runs/<name>/checkpoints/` — and a later
//! invocation with `--resume` continues the run as if it had never
//! stopped.
//!
//! The contract is strict **bit-identity**: running `2R` rounds yields
//! byte-for-byte the same `curve.csv` as running `R` rounds,
//! checkpointing, and resuming for `R` more (regression-tested in
//! `rust/tests/runstate.rs`). That only holds because the snapshot
//! covers every stateful subsystem the round loop touches:
//!
//! | state | lives in | snapshot section |
//! |-------|----------|------------------|
//! | global model θ, round index, client-step counter | `federated::server` | `MODEL`, `SCHED` |
//! | client-selection RNG stream | [`ClientSampler`] | `SAMPLER` |
//! | server-optimizer moments (fedavgm/fedadam) | [`Aggregator::state_save`] | `AGG` |
//! | error-feedback residuals, model-store ring + acks, quantizer RNG | [`Transport`] | `TRANSPORT` |
//! | byte/wall-clock totals + jitter RNG | [`CommSim`] | `COMMS` |
//! | fleet totals + pending telemetry counters | `coordinator` | `FLEET` |
//! | learning curves (accuracy/loss) | `metrics` | `CURVES` |
//! | DP noise stream + ε accounting | [`GaussianMechanism`] | `DP` |
//! | edge-tier byte/latency totals (`--shards`) | `federated::server` | `TIER` |
//! | apply counter + async buffer + late queue (`--async-buffer` / `--late-policy`) | `federated::server` | `ASYNC` |
//!
//! What is deliberately *not* captured: anything that is a pure function
//! of config — device profiles and the diurnal clock
//! ([`Fleet`](crate::coordinator::Fleet) rebuilds from `(seed, client)`
//! hashes), the availability coin, the secure-aggregation masks (session
//! seed), the lr schedule (function of the round index) — and anything
//! mid-round: checkpoints are taken only at round boundaries, so a kill
//! mid-round replays that round from its start (mid-round preemption is
//! a ROADMAP open item).
//!
//! Snapshots follow the `--checkpoint-every` cadence, **plus** a
//! terminal snapshot at the last executed round (final round or early
//! stop): a finished run can be *extended* — `--resume` with a larger
//! `--rounds` — without replaying a single round.
//!
//! The [`obs`](crate::obs) metrics registry (DESIGN.md §10) adds no
//! section of its own: on resume the server re-seeds its counters (wire
//! bytes, client steps, fleet drops/misses, rounds) from the
//! `SCHED`/`COMMS`/`FLEET` sections that already carry the same totals,
//! so resumed runs report cumulative metrics with an unchanged snapshot
//! format.
//!
//! On resume the snapshot's [`RunMeta`] fingerprint is checked against
//! the current invocation (model/C/E/B/lr label, aggregation rule, codec
//! pair, seed, client count, parameter count, lr decay, eval cadence) so
//! a checkpoint cannot be silently resumed under a different
//! configuration, and [`RunWriter::reopen`](crate::telemetry::RunWriter::reopen)
//! truncates `curve.csv` back to the checkpointed round so the curve
//! never contains rows from a lost future.
//!
//! The building blocks are shared: [`atomic_write`] (tmp + fsync +
//! rename) and the [`fnv1a64`] fingerprint hash also back the grid
//! engine's sweep manifests ([`exper::grid`](crate::exper::grid),
//! DESIGN.md §9).
//!
//! [`ClientSampler`]: crate::federated::ClientSampler
//! [`Aggregator::state_save`]: crate::federated::aggregate::Aggregator::state_save
//! [`Transport`]: crate::comms::Transport
//! [`CommSim`]: crate::comms::CommSim
//! [`GaussianMechanism`]: crate::privacy::GaussianMechanism

mod snapshot;

pub use snapshot::{
    atomic_write, checkpoint_dir, fnv1a64, AggState, AsyncState, BufferedDelta, CurveState,
    FleetState, RunMeta, Snapshot, TierState, MAGIC, SNAP_VERSION,
};

/// A resume request carried in
/// [`ServerOptions`](crate::federated::ServerOptions): the loaded
/// snapshot plus the run directory it came from. The server opens the
/// run's telemetry itself — **after** the fingerprint checks pass — via
/// [`RunWriter::reopen`](crate::telemetry::RunWriter::reopen), so a
/// refused resume (wrong flags, stale `--rounds`) never truncates the
/// original run's curve.
#[derive(Debug)]
pub struct ResumeFrom {
    pub snapshot: Snapshot,
    pub run_dir: std::path::PathBuf,
}

/// Checkpoint cadence knobs (`--checkpoint-every` / `--checkpoint-keep`),
/// carried in [`ServerOptions`](crate::federated::ServerOptions).
#[derive(Debug, Clone, Copy)]
pub struct CheckpointConfig {
    /// Write a snapshot every `every` rounds (≥ 1).
    pub every: u64,
    /// Retain the newest `keep` snapshots (≥ 1); older ones are deleted
    /// after each successful write.
    pub keep: usize,
}

impl CheckpointConfig {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.every >= 1, "--checkpoint-every must be >= 1");
        anyhow::ensure!(self.keep >= 1, "--checkpoint-keep must be >= 1");
        Ok(())
    }
}
