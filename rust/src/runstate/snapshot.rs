//! The snapshot file format + atomic write / validated read / rotation.
//!
//! Layout (all little-endian, DESIGN.md §8), mirroring the frame
//! discipline of [`comms::wire`](crate::comms::wire): a fixed
//! self-describing header, then a payload of tagged sections.
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "FCKP"
//!      4     1  format version (2)
//!      5     3  reserved (zero)
//!      8     8  round the snapshot was taken after
//!     16     8  payload length in bytes
//!     24     8  FNV-1a 64 checksum of the payload
//!     32     …  payload: sections of (id:u16, len:u64, body)
//! ```
//!
//! A reader validates magic, version, *exact* length (truncation and
//! trailing garbage both fail), and checksum before decoding a single
//! section; section bodies are decoded with bounds-checked reads and
//! must consume exactly their declared length. Unknown section ids are
//! skipped, so older readers tolerate additive format growth. The result
//! is the property the resume path depends on: a snapshot either loads
//! completely or not at all.
//!
//! Format v2 shrinks the TRANSPORT section: the model-store ring used to
//! hold up to `store_cap` *dense* θ copies; now only the newest retained
//! version is stored dense (as a self-describing wire frame), and every
//! older version ships as an overwrite patch against it through the
//! transport's own delta machinery ([`comms::wire`](crate::comms::wire)),
//! with a dense fallback when the patch would not be smaller. Patches
//! carry raw f32 replacement values, so reconstruction is bit-exact
//! (regression-tested in `rust/tests/runstate.rs`). v1 snapshots are
//! refused — they are crash-recovery artifacts, not archives, and the
//! next checkpoint cadence rewrites them.
//!
//! Writes go to `<file>.tmp` first, are fsynced, and are renamed into
//! place ([`atomic_write`]) — a crash mid-write leaves at worst a stale
//! `.tmp` that the loader never looks at. After each successful write
//! the oldest snapshots beyond the keep-last-K budget are deleted. The
//! same [`atomic_write`] + [`fnv1a64`] machinery backs the grid engine's
//! manifest and cell records ([`exper::grid`](crate::exper::grid)).

use std::fs::File;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::Context as _;

use crate::comms::wire::{decode_frame, FrameHeader, Pipeline, Repr};
use crate::comms::{CommState, TransportState};
use crate::coordinator::FleetTotals;
use crate::data::rng::{Rng, RngState};
use crate::params::ParamVec;
use crate::privacy::MechState;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::Result;

/// Snapshot magic: `b"FCKP"` read as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"FCKP");
/// Current snapshot-format version (2 = delta-encoded model ring).
pub const SNAP_VERSION: u8 = 2;
/// Fixed header size.
const HEADER_BYTES: usize = 32;

// Section ids (u16). Additive: new sections get new ids; readers skip
// ids they do not know.
const SEC_META: u16 = 1;
const SEC_MODEL: u16 = 2;
const SEC_SCHED: u16 = 3;
const SEC_SAMPLER: u16 = 4;
const SEC_AGG: u16 = 5;
const SEC_TRANSPORT: u16 = 6;
const SEC_COMMS: u16 = 7;
const SEC_FLEET: u16 = 8;
const SEC_CURVES: u16 = 9;
const SEC_DP: u16 = 10;
const SEC_TIER: u16 = 11;
const SEC_ASYNC: u16 = 12;

/// Configuration fingerprint stamped into every snapshot and verified on
/// resume: a checkpoint must not silently continue under a different
/// model, rule, codec, seed, or cohort.
#[derive(Debug, Clone, PartialEq)]
pub struct RunMeta {
    /// `FedConfig::label()` — model, C, E, B, lr.
    pub label: String,
    /// Canonical aggregation-rule label (`Aggregator::label`).
    pub agg: String,
    /// Transport codec label (`"<up>/<down>"`).
    pub codec: String,
    pub seed: u64,
    /// Client population size K.
    pub clients: u64,
    /// Model parameter count.
    pub dim: u64,
    /// Per-round lr decay (not part of the label, but part of the
    /// trajectory).
    pub lr_decay: f64,
    /// Eval cadence — determines which rounds produce curve rows.
    pub eval_every: u64,
    /// Harness knobs that alter the trajectory without their own
    /// sections: availability probability, DP clip/σ (Debug-formatted
    /// by the server — any difference on resume is a refusal).
    pub harness: String,
}

/// Opaque per-rule aggregator state plus the rule label it belongs to.
#[derive(Debug, Clone, PartialEq)]
pub struct AggState {
    pub label: String,
    pub bytes: Vec<u8>,
}

/// The learning curves accumulated so far (RunResult + summary inputs).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CurveState {
    pub accuracy: Vec<(u64, f64)>,
    pub test_loss: Vec<(u64, f64)>,
    pub train_loss: Option<Vec<(u64, f64)>>,
}

/// Fleet accounting: run totals plus the since-last-eval telemetry
/// counters (checkpoints are allowed between eval rounds, where these
/// are mid-flight).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FleetState {
    pub totals: FleetTotals,
    pub dropped_since_eval: u64,
    pub misses_since_eval: u64,
}

/// Edge-tier (tier-1) transfer accounting for hierarchical aggregation
/// (`--shards S`, DESIGN.md §11). Cumulative totals — they cannot be
/// recomputed on resume because each round's non-empty shard count
/// depends on that round's cohort size (fleet completions vary).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TierState {
    /// Edge→root wire bytes (dense tier-1 frames).
    pub up_bytes: u64,
    /// Root→edge wire bytes.
    pub down_bytes: u64,
    /// Tier-1 frames shipped.
    pub frames: u64,
    /// Deterministic tier-1 transfer seconds (latency + bytes/bps).
    pub seconds: f64,
}

/// One client delta held by the server between rounds — an async-buffer
/// entry or a semi-sync late-queue entry (DESIGN.md §12). The delta
/// vector is stored exactly as it will enter the combine (async: already
/// codec-encoded, error feedback advanced; semi-sync: raw, encoded only
/// at application), so a resumed run replays the remaining applies
/// bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct BufferedDelta {
    /// Round the client was dispatched in.
    pub dispatch_round: u64,
    /// Dispatch slot within that round (the combine tie-break order).
    pub slot: u64,
    pub client: u64,
    /// Server applies completed when the client was dispatched — the
    /// baseline its staleness is measured from (async mode; 0 for the
    /// late queue, which measures staleness in rounds instead).
    pub basis: u64,
    /// The client's aggregation weight n_k, pre-discount.
    pub weight: f32,
    /// Absolute virtual due time in seconds (semi-sync late queue;
    /// 0 for async-buffer entries, which are already due).
    pub due_s: f64,
    pub delta: ParamVec,
}

/// Buffered-async / semi-sync server state between two buffer
/// applications (DESIGN.md §12): the apply counter staleness is measured
/// against, plus both holding queues. `Some` only when one of the async
/// round modes is active, so synchronous snapshot byte-streams are
/// unchanged by the section.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AsyncState {
    /// combine∘step applications completed so far.
    pub applies_done: u64,
    /// Late-queue entries applied so far (semi-sync run totals).
    pub late_applied: u64,
    /// Σ staleness over deltas applied since the last curve row — the
    /// numerator of the next `staleness_mean` column (checkpoints are
    /// allowed between eval rounds, where this is mid-flight).
    pub stale_sum_since_eval: u64,
    /// Deltas applied since the last curve row (the denominator).
    pub deltas_since_eval: u64,
    /// Async-buffer FIFO, in arrival order.
    pub pending: Vec<BufferedDelta>,
    /// Semi-sync late queue, in dispatch order.
    pub late: Vec<BufferedDelta>,
}

/// One complete run-state snapshot — everything `federated::server::run`
/// needs to continue a run bit-identically (see the module docs for the
/// state inventory and what is deliberately excluded).
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// The round this state is *after* (resume continues at `round + 1`).
    pub round: u64,
    pub meta: RunMeta,
    pub theta: ParamVec,
    pub client_steps: u64,
    pub sampler: RngState,
    pub agg: AggState,
    pub transport: TransportState,
    pub comms: CommState,
    pub fleet: FleetState,
    pub curves: CurveState,
    pub dp: Option<MechState>,
    /// Edge-tier accounting; `Some` only for sharded runs (`--shards S`),
    /// so unsharded snapshot byte-streams are unchanged by the field.
    pub tier: Option<TierState>,
    /// Async-round state; `Some` only under `--async-buffer` /
    /// `--late-policy discount` (DESIGN.md §12).
    pub async_state: Option<AsyncState>,
}

/// Where a run's snapshots live: `<run-dir>/checkpoints/`.
pub fn checkpoint_dir(run_dir: impl AsRef<Path>) -> PathBuf {
    run_dir.as_ref().join("checkpoints")
}

/// FNV-1a 64 — cheap, dependency-free hash shared by the snapshot
/// payload checksum (bit flips, torn writes the length test cannot see)
/// and the grid engine's config fingerprints
/// ([`exper::grid`](crate::exper::grid)).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Write `bytes` to `path` atomically: `<path>.tmp` + fsync + rename. A
/// crash mid-write leaves at worst a stale `.tmp` that readers never
/// consider. Shared by the snapshot writer and the grid engine's
/// manifest/cell records.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = {
        let mut os = path.as_os_str().to_os_string();
        os.push(".tmp");
        PathBuf::from(os)
    };
    {
        let mut f = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        f.write_all(bytes)?;
        f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename into {path:?}"))?;
    Ok(())
}

fn put_rng(w: &mut ByteWriter, st: &RngState) {
    for s in st.s {
        w.put_u64(s);
    }
    match st.gauss_spare {
        None => w.put_u8(0),
        Some(v) => {
            w.put_u8(1);
            w.put_f64(v);
        }
    }
}

fn get_rng(r: &mut ByteReader<'_>) -> Result<RngState> {
    let s = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    let gauss_spare = match r.u8()? {
        0 => None,
        1 => Some(r.f64()?),
        other => anyhow::bail!("corrupt RNG state: spare flag {other}"),
    };
    Ok(RngState { s, gauss_spare })
}

fn put_curve(w: &mut ByteWriter, pts: &[(u64, f64)]) {
    w.put_u64(pts.len() as u64);
    for &(r, v) in pts {
        w.put_u64(r);
        w.put_f64(v);
    }
}

fn get_curve(r: &mut ByteReader<'_>) -> Result<Vec<(u64, f64)>> {
    let n = r.u64()? as usize;
    anyhow::ensure!(
        n.checked_mul(16).map_or(false, |b| b <= r.remaining()),
        "corrupt curve length {n}"
    );
    (0..n).map(|_| Ok((r.u64()?, r.f64()?))).collect()
}

fn put_buffered(w: &mut ByteWriter, entries: &[BufferedDelta]) {
    w.put_u64(entries.len() as u64);
    for e in entries {
        w.put_u64(e.dispatch_round);
        w.put_u64(e.slot);
        w.put_u64(e.client);
        w.put_u64(e.basis);
        w.put_f64(e.weight as f64);
        w.put_f64(e.due_s);
        w.put_f32s(&e.delta);
    }
}

fn get_buffered(r: &mut ByteReader<'_>) -> Result<Vec<BufferedDelta>> {
    let n = r.u64()? as usize;
    anyhow::ensure!(
        n.checked_mul(48).map_or(false, |b| b <= r.remaining()),
        "corrupt buffered-delta count {n}"
    );
    (0..n)
        .map(|_| {
            Ok(BufferedDelta {
                dispatch_round: r.u64()?,
                slot: r.u64()?,
                client: r.u64()?,
                basis: r.u64()?,
                weight: r.f64()? as f32,
                due_s: r.f64()?,
                delta: r.f32s()?,
            })
        })
        .collect()
}

/// Encode the model-store ring (oldest first): each entry is its version
/// plus a self-describing wire frame — the newest dense, older versions
/// as overwrite patches against it via the transport's `delta` stage,
/// falling back to dense when the patch would not be smaller (the same
/// rule the delta downlink applies). Snapshot size then scales with
/// round-to-round model change, not `store_cap · dim`.
fn encode_ring(w: &mut ByteWriter, versions: &[(u64, ParamVec)]) {
    w.put_u64(versions.len() as u64);
    let Some((newest_v, newest)) = versions.last() else {
        return;
    };
    // lint:allow(panic-surface): constant spec string against the built-in registry; encode path, not untrusted input.
    let delta = Pipeline::parse("delta").expect("registry `delta` stage");
    // the delta/dense stages are deterministic and never draw from the
    // stream; the pipeline API just threads one through for `q<b>`
    let mut rng = Rng::new(0);
    let dense_bytes = Repr::dense(newest).wire_bytes();
    for (v, theta) in versions {
        w.put_u64(*v);
        let patch_wins = v != newest_v
            && delta
                .measure(theta, Some(newest.as_slice()))
                .map_or(false, |b| b < dense_bytes);
        let frame = if patch_wins {
            delta
                .run(theta, Some((*newest_v, newest.as_slice())), &mut rng)
                // lint:allow(panic-surface): encode path — the store only retains same-dim versions, so a mismatch is a local invariant break.
                .expect("ring invariant: retained versions share the model dim")
                .to_frame()
        } else {
            Repr::dense(theta).to_frame()
        };
        w.put_bytes(&frame.bytes);
    }
}

/// Decode [`encode_ring`]'s layout: the newest (last) entry must be a
/// dense frame; older entries decode against it, their patch base
/// version cross-checked. Bit-exact by construction — patches carry raw
/// f32 replacement values.
fn decode_ring(raw: &[(u64, &[u8])]) -> Result<Vec<(u64, ParamVec)>> {
    let Some(((newest_v, newest_bytes), older)) = raw.split_last() else {
        return Ok(Vec::new());
    };
    let newest =
        decode_frame(newest_bytes, None).context("model ring: newest frame must be dense")?;
    let mut out = Vec::with_capacity(raw.len());
    for (v, bytes) in older {
        let h = FrameHeader::parse(bytes)?;
        anyhow::ensure!(
            !h.delta || h.base_version == *newest_v,
            "model ring: version {v} patches base {}, newest is {newest_v}",
            h.base_version
        );
        out.push((*v, decode_frame(bytes, Some(newest.as_slice()))?));
    }
    out.push((*newest_v, newest));
    Ok(out)
}

impl Snapshot {
    // ------------------------------------------------------------ encode

    fn section(out: &mut ByteWriter, id: u16, body: ByteWriter) {
        out.put_u16(id);
        out.put_bytes(&body.into_inner());
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut out = ByteWriter::new();

        let mut w = ByteWriter::new();
        w.put_str(&self.meta.label);
        w.put_str(&self.meta.agg);
        w.put_str(&self.meta.codec);
        w.put_u64(self.meta.seed);
        w.put_u64(self.meta.clients);
        w.put_u64(self.meta.dim);
        w.put_f64(self.meta.lr_decay);
        w.put_u64(self.meta.eval_every);
        w.put_str(&self.meta.harness);
        Self::section(&mut out, SEC_META, w);

        let mut w = ByteWriter::new();
        w.put_f32s(&self.theta);
        Self::section(&mut out, SEC_MODEL, w);

        let mut w = ByteWriter::new();
        w.put_u64(self.round);
        w.put_u64(self.client_steps);
        Self::section(&mut out, SEC_SCHED, w);

        let mut w = ByteWriter::new();
        put_rng(&mut w, &self.sampler);
        Self::section(&mut out, SEC_SAMPLER, w);

        let mut w = ByteWriter::new();
        w.put_str(&self.agg.label);
        w.put_bytes(&self.agg.bytes);
        Self::section(&mut out, SEC_AGG, w);

        let mut w = ByteWriter::new();
        put_rng(&mut w, &self.transport.rng);
        w.put_u64(self.transport.feedback.len() as u64);
        for resid in &self.transport.feedback {
            w.put_f32s(resid);
        }
        encode_ring(&mut w, &self.transport.versions);
        w.put_u64s(&self.transport.acked);
        Self::section(&mut out, SEC_TRANSPORT, w);

        let mut w = ByteWriter::new();
        w.put_u64(self.comms.totals.rounds);
        w.put_u64(self.comms.totals.bytes_up);
        w.put_u64(self.comms.totals.bytes_down);
        w.put_f64(self.comms.totals.sim_seconds);
        put_rng(&mut w, &self.comms.rng);
        Self::section(&mut out, SEC_COMMS, w);

        let mut w = ByteWriter::new();
        w.put_u64(self.fleet.totals.dispatched);
        w.put_u64(self.fleet.totals.completed);
        w.put_u64(self.fleet.totals.dropped_stragglers);
        w.put_u64(self.fleet.totals.deadline_misses);
        w.put_u64(self.fleet.dropped_since_eval);
        w.put_u64(self.fleet.misses_since_eval);
        Self::section(&mut out, SEC_FLEET, w);

        let mut w = ByteWriter::new();
        put_curve(&mut w, &self.curves.accuracy);
        put_curve(&mut w, &self.curves.test_loss);
        match &self.curves.train_loss {
            None => w.put_u8(0),
            Some(c) => {
                w.put_u8(1);
                put_curve(&mut w, c);
            }
        }
        Self::section(&mut out, SEC_CURVES, w);

        if let Some(dp) = &self.dp {
            let mut w = ByteWriter::new();
            put_rng(&mut w, &dp.rng);
            w.put_u64(dp.rounds_applied);
            Self::section(&mut out, SEC_DP, w);
        }

        if let Some(tier) = &self.tier {
            let mut w = ByteWriter::new();
            w.put_u64(tier.up_bytes);
            w.put_u64(tier.down_bytes);
            w.put_u64(tier.frames);
            w.put_f64(tier.seconds);
            Self::section(&mut out, SEC_TIER, w);
        }

        if let Some(a) = &self.async_state {
            let mut w = ByteWriter::new();
            w.put_u64(a.applies_done);
            w.put_u64(a.late_applied);
            w.put_u64(a.stale_sum_since_eval);
            w.put_u64(a.deltas_since_eval);
            put_buffered(&mut w, &a.pending);
            put_buffered(&mut w, &a.late);
            Self::section(&mut out, SEC_ASYNC, w);
        }

        out.into_inner()
    }

    /// Serialize to the full on-disk byte image (header + payload).
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.push(SNAP_VERSION);
        out.extend_from_slice(&[0u8; 3]);
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    // ------------------------------------------------------------ decode

    /// Parse and fully validate a snapshot image. Any defect — short
    /// file, trailing bytes, checksum mismatch, missing section,
    /// malformed section body — fails the whole load; no partial state
    /// ever escapes.
    pub fn from_bytes(buf: &[u8]) -> Result<Snapshot> {
        // Header reads go through the bounds-checked ByteReader so a short
        // or lying file errors out instead of panicking (rule
        // `panic-surface` — DESIGN.md §13).
        let mut hdr = ByteReader::new(buf);
        let magic = hdr.u32().context("snapshot truncated inside header")?;
        anyhow::ensure!(magic == MAGIC, "bad snapshot magic {magic:#010x}");
        let version = hdr.u8()?;
        anyhow::ensure!(
            version == SNAP_VERSION,
            "unsupported snapshot version {version} (this build reads {SNAP_VERSION})"
        );
        hdr.take(3)?; // pad
        let round = hdr.u64().context("snapshot truncated inside header")?;
        let payload_len = hdr.u64()? as usize;
        let stored_sum = hdr.u64()?;
        let payload = hdr.take(payload_len).map_err(|_| {
            anyhow::anyhow!(
                "snapshot length mismatch: header declares {payload_len} payload bytes, file has {}",
                buf.len().saturating_sub(HEADER_BYTES)
            )
        })?;
        anyhow::ensure!(
            hdr.is_empty(),
            "snapshot length mismatch: {} trailing bytes past the declared payload",
            hdr.remaining()
        );
        let sum = fnv1a64(payload);
        anyhow::ensure!(
            sum == stored_sum,
            "snapshot checksum mismatch ({sum:#018x} vs {stored_sum:#018x}): corrupt file"
        );

        let mut meta = None;
        let mut theta = None;
        let mut sched = None;
        let mut sampler = None;
        let mut agg = None;
        let mut transport = None;
        let mut comms = None;
        let mut fleet = None;
        let mut curves = None;
        let mut dp = None;
        let mut tier = None;
        let mut async_state = None;

        let mut r = ByteReader::new(payload);
        while !r.is_empty() {
            let id = r.u16()?;
            let body = r.bytes()?;
            let mut b = ByteReader::new(body);
            match id {
                SEC_META => {
                    meta = Some(RunMeta {
                        label: b.str()?,
                        agg: b.str()?,
                        codec: b.str()?,
                        seed: b.u64()?,
                        clients: b.u64()?,
                        dim: b.u64()?,
                        lr_decay: b.f64()?,
                        eval_every: b.u64()?,
                        harness: b.str()?,
                    });
                    b.expect_end()?;
                }
                SEC_MODEL => {
                    theta = Some(b.f32s()?);
                    b.expect_end()?;
                }
                SEC_SCHED => {
                    let r_in = b.u64()?;
                    anyhow::ensure!(
                        r_in == round,
                        "snapshot round disagrees with header: {r_in} vs {round}"
                    );
                    sched = Some(b.u64()?);
                    b.expect_end()?;
                }
                SEC_SAMPLER => {
                    sampler = Some(get_rng(&mut b)?);
                    b.expect_end()?;
                }
                SEC_AGG => {
                    agg = Some(AggState {
                        label: b.str()?,
                        bytes: b.bytes()?.to_vec(),
                    });
                    b.expect_end()?;
                }
                SEC_TRANSPORT => {
                    let rng = get_rng(&mut b)?;
                    let n = b.u64()? as usize;
                    anyhow::ensure!(
                        n.checked_mul(8).map_or(false, |x| x <= b.remaining()),
                        "corrupt feedback count {n}"
                    );
                    let feedback = (0..n).map(|_| b.f32s()).collect::<Result<Vec<_>>>()?;
                    let nv = b.u64()? as usize;
                    anyhow::ensure!(
                        nv.checked_mul(16).map_or(false, |x| x <= b.remaining()),
                        "corrupt version count {nv}"
                    );
                    let raw = (0..nv)
                        .map(|_| Ok((b.u64()?, b.bytes()?)))
                        .collect::<Result<Vec<_>>>()?;
                    let versions = decode_ring(&raw)?;
                    let acked = b.u64s()?;
                    transport = Some(TransportState {
                        rng,
                        feedback,
                        versions,
                        acked,
                    });
                    b.expect_end()?;
                }
                SEC_COMMS => {
                    let totals = crate::comms::CommTotals {
                        rounds: b.u64()?,
                        bytes_up: b.u64()?,
                        bytes_down: b.u64()?,
                        sim_seconds: b.f64()?,
                    };
                    comms = Some(CommState {
                        totals,
                        rng: get_rng(&mut b)?,
                    });
                    b.expect_end()?;
                }
                SEC_FLEET => {
                    fleet = Some(FleetState {
                        totals: FleetTotals {
                            dispatched: b.u64()?,
                            completed: b.u64()?,
                            dropped_stragglers: b.u64()?,
                            deadline_misses: b.u64()?,
                        },
                        dropped_since_eval: b.u64()?,
                        misses_since_eval: b.u64()?,
                    });
                    b.expect_end()?;
                }
                SEC_CURVES => {
                    let accuracy = get_curve(&mut b)?;
                    let test_loss = get_curve(&mut b)?;
                    let train_loss = match b.u8()? {
                        0 => None,
                        1 => Some(get_curve(&mut b)?),
                        other => anyhow::bail!("corrupt train-loss flag {other}"),
                    };
                    curves = Some(CurveState {
                        accuracy,
                        test_loss,
                        train_loss,
                    });
                    b.expect_end()?;
                }
                SEC_DP => {
                    dp = Some(MechState {
                        rng: get_rng(&mut b)?,
                        rounds_applied: b.u64()?,
                    });
                    b.expect_end()?;
                }
                SEC_TIER => {
                    tier = Some(TierState {
                        up_bytes: b.u64()?,
                        down_bytes: b.u64()?,
                        frames: b.u64()?,
                        seconds: b.f64()?,
                    });
                    b.expect_end()?;
                }
                SEC_ASYNC => {
                    async_state = Some(AsyncState {
                        applies_done: b.u64()?,
                        late_applied: b.u64()?,
                        stale_sum_since_eval: b.u64()?,
                        deltas_since_eval: b.u64()?,
                        pending: get_buffered(&mut b)?,
                        late: get_buffered(&mut b)?,
                    });
                    b.expect_end()?;
                }
                _ => {} // unknown section: skip (additive format growth)
            }
        }

        let missing = |what: &str| anyhow::anyhow!("snapshot is missing its {what} section");
        Ok(Snapshot {
            round,
            meta: meta.ok_or_else(|| missing("META"))?,
            theta: theta.ok_or_else(|| missing("MODEL"))?,
            client_steps: sched.ok_or_else(|| missing("SCHED"))?,
            sampler: sampler.ok_or_else(|| missing("SAMPLER"))?,
            agg: agg.ok_or_else(|| missing("AGG"))?,
            transport: transport.ok_or_else(|| missing("TRANSPORT"))?,
            comms: comms.ok_or_else(|| missing("COMMS"))?,
            fleet: fleet.ok_or_else(|| missing("FLEET"))?,
            curves: curves.ok_or_else(|| missing("CURVES"))?,
            dp,
            tier,
            async_state,
        })
    }

    // --------------------------------------------------------------- io

    /// Write the snapshot atomically into `ckpt_dir` as
    /// `ckpt-<round>.bin` ([`atomic_write`]: tmp + fsync + rename), then
    /// prune to the newest `keep` snapshots. Returns the final path.
    pub fn write(&self, ckpt_dir: &Path, keep: usize) -> Result<PathBuf> {
        anyhow::ensure!(keep >= 1, "checkpoint rotation must keep >= 1");
        std::fs::create_dir_all(ckpt_dir).with_context(|| format!("mkdir {ckpt_dir:?}"))?;
        let path = ckpt_dir.join(format!("ckpt-{:010}.bin", self.round));
        atomic_write(&path, &self.to_bytes())?;
        for (_, old) in list(ckpt_dir)?.iter().rev().skip(keep) {
            std::fs::remove_file(old).ok(); // best-effort prune
        }
        Ok(path)
    }

    /// Read and validate one snapshot file.
    pub fn read(path: &Path) -> Result<Snapshot> {
        let buf = std::fs::read(path).with_context(|| format!("reading snapshot {path:?}"))?;
        Self::from_bytes(&buf).with_context(|| format!("snapshot {path:?}"))
    }

    /// Load the newest valid snapshot under `<run_dir>/checkpoints/`.
    /// A corrupt newest file (e.g. the disk filled mid-rename cycle)
    /// falls back to the next-newest with a warning — that is what the
    /// keep-last-K budget is for. `Ok(None)` when no snapshots exist;
    /// `Err` when snapshots exist but none validates.
    pub fn load_latest(run_dir: &Path) -> Result<Option<(PathBuf, Snapshot)>> {
        let dir = checkpoint_dir(run_dir);
        if !dir.is_dir() {
            return Ok(None);
        }
        let files = list(&dir)?;
        if files.is_empty() {
            return Ok(None);
        }
        let mut last_err = anyhow::anyhow!("empty candidate list");
        for (_, path) in files.iter().rev() {
            match Self::read(path) {
                Ok(snap) => return Ok(Some((path.clone(), snap))),
                Err(e) => {
                    eprintln!("warning: skipping unreadable snapshot: {e:#}");
                    last_err = e;
                }
            }
        }
        Err(last_err.context(format!(
            "no valid snapshot among {} candidates in {dir:?}",
            files.len()
        )))
    }
}

/// `(round, path)` of every `ckpt-*.bin` in `dir`, sorted by round.
fn list(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("listing {dir:?}"))? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        let Some(round) = name
            .strip_prefix("ckpt-")
            .and_then(|s| s.strip_suffix(".bin"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue; // .tmp leftovers, foreign files
        };
        out.push((round, path));
    }
    out.sort_unstable_by_key(|(r, _)| *r);
    Ok(out)
}
