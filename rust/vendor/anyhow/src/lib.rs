//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The testbed image has no crates.io access, so the workspace carries the
//! exact `anyhow` surface it uses: [`Error`] (a context chain), [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait. `{e}` prints the outermost message; `{e:#}` prints the
//! full `outer: inner: root` chain, matching real anyhow's formatting.

use std::fmt;

/// An error as a chain of context frames, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that
// keeps this blanket conversion coherent alongside the reflexive
// `From<Error> for Error` (the same trick real anyhow needs nightly
// specialization for).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to any compatible `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_int(s: &str) -> Result<i64> {
        let v: i64 = s.parse().with_context(|| format!("parsing {s:?}"))?;
        Ok(v)
    }

    #[test]
    fn context_chain_formats() {
        let e = parse_int("zap").unwrap_err();
        assert_eq!(format!("{e}"), "parsing \"zap\"");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing \"zap\": "), "{full}");
        assert!(full.contains("invalid digit"), "{full}");
    }

    #[test]
    fn macros_compose() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Err(anyhow!("mid-range {x}"))
        }
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        assert_eq!(format!("{}", f(5).unwrap_err()), "mid-range 5");
    }

    #[test]
    fn io_error_converts_via_question_mark() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
