//! Vendored stub of the `xla` PJRT bindings.
//!
//! The testbed image carries neither crates.io access nor a PJRT shared
//! library, so this crate provides the exact type/function surface the
//! `fedavg` runtime uses — enough to *compile and link* the whole
//! workspace. Host-side [`Literal`] plumbing is fully functional (it is
//! plain data); anything that would need a real XLA backend
//! ([`PjRtClient::compile`], [`PjRtLoadedExecutable::execute`]) returns
//! [`Error`] at runtime. The artifact-gated tests check for
//! `artifacts/manifest.json` before touching the engine, so under this
//! stub they skip cleanly.
//!
//! To run the real AOT artifacts, point the `xla` dependency in
//! `rust/Cargo.toml` at actual PJRT bindings with this same API
//! (`PjRtClient::cpu`, `HloModuleProto::from_text_file`,
//! `XlaComputation::from_proto`, `compile`/`execute`, `Literal`).

use std::fmt;

/// Backend error (stub: mostly "no PJRT in this build").
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type XResult<T> = std::result::Result<T, Error>;

fn no_backend(what: &str) -> Error {
    Error(format!(
        "{what}: xla stub has no PJRT backend (vendor/xla) — swap the `xla` \
         dependency for real bindings to execute artifacts"
    ))
}

// ------------------------------------------------------------- literals

#[derive(Debug, Clone, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

/// Host-side element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Payload;
    fn unwrap(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            Payload::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::I32(data)
    }
    fn unwrap(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            Payload::F32(_) => None,
        }
    }
}

/// A typed host-side array with a shape — functional in the stub.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            payload: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: Vec::new(),
            payload: T::wrap(vec![v]),
        }
    }

    /// Same data, new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> XResult<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.payload.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.payload.len()
            )));
        }
        Ok(Literal {
            payload: self.payload.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy out as a flat host vector.
    pub fn to_vec<T: NativeType>(&self) -> XResult<Vec<T>> {
        T::unwrap(&self.payload).ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Unpack a single-element tuple (identity in the stub).
    pub fn to_tuple1(self) -> XResult<Literal> {
        Ok(self)
    }
}

// ----------------------------------------------------------------- PJRT

/// PJRT client handle (stub: connects, never compiles).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> XResult<Self> {
        Ok(Self { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XResult<PjRtLoadedExecutable> {
        Err(no_backend("compile"))
    }
}

/// Parsed HLO module (stub: validates the file is readable text).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> XResult<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path:?}: {e}")))?;
        Ok(Self { _text: text })
    }
}

/// Computation wrapper around an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _priv: () }
    }
}

/// Compiled executable (unreachable in the stub — compile always errors).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> XResult<Vec<Vec<PjRtBuffer>>> {
        Err(no_backend("execute"))
    }
}

/// Device buffer handle (unreachable in the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XResult<Literal> {
        Err(no_backend("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap().len(), 4);
        assert!(l.reshape(&[3, 2]).is_err());
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn backend_paths_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto {
            _text: String::new(),
        });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("no PJRT backend"));
    }
}
