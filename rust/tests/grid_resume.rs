//! Grid-engine resume regression suite (DESIGN.md §9).
//!
//! The core guarantee under test: **killing a grid mid-run and rerunning
//! the same command produces byte-identical outputs** — the manifest,
//! every cell's artifacts, and the outcome rows the drivers format
//! tables from — versus a grid that was never interrupted. The cells
//! here are synthetic (engine-free) so the whole engine surface runs
//! under plain `cargo test`: execution, the shared cell cache, in-grid
//! aliases, worker pools, dry runs, and the two refusal paths (a stale
//! manifest from a different command; a cell dir whose record does not
//! match the declared fingerprint/spec).

use std::path::{Path, PathBuf};

use fedavg::coordinator::TierLink;
use fedavg::data::rng::hash3_unit;
use fedavg::exper::grid::{self, CellCtx, CellOutcome, CellWork, GridDef, GridOptions, Series};
use fedavg::federated::aggregate::{combine_sharded, AggConfig};
use fedavg::params;
use fedavg::runstate::atomic_write;
use fedavg::runtime::Engine;
use fedavg::Result;

/// Deterministic engine-free cell: writes a curve.csv derived from its
/// id and reports a summary + series. `fail` injects a crash for the
/// kill-mid-grid scenarios — deliberately *not* part of the spec, the
/// same way a real SIGKILL is not part of a training config.
struct SynthCell {
    id: u64,
    fail: bool,
}

impl SynthCell {
    fn ok(id: u64) -> SynthCell {
        SynthCell { id, fail: false }
    }
}

impl CellWork for SynthCell {
    fn spec(&self) -> String {
        format!("synth id={}", self.id)
    }

    fn needs_engine(&self) -> bool {
        false
    }

    fn run(&self, _engine: Option<&Engine>, ctx: &CellCtx) -> Result<CellOutcome> {
        anyhow::ensure!(!self.fail, "injected mid-grid crash (cell {})", self.id);
        std::fs::create_dir_all(&ctx.dir)?;
        let mut csv = String::from("round,value\n");
        let mut pts: Series = Vec::new();
        for r in 1..=5u64 {
            let v = (self.id * 100 + r) as f64 * 0.5;
            csv.push_str(&format!("{r},{v}\n"));
            pts.push((r as f64, v));
        }
        atomic_write(&ctx.dir.join("curve.csv"), csv.as_bytes())?;
        let mut out = CellOutcome::default();
        out.put("id", self.id);
        out.put("final", pts.last().unwrap().1);
        out.curves.push(("series".into(), pts));
        Ok(out)
    }
}

fn test_root(tag: &str) -> PathBuf {
    let root = PathBuf::from(format!("target/test-runs/grid-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    root
}

fn opts(root: &Path, workers: usize) -> GridOptions {
    GridOptions {
        out_root: root.to_str().unwrap().to_string(),
        workers,
        ..Default::default()
    }
}

fn def_of(ids: &[(u64, bool)]) -> GridDef<SynthCell> {
    let mut def = GridDef::new("smoke");
    for &(id, fail) in ids {
        def.cell(format!("cell-{id}"), SynthCell { id, fail });
    }
    def
}

/// Every artifact the byte-identity guarantee covers, as bytes.
fn artifacts(root: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out = vec![(
        "manifest".to_string(),
        std::fs::read(root.join("grid-smoke/manifest.json")).expect("manifest"),
    )];
    let mut dirs: Vec<PathBuf> = std::fs::read_dir(root.join("cells"))
        .expect("cells pool")
        .map(|e| e.unwrap().path())
        .collect();
    dirs.sort();
    for d in dirs {
        for f in ["cell.json", "curve.csv"] {
            out.push((
                format!("{}/{f}", d.file_name().unwrap().to_str().unwrap()),
                std::fs::read(d.join(f)).unwrap_or_else(|_| panic!("missing {f} in {d:?}")),
            ));
        }
    }
    out
}

#[test]
fn killed_grid_rerun_is_byte_identical() {
    let ids: Vec<(u64, bool)> = (1..=4).map(|i| (i, false)).collect();

    // reference: one uninterrupted run
    let clean = test_root("clean");
    let report = grid::run(def_of(&ids), None, &opts(&clean, 1))
        .unwrap()
        .expect("not a dry run");
    assert_eq!(report.executed, 4);
    assert_eq!(report.cache_hits, 0);

    // killed: cell 3 crashes; inline execution stops there with cells
    // 1-2 recorded durably
    let killed = test_root("killed");
    let mut broken = ids.clone();
    broken[2].1 = true;
    let err = grid::run(def_of(&broken), None, &opts(&killed, 1)).unwrap_err();
    assert!(format!("{err:#}").contains("injected"), "{err:#}");
    assert!(killed.join("grid-smoke/manifest.json").exists());

    // rerun the same command: done cells skip, the rest executes
    let report = grid::run(def_of(&ids), None, &opts(&killed, 1))
        .unwrap()
        .expect("not a dry run");
    assert_eq!(report.executed, 2, "cells 3 and 4 remained");
    assert_eq!(report.cache_hits, 2, "cells 1 and 2 were reused");

    // byte-identity: manifest + every cell's record and curve
    let a = artifacts(&clean);
    let b = artifacts(&killed);
    assert_eq!(a.len(), b.len());
    for ((name, bytes_a), (_, bytes_b)) in a.iter().zip(&b) {
        assert_eq!(bytes_a, bytes_b, "{name} differs between clean and resumed grids");
    }

    // and the outcome rows (the table inputs) match a fresh run's
    let again = grid::run(def_of(&ids), None, &opts(&clean, 1))
        .unwrap()
        .expect("not a dry run");
    assert_eq!(again.executed, 0, "fully cached rerun");
    assert_eq!(again.outcomes, report.outcomes);
    std::fs::remove_dir_all(clean).ok();
    std::fs::remove_dir_all(killed).ok();
}

#[test]
fn parallel_workers_match_serial_bytes() {
    let ids: Vec<(u64, bool)> = (1..=6).map(|i| (i, false)).collect();
    let serial = test_root("serial");
    let parallel = test_root("parallel");
    let rs = grid::run(def_of(&ids), None, &opts(&serial, 1))
        .unwrap()
        .expect("not a dry run");
    let rp = grid::run(def_of(&ids), None, &opts(&parallel, 3))
        .unwrap()
        .expect("not a dry run");
    // outcomes come back in declaration order regardless of completion
    assert_eq!(rs.outcomes, rp.outcomes);
    for (i, out) in rp.outcomes.iter().enumerate() {
        assert_eq!(out.get("id"), Some(format!("{}", i + 1).as_str()));
    }
    let a = artifacts(&serial);
    let b = artifacts(&parallel);
    for ((name, bytes_a), (_, bytes_b)) in a.iter().zip(&b) {
        assert_eq!(bytes_a, bytes_b, "{name} differs between workers=1 and workers=3");
    }
    std::fs::remove_dir_all(serial).ok();
    std::fs::remove_dir_all(parallel).ok();
}

#[test]
fn mismatched_cell_record_refused() {
    let root = test_root("cellfp");
    let ids = [(7u64, false)];
    grid::run(def_of(&ids), None, &opts(&root, 1)).unwrap();
    // doctor the record's fingerprint: the dir no longer matches what
    // the declaration expects
    let dir = std::fs::read_dir(root.join("cells"))
        .unwrap()
        .next()
        .unwrap()
        .unwrap()
        .path();
    let record = dir.join("cell.json");
    let doctored = std::fs::read_to_string(&record)
        .unwrap()
        .replace("synth id=7", "synth id=8");
    std::fs::write(&record, doctored).unwrap();
    let err = grid::run(def_of(&ids), None, &opts(&root, 1)).unwrap_err();
    assert!(
        format!("{err:#}").contains("refusing to reuse"),
        "wanted a reuse refusal, got: {err:#}"
    );
    std::fs::remove_dir_all(root).ok();
}

#[test]
fn stale_manifest_refused_unless_overwritten() {
    let root = test_root("manifest");
    grid::run(def_of(&[(1, false), (2, false)]), None, &opts(&root, 1)).unwrap();
    // same grid name, different cell set: a different command
    let changed = [(1u64, false), (3u64, false)];
    let err = grid::run(def_of(&changed), None, &opts(&root, 1)).unwrap_err();
    assert!(format!("{err:#}").contains("--overwrite"), "{err:#}");
    // --overwrite replaces the manifest; cached cell 1 still hits
    let mut o = opts(&root, 1);
    o.overwrite = true;
    let report = grid::run(def_of(&changed), None, &o)
        .unwrap()
        .expect("not a dry run");
    assert_eq!(report.executed, 1, "only the new cell runs");
    assert_eq!(report.cache_hits, 1, "cell 1 reused across commands");
    std::fs::remove_dir_all(root).ok();
}

#[test]
fn resume_requires_manifest_and_dry_run_is_readonly() {
    let root = test_root("flags");
    let mut o = opts(&root, 1);
    o.resume = true;
    let err = grid::run(def_of(&[(1, false)]), None, &o).unwrap_err();
    assert!(format!("{err:#}").contains("no manifest"), "{err:#}");

    let mut o = opts(&root, 1);
    o.dry_run = true;
    let report = grid::run(def_of(&[(1, false)]), None, &o).unwrap();
    assert!(report.is_none(), "dry run returns no report");
    assert!(!root.join("cells").exists(), "dry run created cell dirs");
    assert!(
        !root.join("grid-smoke").exists(),
        "dry run touched the manifest"
    );
    std::fs::remove_dir_all(root).ok();
}

// ------------------------------------------- sharded cells (DESIGN.md §11)

/// Engine-free cell that trains a tiny synthetic trajectory through the
/// real aggregator, flat (`shards == 0`) or via the hierarchical cascade
/// (`shards >= 1`). The curve rows are pure functions of θ, so the
/// shard↔flat bit-identity surfaces directly in the grid's byte-compared
/// artifacts; tier traffic goes only to the cell summary.
struct ShardCell {
    id: u64,
    shards: usize,
    fail: bool,
}

impl CellWork for ShardCell {
    fn spec(&self) -> String {
        format!("shard id={} s={}", self.id, self.shards)
    }

    fn needs_engine(&self) -> bool {
        false
    }

    fn run(&self, _engine: Option<&Engine>, ctx: &CellCtx) -> Result<CellOutcome> {
        anyhow::ensure!(!self.fail, "injected mid-grid crash (shard cell {})", self.id);
        std::fs::create_dir_all(&ctx.dir)?;
        let agg = AggConfig { spec: "fedavgm:0.8".into(), ..Default::default() }.build()?;
        let link = TierLink::default();
        let dim = 64usize;
        let mut theta = vec![0.0f32; dim];
        let mut csv = String::from("round,norm\n");
        let mut tier_up = 0u64;
        for r in 1..=5u64 {
            let cohort: Vec<(f32, Vec<f32>)> = (0..6u64)
                .map(|c| {
                    let d = (0..dim)
                        .map(|i| {
                            (hash3_unit(self.id * 1000 + r, c, i as u64) as f32 - 0.5) * 0.1
                        })
                        .collect();
                    ((c % 3 + 1) as f32, d)
                })
                .collect();
            let refs: Vec<(f32, &[f32])> =
                cohort.iter().map(|(w, d)| (*w, d.as_slice())).collect();
            let delta = if self.shards == 0 {
                agg.combine(&refs)?
            } else {
                let sc = combine_sharded(agg.as_ref(), &refs, self.shards, &link)?;
                tier_up += sc.up_bytes;
                sc.delta
            };
            let step = agg.step(r, delta)?;
            params::axpy(&mut theta, 1.0, &step);
            csv.push_str(&format!("{r},{:.9}\n", params::l2_norm(&theta)));
        }
        atomic_write(&ctx.dir.join("curve.csv"), csv.as_bytes())?;
        let mut out = CellOutcome::default();
        out.put("id", self.id);
        out.put("shards", self.shards as u64);
        out.put("final_norm", format!("{:.9}", params::l2_norm(&theta)));
        if self.shards > 0 {
            out.put("tier_up_bytes", tier_up);
        }
        Ok(out)
    }
}

/// Satellite of the §11 suite: a grid sweeping `--shards` killed
/// mid-flight resumes byte-identically, and every sharded cell's curve
/// is byte-equal to its flat twin's — the bit-identity guarantee holds
/// through the grid engine's cache/resume machinery too.
#[test]
fn killed_sharded_grid_resumes_and_matches_flat() {
    let cells = |fail_third: bool| {
        let mut def = GridDef::new("smoke");
        def.cell("flat", ShardCell { id: 1, shards: 0, fail: false });
        def.cell("s2", ShardCell { id: 1, shards: 2, fail: false });
        def.cell("s7", ShardCell { id: 1, shards: 7, fail: fail_third });
        def.cell("s3", ShardCell { id: 1, shards: 3, fail: false });
        def
    };

    // reference: uninterrupted sweep
    let clean = test_root("shard-clean");
    let report = grid::run(cells(false), None, &opts(&clean, 1))
        .unwrap()
        .expect("not a dry run");
    assert_eq!(report.executed, 4);
    for out in &report.outcomes[1..] {
        assert_eq!(
            out.get("final_norm"),
            report.outcomes[0].get("final_norm"),
            "sharded outcome diverged from flat"
        );
    }

    // killed at the third cell, then rerun the same command
    let killed = test_root("shard-killed");
    let err = grid::run(cells(true), None, &opts(&killed, 1)).unwrap_err();
    assert!(format!("{err:#}").contains("injected"), "{err:#}");
    let report = grid::run(cells(false), None, &opts(&killed, 1))
        .unwrap()
        .expect("not a dry run");
    assert_eq!(report.executed, 2, "cells s7 and s3 remained");
    assert_eq!(report.cache_hits, 2, "flat and s2 were reused");

    let a = artifacts(&clean);
    let b = artifacts(&killed);
    assert_eq!(a.len(), b.len());
    for ((name, bytes_a), (_, bytes_b)) in a.iter().zip(&b) {
        assert_eq!(bytes_a, bytes_b, "{name} differs between clean and resumed grids");
    }

    // shard↔flat bit-identity across the cached cell pool: the flat
    // cell's curve bytes equal every sharded cell's
    let mut flat_curve = None;
    let mut sharded_curves = Vec::new();
    for e in std::fs::read_dir(clean.join("cells")).unwrap() {
        let dir = e.unwrap().path();
        let record = std::fs::read_to_string(dir.join("cell.json")).unwrap();
        let curve = std::fs::read(dir.join("curve.csv")).unwrap();
        if record.contains("s=0") {
            flat_curve = Some(curve);
        } else {
            sharded_curves.push((record, curve));
        }
    }
    let flat_curve = flat_curve.expect("flat cell present");
    assert_eq!(sharded_curves.len(), 3);
    for (record, curve) in sharded_curves {
        assert_eq!(curve, flat_curve, "sharded cell curve != flat: {record}");
    }
    std::fs::remove_dir_all(clean).ok();
    std::fs::remove_dir_all(killed).ok();
}

#[test]
fn identical_specs_alias_to_one_execution() {
    let root = test_root("alias");
    let mut def = GridDef::new("smoke");
    def.cell("first", SynthCell::ok(9));
    def.cell("second", SynthCell::ok(9)); // same spec, different name
    def.cell("third", SynthCell::ok(10));
    let report = grid::run(def, None, &opts(&root, 1))
        .unwrap()
        .expect("not a dry run");
    assert_eq!(report.executed, 2, "the duplicate spec must not re-run");
    assert_eq!(report.cache_hits, 1);
    assert_eq!(report.outcomes[0], report.outcomes[1]);
    assert_ne!(report.outcomes[0], report.outcomes[2]);
    std::fs::remove_dir_all(root).ok();
}
