//! Integration: the fleet coordinator — scheduler invariants without
//! artifacts (always run), and the parallel-vs-sequential bit-identity
//! over real artifacts (skipped, like the other artifact-gated tests,
//! when `make artifacts` has not run).

use fedavg::config::{BatchSize, FedConfig, Partition};
use fedavg::coordinator::{FleetConfig, FleetProfile, FleetSim};
use fedavg::exper::mnist_fed;
use fedavg::federated::{self, ServerOptions};
use fedavg::params;
use fedavg::runtime::Engine;

fn mobile(overselect: f64, deadline_s: Option<f64>) -> FleetConfig {
    FleetConfig {
        profile: FleetProfile::Mobile,
        overselect,
        deadline_s,
        ..Default::default()
    }
}

// ------------------------------------------------- simulation invariants

#[test]
fn overselection_never_aggregates_more_than_m() {
    let m = 40;
    let mut sim = FleetSim::new(&mobile(0.5, Some(60.0)), 2000, m, 800_000, 60.0, 3).unwrap();
    let mut saw_overselection = false;
    let mut saw_drop = false;
    for _ in 0..100 {
        let r = sim.step();
        assert!(r.plan.completed.len() <= m, "round {}", r.round);
        assert!(!r.plan.completed.is_empty(), "round {}", r.round);
        assert!(r.plan.dispatched.len() <= (m as f64 * 1.5).ceil() as usize);
        saw_overselection |= r.plan.dispatched.len() > m;
        saw_drop |= !r.plan.dropped.is_empty();
        // conservation: every dispatched client either completed or dropped
        assert_eq!(
            r.plan.completed.len() + r.plan.dropped.len(),
            r.plan.dispatched.len()
        );
        let mut all: Vec<usize> = r.plan.completed.iter().chain(&r.plan.dropped).copied().collect();
        all.sort_unstable();
        let mut disp = r.plan.dispatched.clone();
        disp.sort_unstable();
        assert_eq!(all, disp);
    }
    assert!(saw_overselection, "over-selection never dispatched extras");
    assert!(saw_drop, "over-selection never dropped a straggler");
}

#[test]
fn dropped_straggler_rounds_keep_weights_normalized() {
    // aggregation weights are n_k / Σ n_k over the COMPLETED set, so they
    // must sum to 1 no matter how many stragglers were dropped
    let mut sim = FleetSim::new(&mobile(0.4, Some(45.0)), 1000, 25, 800_000, 120.0, 9).unwrap();
    // heterogeneous client sizes, like an unbalanced partition
    let sizes: Vec<usize> = (0..1000).map(|c| 100 + (c * 37) % 900).collect();
    for _ in 0..50 {
        let r = sim.step();
        if r.plan.dropped.is_empty() {
            continue;
        }
        let ones = vec![1.0f32; 8];
        let weighted: Vec<(f32, &[f32])> = r
            .plan
            .completed
            .iter()
            .map(|&c| (sizes[c] as f32, ones.as_slice()))
            .collect();
        // weighted_mean normalizes by the completed set's total weight:
        // averaging all-ones must return ones (i.e. the weights sum to 1)
        let mean = params::weighted_mean(&weighted);
        for v in mean {
            assert!((v - 1.0).abs() < 1e-6, "weights did not normalize: {v}");
        }
    }
    let t = sim.totals();
    assert!(t.fleet.dropped_stragglers > 0, "scenario produced no straggler drops");
    assert_eq!(t.fleet.completed + t.fleet.dropped_stragglers, t.fleet.dispatched);
}

#[test]
fn sim_rounds_are_deterministic_and_cadence_independent() {
    let cfg = mobile(0.3, Some(90.0));
    let mut a = FleetSim::new(&cfg, 5000, 100, 6_653_480, 60.0, 42).unwrap();
    let mut b = FleetSim::new(&cfg, 5000, 100, 6_653_480, 60.0, 42).unwrap();
    for _ in 0..30 {
        let ra = a.step();
        let rb = b.step();
        assert_eq!(ra.plan.dispatched, rb.plan.dispatched);
        assert_eq!(ra.plan.completed, rb.plan.completed);
        assert_eq!(ra.plan.dropped, rb.plan.dropped);
        assert!(ra.plan.round_seconds.is_finite() && ra.plan.round_seconds > 0.0);
    }
}

#[test]
fn deadlines_bound_round_wall_clock() {
    let deadline = 30.0;
    let mut tight = FleetSim::new(&mobile(0.2, Some(deadline)), 3000, 50, 6_653_480, 300.0, 7)
        .unwrap();
    let mut open = FleetSim::new(&mobile(0.2, None), 3000, 50, 6_653_480, 300.0, 7).unwrap();
    for _ in 0..40 {
        let r = tight.step();
        // a round never waits past the deadline unless nobody finished
        if r.plan.completed.len() > 1 {
            assert!(
                r.plan.round_seconds <= deadline + 1e-9,
                "round {} ran {}s past a {}s deadline",
                r.round,
                r.plan.round_seconds,
                deadline
            );
        }
        open.step();
    }
    let (t, o) = (tight.totals(), open.totals());
    assert!(t.fleet.deadline_misses > 0, "slow fleet never missed a 30s deadline");
    assert!(
        t.sim_seconds < o.sim_seconds,
        "deadline did not shorten wall-clock: {} vs {}",
        t.sim_seconds,
        o.sim_seconds
    );
}

#[test]
fn fleet_scales_to_100k_clients() {
    let mut sim = FleetSim::new(&mobile(0.3, Some(90.0)), 100_000, 1000, 800_000, 60.0, 1)
        .unwrap();
    for _ in 0..3 {
        let r = sim.step();
        assert!(r.online > 1000, "diurnal mobile fleet mostly offline: {}", r.online);
        assert_eq!(r.plan.dispatched.len(), 1300);
        assert!(r.plan.completed.len() <= 1000);
    }
}

// --------------------------------------------- artifact-gated (training)

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn fleet_cfg() -> FedConfig {
    FedConfig {
        model: "mnist_2nn".into(),
        c: 0.5,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 3,
        eval_every: 3,
        seed: 123,
        ..Default::default()
    }
}

fn fleet_opts(workers: usize) -> ServerOptions {
    ServerOptions {
        eval_cap: Some(200),
        fleet: FleetConfig {
            workers,
            ..mobile(0.3, Some(600.0))
        },
        ..Default::default()
    }
}

#[test]
fn parallel_workers_bit_identical_to_sequential() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 31);
    let cfg = fleet_cfg();
    let seq = federated::run(&eng, &fed, &cfg, fleet_opts(1)).unwrap();
    let par = federated::run(&eng, &fed, &cfg, fleet_opts(3)).unwrap();
    assert_eq!(
        seq.final_theta, par.final_theta,
        "--workers 3 diverged from sequential execution"
    );
    assert_eq!(seq.accuracy.points(), par.accuracy.points());
    assert_eq!(seq.fleet, par.fleet, "fleet accounting diverged");
    assert!(seq.fleet.dispatched > 0);
}

#[test]
fn fleet_run_reports_drops_and_differs_from_legacy() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 32);
    let cfg = fleet_cfg();
    let legacy = federated::run(
        &eng,
        &fed,
        &cfg,
        ServerOptions {
            eval_cap: Some(200),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(legacy.fleet, Default::default(), "legacy path touched fleet state");

    let fleet = federated::run(&eng, &fed, &cfg, fleet_opts(1)).unwrap();
    // over-selection dispatched more than it aggregated
    assert!(fleet.fleet.dispatched > fleet.fleet.completed);
    assert_eq!(
        fleet.fleet.completed + fleet.fleet.dropped_stragglers,
        fleet.fleet.dispatched
    );
    // dropped clients waste downlink: down bytes exceed up bytes / asym
    assert!(fleet.comm.bytes_down > fleet.comm.bytes_up);
    // it still learns
    assert!(fleet.accuracy.last_value().unwrap() > 0.1);
}
