//! Integration: the full FedAvg stack (Algorithm 1) over real artifacts.
//!
//! Requires `make artifacts` (skips with a message otherwise). Covers:
//! learning progress, FedSGD-equivalence, determinism, non-IID behaviour,
//! availability injection, one-shot baseline, and the sweep driver.

use fedavg::baselines::oneshot;
use fedavg::comms::TransportConfig;
use fedavg::config::{BatchSize, FedConfig, Partition};
use fedavg::federated::AggConfig;
use fedavg::exper::mnist_fed;
use fedavg::federated::{self, ServerOptions};
use fedavg::runtime::Engine;
use fedavg::sweep::{sweep_lr, LrGrid};

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn base_cfg() -> FedConfig {
    FedConfig {
        model: "mnist_2nn".into(),
        c: 0.5,
        e: 2,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 6,
        eval_every: 2,
        seed: 77,
        ..Default::default()
    }
}

fn opts() -> ServerOptions {
    ServerOptions {
        eval_cap: Some(300),
        ..Default::default()
    }
}

#[test]
fn fedavg_improves_over_rounds() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 1);
    let mut cfg = base_cfg();
    cfg.rounds = 10;
    let res = federated::run(&eng, &fed, &cfg, opts()).unwrap();
    let pts = res.accuracy.points();
    let first = pts.first().unwrap().1;
    let best = res.accuracy.best_value().unwrap();
    assert!(
        best > first + 0.1 || best > 0.9,
        "no learning: first {first:.3}, best {best:.3}"
    );
    // communication accounting matches rounds x clients x model bytes
    let m = cfg.clients_per_round(fed.num_clients()) as u64;
    let expect_up = res.comm.rounds * m * fedavg::comms::model_bytes(199_210);
    assert_eq!(res.comm.bytes_up, expect_up);
}

#[test]
fn runs_are_deterministic_given_seed() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 2);
    let cfg = base_cfg();
    let a = federated::run(&eng, &fed, &cfg, opts()).unwrap();
    let b = federated::run(&eng, &fed, &cfg, opts()).unwrap();
    assert_eq!(a.final_theta, b.final_theta, "non-deterministic run");
    assert_eq!(a.accuracy.points(), b.accuracy.points());
}

#[test]
fn fedsgd_equals_fedavg_e1_full_batch() {
    // The paper's §2 equivalence: one full-batch local step then average
    // == gradient-averaged step. Our FedSGD IS FedAvg(E=1, B=inf); verify
    // the update direction against a manually computed global gradient.
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 3);
    let model = eng.model("mnist_2nn").unwrap();
    let mut cfg = base_cfg().fedsgd();
    cfg.c = 1.0; // all clients
    cfg.rounds = 1;
    cfg.eval_every = 1;
    cfg.lr = 0.2;
    let res = federated::run(&eng, &fed, &cfg, opts()).unwrap();

    // manual: theta0 - lr * grad(f) over the whole training set
    let theta0 = model.init(cfg.seed as i32).unwrap();
    let all: Vec<usize> = (0..fed.train.len()).collect();
    let (g, _) = model.full_gradient(&theta0, &fed.train, &all).unwrap();
    let manual = model.apply(&theta0, &g, cfg.lr as f32).unwrap();

    let dist = fedavg::params::l2_dist(&res.final_theta, &manual);
    let norm = fedavg::params::l2_norm(&manual);
    assert!(
        dist / norm < 1e-4,
        "FedSGD round != global gradient step: rel {}",
        dist / norm
    );
}

#[test]
fn c_zero_means_single_client() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 4);
    let mut cfg = base_cfg();
    cfg.c = 0.0;
    cfg.rounds = 2;
    let res = federated::run(&eng, &fed, &cfg, opts()).unwrap();
    // bytes_up = rounds x ONE client x model bytes
    assert_eq!(
        res.comm.bytes_up,
        2 * fedavg::comms::model_bytes(199_210),
        "C=0 must select exactly one client per round"
    );
}

#[test]
fn noniid_partition_converges_slower_or_noisier() {
    let Some(eng) = engine() else { return };
    let mut cfg = base_cfg();
    cfg.rounds = 8;
    cfg.c = 0.2;
    let iid = federated::run(&eng, &mnist_fed(0.05, Partition::Iid, 5), &cfg, opts()).unwrap();
    let non = federated::run(
        &eng,
        &mnist_fed(0.05, Partition::Pathological(2), 5),
        &cfg,
        opts(),
    )
    .unwrap();
    // the paper's qualitative claim: at equal round budget, pathological
    // non-IID is no better than IID (almost always strictly worse)
    let iid_best = iid.accuracy.best_value().unwrap();
    let non_best = non.accuracy.best_value().unwrap();
    assert!(
        non_best <= iid_best + 0.05,
        "non-IID ({non_best:.3}) unexpectedly beats IID ({iid_best:.3})"
    );
}

#[test]
fn availability_trace_reduces_round_size() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 6);
    let mut cfg = base_cfg();
    cfg.c = 1.0;
    cfg.rounds = 3;
    let mut o = opts();
    o.availability = Some(0.3); // most clients offline
    let res = federated::run(&eng, &fed, &cfg, o).unwrap();
    let full = res.comm.rounds * fed.num_clients() as u64
        * fedavg::comms::model_bytes(199_210);
    assert!(
        res.comm.bytes_up < full,
        "availability filter had no effect on participation"
    );
    assert!(res.comm.bytes_up > 0);
}

#[test]
fn early_stop_on_target() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 7);
    let mut cfg = base_cfg();
    cfg.rounds = 50;
    cfg.eval_every = 1;
    cfg.target_accuracy = Some(0.3); // trivially reachable
    let res = federated::run(&eng, &fed, &cfg, opts()).unwrap();
    assert!(
        res.rounds_run < 50,
        "did not stop early at target ({} rounds)",
        res.rounds_run
    );
}

#[test]
fn oneshot_averaging_runs_and_reports_both_models() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 8);
    let cfg = oneshot::OneShotConfig {
        model: "mnist_2nn".into(),
        epochs: 2,
        batch: BatchSize::Fixed(10),
        lr: 0.1,
        seed: 9,
    };
    let res = oneshot::run(&eng, &fed, &cfg, Some(200)).unwrap();
    assert!(res.averaged.accuracy() > 0.05);
    assert!(res.best_single.accuracy() > 0.05);
}

#[test]
fn lr_sweep_selects_and_flags_interior() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 9);
    let mut cfg = base_cfg();
    cfg.rounds = 4;
    cfg.target_accuracy = Some(0.5);
    let grid = LrGrid::new(0.1, 3, 3);
    let res = sweep_lr(&eng, &fed, &cfg, &grid, |_| opts()).unwrap();
    assert_eq!(res.table.len(), 3);
    assert!(grid.values.contains(&res.best_lr));
}

#[test]
fn token_model_federated_round_runs() {
    let Some(eng) = engine() else { return };
    let fed = fedavg::exper::shakespeare_fed(0.02, true, 10);
    let cfg = FedConfig {
        model: "shakespeare_lstm".into(),
        c: 0.1,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 1.0,
        rounds: 2,
        eval_every: 1,
        seed: 11,
        ..Default::default()
    };
    let mut o = opts();
    o.eval_cap = Some(60);
    let res = federated::run(&eng, &fed, &cfg, o).unwrap();
    assert_eq!(res.rounds_run, 2);
    assert!(res.accuracy.last_value().unwrap() >= 0.0);
}

#[test]
fn mismatched_model_and_dataset_rejected() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 12);
    let cfg = FedConfig {
        model: "shakespeare_lstm".into(), // token model on image data
        rounds: 1,
        ..base_cfg()
    };
    assert!(federated::run(&eng, &fed, &cfg, opts()).is_err());
}

#[test]
fn aggregation_rules_default_bit_identical_variants_run() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 30);
    let mut cfg = base_cfg();
    cfg.rounds = 4;

    // regression: an explicit --agg fedavg (the default AggConfig) must
    // reproduce the default-options run bit-for-bit — trajectory AND
    // byte accounting
    let plain = federated::run(&eng, &fed, &cfg, opts()).unwrap();
    let mut o = opts();
    o.agg = AggConfig {
        spec: "fedavg".into(),
        ..Default::default()
    };
    let explicit = federated::run(&eng, &fed, &cfg, o).unwrap();
    assert_eq!(plain.final_theta, explicit.final_theta, "trajectory diverged");
    assert_eq!(plain.accuracy.points(), explicit.accuracy.points());
    assert_eq!(plain.comm.bytes_up, explicit.comm.bytes_up);
    assert_eq!(plain.comm.bytes_down, explicit.comm.bytes_down);

    // every registry rule trains to a finite model and actually learns
    // on the clean IID workload, each on its unset-η_s default (fedadam
    // resolves to its Adam-scaled 0.01 automatically)
    for spec in ["fedavgm", "fedadam", "trimmed:0.2", "median"] {
        let mut o = opts();
        o.agg.spec = spec.into();
        let res = federated::run(&eng, &fed, &cfg, o).unwrap();
        assert!(
            res.final_theta.iter().all(|v| v.is_finite()),
            "{spec}: non-finite parameters"
        );
        assert!(
            res.accuracy.best_value().unwrap() > 0.15,
            "{spec}: no learning ({:.3})",
            res.accuracy.best_value().unwrap()
        );
        assert_ne!(res.final_theta, plain.final_theta, "{spec}: rule had no effect");
    }

    // FedProx: μ > 0 anchors the trajectory (different from plain) and
    // stays finite
    let mut o = opts();
    o.agg.prox_mu = 0.1;
    let prox = federated::run(&eng, &fed, &cfg, o).unwrap();
    assert!(prox.final_theta.iter().all(|v| v.is_finite()));
    assert_ne!(prox.final_theta, plain.final_theta);

    // robust rules need individual updates: rejected under secure agg
    let mut o = opts();
    o.secure_agg = true;
    o.agg.spec = "median".into();
    assert!(federated::run(&eng, &fed, &cfg, o).is_err());
    // ...and reject mean-calibrated DP noise (order-statistic combines
    // have O(clip) sensitivity, not clip/m)
    let mut o = opts();
    o.dp = Some(fedavg::federated::server::DpConfig {
        clip_norm: 1.0,
        sigma: 0.5,
    });
    o.agg.spec = "trimmed:0.2".into();
    assert!(federated::run(&eng, &fed, &cfg, o).is_err());
    // ...but the server optimizers compose with it (mean-combine)
    let mut o = opts();
    o.secure_agg = true;
    o.agg.spec = "fedavgm".into();
    assert!(federated::run(&eng, &fed, &cfg, o).is_ok());
}

#[test]
fn dp_secure_agg_and_compression_paths() {
    let Some(eng) = engine() else { return };
    let fed = mnist_fed(0.05, Partition::Iid, 20);
    let mut cfg = base_cfg();
    cfg.rounds = 3;
    cfg.c = 0.2;

    // plain baseline
    let plain = federated::run(&eng, &fed, &cfg, opts()).unwrap();
    assert!(plain.epsilon.is_none());

    // secure aggregation: same algorithm, near-identical ONE-round result
    // (fixed-point masking adds <=2^-20/coord; multi-round comparisons
    // amplify chaotically through training, so compare a single round)
    let mut one = cfg.clone();
    one.rounds = 1;
    let plain1 = federated::run(&eng, &fed, &one, opts()).unwrap();
    let mut o = opts();
    o.secure_agg = true;
    let sec = federated::run(&eng, &fed, &one, o).unwrap();
    let dist = fedavg::params::l2_dist(&plain1.final_theta, &sec.final_theta);
    assert!(
        dist < 5e-3,
        "secure agg diverged from plain FedAvg in one round: {dist}"
    );

    // DP: noise applied, epsilon reported and positive
    let mut o = opts();
    o.dp = Some(fedavg::federated::server::DpConfig {
        clip_norm: 1.0,
        sigma: 0.5,
    });
    let dp = federated::run(&eng, &fed, &cfg, o).unwrap();
    let eps = dp.epsilon.expect("epsilon reported");
    assert!(eps > 0.0 && eps.is_finite());
    assert_ne!(dp.final_theta, plain.final_theta);

    // uplink codec: bytes shrink by ~the sparsity factor
    let mut o = opts();
    o.transport = TransportConfig::parse(Some("topk:0.01"), None).unwrap();
    let comp = federated::run(&eng, &fed, &cfg, o).unwrap();
    assert!(
        comp.comm.bytes_up * 20 < plain.comm.bytes_up,
        "top-1% did not shrink uplink: {} vs {}",
        comp.comm.bytes_up,
        plain.comm.bytes_up
    );
    // downlink unchanged (no downlink codec: full dense broadcast)
    assert_eq!(comp.comm.bytes_down, plain.comm.bytes_down);
    // still learns (error feedback keeps signal flowing)
    assert!(comp.accuracy.best_value().unwrap() > 0.2);

    // quantization-only: ~4x uplink shrink at 8 bits
    let mut o = opts();
    o.transport = TransportConfig::parse(Some("q8"), None).unwrap();
    let q = federated::run(&eng, &fed, &cfg, o).unwrap();
    assert!(q.comm.bytes_up * 3 < plain.comm.bytes_up);

    // composed pipeline + delta downlink: scheduler-priced uplink bytes
    // equal the telemetry-reported wire bytes, and the delta downlink
    // undercuts a dense broadcast once clients are repeat contacts
    let mut o = opts();
    o.transport = TransportConfig::parse(Some("topk:0.01|q8"), Some("delta")).unwrap();
    let pipe = o.transport.up.clone().unwrap();
    let mut cfg6 = cfg.clone();
    // 6 rounds x 4 picks over 20 clients: pigeonhole guarantees repeat
    // contacts, which is when the delta downlink pays off
    cfg6.rounds = 6;
    let both = federated::run(&eng, &fed, &cfg6, o).unwrap();
    let m = cfg6.clients_per_round(fed.num_clients()) as u64;
    let dim = both.final_theta.len();
    assert_eq!(
        both.comm.bytes_up,
        both.comm.rounds * m * pipe.plan_bytes(dim),
        "scheduler-priced uplink bytes != reported wire bytes"
    );
    let dense_equiv = both.comm.rounds * m * fedavg::comms::model_bytes(dim);
    assert!(
        both.comm.bytes_down < dense_equiv,
        "delta downlink no smaller than dense: {} vs {}",
        both.comm.bytes_down,
        dense_equiv
    );
    assert!(both.accuracy.best_value().unwrap() > 0.2);
}
