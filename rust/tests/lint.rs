//! Tier-1 pin for `fedavg lint` (DESIGN.md §13): the real tree is
//! clean, and every rule in the catalog both fires on a minimal
//! violating fixture and stays silent on the fixed twin. The fixtures
//! are in-memory so the suite cannot rot when the tree is refactored —
//! only the real-tree check reads the filesystem.

use fedavg::analysis::consistency::{
    check_curve_schema, check_knob_fingerprint, check_snapshot_tags,
};
use fedavg::analysis::{lint_source, lint_tree, Paths};

/// The whole point of the pass: the shipped tree has zero findings.
/// Every `lint:allow` escape hatch in it therefore carries a
/// justification (a bare hatch is itself a finding).
#[test]
fn real_tree_is_clean() {
    let paths = Paths::from_manifest_dir(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    let findings = lint_tree(&paths).expect("lint walk");
    assert!(
        findings.is_empty(),
        "the tree has {} lint finding(s):\n{}",
        findings.len(),
        fedavg::analysis::render_text(&findings)
    );
}

/// Helper: fixture findings for `text` placed at `path`, as
/// `(line, rule)` pairs.
fn run(path: &str, text: &str) -> Vec<(usize, String)> {
    lint_source(path, text)
        .into_iter()
        .map(|f| (f.line, f.rule))
        .collect()
}

// ------------------------------------------------------------ wall-clock

#[test]
fn wall_clock_fires_outside_observation_modules() {
    let bad = "fn f() {\n    let t0 = Instant::now();\n}\n";
    assert_eq!(
        run("rust/src/coordinator/exec.rs", bad),
        vec![(2, "wall-clock".to_string())]
    );
    // same code in an allowlisted observation module: silent
    assert!(run("rust/src/obs/trace.rs", bad).is_empty());
    assert!(run("rust/src/telemetry/mod.rs", bad).is_empty());
    // the deterministic fix: virtual clock, no wall reads
    let good = "fn f() {\n    let t0 = clock.virtual_now();\n}\n";
    assert!(run("rust/src/coordinator/exec.rs", good).is_empty());
    // hatch with justification: silent; the hatch may sit above the line
    let hatched = "fn f() {\n    // lint:allow(wall-clock): latency probe, value discarded\n    let t0 = Instant::now();\n}\n";
    assert!(run("rust/src/coordinator/exec.rs", hatched).is_empty());
}

#[test]
fn wall_clock_ignores_strings_comments_and_tests() {
    let masked = "fn f() {\n    let s = \"Instant::now\"; // Instant::now\n}\n\
                  #[cfg(test)]\nmod tests {\n    fn t() { let t = Instant::now(); }\n}\n";
    assert!(run("rust/src/coordinator/exec.rs", masked).is_empty());
}

// ------------------------------------------------------------ hash-order

#[test]
fn hash_order_fires_on_iteration_not_construction() {
    let bad = "fn f() {\n    let mut m: HashMap<String, u32> = HashMap::new();\n    m.insert(k, v);\n    for (k, v) in m.iter() {\n        use_it(k, v);\n    }\n}\n";
    assert_eq!(
        run("rust/src/coordinator/exec.rs", bad),
        vec![(4, "hash-order".to_string())]
    );
    // construction + keyed lookup only: silent
    let lookup_only =
        "fn f() {\n    let mut m: HashMap<String, u32> = HashMap::new();\n    m.insert(k, v);\n    let x = m.get(&k);\n}\n";
    assert!(run("rust/src/coordinator/exec.rs", lookup_only).is_empty());
    // the deterministic fix: an ordered map iterates freely
    let btree = "fn f() {\n    let mut m: BTreeMap<String, u32> = BTreeMap::new();\n    for (k, v) in m.iter() {\n        use_it(k, v);\n    }\n}\n";
    assert!(run("rust/src/coordinator/exec.rs", btree).is_empty());
}

#[test]
fn hash_order_tracks_bindings_and_struct_fields() {
    let field = "struct S {\n    cache: HashSet<u64>,\n}\nfn f(s: &S) {\n    for x in &s.cache {\n        use_it(x);\n    }\n}\n";
    let f = run("rust/src/coordinator/exec.rs", field);
    assert_eq!(f, vec![(5, "hash-order".to_string())]);
    let hatched = "struct S {\n    cache: HashSet<u64>,\n}\nfn f(s: &S) {\n    // lint:allow(hash-order): drained into a Vec and sorted below\n    for x in &s.cache {\n        use_it(x);\n    }\n}\n";
    assert!(run("rust/src/coordinator/exec.rs", hatched).is_empty());
}

// ------------------------------------------------------------ seeded-rng

#[test]
fn seeded_rng_fires_outside_data_rng() {
    let bad = "fn f() {\n    let mut r = thread_rng();\n}\n";
    assert_eq!(
        run("rust/src/federated/server.rs", bad),
        vec![(2, "seeded-rng".to_string())]
    );
    // the project RNG home may hold ambient-entropy mentions
    assert!(run("rust/src/data/rng.rs", bad).is_empty());
    // the deterministic fix: the seeded project stream
    let good = "fn f() {\n    let mut r = Rng::new(cfg.seed);\n}\n";
    assert!(run("rust/src/federated/server.rs", good).is_empty());
}

// --------------------------------------------------------- panic-surface

#[test]
fn panic_surface_guards_decode_paths_only() {
    let bad = "fn parse(bytes: &[u8]) -> Header {\n    let magic = bytes[0];\n    let v = field.unwrap();\n}\n";
    assert_eq!(
        run("rust/src/comms/wire.rs", bad),
        vec![
            (2, "panic-surface".to_string()),
            (3, "panic-surface".to_string())
        ]
    );
    // the same code outside the audited decode/load files: silent
    assert!(run("rust/src/exper/figures.rs", bad).is_empty());
    // the robust fix: checked access, typed errors
    let good = "fn parse(bytes: &[u8]) -> Result<Header> {\n    let magic = bytes.get(0).ok_or_else(|| anyhow!(\"truncated\"))?;\n    let v = field.ok_or_else(|| anyhow!(\"missing\"))?;\n}\n";
    assert!(run("rust/src/comms/wire.rs", good).is_empty());
    // justified hatch (e.g. a length proven by an ensure! above)
    let hatched = "fn parse(bytes: &[u8]) -> Header {\n    // lint:allow(panic-surface): offset proven in-bounds by the ensure above\n    let magic = bytes[0];\n}\n";
    assert!(run("rust/src/comms/wire.rs", hatched).is_empty());
}

// ------------------------------------------------------------ float-fold

#[test]
fn float_fold_fires_outside_params() {
    let bad = "fn f(xs: &[f32]) -> f32 {\n    xs.iter().sum()\n}\n";
    assert_eq!(
        run("rust/src/federated/aggregate/mod.rs", bad),
        vec![(2, "float-fold".to_string())]
    );
    // params owns the pairwise deterministic reduction: silent there
    assert!(run("rust/src/params/mod.rs", bad).is_empty());
    // order-independent folds are fine anywhere
    let minmax = "fn f(xs: &[f32]) -> f32 {\n    xs.iter().fold(f32::MIN, |a, &b| a.max(b))\n}\n";
    assert!(run("rust/src/federated/aggregate/mod.rs", minmax).is_empty());
    // integer folds are fine anywhere
    let ints = "fn f(xs: &[u64]) -> u64 {\n    xs.iter().sum()\n}\n";
    assert!(run("rust/src/federated/aggregate/mod.rs", ints).is_empty());
}

// ------------------------------------------------------------- hot-alloc

#[test]
fn hot_alloc_fires_in_audited_hot_paths_only() {
    let bad = "fn combine(&mut self) {\n    let mut acc = Vec::new();\n    let snap = theta.to_vec();\n    let d = delta.clone();\n}\n";
    assert_eq!(
        run("rust/src/comms/transport.rs", bad),
        vec![
            (2, "hot-alloc".to_string()),
            (3, "hot-alloc".to_string()),
            (4, "hot-alloc".to_string()),
        ]
    );
    // the same code outside the audited files: silent
    assert!(run("rust/src/federated/server.rs", bad).is_empty());
    assert!(run("rust/src/comms/wire.rs", bad).is_empty());
    // the scratch-reuse fix: sized setup, newtype ctors, reuse via clear
    let good = "fn combine(&mut self) {\n    let mut acc = Vec::with_capacity(n);\n    self.scratch.clear();\n    let p = ParamVec::new();\n    let it = xs.iter().cloned();\n}\n";
    assert!(run("rust/src/comms/transport.rs", good).is_empty());
    // a justified ownership boundary: silent
    let hatched = "fn publish(&mut self) {\n    // lint:allow(hot-alloc): retained versions must outlive the caller's buffer\n    self.versions.push_back((v, theta.to_vec()));\n}\n";
    assert!(run("rust/src/comms/transport.rs", hatched).is_empty());
}

#[test]
fn hot_alloc_ignores_test_regions() {
    let in_test = "#[cfg(test)]\nmod tests {\n    fn t() { let v = xs.to_vec(); }\n}\n";
    assert!(run("rust/src/params/mod.rs", in_test).is_empty());
}

// -------------------------------------------------------------- bad-allow

#[test]
fn bare_or_unjustified_hatches_are_findings() {
    for bad in [
        "x(); // lint:allow\n",
        "x(); // lint:allow(wall-clock)\n",
        "x(); // lint:allow(wall-clock):\n",
        "x(); // lint:allow(): no rule\n",
    ] {
        let f = run("rust/src/coordinator/exec.rs", bad);
        assert_eq!(f, vec![(1, "bad-allow".to_string())], "fixture: {bad:?}");
    }
    let good = "x(); // lint:allow(wall-clock): justified reason here\n";
    assert!(run("rust/src/coordinator/exec.rs", good).is_empty());
}

// ------------------------------------------------------ cross-file rules

#[test]
fn knob_fingerprint_catches_unclassified_and_unfingerprinted_knobs() {
    let server_ok = "let meta = RunMeta {\n    label: cfg.label(),\n    seed: cfg.seed,\n    harness: format!(\"data={}\", data_fp),\n};\n";
    // a brand-new flag with no table row
    let main = "args.check_known(&[\"model\", \"totally-new-knob\"])?;\n";
    let f = check_knob_fingerprint("rust/src/main.rs", main, server_ok);
    assert!(
        f.iter().any(|f| f.rule == "knob-fingerprint" && f.message.contains("--totally-new-knob")),
        "{f:?}"
    );
    // a fingerprinted flag whose token fell out of the RunMeta block
    let main = "args.check_known(&[\"model\", \"partition\"])?;\n";
    let server_missing = "let meta = RunMeta {\n    label: cfg.label(),\n};\n";
    let f = check_knob_fingerprint("rust/src/main.rs", main, server_missing);
    assert!(
        f.iter().any(|f| f.message.contains("--partition") && f.message.contains("data_fp")),
        "{f:?}"
    );
    // same flags against the complete block: silent (stale-row findings
    // aside, which this tiny fixture necessarily produces)
    let f = check_knob_fingerprint("rust/src/main.rs", main, server_ok);
    assert!(
        !f.iter().any(|f| f.message.contains("--partition") && f.message.contains("does not appear")),
        "{f:?}"
    );
}

#[test]
fn snapshot_tags_catch_unread_and_dead_sections() {
    let good = "const SEC_META: u16 = 1;\nfn section(out: &mut W, id: u16, body: W) {}\nSelf::section(&mut out, SEC_META, w);\nSEC_META => meta = Some(x),\n";
    assert!(check_snapshot_tags("rust/src/runstate/snapshot.rs", good).is_empty());
    // written but never dispatched on read → resume drops state
    let unread = "const SEC_NEW: u16 = 13;\nSelf::section(&mut out, SEC_NEW, w);\n";
    let f = check_snapshot_tags("rust/src/runstate/snapshot.rs", unread);
    assert!(
        f.iter().any(|f| f.rule == "snapshot-tags" && f.message.contains("no reader dispatch arm")),
        "{f:?}"
    );
    // declared but never written/read → dead tag
    let dead = "const SEC_GHOST: u16 = 99;\n";
    let f = check_snapshot_tags("rust/src/runstate/snapshot.rs", dead);
    assert!(f.iter().any(|f| f.message.contains("dead tag")), "{f:?}");
}

#[test]
fn curve_schema_requires_documented_columns() {
    let telem = "const CURVE_HEADER: &str = \"round,acc,shiny_new_col\";\n";
    let readme = "| `round` | the round |\n| `acc` | test accuracy |\n";
    let f = check_curve_schema("rust/src/telemetry/mod.rs", telem, readme);
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].rule == "curve-schema" && f[0].message.contains("shiny_new_col"));
    let documented = "| `round` | x |\n| `acc` | y |\n| `shiny_new_col` | z |\n";
    assert!(check_curve_schema("rust/src/telemetry/mod.rs", telem, documented).is_empty());
}

// ----------------------------------------------------------- report shape

#[test]
fn findings_render_as_file_line_rule_message() {
    let f = lint_source(
        "rust/src/coordinator/exec.rs",
        "fn f() {\n    let t = Instant::now();\n}\n",
    );
    let text = fedavg::analysis::render_text(&f);
    assert!(
        text.starts_with("rust/src/coordinator/exec.rs:2 wall-clock "),
        "{text:?}"
    );
}
